"""AOT pipeline tests: manifest structure, weight offsets, golden vectors,
and HLO-text sanity — everything the rust runtime relies on."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from compile import aot, model

OUT = "/tmp/tas_aot_test"


@pytest.fixture(scope="module")
def built():
    """Build a miniature artifact set once for the whole module."""
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", OUT,
         "--buckets", "1x32,2x32", "--vocab", "512", "--hidden", "128",
         "--layers", "2", "--heads", "4", "--ffn", "256"],
        check=True, cwd=os.path.dirname(os.path.dirname(__file__)), env=env,
    )
    with open(os.path.join(OUT, "manifest.json")) as f:
        return json.load(f)


def test_manifest_shape(built):
    assert built["version"] == 1
    names = [a["name"] for a in built["artifacts"]]
    assert "bert_b1_s32" in names and "bert_b2_s32" in names
    assert any(n.startswith("linear_is_os") for n in names)
    assert any(n.startswith("linear_ws_os") for n in names)


def test_hlo_text_parseable(built):
    for a in built["artifacts"]:
        path = os.path.join(OUT, a["hlo"])
        head = open(path).read(200)
        assert head.startswith("HloModule"), f"{a['name']}: {head[:40]!r}"
        # return_tuple=True: the root computation must return a tuple
        text = open(path).read()
        assert "tuple(" in text or "ROOT" in text


def test_weight_offsets_consistent(built):
    """Offsets+nbytes tile weights.bin without overlap past the end."""
    size = os.path.getsize(os.path.join(OUT, built["weights_bin"]))
    spans = set()
    for a in built["artifacts"]:
        for arg in a["args"]:
            if arg["kind"] == "weight":
                off, nb = arg["offset"], arg["nbytes"]
                assert off + nb <= size
                spans.add((off, nb))
                want = int(np.prod(arg["shape"])) * 4
                assert nb == want, (arg["name"], nb, want)
    # shared checkpoint: bert buckets must reference identical offsets
    berts = [a for a in built["artifacts"] if a["kind"] == "bert"]
    w0 = [(g["name"], g["offset"]) for g in berts[0]["args"]
          if g["kind"] == "weight"]
    w1 = [(g["name"], g["offset"]) for g in berts[1]["args"]
          if g["kind"] == "weight"]
    assert w0 == w1


def test_golden_vectors_match_oracle(built):
    """Re-run the oracle on the stored golden input; must equal the file."""
    cfg = model.TinyBertConfig(vocab=512, hidden=128, n_layers=2, n_heads=4,
                               ffn=256)
    params = model.init_params(cfg, seed=0)
    art = next(a for a in built["artifacts"] if a["name"] == "bert_b1_s32")
    ids = np.fromfile(os.path.join(OUT, art["golden"]["input"]),
                      dtype=np.int32).reshape(1, 32)
    want = np.fromfile(os.path.join(OUT, art["golden"]["output"]),
                       dtype=np.float32).reshape(art["outputs"][0]["shape"])
    import jax.numpy as jnp
    got = np.asarray(model.ref_tiny_bert(params, jnp.asarray(ids),
                                         cfg.n_heads))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_weights_bin_roundtrip(built):
    """Reading emb back from weights.bin reproduces init_params."""
    cfg = model.TinyBertConfig(vocab=512, hidden=128, n_layers=2, n_heads=4,
                               ffn=256)
    params = model.init_params(cfg, seed=0)
    art = next(a for a in built["artifacts"] if a["kind"] == "bert")
    emb_arg = next(g for g in art["args"] if g["name"] == "emb")
    with open(os.path.join(OUT, built["weights_bin"]), "rb") as f:
        f.seek(emb_arg["offset"])
        raw = np.frombuffer(f.read(emb_arg["nbytes"]), dtype=np.float32)
    np.testing.assert_array_equal(
        raw.reshape(emb_arg["shape"]), np.asarray(params["emb"]))


def test_flops_positive_and_monotonic(built):
    berts = sorted((a for a in built["artifacts"] if a["kind"] == "bert"),
                   key=lambda a: a["batch"] * a["seq"])
    flops = [a["flops"] for a in berts]
    assert all(f > 0 for f in flops)
    assert flops == sorted(flops)
