"""Tiled attention kernel vs the pure-jnp oracle (composition claim, §V)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn
from compile.kernels import ref

RNG = np.random.default_rng(55)


def _rand(shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


def ref_attention(q, k, v):
    d = q.shape[-1]
    s = (q @ k.T) / jnp.sqrt(d).astype(q.dtype)
    return ref.softmax(s, axis=-1) @ v


@settings(max_examples=20, deadline=None)
@given(
    s_blocks=st.integers(1, 4),
    bq=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32]),
    d=st.sampled_from([16, 32, 64]),
)
def test_attention_matches_ref(s_blocks, bq, bk, d):
    S = s_blocks * max(bq, bk)
    if S % bq or S % bk:
        return  # block combo does not tile this S
    q, k, v = _rand((S, d)), _rand((S, d)), _rand((S, d))
    got = attn.attention(q, k, v, bq=bq, bk=bk)
    want = ref_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_single_block_degenerate():
    q, k, v = _rand((16, 32)), _rand((16, 32)), _rand((16, 32))
    got = attn.attention(q, k, v, bq=16, bk=16)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref_attention(q, k, v)),
                               rtol=1e-5, atol=1e-5)


def test_online_softmax_handles_large_logits():
    # numerical stability: huge score magnitudes must not overflow
    q = _rand((32, 16)) * 100.0
    k = _rand((32, 16)) * 100.0
    v = _rand((32, 16))
    got = np.asarray(attn.attention(q, k, v, bq=16, bk=16))
    assert np.isfinite(got).all()
    want = np.asarray(ref_attention(q, k, v))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_mha_vmap_wrapper():
    B, H, S, d = 2, 3, 32, 16
    q = _rand((B, H, S, d))
    k = _rand((B, H, S, d))
    v = _rand((B, H, S, d))
    got = attn.mha_attention(q, k, v)
    assert got.shape == (B, H, S, d)
    want = jax.vmap(jax.vmap(ref_attention))(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_indivisible_blocks_rejected():
    q, k, v = _rand((48, 16)), _rand((48, 16)), _rand((48, 16))
    with pytest.raises(ValueError, match="divide"):
        attn.attention(q, k, v, bq=32, bk=16)


def test_composes_with_tas_projections():
    """The §V composition: TAS linear kernels produce Q/K/V, the tiled
    attention kernel consumes them; end-to-end equals the pure oracle."""
    from compile.kernels import tiled_matmul as tm
    S, H = 32, 64
    x = _rand((S, H))
    wq, wk, wv = _rand((H, H)), _rand((H, H)), _rand((H, H))
    b0 = jnp.zeros((H,), jnp.float32)
    q = tm.linear(x, wq, b0, bm=16, bn=16, bk=16)  # TAS picks scheme
    k = tm.linear(x, wk, b0, bm=16, bn=16, bk=16)
    v = tm.linear(x, wv, b0, bm=16, bn=16, bk=16)
    got = attn.attention(q, k, v, bq=16, bk=16)
    want = ref_attention(ref.linear(x, wq, b0), ref.linear(x, wk, b0),
                         ref.linear(x, wv, b0))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)
