"""L1 correctness: every Pallas stationary scheme vs the pure-jnp oracle.

hypothesis sweeps shapes, block sizes and dtypes; each scheme must produce
bit-close results — the dataflow changes the *schedule*, never the math.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import tiled_matmul as tm

RNG = np.random.default_rng(1234)


def _rand(shape, dtype=np.float32):
    a = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(a, dtype=dtype)


def _tol(dtype):
    # bf16 psums accumulate in bf16 across grid revisits (one rounding per
    # contraction step), so the tolerance is wider than a single-cast ref.
    return dict(rtol=1e-1, atol=1e-1) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-5)


# a grid-dim strategy: (blocks, block_size) so divisibility always holds
dims = st.tuples(st.integers(1, 4), st.sampled_from([8, 16, 32]))


@settings(max_examples=30, deadline=None)
@given(m=dims, n=dims, k=dims, scheme=st.sampled_from(tm.SCHEMES))
def test_matmul_matches_ref(m, n, k, scheme):
    (gm, bm), (gn, bn), (gk, bk) = m, n, k
    M, N, K = gm * bm, gn * bn, gk * bk
    x, w = _rand((M, N)), _rand((N, K))
    got = tm.matmul(x, w, scheme=scheme, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.matmul(x, w)),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(m=dims, n=dims, k=dims, scheme=st.sampled_from(tm.SCHEMES),
       act=st.sampled_from([None, "gelu", "relu"]))
def test_linear_matches_ref(m, n, k, scheme, act):
    (gm, bm), (gn, bn), (gk, bk) = m, n, k
    M, N, K = gm * bm, gn * bn, gk * bk
    x, w, b = _rand((M, N)), _rand((N, K)), _rand((K,))
    got = tm.linear(x, w, b, scheme=scheme, act=act, bm=bm, bn=bn, bk=bk)
    want = ref.linear(x, w, b, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("scheme", tm.SCHEMES)
def test_dtypes(scheme, dtype):
    x, w = _rand((32, 64), dtype), _rand((64, 32), dtype)
    got = tm.matmul(x, w, scheme=scheme, bm=16, bn=16, bk=16)
    want = ref.matmul(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("scheme", tm.SCHEMES)
def test_single_block_grid(scheme):
    """Degenerate 1x1x1 grid: the psum-init branch runs exactly once."""
    x, w = _rand((16, 16)), _rand((16, 16))
    got = tm.matmul(x, w, scheme=scheme, bm=16, bn=16, bk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.matmul(x, w)),
                               rtol=1e-5, atol=1e-5)


def test_indivisible_tiling_rejected():
    x, w = _rand((30, 32)), _rand((32, 32))
    with pytest.raises(ValueError, match="tile sizes must divide"):
        tm.matmul(x, w, scheme="is_os", bm=16, bn=16, bk=16)


@settings(max_examples=50, deadline=None)
@given(M=st.integers(1, 100_000), K=st.integers(1, 100_000),
       N=st.integers(1, 10_000))
def test_choose_scheme_is_ema_argmin(M, K, N):
    """The rule sign(N*(M-K)) must pick the smaller stationary matrix."""
    scheme = tm.choose_scheme(M, K)
    input_ema, weight_ema = M * N, N * K  # stationary-matrix EMA (Table II)
    if scheme == "is_os":
        assert input_ema < weight_ema
    else:
        assert weight_ema <= input_ema


def test_default_blocks_divide():
    for d in (1, 7, 32, 100, 128, 250, 384, 1024):
        M, N, K = d, d * 2, max(1, d // 2)
        bm, bn, bk = tm.default_blocks(M, N, K)
        assert M % bm == 0 and N % bn == 0 and K % bk == 0
        assert bm <= 512 and bn <= 1024 and bk <= 1024
