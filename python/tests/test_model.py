"""L2 correctness: the Pallas-backed model equals its pure-jnp twin, and the
trace-time TAS scheme plan obeys the paper's decision rule."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref
from compile.kernels import tiled_matmul as tm

CFG = model.TinyBertConfig(vocab=512, hidden=128, n_layers=2, n_heads=4,
                           ffn=256, max_len=128)
PARAMS = model.init_params(CFG, seed=7)
RNG = np.random.default_rng(7)


def _x(B, S):
    return jnp.asarray(RNG.standard_normal((B, S, CFG.hidden),
                                           ).astype(np.float32))


def _ids(B, S):
    return jnp.asarray(RNG.integers(0, CFG.vocab, (B, S), dtype=np.int32))


@pytest.mark.parametrize("B,S", [(1, 32), (2, 32), (1, 64)])
def test_mha_matches_ref(B, S):
    x = _x(B, S)
    got = model.mha(PARAMS["layers"][0]["attn"], x, CFG.n_heads)
    want = ref.mha(PARAMS["layers"][0]["attn"], x, CFG.n_heads)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,S", [(1, 32), (2, 64)])
def test_encoder_layer_matches_ref(B, S):
    x = _x(B, S)
    got = model.encoder_layer(PARAMS["layers"][0], x, CFG.n_heads)
    want = ref.encoder_layer(PARAMS["layers"][0], x, CFG.n_heads)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,S", [(1, 32), (2, 32), (1, 128)])
def test_tiny_bert_matches_ref(B, S):
    ids = _ids(B, S)
    got = model.tiny_bert(PARAMS, ids, CFG.n_heads)
    want = model.ref_tiny_bert(PARAMS, ids, CFG.n_heads)
    assert got.shape == (B, S, CFG.vocab)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_scheme_plan_rule():
    # M=64 tokens < every K -> all input-stationary
    assert set(model.scheme_plan(CFG, 64).values()) == {"is_os"}
    # M=512 >= hidden(128)/ffn(256)/vocab(512) -> all weight-stationary
    assert set(model.scheme_plan(CFG, 512).values()) == {"ws_os"}
    # mixed regime: M=256 >= hidden(128) and >= ffn(256), < vocab(512)
    plan = model.scheme_plan(CFG, 256)
    assert plan["qkv"] == "ws_os"
    assert plan["ffn1"] == "ws_os"
    assert plan["lm_head"] == "is_os"


def test_scheme_plan_consistent_with_kernel_rule():
    for m in (1, 64, 128, 256, 512, 4096):
        plan = model.scheme_plan(CFG, m)
        assert plan["qkv"] == tm.choose_scheme(m, CFG.hidden)
        assert plan["ffn1"] == tm.choose_scheme(m, CFG.ffn)
        assert plan["lm_head"] == tm.choose_scheme(m, CFG.vocab)


def test_init_params_deterministic():
    p1 = model.init_params(CFG, seed=3)
    p2 = model.init_params(CFG, seed=3)
    np.testing.assert_array_equal(np.asarray(p1["emb"]),
                                  np.asarray(p2["emb"]))
    p3 = model.init_params(CFG, seed=4)
    assert not np.array_equal(np.asarray(p1["emb"]), np.asarray(p3["emb"]))
