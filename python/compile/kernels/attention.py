"""L1 extension: a tiled (flash-style) attention Pallas kernel.

The paper's §I/§V position TAS as *complementary* to attention
optimisations — TAS handles the linear projections, a tiled attention
kernel handles the S×S score matrix.  This kernel demonstrates the
composition: Q/K/V arrive from TAS-scheduled projections, and attention
itself runs as an online-softmax tile sweep so the score matrix never
materialises in (simulated) HBM — the attention analogue of the paper's
psum-window idea: a stationary Q block sweeps K/V tiles while the
reduction state (running max m, normaliser l, accumulator) stays
resident, exactly like TAS keeps psums in registers.

State is carried across the KV grid axis in auxiliary *outputs* whose
index_map ignores the KV index — the same revisited-block accumulation
the matmul kernels use (persistent in interpret mode).

Single-head, 2D (seq, d) per call; vmap over (batch, head) at L2.
interpret=True — see tiled_matmul.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale, n_kv_steps):
    """One (q-block, kv-block) step of online-softmax attention."""
    kv_idx = pl.program_id(1)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    correction = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * correction + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * correction[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv_idx == n_kv_steps - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


def attention(q, k, v, *, bq=None, bk=None):
    """Tiled softmax(q·kᵀ/√d)·v.  q,k,v: [S, d] (S divisible by blocks)."""
    S, d = q.shape
    assert k.shape == (S, d) and v.shape == (S, d), (q.shape, k.shape, v.shape)
    bq = bq or min(S, 64)
    bk = bk or min(S, 64)
    if S % bq or S % bk:
        raise ValueError(f"block sizes must divide S: {S} % ({bq},{bk})")
    n_kv = S // bk
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(_attn_kernel, scale=scale, n_kv_steps=n_kv)
    q_block = pl.BlockSpec((bq, d), lambda i, j: (i, 0))  # stationary over j
    out, _m, _l, _acc = pl.pallas_call(
        kernel,
        grid=(S // bq, n_kv),
        in_specs=[
            q_block,
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            q_block,                                   # o
            pl.BlockSpec((bq,), lambda i, j: (i,)),    # running max m
            pl.BlockSpec((bq,), lambda i, j: (i,)),    # normaliser l
            q_block,                                   # accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, d), q.dtype),
            jax.ShapeDtypeStruct((S,), jnp.float32),
            jax.ShapeDtypeStruct((S,), jnp.float32),
            jax.ShapeDtypeStruct((S, d), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)
    return out


def mha_attention(q, k, v):
    """Multi-head wrapper: q,k,v [B, H, S, d] -> [B, H, S, d]."""
    return jax.vmap(jax.vmap(attention))(q, k, v)
