"""Pure-jnp reference oracle for every kernel and for the L2 model.

This module is the single source of numerical truth: the Pallas kernels in
``tiled_matmul.py`` and the model in ``model.py`` are tested against these
functions (pytest + hypothesis).  Nothing here may import pallas.
"""

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# primitive ops
# ---------------------------------------------------------------------------

def matmul(x, w):
    """out[M,K] = x[M,N] @ w[N,K] — paper notation: N is the contraction dim."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def gelu(x):
    """tanh-approximation GELU (BERT's variant)."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def linear(x, w, b=None, act=None):
    """Dense layer: matmul + optional bias + optional activation."""
    y = matmul(x, w)
    if b is not None:
        y = y + b
    if act == "gelu":
        y = gelu(y)
    elif act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act is not None:
        raise ValueError(f"unknown activation {act!r}")
    return y


def layer_norm(x, gamma, beta, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def softmax(x, axis=-1):
    x = x - jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x)
    return e / jnp.sum(e, axis=axis, keepdims=True)


# ---------------------------------------------------------------------------
# transformer reference (mirrors model.py exactly, pure jnp)
# ---------------------------------------------------------------------------

def mha(p, x, n_heads):
    """Multi-head self-attention. x: [B, S, H]."""
    B, S, H = x.shape
    d = H // n_heads
    x2 = x.reshape(B * S, H)
    q = (x2 @ p["wq"] + p["bq"]).reshape(B, S, n_heads, d).transpose(0, 2, 1, 3)
    k = (x2 @ p["wk"] + p["bk"]).reshape(B, S, n_heads, d).transpose(0, 2, 1, 3)
    v = (x2 @ p["wv"] + p["bv"]).reshape(B, S, n_heads, d).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k) / jnp.sqrt(d).astype(x.dtype)
    probs = softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,bhtd->bhsd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(B * S, H)
    return (ctx @ p["wo"] + p["bo"]).reshape(B, S, H)


def encoder_layer(p, x, n_heads):
    """Post-LN transformer encoder layer (BERT style). x: [B, S, H]."""
    h = x + mha(p["attn"], x, n_heads)
    h = layer_norm(h, p["ln1_g"], p["ln1_b"])
    B, S, H = h.shape
    h2 = h.reshape(B * S, H)
    ff = gelu(h2 @ p["ffn_w1"] + p["ffn_b1"])
    ff = ff @ p["ffn_w2"] + p["ffn_b2"]
    h = h + ff.reshape(B, S, H)
    return layer_norm(h, p["ln2_g"], p["ln2_b"])


def tiny_bert(p, ids, n_heads):
    """Tiny BERT-like encoder: ids [B, S] int32 -> logits [B, S, vocab]."""
    x = p["emb"][ids] + p["pos"][: ids.shape[1]][None, :, :]
    for lp in p["layers"]:
        x = encoder_layer(lp, x, n_heads)
    x = layer_norm(x, p["lnf_g"], p["lnf_b"])
    B, S, H = x.shape
    logits = x.reshape(B * S, H) @ p["emb"].T
    return logits.reshape(B, S, -1)
