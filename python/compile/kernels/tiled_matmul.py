"""L1: the paper's tile dataflows as Pallas kernels.

The paper schedules a GEMM ``out[M,K] = in[M,N] @ w[N,K]`` (N is the
contraction dim) over an (m, n, k)-tiled PE array with one of four
stationary schemes.  In Pallas the schedule is the *grid iteration order*
plus the BlockSpec ``index_map``s:

  scheme   grid (slowest..fastest)   stationary block
  -------  ------------------------  ----------------------------------
  os_row   (i over M, j over K, r)   output block (i, j): r innermost,
                                     psum never leaves VMEM (Fig. 1d)
  os_col   (j over K, i over M, r)   output block, column-major (Fig. 1e)
  is_os    (i over M, r over N, j)   INPUT block (i, r): constant in the
                                     fastest axis j  (paper Fig. 2a)
  ws_os    (j over K, r over N, i)   WEIGHT block (r, j): constant in the
                                     fastest axis i  (paper Fig. 2b)

For is_os / ws_os the output block (i, j) is revisited across the r axis —
that is exactly the paper's hybrid: temporal IS/WS reuse of the stationary
operand plus spatial OS reuse of a row (resp. column) of partial sums, so
DRAM is never read and written concurrently inside a psum pass.

TPU adaptation (DESIGN.md §3): "internal SRAM" maps to VMEM residency —
the stationary operand is the block whose index_map ignores the fastest
grid axis, which Mosaic keeps resident; the psum registers map to the
revisited accumulator block.  interpret=True always (CPU PJRT cannot run
Mosaic custom-calls); on a real TPU these kernels are compile-only.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SCHEMES = ("os_row", "os_col", "is_os", "ws_os")

#: grid-axis position of the contraction (r) axis for each scheme.
_CONTRACT_AXIS = {"os_row": 2, "os_col": 2, "is_os": 1, "ws_os": 1}


def choose_scheme(M, K):
    """The TAS decision rule (§III-A): sign of MN - NK = N(M - K).

    M < K  -> the input matrix is smaller -> keep the input stationary.
    M >= K -> the weight matrix is smaller -> keep the weight stationary.
    """
    return "is_os" if M < K else "ws_os"


def _index_maps(scheme):
    """(x_map, w_map, o_map) from grid indices to block indices."""
    if scheme == "os_row":       # grid = (i, j, r)
        return (lambda i, j, r: (i, r),
                lambda i, j, r: (r, j),
                lambda i, j, r: (i, j))
    if scheme == "os_col":       # grid = (j, i, r)
        return (lambda j, i, r: (i, r),
                lambda j, i, r: (r, j),
                lambda j, i, r: (i, j))
    if scheme == "is_os":        # grid = (i, r, j): x block fixed over j
        return (lambda i, r, j: (i, r),
                lambda i, r, j: (r, j),
                lambda i, r, j: (i, j))
    if scheme == "ws_os":        # grid = (j, r, i): w block fixed over i
        return (lambda j, r, i: (i, r),
                lambda j, r, i: (r, j),
                lambda j, r, i: (i, j))
    raise ValueError(f"unknown scheme {scheme!r}")


def _grid(scheme, gm, gn, gk):
    if scheme == "os_row":
        return (gm, gk, gn)
    if scheme == "os_col":
        return (gk, gm, gn)
    if scheme == "is_os":
        return (gm, gn, gk)
    if scheme == "ws_os":
        return (gk, gn, gm)
    raise ValueError(f"unknown scheme {scheme!r}")


def _mm_kernel(x_ref, w_ref, o_ref, *, contract_axis):
    r = pl.program_id(contract_axis)

    @pl.when(r == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, contract_axis, n_steps, act):
    r = pl.program_id(contract_axis)

    @pl.when(r == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)

    @pl.when(r == n_steps - 1)
    def _epilogue():
        y = o_ref[...] + b_ref[...]
        if act == "gelu":
            c = jnp.sqrt(2.0 / jnp.pi).astype(y.dtype)
            y = 0.5 * y * (1.0 + jnp.tanh(c * (y + 0.044715 * y**3)))
        elif act == "relu":
            y = jnp.maximum(y, 0.0)
        o_ref[...] = y


def _check_tiling(M, N, K, bm, bn, bk):
    if M % bm or N % bn or K % bk:
        raise ValueError(
            f"tile sizes must divide the GEMM: ({M},{N},{K}) % ({bm},{bn},{bk})"
        )


def default_blocks(M, N, K):
    """MXU-friendly block shapes.

    Targets (512, 1024, 1024): at tiny-BERT serving shapes this folds
    most projections into a single MXU-aligned dot per pallas call —
    under interpret=True every extra grid step is pure scheduling
    overhead (§Perf iterations 2-4 measured 1009 -> 6492 tok/s E2E).
    Kernels that demonstrate the tile dataflow pass explicit small
    blocks instead (the linear_* artifacts and the pytest suite); on a
    real TPU the block ceiling is the VMEM budget, not this target.
    """
    def pick(d, target):
        b = min(d, target)
        while d % b:
            b -= 1
        return b
    return pick(M, 512), pick(N, 1024), pick(K, 1024)


def matmul(x, w, *, scheme="os_row", bm=None, bn=None, bk=None):
    """Tiled matmul under the given stationary scheme.  x:[M,N], w:[N,K]."""
    M, N = x.shape
    N2, K = w.shape
    assert N == N2, (x.shape, w.shape)
    dbm, dbn, dbk = default_blocks(M, N, K)
    bm, bn, bk = bm or dbm, bn or dbn, bk or dbk
    _check_tiling(M, N, K, bm, bn, bk)
    gm, gn, gk = M // bm, N // bn, K // bk
    xm, wm, om = _index_maps(scheme)
    ca = _CONTRACT_AXIS[scheme]
    return pl.pallas_call(
        functools.partial(_mm_kernel, contract_axis=ca),
        grid=_grid(scheme, gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bn), xm),
            pl.BlockSpec((bn, bk), wm),
        ],
        out_specs=pl.BlockSpec((bm, bk), om),
        out_shape=jax.ShapeDtypeStruct((M, K), x.dtype),
        interpret=True,
    )(x, w)


def linear(x, w, b, *, scheme=None, act=None, bm=None, bn=None, bk=None):
    """TAS dense layer: scheme auto-selected by the paper's rule when None.

    The bias add + activation run in the kernel epilogue on the last psum
    revisit — the partial sums never travel to DRAM (the OS half of the
    hybrid), matching §III-B.
    """
    M, N = x.shape
    N2, K = w.shape
    assert N == N2 and b.shape == (K,), (x.shape, w.shape, b.shape)
    if scheme is None:
        scheme = choose_scheme(M, K)
    dbm, dbn, dbk = default_blocks(M, N, K)
    bm, bn, bk = bm or dbm, bn or dbn, bk or dbk
    _check_tiling(M, N, K, bm, bn, bk)
    gm, gn, gk = M // bm, N // bn, K // bk
    xm, wm, om = _index_maps(scheme)
    ca = _CONTRACT_AXIS[scheme]
    bmap = {
        "os_row": (lambda i, j, r: (j,)),
        "os_col": (lambda j, i, r: (j,)),
        "is_os": (lambda i, r, j: (j,)),
        "ws_os": (lambda j, r, i: (j,)),
    }[scheme]
    return pl.pallas_call(
        functools.partial(_linear_kernel, contract_axis=ca, n_steps=gn, act=act),
        grid=_grid(scheme, gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bn), xm),
            pl.BlockSpec((bn, bk), wm),
            pl.BlockSpec((bk,), bmap),
        ],
        out_specs=pl.BlockSpec((bm, bk), om),
        out_shape=jax.ShapeDtypeStruct((M, K), x.dtype),
        interpret=True,
    )(x, w, b)
