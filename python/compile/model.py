"""L2: tiny BERT-like encoder whose every linear projection goes through the
L1 Pallas TAS kernel (``kernels.tiled_matmul.linear``).

The stationary scheme of each projection is selected at trace time by the
paper's rule ``choose_scheme(M, K)`` with M = B*S (token count) and K = the
projection's output width — exactly the decision the rust coordinator makes
per request bucket.  ``scheme_plan`` exposes that choice so the AOT manifest
can record which dataflow each artifact embeds.

Build-time only: this module is lowered once by ``aot.py`` and never
imported on the request path.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels import tiled_matmul as tm


class TinyBertConfig:
    """Model hyper-parameters. All dims divide the Pallas block shapes."""

    def __init__(self, vocab=1024, hidden=256, n_layers=4, n_heads=4,
                 ffn=1024, max_len=512):
        assert hidden % n_heads == 0
        self.vocab = vocab
        self.hidden = hidden
        self.n_layers = n_layers
        self.n_heads = n_heads
        self.ffn = ffn
        self.max_len = max_len

    def __repr__(self):
        return (f"TinyBertConfig(vocab={self.vocab}, hidden={self.hidden}, "
                f"n_layers={self.n_layers}, n_heads={self.n_heads}, "
                f"ffn={self.ffn}, max_len={self.max_len})")


def init_params(cfg, seed=0):
    """Deterministic random init (numpy, so the checkpoint is reproducible)."""
    rng = np.random.default_rng(seed)

    def mat(*shape, scale=None):
        scale = scale if scale is not None else (shape[0] ** -0.5)
        return jnp.asarray(
            rng.standard_normal(shape, dtype=np.float32) * scale)

    def layer():
        h, f = cfg.hidden, cfg.ffn
        return {
            "attn": {
                "wq": mat(h, h), "bq": jnp.zeros((h,), jnp.float32),
                "wk": mat(h, h), "bk": jnp.zeros((h,), jnp.float32),
                "wv": mat(h, h), "bv": jnp.zeros((h,), jnp.float32),
                "wo": mat(h, h), "bo": jnp.zeros((h,), jnp.float32),
            },
            "ffn_w1": mat(h, f), "ffn_b1": jnp.zeros((f,), jnp.float32),
            "ffn_w2": mat(f, h), "ffn_b2": jnp.zeros((h,), jnp.float32),
            "ln1_g": jnp.ones((h,), jnp.float32),
            "ln1_b": jnp.zeros((h,), jnp.float32),
            "ln2_g": jnp.ones((h,), jnp.float32),
            "ln2_b": jnp.zeros((h,), jnp.float32),
        }

    return {
        "emb": mat(cfg.vocab, cfg.hidden, scale=0.02),
        "pos": mat(cfg.max_len, cfg.hidden, scale=0.02),
        "layers": [layer() for _ in range(cfg.n_layers)],
        "lnf_g": jnp.ones((cfg.hidden,), jnp.float32),
        "lnf_b": jnp.zeros((cfg.hidden,), jnp.float32),
    }


def scheme_plan(cfg, n_tokens):
    """Which stationary scheme TAS picks for each projection at M=n_tokens."""
    h, f, v = cfg.hidden, cfg.ffn, cfg.vocab
    return {
        "qkv": tm.choose_scheme(n_tokens, h),
        "attn_out": tm.choose_scheme(n_tokens, h),
        "ffn1": tm.choose_scheme(n_tokens, f),
        "ffn2": tm.choose_scheme(n_tokens, h),
        "lm_head": tm.choose_scheme(n_tokens, v),
    }


def _linear(x2, w, b, act=None):
    """All projections funnel through the L1 TAS kernel."""
    return tm.linear(x2, w, b, act=act)


def mha(p, x, n_heads):
    """Multi-head self-attention; projections via the Pallas TAS kernel."""
    B, S, H = x.shape
    d = H // n_heads
    x2 = x.reshape(B * S, H)
    q = _linear(x2, p["wq"], p["bq"]).reshape(B, S, n_heads, d).transpose(0, 2, 1, 3)
    k = _linear(x2, p["wk"], p["bk"]).reshape(B, S, n_heads, d).transpose(0, 2, 1, 3)
    v = _linear(x2, p["wv"], p["bv"]).reshape(B, S, n_heads, d).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k) / jnp.sqrt(d).astype(x.dtype)
    probs = ref.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,bhtd->bhsd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(B * S, H)
    return _linear(ctx, p["wo"], p["bo"]).reshape(B, S, H)


def encoder_layer(p, x, n_heads):
    """Post-LN encoder layer; FFN matmuls via the Pallas TAS kernel."""
    h = x + mha(p["attn"], x, n_heads)
    h = ref.layer_norm(h, p["ln1_g"], p["ln1_b"])
    B, S, H = h.shape
    h2 = h.reshape(B * S, H)
    ff = _linear(h2, p["ffn_w1"], p["ffn_b1"], act="gelu")
    ff = _linear(ff, p["ffn_w2"], p["ffn_b2"])
    h = h + ff.reshape(B, S, H)
    return ref.layer_norm(h, p["ln2_g"], p["ln2_b"])


def tiny_bert(p, ids, n_heads):
    """ids [B, S] int32 -> logits [B, S, vocab]; lm head via TAS kernel."""
    x = p["emb"][ids] + p["pos"][: ids.shape[1]][None, :, :]
    for lp in p["layers"]:
        x = encoder_layer(lp, x, n_heads)
    x = ref.layer_norm(x, p["lnf_g"], p["lnf_b"])
    B, S, H = x.shape
    wv = p["emb"].T  # tied embedding lm head: [H, vocab]
    logits = tm.matmul(x.reshape(B * S, H), wv,
                       scheme=tm.choose_scheme(B * S, wv.shape[1]))
    return logits.reshape(B, S, -1)


def ref_tiny_bert(p, ids, n_heads):
    """Pure-jnp twin of tiny_bert (oracle for tests and golden vectors)."""
    return ref.tiny_bert(p, ids, n_heads)
