"""AOT compile path: lower the L2 model (with its L1 Pallas kernels) to HLO
*text* artifacts the rust runtime loads via PJRT.

Emits, under ``--out`` (default ``../artifacts``):

  manifest.json          — artifact index: arg shapes/dtypes, weight offsets,
                           scheme plan, golden-vector paths
  weights.bin            — little-endian raw tensor data (shared checkpoint)
  golden/<name>.{in,out}.bin — sample input and oracle output per artifact
  <name>.hlo.txt         — one HLO module per (batch, seq) bucket + kernels

HLO **text** (never ``.serialize()``): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once at build time (``make artifacts``); never on the request path.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref
from .kernels import tiled_matmul as tm

#: (batch, seq) buckets the coordinator routes requests into.  M = B*S spans
#: 32..512 so TAS picks different schemes across buckets (vocab=1024 head is
#: is_os everywhere; qkv/ffn2 against K=256 flip at M=256).  Every seq class
#: carries multiple batch sizes — the §Perf pass showed that a seq bucket
#: with only batch=1 compiled degenerates the coordinator to unbatched
#: serving (EXPERIMENTS.md §Perf, iteration 1).
DEFAULT_BUCKETS = (
    (1, 32), (4, 32), (8, 32),
    (1, 64), (2, 64), (4, 64), (8, 64),
    (1, 128), (2, 128), (4, 128),
)

DTYPE_NAMES = {np.dtype(np.float32): "f32", np.dtype(np.int32): "i32"}


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


class WeightsBin:
    """Append-only little-endian tensor store shared by all artifacts."""

    def __init__(self):
        self.chunks = []
        self.nbytes = 0
        self._memo = {}  # id(array) -> offset
        self._refs = []  # keep arrays alive so ids are never recycled

    def add(self, arr):
        key = id(arr)
        if key in self._memo:
            return self._memo[key]
        self._refs.append(arr)
        data = np.ascontiguousarray(np.asarray(arr))
        if data.dtype == np.float64:
            data = data.astype(np.float32)
        off = self.nbytes
        self.chunks.append(data.tobytes())
        self.nbytes += data.nbytes
        self._memo[key] = off
        return off

    def write(self, path):
        with open(path, "wb") as f:
            for c in self.chunks:
                f.write(c)


def _arg_entry(name, arr, kind, offset=None):
    a = np.asarray(arr)
    e = {
        "name": name,
        "kind": kind,
        "dtype": DTYPE_NAMES[a.dtype],
        "shape": list(a.shape),
    }
    if offset is not None:
        e["offset"] = offset
        e["nbytes"] = a.nbytes
    return e


def _flatten_params(params):
    """Deterministic (path-name, leaf) list via jax tree flattening."""
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    out = []
    for path, leaf in leaves:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        out.append((name, leaf))
    return out


def _write_bin(path, arr):
    np.ascontiguousarray(np.asarray(arr)).tofile(path)


def lower_artifact(fn, example_args, name, out_dir):
    """jit-lower fn at the example shapes and write <name>.hlo.txt."""
    specs = [jax.ShapeDtypeStruct(np.asarray(a).shape, np.asarray(a).dtype)
             for a in example_args]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return path, len(text)


def build_linear_artifacts(wb, out_dir, rng):
    """Standalone TAS-linear artifacts (runtime micro-bench + validation)."""
    arts = []
    shapes = [
        (64, 256, 1024, "is_os"),    # M < K  -> input stationary
        (512, 256, 128, "ws_os"),    # M >= K -> weight stationary
    ]
    for M, N, K, expect in shapes:
        scheme = tm.choose_scheme(M, K)
        assert scheme == expect, (M, K, scheme, expect)
        x = rng.standard_normal((M, N), dtype=np.float32)
        w = rng.standard_normal((N, K), dtype=np.float32) * (N ** -0.5)
        b = rng.standard_normal((K,), dtype=np.float32) * 0.1
        name = f"linear_{scheme}_{M}x{N}x{K}"

        def fn(xx, ww, bb):
            # explicit paper-faithful tiling: these two artifacts are the
            # dataflow showcase (the serving berts use coarse blocks for
            # CPU throughput — §Perf iterations 2-4)
            return (tm.linear(xx, ww, bb, act="gelu", bm=64, bn=64, bk=64),)

        lower_artifact(fn, (x, w, b), name, out_dir)
        gold = np.asarray(ref.linear(jnp.asarray(x), jnp.asarray(w),
                                     jnp.asarray(b), act="gelu"))
        gin = os.path.join("golden", f"{name}.in.bin")
        gout = os.path.join("golden", f"{name}.out.bin")
        _write_bin(os.path.join(out_dir, gin), x)
        _write_bin(os.path.join(out_dir, gout), gold)
        arts.append({
            "name": name,
            "hlo": f"{name}.hlo.txt",
            "kind": "linear",
            "scheme": scheme,
            "args": [
                _arg_entry("x", x, "input"),
                _arg_entry("w", w, "weight", wb.add(w)),
                _arg_entry("b", b, "weight", wb.add(b)),
            ],
            "outputs": [{"dtype": "f32", "shape": [M, K]}],
            "flops": 2 * M * N * K,
            "golden": {"input": gin, "output": gout},
        })
    return arts


def build_bert_artifacts(cfg, params, wb, out_dir, rng, buckets):
    """One HLO module per (batch, seq) bucket over the shared checkpoint."""
    flat = _flatten_params(params)
    weight_args = [_arg_entry(n, a, "weight", wb.add(a)) for n, a in flat]
    leaves = [a for _, a in flat]
    treedef = jax.tree_util.tree_structure(params)

    arts = []
    for B, S in buckets:
        name = f"bert_b{B}_s{S}"
        ids = rng.integers(0, cfg.vocab, (B, S), dtype=np.int32)

        def fn(*args):
            *ws, ii = args
            p = jax.tree_util.tree_unflatten(treedef, ws)
            return (model.tiny_bert(p, ii, cfg.n_heads),)

        lower_artifact(fn, (*leaves, ids), name, out_dir)
        gold = np.asarray(model.ref_tiny_bert(params, jnp.asarray(ids),
                                              cfg.n_heads))
        gin = os.path.join("golden", f"{name}.in.bin")
        gout = os.path.join("golden", f"{name}.out.bin")
        _write_bin(os.path.join(out_dir, gin), ids)
        _write_bin(os.path.join(out_dir, gout), gold)
        n_tokens = B * S
        flops = model_flops(cfg, B, S)
        arts.append({
            "name": name,
            "hlo": f"{name}.hlo.txt",
            "kind": "bert",
            "batch": B,
            "seq": S,
            "args": weight_args + [_arg_entry("ids", ids, "input")],
            "outputs": [{"dtype": "f32", "shape": [B, S, cfg.vocab]}],
            "schemes": model.scheme_plan(cfg, n_tokens),
            "flops": flops,
            "golden": {"input": gin, "output": gout},
        })
    return arts


def model_flops(cfg, B, S):
    """2*M*N*K over every projection (linear projections only, like EMA)."""
    M = B * S
    h, f, v = cfg.hidden, cfg.ffn, cfg.vocab
    per_layer = 2 * M * h * h * 4 + 2 * M * h * f + 2 * M * f * h
    attn = 2 * B * cfg.n_heads * S * S * (h // cfg.n_heads) * 2
    return cfg.n_layers * (per_layer + attn) + 2 * M * h * v


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--buckets", default=None,
                    help="comma list like 1x32,2x64 (default: built-in set)")
    ap.add_argument("--vocab", type=int, default=1024)
    ap.add_argument("--hidden", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--ffn", type=int, default=1024)
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out)
    os.makedirs(os.path.join(out_dir, "golden"), exist_ok=True)

    buckets = DEFAULT_BUCKETS
    if args.buckets:
        buckets = tuple(tuple(map(int, b.split("x")))
                        for b in args.buckets.split(","))

    cfg = model.TinyBertConfig(vocab=args.vocab, hidden=args.hidden,
                               n_layers=args.layers, n_heads=args.heads,
                               ffn=args.ffn)
    params = model.init_params(cfg, seed=args.seed)
    rng = np.random.default_rng(args.seed + 1)
    wb = WeightsBin()

    artifacts = []
    artifacts += build_linear_artifacts(wb, out_dir, rng)
    artifacts += build_bert_artifacts(cfg, params, wb, out_dir, rng, buckets)

    wb.write(os.path.join(out_dir, "weights.bin"))
    manifest = {
        "version": 1,
        "weights_bin": "weights.bin",
        "model": {
            "vocab": cfg.vocab, "hidden": cfg.hidden,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "ffn": cfg.ffn, "max_len": cfg.max_len,
        },
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    total = sum(os.path.getsize(os.path.join(out_dir, a["hlo"]))
                for a in artifacts)
    print(f"wrote {len(artifacts)} artifacts ({total/1e6:.1f} MB HLO), "
          f"weights.bin {wb.nbytes/1e6:.1f} MB -> {out_dir}")


if __name__ == "__main__":
    main()
