//! Reimplementation of the Ayaka [9] fixed-dataflow baseline for Table IV.
//!
//! Ayaka (Qin et al., JSSC 2024) is a versatile transformer accelerator
//! with a *fixed* heterogeneous dataflow: each operator class is assigned
//! one stationary scheme at design time, tuned for a nominal model, and
//! the linear projections run weight-stationary — the weight matrix is
//! resident while input activations stream per output element.  Because
//! the choice is input-length independent (§I), the streaming operand is
//! re-fetched at element granularity:
//!
//! * weights: read once (`N·K` words — the WS win),
//! * inputs: re-read once per output column (`K · M·N` words),
//!
//! i.e. read-EMA ≈ `MNK + NK` vs naive's `2MNK` — about half, matching
//! the ≈48% average energy reduction the paper attributes to [9] in
//! Table IV.  (Substitution note: we cannot run Ayaka's silicon; this
//! closed form reproduces its published *behaviour class* — fixed WS,
//! length-independent — which is all Table IV's comparison needs.  See
//! DESIGN.md §4.)
//!
//! Its second published weakness (§I): the fixed dataflow forces psum
//! spill traffic, so reads and writes interleave at DRAM — modelled by
//! [`ayaka_turnaround_class`].

use crate::gemm::GemmShape;
use crate::models::GemmWorkload;

/// Read-direction EMA (words) of one GEMM under Ayaka's fixed dataflow.
pub fn ayaka_fixed_read_ema(shape: &GemmShape) -> u64 {
    shape.macs() + shape.weight_words()
}

/// Read-EMA over a workload.
pub fn ayaka_workload_read_ema(gemms: &[GemmWorkload]) -> u64 {
    gemms
        .iter()
        .map(|g| g.count * ayaka_fixed_read_ema(&g.shape))
        .sum()
}

/// Concurrent-R/W behaviour class: Ayaka's spilling dataflow switches
/// DRAM direction once per output row of psums; the proposed hybrids
/// only at psum-window completion.  Returns the switch-count ratio
/// (Ayaka / TAS) for a GEMM — used by the communication-efficiency bench
/// ("nearly twice the efficiency", §I).
pub fn ayaka_turnaround_class(shape: &GemmShape, tile: u64, kp: u64) -> f64 {
    // Ayaka: one write burst per (row-block, contraction-step): (M/m)(N/n)
    let spills = (shape.m.div_ceil(tile)) * (shape.n.div_ceil(tile));
    // Hybrid: one write burst per psum window: (M/m)(K/k')
    let windows = (shape.m.div_ceil(tile)) * (shape.k.div_ceil(kp.max(1)));
    spills as f64 / windows.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::Scheme;
    use crate::energy::{read_ema_words, workload_read_ema};
    use crate::gemm::Tiling;
    use crate::models::bert_base;

    #[test]
    fn ayaka_is_roughly_half_of_naive() {
        // Table IV column B: ≈48% reduction vs naive, per layer.
        let gemms = bert_base().linear_gemms(384);
        let naive = workload_read_ema(Scheme::Naive, &gemms, &Tiling::square(16));
        let ayaka = ayaka_workload_read_ema(&gemms);
        let reduction = 1.0 - ayaka as f64 / naive as f64;
        assert!(
            (0.44..0.52).contains(&reduction),
            "Ayaka reduction {reduction}"
        );
    }

    #[test]
    fn tas_doubles_ayaka_efficiency() {
        // §IV: "double the energy efficiency compared to [9]" — the
        // reduction ratio goes 48% -> 97%.
        let gemms = bert_base().linear_gemms(384);
        let t = Tiling::square(16);
        let naive = workload_read_ema(Scheme::Naive, &gemms, &t) as f64;
        let ayaka = ayaka_workload_read_ema(&gemms) as f64;
        let tas = workload_read_ema(Scheme::Tas, &gemms, &t) as f64;
        let red_ayaka = 1.0 - ayaka / naive;
        let red_tas = 1.0 - tas / naive;
        assert!(red_tas / red_ayaka > 1.8, "{red_tas} vs {red_ayaka}");
        assert!(red_tas > 0.95);
    }

    #[test]
    fn ayaka_read_ema_closed_form() {
        let s = GemmShape::new(10, 20, 30);
        assert_eq!(ayaka_fixed_read_ema(&s), 10 * 20 * 30 + 20 * 30);
    }

    #[test]
    fn turnaround_class_favors_hybrid() {
        let s = GemmShape::new(384, 768, 768);
        let ratio = ayaka_turnaround_class(&s, 16, 256);
        assert!(ratio > 4.0, "ratio {ratio}");
    }

    #[test]
    fn ayaka_beats_naive_but_loses_to_tiled_ws() {
        // sanity ordering: naive > ayaka(element WS) > tiled WS reads
        let s = GemmShape::new(512, 1024, 1024);
        let t = Tiling::square(16);
        let naive = read_ema_words(Scheme::Naive, &s, &t);
        let ayaka = ayaka_fixed_read_ema(&s);
        let ws = read_ema_words(Scheme::Ws, &s, &t);
        assert!(naive > ayaka && ayaka > ws);
    }
}
