//! Energy model + the Ayaka [9] fixed-dataflow baseline (Table IV).
//!
//! §IV: *"the energy consumed by external data transmission is 10 to 100
//! times greater than that of internal chip computation.  To simplify the
//! effective simulation of computing energy costs, measurements can be
//! efficiently taken by evaluating the EMA ratio across various stationary
//! schemes."*  We implement both levels:
//!
//! * [`EnergyModel`] — full pJ accounting (DRAM/SRAM/MAC) for absolute
//!   numbers and ablations;
//! * [`read_ema_words`] — the paper's EMA-ratio proxy used to regenerate
//!   Table IV's reduction columns.  Operand *reads* stall the pipeline and
//!   dominate; write traffic shows up as turnaround stalls instead (§II-d).

pub mod ayaka;

pub use ayaka::ayaka_fixed_read_ema;

use crate::config::EnergyConfig;
use crate::dataflow::{ema, Plan, Scheme};
use crate::gemm::{GemmShape, Tiling};
use crate::models::GemmWorkload;

/// Read-direction EMA in words for one GEMM under `scheme` — the paper's
/// Table IV accounting unit.
///
/// * `Naive` reads every operand per MAC: `2·MNK` words.
/// * Tiled schemes read `input + weight` of the Table II breakdown (the
///   output column is write traffic).
pub fn read_ema_words(scheme: Scheme, shape: &GemmShape, tiling: &Tiling) -> u64 {
    match scheme.resolve(shape) {
        Scheme::Naive => 2 * shape.macs(),
        s => {
            let e = ema(s, shape, tiling);
            e.input + e.weight
        }
    }
}

/// Full energy accounting for one GEMM under one scheme.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyCost {
    pub dram_pj: f64,
    pub sram_pj: f64,
    pub mac_pj: f64,
}

impl EnergyCost {
    pub fn total_pj(&self) -> f64 {
        self.dram_pj + self.sram_pj + self.mac_pj
    }

    pub fn total_mj(&self) -> f64 {
        self.total_pj() / 1e9
    }
}

/// Energy model: converts dataflow statistics into pJ.
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyModel {
    pub cfg: EnergyConfig,
}

impl EnergyModel {
    pub fn new(cfg: EnergyConfig) -> Self {
        EnergyModel { cfg }
    }

    /// Energy of one GEMM: EMA words × DRAM cost + internal traffic.
    ///
    /// Internal accounting: each MAC reads two operands from SRAM and
    /// updates a psum register (≈3 short-wire accesses folded into
    /// `reg_pj`), independent of the external scheme.
    pub fn gemm_energy(&self, scheme: Scheme, shape: &GemmShape, tiling: &Tiling) -> EnergyCost {
        let e = ema(scheme.resolve(shape), shape, tiling);
        let macs = shape.macs() as f64;
        EnergyCost {
            dram_pj: self.cfg.dram_pj * e.total() as f64,
            sram_pj: self.cfg.sram_pj * 2.0 * macs + self.cfg.reg_pj * macs,
            mac_pj: self.cfg.mac_pj * macs,
        }
    }

    /// Energy of one GEMM under a schedule [`Plan`] — the per-tile TAS
    /// counterpart of [`EnergyModel::gemm_energy`].  `dram_words` is the
    /// plan's replayed (or closed-form) Table II word count; internal
    /// SRAM/MAC terms depend only on the MAC count, exactly as in the
    /// fixed-scheme path.
    pub fn plan_energy(&self, plan: &Plan, dram_words: u64) -> EnergyCost {
        self.traffic_energy(plan.shape.macs(), dram_words)
    }

    /// Energy from raw MAC and DRAM word counts — the unit a sharded
    /// device reports ([`crate::sim::shard`]): its MACs and EMA are
    /// partial sums of the plan's, and the same formula applies per
    /// device.  Inter-chip link energy is accounted separately by
    /// [`crate::arch::Interconnect::transfer_energy_pj`].
    pub fn traffic_energy(&self, macs: u64, dram_words: u64) -> EnergyCost {
        let macs = macs as f64;
        EnergyCost {
            dram_pj: self.cfg.dram_pj * dram_words as f64,
            sram_pj: self.cfg.sram_pj * 2.0 * macs + self.cfg.reg_pj * macs,
            mac_pj: self.cfg.mac_pj * macs,
        }
    }

    /// Energy over a whole workload (e.g. one model forward pass).
    pub fn workload_energy(
        &self,
        scheme: Scheme,
        gemms: &[GemmWorkload],
        tiling: &Tiling,
    ) -> EnergyCost {
        let mut total = EnergyCost::default();
        for g in gemms {
            let c = self.gemm_energy(scheme, &g.shape, tiling);
            total.dram_pj += c.dram_pj * g.count as f64;
            total.sram_pj += c.sram_pj * g.count as f64;
            total.mac_pj += c.mac_pj * g.count as f64;
        }
        total
    }
}

/// Read-EMA over a whole workload under `scheme` (Table IV proxy).
pub fn workload_read_ema(scheme: Scheme, gemms: &[GemmWorkload], tiling: &Tiling) -> u64 {
    gemms
        .iter()
        .map(|g| g.count * read_ema_words(scheme, &g.shape, tiling))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::bert_base;

    fn t() -> Tiling {
        Tiling::square(16)
    }

    #[test]
    fn naive_read_ema_is_2mnk() {
        let s = GemmShape::new(384, 768, 768);
        assert_eq!(read_ema_words(Scheme::Naive, &s, &t()), 2 * s.macs());
    }

    #[test]
    fn tas_read_ema_is_tiny_fraction_of_naive() {
        // The Table IV headline: ≈97% reduction per BERT-Base layer.
        let gemms = bert_base().linear_gemms(384);
        let naive = workload_read_ema(Scheme::Naive, &gemms, &t());
        let tas = workload_read_ema(Scheme::Tas, &gemms, &t());
        let reduction = 1.0 - tas as f64 / naive as f64;
        assert!(
            (0.95..0.99).contains(&reduction),
            "TAS reduction {reduction}"
        );
    }

    #[test]
    fn dram_dominates_full_energy_for_naive() {
        let m = EnergyModel::new(EnergyConfig::default());
        let c = m.gemm_energy(Scheme::Naive, &GemmShape::new(128, 256, 256), &t());
        assert!(c.dram_pj > 10.0 * (c.sram_pj + c.mac_pj));
    }

    #[test]
    fn tas_flips_the_balance_to_internal() {
        let m = EnergyModel::new(EnergyConfig::default());
        let shape = GemmShape::new(384, 768, 768);
        let naive = m.gemm_energy(Scheme::Naive, &shape, &t());
        let tas = m.gemm_energy(Scheme::Tas, &shape, &t());
        assert!(tas.total_pj() < 0.1 * naive.total_pj());
        // internal terms identical — the scheme only moves DRAM cost
        assert_eq!(tas.sram_pj, naive.sram_pj);
        assert_eq!(tas.mac_pj, naive.mac_pj);
    }

    #[test]
    fn workload_energy_linear_in_count() {
        let m = EnergyModel::new(EnergyConfig::default());
        let g1 = vec![GemmWorkload {
            name: "x",
            shape: GemmShape::new(64, 64, 64),
            count: 1,
        }];
        let g5 = vec![GemmWorkload { count: 5, ..g1[0].clone() }];
        let e1 = m.workload_energy(Scheme::Tas, &g1, &t()).total_pj();
        let e5 = m.workload_energy(Scheme::Tas, &g5, &t()).total_pj();
        assert!((e5 - 5.0 * e1).abs() < 1e-6 * e5);
    }
}
