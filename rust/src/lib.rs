//! # TAS — Tile-based Adaptive Stationary for Transformer Accelerators
//!
//! Reproduction of Li & Chang, *"An Efficient Data Reuse with Tile-Based
//! Adaptive Stationary for Transformer Accelerators"* (2025) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the accelerator-side system: dataflow schedule
//!   generators for every stationary scheme (Fig. 1/2), the analytic EMA
//!   model (Table II), a trace-driven accelerator simulator, the
//!   transformer workload zoo, the Ayaka-style energy model, and a
//!   serving coordinator that applies the TAS decision rule per request
//!   bucket and executes real numerics through PJRT.
//! * **L2/L1 (python/, build-time only)** — a tiny-BERT JAX model whose
//!   linear projections run through Pallas kernels implementing the very
//!   same tile dataflows, AOT-lowered to `artifacts/*.hlo.txt`.
//!
//! See DESIGN.md for the system inventory and experiment index, and
//! EXPERIMENTS.md for paper-vs-measured numbers.

pub mod arch;
pub mod config;
pub mod coordinator;
pub mod dataflow;
pub mod sim;
pub mod energy;
pub mod gemm;
pub mod models;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod util;
