//! Deterministic pseudo-random number generation.
//!
//! The build environment vendors no `rand` crate, so the simulator, the
//! property-test harness ([`crate::util::check`]) and the workload
//! generators share this self-contained xoshiro256** implementation
//! (Blackman & Vigna, 2018).  Everything seeded is reproducible across
//! runs and platforms — a hard requirement for the paper-table benches.

/// xoshiro256** PRNG with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that any `u64` (including 0) is a good seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        // Lemire's nearly-divisionless method.
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    pub fn gen_in(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "gen_in: {lo} > {hi}");
        lo + self.gen_range(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(f64::MIN_POSITIVE);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given underlying mu/sigma.
    pub fn gen_lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.gen_normal()).exp()
    }

    /// Random f32 in `[-1, 1)` (test-data generator).
    pub fn gen_f32_signed(&mut self) -> f32 {
        (self.gen_f64() * 2.0 - 1.0) as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(7);
        for n in [1u64, 2, 3, 10, 1000, u64::MAX] {
            for _ in 0..200 {
                assert!(r.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut r = Rng::new(9);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.gen_range(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
