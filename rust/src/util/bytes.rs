//! Little-endian binary I/O for the weights checkpoint and golden vectors
//! written by `python/compile/aot.py` (raw `numpy.tofile` blobs).

use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

/// Read a whole file as raw f32 little-endian values.
pub fn read_f32_file(path: &Path) -> Result<Vec<f32>> {
    let data = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    f32_from_le(&data)
}

/// Read a whole file as raw i32 little-endian values.
pub fn read_i32_file(path: &Path) -> Result<Vec<i32>> {
    let data = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    if data.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.display(), data.len());
    }
    Ok(data
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Decode a byte slice as f32 little-endian.
pub fn f32_from_le(data: &[u8]) -> Result<Vec<f32>> {
    if data.len() % 4 != 0 {
        bail!("byte length {} not a multiple of 4", data.len());
    }
    Ok(data
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read `nbytes` at `offset` from an open file.
pub fn read_slice(file: &mut std::fs::File, offset: u64, nbytes: usize) -> Result<Vec<u8>> {
    use std::io::Seek;
    file.seek(std::io::SeekFrom::Start(offset))?;
    let mut buf = vec![0u8; nbytes];
    file.read_exact(&mut buf)?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn f32_roundtrip() {
        let vals = [1.5f32, -2.25, 0.0, f32::MAX];
        let bytes: Vec<u8> =
            vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        assert_eq!(f32_from_le(&bytes).unwrap(), vals);
    }

    #[test]
    fn rejects_ragged() {
        assert!(f32_from_le(&[0, 1, 2]).is_err());
    }

    #[test]
    fn file_slice_reads() {
        let dir = std::env::temp_dir().join("tas_bytes_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.bin");
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(&[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        drop(f);
        let mut f = std::fs::File::open(&p).unwrap();
        assert_eq!(read_slice(&mut f, 2, 4).unwrap(), vec![3, 4, 5, 6]);
    }
}
