//! Self-contained JSON parser + writer (no `serde` in the vendored set).
//!
//! Parses the artifact `manifest.json` written by `python/compile/aot.py`
//! and serialises metric reports.  Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (the manifest is pure ASCII).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as `f64` plus the raw text so integer
/// round-trips (byte offsets!) stay exact up to u64.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest parsing convenience.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialise compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.into(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let c = self
                        .peek()
                        .ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e1").unwrap(), Json::Num(-25.0));
        assert_eq!(
            Json::parse(r#""a\nb""#).unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
    }

    #[test]
    fn u64_offsets_roundtrip_exactly() {
        let v = Json::parse("15395328").unwrap();
        assert_eq!(v.as_u64(), Some(15_395_328));
        let big = Json::parse("9007199254740991").unwrap(); // 2^53 - 1
        assert_eq!(big.as_u64(), Some((1u64 << 53) - 1));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }
}
