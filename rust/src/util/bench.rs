//! Criterion-like micro-bench harness (no `criterion` in the vendored set).
//!
//! Benches are plain binaries under `rust/benches/` with `harness = false`;
//! they call [`Bench::run`] which warms up, sizes the iteration count to a
//! target measurement time, reports mean/p50/p99 and a throughput line, and
//! appends machine-readable rows to `target/tas-bench.csv` so EXPERIMENTS.md
//! numbers are reproducible.

use super::stats::Summary;
use std::hint::black_box;
use std::time::{Duration, Instant};

/// Re-export so benches write `bench::black_box(..)`.
pub use std::hint::black_box as bb;

pub struct Bench {
    /// Suite name, prefixed to every benchmark id.
    pub suite: String,
    /// Warm-up time per benchmark.
    pub warmup: Duration,
    /// Target measurement time per benchmark.
    pub measure: Duration,
    /// Collected results (id, mean_ns, p50_ns, p99_ns, iters, throughput).
    pub results: Vec<BenchResult>,
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub id: String,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub iters: u64,
    /// Optional items/second derived from `Throughput`.
    pub per_sec: Option<f64>,
}

/// Units processed per iteration, for a derived rate report.
#[derive(Clone, Copy, Debug)]
pub enum Throughput {
    None,
    Elements(u64),
    Bytes(u64),
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        // Honour quick runs: TAS_BENCH_FAST=1 trims times for CI smoke.
        let fast = std::env::var("TAS_BENCH_FAST").is_ok();
        Bench {
            suite: suite.to_string(),
            warmup: Duration::from_millis(if fast { 20 } else { 300 }),
            measure: Duration::from_millis(if fast { 80 } else { 1500 }),
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, which must consume its output via `bb(..)` itself or
    /// return a value (we black-box the return).
    pub fn run<T, F: FnMut() -> T>(&mut self, id: &str, tput: Throughput, mut f: F) {
        // Warm-up and calibration: find iterations per sample.
        let wu_start = Instant::now();
        let mut wu_iters = 0u64;
        while wu_start.elapsed() < self.warmup {
            black_box(f());
            wu_iters += 1;
        }
        let est_ns = (self.warmup.as_nanos() as f64 / wu_iters.max(1) as f64)
            .max(1.0);
        // ~100 samples over the measurement window, >=1 iter per sample.
        let samples = 100u64;
        let per_sample = ((self.measure.as_nanos() as f64
            / (samples as f64 * est_ns))
            .ceil() as u64)
            .max(1);

        let mut summary = Summary::default();
        let mut total_iters = 0u64;
        let m_start = Instant::now();
        for _ in 0..samples {
            let t0 = Instant::now();
            for _ in 0..per_sample {
                black_box(f());
            }
            let dt = t0.elapsed().as_nanos() as f64 / per_sample as f64;
            summary.push(dt);
            total_iters += per_sample;
            if m_start.elapsed() > self.measure * 2 {
                break; // guard against miscalibration on slow benches
            }
        }

        let per_sec = match tput {
            Throughput::None => None,
            Throughput::Elements(n) | Throughput::Bytes(n) => {
                Some(n as f64 * 1e9 / summary.mean())
            }
        };
        let result = BenchResult {
            id: format!("{}/{}", self.suite, id),
            mean_ns: summary.mean(),
            p50_ns: summary.p50().unwrap_or(f64::NAN),
            p99_ns: summary.p99().unwrap_or(f64::NAN),
            iters: total_iters,
            per_sec,
        };
        self.report(&result, tput);
        self.results.push(result);
    }

    fn report(&self, r: &BenchResult, tput: Throughput) {
        let rate = match (r.per_sec, tput) {
            (Some(v), Throughput::Bytes(_)) => {
                format!("  {:>10.1} MiB/s", v / (1024.0 * 1024.0))
            }
            (Some(v), _) => format!("  {:>12.0} elem/s", v),
            _ => String::new(),
        };
        println!(
            "{:<56} {:>12} /iter  p50 {:>10}  p99 {:>10}{}",
            r.id,
            fmt_ns(r.mean_ns),
            fmt_ns(r.p50_ns),
            fmt_ns(r.p99_ns),
            rate
        );
    }

    /// Append all results to `target/tas-bench.csv`.
    pub fn write_csv(&self) {
        use std::io::Write;
        let path = std::path::Path::new("target").join("tas-bench.csv");
        let new = !path.exists();
        if let Ok(mut f) = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
        {
            if new {
                let _ = writeln!(f, "id,mean_ns,p50_ns,p99_ns,iters,per_sec");
            }
            for r in &self.results {
                let _ = writeln!(
                    f,
                    "{},{:.1},{:.1},{:.1},{},{}",
                    r.id,
                    r.mean_ns,
                    r.p50_ns,
                    r.p99_ns,
                    r.iters,
                    r.per_sec.map(|v| format!("{v:.1}")).unwrap_or_default()
                );
            }
        }
    }
}

/// Human-format a nanosecond duration.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_records() {
        std::env::set_var("TAS_BENCH_FAST", "1");
        let mut b = Bench::new("unit");
        let mut acc = 0u64;
        b.run("noop", Throughput::Elements(1), || {
            acc = acc.wrapping_add(1);
            acc
        });
        assert_eq!(b.results.len(), 1);
        let r = &b.results[0];
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 0);
        assert!(r.per_sec.unwrap() > 0.0);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.20 s");
    }
}
