//! Minimal property-test harness (the environment vendors no `proptest`).
//!
//! [`property`] runs a closure over `n` randomly generated cases from a
//! seeded [`Rng`]; on failure it re-runs a simple input-shrinking loop and
//! reports the smallest failing seed so the case reproduces exactly:
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath in this environment)
//! use tas::util::check::property;
//! property("addition commutes", 256, |rng| {
//!     let (a, b) = (rng.gen_range(1000), rng.gen_range(1000));
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::prng::Rng;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Base seed for all property runs; override with `TAS_CHECK_SEED`.
fn base_seed() -> u64 {
    std::env::var("TAS_CHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Case-count floor from the environment: `PROPTEST_CASES=256` (the
/// conventional proptest knob) raises every property to at least that
/// many cases — the weekly CI deep-fuzz job uses it to push allocator
/// and planner edge cases far past the PR-speed defaults.  Tests that
/// already request more cases keep their own count.
fn case_floor() -> u64 {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0)
}

/// Run `f` over `cases` seeded RNGs; panic with the failing seed on error.
pub fn property<F: FnMut(&mut Rng)>(name: &str, cases: u64, mut f: F) {
    let base = base_seed();
    let cases = cases.max(case_floor());
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        let result = catch_unwind(AssertUnwindSafe(|| f(&mut rng)));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} \
                 (TAS_CHECK_SEED={base}, case seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(got: &[f32], want: &[f32], rtol: f32, atol: f32) {
    assert_eq!(got.len(), want.len(), "length mismatch");
    for (idx, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * w.abs();
        assert!(
            (g - w).abs() <= tol,
            "allclose failed at [{idx}]: got {g}, want {w} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        property("counts", 50, |_| count += 1);
        // PROPTEST_CASES only ever raises the count (deep-fuzz CI).
        assert_eq!(count, 50u64.max(case_floor()));
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports_seed() {
        property("fails", 50, |rng| {
            assert!(rng.gen_range(10) < 9, "hit the 10% case");
        });
    }

    #[test]
    fn allclose_accepts_within_tol() {
        assert_allclose(&[1.0, 2.0], &[1.0001, 2.0001], 1e-3, 0.0);
    }

    #[test]
    #[should_panic(expected = "allclose failed")]
    fn allclose_rejects_outside_tol() {
        assert_allclose(&[1.0], &[1.1], 1e-3, 1e-3);
    }
}
