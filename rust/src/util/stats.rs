//! Streaming statistics used by the bench harness and the coordinator's
//! latency metrics: mean/stddev via Welford, and exact percentiles over a
//! retained sample vector (sample counts here are small: bench iterations
//! or per-run request counts).

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Retained-sample summary: exact order statistics + Welford moments.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
    w: Welford,
}

impl Summary {
    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
        self.w.push(x);
    }

    pub fn count(&self) -> u64 {
        self.w.count()
    }

    pub fn mean(&self) -> f64 {
        self.w.mean()
    }

    pub fn stddev(&self) -> f64 {
        self.w.stddev()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Exact percentile (nearest-rank on the sorted retained samples).
    pub fn percentile(&self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p), "percentile {p}");
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
        sorted[rank]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let mut w = Welford::default();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles_exact_on_known_data() {
        let mut s = Summary::default();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
        assert_eq!(s.p50(), 51.0); // nearest-rank on 0-based index
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn empty_summary_is_nan() {
        let s = Summary::default();
        assert!(s.p50().is_nan());
        assert_eq!(s.count(), 0);
    }
}
