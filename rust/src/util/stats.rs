//! Streaming statistics used by the bench harness and the coordinator's
//! latency metrics: mean/stddev via Welford, and nearest-rank percentiles
//! over a bounded reservoir sample (Vitter's Algorithm R with a
//! deterministic [`crate::util::prng::Rng`] seed, so million-request runs
//! keep O(1) memory and percentile output stays reproducible).

use crate::util::prng::Rng;

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    /// Parallel combination (Chan et al.): fold `other`'s moments into
    /// `self` as if both streams had been pushed into one accumulator.
    /// Count is exact; mean/m2 combine by the closed form.
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let (na, nb) = (self.n as f64, other.n as f64);
        let n = na + nb;
        let delta = other.mean - self.mean;
        self.mean += delta * nb / n;
        self.m2 += other.m2 + delta * delta * na * nb / n;
        self.n += other.n;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Reservoir capacity: enough for stable p99 estimates, small enough that a
/// long-lived coordinator never grows its metrics footprint.
pub const RESERVOIR_CAP: usize = 4096;

/// Bounded-sample summary: order statistics over an Algorithm-R reservoir
/// plus exact Welford moments and exact running min/max.
///
/// Up to [`RESERVOIR_CAP`] samples the reservoir holds every observation,
/// so percentiles are exact (the bench harness and short serving runs stay
/// in this regime); past the cap each incoming sample replaces a uniformly
/// random slot, keeping a uniform sample of the full stream.  The
/// replacement PRNG is seeded deterministically so runs are reproducible.
#[derive(Clone, Debug)]
pub struct Summary {
    samples: Vec<f64>,
    w: Welford,
    sum: f64,
    lo: f64,
    hi: f64,
    rng: Rng,
}

/// Base seed of the reservoir-replacement PRNG (also the re-seed base
/// after a [`Summary::merge`], XORed with the merged count).
const RESERVOIR_SEED: u64 = 0x5441_535f_5245_5356;
/// Seed base of the deterministic weighted draw a merge performs when the
/// two reservoirs together exceed [`RESERVOIR_CAP`].
const MERGE_SEED: u64 = 0x5441_535f_4d52_4745;

impl Default for Summary {
    fn default() -> Self {
        Summary {
            samples: Vec::new(),
            w: Welford::default(),
            sum: 0.0,
            lo: f64::INFINITY,
            hi: f64::NEG_INFINITY,
            // Fixed seed: reservoir contents depend only on the sample
            // stream, never on wall-clock or thread interleaving.
            rng: Rng::new(RESERVOIR_SEED),
        }
    }
}

impl Summary {
    pub fn push(&mut self, x: f64) {
        self.w.push(x);
        self.sum += x;
        self.lo = self.lo.min(x);
        self.hi = self.hi.max(x);
        if self.samples.len() < RESERVOIR_CAP {
            self.samples.push(x);
        } else {
            // Algorithm R: the i-th sample (1-based) survives with
            // probability cap/i; replace a uniformly random slot.
            let i = self.w.count();
            let j = self.rng.gen_range(i);
            if (j as usize) < RESERVOIR_CAP {
                self.samples[j as usize] = x;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.w.count()
    }

    pub fn mean(&self) -> f64 {
        self.w.mean()
    }

    /// Running sum of every pushed sample (kept explicitly, not derived
    /// from the Welford mean, so merged sums add exactly).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn stddev(&self) -> f64 {
        self.w.stddev()
    }

    /// Fold `other` into `self` as if both sample streams had been pushed
    /// into one summary.  Count, sum, min and max combine exactly; the
    /// Welford moments combine by the parallel closed form; the merged
    /// reservoir is a deterministic function of the two inputs.
    ///
    /// While the combined reservoirs fit under [`RESERVOIR_CAP`] the
    /// merge concatenates them (every retained sample survives, so
    /// percentiles equal the union's exactly).  Past the cap, each side's
    /// samples enter a weighted draw (Efraimidis–Spirakis keys on a
    /// [`MERGE_SEED`]-seeded PRNG, weight = represented stream count per
    /// retained sample) and the top [`RESERVOIR_CAP`] keys survive —
    /// deterministic given the inputs, and each source stream keeps
    /// representation proportional to its true count.  The replacement
    /// PRNG is re-seeded on the merged count so later pushes stay
    /// reproducible.
    pub fn merge(&mut self, other: &Summary) {
        if other.count() == 0 {
            return;
        }
        if self.count() == 0 {
            *self = other.clone();
            return;
        }
        let (na, nb) = (self.w.count(), other.w.count());
        self.w.merge(&other.w);
        self.sum += other.sum;
        self.lo = self.lo.min(other.lo);
        self.hi = self.hi.max(other.hi);
        if self.samples.len() + other.samples.len() <= RESERVOIR_CAP {
            self.samples.extend_from_slice(&other.samples);
        } else {
            let mut rng = Rng::new(MERGE_SEED ^ na.rotate_left(17) ^ nb);
            let wa = na as f64 / self.samples.len() as f64;
            let wb = nb as f64 / other.samples.len() as f64;
            let mut keyed: Vec<(f64, f64)> =
                Vec::with_capacity(self.samples.len() + other.samples.len());
            for &x in &self.samples {
                keyed.push((rng.gen_f64().powf(1.0 / wa), x));
            }
            for &x in &other.samples {
                keyed.push((rng.gen_f64().powf(1.0 / wb), x));
            }
            keyed.sort_by(|a, b| {
                b.0.partial_cmp(&a.0)
                    .unwrap()
                    .then(a.1.partial_cmp(&b.1).unwrap())
            });
            keyed.truncate(RESERVOIR_CAP);
            self.samples = keyed.into_iter().map(|(_, x)| x).collect();
        }
        self.rng = Rng::new(RESERVOIR_SEED ^ self.w.count());
    }

    /// Exact running minimum (not subject to reservoir eviction).
    pub fn min(&self) -> f64 {
        self.lo
    }

    /// Exact running maximum (not subject to reservoir eviction).
    pub fn max(&self) -> f64 {
        self.hi
    }

    /// Nearest-rank percentile over the retained reservoir, or `None` when
    /// no samples have been pushed (callers emit JSON `null`, never `NaN`).
    pub fn percentile(&self, p: f64) -> Option<f64> {
        assert!((0.0..=100.0).contains(&p), "percentile {p}");
        if self.samples.is_empty() {
            return None;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
        Some(sorted[rank])
    }

    pub fn p50(&self) -> Option<f64> {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> Option<f64> {
        self.percentile(99.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let mut w = Welford::default();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles_exact_on_known_data() {
        let mut s = Summary::default();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
        assert_eq!(s.p50(), Some(51.0)); // nearest-rank on 0-based index
        assert_eq!(s.percentile(0.0), Some(1.0));
        assert_eq!(s.percentile(100.0), Some(100.0));
    }

    #[test]
    fn empty_summary_has_no_percentiles() {
        let s = Summary::default();
        assert_eq!(s.p50(), None);
        assert_eq!(s.p99(), None);
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn reservoir_bounds_memory_and_keeps_exact_extremes() {
        let mut s = Summary::default();
        let n = 3 * RESERVOIR_CAP;
        for i in 0..n {
            s.push(i as f64);
        }
        assert_eq!(s.count(), n as u64);
        assert_eq!(s.samples.len(), RESERVOIR_CAP);
        // min/max are tracked outside the reservoir, so they stay exact
        // even after the early samples may have been evicted.
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), (n - 1) as f64);
        // On a uniform ramp the reservoir median stays near the true
        // median: a uniform sample of 4096 points has p50 within a few
        // percent with overwhelming probability (seed is fixed, so this
        // is a deterministic regression pin, not a flaky bound).
        let p50 = s.p50().unwrap();
        let true_mid = n as f64 / 2.0;
        assert!(
            (p50 - true_mid).abs() < 0.05 * n as f64,
            "reservoir p50 {p50} drifted from {true_mid}"
        );
    }

    #[test]
    fn merge_below_cap_equals_the_union_exactly() {
        // Integer-valued samples: FP addition is exact in any order, so
        // even `sum` compares with ==, not a tolerance.
        let mut a = Summary::default();
        let mut b = Summary::default();
        let mut union = Summary::default();
        for i in 0..500 {
            a.push(i as f64);
            union.push(i as f64);
        }
        for i in 500..1300 {
            b.push(i as f64);
            union.push(i as f64);
        }
        a.merge(&b);
        assert_eq!(a.count(), union.count());
        assert_eq!(a.sum(), union.sum());
        assert_eq!(a.min(), union.min());
        assert_eq!(a.max(), union.max());
        // under the cap the merged reservoir holds the exact union
        assert_eq!(a.p50(), union.p50());
        assert_eq!(a.p99(), union.p99());
        assert!((a.mean() - union.mean()).abs() < 1e-9);
        assert!((a.stddev() - union.stddev()).abs() < 1e-9);
    }

    #[test]
    fn merge_over_cap_is_deterministic_and_keeps_exact_scalars() {
        let fill = |lo: usize, hi: usize| {
            let mut s = Summary::default();
            for i in lo..hi {
                s.push(i as f64);
            }
            s
        };
        let n = 3 * RESERVOIR_CAP;
        let (a0, b) = (fill(0, n), fill(n, 2 * n));
        let mut a = a0.clone();
        a.merge(&b);
        let mut a2 = a0.clone();
        a2.merge(&b);
        assert_eq!(a.p50(), a2.p50(), "merge must be deterministic");
        let union = fill(0, 2 * n);
        assert_eq!(a.count(), union.count());
        assert_eq!(a.sum(), union.sum());
        assert_eq!(a.min(), union.min());
        assert_eq!(a.max(), union.max());
        assert_eq!(a.samples.len(), RESERVOIR_CAP);
        // both source streams survive in the reservoir roughly per their
        // counts: the median of the merged uniform ramp stays near n.
        let p50 = a.p50().unwrap();
        assert!(
            (p50 - n as f64).abs() < 0.1 * (2 * n) as f64,
            "merged p50 {p50} drifted from {n}"
        );
        // merged moments match the union's closed form
        assert!((a.mean() - union.mean()).abs() < 1e-9 * union.mean().abs());
        assert!((a.stddev() - union.stddev()).abs() < 1e-6 * union.stddev());
    }

    #[test]
    fn merge_with_empty_is_identity_both_ways() {
        let mut a = Summary::default();
        for i in 0..10 {
            a.push(i as f64);
        }
        let before = (a.count(), a.sum(), a.p50());
        a.merge(&Summary::default());
        assert_eq!((a.count(), a.sum(), a.p50()), before);
        let mut empty = Summary::default();
        empty.merge(&a);
        assert_eq!(empty.count(), a.count());
        assert_eq!(empty.p50(), a.p50());
    }

    #[test]
    fn reservoir_is_deterministic() {
        let fill = |seed_shift: f64| {
            let mut s = Summary::default();
            for i in 0..(2 * RESERVOIR_CAP) {
                s.push(i as f64 + seed_shift);
            }
            s.p50()
        };
        assert_eq!(fill(0.0), fill(0.0));
    }
}
