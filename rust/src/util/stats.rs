//! Streaming statistics used by the bench harness and the coordinator's
//! latency metrics: mean/stddev via Welford, and nearest-rank percentiles
//! over a bounded reservoir sample (Vitter's Algorithm R with a
//! deterministic [`crate::util::prng::Rng`] seed, so million-request runs
//! keep O(1) memory and percentile output stays reproducible).

use crate::util::prng::Rng;

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Reservoir capacity: enough for stable p99 estimates, small enough that a
/// long-lived coordinator never grows its metrics footprint.
pub const RESERVOIR_CAP: usize = 4096;

/// Bounded-sample summary: order statistics over an Algorithm-R reservoir
/// plus exact Welford moments and exact running min/max.
///
/// Up to [`RESERVOIR_CAP`] samples the reservoir holds every observation,
/// so percentiles are exact (the bench harness and short serving runs stay
/// in this regime); past the cap each incoming sample replaces a uniformly
/// random slot, keeping a uniform sample of the full stream.  The
/// replacement PRNG is seeded deterministically so runs are reproducible.
#[derive(Clone, Debug)]
pub struct Summary {
    samples: Vec<f64>,
    w: Welford,
    lo: f64,
    hi: f64,
    rng: Rng,
}

impl Default for Summary {
    fn default() -> Self {
        Summary {
            samples: Vec::new(),
            w: Welford::default(),
            lo: f64::INFINITY,
            hi: f64::NEG_INFINITY,
            // Fixed seed: reservoir contents depend only on the sample
            // stream, never on wall-clock or thread interleaving.
            rng: Rng::new(0x5441_535f_5245_5356),
        }
    }
}

impl Summary {
    pub fn push(&mut self, x: f64) {
        self.w.push(x);
        self.lo = self.lo.min(x);
        self.hi = self.hi.max(x);
        if self.samples.len() < RESERVOIR_CAP {
            self.samples.push(x);
        } else {
            // Algorithm R: the i-th sample (1-based) survives with
            // probability cap/i; replace a uniformly random slot.
            let i = self.w.count();
            let j = self.rng.gen_range(i);
            if (j as usize) < RESERVOIR_CAP {
                self.samples[j as usize] = x;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.w.count()
    }

    pub fn mean(&self) -> f64 {
        self.w.mean()
    }

    pub fn stddev(&self) -> f64 {
        self.w.stddev()
    }

    /// Exact running minimum (not subject to reservoir eviction).
    pub fn min(&self) -> f64 {
        self.lo
    }

    /// Exact running maximum (not subject to reservoir eviction).
    pub fn max(&self) -> f64 {
        self.hi
    }

    /// Nearest-rank percentile over the retained reservoir, or `None` when
    /// no samples have been pushed (callers emit JSON `null`, never `NaN`).
    pub fn percentile(&self, p: f64) -> Option<f64> {
        assert!((0.0..=100.0).contains(&p), "percentile {p}");
        if self.samples.is_empty() {
            return None;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
        Some(sorted[rank])
    }

    pub fn p50(&self) -> Option<f64> {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> Option<f64> {
        self.percentile(99.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let mut w = Welford::default();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles_exact_on_known_data() {
        let mut s = Summary::default();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
        assert_eq!(s.p50(), Some(51.0)); // nearest-rank on 0-based index
        assert_eq!(s.percentile(0.0), Some(1.0));
        assert_eq!(s.percentile(100.0), Some(100.0));
    }

    #[test]
    fn empty_summary_has_no_percentiles() {
        let s = Summary::default();
        assert_eq!(s.p50(), None);
        assert_eq!(s.p99(), None);
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn reservoir_bounds_memory_and_keeps_exact_extremes() {
        let mut s = Summary::default();
        let n = 3 * RESERVOIR_CAP;
        for i in 0..n {
            s.push(i as f64);
        }
        assert_eq!(s.count(), n as u64);
        assert_eq!(s.samples.len(), RESERVOIR_CAP);
        // min/max are tracked outside the reservoir, so they stay exact
        // even after the early samples may have been evicted.
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), (n - 1) as f64);
        // On a uniform ramp the reservoir median stays near the true
        // median: a uniform sample of 4096 points has p50 within a few
        // percent with overwhelming probability (seed is fixed, so this
        // is a deterministic regression pin, not a flaky bound).
        let p50 = s.p50().unwrap();
        let true_mid = n as f64 / 2.0;
        assert!(
            (p50 - true_mid).abs() < 0.05 * n as f64,
            "reservoir p50 {p50} drifted from {true_mid}"
        );
    }

    #[test]
    fn reservoir_is_deterministic() {
        let fill = |seed_shift: f64| {
            let mut s = Summary::default();
            for i in 0..(2 * RESERVOIR_CAP) {
                s.push(i as f64 + seed_shift);
            }
            s.p50()
        };
        assert_eq!(fill(0.0), fill(0.0));
    }
}
