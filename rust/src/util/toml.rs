//! Minimal TOML-subset parser for config files (no `toml` crate offline).
//!
//! Supported grammar — everything the `configs/*.toml` files need:
//! `[section]` and `[section.sub]` headers, `key = value` with integers,
//! floats, booleans, strings and homogeneous inline arrays, `#` comments.
//! Keys flatten to dotted paths: `[sim]\nbw = 16` -> `"sim.bw"`.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed document: dotted-path -> value.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> anyhow::Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err(lineno, "unterminated section"))?
                    .trim();
                if name.is_empty() {
                    return Err(err(lineno, "empty section name"));
                }
                section = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| err(lineno, "expected 'key = value'"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err(lineno, "empty key"));
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|m| err(lineno, &m))?;
            let path = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if doc.entries.insert(path.clone(), value).is_some() {
                return Err(err(lineno, &format!("duplicate key '{path}'")));
            }
        }
        Ok(doc)
    }

    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.entries.get(path)
    }

    pub fn get_u64(&self, path: &str, default: u64) -> u64 {
        self.get(path).and_then(|v| v.as_u64()).unwrap_or(default)
    }

    pub fn get_f64(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, path: &str, default: &'a str) -> &'a str {
        self.get(path).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn get_bool(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn err(lineno: usize, msg: &str) -> anyhow::Error {
    anyhow::anyhow!("toml line {}: {}", lineno + 1, msg)
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"")));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        return inner
            .split(',')
            .map(|p| parse_value(p.trim()))
            .collect::<Result<Vec<_>, _>>()
            .map(TomlValue::Arr);
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
            name = "tas"        # a comment
            [sim]
            bandwidth = 16
            turnaround = 7.5
            enabled = true
            tiles = [16, 16, 16]
            [sim.deep]
            x = 1_000_000
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_str("name", ""), "tas");
        assert_eq!(doc.get_u64("sim.bandwidth", 0), 16);
        assert_eq!(doc.get_f64("sim.turnaround", 0.0), 7.5);
        assert!(doc.get_bool("sim.enabled", false));
        assert_eq!(doc.get_u64("sim.deep.x", 0), 1_000_000);
        assert_eq!(
            doc.get("sim.tiles").unwrap(),
            &TomlValue::Arr(vec![
                TomlValue::Int(16),
                TomlValue::Int(16),
                TomlValue::Int(16)
            ])
        );
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = TomlDoc::parse(r##"k = "a#b""##).unwrap();
        assert_eq!(doc.get_str("k", ""), "a#b");
    }

    #[test]
    fn rejects_duplicates_and_garbage() {
        assert!(TomlDoc::parse("a = 1\na = 2").is_err());
        assert!(TomlDoc::parse("a 1").is_err());
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("a = ").is_err());
    }

    #[test]
    fn defaults_apply() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.get_u64("missing", 42), 42);
    }
}
