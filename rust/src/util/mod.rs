//! Infrastructure substrates built in-repo (the offline environment vendors
//! only the `xla` crate closure + `anyhow`): PRNG, property-test harness,
//! JSON and TOML parsing, CLI, stats, bench harness, table rendering and
//! binary I/O.  See DESIGN.md §1 (S1–S5).

pub mod bench;
pub mod bytes;
pub mod check;
pub mod cli;
pub mod json;
pub mod prng;
pub mod stats;
pub mod table;
pub mod toml;

/// `ceil(a / b)` for tile counts; the paper's `M/m` etc. are all ceilings
/// once shapes stop being tile-divisible.
pub fn ceil_div(a: u64, b: u64) -> u64 {
    assert!(b > 0, "ceil_div by zero");
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(10, 5), 2);
        assert_eq!(ceil_div(11, 5), 3);
        assert_eq!(ceil_div(0, 5), 0);
        assert_eq!(ceil_div(1, 1), 1);
    }

    #[test]
    #[should_panic(expected = "ceil_div by zero")]
    fn ceil_div_zero_division_panics() {
        ceil_div(1, 0);
    }
}
