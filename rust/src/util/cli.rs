//! Tiny argv parser (no `clap` in the vendored set).
//!
//! Grammar: `tas <subcommand> [--key value]... [--flag]... [positional]...`
//! Values may also be attached: `--key=value`.  Unknown flags are collected
//! and reported by [`Args::finish`] so typos fail loudly.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: Vec<String>,
}

impl Args {
    /// Parse from the process argv (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut a = Args::default();
        let mut it = argv.into_iter().peekable();
        // First non-flag token is the subcommand.
        if let Some(tok) = it.peek() {
            if !tok.starts_with('-') {
                a.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    a.options.insert(body.to_string(), it.next().unwrap());
                } else {
                    a.flags.push(body.to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    pub fn opt(&mut self, key: &str) -> Option<String> {
        self.consumed.push(key.to_string());
        self.options.get(key).cloned()
    }

    pub fn opt_or(&mut self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or_else(|| default.to_string())
    }

    pub fn opt_u64(&mut self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: expected integer, got '{v}'")),
        }
    }

    pub fn opt_f64(&mut self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: expected number, got '{v}'")),
        }
    }

    pub fn flag(&mut self, key: &str) -> bool {
        self.consumed.push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Error on any option/flag that no handler consumed.
    pub fn finish(&self) -> anyhow::Result<()> {
        let unknown: Vec<&String> = self
            .options
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !self.consumed.contains(k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(anyhow::anyhow!(
                "unknown option(s): {}",
                unknown
                    .iter()
                    .map(|k| format!("--{k}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_options_flags_positional() {
        let mut a = parse("simulate --model bert-base --seq 384 --json out.csv extra");
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.opt("model").as_deref(), Some("bert-base"));
        assert_eq!(a.opt_u64("seq", 0).unwrap(), 384);
        assert_eq!(a.opt("json").as_deref(), Some("out.csv"));
        assert_eq!(a.positional, vec!["extra"]);
        a.finish().unwrap();
    }

    #[test]
    fn equals_form_and_flags() {
        let mut a = parse("run --k=v --verbose");
        assert_eq!(a.opt("k").as_deref(), Some("v"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn unknown_options_rejected() {
        let mut a = parse("run --typo 1");
        let _ = a.opt("other");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_integer_reported() {
        let mut a = parse("run --n abc");
        assert!(a.opt_u64("n", 0).is_err());
    }

    #[test]
    fn no_subcommand_when_first_is_flag() {
        let a = parse("--help");
        assert!(a.subcommand.is_none());
    }
}
