//! Text/markdown/CSV table rendering for the paper-table benches.
//!
//! The benches print tables shaped like the paper's (rows = layers or
//! sequence lengths, columns = schemes), so reviewers can diff ours
//! against the published ones by eye.

/// A simple column-aligned table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width != header width"
        );
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> =
            self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Render as an aligned ASCII table.
    pub fn to_text(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let line = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(c);
                for _ in c.chars().count()..w[i] {
                    out.push(' ');
                }
            }
            out.push('\n');
        };
        line(&self.headers, &mut out);
        let total: usize = w.iter().sum::<usize>() + 2 * (w.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(row, &mut out);
        }
        out
    }

    /// Render as GitHub-flavored markdown.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.headers.iter().map(|_| "---|").collect::<String>()
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    /// Render as CSV (RFC-4180 quoting where needed).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| esc(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        out
    }
}

/// Format a count in the paper's scientific style: `1.18 x 10^5`.
pub fn sci(v: f64) -> String {
    if v == 0.0 {
        return "0".into();
    }
    let sign = if v < 0.0 { "-" } else { "" };
    let a = v.abs();
    let exp = a.log10().floor() as i32;
    let mant = a / 10f64.powi(exp);
    format!("{sign}{mant:.2}e{exp}")
}

/// Format a big integer with thousands separators: `11,132.6 G` style
/// helper — returns e.g. `312.9 G` for 312.9e9.
pub fn giga(v: f64) -> String {
    format!("{:.1}", v / 1e9)
}

/// Percentage with two decimals: `97.17%`.
pub fn pct(fraction: f64) -> String {
    format!("{:.2}%", fraction * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        t
    }

    #[test]
    fn text_aligns_columns() {
        let txt = sample().to_text();
        let lines: Vec<&str> = txt.lines().collect();
        assert_eq!(lines[1], "a    bb");
        assert_eq!(lines[3], "1    2 ");
        assert_eq!(lines[4], "333  4 ");
    }

    #[test]
    fn markdown_and_csv_shapes() {
        let t = sample();
        assert!(t.to_markdown().contains("| a | bb |"));
        assert_eq!(t.to_csv(), "a,bb\n1,2\n333,4\n");
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("", &["x"]);
        t.row(vec!["a,b".into()]);
        assert_eq!(t.to_csv(), "x\n\"a,b\"\n");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        Table::new("", &["a"]).row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn sci_matches_paper_style() {
        assert_eq!(sci(1.18e5), "1.18e5");
        assert_eq!(sci(-9.22e5), "-9.22e5");
        assert_eq!(sci(0.0), "0");
    }

    #[test]
    fn pct_two_decimals() {
        assert_eq!(pct(0.9717), "97.17%");
    }
}
