//! Transformer workload zoo: the models the paper evaluates (Tables I, III,
//! IV) expressed as per-layer GEMM workloads for the dataflow analysis.
//!
//! A [`ModelSpec`] carries architecture hyper-parameters; [`ModelSpec::
//! linear_gemms`] expands one forward pass at a given token count into the
//! linear-projection GEMMs the paper optimises (QKV, attention output,
//! FFN up/down, and optionally the LM head).  Attention score/context
//! matmuls are exposed separately ([`ModelSpec::attention_gemms`]) — the
//! paper's scheme targets linear projections and composes with separate
//! attention optimisations (§I, §V).

pub mod lengths;
pub mod zoo;

pub use lengths::{
    format_arrival_trace, generate_arrivals, parse_arrival_trace, ArrivalEvent,
    ArrivalProcess, LengthDist,
};
pub use zoo::{bert_base, bert_large, gpt3, vit_g14, wav2vec2_large, xlsr_2b, all_models};

use crate::gemm::GemmShape;

/// One GEMM in a forward pass, with a human-readable role and multiplicity.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GemmWorkload {
    /// Role, e.g. "qkv", "attn_out", "ffn1".
    pub name: &'static str,
    pub shape: GemmShape,
    /// How many identical instances per forward pass (e.g. layer count).
    pub count: u64,
}

impl GemmWorkload {
    pub fn total_macs(&self) -> u64 {
        self.count * self.shape.macs()
    }
}

/// Transformer architecture description.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    pub name: &'static str,
    /// Embedding width H.
    pub hidden: u64,
    /// FFN inner width.
    pub ffn: u64,
    /// Encoder/decoder layer count.
    pub layers: u64,
    pub heads: u64,
    /// Output vocabulary (LM head); `None` for pure encoders w/o head.
    pub vocab: Option<u64>,
    /// The paper's "pre-defined token length" (Table I).
    pub default_seq: u64,
    /// Parameter count in billions (Table I reporting).
    pub params_b: f64,
}

impl ModelSpec {
    /// Linear-projection GEMMs of one forward pass at `tokens` tokens.
    /// Shapes follow the paper's convention: `out[M,K] = in[M,N]·w[N,K]`
    /// with M = tokens.
    pub fn linear_gemms(&self, tokens: u64) -> Vec<GemmWorkload> {
        assert!(tokens > 0);
        let h = self.hidden;
        let f = self.ffn;
        let mut v = vec![
            // Q, K, V projections: three H×H GEMMs per layer.
            GemmWorkload {
                name: "qkv",
                shape: GemmShape::new(tokens, h, h),
                count: 3 * self.layers,
            },
            GemmWorkload {
                name: "attn_out",
                shape: GemmShape::new(tokens, h, h),
                count: self.layers,
            },
            GemmWorkload {
                name: "ffn1",
                shape: GemmShape::new(tokens, h, f),
                count: self.layers,
            },
            GemmWorkload {
                name: "ffn2",
                shape: GemmShape::new(tokens, f, h),
                count: self.layers,
            },
        ];
        if let Some(vocab) = self.vocab {
            v.push(GemmWorkload {
                name: "lm_head",
                shape: GemmShape::new(tokens, h, vocab),
                count: 1,
            });
        }
        v
    }

    /// The same linear projections as [`ModelSpec::linear_gemms`], but as
    /// a *chained* stage list for layer-level planning
    /// ([`crate::dataflow::LayerPlan`]): Q/K/V share the block input;
    /// FFN up consumes the attention projection's output and FFN down
    /// consumes FFN up's (with elementwise LayerNorm/GeLU in between,
    /// which move no DRAM words when the tensor is SRAM-resident).  The
    /// attention-context input of `attn_out` and the cross-layer edge are
    /// conservatively treated as DRAM round-trips.  Stage shapes × counts
    /// sum to exactly the `linear_gemms` inventory.
    pub fn block_stages(&self, tokens: u64) -> Vec<crate::dataflow::StageSpec> {
        // One source of truth for the block inventory: the decode module's
        // sliced builder at full slices (it also serves the head-sharded
        // prefill path).  The coordinator's manifest-dims twin
        // (`coordinator::decisions::bucket_stages`) stays a deliberate
        // independent copy, pinned by a cross-implementation contract test.
        let dims = crate::dataflow::DecodeDims::of(self);
        crate::dataflow::decode::prefill_stages_sliced(
            &dims,
            tokens,
            dims.heads,
            dims.ffn,
            dims.vocab,
        )
    }

    /// Decode-phase stage inventory: ONE autoregressive step at `batch`
    /// in-flight sequences whose K/V caches hold `cache_len` positions.
    /// Unlike [`ModelSpec::block_stages`] this includes the attention
    /// matmuls — during decode they read the growing K/V cache, which is
    /// exactly the traffic the decode planner
    /// ([`crate::dataflow::DecodePlan`]) keeps SRAM-resident.
    pub fn decode_stages(&self, batch: u64, cache_len: u64) -> Vec<crate::dataflow::StageSpec> {
        crate::dataflow::decode::decode_step_stages(
            &crate::dataflow::DecodeDims::of(self),
            batch,
            cache_len,
        )
    }

    /// Attention score (Q·Kᵀ) and context (P·V) matmuls — per head.
    pub fn attention_gemms(&self, tokens: u64) -> Vec<GemmWorkload> {
        let d = self.hidden / self.heads;
        vec![
            GemmWorkload {
                name: "qk_t",
                shape: GemmShape::new(tokens, d, tokens),
                count: self.layers * self.heads,
            },
            GemmWorkload {
                name: "attn_v",
                shape: GemmShape::new(tokens, tokens, d),
                count: self.layers * self.heads,
            },
        ]
    }

    /// Total linear-projection MACs of one forward pass.
    pub fn total_linear_macs(&self, tokens: u64) -> u64 {
        self.linear_gemms(tokens).iter().map(|g| g.total_macs()).sum()
    }

    /// Approximate parameter count implied by the spec's linear layers
    /// (sanity check against `params_b`).
    pub fn linear_param_count(&self) -> u64 {
        let h = self.hidden;
        let per_layer = 4 * h * h + 2 * h * self.ffn;
        self.layers * per_layer
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_base_gemm_inventory() {
        let m = bert_base();
        let gemms = m.linear_gemms(384);
        assert_eq!(gemms.len(), 4); // no LM head configured by default zoo
        let qkv = &gemms[0];
        assert_eq!(qkv.shape, GemmShape::new(384, 768, 768));
        assert_eq!(qkv.count, 36); // 3 × 12 layers
        let ffn1 = gemms.iter().find(|g| g.name == "ffn1").unwrap();
        assert_eq!(ffn1.shape, GemmShape::new(384, 768, 3072));
    }

    #[test]
    fn linear_params_match_published_order() {
        // BERT-Base linear params ≈ 85M of the 110M total.
        let p = bert_base().linear_param_count();
        assert!((80_000_000..90_000_000).contains(&p), "{p}");
        // GPT-3 ≈ 174B of 175B.
        let g = gpt3().linear_param_count();
        assert!((150_000_000_000..200_000_000_000).contains(&g), "{g}");
    }

    #[test]
    fn attention_gemms_scale_with_seq() {
        let m = bert_base();
        let short = &m.attention_gemms(128)[0];
        let long = &m.attention_gemms(512)[0];
        assert_eq!(short.shape.k, 128);
        assert_eq!(long.shape.k, 512);
        assert_eq!(long.count, 12 * 12);
    }

    #[test]
    fn block_stages_match_linear_gemm_inventory() {
        // Same GEMMs, different bookkeeping: total MACs must agree.
        for m in zoo::all_models() {
            for tokens in [64, 384] {
                let stage_macs: u64 = m
                    .block_stages(tokens)
                    .iter()
                    .map(|s| s.count * s.shape.macs())
                    .sum();
                assert_eq!(stage_macs, m.total_linear_macs(tokens), "{}", m.name);
            }
        }
    }

    #[test]
    fn decode_stages_inventory_matches_phase_shapes() {
        let m = bert_base();
        let stages = m.decode_stages(8, 96);
        // linear projections are skinny (M = batch) ...
        let q = stages.iter().find(|s| s.name == "q").unwrap();
        assert_eq!(q.shape, GemmShape::new(8, 768, 768));
        // ... attention runs per sequence per head against the cache
        let qk = stages.iter().find(|s| s.name == "qk_t").unwrap();
        assert_eq!(qk.shape, GemmShape::new(1, 64, 96));
        assert_eq!(qk.count, m.layers * m.heads * 8);
        assert!(qk.cache.is_some());
        // the cache length only scales the attention stages
        let longer = m.decode_stages(8, 512);
        let qk_long = longer.iter().find(|s| s.name == "qk_t").unwrap();
        assert_eq!(qk_long.shape.k, 512);
        let q_long = longer.iter().find(|s| s.name == "q").unwrap();
        assert_eq!(q_long.shape, q.shape);
    }

    #[test]
    fn macs_scale_linearly_with_tokens() {
        let m = wav2vec2_large();
        assert_eq!(m.total_linear_macs(200), 2 * m.total_linear_macs(100));
    }
}
