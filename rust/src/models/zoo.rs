//! The concrete models the paper references (Table I & §IV), with
//! hyper-parameters from their original publications.

use super::ModelSpec;

/// BERT-Base (Devlin et al., 2018): 12 layers, H=768, FFN=3072.
/// Table IV's workload.
pub fn bert_base() -> ModelSpec {
    ModelSpec {
        name: "bert-base",
        hidden: 768,
        ffn: 3072,
        layers: 12,
        heads: 12,
        vocab: None,
        default_seq: 512,
        params_b: 0.110,
    }
}

/// BERT-Large: 24 layers, H=1024 (the §I "length 3072" motivating example).
pub fn bert_large() -> ModelSpec {
    ModelSpec {
        name: "bert-large",
        hidden: 1024,
        ffn: 4096,
        layers: 24,
        heads: 16,
        vocab: None,
        default_seq: 512,
        params_b: 0.340,
    }
}

/// Wav2Vec2.0-Large (Baevski et al., 2020): 24 layers, H=1024 — Table III's
/// workload, evaluated on LibriSpeech lengths.
pub fn wav2vec2_large() -> ModelSpec {
    ModelSpec {
        name: "wav2vec2-large",
        hidden: 1024,
        ffn: 4096,
        layers: 24,
        heads: 16,
        vocab: None,
        default_seq: 384, // LibriSpeech mean (7.6 s ≈ 384 tokens)
        params_b: 0.317,
    }
}

/// ViT-G/14 (Zhai et al., 2022) as cited in Table I: hidden 4096*, token
/// length 518 (14×14 patches of 518² crops + cls), 1.8 B parameters.
/// *The paper's Table I lists hidden = 4096; we follow the paper.
pub fn vit_g14() -> ModelSpec {
    ModelSpec {
        name: "vit-g14",
        hidden: 4096,
        ffn: 4 * 4096,
        layers: 48,
        heads: 16,
        vocab: None,
        default_seq: 518,
        params_b: 1.8,
    }
}

/// Wav2Vec2-XLS-R-2B (Babu et al., 2021) as in Table I: hidden 2560,
/// token length 1536, 2 B parameters.
pub fn xlsr_2b() -> ModelSpec {
    ModelSpec {
        name: "wav2vec2-xls-r-2b",
        hidden: 2560,
        ffn: 4 * 2560,
        layers: 48,
        heads: 32,
        vocab: None,
        default_seq: 1536,
        params_b: 2.0,
    }
}

/// GPT-3 175B (Brown et al., 2020) as in Table I: hidden 12288, context
/// 2048.
pub fn gpt3() -> ModelSpec {
    ModelSpec {
        name: "gpt-3",
        hidden: 12288,
        ffn: 4 * 12288,
        layers: 96,
        heads: 96,
        vocab: Some(50257),
        default_seq: 2048,
        params_b: 175.0,
    }
}

/// Every model in the zoo (Table I order first, then the §IV workloads).
pub fn all_models() -> Vec<ModelSpec> {
    vec![
        vit_g14(),
        xlsr_2b(),
        gpt3(),
        bert_base(),
        bert_large(),
        wav2vec2_large(),
    ]
}

/// Look a model up by CLI name.
pub fn by_name(name: &str) -> anyhow::Result<ModelSpec> {
    all_models()
        .into_iter()
        .find(|m| m.name == name)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown model '{name}' (known: {})",
                all_models()
                    .iter()
                    .map(|m| m.name)
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_names_unique_and_resolvable() {
        let models = all_models();
        for m in &models {
            assert_eq!(by_name(m.name).unwrap(), *m);
        }
        let mut names: Vec<_> = models.iter().map(|m| m.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), models.len());
    }

    #[test]
    fn unknown_model_errors_with_list() {
        let err = by_name("nope").unwrap_err().to_string();
        assert!(err.contains("bert-base"));
    }

    #[test]
    fn table1_attributes() {
        // Table I row values the benches print.
        assert_eq!(vit_g14().hidden, 4096);
        assert_eq!(vit_g14().default_seq, 518);
        assert_eq!(xlsr_2b().hidden, 2560);
        assert_eq!(xlsr_2b().default_seq, 1536);
        assert_eq!(gpt3().hidden, 12288);
        assert_eq!(gpt3().default_seq, 2048);
    }
}
