//! Sequence-length distributions — the input-length variability that
//! motivates the adaptive scheme (§I, Table III).
//!
//! LibriSpeech (the paper's ASR dataset) is not shipped here; Table III
//! only depends on its token-length anchors, which the paper states:
//! shortest 2.3 s = 115 tokens, mean 7.6 s = 384, longest 31.3 s = 1565
//! (wav2vec2 emits ≈50 tokens/s).  For serving experiments we model the
//! length distribution as a clipped log-normal through those anchors
//! (speech-corpus durations are classically log-normal).

use crate::util::prng::Rng;

/// Wav2vec2 frame rate: one token per 20 ms of audio.
pub const TOKENS_PER_SECOND: u64 = 50;

/// Table III's anchor lengths, in tokens.
pub const LIBRISPEECH_MIN: u64 = 115;
pub const LIBRISPEECH_MEAN: u64 = 384;
pub const LIBRISPEECH_MAX: u64 = 1565;
/// The paper's long-speech extrapolation row.
pub const LONG_SPEECH: u64 = 15_000;

/// Token count for an audio clip length in seconds.
pub fn tokens_for_seconds(seconds: f64) -> u64 {
    (seconds * TOKENS_PER_SECOND as f64).round().max(1.0) as u64
}

/// A clipped log-normal token-length distribution.
#[derive(Clone, Debug)]
pub struct LengthDist {
    mu: f64,
    sigma: f64,
    min: u64,
    max: u64,
}

impl LengthDist {
    /// LibriSpeech-like: log-normal with mean ≈ 384 tokens, clipped to the
    /// dataset's observed [115, 1565] token range.
    pub fn librispeech() -> Self {
        let sigma: f64 = 0.55;
        // mean of lognormal = exp(mu + sigma²/2) -> mu = ln(mean) − σ²/2
        let mu = (LIBRISPEECH_MEAN as f64).ln() - sigma * sigma / 2.0;
        LengthDist { mu, sigma, min: LIBRISPEECH_MIN, max: LIBRISPEECH_MAX }
    }

    /// Fixed-length "distribution" (NLP benchmarks with padded batches).
    pub fn fixed(tokens: u64) -> Self {
        LengthDist { mu: (tokens as f64).ln(), sigma: 0.0, min: tokens, max: tokens }
    }

    /// General clipped log-normal around `mean_tokens`.
    pub fn lognormal(mean_tokens: u64, sigma: f64, min: u64, max: u64) -> Self {
        assert!(min <= max && mean_tokens > 0);
        let mu = (mean_tokens as f64).ln() - sigma * sigma / 2.0;
        LengthDist { mu, sigma, min, max }
    }

    /// Draw one token length.
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        if self.sigma == 0.0 {
            return self.min;
        }
        let x = rng.gen_lognormal(self.mu, self.sigma);
        (x.round() as u64).clamp(self.min, self.max)
    }

    /// Draw `n` lengths.
    pub fn sample_n(&self, rng: &mut Rng, n: usize) -> Vec<u64> {
        (0..n).map(|_| self.sample(rng)).collect()
    }

    pub fn bounds(&self) -> (u64, u64) {
        (self.min, self.max)
    }

    /// Parse a CLI distribution spec against a serving bucket ceiling:
    ///
    /// * `librispeech` — the LibriSpeech log-normal shape rescaled into
    ///   the compiled bucket range (mean `max_len/3`, clipped to
    ///   `[4, max_len]`) — exactly what `tas serve` has always done;
    /// * `fixed` / `fixed:N` — constant length (default
    ///   `min(max_len, 64)`);
    /// * `lognormal:MEAN,SIGMA` — clipped log-normal around `MEAN`
    ///   tokens with log-space `SIGMA`, clipped to `[4, max_len]`.
    pub fn parse(spec: &str, max_len: u64) -> anyhow::Result<LengthDist> {
        anyhow::ensure!(max_len >= 1, "max_len must be >= 1");
        let lo = 4.min(max_len);
        if spec == "librispeech" {
            return Ok(LengthDist::lognormal(
                (max_len / 3).max(8).min(max_len),
                0.55,
                lo,
                max_len,
            ));
        }
        if spec == "fixed" {
            return Ok(LengthDist::fixed(max_len.min(64)));
        }
        if let Some(rest) = spec.strip_prefix("fixed:") {
            let n: u64 = rest
                .parse()
                .map_err(|_| anyhow::anyhow!("bad fixed length '{rest}'"))?;
            anyhow::ensure!(
                (1..=max_len).contains(&n),
                "fixed length {n} outside [1, {max_len}]"
            );
            return Ok(LengthDist::fixed(n));
        }
        if let Some(rest) = spec.strip_prefix("lognormal:") {
            let (mean_s, sigma_s) = rest.split_once(',').ok_or_else(|| {
                anyhow::anyhow!("lognormal spec needs MEAN,SIGMA (got '{rest}')")
            })?;
            let mean: u64 = mean_s
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad lognormal mean '{mean_s}'"))?;
            let sigma: f64 = sigma_s
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad lognormal sigma '{sigma_s}'"))?;
            anyhow::ensure!(mean >= 1, "lognormal mean must be >= 1");
            anyhow::ensure!(
                sigma.is_finite() && sigma >= 0.0,
                "lognormal sigma must be finite and >= 0"
            );
            return Ok(LengthDist::lognormal(mean.min(max_len).max(lo), sigma, lo, max_len));
        }
        anyhow::bail!(
            "unknown dist '{spec}' (want librispeech | fixed[:N] | lognormal:MEAN,SIGMA)"
        )
    }
}

/// Open-loop arrival process over virtual time: arrivals happen at their
/// own pace whether or not the servers keep up (closed-loop generators —
/// `Coordinator::run_closed_loop` — only offer load as fast as replies
/// return, which hides queueing collapse).  Both variants are sampled
/// through the deterministic [`Rng`], so a (process, seed) pair names one
/// exact arrival sequence.
#[derive(Clone, Copy, Debug)]
pub enum ArrivalProcess {
    /// Memoryless arrivals: exponential inter-arrival gaps at `rate_per_s`.
    Poisson { rate_per_s: f64 },
    /// On-off modulated Poisson (bursty): exponential ON periods of mean
    /// `mean_on_s` seconds emitting at `rate_on_per_s`, alternating with
    /// silent exponential OFF periods of mean `mean_off_s`.
    Bursty {
        rate_on_per_s: f64,
        mean_on_s: f64,
        mean_off_s: f64,
    },
}

impl ArrivalProcess {
    pub fn poisson(rate_per_s: f64) -> Self {
        assert!(rate_per_s > 0.0 && rate_per_s.is_finite(), "rate {rate_per_s}");
        ArrivalProcess::Poisson { rate_per_s }
    }

    pub fn bursty(rate_on_per_s: f64, mean_on_s: f64, mean_off_s: f64) -> Self {
        assert!(rate_on_per_s > 0.0 && rate_on_per_s.is_finite());
        assert!(mean_on_s > 0.0 && mean_off_s >= 0.0);
        ArrivalProcess::Bursty { rate_on_per_s, mean_on_s, mean_off_s }
    }

    /// Long-run arrival rate: the Poisson rate, or the ON rate scaled by
    /// the duty cycle `on / (on + off)` for the bursty process.
    pub fn mean_rate_per_s(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate_per_s } => rate_per_s,
            ArrivalProcess::Bursty { rate_on_per_s, mean_on_s, mean_off_s } => {
                rate_on_per_s * mean_on_s / (mean_on_s + mean_off_s)
            }
        }
    }

    /// Draw `n` arrival timestamps (microseconds from t=0, non-decreasing).
    pub fn sample_arrivals_us(&self, rng: &mut Rng, n: usize) -> Vec<u64> {
        let exp = |rng: &mut Rng, mean: f64| -> f64 {
            // inverse CDF; 1-u in (0,1] so ln never sees zero
            -(1.0 - rng.gen_f64()).ln() * mean
        };
        let mut out = Vec::with_capacity(n);
        let mut t_s = 0.0f64;
        match *self {
            ArrivalProcess::Poisson { rate_per_s } => {
                for _ in 0..n {
                    t_s += exp(rng, 1.0 / rate_per_s);
                    out.push((t_s * 1e6) as u64);
                }
            }
            ArrivalProcess::Bursty { rate_on_per_s, mean_on_s, mean_off_s } => {
                let mut on_left_s = exp(rng, mean_on_s);
                while out.len() < n {
                    let gap = exp(rng, 1.0 / rate_on_per_s);
                    if gap <= on_left_s {
                        on_left_s -= gap;
                        t_s += gap;
                        out.push((t_s * 1e6) as u64);
                    } else {
                        // burst ends before the next arrival: spend the
                        // rest of the ON period, sleep through OFF, and
                        // start a fresh burst (memoryless, so the
                        // discarded gap costs nothing statistically).
                        t_s += on_left_s + exp(rng, mean_off_s);
                        on_left_s = exp(rng, mean_on_s);
                    }
                }
            }
        }
        out
    }
}

/// One open-loop arrival: a request of `tokens` tokens at `t_us`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArrivalEvent {
    pub t_us: u64,
    pub tokens: u64,
}

/// Draw a full arrival schedule: timestamps from the process, lengths
/// from the distribution, both through one seeded stream (so a
/// (process, dist, seed) triple names one exact workload).
pub fn generate_arrivals(
    process: &ArrivalProcess,
    dist: &LengthDist,
    rng: &mut Rng,
    n: usize,
) -> Vec<ArrivalEvent> {
    let times = process.sample_arrivals_us(rng, n);
    times
        .into_iter()
        .map(|t_us| ArrivalEvent { t_us, tokens: dist.sample(rng) })
        .collect()
}

/// Header line of the replayable arrival-trace format.
pub const ARRIVAL_TRACE_HEADER: &str = "# tas-arrivals v1";

/// Serialise arrivals as a replayable text trace: one `t_us tokens` line
/// per request under a version header.  The format is the unit of
/// workload exchange — `tas fleet --arrivals-out` writes it, and
/// `--arrivals-in` replays it bit-for-bit (same schedule, any router /
/// replica count / SLO under test).
pub fn format_arrival_trace(arrivals: &[ArrivalEvent]) -> String {
    let mut out = String::with_capacity(arrivals.len() * 12 + 32);
    out.push_str(ARRIVAL_TRACE_HEADER);
    out.push('\n');
    for a in arrivals {
        out.push_str(&format!("{} {}\n", a.t_us, a.tokens));
    }
    out
}

/// Parse the [`format_arrival_trace`] format. Comments (`#`) and blank
/// lines are ignored after the mandatory version header; timestamps must
/// be non-decreasing and every request non-empty.
pub fn parse_arrival_trace(text: &str) -> anyhow::Result<Vec<ArrivalEvent>> {
    let mut lines = text.lines();
    let header = lines.next().unwrap_or("").trim();
    anyhow::ensure!(
        header == ARRIVAL_TRACE_HEADER,
        "bad arrival trace header '{header}' (want '{ARRIVAL_TRACE_HEADER}')"
    );
    let mut out = Vec::new();
    let mut last = 0u64;
    for (i, line) in lines.enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (t_s, tok_s) = line
            .split_once(' ')
            .ok_or_else(|| anyhow::anyhow!("line {}: want 't_us tokens'", i + 2))?;
        let t_us: u64 = t_s
            .parse()
            .map_err(|_| anyhow::anyhow!("line {}: bad timestamp '{t_s}'", i + 2))?;
        let tokens: u64 = tok_s
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("line {}: bad token count '{tok_s}'", i + 2))?;
        anyhow::ensure!(t_us >= last, "line {}: timestamps must not decrease", i + 2);
        anyhow::ensure!(tokens >= 1, "line {}: empty request", i + 2);
        last = t_us;
        out.push(ArrivalEvent { t_us, tokens });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_conversion_matches_paper_anchors() {
        assert_eq!(tokens_for_seconds(2.3), LIBRISPEECH_MIN);
        assert_eq!(tokens_for_seconds(7.6), 380); // paper rounds to 384
        assert_eq!(tokens_for_seconds(31.3), LIBRISPEECH_MAX);
    }

    #[test]
    fn librispeech_samples_in_range_with_plausible_mean() {
        let dist = LengthDist::librispeech();
        let mut rng = Rng::new(42);
        let xs = dist.sample_n(&mut rng, 20_000);
        assert!(xs.iter().all(|&x| (115..=1565).contains(&x)));
        let mean = xs.iter().sum::<u64>() as f64 / xs.len() as f64;
        // clipping pulls the mean slightly below the unclipped 384
        assert!((300.0..450.0).contains(&mean), "mean {mean}");
    }

    #[test]
    fn fixed_dist_is_constant() {
        let dist = LengthDist::fixed(512);
        let mut rng = Rng::new(1);
        assert!(dist.sample_n(&mut rng, 100).iter().all(|&x| x == 512));
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let dist = LengthDist::librispeech();
        let a = dist.sample_n(&mut Rng::new(7), 50);
        let b = dist.sample_n(&mut Rng::new(7), 50);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn lognormal_rejects_inverted_bounds() {
        LengthDist::lognormal(100, 0.5, 200, 100);
    }

    #[test]
    fn dist_parse_covers_the_cli_specs() {
        let max = 256;
        let lib = LengthDist::parse("librispeech", max).unwrap();
        assert_eq!(lib.bounds(), (4, 256));
        let fixed = LengthDist::parse("fixed", max).unwrap();
        assert_eq!(fixed.bounds(), (64, 64));
        let fixed_n = LengthDist::parse("fixed:100", max).unwrap();
        assert_eq!(fixed_n.bounds(), (100, 100));
        let ln = LengthDist::parse("lognormal:80,0.4", max).unwrap();
        assert_eq!(ln.bounds(), (4, 256));
        let mut rng = Rng::new(3);
        let xs = ln.sample_n(&mut rng, 5000);
        let mean = xs.iter().sum::<u64>() as f64 / xs.len() as f64;
        assert!((60.0..110.0).contains(&mean), "mean {mean}");
        assert!(LengthDist::parse("nope", max).is_err());
        assert!(LengthDist::parse("lognormal:80", max).is_err());
        assert!(LengthDist::parse("fixed:0", max).is_err());
        assert!(LengthDist::parse("fixed:257", max).is_err());
    }

    #[test]
    fn poisson_arrivals_hit_the_target_rate() {
        let p = ArrivalProcess::poisson(1000.0);
        let mut rng = Rng::new(9);
        let n = 50_000;
        let times = p.sample_arrivals_us(&mut rng, n);
        assert!(times.windows(2).all(|w| w[0] <= w[1]));
        let horizon_s = *times.last().unwrap() as f64 / 1e6;
        let rate = n as f64 / horizon_s;
        assert!(
            (rate - 1000.0).abs() < 20.0,
            "empirical rate {rate} missed target 1000 (±2%)"
        );
    }

    #[test]
    fn bursty_arrivals_hit_the_duty_cycled_rate() {
        let p = ArrivalProcess::bursty(2000.0, 0.05, 0.05);
        assert!((p.mean_rate_per_s() - 1000.0).abs() < 1e-9);
        let mut rng = Rng::new(11);
        let n = 50_000;
        let times = p.sample_arrivals_us(&mut rng, n);
        let horizon_s = *times.last().unwrap() as f64 / 1e6;
        let rate = n as f64 / horizon_s;
        assert!(
            (rate - 1000.0).abs() < 50.0,
            "empirical rate {rate} missed duty-cycled 1000 (±5%)"
        );
    }

    #[test]
    fn arrival_generation_is_deterministic_per_seed() {
        let p = ArrivalProcess::bursty(500.0, 0.1, 0.1);
        let d = LengthDist::librispeech();
        let a = generate_arrivals(&p, &d, &mut Rng::new(13), 200);
        let b = generate_arrivals(&p, &d, &mut Rng::new(13), 200);
        assert_eq!(a, b);
        let c = generate_arrivals(&p, &d, &mut Rng::new(14), 200);
        assert_ne!(a, c, "different seed, different schedule");
    }

    #[test]
    fn arrival_trace_round_trips() {
        let p = ArrivalProcess::poisson(100.0);
        let d = LengthDist::fixed(64);
        let arrivals = generate_arrivals(&p, &d, &mut Rng::new(5), 100);
        let text = format_arrival_trace(&arrivals);
        let back = parse_arrival_trace(&text).unwrap();
        assert_eq!(arrivals, back);
        assert!(parse_arrival_trace("no header\n1 2\n").is_err());
        assert!(parse_arrival_trace("# tas-arrivals v1\n5 3\n4 3\n").is_err());
        assert!(parse_arrival_trace("# tas-arrivals v1\n5 0\n").is_err());
        // comments and blank lines are tolerated after the header
        let ok = parse_arrival_trace("# tas-arrivals v1\n# c\n\n5 3\n").unwrap();
        assert_eq!(ok, vec![ArrivalEvent { t_us: 5, tokens: 3 }]);
    }
}
