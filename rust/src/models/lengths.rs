//! Sequence-length distributions — the input-length variability that
//! motivates the adaptive scheme (§I, Table III).
//!
//! LibriSpeech (the paper's ASR dataset) is not shipped here; Table III
//! only depends on its token-length anchors, which the paper states:
//! shortest 2.3 s = 115 tokens, mean 7.6 s = 384, longest 31.3 s = 1565
//! (wav2vec2 emits ≈50 tokens/s).  For serving experiments we model the
//! length distribution as a clipped log-normal through those anchors
//! (speech-corpus durations are classically log-normal).

use crate::util::prng::Rng;

/// Wav2vec2 frame rate: one token per 20 ms of audio.
pub const TOKENS_PER_SECOND: u64 = 50;

/// Table III's anchor lengths, in tokens.
pub const LIBRISPEECH_MIN: u64 = 115;
pub const LIBRISPEECH_MEAN: u64 = 384;
pub const LIBRISPEECH_MAX: u64 = 1565;
/// The paper's long-speech extrapolation row.
pub const LONG_SPEECH: u64 = 15_000;

/// Token count for an audio clip length in seconds.
pub fn tokens_for_seconds(seconds: f64) -> u64 {
    (seconds * TOKENS_PER_SECOND as f64).round().max(1.0) as u64
}

/// A clipped log-normal token-length distribution.
#[derive(Clone, Debug)]
pub struct LengthDist {
    mu: f64,
    sigma: f64,
    min: u64,
    max: u64,
}

impl LengthDist {
    /// LibriSpeech-like: log-normal with mean ≈ 384 tokens, clipped to the
    /// dataset's observed [115, 1565] token range.
    pub fn librispeech() -> Self {
        let sigma: f64 = 0.55;
        // mean of lognormal = exp(mu + sigma²/2) -> mu = ln(mean) − σ²/2
        let mu = (LIBRISPEECH_MEAN as f64).ln() - sigma * sigma / 2.0;
        LengthDist { mu, sigma, min: LIBRISPEECH_MIN, max: LIBRISPEECH_MAX }
    }

    /// Fixed-length "distribution" (NLP benchmarks with padded batches).
    pub fn fixed(tokens: u64) -> Self {
        LengthDist { mu: (tokens as f64).ln(), sigma: 0.0, min: tokens, max: tokens }
    }

    /// General clipped log-normal around `mean_tokens`.
    pub fn lognormal(mean_tokens: u64, sigma: f64, min: u64, max: u64) -> Self {
        assert!(min <= max && mean_tokens > 0);
        let mu = (mean_tokens as f64).ln() - sigma * sigma / 2.0;
        LengthDist { mu, sigma, min, max }
    }

    /// Draw one token length.
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        if self.sigma == 0.0 {
            return self.min;
        }
        let x = rng.gen_lognormal(self.mu, self.sigma);
        (x.round() as u64).clamp(self.min, self.max)
    }

    /// Draw `n` lengths.
    pub fn sample_n(&self, rng: &mut Rng, n: usize) -> Vec<u64> {
        (0..n).map(|_| self.sample(rng)).collect()
    }

    pub fn bounds(&self) -> (u64, u64) {
        (self.min, self.max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_conversion_matches_paper_anchors() {
        assert_eq!(tokens_for_seconds(2.3), LIBRISPEECH_MIN);
        assert_eq!(tokens_for_seconds(7.6), 380); // paper rounds to 384
        assert_eq!(tokens_for_seconds(31.3), LIBRISPEECH_MAX);
    }

    #[test]
    fn librispeech_samples_in_range_with_plausible_mean() {
        let dist = LengthDist::librispeech();
        let mut rng = Rng::new(42);
        let xs = dist.sample_n(&mut rng, 20_000);
        assert!(xs.iter().all(|&x| (115..=1565).contains(&x)));
        let mean = xs.iter().sum::<u64>() as f64 / xs.len() as f64;
        // clipping pulls the mean slightly below the unclipped 384
        assert!((300.0..450.0).contains(&mean), "mean {mean}");
    }

    #[test]
    fn fixed_dist_is_constant() {
        let dist = LengthDist::fixed(512);
        let mut rng = Rng::new(1);
        assert!(dist.sample_n(&mut rng, 100).iter().all(|&x| x == 512));
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let dist = LengthDist::librispeech();
        let a = dist.sample_n(&mut Rng::new(7), 50);
        let b = dist.sample_n(&mut Rng::new(7), 50);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn lognormal_rejects_inverted_bounds() {
        LengthDist::lognormal(100, 0.5, 200, 100);
    }
}
