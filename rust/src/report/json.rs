//! Shared JSON report assembly for the CLI.
//!
//! Every `tas` subcommand used to hand-roll its own `--json` document in
//! `main.rs`; this module centralises the value helpers and wraps each
//! document in one consistent envelope, so `simulate`/`plan`/`shard`/
//! `sweep`/`trace`/`decode` all emit:
//!
//! ```json
//! {"command": "<subcommand>", "schema_version": 1, ...fields}
//! ```
//!
//! Downstream tooling dispatches on `command` and can rely on the field
//! names staying put within a schema version.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// `Json::Num` from a count (exact below 2^53 — every EMA figure is).
pub fn jnum(v: u64) -> Json {
    Json::Num(v as f64)
}

pub fn jf64(v: f64) -> Json {
    Json::Num(v)
}

pub fn jstr(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn jbool(v: bool) -> Json {
    Json::Bool(v)
}

pub fn jnull() -> Json {
    Json::Null
}

/// `null` for absent values — the JSON-safe encoding of "no samples yet"
/// (a bare `NaN` token is not valid JSON and breaks downstream parsers).
pub fn jopt(v: Option<f64>) -> Json {
    match v {
        Some(x) if x.is_finite() => Json::Num(x),
        _ => Json::Null,
    }
}

pub fn jarr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn jobj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        entries
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<String, Json>>(),
    )
}

/// Builder for one subcommand's report document.
pub struct Report {
    fields: Vec<(String, Json)>,
}

impl Report {
    pub fn new(command: &str) -> Report {
        Report {
            fields: vec![
                ("command".to_string(), jstr(command)),
                ("schema_version".to_string(), jnum(1)),
            ],
        }
    }

    pub fn field(mut self, key: &str, value: Json) -> Report {
        self.fields.push((key.to_string(), value));
        self
    }

    pub fn into_json(self) -> Json {
        Json::Obj(self.fields.into_iter().collect::<BTreeMap<String, Json>>())
    }

    /// Print the document compactly to stdout — the one emission path
    /// every subcommand shares.
    pub fn print(self) {
        println!("{}", self.into_json().to_string_compact());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn envelope_carries_command_and_version() {
        let doc = Report::new("simulate")
            .field("total", jnum(42))
            .field("ok", jbool(true))
            .into_json();
        assert_eq!(doc.get("command").unwrap().as_str(), Some("simulate"));
        assert_eq!(doc.get("schema_version").unwrap().as_u64(), Some(1));
        assert_eq!(doc.get("total").unwrap().as_u64(), Some(42));
        // round-trips through the parser
        let text = doc.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), doc);
    }

    #[test]
    fn helpers_build_the_expected_values() {
        assert_eq!(jnum(7), Json::Num(7.0));
        assert_eq!(jstr("x"), Json::Str("x".into()));
        assert_eq!(jbool(false), Json::Bool(false));
        assert_eq!(jnull(), Json::Null);
        assert_eq!(jopt(None), Json::Null);
        assert_eq!(jopt(Some(f64::NAN)), Json::Null);
        assert_eq!(jopt(Some(2.5)), Json::Num(2.5));
        let o = jobj(vec![("a", jnum(1)), ("b", jarr(vec![jnum(2)]))]);
        assert_eq!(o.get("a").unwrap().as_u64(), Some(1));
        assert_eq!(o.get("b").unwrap().as_arr().unwrap().len(), 1);
    }
}
