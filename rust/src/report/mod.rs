//! Paper-table generators: each function renders one of the paper's
//! tables from the analytic model / simulator, shaped like the original
//! so the two can be diffed by eye.  Used by `tas tables`, the benches
//! and EXPERIMENTS.md.  [`json`] holds the shared `--json` report
//! envelope every CLI subcommand emits; [`explain`] builds the
//! `tas explain` EMA attribution ledger; [`prom`] renders metrics
//! snapshots as Prometheus text exposition for `--metrics-out`.

pub mod explain;
pub mod figviz;
pub mod json;
pub mod prom;

use crate::dataflow::{analytic, ema, Scheme};
use crate::energy::{ayaka::ayaka_workload_read_ema, workload_read_ema};
use crate::gemm::{GemmShape, Tiling};
use crate::models::{self, lengths, ModelSpec};
use crate::util::prng::Rng;
use crate::util::table::{pct, sci, Table};

/// Table I: model stats + total naive EMA (words) for the Table I trio.
pub fn table1(tiling: &Tiling) -> Table {
    let mut t = Table::new(
        "Table I — representative large models (EMA = naive read EMA, G-words)",
        &["model", "hidden", "token len", "params (B)", "total EMA (G)", "TAS EMA (G)"],
    );
    for m in [models::vit_g14(), models::xlsr_2b(), models::gpt3()] {
        let gemms = m.linear_gemms(m.default_seq);
        let naive = workload_read_ema(Scheme::Naive, &gemms, tiling);
        let tas = workload_read_ema(Scheme::Tas, &gemms, tiling);
        t.row(vec![
            m.name.to_string(),
            m.hidden.to_string(),
            m.default_seq.to_string(),
            format!("{:.1}", m.params_b),
            format!("{:.1}", naive as f64 / 1e9),
            format!("{:.2}", tas as f64 / 1e9),
        ]);
    }
    t
}

/// Table II: closed-form EMA per scheme on a symbolic-ish example shape,
/// cross-checked against the formulas.
pub fn table2(shape: &GemmShape, tiling: &Tiling) -> Table {
    let mut t = Table::new(
        &format!(
            "Table II — EMA (words) per stationary scheme, M={} N={} K={} tiles ({},{},{})",
            shape.m, shape.n, shape.k, tiling.tm, tiling.tn, tiling.tk
        ),
        &["scheme", "input", "weight", "output", "total", "vs naive"],
    );
    let naive_total = ema(Scheme::Naive, shape, tiling).total();
    for s in Scheme::FIXED {
        let e = ema(s, shape, tiling);
        t.row(vec![
            s.name().to_string(),
            sci(e.input as f64),
            sci(e.weight as f64),
            sci(e.output as f64),
            sci(e.total() as f64),
            pct(1.0 - e.total() as f64 / naive_total as f64),
        ]);
    }
    t
}

/// Table III: stationary-matrix EMA for Wav2Vec2.0-Large across
/// LibriSpeech sequence lengths; the IS−WS difference column decides.
pub fn table3() -> Table {
    let model = models::wav2vec2_large();
    let mut t = Table::new(
        "Table III — EMA (words) of the reused matrix, Wav2Vec2.0-Large Q projection",
        &["seq_len", "IS", "WS", "IS-WS", "optimal ss."],
    );
    for seq in [
        lengths::LIBRISPEECH_MIN,
        lengths::LIBRISPEECH_MEAN,
        lengths::LIBRISPEECH_MAX,
        lengths::LONG_SPEECH,
    ] {
        // Q projection: M = seq, N = K = hidden.
        let shape = GemmShape::new(seq, model.hidden, model.hidden);
        let is = analytic::stationary_matrix_words(Scheme::Is, &shape);
        let ws = analytic::stationary_matrix_words(Scheme::Ws, &shape);
        let diff = analytic::is_ws_difference(&shape);
        t.row(vec![
            seq.to_string(),
            sci(is as f64),
            sci(ws as f64),
            sci(diff as f64),
            if diff < 0 { "IS".into() } else { "WS".into() },
        ]);
    }
    t
}

/// One Table IV row: per-layer read-EMA proxy energies + reductions.
#[derive(Clone, Debug)]
pub struct Table4Row {
    pub layer: u64,
    pub naive: f64,
    pub ayaka: f64,
    pub ours: f64,
    pub red_ayaka: f64,
    pub red_ours: f64,
}

/// Table IV: BERT-Base per-layer energy (read-EMA proxy, §IV) under
/// naive / Ayaka-fixed [9] / TAS.  Per-layer sequence lengths are drawn
/// near the nominal 384 tokens (fixed seed) to reproduce the paper's
/// ±2% row spread — see DESIGN.md §4.4.
pub fn table4_rows(tiling: &Tiling, seed: u64) -> Vec<Table4Row> {
    let model = models::bert_base();
    let mut rng = Rng::new(seed);
    // Energy scale: 200 pJ per DRAM word -> report in mJ (the paper's
    // absolute column is unit-less; only the reduction ratios transfer).
    let scale = 200.0 * 1e-9; // pJ/word -> mJ
    let mut rows = Vec::new();
    for layer in 0..=12 {
        // per-layer measured occupancy: 384 ± up to ~2%
        let seq = 376 + rng.gen_range(17); // 376..=392
        let gemms = per_layer_gemms(&model, seq, layer);
        let naive_w = workload_read_ema(Scheme::Naive, &gemms, tiling) as f64;
        let ayaka_w = ayaka_workload_read_ema(&gemms) as f64;
        let ours_w = workload_read_ema(Scheme::Tas, &gemms, tiling) as f64;
        rows.push(Table4Row {
            layer,
            naive: naive_w * scale,
            ayaka: ayaka_w * scale,
            ours: ours_w * scale,
            red_ayaka: 1.0 - ayaka_w / naive_w,
            red_ours: 1.0 - ours_w / naive_w,
        });
    }
    rows
}

/// The paper's Table IV lists 13 rows (0..=12) for BERT-Base: 12 encoder
/// layers plus the output stage; row 12 is the MLM head projection.
fn per_layer_gemms(model: &ModelSpec, seq: u64, layer: u64) -> Vec<models::GemmWorkload> {
    if layer < 12 {
        let mut per_layer = model.linear_gemms(seq);
        for g in &mut per_layer {
            g.count /= model.layers; // one layer's worth
        }
        per_layer
    } else {
        vec![models::GemmWorkload {
            name: "mlm_head",
            shape: GemmShape::new(seq, model.hidden, 30522),
            count: 1,
        }]
    }
}

pub fn table4(tiling: &Tiling, seed: u64) -> Table {
    let mut t = Table::new(
        "Table IV — BERT-Base per-layer energy (read-EMA proxy, mJ)",
        &["layer", "naive (A)", "ayaka [9] (B)", "ours (C)", "(A-B)/A", "(A-C)/A"],
    );
    let rows = table4_rows(tiling, seed);
    for r in &rows {
        t.row(vec![
            r.layer.to_string(),
            format!("{:.2}", r.naive),
            format!("{:.2}", r.ayaka),
            format!("{:.2}", r.ours),
            pct(r.red_ayaka),
            pct(r.red_ours),
        ]);
    }
    let n = rows.len() as f64;
    t.row(vec![
        "mean".into(),
        format!("{:.2}", rows.iter().map(|r| r.naive).sum::<f64>() / n),
        format!("{:.2}", rows.iter().map(|r| r.ayaka).sum::<f64>() / n),
        format!("{:.2}", rows.iter().map(|r| r.ours).sum::<f64>() / n),
        pct(rows.iter().map(|r| r.red_ayaka).sum::<f64>() / n),
        pct(rows.iter().map(|r| r.red_ours).sum::<f64>() / n),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t16() -> Tiling {
        Tiling::square(16)
    }

    #[test]
    fn table1_has_three_models() {
        let t = table1(&t16());
        assert_eq!(t.rows.len(), 3);
        assert!(t.rows[2][0] == "gpt-3");
        // GPT-3's EMA dwarfs the others (paper: 11,132.6G vs ~300G)
        let vit: f64 = t.rows[0][4].parse().unwrap();
        let gpt: f64 = t.rows[2][4].parse().unwrap();
        assert!(gpt > 20.0 * vit, "vit {vit} gpt {gpt}");
    }

    #[test]
    fn table3_matches_paper_exactly() {
        // The IS/WS columns are pure shape arithmetic — they must equal
        // the paper's mantissas at two decimals.
        let t = table3();
        assert_eq!(t.rows[0], vec!["115", "1.18e5", "1.05e6", "-9.31e5", "IS"]);
        assert_eq!(t.rows[1][1], "3.93e5");
        assert_eq!(t.rows[1][4], "IS");
        assert_eq!(t.rows[2][1], "1.60e6");
        assert_eq!(t.rows[2][4], "WS");
        assert_eq!(t.rows[3][4], "WS");
        assert_eq!(t.rows[3][1], "1.54e7");
    }

    #[test]
    fn table4_reductions_match_paper_bands() {
        let rows = table4_rows(&t16(), 0xBEEF);
        assert_eq!(rows.len(), 13);
        for r in &rows {
            assert!(
                (0.44..0.52).contains(&r.red_ayaka),
                "layer {}: ayaka {}",
                r.layer,
                r.red_ayaka
            );
            assert!(
                (0.955..0.985).contains(&r.red_ours),
                "layer {}: ours {}",
                r.layer,
                r.red_ours
            );
            assert!(r.naive > r.ayaka && r.ayaka > r.ours);
        }
    }

    #[test]
    fn table2_total_column_consistent() {
        let shape = GemmShape::new(384, 768, 768);
        let t = table2(&shape, &t16());
        assert_eq!(t.rows.len(), 7);
        assert_eq!(t.rows[0][0], "naive");
    }
}
