//! Fig. 1 / Fig. 2 regeneration: renders a schedule's tile movement as
//! ASCII matrix maps — the executable version of the paper's arrow
//! diagrams.  Each matrix cell shows the *order* in which its tile is
//! first touched (base-36), so the circled-number sequences in the
//! figures can be read directly off the output; stationary phases show
//! up as repeated visits (the `visits` map).

use crate::dataflow::{for_each_step, Scheme};
use crate::gemm::{GemmShape, Tiling};

/// Rendered dataflow maps for one schedule.
#[derive(Clone, Debug)]
pub struct FigViz {
    pub scheme: Scheme,
    /// First-touch order per input tile (gm × gn).
    pub input_order: Vec<Vec<u64>>,
    /// First-touch order per weight tile (gn × gk).
    pub weight_order: Vec<Vec<u64>>,
    /// Completion (store) order per output tile (gm × gk).
    pub output_order: Vec<Vec<u64>>,
    /// DRAM loads per input tile (reuse = 1 ⇒ stationary win).
    pub input_loads: Vec<Vec<u64>>,
    pub weight_loads: Vec<Vec<u64>>,
}

/// Trace `scheme` and collect the figure maps.
pub fn trace_fig(scheme: Scheme, shape: &GemmShape, tiling: &Tiling) -> FigViz {
    let (gm, gn, gk) = tiling.grid(shape);
    let mut viz = FigViz {
        scheme: scheme.resolve(shape),
        input_order: vec![vec![u64::MAX; gn as usize]; gm as usize],
        weight_order: vec![vec![u64::MAX; gk as usize]; gn as usize],
        output_order: vec![vec![u64::MAX; gk as usize]; gm as usize],
        input_loads: vec![vec![0; gn as usize]; gm as usize],
        weight_loads: vec![vec![0; gk as usize]; gn as usize],
    };
    let mut touch = 0u64;
    let mut stores = 0u64;
    for_each_step(scheme, shape, tiling, |s| {
        let (i, r, j) = (s.i as usize, s.r as usize, s.j as usize);
        if viz.input_order[i][r] == u64::MAX {
            viz.input_order[i][r] = touch;
        }
        if viz.weight_order[r][j] == u64::MAX {
            viz.weight_order[r][j] = touch;
        }
        if s.load_input || s.scalar_traffic {
            viz.input_loads[i][r] += 1;
        }
        if s.load_weight || s.scalar_traffic {
            viz.weight_loads[r][j] += 1;
        }
        if s.store_out && viz.output_order[i][j] == u64::MAX {
            viz.output_order[i][j] = stores;
            stores += 1;
        }
        touch += 1;
    });
    viz
}

fn digit36(x: u64) -> char {
    match x {
        0..=9 => (b'0' + x as u8) as char,
        10..=35 => (b'a' + (x - 10) as u8) as char,
        _ => '*',
    }
}

fn render_grid(title: &str, grid: &[Vec<u64>], rank: bool) -> String {
    // rank mode: compress values to their order statistics so maps stay
    // single-character even for long schedules.
    let mut vals: Vec<u64> = grid.iter().flatten().copied().collect();
    vals.sort_unstable();
    vals.dedup();
    let mut out = format!("{title}\n");
    for row in grid {
        out.push_str("  ");
        for &v in row {
            if v == u64::MAX {
                out.push('.');
            } else if rank {
                let r = vals.binary_search(&v).unwrap() as u64;
                out.push(digit36(r));
            } else {
                out.push(digit36(v));
            }
            out.push(' ');
        }
        out.push('\n');
    }
    out
}

impl FigViz {
    /// Full figure text: touch-order maps + load counts.
    pub fn render(&self) -> String {
        let mut out = format!("== {} dataflow ==\n", self.scheme.name());
        out += &render_grid("input matrix (first-touch order, M×N tiles):", &self.input_order, true);
        out += &render_grid("weight matrix (first-touch order, N×K tiles):", &self.weight_order, true);
        out += &render_grid("output matrix (completion order, M×K tiles):", &self.output_order, true);
        out += &render_grid("input tile DRAM loads:", &self.input_loads, false);
        out += &render_grid("weight tile DRAM loads:", &self.weight_loads, false);
        out
    }

    /// Max loads of any input / weight tile — the figure's reuse story.
    pub fn max_loads(&self) -> (u64, u64) {
        let maxi = self.input_loads.iter().flatten().copied().max().unwrap_or(0);
        let maxw = self.weight_loads.iter().flatten().copied().max().unwrap_or(0);
        (maxi, maxw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> (GemmShape, Tiling) {
        (GemmShape::new(64, 48, 80), Tiling::square(16))
    }

    #[test]
    fn is_loads_input_once() {
        let (shape, t) = small();
        let viz = trace_fig(Scheme::Is, &shape, &t);
        let (maxi, maxw) = viz.max_loads();
        assert_eq!(maxi, 1); // Fig. 1b: input tiles enter once
        assert_eq!(maxw as u64, shape.m / t.tm); // weights re-read per row block
    }

    #[test]
    fn ws_loads_weight_once() {
        let (shape, t) = small();
        let viz = trace_fig(Scheme::Ws, &shape, &t);
        let (maxi, maxw) = viz.max_loads();
        assert_eq!(maxw, 1); // Fig. 1c
        assert_eq!(maxi as u64, shape.k / t.tk);
    }

    #[test]
    fn tas_resolves_before_rendering() {
        let (shape, t) = small(); // M=64 < K=80 -> IS-OS
        let viz = trace_fig(Scheme::Tas, &shape, &t);
        assert_eq!(viz.scheme, Scheme::IsOs);
        assert_eq!(viz.max_loads().0, 1);
    }

    #[test]
    fn every_output_tile_completes() {
        let (shape, t) = small();
        for scheme in Scheme::FIXED {
            let viz = trace_fig(scheme, &shape, &t);
            assert!(
                viz.output_order.iter().flatten().all(|&v| v != u64::MAX),
                "{scheme:?} left output tiles incomplete"
            );
        }
    }

    #[test]
    fn os_row_completes_row_major_os_col_column_major() {
        let (shape, t) = small();
        let row = trace_fig(Scheme::OsRow, &shape, &t).output_order;
        // row-major: order increases along each row
        for r in &row {
            for w in r.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
        let col = trace_fig(Scheme::OsCol, &shape, &t).output_order;
        for c in 0..col[0].len() {
            for i in 1..col.len() {
                assert!(col[i - 1][c] < col[i][c]);
            }
        }
    }

    #[test]
    fn render_is_readable() {
        let (shape, t) = small();
        let txt = trace_fig(Scheme::IsOs, &shape, &t).render();
        assert!(txt.contains("is-os dataflow"));
        assert!(txt.contains("input matrix"));
        // grid is gm rows of gn cells
        let lines: Vec<&str> = txt.lines().collect();
        assert!(lines.len() > 15);
    }
}
