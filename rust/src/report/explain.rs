//! The `tas explain` EMA attribution ledger.
//!
//! Walks a layer plan ([`crate::dataflow::LayerPlan`]) stage by stage and
//! attributes every DRAM word the closed-form cost model charges: which
//! tensor moved it (input read / weight read / output write), what the
//! per-strip stationary choice was, how many words the choice saved over
//! the flipped orientation ([`StripShare::margin_words`]), and how many
//! rows the residency allocator parked in SRAM for the stage.
//!
//! The ledger is an *audit*, not a second model: per-stage word totals
//! are rebuilt from [`attribute_strips`] (strip bodies) and
//! [`crate::dataflow::Plan::ema`] (fixed-body fallback), and the property
//! suite pins them to [`crate::sim::strip::plan_cost`] **word-for-word**
//! across the model zoo — if the ledger and the planner ever disagree on
//! a single word, a test fails, not a report footnote.

use crate::config::AcceleratorConfig;
use crate::dataflow::{LayerPlan, PlanBody, StagePlan};
use crate::report::json::{jarr, jnum, jobj, jopt, jstr};
use crate::sim::strip::{attribute_strips, StripShare};
use crate::util::json::Json;

/// Ledger row for one GEMM stage of the planned block.
#[derive(Clone, Debug)]
pub struct StageLedger {
    /// Stage role, e.g. `"q"`, `"ffn1"`.
    pub name: &'static str,
    /// Instances per forward pass (usually the layer count).
    pub count: u64,
    /// Stationary decision summary across the stage's slices.
    pub decision: String,
    /// Device the stage runs on (0 unless the plan is sharded).
    pub device: usize,
    /// Input/output residency, as the planner's `hot/total` notation.
    pub input_residency: String,
    pub output_residency: String,
    /// SRAM-resident rows of the stage's input / output tensors — the
    /// pages the residency allocator granted this stage.
    pub input_hot_rows: u64,
    pub output_hot_rows: u64,
    /// Output tiles covered by input-stationary / weight-stationary
    /// strips across the stage's slices.
    pub is_tiles: u64,
    pub ws_tiles: u64,
    /// Gated DRAM words per stage instance, by tensor.
    pub input_words: u64,
    pub weight_words: u64,
    pub output_words: u64,
    /// Words the stationary choices saved per instance vs re-covering
    /// each strip in the flipped orientation (Σ strip margins).
    pub margin_words: u64,
    /// Per-instance words under per-GEMM TAS — the paper's baseline.
    pub per_gemm_tas_words: u64,
}

impl StageLedger {
    /// Total gated words per stage instance — must equal the planner's
    /// [`StagePlan::ema_words`] and the closed-form
    /// [`crate::sim::strip::plan_cost`] for the same slices.
    pub fn ema_words(&self) -> u64 {
        self.input_words + self.weight_words + self.output_words
    }
}

/// The full attribution ledger of one planned block.
#[derive(Clone, Debug)]
pub struct LayerLedger {
    /// Padded token count the block was planned for.
    pub tokens: u64,
    /// SRAM words the residency planner could park activations in.
    pub sram_budget: u64,
    /// Residency model that produced the plan (`"paged"`, ...).
    pub policy: &'static str,
    /// Peak SRAM words resident at any stage of the chain.
    pub resident_peak_words: u64,
    pub stages: Vec<StageLedger>,
}

impl LayerLedger {
    /// Total DRAM words of one forward pass (Σ count × stage words) —
    /// equals [`LayerPlan::total_ema`] by construction.
    pub fn total_ema(&self) -> u64 {
        self.stages.iter().map(|s| s.count * s.ema_words()).sum()
    }

    /// The per-GEMM TAS baseline for the same pass.
    pub fn per_gemm_tas_total(&self) -> u64 {
        self.stages
            .iter()
            .map(|s| s.count * s.per_gemm_tas_words)
            .sum()
    }

    /// Fractional EMA saved vs per-GEMM TAS; `None` on an empty baseline.
    pub fn reduction_vs_per_gemm(&self) -> Option<f64> {
        let base = self.per_gemm_tas_total();
        if base == 0 {
            None
        } else {
            Some(1.0 - self.total_ema() as f64 / base as f64)
        }
    }

    /// The ledger as a JSON value (embedded in the `tas explain --json`
    /// report envelope).
    pub fn to_json(&self) -> Json {
        let stages = self
            .stages
            .iter()
            .map(|s| {
                jobj(vec![
                    ("stage", jstr(s.name)),
                    ("count", jnum(s.count)),
                    ("decision", jstr(&s.decision)),
                    ("device", jnum(s.device as u64)),
                    ("input_residency", jstr(&s.input_residency)),
                    ("output_residency", jstr(&s.output_residency)),
                    ("input_hot_rows", jnum(s.input_hot_rows)),
                    ("output_hot_rows", jnum(s.output_hot_rows)),
                    ("is_tiles", jnum(s.is_tiles)),
                    ("ws_tiles", jnum(s.ws_tiles)),
                    ("input_words", jnum(s.input_words)),
                    ("weight_words", jnum(s.weight_words)),
                    ("output_words", jnum(s.output_words)),
                    ("ema_words", jnum(s.ema_words())),
                    ("margin_words", jnum(s.margin_words)),
                    ("per_gemm_tas_words", jnum(s.per_gemm_tas_words)),
                ])
            })
            .collect();
        jobj(vec![
            ("tokens", jnum(self.tokens)),
            ("sram_words", jnum(self.sram_budget)),
            ("policy", jstr(self.policy)),
            ("resident_peak_words", jnum(self.resident_peak_words)),
            ("total_ema_words", jnum(self.total_ema())),
            ("per_gemm_tas_words", jnum(self.per_gemm_tas_total())),
            ("reduction_vs_per_gemm", jopt(self.reduction_vs_per_gemm())),
            ("stages", jarr(stages)),
        ])
    }
}

/// Attribute one stage: per-strip shares on strip bodies, the analytic
/// breakdown on fixed-body fallbacks (no strips to attribute — margin 0).
fn stage_ledger(stage: &StagePlan, cfg: &AcceleratorConfig) -> StageLedger {
    let (mut iw, mut ww, mut ow, mut margin) = (0u64, 0u64, 0u64, 0u64);
    let (mut is_tiles, mut ws_tiles) = (0u64, 0u64);
    for plan in &stage.slices {
        match &plan.body {
            PlanBody::Strips(_) => {
                for share in attribute_strips(plan, cfg) {
                    let StripShare { input_words, weight_words, output_words, .. } = share;
                    iw += input_words;
                    ww += weight_words;
                    ow += output_words;
                    margin += share.margin_words();
                }
            }
            PlanBody::Fixed(_) => {
                let e = plan.ema();
                iw += e.input;
                ww += e.weight;
                ow += e.output;
            }
        }
        let (is, ws, _) = plan.tile_mix();
        is_tiles += is;
        ws_tiles += ws;
    }
    StageLedger {
        name: stage.spec.name,
        count: stage.spec.count,
        decision: stage.describe(),
        device: stage.device,
        input_residency: stage.input.describe(),
        output_residency: stage.output.describe(),
        input_hot_rows: stage.input.hot_in(stage.spec.shape.m),
        output_hot_rows: stage.output.hot_in(stage.spec.shape.m),
        is_tiles,
        ws_tiles,
        input_words: iw,
        weight_words: ww,
        output_words: ow,
        margin_words: margin,
        per_gemm_tas_words: stage.per_gemm_tas_words,
    }
}

/// Build the attribution ledger for a planned block.
pub fn explain_layer_plan(plan: &LayerPlan, cfg: &AcceleratorConfig) -> LayerLedger {
    LayerLedger {
        tokens: plan.tokens,
        sram_budget: plan.sram_budget,
        policy: plan.policy.name(),
        resident_peak_words: plan.resident_peak_words,
        stages: plan.stages.iter().map(|s| stage_ledger(s, cfg)).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::EnergyModel;
    use crate::gemm::Tiling;
    use crate::models::zoo;
    use crate::sim::strip::plan_cost;

    #[test]
    fn ledger_matches_the_planner_and_the_cost_model() {
        let model = zoo::by_name("bert-base").unwrap();
        let seq = 64;
        let tiling = Tiling::square(16);
        let cfg = AcceleratorConfig::default();
        let plan =
            LayerPlan::plan(model.block_stages(seq), seq, &tiling, cfg.sram_words);
        let ledger = explain_layer_plan(&plan, &cfg);

        // Layer-level reconciliation with the planner's own totals.
        assert_eq!(ledger.total_ema(), plan.total_ema());
        assert_eq!(ledger.per_gemm_tas_total(), plan.per_gemm_tas_total());

        // Stage-level reconciliation with plan_cost, word for word.
        let em = EnergyModel::default();
        for (row, stage) in ledger.stages.iter().zip(&plan.stages) {
            assert_eq!(row.ema_words(), stage.ema_words, "{}", row.name);
            let cost: u64 = stage
                .slices
                .iter()
                .map(|p| {
                    let (i, w, o) = plan_cost(p, &cfg, &em).ema.table2();
                    i + w + o
                })
                .sum();
            assert_eq!(row.ema_words(), cost, "{}", row.name);
        }

        // The document is valid JSON with the expected keys.
        let doc = ledger.to_json();
        let text = doc.to_string_compact();
        assert!(!text.contains("NaN"));
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        assert!(parsed.get("stages").unwrap().as_arr().unwrap().len() >= 6);
    }
}
