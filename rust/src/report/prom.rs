//! Prometheus text exposition (version 0.0.4) for metrics snapshots.
//!
//! [`Prom`] is a buffered writer: samples land grouped by metric family
//! so the rendered page carries one `# HELP`/`# TYPE` header per family
//! even when several replicas emit the same metric with different
//! labels (the format forbids repeating a family header).  Families
//! render in name order — deterministic output, diff-able in tests.
//!
//! [`render_metrics`] maps a [`MetricsSnapshot`] onto conventional
//! families (`_total` counters, gauges, latency summaries with
//! `quantile` labels plus exact `_sum`/`_count` series);
//! [`render_slo`] adds the SLO goodput/burn families from an
//! [`SloSnapshot`].  `tas serve --metrics-out` and `tas fleet
//! --metrics-out` both write through this path, so a scrape of either
//! surface parses with the same rules.

use crate::coordinator::MetricsSnapshot;
use crate::obs::slo::SloSnapshot;
use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    Counter,
    Gauge,
    Summary,
}

impl Kind {
    fn name(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Summary => "summary",
        }
    }
}

#[derive(Debug)]
struct Family {
    help: String,
    kind: Kind,
    lines: Vec<String>,
}

/// Buffered exposition writer; see the module docs.
#[derive(Debug, Default)]
pub struct Prom {
    families: BTreeMap<String, Family>,
}

/// Escape a label value per the exposition format: backslash, double
/// quote and newline must be backslash-escaped.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Render a `{k="v",...}` label block ("" when empty).
fn label_block(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let body: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{}=\"{}\"", k, escape_label(v)))
        .collect();
    format!("{{{}}}", body.join(","))
}

/// One sample value. `{}` prints integers bare and floats with the
/// shortest round-trip form; infinities use the format's +Inf/-Inf.
fn num(v: f64) -> String {
    if v.is_infinite() {
        if v > 0.0 { "+Inf".into() } else { "-Inf".into() }
    } else {
        format!("{v}")
    }
}

impl Prom {
    pub fn new() -> Self {
        Prom::default()
    }

    fn family(&mut self, name: &str, help: &str, kind: Kind) -> &mut Family {
        let f = self.families.entry(name.to_string()).or_insert_with(|| Family {
            help: help.to_string(),
            kind,
            lines: Vec::new(),
        });
        debug_assert_eq!(f.kind, kind, "metric {name} re-registered as a different type");
        f
    }

    /// Add one counter sample. `name` should end in `_total` by
    /// convention; the value must be monotone from the source's view.
    pub fn counter(&mut self, name: &str, help: &str, labels: &[(&str, &str)], value: f64) {
        let line = format!("{}{} {}", name, label_block(labels), num(value));
        self.family(name, help, Kind::Counter).lines.push(line);
    }

    /// Add one gauge sample.
    pub fn gauge(&mut self, name: &str, help: &str, labels: &[(&str, &str)], value: f64) {
        let line = format!("{}{} {}", name, label_block(labels), num(value));
        self.family(name, help, Kind::Gauge).lines.push(line);
    }

    /// Add one summary: known quantiles (skipping empty ones) plus the
    /// exact `_sum` and `_count` series.
    pub fn summary(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        quantiles: &[(f64, Option<f64>)],
        sum: f64,
        count: u64,
    ) {
        let mut lines = Vec::new();
        for &(q, v) in quantiles {
            if let Some(v) = v {
                let mut ql: Vec<(&str, &str)> = labels.to_vec();
                let qs = num(q);
                ql.push(("quantile", &qs));
                lines.push(format!("{}{} {}", name, label_block(&ql), num(v)));
            }
        }
        lines.push(format!("{}_sum{} {}", name, label_block(labels), num(sum)));
        lines.push(format!("{}_count{} {}", name, label_block(labels), count));
        self.family(name, help, Kind::Summary).lines.append(&mut lines);
    }

    /// Render the exposition page: families in name order, each with one
    /// HELP/TYPE header followed by its buffered samples.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, f) in &self.families {
            let _ = writeln!(out, "# HELP {} {}", name, f.help);
            let _ = writeln!(out, "# TYPE {} {}", name, f.kind.name());
            for line in &f.lines {
                out.push_str(line);
                out.push('\n');
            }
        }
        out
    }
}

/// Render one coordinator's [`MetricsSnapshot`] into `prom` under
/// `labels` (e.g. `[("replica", "3")]`; empty for single `serve`).
pub fn render_metrics(prom: &mut Prom, labels: &[(&str, &str)], s: &MetricsSnapshot) {
    let c = |prom: &mut Prom, name: &str, help: &str, v: u64| {
        prom.counter(name, help, labels, v as f64);
    };
    c(prom, "tas_requests_total", "Requests dispatched in prefill batches.", s.requests);
    c(prom, "tas_batches_total", "Prefill batches dispatched.", s.batches);
    c(prom, "tas_tokens_total", "Real (unpadded) prefill tokens served.", s.tokens);
    c(prom, "tas_padded_tokens_total", "Padding tokens added by bucketing.", s.padded_tokens);
    c(prom, "tas_decode_batches_total", "Decode steps dispatched.", s.decode_batches);
    c(prom, "tas_decode_tokens_total", "Tokens generated by decode steps.", s.decode_tokens);
    c(prom, "tas_flops_total", "MAC count of dispatched work.", s.flops);
    c(
        prom,
        "tas_ema_naive_words_total",
        "DRAM read words the served batches would move under the naive scheme.",
        s.ema_naive_words,
    );
    c(
        prom,
        "tas_ema_tas_words_total",
        "DRAM read words under tile-based adaptive stationary.",
        s.ema_tas_words,
    );
    c(
        prom,
        "tas_ema_plan_words_total",
        "Total DRAM words of the served layer-level plans.",
        s.ema_plan_words,
    );
    c(
        prom,
        "tas_link_words_total",
        "Inter-chip activation handoff words of served plans.",
        s.link_words,
    );
    c(
        prom,
        "tas_planner_cache_hits_total",
        "Dispatch-planner plan-memo hits.",
        s.planner_cache.hits,
    );
    c(
        prom,
        "tas_planner_cache_misses_total",
        "Dispatch-planner plan-memo misses.",
        s.planner_cache.misses,
    );
    c(
        prom,
        "tas_searches_total",
        "Joint plan searches run (plan-database misses that priced candidates).",
        s.plan_db.searches,
    );
    c(
        prom,
        "tas_plan_db_hits_total",
        "Plan-database lookups served without a search (exact or congruent).",
        s.plan_db.db_hits,
    );
    c(
        prom,
        "tas_plan_db_misses_total",
        "Plan-database lookups that found no usable entry.",
        s.plan_db.db_misses,
    );
    c(
        prom,
        "tas_plan_db_evictions_total",
        "Plan-database spec keys evicted by the LRU cap.",
        s.plan_db.evictions,
    );
    c(
        prom,
        "tas_search_pruned_total",
        "Search candidates discarded by the beam lower bound.",
        s.plan_db.pruned,
    );
    prom.gauge(
        "tas_plan_db_entries",
        "Entries currently stored in the plan database.",
        labels,
        s.plan_db.entries as f64,
    );
    if let Some(v) = s.queue_depth {
        prom.gauge("tas_queue_depth", "Prefill queue depth at the last poll.", labels, v);
    }
    if let Some(v) = s.queue_depth_peak {
        prom.gauge(
            "tas_queue_depth_peak",
            "High-water prefill queue depth.",
            labels,
            v,
        );
    }
    if let Some(v) = s.batch_occupancy {
        prom.gauge(
            "tas_batch_occupancy",
            "Requests over bucket capacity of the last dispatched batch.",
            labels,
            v,
        );
    }
    prom.summary(
        "tas_request_latency_ms",
        "End-to-end request latency (milliseconds).",
        labels,
        &[(0.5, s.latency_p50_ms), (0.99, s.latency_p99_ms)],
        s.latency_sum_ms,
        s.latency_count,
    );
    prom.summary(
        "tas_ttft_ms",
        "Time to first token (milliseconds).",
        labels,
        &[(0.5, s.ttft_p50_ms), (0.99, s.ttft_p99_ms)],
        s.ttft_sum_ms,
        s.ttft_count,
    );
    prom.summary(
        "tas_tpot_ms",
        "Time per output token (milliseconds, one sample per decode step).",
        labels,
        &[(0.5, s.tpot_p50_ms), (0.99, s.tpot_p99_ms)],
        s.tpot_sum_ms,
        s.tpot_count,
    );
}

/// Render an [`SloSnapshot`]'s goodput and burn-rate families.
pub fn render_slo(prom: &mut Prom, labels: &[(&str, &str)], s: &SloSnapshot) {
    prom.counter(
        "tas_slo_checked_total",
        "Latency samples checked against an SLO bound.",
        labels,
        s.checked as f64,
    );
    prom.counter(
        "tas_slo_good_total",
        "Checked samples that met their SLO bound.",
        labels,
        s.good as f64,
    );
    if let Some(g) = s.goodput {
        prom.gauge(
            "tas_slo_goodput",
            "Fraction of checked samples meeting their bound (whole run).",
            labels,
            g,
        );
    }
    let horizons = [
        ("last_window", s.burn.last_window),
        ("last_8_windows", s.burn.last_8_windows),
        ("overall", s.burn.overall),
    ];
    for (h, v) in horizons {
        if let Some(v) = v {
            let mut hl: Vec<(&str, &str)> = labels.to_vec();
            hl.push(("horizon", h));
            prom.gauge(
                "tas_slo_burn_rate",
                "Error-budget burn rate (1 at the sustainable pace).",
                &hl,
                v,
            );
        }
    }
}

/// One-call exposition for a single coordinator: metrics (no labels),
/// ready to write to `--metrics-out`.
pub fn metrics_exposition(s: &MetricsSnapshot) -> String {
    let mut p = Prom::new();
    render_metrics(&mut p, &[], s);
    p.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every non-comment line must be `name{labels} value` with a
    /// parseable value — the well-formedness CI's jq-less check mirrors.
    fn assert_well_formed(page: &str) {
        for line in page.lines() {
            if line.starts_with('#') {
                assert!(
                    line.starts_with("# HELP ") || line.starts_with("# TYPE "),
                    "bad comment: {line}"
                );
                continue;
            }
            let (_, value) = line.rsplit_once(' ').expect("sample needs a value");
            assert!(
                value == "+Inf" || value == "-Inf" || value.parse::<f64>().is_ok(),
                "bad value in: {line}"
            );
        }
    }

    #[test]
    fn empty_snapshot_renders_well_formed_families() {
        let page = metrics_exposition(&MetricsSnapshot::default());
        assert_well_formed(&page);
        assert!(page.contains("# TYPE tas_requests_total counter"));
        assert!(page.contains("tas_requests_total 0"));
        // empty quantiles are skipped; _sum/_count always present
        assert!(!page.contains("quantile"));
        assert!(page.contains("tas_ttft_ms_count 0"));
        assert!(!page.contains("NaN"));
    }

    #[test]
    fn plan_db_families_render_search_amortization() {
        let mut p = Prom::new();
        let s = MetricsSnapshot {
            plan_db: crate::dataflow::SearchStats {
                searches: 3,
                db_hits: 40,
                db_misses: 3,
                entries: 12,
                pruned: 7,
                ..Default::default()
            },
            ..Default::default()
        };
        render_metrics(&mut p, &[("replica", "0")], &s);
        let page = p.render();
        assert_well_formed(&page);
        assert!(page.contains("# TYPE tas_searches_total counter"));
        assert!(page.contains("tas_searches_total{replica=\"0\"} 3"));
        assert!(page.contains("tas_plan_db_hits_total{replica=\"0\"} 40"));
        assert!(page.contains("# TYPE tas_plan_db_entries gauge"));
        assert!(page.contains("tas_plan_db_entries{replica=\"0\"} 12"));
        assert!(page.contains("tas_search_pruned_total{replica=\"0\"} 7"));
    }

    #[test]
    fn family_headers_appear_once_across_replicas() {
        let mut p = Prom::new();
        let a = MetricsSnapshot { requests: 3, ..Default::default() };
        let b = MetricsSnapshot { requests: 5, ..Default::default() };
        render_metrics(&mut p, &[("replica", "0")], &a);
        render_metrics(&mut p, &[("replica", "1")], &b);
        let page = p.render();
        assert_well_formed(&page);
        assert_eq!(page.matches("# TYPE tas_requests_total counter").count(), 1);
        assert!(page.contains("tas_requests_total{replica=\"0\"} 3"));
        assert!(page.contains("tas_requests_total{replica=\"1\"} 5"));
    }

    #[test]
    fn summaries_carry_quantiles_sum_and_count() {
        let mut p = Prom::new();
        let s = MetricsSnapshot {
            ttft_p50_ms: Some(4.0),
            ttft_p99_ms: Some(9.5),
            ttft_count: 12,
            ttft_sum_ms: 60.0,
            ..Default::default()
        };
        render_metrics(&mut p, &[], &s);
        let page = p.render();
        assert_well_formed(&page);
        assert!(page.contains("tas_ttft_ms{quantile=\"0.5\"} 4"));
        assert!(page.contains("tas_ttft_ms{quantile=\"0.99\"} 9.5"));
        assert!(page.contains("tas_ttft_ms_sum 60"));
        assert!(page.contains("tas_ttft_ms_count 12"));
    }

    #[test]
    fn label_values_escape_the_format_specials() {
        let mut p = Prom::new();
        p.gauge("g", "h", &[("k", "a\"b\\c\nd")], 1.0);
        assert!(p.render().contains("g{k=\"a\\\"b\\\\c\\nd\"} 1"));
    }

    #[test]
    fn slo_families_render_goodput_and_horizon_burns() {
        use crate::obs::slo::{SloSpec, SloTracker};
        let t = SloTracker::new(SloSpec::default(), 100);
        t.observe_ttft_at(1_000, 5.0);
        t.observe_ttft_at(2_000, 500.0);
        let mut p = Prom::new();
        render_slo(&mut p, &[], &t.snapshot());
        let page = p.render();
        assert_well_formed(&page);
        assert!(page.contains("tas_slo_checked_total 2"));
        assert!(page.contains("tas_slo_good_total 1"));
        assert!(page.contains("tas_slo_goodput 0.5"));
        assert!(page.contains("tas_slo_burn_rate{horizon=\"overall\"}"));
        assert!(page.contains("tas_slo_burn_rate{horizon=\"last_window\"}"));
    }
}
