//! Fused single-pass cost replay over the schedule IR.
//!
//! The seed replayed every schedule four times — once each for EMA,
//! cycles, energy and the DRAM timing trace.  With the [`Plan`] IR the
//! step stream is walked **once** and every cost backend observes the same
//! steps through the [`CostSink`] trait:
//!
//! * [`EmaSink`] — per-stream DRAM word counts + direction switches (the
//!   Table II instrument), via the exact same charging rule the standalone
//!   [`super::ema::simulate_ema`] uses;
//! * [`TimingSink`] — transaction-level bank/row DRAM timing, sharing the
//!   per-step logic of [`super::dram_trace`];
//! * [`PipelineSink`] — step-level (DMA ‖ PE) stall attribution
//!   ([`super::pipeline`]);
//! * cycles and energy are closed forms over the EMA result, derived at
//!   [`FusedCost`] assembly (`cycles_from_replay`, `plan_energy`) — no
//!   second walk.
//!
//! The equivalence between this fused pass and the per-consumer replays is
//! a property test (`rust/tests/plan_equivalence.rs`): bit-identical EMA
//! and cycle totals for every scheme over a grid of shapes.

use crate::arch::dram_timing::{DramTiming, DramTimingConfig, DramTimingStats, MatrixLayout};
use crate::arch::Dram;
use crate::config::AcceleratorConfig;
use crate::dataflow::{Plan, Step};
use crate::energy::{EnergyCost, EnergyModel};
use crate::gemm::tile_extent;
use crate::sim::cycles::{cycles_from_replay, CycleEstimate};
use crate::sim::dram_trace::charge_timing_step;
use crate::sim::ema::{charge_step_scaled, SimEma};
use crate::sim::pipeline::{PipelineSink, PipelineStats};

/// One schedule step with its resolved tile extents, as seen by sinks.
pub struct StepCtx<'a> {
    pub plan: &'a Plan,
    pub step: Step,
    /// True extents of the (i, r, j) tile (ragged edges resolved).
    pub mi: u64,
    pub nr: u64,
    pub kj: u64,
}

/// A pluggable cost backend fed by the fused replay.
pub trait CostSink {
    fn on_step(&mut self, ctx: &StepCtx);
}

/// Drive every sink over the plan's step stream in one pass.
pub fn replay(plan: &Plan, sinks: &mut [&mut dyn CostSink]) {
    let (shape, tiling) = (plan.shape, plan.tiling);
    plan.for_each_step(|step| {
        let ctx = StepCtx {
            plan,
            step,
            mi: tile_extent(shape.m, tiling.tm, step.i),
            nr: tile_extent(shape.n, tiling.tn, step.r),
            kj: tile_extent(shape.k, tiling.tk, step.j),
        };
        for sink in sinks.iter_mut() {
            sink.on_step(&ctx);
        }
    });
}

/// EMA backend: flat DRAM word/switch counting.
pub struct EmaSink {
    dram: Dram,
    steps: u64,
    charge: [u64; 3],
}

impl EmaSink {
    pub fn new(dram: Dram) -> EmaSink {
        EmaSink::with_charge(dram, [1, 1, 1])
    }

    /// An EMA sink with a backend charge triple (see
    /// [`crate::arch::backend::BackendParams::charge`]).
    pub fn with_charge(dram: Dram, charge: [u64; 3]) -> EmaSink {
        EmaSink { dram, steps: 0, charge }
    }

    pub fn finish(self) -> SimEma {
        SimEma { stats: self.dram.stats(), steps: self.steps }
    }
}

impl CostSink for EmaSink {
    fn on_step(&mut self, ctx: &StepCtx) {
        self.steps += 1;
        charge_step_scaled(
            &mut self.dram,
            &ctx.step,
            ctx.mi,
            ctx.nr,
            ctx.kj,
            ctx.plan.input_residency,
            ctx.plan.weight_residency,
            ctx.plan.output_residency,
            self.charge,
        );
    }
}

/// Transaction-level DRAM timing backend.
pub struct TimingSink {
    dram: DramTiming,
    layout: MatrixLayout,
    charge: [u64; 3],
}

impl TimingSink {
    pub fn new(plan: &Plan, cfg: DramTimingConfig) -> TimingSink {
        TimingSink::with_charge(plan, cfg, [1, 1, 1])
    }

    /// A timing sink with a backend charge triple.  The address-walking
    /// machine has no notion of fractional words, so the charge acts as a
    /// 0/1 gate: a zero-charged operand issues no transactions at all
    /// (crossbar weights live in NVM, not behind this bus).
    pub fn with_charge(plan: &Plan, cfg: DramTimingConfig, charge: [u64; 3]) -> TimingSink {
        let layout = MatrixLayout::for_gemm(&plan.shape, &cfg);
        TimingSink { dram: DramTiming::new(cfg), layout, charge }
    }

    pub fn finish(self) -> DramTimingStats {
        self.dram.stats()
    }
}

impl CostSink for TimingSink {
    fn on_step(&mut self, ctx: &StepCtx) {
        let gate = |c: u64, r: crate::dataflow::Residency| {
            if c == 0 {
                crate::dataflow::Residency::Full
            } else {
                r
            }
        };
        charge_timing_step(
            &mut self.dram,
            &self.layout,
            &ctx.plan.tiling,
            &ctx.step,
            ctx.mi,
            ctx.nr,
            ctx.kj,
            gate(self.charge[0], ctx.plan.input_residency),
            gate(self.charge[1], ctx.plan.weight_residency),
            gate(self.charge[2], ctx.plan.output_residency),
        );
    }
}

/// Every cost model's verdict on one plan, from one walk of the schedule.
#[derive(Clone, Debug)]
pub struct FusedCost {
    pub ema: SimEma,
    pub cycles: CycleEstimate,
    pub energy: EnergyCost,
    pub timing: DramTimingStats,
    /// Step-level stall attribution ([`crate::sim::pipeline`]).
    pub pipeline: PipelineStats,
}

/// Replay `plan` once and report EMA, cycles, energy, DRAM timing and
/// step-level pipeline stalls.
pub fn fused_cost(
    plan: &Plan,
    cfg: &AcceleratorConfig,
    energy: &EnergyModel,
    timing_cfg: DramTimingConfig,
) -> FusedCost {
    let mut ema_sink = EmaSink::new(cfg.dram());
    let mut timing_sink = TimingSink::new(plan, timing_cfg);
    let mut pipeline_sink = PipelineSink::new(cfg);
    {
        let sinks: &mut [&mut dyn CostSink] =
            &mut [&mut ema_sink, &mut timing_sink, &mut pipeline_sink];
        replay(plan, sinks);
    }
    let ema = ema_sink.finish();
    let cycles = cycles_from_replay(&ema, &plan.shape, cfg);
    let (i, w, o) = ema.table2();
    let energy = energy.plan_energy(plan, i + w + o);
    FusedCost {
        ema,
        cycles,
        energy,
        timing: timing_sink.finish(),
        pipeline: pipeline_sink.finish(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnergyConfig;
    use crate::dataflow::Scheme;
    use crate::gemm::{GemmShape, Tiling};
    use crate::sim::cycles::estimate_cycles_tiled;
    use crate::sim::{simulate_dram_timing, simulate_ema};

    #[test]
    fn fused_pass_equals_separate_replays() {
        let shape = GemmShape::new(96, 128, 160);
        let tiling = Tiling::square(16);
        let cfg = AcceleratorConfig::default();
        let em = EnergyModel::new(EnergyConfig::default());
        for scheme in Scheme::FIXED.iter().chain([Scheme::Tas].iter()) {
            let plan = Plan::from_scheme(*scheme, &shape, &tiling);
            let fused = fused_cost(&plan, &cfg, &em, DramTimingConfig::default());

            let mut dram = cfg.dram();
            let sim = simulate_ema(*scheme, &shape, &tiling, &mut dram);
            assert_eq!(fused.ema, sim, "{scheme:?} ema");

            let cycles = estimate_cycles_tiled(*scheme, &shape, &tiling, &cfg);
            assert_eq!(fused.cycles, cycles, "{scheme:?} cycles");

            let timing =
                simulate_dram_timing(*scheme, &shape, &tiling, DramTimingConfig::default());
            assert_eq!(fused.timing, timing, "{scheme:?} timing");

            let energy = em.gemm_energy(*scheme, &shape, &tiling);
            assert!((fused.energy.total_pj() - energy.total_pj()).abs() < 1e-6);

            let pipeline =
                crate::sim::pipeline::simulate_pipeline(*scheme, &shape, &tiling, &cfg);
            assert_eq!(fused.pipeline, pipeline, "{scheme:?} pipeline");
        }
    }

    #[test]
    fn fused_pass_covers_per_tile_plans() {
        let shape = GemmShape::new(130, 70, 90);
        let tiling = Tiling::square(16).with_kp(32).with_mp(32);
        let plan = Plan::tas_per_tile(&shape, &tiling);
        let fused = fused_cost(
            &plan,
            &AcceleratorConfig::default(),
            &EnergyModel::default(),
            DramTimingConfig::default(),
        );
        let e = plan.ema();
        assert_eq!(fused.ema.table2(), (e.input, e.weight, e.output));
        assert!(fused.cycles.total_cycles > 0);
        assert!(fused.timing.words > 0);
    }
}
