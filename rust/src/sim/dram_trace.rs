//! Transaction-level DRAM replay: runs a schedule against the bank/row
//! timing model with real matrix addresses, quantifying §II-d's stall
//! argument — the spilling schemes don't just move more words, they
//! interleave directions and trash row-buffer locality.

use crate::arch::dram::DramDir;
use crate::arch::dram_timing::{DramTiming, DramTimingConfig, DramTimingStats, MatrixLayout};
use crate::dataflow::{Plan, Residency, Scheme, Step};
use crate::gemm::{tile_extent, GemmShape, Tiling};

/// Replay `scheme` at transaction granularity (one transaction per tile
/// row — the unit a DMA engine would issue) and return timing stats.
pub fn simulate_dram_timing(
    scheme: Scheme,
    shape: &GemmShape,
    tiling: &Tiling,
    cfg: DramTimingConfig,
) -> DramTimingStats {
    simulate_dram_timing_plan(&Plan::from_scheme(scheme, shape, tiling), cfg)
}

/// Transaction-level replay of any [`Plan`].
pub fn simulate_dram_timing_plan(plan: &Plan, cfg: DramTimingConfig) -> DramTimingStats {
    let layout = MatrixLayout::for_gemm(&plan.shape, &cfg);
    let mut dram = DramTiming::new(cfg);
    let (shape, tiling) = (plan.shape, plan.tiling);
    plan.for_each_step(|s| {
        let mi = tile_extent(shape.m, tiling.tm, s.i);
        let nr = tile_extent(shape.n, tiling.tn, s.r);
        let kj = tile_extent(shape.k, tiling.tk, s.j);
        charge_timing_step(
            &mut dram,
            &layout,
            &tiling,
            &s,
            mi,
            nr,
            kj,
            plan.input_residency,
            plan.weight_residency,
            plan.output_residency,
        );
    });
    dram.stats()
}

/// Issue one schedule step's DRAM transactions.  Shared by the standalone
/// timing replay above and the fused pass in [`crate::sim::replay`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn charge_timing_step(
    dram: &mut DramTiming,
    layout: &MatrixLayout,
    tiling: &Tiling,
    s: &Step,
    mi: u64,
    nr: u64,
    kj: u64,
    input: Residency,
    weight: Residency,
    output: Residency,
) {
    let input_resident = input.is_free();
    let weight_resident = weight.is_free();
    let output_resident = output.is_free();
    let (i0, r0, j0) = (s.i * tiling.tm, s.r * tiling.tn, s.j * tiling.tk);

    if s.scalar_traffic {
        // naive: stream each operand tile once per scalar pass — model
        // as kj repetitions of the input tile rows & mi of the weight.
        for rep in 0..kj.min(4) {
            // cap reps: timing shape, not words (words counted in ema)
            let _ = rep;
            for di in 0..mi {
                dram.access(DramDir::Read, layout.input_base + (i0 + di) * layout.input_ld + r0, nr);
            }
        }
        for di in 0..mi.min(4) {
            let _ = di;
            for dr in 0..nr {
                dram.access(DramDir::Read, layout.weight_base + (r0 + dr) * layout.weight_ld + j0, kj);
            }
        }
        for di in 0..mi {
            dram.access(DramDir::Write, layout.output_base + (i0 + di) * layout.output_ld + j0, kj);
        }
        return;
    }
    if s.load_input && !input_resident {
        for di in 0..mi {
            dram.access(
                DramDir::Read,
                layout.input_base + (i0 + di) * layout.input_ld + r0,
                nr,
            );
        }
    }
    if s.load_weight && !weight_resident {
        for dr in 0..nr {
            dram.access(
                DramDir::Read,
                layout.weight_base + (r0 + dr) * layout.weight_ld + j0,
                kj,
            );
        }
    }
    if s.psum_fetch {
        for di in 0..mi {
            dram.access(
                DramDir::Read,
                layout.output_base + (i0 + di) * layout.output_ld + j0,
                kj,
            );
        }
    }
    if s.psum_spill || (s.store_out && !output_resident) {
        for di in 0..mi {
            dram.access(
                DramDir::Write,
                layout.output_base + (i0 + di) * layout.output_ld + j0,
                kj,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(scheme: Scheme, shape: &GemmShape) -> DramTimingStats {
        simulate_dram_timing(scheme, shape, &Tiling::square(16), DramTimingConfig::default())
    }

    #[test]
    fn hybrids_switch_direction_less_and_run_faster() {
        let shape = GemmShape::new(256, 256, 512);
        let is = stats(Scheme::Is, &shape);
        let is_os = stats(Scheme::IsOs, &shape);
        assert!(is_os.dir_switches * 4 < is.dir_switches,
                "{} vs {}", is_os.dir_switches, is.dir_switches);
        assert!(is_os.cycles < is.cycles);
        let ws = stats(Scheme::Ws, &shape);
        let ws_os = stats(Scheme::WsOs, &shape);
        assert!(ws_os.cycles < ws.cycles);
    }

    #[test]
    fn hybrid_moves_fewer_words_in_fewer_cycles() {
        // The spilling scheme's psum round-trips keep the bus streaming
        // (high raw bandwidth!) — the win is *useful* traffic: the hybrid
        // transfers a fraction of the words and finishes earlier.
        let shape = GemmShape::new(512, 512, 512);
        let spill = stats(Scheme::Ws, &shape);
        let hybrid = stats(Scheme::WsOs, &shape);
        assert!(hybrid.words * 2 < spill.words);
        assert!(hybrid.cycles < spill.cycles);
        // sequential tile streams keep row locality reasonable
        assert!(hybrid.row_hit_rate() >= 0.4, "{}", hybrid.row_hit_rate());
    }

    #[test]
    fn word_counts_match_flat_model_for_tiled_schemes() {
        // the timing replay must move exactly the words the EMA model
        // counts (spilling and hybrid schemes; naive uses capped reps).
        use crate::arch::Dram;
        use crate::sim::simulate_ema;
        let shape = GemmShape::new(96, 128, 160);
        let tiling = Tiling::square(16);
        for scheme in [Scheme::Is, Scheme::Ws, Scheme::OsRow, Scheme::IsOs, Scheme::WsOs] {
            let timing = simulate_dram_timing(scheme, &shape, &tiling, DramTimingConfig::default());
            let mut d = Dram::new(16, 12);
            let ema = simulate_ema(scheme, &shape, &tiling, &mut d);
            let expected = ema.total_words() + ema.psum_readback_words();
            assert_eq!(timing.words, expected, "{scheme:?}");
        }
    }

    #[test]
    fn row_hit_rate_in_unit_range() {
        let shape = GemmShape::new(128, 128, 128);
        for scheme in [Scheme::Is, Scheme::IsOs, Scheme::OsRow] {
            let s = stats(scheme, &shape);
            let r = s.row_hit_rate();
            assert!((0.0..=1.0).contains(&r), "{scheme:?}: {r}");
        }
    }
}
