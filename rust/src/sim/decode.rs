//! Trajectory-level fused cost: price a whole decode trajectory (prefill
//! plus every autoregressive step) through the [`CostSink`] machinery in
//! one pass.
//!
//! The [`crate::dataflow::DecodePlan`] is a sequence of stage plans whose
//! instances repeat `count` times with identical step streams, so the
//! replay walks each distinct [`Plan`] once through an [`EmaSink`] and a
//! [`PipelineSink`] and scales the observed statistics by the instance
//! count — words, MACs, steps and switches are all exactly linear in the
//! count, and the cycle/energy closed forms derive from those totals the
//! same way [`super::replay::fused_cost`] derives them for one GEMM.
//! Every EMA word is therefore *replayed*, never assumed: the equality
//! between this pass and the planner's closed forms is pinned by
//! `rust/tests/decode_invariants.rs`.

use crate::arch::dram::DramStats;
use crate::config::AcceleratorConfig;
use crate::dataflow::{DecodePlan, Plan};
use crate::energy::{EnergyCost, EnergyModel};
use crate::sim::cycles::{cycles_from_parts, CycleEstimate};
use crate::sim::ema::SimEma;
use crate::sim::pipeline::{PipelineSink, PipelineStats};
use crate::sim::replay::{replay, CostSink, EmaSink};

/// Every cost model's verdict on one decode trajectory.
#[derive(Clone, Debug)]
pub struct TrajectoryCost {
    /// Trajectory-wide DRAM accounting (prefill + decode).
    pub ema: SimEma,
    /// Total MACs executed.
    pub macs: u64,
    pub cycles: CycleEstimate,
    pub energy: EnergyCost,
    pub pipeline: PipelineStats,
    /// Replayed DRAM words of the prefill phase.
    pub prefill_ema_words: u64,
    /// Replayed DRAM words per decode step (length = `steps`).
    pub per_step_ema: Vec<u64>,
}

impl TrajectoryCost {
    /// Replayed decode-phase DRAM words (sum over steps).
    pub fn decode_ema_words(&self) -> u64 {
        self.per_step_ema.iter().sum()
    }

    /// Replayed trajectory total.
    pub fn dram_words(&self) -> u64 {
        let (i, w, o) = self.ema.table2();
        i + w + o
    }
}

#[derive(Default)]
struct Acc {
    stats: DramStats,
    steps: u64,
    macs: u64,
    pipeline: PipelineStats,
}

impl Acc {
    /// Replay `plan` once, scale everything by `count`, and return the
    /// table2 words this plan group contributed.
    fn add(&mut self, plan: &Plan, count: u64, cfg: &AcceleratorConfig) -> u64 {
        let mut ema = EmaSink::new(cfg.dram());
        let mut pipe = PipelineSink::new(cfg);
        {
            let sinks: &mut [&mut dyn CostSink] = &mut [&mut ema, &mut pipe];
            replay(plan, sinks);
        }
        let sim = ema.finish();
        self.stats.input_read_words += count * sim.stats.input_read_words;
        self.stats.weight_read_words += count * sim.stats.weight_read_words;
        self.stats.psum_read_words += count * sim.stats.psum_read_words;
        self.stats.psum_write_words += count * sim.stats.psum_write_words;
        self.stats.output_write_words += count * sim.stats.output_write_words;
        self.stats.direction_switches += count * sim.stats.direction_switches;
        self.steps += count * sim.steps;
        self.macs += count * plan.shape.macs();
        let p = pipe.finish();
        self.pipeline.steps += count * p.steps;
        self.pipeline.compute_cycles += count * p.compute_cycles;
        self.pipeline.stall_cycles += count * p.stall_cycles;
        self.pipeline.stalled_steps += count * p.stalled_steps;
        self.pipeline.total_cycles += count * p.total_cycles;
        let (i, w, o) = sim.table2();
        count * (i + w + o)
    }
}

/// Replay a whole decode trajectory once and report EMA, cycles, energy
/// and pipeline stalls, plus the per-step decode EMA profile.
pub fn trajectory_fused_cost(
    dp: &DecodePlan,
    cfg: &AcceleratorConfig,
    energy: &EnergyModel,
) -> TrajectoryCost {
    let mut acc = Acc::default();
    let mut prefill_ema_words = 0u64;
    for stage in &dp.prefill.stages {
        // A layer stage's hot/cold row slices each run once per instance.
        for slice in &stage.slices {
            prefill_ema_words += acc.add(slice, stage.spec.count, cfg);
        }
    }
    let mut per_step_ema = Vec::with_capacity(dp.step_plans.len());
    for step in &dp.step_plans {
        let mut step_words = 0u64;
        for stage in &step.stages {
            // Decode slices carry their own instance counts (layer groups
            // with different residency allocations split the stage).
            for slice in &stage.slices {
                step_words += acc.add(&slice.plan, slice.count, cfg);
            }
        }
        per_step_ema.push(step_words);
    }
    let ema = SimEma { stats: acc.stats, steps: acc.steps };
    let cycles = cycles_from_parts(acc.macs, &ema, cfg);
    let (i, w, o) = ema.table2();
    let energy = energy.traffic_energy(acc.macs, i + w + o);
    TrajectoryCost {
        ema,
        macs: acc.macs,
        cycles,
        energy,
        pipeline: acc.pipeline,
        prefill_ema_words,
        per_step_ema,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{DecodeDims, ResidencyPolicy};
    use crate::gemm::Tiling;
    use crate::models::zoo;

    #[test]
    fn trajectory_replay_matches_planner_closed_forms() {
        let dims = DecodeDims::of(&zoo::bert_base());
        let cfg = AcceleratorConfig::default();
        let em = EnergyModel::default();
        for policy in [
            ResidencyPolicy::Paged,
            ResidencyPolicy::AllOrNothing,
            ResidencyPolicy::Off,
        ] {
            let dp = DecodePlan::plan_with_policy(
                &dims,
                16,
                3,
                2,
                &Tiling::square(16),
                256 * 1024,
                policy,
            );
            let tc = trajectory_fused_cost(&dp, &cfg, &em);
            assert_eq!(tc.prefill_ema_words, dp.prefill.total_ema());
            assert_eq!(tc.per_step_ema.len(), dp.step_plans.len());
            for (replayed, planned) in tc.per_step_ema.iter().zip(&dp.step_plans) {
                assert_eq!(*replayed, planned.total_ema(), "policy={policy:?}");
            }
            assert_eq!(tc.dram_words(), dp.total_ema());
            assert_eq!(tc.decode_ema_words(), dp.decode_ema());
            assert!(tc.macs > 0);
            assert!(tc.cycles.total_cycles > 0);
            assert!(tc.energy.total_pj() > 0.0);
            assert!(tc.pipeline.total_cycles > 0);
        }
    }

    #[test]
    fn cache_residency_cuts_replayed_traffic_too() {
        let dims = DecodeDims::of(&zoo::bert_base());
        let cfg = AcceleratorConfig::default();
        let em = EnergyModel::default();
        let t = Tiling::square(16);
        let on = DecodePlan::plan_with_policy(
            &dims,
            32,
            4,
            1,
            &t,
            256 * 1024,
            ResidencyPolicy::Paged,
        );
        let off =
            DecodePlan::plan_with_policy(&dims, 32, 4, 1, &t, 256 * 1024, ResidencyPolicy::Off);
        let c_on = trajectory_fused_cost(&on, &cfg, &em);
        let c_off = trajectory_fused_cost(&off, &cfg, &em);
        assert!(c_on.decode_ema_words() < c_off.decode_ema_words());
        assert!(c_on.energy.total_pj() < c_off.energy.total_pj());
        // compute is identical — only data movement changed
        assert_eq!(c_on.macs, c_off.macs);
    }
}
