//! Trajectory-level fused cost: price a whole decode trajectory (prefill
//! plus every autoregressive step) through the [`CostSink`] machinery in
//! one pass.
//!
//! The [`crate::dataflow::DecodePlan`] is a sequence of stage plans whose
//! instances repeat `count` times with identical step streams, so the
//! pass prices each distinct [`Plan`] once through the closed-form strip
//! walker ([`crate::sim::strip::plan_ema_pipeline`], replay-equal by the
//! strip property suite; fixed bodies still replay) and scales the
//! observed statistics by the instance count — words, MACs, steps,
//! switches and pipeline fills are all exactly linear in the count (one
//! fill per plan segment instance — the convention documented in
//! [`crate::sim::pipeline`] and asserted here), and the cycle/energy
//! closed forms derive from those totals the same way
//! [`super::replay::fused_cost`] derives them for one GEMM.  The
//! equality between this pass and the planner's closed forms is pinned
//! by `rust/tests/decode_invariants.rs`.
//!
//! **Link overlap.**  A head-sharded decode
//! ([`crate::dataflow::ShardedDecodePlan`]) all-reduces every layer's
//! attention/FFN partials and gathers the logits each step.  The old
//! model charged that as a barrier after every token
//! (`steps × link_cycles_per_step` on top of compute); here the step's
//! round list ([`ShardedDecodePlan::link_rounds_per_step`]) drains
//! behind the same step's compute window ([`LinkSchedule`]), so
//! [`ShardedTrajectoryCost`] reports both the serialized and the
//! overlapped trajectory latency, with
//! `max(compute, link) ≤ overlapped ≤ serialized` by construction
//! (property-tested in `rust/tests/overlap_invariants.rs`).  Per-step
//! hiding windows use *floored* MAC cycles, so the sum of windows never
//! exceeds the trajectory's compute total and the bound stays exact.

use crate::arch::dram::DramStats;
use crate::arch::Interconnect;
use crate::config::AcceleratorConfig;
use crate::dataflow::{DecodePlan, Plan, ShardedDecodePlan};
use crate::energy::{EnergyCost, EnergyModel};
use crate::sim::cycles::{cycles_from_parts, CycleEstimate};
use crate::sim::ema::SimEma;
use crate::sim::pipeline::{LinkSchedule, PipelineStats};

/// Every cost model's verdict on one decode trajectory.
#[derive(Clone, Debug)]
pub struct TrajectoryCost {
    /// Trajectory-wide DRAM accounting (prefill + decode).
    pub ema: SimEma,
    /// Total MACs executed.
    pub macs: u64,
    pub cycles: CycleEstimate,
    pub energy: EnergyCost,
    pub pipeline: PipelineStats,
    /// Replayed DRAM words of the prefill phase.
    pub prefill_ema_words: u64,
    /// Replayed DRAM words per decode step (length = `steps`).
    pub per_step_ema: Vec<u64>,
    /// Serialized link time over the trajectory (every per-step round
    /// list end to end; 0 for an unsharded trajectory).
    pub link_cycles: u64,
    /// Link cycles hidden behind the owning step's compute window.
    pub link_hidden_cycles: u64,
}

impl TrajectoryCost {
    /// Replayed decode-phase DRAM words (sum over steps).
    pub fn decode_ema_words(&self) -> u64 {
        self.per_step_ema.iter().sum()
    }

    /// Replayed trajectory total.
    pub fn dram_words(&self) -> u64 {
        let (i, w, o) = self.ema.table2();
        i + w + o
    }

    /// Pre-overlap latency: trajectory busy time plus a link barrier
    /// after every step.
    pub fn serialized_cycles(&self) -> u64 {
        self.cycles.total_cycles + self.link_cycles
    }

    /// Latency with each step's link rounds drained behind its compute.
    pub fn overlapped_cycles(&self) -> u64 {
        self.cycles.total_cycles + (self.link_cycles - self.link_hidden_cycles)
    }
}

#[derive(Default)]
struct Acc {
    stats: DramStats,
    steps: u64,
    macs: u64,
    pipeline: PipelineStats,
}

impl Acc {
    /// Price `plan` once (closed-form strip walk; fixed bodies replay),
    /// scale everything by `count`, and return the table2 words this plan
    /// group contributed.
    fn add(&mut self, plan: &Plan, count: u64, cfg: &AcceleratorConfig) -> u64 {
        let (sim, p) = crate::sim::strip::plan_ema_pipeline(plan, cfg);
        self.stats.input_read_words += count * sim.stats.input_read_words;
        self.stats.weight_read_words += count * sim.stats.weight_read_words;
        self.stats.psum_read_words += count * sim.stats.psum_read_words;
        self.stats.psum_write_words += count * sim.stats.psum_write_words;
        self.stats.output_write_words += count * sim.stats.output_write_words;
        self.stats.direction_switches += count * sim.stats.direction_switches;
        self.steps += count * sim.steps;
        self.macs += count * plan.shape.macs();
        // One pipeline fill per plan segment instance (count fills): the
        // documented convention — total stays fills·fill + compute + stall.
        debug_assert_eq!(p.fills, 1);
        self.pipeline.steps += count * p.steps;
        self.pipeline.compute_cycles += count * p.compute_cycles;
        self.pipeline.stall_cycles += count * p.stall_cycles;
        self.pipeline.stalled_steps += count * p.stalled_steps;
        self.pipeline.fills += count * p.fills;
        self.pipeline.total_cycles += count * p.total_cycles;
        let (i, w, o) = sim.table2();
        count * (i + w + o)
    }
}

/// Replay a whole decode trajectory once and report EMA, cycles, energy
/// and pipeline stalls, plus the per-step decode EMA profile.
pub fn trajectory_fused_cost(
    dp: &DecodePlan,
    cfg: &AcceleratorConfig,
    energy: &EnergyModel,
) -> TrajectoryCost {
    trajectory_cost_with_links(dp, cfg, energy, &[])
}

/// Same pass, with each decode step carrying `step_rounds` of inter-chip
/// link time (one round list, repeated per step) drained behind the
/// step's own compute window.  An empty round list reproduces
/// [`trajectory_fused_cost`] exactly.
pub fn trajectory_cost_with_links(
    dp: &DecodePlan,
    cfg: &AcceleratorConfig,
    energy: &EnergyModel,
    step_rounds: &[u64],
) -> TrajectoryCost {
    let pe = cfg.pe_array();
    let mpc = pe.macs_per_cycle();
    let mut acc = Acc::default();
    let mut prefill_ema_words = 0u64;
    for stage in &dp.prefill.stages {
        // A layer stage's hot/cold row slices each run once per instance.
        for slice in &stage.slices {
            prefill_ema_words += acc.add(slice, stage.spec.count, cfg);
        }
    }
    let mut per_step_ema = Vec::with_capacity(dp.step_plans.len());
    let mut link_cycles = 0u64;
    let mut link_hidden_cycles = 0u64;
    for step in &dp.step_plans {
        let mut step_words = 0u64;
        // The step's compute window the link rounds hide behind: floored
        // MAC cycles plus per-pass fill, summed over the step's slices —
        // never more than the trajectory compute total.
        let mut window = 0u64;
        for stage in &step.stages {
            // Decode slices carry their own instance counts (layer groups
            // with different residency allocations split the stage).
            for slice in &stage.slices {
                step_words += acc.add(&slice.plan, slice.count, cfg);
                window += slice.count
                    * (slice.plan.shape.macs() / mpc
                        + pe.fill_latency * slice.plan.step_count());
            }
        }
        if !step_rounds.is_empty() {
            let mut sched = LinkSchedule::new(step_rounds.to_vec());
            sched.drain(window);
            link_cycles += sched.total_cycles();
            link_hidden_cycles += sched.hidden_cycles();
        }
        per_step_ema.push(step_words);
    }
    let ema = SimEma { stats: acc.stats, steps: acc.steps };
    let cycles = cycles_from_parts(acc.macs, &ema, cfg);
    let (i, w, o) = ema.table2();
    let energy = energy.traffic_energy(acc.macs, i + w + o);
    TrajectoryCost {
        ema,
        macs: acc.macs,
        cycles,
        energy,
        pipeline: acc.pipeline,
        prefill_ema_words,
        per_step_ema,
        link_cycles,
        link_hidden_cycles,
    }
}

/// A head-sharded decode trajectory, fully costed: one replayed
/// [`TrajectoryCost`] per device, each draining the per-step collective
/// rounds behind its own compute, plus the serialized-vs-overlapped
/// whole-trajectory latency.
#[derive(Clone, Debug)]
pub struct ShardedTrajectoryCost {
    pub per_device: Vec<TrajectoryCost>,
    /// Serialized link time of one decode step (sum of the round list).
    pub link_cycles_per_step: u64,
    /// Busiest device's trajectory busy time (no link time).
    pub max_device_cycles: u64,
    /// Pre-overlap model: busiest device + a barrier after every step.
    pub serialized_cycles: u64,
    /// Each device pays its busy time plus the link time its own step
    /// windows could not hide; the trajectory waits for the worst.
    pub overlapped_cycles: u64,
}

impl ShardedTrajectoryCost {
    /// Link cycles hidden behind compute — the overlap win.
    pub fn hidden_link_cycles(&self) -> u64 {
        self.serialized_cycles - self.overlapped_cycles
    }
}

/// Replay every device's trajectory with the per-step all-reduce rounds
/// overlapped against that device's compute windows.
pub fn sharded_trajectory_cost(
    sp: &ShardedDecodePlan,
    cfg: &AcceleratorConfig,
    energy: &EnergyModel,
    icx: &Interconnect,
) -> ShardedTrajectoryCost {
    let rounds = sp.link_rounds_per_step(icx);
    let link_cycles_per_step: u64 = rounds.iter().sum();
    let per_device: Vec<TrajectoryCost> = sp
        .per_device
        .iter()
        .map(|dp| trajectory_cost_with_links(dp, cfg, energy, &rounds))
        .collect();
    let max_device_cycles = per_device
        .iter()
        .map(|t| t.cycles.total_cycles)
        .max()
        .unwrap_or(0);
    let link_total = sp.steps * link_cycles_per_step;
    let serialized_cycles = max_device_cycles + link_total;
    let overlapped_cycles = per_device
        .iter()
        .map(|t| t.overlapped_cycles())
        .max()
        .unwrap_or(link_total);
    ShardedTrajectoryCost {
        per_device,
        link_cycles_per_step,
        max_device_cycles,
        serialized_cycles,
        overlapped_cycles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{DecodeDims, ResidencyPolicy};
    use crate::gemm::Tiling;
    use crate::models::zoo;

    #[test]
    fn trajectory_replay_matches_planner_closed_forms() {
        let dims = DecodeDims::of(&zoo::bert_base());
        let cfg = AcceleratorConfig::default();
        let em = EnergyModel::default();
        for policy in [
            ResidencyPolicy::Paged,
            ResidencyPolicy::AllOrNothing,
            ResidencyPolicy::Off,
        ] {
            let dp = DecodePlan::plan_with_policy(
                &dims,
                16,
                3,
                2,
                &Tiling::square(16),
                256 * 1024,
                policy,
            );
            let tc = trajectory_fused_cost(&dp, &cfg, &em);
            assert_eq!(tc.prefill_ema_words, dp.prefill.total_ema());
            assert_eq!(tc.per_step_ema.len(), dp.step_plans.len());
            for (replayed, planned) in tc.per_step_ema.iter().zip(&dp.step_plans) {
                assert_eq!(*replayed, planned.total_ema(), "policy={policy:?}");
            }
            assert_eq!(tc.dram_words(), dp.total_ema());
            assert_eq!(tc.decode_ema_words(), dp.decode_ema());
            assert!(tc.macs > 0);
            assert!(tc.cycles.total_cycles > 0);
            assert!(tc.energy.total_pj() > 0.0);
            assert!(tc.pipeline.total_cycles > 0);
            // one fill per replayed plan segment instance
            assert_eq!(
                tc.pipeline.total_cycles,
                tc.pipeline.fills * cfg.pe_array().fill_latency
                    + tc.pipeline.compute_cycles
                    + tc.pipeline.stall_cycles
            );
            // no links: serialized == overlapped == busy
            assert_eq!(tc.link_cycles, 0);
            assert_eq!(tc.serialized_cycles(), tc.cycles.total_cycles);
            assert_eq!(tc.overlapped_cycles(), tc.cycles.total_cycles);
        }
    }

    #[test]
    fn cache_residency_cuts_replayed_traffic_too() {
        let dims = DecodeDims::of(&zoo::bert_base());
        let cfg = AcceleratorConfig::default();
        let em = EnergyModel::default();
        let t = Tiling::square(16);
        let on = DecodePlan::plan_with_policy(
            &dims,
            32,
            4,
            1,
            &t,
            256 * 1024,
            ResidencyPolicy::Paged,
        );
        let off =
            DecodePlan::plan_with_policy(&dims, 32, 4, 1, &t, 256 * 1024, ResidencyPolicy::Off);
        let c_on = trajectory_fused_cost(&on, &cfg, &em);
        let c_off = trajectory_fused_cost(&off, &cfg, &em);
        assert!(c_on.decode_ema_words() < c_off.decode_ema_words());
        assert!(c_on.energy.total_pj() < c_off.energy.total_pj());
        // compute is identical — only data movement changed
        assert_eq!(c_on.macs, c_off.macs);
    }

    #[test]
    fn sharded_trajectory_overlap_obeys_the_bounds() {
        let dims = DecodeDims::of(&zoo::bert_base());
        let cfg = AcceleratorConfig::default();
        let em = EnergyModel::default();
        let icx = Interconnect::default();
        let t = Tiling::square(16);
        let sp = ShardedDecodePlan::plan(&dims, 64, 4, 8, &t, 256 * 1024, 4).unwrap();
        let c = sharded_trajectory_cost(&sp, &cfg, &em, &icx);
        assert_eq!(c.per_device.len(), 4);
        assert_eq!(
            c.link_cycles_per_step,
            sp.link_cycles_per_step(&icx),
            "round list sums to the closed form"
        );
        let link_total = sp.steps * c.link_cycles_per_step;
        assert!(c.link_cycles_per_step > 0);
        assert!(c.overlapped_cycles >= c.max_device_cycles.max(link_total));
        assert!(c.overlapped_cycles <= c.serialized_cycles);
        assert_eq!(c.serialized_cycles, c.max_device_cycles + link_total);
        for tc in &c.per_device {
            assert_eq!(tc.link_cycles, link_total);
            assert!(tc.link_hidden_cycles <= tc.link_cycles);
        }
    }

    #[test]
    fn one_device_sharded_trajectory_has_no_link_time() {
        let dims = DecodeDims::of(&zoo::bert_base());
        let cfg = AcceleratorConfig::default();
        let em = EnergyModel::default();
        let icx = Interconnect::default();
        let sp =
            ShardedDecodePlan::plan(&dims, 32, 2, 4, &Tiling::square(16), 256 * 1024, 1).unwrap();
        let c = sharded_trajectory_cost(&sp, &cfg, &em, &icx);
        assert_eq!(c.link_cycles_per_step, 0);
        assert_eq!(c.overlapped_cycles, c.serialized_cycles);
        assert_eq!(c.overlapped_cycles, c.max_device_cycles);
    }
}
