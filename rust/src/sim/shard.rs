//! Per-device cost replay for sharded plans.
//!
//! One walk of the sharded step stream drives a [`CostSink`] per device
//! (the same sink machinery as [`super::replay`]), so every device gets
//! the full EMA → cycles → energy derivation over exactly the steps it
//! executes; inter-chip traffic comes from the partition's closed form
//! ([`ShardedPlan::link_traffic`]) and is costed by the
//! [`Interconnect`] primitives.
//!
//! Invariants (property-tested in `rust/tests/shard_conservation.rs`):
//! summed per-device EMA equals the plan's EMA word-for-word, and link
//! traffic is additive on top — a sharded plan never undercuts its
//! unsharded cost.

use crate::arch::Interconnect;
use crate::config::AcceleratorConfig;
use crate::dataflow::shard::{LinkTraffic, ShardAxis, ShardedPlan};
use crate::energy::{EnergyCost, EnergyModel};
use crate::gemm::tile_extent;
use crate::sim::cycles::{cycles_from_parts, CycleEstimate};
use crate::sim::ema::SimEma;
use crate::sim::replay::{CostSink, EmaSink, StepCtx};

/// One device's share of a sharded plan, fully costed.
#[derive(Clone, Debug)]
pub struct DeviceCost {
    pub device: usize,
    /// DRAM words this device's steps consume (compute EMA).
    pub ema: SimEma,
    /// MACs this device executes.
    pub macs: u64,
    pub cycles: CycleEstimate,
    pub energy: EnergyCost,
    /// Words this device receives over links.
    pub link_in_words: u64,
    /// Words this device sends over links.
    pub link_out_words: u64,
}

/// Cost report of one sharded GEMM.
#[derive(Clone, Debug)]
pub struct ShardCost {
    pub per_device: Vec<DeviceCost>,
    pub link: LinkTraffic,
    /// Serialized link time: operand point-to-point + psum reduce.
    pub link_cycles: u64,
    pub link_energy_pj: f64,
}

impl ShardCost {
    /// Total DRAM words across devices (== the plan's EMA total).
    pub fn dram_words(&self) -> u64 {
        self.per_device.iter().map(|d| d.ema.total_words()).sum()
    }

    pub fn link_words(&self) -> u64 {
        self.link.total()
    }

    /// Slowest device's cycle estimate — the shard's critical path before
    /// link serialization.
    pub fn max_device_cycles(&self) -> u64 {
        self.per_device
            .iter()
            .map(|d| d.cycles.total_cycles)
            .max()
            .unwrap_or(0)
    }

    /// Whole-shard latency: slowest device plus serialized link time.
    pub fn total_cycles(&self) -> u64 {
        self.max_device_cycles() + self.link_cycles
    }

    /// Total energy: per-device DRAM/SRAM/MAC plus link transfer energy.
    pub fn total_energy_pj(&self) -> f64 {
        self.per_device.iter().map(|d| d.energy.total_pj()).sum::<f64>()
            + self.link_energy_pj
    }
}

/// Replay a sharded plan once, dispatching each step to its device's
/// [`EmaSink`], and assemble the per-device and link cost report.
pub fn sharded_fused_cost(
    sp: &ShardedPlan,
    cfg: &AcceleratorConfig,
    energy: &EnergyModel,
    icx: &Interconnect,
) -> ShardCost {
    let d = sp.devices as usize;
    let mut sinks: Vec<EmaSink> = (0..d).map(|_| EmaSink::new(cfg.dram())).collect();
    let mut macs = vec![0u64; d];
    let (shape, tiling) = (sp.plan.shape, sp.plan.tiling);
    sp.for_each_step_device(|dev, step| {
        let ctx = StepCtx {
            plan: &sp.plan,
            step,
            mi: tile_extent(shape.m, tiling.tm, step.i),
            nr: tile_extent(shape.n, tiling.tn, step.r),
            kj: tile_extent(shape.k, tiling.tk, step.j),
        };
        macs[dev] += ctx.mi * ctx.nr * ctx.kj;
        sinks[dev].on_step(&ctx);
    });

    let link = sp.link_traffic();
    let mut link_cycles = 0u64;
    if link.operand_words > 0 {
        // Ring all-gather: every device forwards its share over its own
        // link each round, instead of one serialized p2p of the total.
        let share = link.operand_words.div_ceil(sp.devices);
        link_cycles += icx.all_gather_cycles(share, sp.devices);
    }
    if link.reduce_words > 0 {
        // Collective tree reduce of the full-output psum payload: the
        // pairwise rounds run on disjoint links, so reduce time scales
        // with ceil(log2 D) payloads, not with the (D-1) copies the
        // serialized point-to-point chain streamed (ROADMAP item).
        let payload = sp.plan.shape.output_words();
        let active = link.reduce_words / payload + 1;
        link_cycles += icx.tree_reduce_cycles(payload, active);
    }
    let link_energy_pj = icx.transfer_energy_pj(link.total());

    let per_device = sinks
        .into_iter()
        .enumerate()
        .map(|(dev, sink)| {
            let ema = sink.finish();
            let cycles = cycles_from_parts(macs[dev], &ema, cfg);
            let (i, w, o) = ema.table2();
            DeviceCost {
                device: dev,
                cycles,
                energy: energy.traffic_energy(macs[dev], i + w + o),
                macs: macs[dev],
                link_in_words: link.per_device_in[dev],
                link_out_words: link.per_device_out[dev],
                ema,
            }
        })
        .collect();

    ShardCost { per_device, link, link_cycles, link_energy_pj }
}

/// Convenience: is the partition a psum-reducing contraction split?
pub fn is_reduce_shard(sp: &ShardedPlan) -> bool {
    sp.axis == ShardAxis::Contraction
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::shard::{shard_gemm, ShardSpec};
    use crate::dataflow::Plan;
    use crate::gemm::{GemmShape, Tiling};

    fn cost(shape: GemmShape, devices: u64, axis: ShardAxis) -> (ShardedPlan, ShardCost) {
        let tiling = Tiling::square(16);
        let sp = shard_gemm(&shape, &tiling, ShardSpec::new(devices, axis), 0.0);
        let cfg = AcceleratorConfig::default();
        let c = sharded_fused_cost(&sp, &cfg, &EnergyModel::default(), &Interconnect::default());
        (sp, c)
    }

    #[test]
    fn replayed_device_emas_match_closed_form() {
        for axis in [ShardAxis::Rows, ShardAxis::Cols, ShardAxis::Contraction] {
            let (sp, c) = cost(GemmShape::new(130, 70, 90), 3, axis);
            let closed = sp.device_emas();
            assert_eq!(c.per_device.len(), closed.len());
            for (dc, e) in c.per_device.iter().zip(&closed) {
                assert_eq!(
                    dc.ema.table2(),
                    (e.input, e.weight, e.output),
                    "device {} {axis:?}",
                    dc.device
                );
            }
        }
    }

    #[test]
    fn device_macs_sum_to_the_gemm() {
        let shape = GemmShape::new(120, 96, 88);
        for axis in [ShardAxis::Rows, ShardAxis::Cols, ShardAxis::Contraction] {
            let (_, c) = cost(shape, 4, axis);
            let total: u64 = c.per_device.iter().map(|d| d.macs).sum();
            assert_eq!(total, shape.macs(), "{axis:?}");
        }
    }

    #[test]
    fn one_device_matches_the_unsharded_fused_pass() {
        use crate::arch::dram_timing::DramTimingConfig;
        use crate::sim::replay::fused_cost;
        let shape = GemmShape::new(96, 128, 160);
        let tiling = Tiling::square(16);
        let cfg = AcceleratorConfig::default();
        let (_, c) = cost(shape, 1, ShardAxis::Auto);
        let plan = Plan::tas_per_tile(&shape, &tiling);
        let fused = fused_cost(&plan, &cfg, &EnergyModel::default(), DramTimingConfig::default());
        assert_eq!(c.per_device.len(), 1);
        assert_eq!(c.per_device[0].ema, fused.ema);
        assert_eq!(c.per_device[0].cycles, fused.cycles);
        assert_eq!(c.link_words(), 0);
        assert_eq!(c.link_cycles, 0);
    }

    #[test]
    fn sharding_splits_the_critical_path() {
        // 4-way row shard of an IS-friendly GEMM: the slowest device does
        // about a quarter of the work.
        let shape = GemmShape::new(256, 768, 768);
        let (_, c1) = cost(shape, 1, ShardAxis::Rows);
        let (_, c4) = cost(shape, 4, ShardAxis::Rows);
        assert!(c4.max_device_cycles() < c1.max_device_cycles());
        // but link time + conserved EMA mean total work never shrinks
        assert_eq!(c4.dram_words(), c1.dram_words());
        assert!(c4.total_energy_pj() > c1.total_energy_pj());
    }

    #[test]
    fn reduce_shard_reports_link_cycles() {
        let shape = GemmShape::new(128, 512, 128);
        let (sp, c) = cost(shape, 4, ShardAxis::Contraction);
        assert!(is_reduce_shard(&sp));
        assert!(c.link.reduce_words > 0);
        assert!(c.link_cycles > 0);
        assert!(c.link_energy_pj > 0.0);
    }

    #[test]
    fn collective_reduce_beats_serialized_chain_at_scale() {
        // The psum reduce rides the tree primitive: at 4+ devices its
        // serialized time must undercut streaming every (D-1) psum copy
        // through one link, which is what the old point-to-point model
        // charged.
        let shape = GemmShape::new(512, 1024, 512);
        let icx = Interconnect::default();
        for devices in [4u64, 8] {
            let (_, c) = cost(shape, devices, ShardAxis::Contraction);
            let serialized = icx.p2p_cycles(c.link.reduce_words);
            assert!(
                c.link_cycles < serialized,
                "d={devices}: {} >= {serialized}",
                c.link_cycles
            );
        }
    }

    #[test]
    fn operand_traffic_rides_the_all_gather_ring() {
        // Rows shard of an IS GEMM: every device gathers the remote
        // weight columns; (D-1) rounds of one per-device share each.
        let shape = GemmShape::new(64, 768, 768);
        let icx = Interconnect::default();
        let d = 4u64;
        let (_, c) = cost(shape, d, ShardAxis::Rows);
        assert!(c.link.operand_words > 0);
        let share = c.link.operand_words.div_ceil(d);
        assert_eq!(c.link_cycles, icx.all_gather_cycles(share, d));
        assert!(c.link_cycles < icx.p2p_cycles(c.link.operand_words));
    }
}
