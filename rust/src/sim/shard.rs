//! Per-device cost model for sharded plans — closed-form by default,
//! replay-backed as the oracle.
//!
//! Every device of a [`ShardedPlan`] executes a contiguous slice of the
//! strip cover (whole strips on the Rows/Cols axes, a contraction round
//! range of every strip on the Contraction axis —
//! [`ShardedPlan::for_each_strip_range`]), so one compressed-run walker
//! ([`crate::sim::strip::StripWalker`]) per device folds exactly the
//! steps that device executes in O(strips) — the same EMA → cycles →
//! energy → pipeline derivation the step replay produces, word-for-word
//! and cycle-for-cycle.  [`sharded_replayed_cost`] drives the original
//! per-device [`CostSink`]s step by step and is retained as the
//! property-test oracle ([`sharded_fused_cost`] equals it exactly;
//! pinned below and in `rust/tests/strip_closed_form.rs`).  Inter-chip
//! traffic comes from the partition's closed form
//! ([`ShardedPlan::link_traffic`]) and is costed by the [`Interconnect`]
//! primitives either way.
//!
//! **Latency** is a first-class output: the collective transfers (ring
//! all-gather of remote operands, tree reduce of contraction psums) are
//! a round list ([`Interconnect::all_gather_rounds`] /
//! [`Interconnect::tree_reduce_rounds`]) that drains behind each
//! device's compute window instead of serializing after the slowest
//! device.  [`ShardLatency`] reports both models — `serialized`
//! (`max_device_cycles + link_cycles`, the pre-overlap behaviour) and
//! `overlapped` — and the bound
//! `max(compute, link) ≤ overlapped ≤ serialized` holds by construction
//! (property-tested across the zoo in `rust/tests/overlap_invariants.rs`).
//! The link time a device hides is the greedy [`LinkStream`] drain's
//! `min(link total, Σ MAC windows)` (pinned in [`super::pipeline`]),
//! which the closed path charges directly.
//!
//! The cheap closed form also pays for a better `Auto` axis:
//! [`shard_gemm_overlap_aware`] prices all three partition axes by
//! overlapped latency and keeps the tile-mix natural axis unless another
//! axis strictly wins — at 4+ devices a contraction split's
//! `ceil(log2 D)` tree-reduce rounds hide behind compute where the
//! natural axis's `(D-1)` all-gather rounds cannot.
//!
//! Invariants (property-tested in `rust/tests/shard_conservation.rs`):
//! summed per-device EMA equals the plan's EMA word-for-word, and link
//! traffic is additive on top — a sharded plan never undercuts its
//! unsharded cost.

use crate::arch::Interconnect;
use crate::config::AcceleratorConfig;
use crate::dataflow::shard::{shard_gemm, LinkTraffic, ShardAxis, ShardSpec, ShardedPlan};
use crate::dataflow::PlanBody;
use crate::energy::{EnergyCost, EnergyModel};
use crate::gemm::{tile_extent, GemmShape, Tiling};
use crate::sim::cycles::{cycles_from_parts, CycleEstimate};
use crate::sim::ema::SimEma;
use crate::sim::pipeline::{LinkStream, PipelineSink, PipelineStats};
use crate::sim::replay::{CostSink, EmaSink, StepCtx};
use crate::sim::strip::{StripSummary, StripWalker};

/// One device's share of a sharded plan, fully costed.
#[derive(Clone, Debug)]
pub struct DeviceCost {
    pub device: usize,
    /// DRAM words this device's steps consume (compute EMA).
    pub ema: SimEma,
    /// MACs this device executes.
    pub macs: u64,
    pub cycles: CycleEstimate,
    pub energy: EnergyCost,
    /// Step-granular (DMA ‖ PE) stall attribution over this device's
    /// slice of the step stream (one pipeline fill per device).
    pub pipeline: PipelineStats,
    /// Link-round cycles this device's MAC bursts hid (third stream).
    pub link_hidden_cycles: u64,
    /// Words this device receives over links.
    pub link_in_words: u64,
    /// Words this device sends over links.
    pub link_out_words: u64,
}

/// Latency decomposition of one sharded GEMM under the aggregate cycle
/// model ([`cycles_from_parts`]): serialized vs overlapped link time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardLatency {
    /// Serialized link time: every collective round end to end.
    pub link_cycles: u64,
    /// Busiest device's latency before any link time.
    pub max_device_cycles: u64,
    /// Pre-overlap model: `max_device_cycles + link_cycles`.
    pub serialized_cycles: u64,
    /// Link rounds drained behind each device's PE-busy window: the
    /// whole-shard latency is the worst device's busy time plus the link
    /// cycles *its own* compute could not hide (an idle device just
    /// waits out the collective).
    pub overlapped_cycles: u64,
}

impl ShardLatency {
    /// Assemble from per-device cycle estimates plus the round total.
    /// Per device, `exposed = link - min(link, compute)`; the overlapped
    /// latency is `max over devices of (total + exposed)`, which pins
    /// `max(compute, link) <= overlapped <= serialized` by construction.
    pub fn from_parts(per_device: &[CycleEstimate], link_cycles: u64) -> ShardLatency {
        let max_device_cycles = per_device
            .iter()
            .map(|c| c.total_cycles)
            .max()
            .unwrap_or(0);
        let mut overlapped = link_cycles; // an all-idle shard still waits
        for c in per_device {
            let exposed = link_cycles - link_cycles.min(c.compute_cycles);
            overlapped = overlapped.max(c.total_cycles + exposed);
        }
        ShardLatency {
            link_cycles,
            max_device_cycles,
            serialized_cycles: max_device_cycles + link_cycles,
            overlapped_cycles: overlapped,
        }
    }

    /// Link cycles hidden behind compute — the overlap win.
    pub fn hidden_link_cycles(&self) -> u64 {
        self.serialized_cycles - self.overlapped_cycles
    }
}

/// Cost report of one sharded GEMM.
#[derive(Clone, Debug)]
pub struct ShardCost {
    pub per_device: Vec<DeviceCost>,
    pub link: LinkTraffic,
    pub link_energy_pj: f64,
    /// Serialized-vs-overlapped latency (aggregate cycle model) — the
    /// single source for the shard's cycle-level quantities
    /// (`link_cycles`, `max_device_cycles`, both totals).
    pub latency: ShardLatency,
}

impl ShardCost {
    /// Total DRAM words across devices (== the plan's EMA total).
    pub fn dram_words(&self) -> u64 {
        self.per_device.iter().map(|d| d.ema.total_words()).sum()
    }

    pub fn link_words(&self) -> u64 {
        self.link.total()
    }

    /// Serialized link time: operand all-gather + psum tree reduce.
    pub fn link_cycles(&self) -> u64 {
        self.latency.link_cycles
    }

    /// Slowest device's cycle estimate — the shard's critical path before
    /// link time.
    pub fn max_device_cycles(&self) -> u64 {
        self.latency.max_device_cycles
    }

    /// Pre-overlap latency: slowest device plus every link round.
    pub fn serialized_cycles(&self) -> u64 {
        self.latency.serialized_cycles
    }

    /// Latency with link rounds overlapped against compute.
    pub fn overlapped_cycles(&self) -> u64 {
        self.latency.overlapped_cycles
    }

    /// Whole-shard latency — the overlapped model (link transfers hide
    /// behind compute; see [`ShardLatency`]).  The serialized number the
    /// old model reported is [`ShardCost::serialized_cycles`].
    pub fn total_cycles(&self) -> u64 {
        self.latency.overlapped_cycles
    }

    /// Step-granular serialized latency: slowest pipeline walk (DMA
    /// stalls included) plus every link round.
    pub fn pipeline_serialized_cycles(&self) -> u64 {
        let max_pipe = self
            .per_device
            .iter()
            .map(|d| d.pipeline.total_cycles)
            .max()
            .unwrap_or(0);
        max_pipe + self.latency.link_cycles
    }

    /// Step-granular overlapped latency: each device pays its pipeline
    /// walk plus the link rounds its own MAC windows could not hide
    /// ([`LinkStream`]); the shard waits for the worst device.
    pub fn pipeline_overlapped_cycles(&self) -> u64 {
        let link = self.latency.link_cycles;
        self.per_device
            .iter()
            .map(|d| d.pipeline.total_cycles + (link - d.link_hidden_cycles))
            .max()
            .unwrap_or(link)
    }

    /// Total energy: per-device DRAM/SRAM/MAC plus link transfer energy.
    pub fn total_energy_pj(&self) -> f64 {
        self.per_device.iter().map(|d| d.energy.total_pj()).sum::<f64>()
            + self.link_energy_pj
    }
}

/// The collective round list of one sharded plan: the ring all-gather of
/// remote operand shares, then the tree reduce of contraction psums.
/// Sums to the serialized `link_cycles` exactly (the round closed forms
/// are pinned in [`crate::arch::interconnect`]'s tests).
pub fn shard_link_rounds(sp: &ShardedPlan, icx: &Interconnect) -> Vec<u64> {
    link_rounds_from(&sp.link_traffic(), sp, icx)
}

/// Round list from an already-computed [`LinkTraffic`] (the closed-form
/// walk is O(strips × devices), so callers that need the traffic anyway
/// pass it in instead of recomputing).
fn link_rounds_from(link: &LinkTraffic, sp: &ShardedPlan, icx: &Interconnect) -> Vec<u64> {
    let mut rounds = Vec::new();
    if link.operand_words > 0 {
        // Ring all-gather: every device forwards its share over its own
        // link each round, instead of one serialized p2p of the total.
        let share = link.operand_words.div_ceil(sp.devices);
        rounds.extend(icx.all_gather_rounds(share, sp.devices));
    }
    if link.reduce_words > 0 {
        // Collective tree reduce of the full-output psum payload: the
        // pairwise rounds run on disjoint links, so reduce time scales
        // with ceil(log2 D) payloads, not with (D-1) serialized copies.
        let payload = sp.plan.shape.output_words();
        let active = link.reduce_words / payload + 1;
        rounds.extend(icx.tree_reduce_rounds(payload, active));
    }
    rounds
}

/// Fold one compressed-run walker per device over the strip ranges the
/// partition routes to it ([`ShardedPlan::for_each_strip_range`]) — each
/// device's step subsequence is contiguous in schedule order, so per-
/// device walker state evolves exactly like the replayed per-device
/// sinks.  `None` for a fixed-scheme body (reachable only unsharded):
/// callers fall back to the step replay.
fn closed_device_summaries(
    sp: &ShardedPlan,
    cfg: &AcceleratorConfig,
) -> Option<Vec<StripSummary>> {
    if !matches!(sp.plan.body, PlanBody::Strips(_)) {
        return None;
    }
    let mut walkers: Vec<StripWalker> =
        (0..sp.devices).map(|_| StripWalker::new(cfg)).collect();
    sp.for_each_strip_range(|dev, strip, r_lo, r_hi| {
        walkers[dev].fold_strip(&sp.plan, strip, r_lo, r_hi);
    });
    Some(walkers.into_iter().map(StripWalker::finish).collect())
}

/// Closed-form [`ShardLatency`]: per-device cycle estimates from the
/// compressed-run walker — no step replay, so the whole zoo (and the
/// overlap-aware axis search) is checkable in milliseconds.  Equals the
/// replayed latency exactly on every strip body, resident streams
/// included (property-pinned below); the rare fixed-scheme body
/// (reachable only unsharded) falls back to the replayed per-device
/// pass.
pub fn sharded_closed_latency(
    sp: &ShardedPlan,
    cfg: &AcceleratorConfig,
    icx: &Interconnect,
) -> ShardLatency {
    let link_cycles: u64 = shard_link_rounds(sp, icx).iter().sum();
    let per_device: Vec<CycleEstimate> = match closed_device_summaries(sp, cfg) {
        Some(summaries) => summaries
            .iter()
            .map(|s| cycles_from_parts(s.macs, &s.ema, cfg))
            .collect(),
        None => replayed_device_estimates(sp, cfg),
    };
    ShardLatency::from_parts(&per_device, link_cycles)
}

/// True lower bound on any cover's overlapped latency at `devices`
/// shards: the busiest device computes at least `ceil(macs / devices)`
/// MACs, and no plan beats the PE array's throughput on them.  The joint
/// search ([`crate::dataflow::search`]) beam-prunes with
/// `max(this, candidate link rounds)` against its incumbent, so
/// candidates that cannot win are never fully priced.
pub fn overlapped_lower_bound(shape: GemmShape, devices: u64, cfg: &AcceleratorConfig) -> u64 {
    let per_device = shape.macs().div_ceil(devices.max(1));
    per_device.div_ceil(cfg.pe_array().macs_per_cycle().max(1))
}

/// Per-device cycle estimates via the replayed EmaSink pass — the
/// fallback for resident streams / fixed bodies, and the reference the
/// closed form is pinned against.
fn replayed_device_estimates(sp: &ShardedPlan, cfg: &AcceleratorConfig) -> Vec<CycleEstimate> {
    let d = sp.devices as usize;
    let mut sinks: Vec<EmaSink> = (0..d).map(|_| EmaSink::new(cfg.dram())).collect();
    let mut macs = vec![0u64; d];
    let (shape, tiling) = (sp.plan.shape, sp.plan.tiling);
    sp.for_each_step_device(|dev, step| {
        let ctx = StepCtx {
            plan: &sp.plan,
            step,
            mi: tile_extent(shape.m, tiling.tm, step.i),
            nr: tile_extent(shape.n, tiling.tn, step.r),
            kj: tile_extent(shape.k, tiling.tk, step.j),
        };
        macs[dev] += ctx.mi * ctx.nr * ctx.kj;
        sinks[dev].on_step(&ctx);
    });
    sinks
        .into_iter()
        .enumerate()
        .map(|(dev, sink)| cycles_from_parts(macs[dev], &sink.finish(), cfg))
        .collect()
}

/// Price a sharded plan through every per-device sink in O(strips):
/// one compressed-run walker per device, link traffic from the
/// partition's closed form.  Equals [`sharded_replayed_cost`] exactly on
/// every strip body (the per-device `link_hidden_cycles` is the greedy
/// drain's `min(link, Σ MAC windows)` — pinned in [`super::pipeline`]);
/// fixed bodies fall back to the replay, so the report never drifts from
/// the oracle on any plan.
pub fn sharded_fused_cost(
    sp: &ShardedPlan,
    cfg: &AcceleratorConfig,
    energy: &EnergyModel,
    icx: &Interconnect,
) -> ShardCost {
    let Some(summaries) = closed_device_summaries(sp, cfg) else {
        return sharded_replayed_cost(sp, cfg, energy, icx);
    };
    let link = sp.link_traffic();
    let rounds = link_rounds_from(&link, sp, icx);
    let link_cycles: u64 = rounds.iter().sum();
    let link_energy_pj = icx.transfer_energy_pj(link.total());
    let per_device: Vec<DeviceCost> = summaries
        .into_iter()
        .enumerate()
        .map(|(dev, s)| {
            let cycles = cycles_from_parts(s.macs, &s.ema, cfg);
            let (i, w, o) = s.ema.table2();
            DeviceCost {
                device: dev,
                cycles,
                energy: energy.traffic_energy(s.macs, i + w + o),
                macs: s.macs,
                link_hidden_cycles: link_cycles.min(s.pipeline.compute_cycles),
                pipeline: s.pipeline,
                link_in_words: link.per_device_in[dev],
                link_out_words: link.per_device_out[dev],
                ema: s.ema,
            }
        })
        .collect();
    let estimates: Vec<CycleEstimate> = per_device.iter().map(|dc| dc.cycles).collect();
    let latency = ShardLatency::from_parts(&estimates, link_cycles);
    ShardCost { per_device, link, link_energy_pj, latency }
}

/// The replay-backed oracle: walk the sharded step stream once,
/// dispatching each step to its device's [`EmaSink`] + [`PipelineSink`] +
/// [`LinkStream`], and assemble the same report [`sharded_fused_cost`]
/// derives closed-form.  Public so the property suites compare against
/// exactly this path.
pub fn sharded_replayed_cost(
    sp: &ShardedPlan,
    cfg: &AcceleratorConfig,
    energy: &EnergyModel,
    icx: &Interconnect,
) -> ShardCost {
    let d = sp.devices as usize;
    let link = sp.link_traffic();
    let rounds = link_rounds_from(&link, sp, icx);
    let link_cycles: u64 = rounds.iter().sum();
    let mut sinks: Vec<EmaSink> = (0..d).map(|_| EmaSink::new(cfg.dram())).collect();
    let mut pipes: Vec<PipelineSink> = (0..d).map(|_| PipelineSink::new(cfg)).collect();
    let mut links: Vec<LinkStream> =
        (0..d).map(|_| LinkStream::new(cfg, rounds.clone())).collect();
    let mut macs = vec![0u64; d];
    let (shape, tiling) = (sp.plan.shape, sp.plan.tiling);
    sp.for_each_step_device(|dev, step| {
        let ctx = StepCtx {
            plan: &sp.plan,
            step,
            mi: tile_extent(shape.m, tiling.tm, step.i),
            nr: tile_extent(shape.n, tiling.tn, step.r),
            kj: tile_extent(shape.k, tiling.tk, step.j),
        };
        macs[dev] += ctx.mi * ctx.nr * ctx.kj;
        sinks[dev].on_step(&ctx);
        pipes[dev].on_step(&ctx);
        links[dev].on_step(&ctx);
    });

    let link_energy_pj = icx.transfer_energy_pj(link.total());

    let per_device: Vec<DeviceCost> = sinks
        .into_iter()
        .zip(pipes)
        .zip(links)
        .enumerate()
        .map(|(dev, ((sink, pipe), lstream))| {
            let ema = sink.finish();
            let cycles = cycles_from_parts(macs[dev], &ema, cfg);
            let pipeline = pipe.finish();
            debug_assert_eq!(
                pipeline.total_cycles,
                pipeline.fills * cfg.pe_array().fill_latency
                    + pipeline.compute_cycles
                    + pipeline.stall_cycles,
                "single-fill-per-segment convention (see sim::pipeline)"
            );
            let (i, w, o) = ema.table2();
            DeviceCost {
                device: dev,
                cycles,
                energy: energy.traffic_energy(macs[dev], i + w + o),
                macs: macs[dev],
                pipeline,
                link_hidden_cycles: lstream.finish().hidden_cycles(),
                link_in_words: link.per_device_in[dev],
                link_out_words: link.per_device_out[dev],
                ema,
            }
        })
        .collect();

    let estimates: Vec<CycleEstimate> = per_device.iter().map(|dc| dc.cycles).collect();
    let latency = ShardLatency::from_parts(&estimates, link_cycles);
    ShardCost { per_device, link, link_energy_pj, latency }
}

/// Overlap-aware [`ShardAxis::Auto`]: price every candidate partition by
/// its **overlapped** latency ([`sharded_closed_latency`], O(strips) per
/// candidate) and keep the tile-mix natural axis
/// ([`crate::dataflow::shard::natural_axis`]) unless another axis
/// strictly wins.  Candidates are tried natural-first, then the other
/// output axis, then the contraction split — so ties preserve the
/// stationary-decision default, and the contraction split only takes
/// over where its `ceil(log2 D)` tree-reduce rounds genuinely hide
/// behind compute that the natural axis's `(D-1)` all-gather rounds
/// drown (the d ≥ 4 flip pinned in the tests below).  Explicit axes and
/// single devices pass straight through to [`shard_gemm`].
pub fn shard_gemm_overlap_aware(
    shape: &GemmShape,
    tiling: &Tiling,
    spec: ShardSpec,
    cfg: &AcceleratorConfig,
    icx: &Interconnect,
) -> ShardedPlan {
    let rww = icx.remote_word_weight(cfg.dram_bandwidth);
    if !matches!(spec.axis, ShardAxis::Auto) || spec.devices <= 1 {
        return shard_gemm(shape, tiling, spec, rww);
    }
    // shard_gemm resolves Auto to the tile-mix natural axis.
    let mut best = shard_gemm(shape, tiling, spec, rww);
    let mut best_cycles = sharded_closed_latency(&best, cfg, icx).overlapped_cycles;
    let other = match best.axis {
        ShardAxis::Rows => ShardAxis::Cols,
        _ => ShardAxis::Rows,
    };
    for axis in [other, ShardAxis::Contraction] {
        let cand = shard_gemm(shape, tiling, ShardSpec { axis, ..spec }, rww);
        let cycles = sharded_closed_latency(&cand, cfg, icx).overlapped_cycles;
        if cycles < best_cycles {
            best = cand;
            best_cycles = cycles;
        }
    }
    best
}

/// Convenience: is the partition a psum-reducing contraction split?
pub fn is_reduce_shard(sp: &ShardedPlan) -> bool {
    sp.axis == ShardAxis::Contraction
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::shard::{shard_gemm, ShardSpec};
    use crate::dataflow::Plan;
    use crate::gemm::{GemmShape, Tiling};

    fn cost(shape: GemmShape, devices: u64, axis: ShardAxis) -> (ShardedPlan, ShardCost) {
        let tiling = Tiling::square(16);
        let sp = shard_gemm(&shape, &tiling, ShardSpec::new(devices, axis), 0.0);
        let cfg = AcceleratorConfig::default();
        let c = sharded_fused_cost(&sp, &cfg, &EnergyModel::default(), &Interconnect::default());
        (sp, c)
    }

    #[test]
    fn replayed_device_emas_match_closed_form() {
        for axis in [ShardAxis::Rows, ShardAxis::Cols, ShardAxis::Contraction] {
            let (sp, c) = cost(GemmShape::new(130, 70, 90), 3, axis);
            let closed = sp.device_emas();
            assert_eq!(c.per_device.len(), closed.len());
            for (dc, e) in c.per_device.iter().zip(&closed) {
                assert_eq!(
                    dc.ema.table2(),
                    (e.input, e.weight, e.output),
                    "device {} {axis:?}",
                    dc.device
                );
            }
        }
    }

    #[test]
    fn device_macs_sum_to_the_gemm() {
        let shape = GemmShape::new(120, 96, 88);
        for axis in [ShardAxis::Rows, ShardAxis::Cols, ShardAxis::Contraction] {
            let (_, c) = cost(shape, 4, axis);
            let total: u64 = c.per_device.iter().map(|d| d.macs).sum();
            assert_eq!(total, shape.macs(), "{axis:?}");
        }
    }

    #[test]
    fn one_device_matches_the_unsharded_fused_pass() {
        use crate::arch::dram_timing::DramTimingConfig;
        use crate::sim::replay::fused_cost;
        let shape = GemmShape::new(96, 128, 160);
        let tiling = Tiling::square(16);
        let cfg = AcceleratorConfig::default();
        let (_, c) = cost(shape, 1, ShardAxis::Auto);
        let plan = Plan::tas_per_tile(&shape, &tiling);
        let fused = fused_cost(&plan, &cfg, &EnergyModel::default(), DramTimingConfig::default());
        assert_eq!(c.per_device.len(), 1);
        assert_eq!(c.per_device[0].ema, fused.ema);
        assert_eq!(c.per_device[0].cycles, fused.cycles);
        assert_eq!(c.per_device[0].pipeline, fused.pipeline);
        assert_eq!(c.link_words(), 0);
        assert_eq!(c.link_cycles(), 0);
        // no links: overlapped == serialized == the device's own latency
        assert_eq!(c.overlapped_cycles(), c.serialized_cycles());
        assert_eq!(c.overlapped_cycles(), c.max_device_cycles());
        assert_eq!(c.per_device[0].link_hidden_cycles, 0);
    }

    #[test]
    fn sharding_splits_the_critical_path() {
        // 4-way row shard of an IS-friendly GEMM: the slowest device does
        // about a quarter of the work.
        let shape = GemmShape::new(256, 768, 768);
        let (_, c1) = cost(shape, 1, ShardAxis::Rows);
        let (_, c4) = cost(shape, 4, ShardAxis::Rows);
        assert!(c4.max_device_cycles() < c1.max_device_cycles());
        // but link time + conserved EMA mean total work never shrinks
        assert_eq!(c4.dram_words(), c1.dram_words());
        assert!(c4.total_energy_pj() > c1.total_energy_pj());
    }

    #[test]
    fn reduce_shard_reports_link_cycles() {
        let shape = GemmShape::new(128, 512, 128);
        let (sp, c) = cost(shape, 4, ShardAxis::Contraction);
        assert!(is_reduce_shard(&sp));
        assert!(c.link.reduce_words > 0);
        assert!(c.link_cycles() > 0);
        assert!(c.link_energy_pj > 0.0);
    }

    #[test]
    fn collective_reduce_beats_serialized_chain_at_scale() {
        // The psum reduce rides the tree primitive: at 4+ devices it must
        // undercut streaming every (D-1) psum copy through one link — and
        // the overlapped latency must undercut even the chain model's
        // total, because overlap only ever removes link time.
        let shape = GemmShape::new(512, 1024, 512);
        let icx = Interconnect::default();
        for devices in [4u64, 8] {
            let (_, c) = cost(shape, devices, ShardAxis::Contraction);
            let chain = icx.p2p_cycles(c.link.reduce_words);
            assert!(
                c.link_cycles() < chain,
                "d={devices}: {} >= {chain}",
                c.link_cycles()
            );
            assert!(c.overlapped_cycles() <= c.serialized_cycles());
            assert!(c.overlapped_cycles() < c.max_device_cycles() + chain);
        }
    }

    #[test]
    fn operand_traffic_rides_the_all_gather_ring() {
        // Rows shard of an IS GEMM: every device gathers the remote
        // weight columns; (D-1) rounds of one per-device share each.
        let shape = GemmShape::new(64, 768, 768);
        let icx = Interconnect::default();
        let d = 4u64;
        let (sp, c) = cost(shape, d, ShardAxis::Rows);
        assert!(c.link.operand_words > 0);
        let share = c.link.operand_words.div_ceil(d);
        assert_eq!(c.link_cycles(), icx.all_gather_cycles(share, d));
        assert!(c.link_cycles() < icx.p2p_cycles(c.link.operand_words));
        // the round list is the same time, cut into D-1 rounds
        let rounds = shard_link_rounds(&sp, &icx);
        assert_eq!(rounds.len() as u64, d - 1);
        assert_eq!(rounds.iter().sum::<u64>(), c.link_cycles());
    }

    #[test]
    fn closed_latency_matches_replayed_latency() {
        let cfg = AcceleratorConfig::default();
        let icx = Interconnect::default();
        for shape in [
            GemmShape::new(130, 70, 90),
            GemmShape::new(64, 768, 768),
            GemmShape::new(512, 96, 256),
        ] {
            for axis in [ShardAxis::Rows, ShardAxis::Cols, ShardAxis::Contraction] {
                for d in [1u64, 2, 3, 4, 8] {
                    let tiling = Tiling::square(16);
                    let sp = shard_gemm(&shape, &tiling, ShardSpec::new(d, axis), 0.0);
                    let closed = sharded_closed_latency(&sp, &cfg, &icx);
                    let replayed =
                        sharded_replayed_cost(&sp, &cfg, &EnergyModel::default(), &icx).latency;
                    assert_eq!(closed, replayed, "{shape:?} {axis:?} d={d}");
                }
            }
        }
    }

    #[test]
    fn closed_shard_cost_matches_the_replayed_oracle() {
        // The walker-backed sharded_fused_cost must reproduce the step
        // replay field for field on every axis — ragged shapes, idle
        // devices and contraction round routing included.
        let cfg = AcceleratorConfig::default();
        let em = EnergyModel::default();
        let icx = Interconnect::default();
        for shape in [
            GemmShape::new(130, 70, 90),
            GemmShape::new(64, 768, 768),
            GemmShape::new(512, 96, 256),
            GemmShape::new(32, 64, 64),
        ] {
            for axis in [ShardAxis::Rows, ShardAxis::Cols, ShardAxis::Contraction] {
                for d in [1u64, 2, 3, 4, 8] {
                    let tiling = Tiling::square(16);
                    let sp = shard_gemm(&shape, &tiling, ShardSpec::new(d, axis), 0.0);
                    let closed = sharded_fused_cost(&sp, &cfg, &em, &icx);
                    let oracle = sharded_replayed_cost(&sp, &cfg, &em, &icx);
                    let tag = format!("{shape:?} {axis:?} d={d}");
                    assert_eq!(closed.latency, oracle.latency, "{tag}");
                    assert_eq!(closed.link, oracle.link, "{tag}");
                    assert_eq!(closed.per_device.len(), oracle.per_device.len(), "{tag}");
                    for (c, o) in closed.per_device.iter().zip(&oracle.per_device) {
                        assert_eq!(c.ema, o.ema, "{tag} dev={}", c.device);
                        assert_eq!(c.macs, o.macs, "{tag} dev={}", c.device);
                        assert_eq!(c.cycles, o.cycles, "{tag} dev={}", c.device);
                        assert_eq!(c.pipeline, o.pipeline, "{tag} dev={}", c.device);
                        assert_eq!(
                            c.link_hidden_cycles, o.link_hidden_cycles,
                            "{tag} dev={}",
                            c.device
                        );
                        assert!((c.energy.total_pj() - o.energy.total_pj()).abs() < 1e-6);
                    }
                }
            }
        }
    }

    #[test]
    fn overlap_aware_auto_flips_to_contraction_at_scale() {
        // IS-friendly GEMM (M < K): the tile-mix natural axis is Rows,
        // whose (D-1) weight all-gather rounds swamp the per-device
        // compute at 4+ devices; the contraction split's ceil(log2 D)
        // tree-reduce rounds hide entirely.  At 2 devices the single
        // all-gather round still hides, so the natural axis survives.
        let shape = GemmShape::new(64, 768, 768);
        let tiling = Tiling::square(16);
        let cfg = AcceleratorConfig::default();
        let icx = Interconnect::default();
        let resolve = |d: u64| {
            let spec = ShardSpec::new(d, ShardAxis::Auto);
            shard_gemm_overlap_aware(&shape, &tiling, spec, &cfg, &icx)
        };
        assert_eq!(resolve(2).axis, ShardAxis::Rows, "2 devices keep the natural axis");
        for d in [4u64, 8] {
            let sp = resolve(d);
            assert_eq!(sp.axis, ShardAxis::Contraction, "d={d}");
            // ...and the flip is a genuine overlapped-latency win over the
            // natural axis.
            let natural = shard_gemm(&shape, &tiling, ShardSpec::new(d, ShardAxis::Auto), 0.0);
            assert!(
                sharded_closed_latency(&sp, &cfg, &icx).overlapped_cycles
                    < sharded_closed_latency(&natural, &cfg, &icx).overlapped_cycles,
                "d={d}"
            );
        }
        // Explicit axes pass through untouched.
        let pinned = shard_gemm_overlap_aware(
            &shape,
            &tiling,
            ShardSpec::new(4, ShardAxis::Rows),
            &cfg,
            &icx,
        );
        assert_eq!(pinned.axis, ShardAxis::Rows);
    }

    #[test]
    fn overlap_bounds_hold_and_bite() {
        // The invariant, plus a case where overlap strictly wins: a
        // contraction shard's tree reduce hides behind the per-device
        // compute of a compute-heavy GEMM.
        let (_, c) = cost(GemmShape::new(512, 1024, 512), 4, ShardAxis::Contraction);
        let lat = c.latency;
        assert!(lat.overlapped_cycles >= lat.max_device_cycles.max(lat.link_cycles));
        assert!(lat.overlapped_cycles <= lat.serialized_cycles);
        assert!(
            lat.overlapped_cycles < lat.serialized_cycles,
            "overlap should hide link time here: {lat:?}"
        );
        assert_eq!(
            lat.hidden_link_cycles(),
            lat.serialized_cycles - lat.overlapped_cycles
        );
        // pipeline-granular model obeys the same bound
        let max_pipe = c
            .per_device
            .iter()
            .map(|d| d.pipeline.total_cycles)
            .max()
            .unwrap();
        assert!(c.pipeline_overlapped_cycles() >= max_pipe.max(c.link_cycles()));
        assert!(c.pipeline_overlapped_cycles() <= c.pipeline_serialized_cycles());
    }

    #[test]
    fn link_stream_hidden_bounded_by_device_compute() {
        let (_, c) = cost(GemmShape::new(64, 768, 768), 4, ShardAxis::Rows);
        for dc in &c.per_device {
            assert!(dc.link_hidden_cycles <= c.link_cycles());
            assert!(dc.link_hidden_cycles <= dc.pipeline.compute_cycles);
            assert_eq!(
                dc.link_hidden_cycles,
                c.link_cycles().min(dc.pipeline.compute_cycles),
                "greedy drain hides min(link, compute)"
            );
        }
    }
}
