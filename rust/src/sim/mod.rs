//! Trace-driven accelerator simulator.
//!
//! Replays a dataflow schedule ([`crate::dataflow::schedule`]) against the
//! hardware model ([`crate::arch`]) and reports:
//!
//! * [`ema`] — exact per-stream DRAM word counts + read↔write turnaround
//!   switches (the measurement instrument behind Tables II–IV),
//! * [`replay`] — the fused single-pass replay: every cost backend
//!   ([`replay::CostSink`]) observes one walk of a schedule [`Plan`]
//!   instead of each consumer re-running the loop nest,
//! * [`occupancy`] — peak psum-register and SRAM usage (verifies §III-B's
//!   capacity argument),
//! * [`functional`] — numeric execution of the schedule on real f32 data
//!   (proves every schedule computes the same GEMM),
//! * [`cycles`] — a first-order latency model (compute/DRAM overlap with
//!   turnaround stalls),
//! * [`pipeline`] — step-level (DMA ‖ PE) stall attribution, a
//!   [`replay::CostSink`] over the fused pass, plus the third
//!   ([`pipeline::LinkStream`]) stream: inter-chip link rounds drained
//!   behind the same compute windows,
//! * [`shard`] — per-device cost model for multi-accelerator shards
//!   ([`crate::dataflow::shard`]): closed-form per-device walkers with
//!   the step replay retained as the oracle
//!   ([`shard::sharded_replayed_cost`]), link traffic costed by
//!   [`crate::arch::Interconnect`] and reported both serialized and
//!   overlapped ([`shard::ShardLatency`]); the cheap closed form funds
//!   the overlap-aware `Auto` axis ([`shard::shard_gemm_overlap_aware`]),
//! * [`strip`] — closed-form strip costing: every planner-facing sink
//!   (EMA, cycles, energy, pipeline, DRAM words/transactions/switches)
//!   priced in O(strips) via compressed-run folding, with the replay
//!   retained as the property-test oracle,
//! * [`decode`] — trajectory-level fused cost for decode plans
//!   ([`crate::dataflow::DecodePlan`]): prefill plus every autoregressive
//!   step priced through the same sinks in one pass; head-sharded
//!   trajectories overlap each step's all-reduce against its compute
//!   ([`decode::sharded_trajectory_cost`]).
//!
//! [`Plan`]: crate::dataflow::Plan

pub mod cycles;
pub mod decode;
pub mod dram_trace;
pub mod ema;
pub mod functional;
pub mod occupancy;
pub mod pipeline;
pub mod replay;
pub mod roofline;
pub mod shard;
pub mod strip;

pub use cycles::{estimate_cycles, estimate_cycles_plan, CycleEstimate};
pub use decode::{
    sharded_trajectory_cost, trajectory_cost_with_links, trajectory_fused_cost,
    ShardedTrajectoryCost, TrajectoryCost,
};
pub use dram_trace::{simulate_dram_timing, simulate_dram_timing_plan};
pub use ema::{simulate_ema, simulate_ema_plan, SimEma};
pub use replay::{fused_cost, CostSink, EmaSink, FusedCost, StepCtx, TimingSink};
pub use roofline::{ridge_intensity, roofline, RooflinePoint};
pub use functional::{execute_plan, execute_schedule};
pub use occupancy::{measure_occupancy, measure_occupancy_plan, Occupancy};
pub use pipeline::{
    simulate_pipeline, simulate_pipeline_plan, LinkSchedule, LinkStream, PipelineSink,
    PipelineStats,
};
pub use shard::{
    overlapped_lower_bound, shard_gemm_overlap_aware, shard_link_rounds, sharded_closed_latency,
    sharded_fused_cost, sharded_replayed_cost, DeviceCost, ShardCost, ShardLatency,
};
pub use strip::{
    attribute_strips, attribute_strips_on, plan_cost, plan_cost_on, plan_ema_pipeline,
    plan_ema_pipeline_on, plan_sim_ema, plan_sim_ema_on, replayed_cost, replayed_cost_on,
    StripCost, StripShare, StripTiming,
};
