//! Trace-driven accelerator simulator.
//!
//! Replays a dataflow schedule ([`crate::dataflow::schedule`]) against the
//! hardware model ([`crate::arch`]) and reports:
//!
//! * [`ema`] — exact per-stream DRAM word counts + read↔write turnaround
//!   switches (the measurement instrument behind Tables II–IV),
//! * [`occupancy`] — peak psum-register and SRAM usage (verifies §III-B's
//!   capacity argument),
//! * [`functional`] — numeric execution of the schedule on real f32 data
//!   (proves every schedule computes the same GEMM),
//! * [`cycles`] — a first-order latency model (compute/DRAM overlap with
//!   turnaround stalls).

pub mod cycles;
pub mod dram_trace;
pub mod ema;
pub mod functional;
pub mod occupancy;
pub mod pipeline;
pub mod roofline;

pub use cycles::{estimate_cycles, CycleEstimate};
pub use dram_trace::simulate_dram_timing;
pub use ema::{simulate_ema, SimEma};
pub use roofline::{ridge_intensity, roofline, RooflinePoint};
pub use functional::execute_schedule;
pub use occupancy::{measure_occupancy, Occupancy};
pub use pipeline::{simulate_pipeline, PipelineStats};
