//! Functional (numeric) execution of a schedule: replays the tile steps on
//! real `f32` data, accumulating `out[i,j] += in[i,r]·w[r,j]` tile by tile
//! in schedule order.  If a schedule skipped, repeated or mis-ordered a
//! tile pass, the result would diverge from a plain matmul — so equality
//! with [`reference_matmul`] proves schedule correctness for *every*
//! scheme, mirroring what `python/tests` prove for the Pallas kernels.

use crate::dataflow::{Plan, Scheme};
use crate::gemm::{tile_extent, GemmShape, Tiling};

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_fn<F: FnMut(usize, usize) -> f32>(rows: usize, cols: usize, mut f: F) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.data[r * cols + c] = f(r, c);
            }
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }
}

/// Plain triple-loop reference.
pub fn reference_matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows);
    let mut out = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for r in 0..a.cols {
            let av = a.at(i, r);
            if av == 0.0 {
                continue;
            }
            for j in 0..b.cols {
                *out.at_mut(i, j) += av * b.at(r, j);
            }
        }
    }
    out
}

/// Execute `scheme`'s schedule numerically. Panics if shapes disagree with
/// `shape`.
pub fn execute_schedule(
    scheme: Scheme,
    shape: &GemmShape,
    tiling: &Tiling,
    input: &Mat,
    weight: &Mat,
) -> Mat {
    execute_plan(&Plan::from_scheme(scheme, shape, tiling), input, weight)
}

/// Execute any [`Plan`]'s step stream numerically — per-tile TAS covers
/// must compute the same GEMM as every fixed schedule.
pub fn execute_plan(plan: &Plan, input: &Mat, weight: &Mat) -> Mat {
    let (shape, tiling) = (plan.shape, plan.tiling);
    assert_eq!((input.rows as u64, input.cols as u64), (shape.m, shape.n));
    assert_eq!((weight.rows as u64, weight.cols as u64), (shape.n, shape.k));
    let mut out = Mat::zeros(shape.m as usize, shape.k as usize);
    plan.for_each_step(|s| {
        let mi = tile_extent(shape.m, tiling.tm, s.i) as usize;
        let nr = tile_extent(shape.n, tiling.tn, s.r) as usize;
        let kj = tile_extent(shape.k, tiling.tk, s.j) as usize;
        let i0 = (s.i * tiling.tm) as usize;
        let r0 = (s.r * tiling.tn) as usize;
        let j0 = (s.j * tiling.tk) as usize;
        // One tile MAC pass on the PE array.
        for di in 0..mi {
            for dr in 0..nr {
                let av = input.at(i0 + di, r0 + dr);
                for dj in 0..kj {
                    *out.at_mut(i0 + di, j0 + dj) += av * weight.at(r0 + dr, j0 + dj);
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{assert_allclose, property};
    use crate::util::prng::Rng;

    fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
        Mat::from_fn(rows, cols, |_, _| rng.gen_f32_signed())
    }

    #[test]
    fn reference_matmul_known_values() {
        let a = Mat::from_fn(2, 2, |r, c| (r * 2 + c + 1) as f32); // [[1,2],[3,4]]
        let b = Mat::from_fn(2, 2, |_, _| 1.0);
        let out = reference_matmul(&a, &b);
        assert_eq!(out.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    /// Every scheme, every shape (ragged included): schedule-driven GEMM
    /// equals the reference — the rust twin of the Pallas-vs-ref pytest.
    #[test]
    fn all_schedules_compute_the_same_gemm() {
        property("functional equivalence", 40, |rng: &mut Rng| {
            let shape = GemmShape::new(
                rng.gen_in(1, 60),
                rng.gen_in(1, 60),
                rng.gen_in(1, 60),
            );
            let t = Tiling::new(
                rng.gen_in(1, 20),
                rng.gen_in(1, 20),
                rng.gen_in(1, 20),
            );
            let a = rand_mat(rng, shape.m as usize, shape.n as usize);
            let b = rand_mat(rng, shape.n as usize, shape.k as usize);
            let want = reference_matmul(&a, &b);
            for scheme in Scheme::FIXED.iter().chain([Scheme::Tas].iter()) {
                let got = execute_schedule(*scheme, &shape, &t, &a, &b);
                assert_allclose(&got.data, &want.data, 1e-5, 1e-5);
            }
        });
    }

    #[test]
    fn psum_windows_do_not_change_numerics() {
        property("window numerics", 30, |rng: &mut Rng| {
            let shape = GemmShape::new(
                rng.gen_in(1, 80),
                rng.gen_in(1, 80),
                rng.gen_in(1, 80),
            );
            let base = Tiling::square(8);
            let t = Tiling {
                kp: Some(rng.gen_in(1, 4) * 8),
                mp: Some(rng.gen_in(1, 4) * 8),
                ..base
            };
            let a = rand_mat(rng, shape.m as usize, shape.n as usize);
            let b = rand_mat(rng, shape.n as usize, shape.k as usize);
            let want = reference_matmul(&a, &b);
            for scheme in [Scheme::IsOs, Scheme::WsOs, Scheme::Tas] {
                let got = execute_schedule(scheme, &shape, &t, &a, &b);
                assert_allclose(&got.data, &want.data, 1e-5, 1e-5);
            }
        });
    }

    #[test]
    fn per_tile_plans_compute_the_same_gemm() {
        property("plan functional", 30, |rng: &mut Rng| {
            let shape = GemmShape::new(
                rng.gen_in(1, 80),
                rng.gen_in(1, 80),
                rng.gen_in(1, 80),
            );
            let t = 8;
            let tiling = Tiling::square(t)
                .with_kp(rng.gen_in(1, 4) * t)
                .with_mp(rng.gen_in(1, 4) * t);
            let a = rand_mat(rng, shape.m as usize, shape.n as usize);
            let b = rand_mat(rng, shape.n as usize, shape.k as usize);
            let want = reference_matmul(&a, &b);
            let plan = Plan::tas_per_tile(&shape, &tiling);
            let got = execute_plan(&plan, &a, &b);
            assert_allclose(&got.data, &want.data, 1e-5, 1e-5);
        });
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let shape = GemmShape::new(4, 4, 4);
        let a = Mat::zeros(3, 4);
        let b = Mat::zeros(4, 4);
        execute_schedule(Scheme::Tas, &shape, &Tiling::square(2), &a, &b);
    }
}
