//! First-order latency model: compute and DRAM streaming overlap (double
//! buffering), but read↔write turnaround stalls serialise — that is the
//! §II-d penalty the hybrids remove.
//!
//! EMA (the paper's headline metric) needs no timing; this model exists to
//! show the *communication-efficiency* claim (§I: "nearly twice the
//! efficiency compared to the previous fixed stationary method") as a
//! cycle count, and to let the coordinator estimate request latency.

use crate::arch::PeArray;
use crate::config::AcceleratorConfig;
use crate::dataflow::{Plan, Scheme};
use crate::gemm::{GemmShape, Tiling};
use crate::sim::ema::SimEma;

/// Cycle estimate for one GEMM under one scheme.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CycleEstimate {
    /// PE-array busy cycles (incl. pipeline fill per tile pass).
    pub compute_cycles: u64,
    /// DRAM streaming cycles (words / bandwidth).
    pub dram_stream_cycles: u64,
    /// Turnaround stall cycles (direction switches × penalty).
    pub turnaround_cycles: u64,
    /// Total latency: max(compute, stream) + stalls.
    pub total_cycles: u64,
}

impl CycleEstimate {
    /// Fraction of total time lost to read/write turnaround.
    pub fn stall_fraction(&self) -> f64 {
        if self.total_cycles == 0 {
            0.0
        } else {
            self.turnaround_cycles as f64 / self.total_cycles as f64
        }
    }

    /// Effective MAC utilisation vs the PE array peak.
    pub fn utilization(&self, shape: &GemmShape, pe: &PeArray) -> f64 {
        if self.total_cycles == 0 {
            return 0.0;
        }
        shape.macs() as f64 / (self.total_cycles * pe.macs_per_cycle()) as f64
    }
}

/// Estimate cycles for `scheme` on `shape` under `cfg`.
pub fn estimate_cycles(scheme: Scheme, shape: &GemmShape, cfg: &AcceleratorConfig) -> CycleEstimate {
    let tiling = cfg.tiling();
    estimate_cycles_tiled(scheme, shape, &tiling, cfg)
}

/// Same, with an explicit tiling (ablation sweeps).
pub fn estimate_cycles_tiled(
    scheme: Scheme,
    shape: &GemmShape,
    tiling: &Tiling,
    cfg: &AcceleratorConfig,
) -> CycleEstimate {
    estimate_cycles_plan(&Plan::from_scheme(scheme, shape, tiling), cfg)
}

/// Cycle estimate for any [`Plan`] (fixed scheme or per-tile TAS).
///
/// Strip bodies are priced by the closed-form walker
/// ([`crate::sim::strip`]) in O(strips); fixed bodies still replay.  The
/// result is bit-identical to the replayed estimate either way — the
/// strip property suite pins it.
pub fn estimate_cycles_plan(plan: &Plan, cfg: &AcceleratorConfig) -> CycleEstimate {
    let sim = crate::sim::strip::plan_sim_ema(plan, cfg);
    cycles_from_replay(&sim, &plan.shape, cfg)
}

/// Derive the cycle estimate from an already-replayed EMA result — the
/// closed-form half of the model, shared with the fused single-pass
/// replay ([`crate::sim::replay::fused_cost`]) so both paths are one
/// formula by construction.
pub fn cycles_from_replay(sim: &SimEma, shape: &GemmShape, cfg: &AcceleratorConfig) -> CycleEstimate {
    cycles_from_parts(shape.macs(), sim, cfg)
}

/// Same formula from an explicit MAC count — a sharded device replays
/// only its slice of the grid, so its MACs are a partial sum rather than
/// `shape.macs()` ([`crate::sim::shard`]).
pub fn cycles_from_parts(macs: u64, sim: &SimEma, cfg: &AcceleratorConfig) -> CycleEstimate {
    cycles_from_parts_on(macs, sim, &crate::arch::backend::BackendParams::systolic(cfg))
}

/// The same formula over any backend's parameter block (fill latency, MAC
/// throughput, bus bandwidth, turnaround) — the systolic block reproduces
/// [`cycles_from_parts`] exactly.
pub fn cycles_from_parts_on(
    macs: u64,
    sim: &SimEma,
    params: &crate::arch::backend::BackendParams,
) -> CycleEstimate {
    // Compute: each of the `steps` tile passes is a tile MAC burst; model
    // the whole workload as total MACs at fabric throughput + per-pass fill.
    let fill = params.fill_latency * sim.steps;
    let mac_cycles = macs.div_ceil(params.macs_per_cycle);
    let compute_cycles = mac_cycles + fill;

    let dram_stream_cycles = sim.stats.total_words().div_ceil(params.bandwidth);
    let turnaround_cycles = sim.stats.direction_switches * params.turnaround;

    CycleEstimate {
        compute_cycles,
        dram_stream_cycles,
        turnaround_cycles,
        total_cycles: compute_cycles.max(dram_stream_cycles) + turnaround_cycles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AcceleratorConfig {
        AcceleratorConfig::default()
    }

    #[test]
    fn hybrid_faster_than_spilling_parent() {
        // Spilling schemes move more words AND switch direction per step.
        let shape = GemmShape::new(512, 1024, 1024);
        let is = estimate_cycles(Scheme::Is, &shape, &cfg());
        let is_os = estimate_cycles(Scheme::IsOs, &shape, &cfg());
        assert!(is_os.total_cycles < is.total_cycles);
        assert!(is_os.turnaround_cycles < is.turnaround_cycles);
    }

    #[test]
    fn naive_is_worst() {
        let shape = GemmShape::new(256, 512, 512);
        let naive = estimate_cycles(Scheme::Naive, &shape, &cfg());
        for s in [Scheme::Is, Scheme::Ws, Scheme::OsRow, Scheme::Tas] {
            assert!(
                estimate_cycles(s, &shape, &cfg()).total_cycles <= naive.total_cycles,
                "{s:?}"
            );
        }
    }

    #[test]
    fn stall_fraction_bounded() {
        let shape = GemmShape::new(128, 256, 256);
        for s in Scheme::FIXED {
            let e = estimate_cycles(s, &shape, &cfg());
            let f = e.stall_fraction();
            assert!((0.0..=1.0).contains(&f), "{s:?}: {f}");
            assert_eq!(
                e.total_cycles,
                e.compute_cycles.max(e.dram_stream_cycles) + e.turnaround_cycles
            );
        }
    }

    #[test]
    fn utilization_in_unit_range() {
        let shape = GemmShape::new(512, 512, 512);
        let pe = cfg().pe_array();
        let e = estimate_cycles(Scheme::Tas, &shape, &cfg());
        let u = e.utilization(&shape, &pe);
        assert!(u > 0.0 && u <= 1.0, "{u}");
    }
}
