//! External-memory-access replay: the simulator counterpart of Table II.
//!
//! Walks every schedule step, charging the DRAM model with the exact word
//! counts of each transfer (ragged edge tiles use their true extents).
//! Within a step the access order is: operand reads, psum fetch (read),
//! then psum spill / output store (writes) — direction switches are
//! counted by [`crate::arch::Dram`], reproducing §II-d's concurrent
//! read/write problem for the spilling schemes.

use crate::arch::dram::{Dram, DramStats, Stream};
use crate::dataflow::{Plan, Residency, Scheme, Step};
use crate::gemm::{tile_extent, GemmShape, Tiling};

/// Simulated EMA result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SimEma {
    pub stats: DramStats,
    /// Schedule steps replayed.
    pub steps: u64,
}

impl SimEma {
    /// Table II accounting: (input reads, weight reads, output writes).
    pub fn table2(&self) -> (u64, u64, u64) {
        self.stats.table2_words()
    }

    pub fn total_words(&self) -> u64 {
        let (i, w, o) = self.table2();
        i + w + o
    }

    /// Extended accounting: psum re-fetch traffic the paper folds away.
    pub fn psum_readback_words(&self) -> u64 {
        self.stats.psum_read_words
    }
}

/// Charge one schedule step's DRAM traffic.  Shared by [`simulate_ema`],
/// the fused replay ([`crate::sim::replay`]) and anything else that walks
/// a [`Plan`]: one accounting rule, every consumer.
///
/// The per-stream [`Residency`] values suppress the corresponding DRAM
/// streams when the tensor is fully SRAM-resident (see
/// [`crate::dataflow::residency`]); a partial residency never reaches
/// this level — the planners slice it into fully hot / fully cold plans.
pub(crate) fn charge_step(
    dram: &mut Dram,
    s: &Step,
    mi: u64,
    nr: u64,
    kj: u64,
    input: Residency,
    weight: Residency,
    output: Residency,
) {
    charge_step_scaled(dram, s, mi, nr, kj, input, weight, output, [1, 1, 1])
}

/// [`charge_step`] with a backend charge triple `[input, weight, output]`
/// multiplying each stream's words: an operand the backend never streams
/// (a crossbar's programmed weights) charges zero words and therefore no
/// direction switches ([`Dram`] ignores zero-word transfers).  Psum spill
/// and re-fetch ride the output charge — they are output-direction
/// traffic.
#[allow(clippy::too_many_arguments)]
pub(crate) fn charge_step_scaled(
    dram: &mut Dram,
    s: &Step,
    mi: u64,
    nr: u64,
    kj: u64,
    input: Residency,
    weight: Residency,
    output: Residency,
    charge: [u64; 3],
) {
    let input_resident = input.is_free();
    let weight_resident = weight.is_free();
    let output_resident = output.is_free();
    if s.scalar_traffic {
        // Naive: per-MAC operand fetches and psum writes (3·MNK).
        let macs = mi * nr * kj;
        dram.transfer(Stream::Input, charge[0] * macs);
        dram.transfer(Stream::Weight, charge[1] * macs);
        if s.store_out {
            // Final contraction step: its per-MAC writes complete the
            // output; account the last tile-depth as Output stream.
            dram.psum_write(charge[2] * macs.saturating_sub(mi * kj));
            dram.transfer(Stream::Output, charge[2] * mi * kj);
        } else {
            dram.psum_write(charge[2] * macs);
        }
        return;
    }
    if s.load_input && !input_resident {
        dram.transfer(Stream::Input, charge[0] * mi * nr);
    }
    if s.load_weight && !weight_resident {
        dram.transfer(Stream::Weight, charge[1] * nr * kj);
    }
    if s.psum_fetch {
        dram.psum_read(charge[2] * mi * kj);
    }
    if s.psum_spill {
        dram.psum_write(charge[2] * mi * kj);
    }
    if s.store_out && !output_resident {
        dram.transfer(Stream::Output, charge[2] * mi * kj);
    }
}

/// Replay `scheme` on `shape`/`tiling` over a fresh DRAM and count EMA.
pub fn simulate_ema(scheme: Scheme, shape: &GemmShape, tiling: &Tiling, dram: &mut Dram) -> SimEma {
    simulate_ema_plan(&Plan::from_scheme(scheme, shape, tiling), dram)
}

/// Replay any [`Plan`] (fixed scheme or per-tile TAS) and count EMA.
pub fn simulate_ema_plan(plan: &Plan, dram: &mut Dram) -> SimEma {
    let (shape, tiling) = (plan.shape, plan.tiling);
    let mut steps = 0u64;
    plan.for_each_step(|s| {
        steps += 1;
        let mi = tile_extent(shape.m, tiling.tm, s.i);
        let nr = tile_extent(shape.n, tiling.tn, s.r);
        let kj = tile_extent(shape.k, tiling.tk, s.j);
        charge_step(
            dram,
            &s,
            mi,
            nr,
            kj,
            plan.input_residency,
            plan.weight_residency,
            plan.output_residency,
        );
    });
    SimEma { stats: dram.stats(), steps }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{analytic, ema as analytic_ema};
    use crate::util::check::property;
    use crate::util::prng::Rng;

    fn run(scheme: Scheme, shape: &GemmShape, tiling: &Tiling) -> SimEma {
        let mut dram = Dram::new(16, 12);
        simulate_ema(scheme, shape, tiling, &mut dram)
    }

    /// THE central invariant: replayed counts == Table II closed forms,
    /// for every scheme, exact even on ragged shapes.
    #[test]
    fn sim_matches_analytic_exactly() {
        property("sim == analytic", 150, |rng: &mut Rng| {
            let shape = GemmShape::new(
                rng.gen_in(1, 300),
                rng.gen_in(1, 300),
                rng.gen_in(1, 300),
            );
            let t = Tiling::square(*rng.choose(&[4, 8, 16, 32]));
            for scheme in Scheme::FIXED {
                let sim = run(scheme, &shape, &t);
                let ana = analytic_ema(scheme, &shape, &t);
                assert_eq!(
                    sim.table2(),
                    (ana.input, ana.weight, ana.output),
                    "{scheme:?} on {shape:?}"
                );
            }
        });
    }

    #[test]
    fn sim_matches_analytic_with_psum_windows() {
        property("sim == analytic (windows)", 100, |rng: &mut Rng| {
            let shape = GemmShape::new(
                rng.gen_in(1, 300),
                rng.gen_in(1, 300),
                rng.gen_in(1, 300),
            );
            let t0 = Tiling::square(16);
            let kp = rng.gen_in(1, 8) * 16;
            let mp = rng.gen_in(1, 8) * 16;
            let t = Tiling { kp: Some(kp), mp: Some(mp), ..t0 };
            for scheme in [Scheme::IsOs, Scheme::WsOs, Scheme::Tas] {
                let sim = run(scheme, &shape, &t);
                let ana = analytic_ema(scheme, &shape, &t);
                assert_eq!(
                    sim.table2(),
                    (ana.input, ana.weight, ana.output),
                    "{scheme:?} on {shape:?} kp={kp} mp={mp}"
                );
            }
        });
    }

    /// The plan IR's closed-form EMA and the DRAM-charged replay are two
    /// independent accountings of the same step stream — they must agree
    /// for per-tile plans just as analytic/sim do for fixed schemes.
    #[test]
    fn plan_replay_matches_plan_closed_form() {
        use crate::dataflow::Plan;
        property("plan replay == closed form", 120, |rng: &mut Rng| {
            let shape = GemmShape::new(
                rng.gen_in(1, 250),
                rng.gen_in(1, 250),
                rng.gen_in(1, 250),
            );
            let t = *rng.choose(&[8u64, 16]);
            let tiling = Tiling::square(t)
                .with_kp(rng.gen_in(1, 5) * t)
                .with_mp(rng.gen_in(1, 5) * t);
            let plan = Plan::tas_per_tile(&shape, &tiling);
            let mut dram = Dram::new(16, 12);
            let sim = simulate_ema_plan(&plan, &mut dram);
            let e = plan.ema();
            assert_eq!(sim.table2(), (e.input, e.weight, e.output), "{shape:?}");
        });
    }

    #[test]
    fn naive_total_is_3mnk() {
        let shape = GemmShape::new(48, 32, 80);
        let sim = run(Scheme::Naive, &shape, &Tiling::square(16));
        assert_eq!(sim.total_words(), 3 * shape.macs());
    }

    /// §II-d: spilling schemes (IS/WS) interleave psum writes with operand
    /// reads — direction switches scale with step count.  The proposed
    /// hybrids only write when a psum window completes.
    #[test]
    fn hybrids_slash_direction_switches() {
        let shape = GemmShape::new(256, 256, 256);
        let t = Tiling::square(16);
        let is = run(Scheme::Is, &shape, &t).stats.direction_switches;
        let is_os = run(Scheme::IsOs, &shape, &t).stats.direction_switches;
        let ws = run(Scheme::Ws, &shape, &t).stats.direction_switches;
        let ws_os = run(Scheme::WsOs, &shape, &t).stats.direction_switches;
        assert!(is_os * 4 < is, "is {is} vs is-os {is_os}");
        assert!(ws_os * 4 < ws, "ws {ws} vs ws-os {ws_os}");
    }

    #[test]
    fn hybrids_have_zero_psum_readback() {
        let shape = GemmShape::new(128, 96, 160);
        let t = Tiling::square(16);
        for scheme in [Scheme::OsRow, Scheme::OsCol, Scheme::IsOs, Scheme::WsOs] {
            assert_eq!(run(scheme, &shape, &t).psum_readback_words(), 0);
        }
        assert!(run(Scheme::Is, &shape, &t).psum_readback_words() > 0);
        assert!(run(Scheme::Ws, &shape, &t).psum_readback_words() > 0);
    }

    #[test]
    fn tas_picks_smaller_total() {
        property("tas optimal in sim", 80, |rng: &mut Rng| {
            // divisible shapes: the sign rule is exactly the argmin
            let shape = GemmShape::new(
                rng.gen_in(1, 25) * 16,
                rng.gen_in(1, 25) * 16,
                rng.gen_in(1, 25) * 16,
            );
            let t = Tiling::square(16);
            let tas = run(Scheme::Tas, &shape, &t).total_words();
            let is_os = run(Scheme::IsOs, &shape, &t).total_words();
            let ws_os = run(Scheme::WsOs, &shape, &t).total_words();
            assert_eq!(tas, is_os.min(ws_os));
        });
    }

    #[test]
    fn decision_quantity_matches_table3_column() {
        // Table III's IS-WS column = MN - NK.
        let shape = GemmShape::new(115, 1024, 1024);
        let d = analytic::is_ws_difference(&shape);
        assert_eq!(d, 115 * 1024 - 1024 * 1024);
    }
}
