//! Closed-form strip costing: every cost sink priced in O(strips), not
//! O(tiles).
//!
//! A strip body's step stream is extremely regular: each strip runs `gn`
//! contraction **rounds**, every round visits the strip's tiles in the
//! same order with the same load flags, and only the first/last position
//! of a round (ragged edge) and the last round (ragged `nr`, stores) can
//! differ.  So each round folds into at most three *runs* of identical
//! steps, and a run of identical steps reaches a fixed point of the
//! replay state after one step — the walker below prices a run with two
//! state transitions no matter how many steps it contains.
//!
//! The replay state every sink actually carries across steps is tiny:
//! the DRAM bus direction (for §II-d turnaround switches) and the
//! previous step's compute window (for the DMA ‖ PE stall attribution of
//! [`super::pipeline`]).  Both are structure-determined after one step of
//! a run, which is what makes the fold exact rather than approximate:
//! [`plan_cost`] reproduces the fused replay ([`super::replay::fused_cost`])
//! **word-for-word and cycle-for-cycle** on strip bodies — pinned by the
//! property suite in `rust/tests/strip_closed_form.rs` and the replica
//! fuzzer, with `sim::replay` retained as the oracle.
//!
//! Fixed-scheme bodies (the planner's spilling-scheme fallback) have no
//! strip structure; [`plan_cost`] replays those through the original
//! sinks, so the closed forms never drift from the oracle on any body.
//!
//! One honest asymmetry: the bank/row-buffer cycle machine of
//! [`crate::arch::dram_timing`] walks real addresses and is *not* folded
//! — no planner consumes its cycle output, so [`StripTiming`] carries the
//! closed half (words, transactions, direction switches — all exact) and
//! leaves row-hit cycle counts to the replay-only reports.

use crate::arch::backend::{Backend, BackendParams};
use crate::arch::dram::{Dram, DramDir, DramStats};
use crate::arch::dram_timing::DramTimingConfig;
use crate::config::AcceleratorConfig;
use crate::dataflow::{Plan, PlanBody, Strip, StripKind};
use crate::energy::{EnergyCost, EnergyModel};
use crate::gemm::tile_extent;
use crate::sim::cycles::{cycles_from_parts_on, CycleEstimate};
use crate::sim::ema::SimEma;
use crate::sim::pipeline::{PipelineSink, PipelineStats};
use crate::sim::replay::{replay, CostSink, EmaSink, TimingSink};

/// The closed half of the transaction-level DRAM accounting: exact word,
/// transaction and direction-switch counts.  (Row-buffer hit/miss cycles
/// need the address-walking replay and stay with
/// [`crate::sim::simulate_dram_timing_plan`].)
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StripTiming {
    pub words: u64,
    pub transactions: u64,
    pub dir_switches: u64,
}

/// Every planner-facing cost sink for one plan, priced closed-form.
#[derive(Clone, Debug)]
pub struct StripCost {
    pub ema: SimEma,
    pub cycles: CycleEstimate,
    pub energy: EnergyCost,
    pub timing: StripTiming,
    /// Step-level DMA ‖ PE stall attribution, folded per run.
    pub pipeline: PipelineStats,
}

/// One step's gated DRAM transfers, in replay order (input read, weight
/// read, output write).  Residency gating is already applied: a resident
/// stream's words are zero, exactly like the sinks' `is_free()` guards
/// (tile extents are ≥ 1, so "flag set and not resident" ⇔ "words > 0").
#[derive(Clone, Copy, Debug, Default)]
struct StepXfer {
    input: u64,
    weight: u64,
    write: u64,
    macs: u64,
    /// DRAM transactions a DMA engine issues for this step: one per
    /// matrix row touched (`mi` for input/output, `nr` for weight), the
    /// granularity of [`crate::sim::dram_trace::charge_timing_step`].
    transactions: u64,
}

impl StepXfer {
    fn new(input: u64, weight: u64, write: u64, macs: u64, mi: u64, nr: u64) -> StepXfer {
        let transactions = (if input > 0 { mi } else { 0 })
            + (if weight > 0 { nr } else { 0 })
            + (if write > 0 { mi } else { 0 });
        StepXfer { input, weight, write, macs, transactions }
    }
}

/// The replay state that survives across steps — everything else the
/// sinks accumulate is additive.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct WalkState {
    last_dir: Option<DramDir>,
    prev_compute: u64,
}

/// Additive accumulators; a snapshot diff of this struct is the delta of
/// one folded round, which mid-round multiplication scales.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
struct Totals {
    input_words: u64,
    weight_words: u64,
    output_words: u64,
    switches: u64,
    steps: u64,
    macs: u64,
    transactions: u64,
    compute_cycles: u64,
    stall_cycles: u64,
    stalled_steps: u64,
}

impl Totals {
    fn diff(&self, before: &Totals) -> Totals {
        Totals {
            input_words: self.input_words - before.input_words,
            weight_words: self.weight_words - before.weight_words,
            output_words: self.output_words - before.output_words,
            switches: self.switches - before.switches,
            steps: self.steps - before.steps,
            macs: self.macs - before.macs,
            transactions: self.transactions - before.transactions,
            compute_cycles: self.compute_cycles - before.compute_cycles,
            stall_cycles: self.stall_cycles - before.stall_cycles,
            stalled_steps: self.stalled_steps - before.stalled_steps,
        }
    }

    fn add_scaled(&mut self, d: &Totals, times: u64) {
        self.input_words += d.input_words * times;
        self.weight_words += d.weight_words * times;
        self.output_words += d.output_words * times;
        self.switches += d.switches * times;
        self.steps += d.steps * times;
        self.macs += d.macs * times;
        self.transactions += d.transactions * times;
        self.compute_cycles += d.compute_cycles * times;
        self.stall_cycles += d.stall_cycles * times;
        self.stalled_steps += d.stalled_steps * times;
    }
}

/// What one closed walk yields: the EMA result, the pipeline stall
/// breakdown (one fill, like one replayed segment), the transaction count
/// and the MAC partial sum (a device slice's MACs are partial —
/// [`crate::sim::shard`]).
pub(crate) struct StripSummary {
    pub(crate) ema: SimEma,
    pub(crate) pipeline: PipelineStats,
    pub(crate) transactions: u64,
    pub(crate) macs: u64,
}

/// The compressed-run walker.  Feed it whole strips ([`fold_strip`] with
/// the full round range) or a device's round slice of a strip (the
/// contraction-sharded case routes rounds, not strips), in schedule
/// order; state carries across calls exactly as the replay's sinks carry
/// it across steps.
///
/// [`fold_strip`]: StripWalker::fold_strip
pub(crate) struct StripWalker {
    params: BackendParams,
    state: WalkState,
    totals: Totals,
}

impl StripWalker {
    pub(crate) fn new(cfg: &AcceleratorConfig) -> StripWalker {
        StripWalker::with_params(BackendParams::systolic(cfg))
    }

    /// A walker for any backend's parameter block — the systolic block
    /// reproduces [`StripWalker::new`] exactly.
    pub(crate) fn with_params(params: BackendParams) -> StripWalker {
        StripWalker {
            state: WalkState { last_dir: None, prev_compute: params.fill_latency },
            params,
            totals: Totals::default(),
        }
    }

    /// One step's (switches, stall, compute, next state), the transition
    /// every sink applies — [`crate::arch::Dram::record`]'s direction
    /// chain and [`PipelineSink`]'s overlap rule in closed form.
    fn step_delta(&self, state: WalkState, x: &StepXfer) -> (u64, u64, u64, WalkState) {
        let mut last = state.last_dir;
        let mut switches = 0u64;
        for (words, d) in [
            (x.input, DramDir::Read),
            (x.weight, DramDir::Read),
            (x.write, DramDir::Write),
        ] {
            if words > 0 {
                if last.is_some() && last != Some(d) {
                    switches += 1;
                }
                last = Some(d);
            }
        }
        let xfer = (x.input + x.weight + x.write).div_ceil(self.params.bandwidth)
            + switches * self.params.turnaround;
        let stall = xfer.saturating_sub(state.prev_compute);
        let compute = self.params.tile_cycles(x.macs) - self.params.fill_latency;
        (
            switches,
            stall,
            compute,
            WalkState { last_dir: last, prev_compute: compute.max(1) },
        )
    }

    fn apply(&mut self, switches: u64, stall: u64, compute: u64, times: u64) {
        self.totals.switches += switches * times;
        self.totals.compute_cycles += compute * times;
        if stall > 0 {
            self.totals.stall_cycles += stall * times;
            self.totals.stalled_steps += times;
        }
    }

    /// Fold `count` identical steps.  Step 2 starts from step 1's exit
    /// state and — because the steps are identical — exits in that same
    /// state, so steps 2..count all contribute step 2's delta.
    fn fold_run(&mut self, x: &StepXfer, count: u64) {
        if count == 0 {
            return;
        }
        self.totals.input_words += x.input * count;
        self.totals.weight_words += x.weight * count;
        self.totals.output_words += x.write * count;
        self.totals.macs += x.macs * count;
        self.totals.transactions += x.transactions * count;
        self.totals.steps += count;
        let (sw, stall, compute, next) = self.step_delta(self.state, x);
        self.apply(sw, stall, compute, 1);
        self.state = next;
        if count > 1 {
            let (sw2, stall2, compute2, next2) = self.step_delta(self.state, x);
            debug_assert_eq!(next2, self.state, "identical-step run must be a fixed point");
            self.apply(sw2, stall2, compute2, count - 1);
            self.state = next2;
        }
    }

    /// One contraction round of a strip: ≤ 3 runs.  The first position
    /// carries the stationary load (IS: the input tile; WS: the weight
    /// tile); interior positions are full tiles by construction (only the
    /// last grid row/column is ragged); the last position re-resolves its
    /// ragged extent.  `store` marks the final round (`r + 1 == gn`).
    fn fold_round(&mut self, plan: &Plan, strip: &Strip, nr: u64, store: bool) {
        let (shape, t) = (plan.shape, plan.tiling);
        // Residency gating × the backend's per-operand charge: a parked
        // operand streams zero words, and so does an operand the backend
        // never streams (crossbar weights).
        let gi = self.params.charge[0] * u64::from(!plan.input_residency.is_free());
        let gw = self.params.charge[1] * u64::from(!plan.weight_residency.is_free());
        let go = self.params.charge[2] * u64::from(!plan.output_residency.is_free());
        let out = |mi: u64, kj: u64| if store { go * mi * kj } else { 0 };
        match strip.kind {
            StripKind::InputStationary => {
                let mi = tile_extent(shape.m, t.tm, strip.i0);
                let w = strip.j1 - strip.j0;
                let kj0 = tile_extent(shape.k, t.tk, strip.j0);
                let first =
                    StepXfer::new(gi * mi * nr, gw * nr * kj0, out(mi, kj0), mi * nr * kj0, mi, nr);
                self.fold_run(&first, 1);
                if w >= 2 {
                    let kj1 = tile_extent(shape.k, t.tk, strip.j1 - 1);
                    self.fold_run(
                        &StepXfer::new(0, gw * nr * t.tk, out(mi, t.tk), mi * nr * t.tk, mi, nr),
                        w - 2,
                    );
                    self.fold_run(
                        &StepXfer::new(0, gw * nr * kj1, out(mi, kj1), mi * nr * kj1, mi, nr),
                        1,
                    );
                }
            }
            StripKind::WeightStationary => {
                let kj = tile_extent(shape.k, t.tk, strip.j0);
                let h = strip.i1 - strip.i0;
                let mi0 = tile_extent(shape.m, t.tm, strip.i0);
                let first =
                    StepXfer::new(gi * mi0 * nr, gw * nr * kj, out(mi0, kj), mi0 * nr * kj, mi0, nr);
                self.fold_run(&first, 1);
                if h >= 2 {
                    let mi1 = tile_extent(shape.m, t.tm, strip.i1 - 1);
                    self.fold_run(
                        &StepXfer::new(gi * t.tm * nr, 0, out(t.tm, kj), t.tm * nr * kj, t.tm, nr),
                        h - 2,
                    );
                    self.fold_run(
                        &StepXfer::new(gi * mi1 * nr, 0, out(mi1, kj), mi1 * nr * kj, mi1, nr),
                        1,
                    );
                }
            }
        }
    }

    /// Fold contraction rounds `[r_lo, r_hi)` of one strip.  Whole strips
    /// use `(0, gn)`; a contraction-sharded device folds only its round
    /// range.  All rounds before `gn - 1` are identical (full `tn`, no
    /// stores) and fold as round 0 + round 1 × (mids − 1) — round 1's exit
    /// state is debug-asserted to be round 0's, the round-level fixed
    /// point that makes the multiplication exact.
    pub(crate) fn fold_strip(&mut self, plan: &Plan, strip: &Strip, r_lo: u64, r_hi: u64) {
        let (_, gn, _) = plan.tiling.grid(&plan.shape);
        debug_assert!(r_lo <= r_hi && r_hi <= gn);
        let mids = r_hi.min(gn - 1).saturating_sub(r_lo);
        if mids >= 1 {
            self.fold_round(plan, strip, plan.tiling.tn, false);
            if mids >= 2 {
                let before = self.totals;
                let state0 = self.state;
                self.fold_round(plan, strip, plan.tiling.tn, false);
                debug_assert_eq!(self.state, state0, "mid rounds must reach a fixed point");
                let delta = self.totals.diff(&before);
                self.totals.add_scaled(&delta, mids - 2);
            }
        }
        if r_hi == gn && r_lo < r_hi {
            let nr = tile_extent(plan.shape.n, plan.tiling.tn, gn - 1);
            self.fold_round(plan, strip, nr, true);
        }
    }

    /// Fold a whole strip cover in schedule order.
    pub(crate) fn fold_plan(&mut self, plan: &Plan, strips: &[Strip]) {
        let (_, gn, _) = plan.tiling.grid(&plan.shape);
        for strip in strips {
            self.fold_strip(plan, strip, 0, gn);
        }
    }

    pub(crate) fn finish(self) -> StripSummary {
        let stats = DramStats {
            input_read_words: self.totals.input_words,
            weight_read_words: self.totals.weight_words,
            psum_read_words: 0,
            psum_write_words: 0,
            output_write_words: self.totals.output_words,
            direction_switches: self.totals.switches,
        };
        let pipeline = PipelineStats {
            steps: self.totals.steps,
            compute_cycles: self.totals.compute_cycles,
            stall_cycles: self.totals.stall_cycles,
            stalled_steps: self.totals.stalled_steps,
            fills: 1,
            total_cycles: self.params.fill_latency
                + self.totals.compute_cycles
                + self.totals.stall_cycles,
        };
        StripSummary {
            ema: SimEma { stats, steps: self.totals.steps },
            pipeline,
            transactions: self.totals.transactions,
            macs: self.totals.macs,
        }
    }
}

/// One strip's share of a plan's DRAM traffic, plus the stationary
/// margin — the `tas explain` ledger row ([`crate::report::explain`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StripShare {
    /// Stationary orientation the planner chose for this strip.
    pub kind: StripKind,
    /// Output tiles the strip covers.
    pub tiles: u64,
    /// Gated DRAM words the strip charges over the full contraction
    /// (input reads, weight reads, output writes) — residency-gated
    /// exactly like the full walk, so the shares sum to [`plan_cost`]'s
    /// EMA word-for-word.
    pub input_words: u64,
    pub weight_words: u64,
    pub output_words: u64,
    /// Words the same tile rectangle charges with its stationary reuse
    /// broken: the rectangle re-covered by single-tile strips of the
    /// *opposite* orientation, which reload the formerly-stationary
    /// operand at every tile.  Always ≥ the chosen words.
    pub flipped_words: u64,
}

impl StripShare {
    /// Total gated words the strip charges.
    pub fn words(&self) -> u64 {
        self.input_words + self.weight_words + self.output_words
    }

    /// Sign-rule margin: words saved by keeping the chosen operand
    /// stationary across the strip instead of re-covering its tiles in
    /// the flipped orientation.  Non-negative by construction.
    pub fn margin_words(&self) -> u64 {
        self.flipped_words.saturating_sub(self.words())
    }
}

/// Per-strip attribution of one plan's EMA: each strip priced by a fresh
/// walker over its full round range.  Word accumulation in the walker is
/// additive and state-free (only direction switches and stalls carry
/// state, and those are not attributed), so the shares sum to the whole
/// plan's EMA **word-for-word**, residency gating included — pinned by
/// `strip_shares_sum_to_plan_cost` below and the ledger property suite.
///
/// Fixed-scheme bodies have no strip structure and return an empty vec;
/// callers fall back to [`crate::dataflow::Plan::ema`] for those.
pub fn attribute_strips(plan: &Plan, cfg: &AcceleratorConfig) -> Vec<StripShare> {
    attribute_strips_on(plan, BackendParams::systolic(cfg))
}

/// [`attribute_strips`] for any backend's parameter block.
pub fn attribute_strips_on(plan: &Plan, params: BackendParams) -> Vec<StripShare> {
    let strips = match &plan.body {
        PlanBody::Strips(s) => s,
        PlanBody::Fixed(_) => return Vec::new(),
    };
    let (_, gn, _) = plan.tiling.grid(&plan.shape);
    strips
        .iter()
        .map(|strip| {
            let mut chosen = StripWalker::with_params(params);
            chosen.fold_strip(plan, strip, 0, gn);
            let (i, w, o) = chosen.finish().ema.table2();

            // The flipped re-cover: single-tile strips of the opposite
            // kind.  O(1) per tile (fold_strip compresses rounds), so the
            // whole attribution is O(tiles), acceptable for a report path.
            let flipped_kind = match strip.kind {
                StripKind::InputStationary => StripKind::WeightStationary,
                StripKind::WeightStationary => StripKind::InputStationary,
            };
            let mut flipped = StripWalker::with_params(params);
            for ti in strip.i0..strip.i1 {
                for tj in strip.j0..strip.j1 {
                    let tile = Strip {
                        kind: flipped_kind,
                        i0: ti,
                        i1: ti + 1,
                        j0: tj,
                        j1: tj + 1,
                    };
                    flipped.fold_strip(plan, &tile, 0, gn);
                }
            }
            let (fi, fw, fo) = flipped.finish().ema.table2();
            StripShare {
                kind: strip.kind,
                tiles: strip.tiles(),
                input_words: i,
                weight_words: w,
                output_words: o,
                flipped_words: fi + fw + fo,
            }
        })
        .collect()
}

/// Closed-form EMA + pipeline pair for one plan — the cheap inner query
/// of the cycle model ([`crate::sim::cycles::estimate_cycles_plan`]) and
/// the decode trajectory accumulator ([`crate::sim::decode`]).  Fixed
/// bodies fall back to the replay sinks, so the pair is exact for every
/// plan body.
pub fn plan_ema_pipeline(plan: &Plan, cfg: &AcceleratorConfig) -> (SimEma, PipelineStats) {
    plan_ema_pipeline_on(plan, BackendParams::systolic(cfg))
}

/// [`plan_ema_pipeline`] for any backend's parameter block.
pub fn plan_ema_pipeline_on(plan: &Plan, params: BackendParams) -> (SimEma, PipelineStats) {
    match &plan.body {
        PlanBody::Strips(strips) => {
            let mut walker = StripWalker::with_params(params);
            walker.fold_plan(plan, strips);
            let s = walker.finish();
            (s.ema, s.pipeline)
        }
        PlanBody::Fixed(_) => {
            let mut ema_sink =
                EmaSink::with_charge(Dram::new(params.bandwidth, params.turnaround), params.charge);
            let mut pipeline_sink = PipelineSink::with_params(params);
            {
                let sinks: &mut [&mut dyn CostSink] = &mut [&mut ema_sink, &mut pipeline_sink];
                replay(plan, sinks);
            }
            (ema_sink.finish(), pipeline_sink.finish())
        }
    }
}

/// Closed-form [`SimEma`] for one plan (replay fallback on fixed bodies).
pub fn plan_sim_ema(plan: &Plan, cfg: &AcceleratorConfig) -> SimEma {
    plan_sim_ema_on(plan, BackendParams::systolic(cfg))
}

/// [`plan_sim_ema`] for any backend's parameter block.
pub fn plan_sim_ema_on(plan: &Plan, params: BackendParams) -> SimEma {
    match &plan.body {
        PlanBody::Strips(strips) => {
            let mut walker = StripWalker::with_params(params);
            walker.fold_plan(plan, strips);
            walker.finish().ema
        }
        PlanBody::Fixed(_) => {
            let mut ema_sink =
                EmaSink::with_charge(Dram::new(params.bandwidth, params.turnaround), params.charge);
            {
                let sinks: &mut [&mut dyn CostSink] = &mut [&mut ema_sink];
                replay(plan, sinks);
            }
            ema_sink.finish()
        }
    }
}

/// Price one plan through every sink: O(strips) closed forms for strip
/// bodies, the fused replay for fixed bodies.  The strip-body result is
/// bit-identical to [`crate::sim::replay::fused_cost`] on the shared
/// fields (EMA, cycles, energy, pipeline; timing words/transactions/
/// switches) — `rust/tests/strip_closed_form.rs` pins it.
pub fn plan_cost(plan: &Plan, cfg: &AcceleratorConfig, energy: &EnergyModel) -> StripCost {
    plan_cost_with(plan, BackendParams::systolic(cfg), energy, DramTimingConfig::default())
}

/// [`plan_cost`] on any backend: walker parameters, energy table and
/// timing hook all come from the trait.
pub fn plan_cost_on(plan: &Plan, backend: &dyn Backend) -> StripCost {
    plan_cost_with(plan, backend.params(), &backend.energy(), backend.timing_config())
}

fn plan_cost_with(
    plan: &Plan,
    params: BackendParams,
    energy: &EnergyModel,
    timing_cfg: DramTimingConfig,
) -> StripCost {
    match &plan.body {
        PlanBody::Strips(strips) => {
            let mut walker = StripWalker::with_params(params);
            walker.fold_plan(plan, strips);
            let s = walker.finish();
            debug_assert_eq!(s.macs, plan.shape.macs(), "strip cover must tile the grid");
            let cycles = cycles_from_parts_on(plan.shape.macs(), &s.ema, &params);
            let (i, w, o) = s.ema.table2();
            StripCost {
                cycles,
                energy: energy.plan_energy(plan, i + w + o),
                timing: StripTiming {
                    words: s.ema.stats.total_words(),
                    transactions: s.transactions,
                    dir_switches: s.ema.stats.direction_switches,
                },
                pipeline: s.pipeline,
                ema: s.ema,
            }
        }
        PlanBody::Fixed(_) => replayed_cost_with(plan, params, energy, timing_cfg),
    }
}

/// The replay-backed oracle: the same sinks [`plan_cost`] folds, driven
/// step by step.  Public so the property suites and the throughput bench
/// compare against exactly this path.
pub fn replayed_cost(plan: &Plan, cfg: &AcceleratorConfig, energy: &EnergyModel) -> StripCost {
    replayed_cost_with(plan, BackendParams::systolic(cfg), energy, DramTimingConfig::default())
}

/// [`replayed_cost`] on any backend — the oracle [`plan_cost_on`] must
/// match word-for-word on strip bodies.
pub fn replayed_cost_on(plan: &Plan, backend: &dyn Backend) -> StripCost {
    replayed_cost_with(plan, backend.params(), &backend.energy(), backend.timing_config())
}

fn replayed_cost_with(
    plan: &Plan,
    params: BackendParams,
    energy: &EnergyModel,
    timing_cfg: DramTimingConfig,
) -> StripCost {
    let mut ema_sink =
        EmaSink::with_charge(Dram::new(params.bandwidth, params.turnaround), params.charge);
    let mut timing_sink = TimingSink::with_charge(plan, timing_cfg, params.charge);
    let mut pipeline_sink = PipelineSink::with_params(params);
    {
        let sinks: &mut [&mut dyn CostSink] =
            &mut [&mut ema_sink, &mut timing_sink, &mut pipeline_sink];
        replay(plan, sinks);
    }
    let ema = ema_sink.finish();
    let timing = timing_sink.finish();
    let cycles = cycles_from_parts_on(plan.shape.macs(), &ema, &params);
    let (i, w, o) = ema.table2();
    StripCost {
        cycles,
        energy: energy.plan_energy(plan, i + w + o),
        timing: StripTiming {
            words: timing.words,
            transactions: timing.transactions,
            dir_switches: timing.dir_switches,
        },
        pipeline: pipeline_sink.finish(),
        ema,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::Residency;
    use crate::gemm::{GemmShape, Tiling};
    use crate::util::check::property;
    use crate::util::prng::Rng;

    fn cfg() -> AcceleratorConfig {
        AcceleratorConfig::default()
    }

    fn rand_tiling(rng: &mut Rng) -> Tiling {
        let t = *rng.choose(&[4u64, 8, 16]);
        let mut tiling = Tiling::square(t);
        if rng.gen_range(2) == 0 {
            tiling = tiling.with_kp(rng.gen_in(1, 6) * t);
        }
        if rng.gen_range(2) == 0 {
            tiling = tiling.with_mp(rng.gen_in(1, 6) * t);
        }
        tiling
    }

    fn assert_closed_matches_replayed(plan: &Plan) {
        let cfg = cfg();
        let em = EnergyModel::default();
        let closed = plan_cost(plan, &cfg, &em);
        let oracle = replayed_cost(plan, &cfg, &em);
        assert_eq!(closed.ema, oracle.ema, "{:?}", plan.shape);
        assert_eq!(closed.cycles, oracle.cycles, "{:?}", plan.shape);
        assert_eq!(closed.pipeline, oracle.pipeline, "{:?}", plan.shape);
        assert_eq!(closed.timing, oracle.timing, "{:?}", plan.shape);
        assert!((closed.energy.total_pj() - oracle.energy.total_pj()).abs() < 1e-6);
    }

    #[test]
    fn closed_cost_matches_replay_on_random_ragged_shapes() {
        property("strip closed == replayed", 120, |rng: &mut Rng| {
            let shape = GemmShape::new(
                rng.gen_in(1, 260),
                rng.gen_in(1, 260),
                rng.gen_in(1, 260),
            );
            let tiling = rand_tiling(rng);
            assert_closed_matches_replayed(&Plan::tas_per_tile(&shape, &tiling));
        });
    }

    #[test]
    fn closed_cost_matches_replay_under_residency() {
        let combos = [
            (Residency::Full, Residency::None, Residency::None),
            (Residency::None, Residency::Full, Residency::None),
            (Residency::None, Residency::None, Residency::Full),
            (Residency::Full, Residency::Full, Residency::None),
            (Residency::Full, Residency::None, Residency::Full),
            (Residency::Full, Residency::Full, Residency::Full),
        ];
        property("strip closed == replayed (residency)", 80, |rng: &mut Rng| {
            let shape = GemmShape::new(
                rng.gen_in(1, 200),
                rng.gen_in(1, 200),
                rng.gen_in(1, 200),
            );
            let tiling = rand_tiling(rng);
            let (i, w, o) = *rng.choose(&combos);
            assert_closed_matches_replayed(&Plan::tas_cached(&shape, &tiling, i, w, o));
        });
    }

    #[test]
    fn fixed_bodies_fall_back_to_the_fused_replay() {
        use crate::dataflow::Scheme;
        let shape = GemmShape::new(96, 128, 160);
        let tiling = Tiling::square(16);
        let cfg = cfg();
        let em = EnergyModel::default();
        for scheme in crate::dataflow::Scheme::FIXED.iter().chain([Scheme::Tas].iter()) {
            let plan = Plan::from_scheme(*scheme, &shape, &tiling);
            let cost = plan_cost(&plan, &cfg, &em);
            let fused = crate::sim::replay::fused_cost(
                &plan,
                &cfg,
                &em,
                DramTimingConfig::default(),
            );
            assert_eq!(cost.ema, fused.ema, "{scheme:?}");
            assert_eq!(cost.cycles, fused.cycles, "{scheme:?}");
            assert_eq!(cost.pipeline, fused.pipeline, "{scheme:?}");
            assert_eq!(cost.timing.words, fused.timing.words, "{scheme:?}");
            assert_eq!(cost.timing.transactions, fused.timing.transactions, "{scheme:?}");
            assert_eq!(cost.timing.dir_switches, fused.timing.dir_switches, "{scheme:?}");
        }
    }

    #[test]
    fn ema_pair_agrees_with_plan_closed_form() {
        // plan_ema_pipeline's word counts must equal Plan::ema — two
        // independent closed forms of the same stream.
        property("walker ema == Plan::ema", 80, |rng: &mut Rng| {
            let shape = GemmShape::new(
                rng.gen_in(1, 220),
                rng.gen_in(1, 220),
                rng.gen_in(1, 220),
            );
            let tiling = rand_tiling(rng);
            let plan = Plan::tas_per_tile(&shape, &tiling);
            let (sim, pipeline) = plan_ema_pipeline(&plan, &cfg());
            let e = plan.ema();
            if let PlanBody::Strips(_) = plan.body {
                assert_eq!(sim.table2(), (e.input, e.weight, e.output), "{shape:?}");
            }
            assert_eq!(sim.steps, plan.step_count());
            assert_eq!(pipeline.steps, plan.step_count());
            assert_eq!(
                pipeline.total_cycles,
                cfg().pe_array().fill_latency + pipeline.compute_cycles + pipeline.stall_cycles
            );
        });
    }

    #[test]
    fn strip_shares_sum_to_plan_cost() {
        // The ledger invariant: per-strip attribution must re-add to the
        // plan's closed-form EMA word-for-word, residency included.
        let combos = [
            (Residency::None, Residency::None, Residency::None),
            (Residency::Full, Residency::None, Residency::None),
            (Residency::None, Residency::Full, Residency::None),
            (Residency::None, Residency::None, Residency::Full),
            (Residency::Full, Residency::Full, Residency::Full),
        ];
        property("Σ strip shares == plan_cost", 80, |rng: &mut Rng| {
            let shape = GemmShape::new(
                rng.gen_in(1, 220),
                rng.gen_in(1, 220),
                rng.gen_in(1, 220),
            );
            let tiling = rand_tiling(rng);
            let (i, w, o) = *rng.choose(&combos);
            let plan = Plan::tas_cached(&shape, &tiling, i, w, o);
            let shares = attribute_strips(&plan, &cfg());
            let cost = plan_cost(&plan, &cfg(), &EnergyModel::default());
            let (ci, cw, co) = cost.ema.table2();
            let si: u64 = shares.iter().map(|s| s.input_words).sum();
            let sw: u64 = shares.iter().map(|s| s.weight_words).sum();
            let so: u64 = shares.iter().map(|s| s.output_words).sum();
            if let PlanBody::Strips(_) = plan.body {
                assert_eq!((si, sw, so), (ci, cw, co), "{shape:?}");
                // margins never negative, and the flipped cover is an
                // upper bound tile by tile
                for s in &shares {
                    assert!(s.flipped_words >= s.words(), "{shape:?}");
                }
            } else {
                assert!(shares.is_empty());
            }
        });
    }

    #[test]
    fn walker_folds_partial_round_ranges_exactly() {
        // Fold a strip as [0, split) + [split, gn) with state carried —
        // must equal the whole-strip fold (the contraction-shard path).
        property("split rounds == whole strip", 60, |rng: &mut Rng| {
            let shape = GemmShape::new(
                rng.gen_in(1, 150),
                rng.gen_in(32, 200),
                rng.gen_in(1, 150),
            );
            let tiling = rand_tiling(rng);
            let plan = Plan::tas_strips(&shape, &tiling);
            let strips = match &plan.body {
                PlanBody::Strips(s) => s.clone(),
                PlanBody::Fixed(_) => unreachable!("tas_strips never falls back"),
            };
            let (_, gn, _) = tiling.grid(&shape);
            let split = rng.gen_range(gn + 1);
            let mut whole = StripWalker::new(&cfg());
            let mut parts = StripWalker::new(&cfg());
            for strip in &strips {
                whole.fold_strip(&plan, strip, 0, gn);
                parts.fold_strip(&plan, strip, 0, split);
                parts.fold_strip(&plan, strip, split, gn);
            }
            let (a, b) = (whole.finish(), parts.finish());
            assert_eq!(a.ema, b.ema);
            assert_eq!(a.pipeline, b.pipeline);
            assert_eq!(a.transactions, b.transactions);
            assert_eq!(a.macs, b.macs);
        });
    }
}
