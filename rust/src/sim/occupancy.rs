//! Internal-capacity measurement: peak live partial sums and SRAM tile
//! residency per schedule — the quantitative form of §III-B's argument
//! that plain IS/WS need up to K (resp. M) psums while the hybrids cap
//! the live set at the k'/m' window.

use crate::dataflow::{Plan, Scheme};
use crate::gemm::{tile_extent, GemmShape, Tiling};
use std::collections::HashSet;

/// Peak internal-resource usage of one schedule replay.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Occupancy {
    /// Peak live partial-sum words (register-file demand).
    pub peak_psum_words: u64,
    /// Peak resident operand-tile words (SRAM demand: one stationary tile
    /// + one streaming tile double-buffered).
    pub peak_sram_words: u64,
}

/// Replay and measure internal occupancy (no capacity enforcement; use
/// the result to check a [`crate::config::AcceleratorConfig`]).
pub fn measure_occupancy(scheme: Scheme, shape: &GemmShape, tiling: &Tiling) -> Occupancy {
    measure_occupancy_plan(&Plan::from_scheme(scheme, shape, tiling))
}

/// Occupancy of any [`Plan`] — per-tile TAS strip covers must respect the
/// same k'/m' psum-register caps as the fixed hybrids.
pub fn measure_occupancy_plan(plan: &Plan) -> Occupancy {
    let (shape, tiling) = (plan.shape, plan.tiling);
    let mut live: HashSet<(u64, u64)> = HashSet::new();
    let mut live_words = 0u64;
    let mut occ = Occupancy::default();
    plan.for_each_step(|s| {
        let mi = tile_extent(shape.m, tiling.tm, s.i);
        let nr = tile_extent(shape.n, tiling.tn, s.r);
        let kj = tile_extent(shape.k, tiling.tk, s.j);
        // Psum tile (i, j) becomes live on first touch.
        if live.insert((s.i, s.j)) {
            live_words += mi * kj;
        }
        occ.peak_psum_words = occ.peak_psum_words.max(live_words);
        // Spill or final store retires the live tile.
        if s.psum_spill || s.store_out {
            if live.remove(&(s.i, s.j)) {
                live_words -= mi * kj;
            }
        }
        // SRAM: one input tile + one weight tile, double-buffered so the
        // next fetch overlaps compute.
        let sram = 2 * (mi * nr + nr * kj);
        occ.peak_sram_words = occ.peak_sram_words.max(sram);
    });
    occ
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unwindowed_is_reuse_needs_full_output_row() {
        // §III-B: exploiting IS reuse *without spilling* keeps a whole
        // output row of psums (m × K words) — that is IS-OS with k' = K.
        // It grows with K, which is why the k' window exists.
        let t = Tiling::square(16); // kp = None -> k' = K
        let small = measure_occupancy(Scheme::IsOs, &GemmShape::new(32, 64, 64), &t);
        let big = measure_occupancy(Scheme::IsOs, &GemmShape::new(32, 64, 1024), &t);
        assert_eq!(small.peak_psum_words, 16 * 64);
        assert_eq!(big.peak_psum_words, 16 * 1024);
    }

    #[test]
    fn unwindowed_ws_reuse_needs_full_output_col() {
        let t = Tiling::square(16); // mp = None -> m' = M
        let big = measure_occupancy(Scheme::WsOs, &GemmShape::new(2048, 64, 32), &t);
        assert_eq!(big.peak_psum_words, 2048 * 16);
    }

    #[test]
    fn spilling_is_holds_one_tile_but_pays_dram() {
        // Plain IS avoids the register blow-up by spilling psums to DRAM
        // every contraction step — the §II-d concurrent read/write cost.
        let t = Tiling::square(16);
        let occ = measure_occupancy(Scheme::Is, &GemmShape::new(32, 64, 1024), &t);
        assert_eq!(occ.peak_psum_words, 16 * 16);
    }

    #[test]
    fn hybrid_windows_cap_psum_demand() {
        let t = Tiling::square(16).with_kp(64).with_mp(64);
        let shape = GemmShape::new(1024, 64, 1024);
        let is_os = measure_occupancy(Scheme::IsOs, &shape, &t);
        let ws_os = measure_occupancy(Scheme::WsOs, &shape, &t);
        // k'·m = 64·16, m'·k = 64·16 — independent of M, N, K.
        assert_eq!(is_os.peak_psum_words, 64 * 16);
        assert_eq!(ws_os.peak_psum_words, 64 * 16);
    }

    #[test]
    fn os_keeps_exactly_one_tile() {
        let t = Tiling::square(16);
        let occ = measure_occupancy(Scheme::OsRow, &GemmShape::new(256, 256, 256), &t);
        assert_eq!(occ.peak_psum_words, 16 * 16);
    }

    #[test]
    fn naive_holds_at_most_one_tile() {
        let t = Tiling::square(8);
        let occ = measure_occupancy(Scheme::Naive, &GemmShape::new(64, 64, 64), &t);
        assert_eq!(occ.peak_psum_words, 8 * 8);
    }
}
