//! Roofline analysis: where each stationary scheme sits relative to the
//! accelerator's compute and memory roofs.
//!
//! The paper's claim in roofline terms: a linear projection's MAC count
//! is fixed, so the *only* lever is EMA — the scheme moves arithmetic
//! intensity (MACs / DRAM word).  TAS pushes every projection to the
//! compute-bound side of the ridge when any fixed scheme would leave
//! short-or-long sequences memory-bound.

use crate::config::AcceleratorConfig;
use crate::dataflow::{ema, Scheme};
use crate::gemm::{GemmShape, Tiling};

/// One scheme's roofline position for one GEMM.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RooflinePoint {
    /// MACs per DRAM word moved.
    pub arithmetic_intensity: f64,
    /// Attainable MACs/cycle = min(peak, AI × bandwidth).
    pub attainable_macs_per_cycle: f64,
    /// Fraction of the PE array's peak.
    pub efficiency: f64,
    /// True when AI clears the ridge point (compute-bound).
    pub compute_bound: bool,
}

/// Ridge point of the machine: peak MACs/cycle ÷ words/cycle.
pub fn ridge_intensity(cfg: &AcceleratorConfig) -> f64 {
    let peak = (cfg.pe_dim * cfg.pe_dim) as f64;
    peak / cfg.dram_bandwidth as f64
}

/// Roofline position of `scheme` on `shape`.
pub fn roofline(scheme: Scheme, shape: &GemmShape, tiling: &Tiling, cfg: &AcceleratorConfig) -> RooflinePoint {
    let words = ema(scheme, shape, tiling).total().max(1) as f64;
    let ai = shape.macs() as f64 / words;
    let peak = (cfg.pe_dim * cfg.pe_dim) as f64;
    let attainable = peak.min(ai * cfg.dram_bandwidth as f64);
    RooflinePoint {
        arithmetic_intensity: ai,
        attainable_macs_per_cycle: attainable,
        efficiency: attainable / peak,
        compute_bound: ai >= ridge_intensity(cfg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AcceleratorConfig {
        // 16×16 PEs with an HBM-ish 32 words/cycle: ridge = 8 MACs/word.
        // (The hybrids' AI ≈ tile edge = 16, so a balanced design wants
        // the ridge below that — exactly the co-design argument.)
        AcceleratorConfig { dram_bandwidth: 32, ..AcceleratorConfig::default() }
    }

    #[test]
    fn ridge_point_value() {
        assert_eq!(ridge_intensity(&cfg()), 256.0 / 32.0);
        assert_eq!(ridge_intensity(&AcceleratorConfig::default()), 16.0);
    }

    #[test]
    fn hybrid_intensity_approaches_tile_edge() {
        // AI(IS-OS) ≈ m: the weight stream dominates at MNK/((M/m)·NK).
        let shape = GemmShape::new(384, 768, 768);
        let p = roofline(Scheme::Tas, &shape, &Tiling::square(16), &cfg());
        assert!((15.0..=16.0).contains(&p.arithmetic_intensity), "{}", p.arithmetic_intensity);
    }

    #[test]
    fn naive_is_always_memory_bound() {
        // AI(naive) = MNK / 3MNK = 1/3 << ridge
        let shape = GemmShape::new(512, 768, 768);
        let p = roofline(Scheme::Naive, &shape, &Tiling::square(16), &cfg());
        assert!((p.arithmetic_intensity - 1.0 / 3.0).abs() < 1e-9);
        assert!(!p.compute_bound);
        assert!(p.efficiency < 0.05);
    }

    #[test]
    fn tas_reaches_compute_bound_on_paper_workloads() {
        // BERT-Base qkv at mean length: TAS must clear the ridge.
        let shape = GemmShape::new(384, 768, 768);
        let p = roofline(Scheme::Tas, &shape, &Tiling::square(16), &cfg());
        assert!(p.compute_bound, "AI = {}", p.arithmetic_intensity);
        assert_eq!(p.efficiency, 1.0);
    }

    #[test]
    fn wrong_fixed_scheme_stays_memory_bound_where_tas_escapes() {
        // Long sequence, IS is the wrong choice (M >= K): its weight
        // re-reads push AI below the ridge while TAS (-> WS-OS) clears it.
        let shape = GemmShape::new(15000, 1024, 1024);
        let t = Tiling::square(16);
        let is = roofline(Scheme::Is, &shape, &t, &cfg());
        let tas = roofline(Scheme::Tas, &shape, &t, &cfg());
        // IS's psum spills halve its intensity (below the ridge = 8);
        // TAS (-> WS-OS) nearly doubles it and clears the ridge.
        assert!(tas.arithmetic_intensity > 1.5 * is.arithmetic_intensity);
        assert!(tas.compute_bound && !is.compute_bound);
    }

    #[test]
    fn efficiency_monotone_in_intensity() {
        let shape = GemmShape::new(384, 768, 3072);
        let t = Tiling::square(16);
        let order = [Scheme::Naive, Scheme::Ws, Scheme::Tas];
        let effs: Vec<f64> = order
            .iter()
            .map(|s| roofline(*s, &shape, &t, &cfg()).efficiency)
            .collect();
        assert!(effs[0] <= effs[1] && effs[1] <= effs[2], "{effs:?}");
    }
}
