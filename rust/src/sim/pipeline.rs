//! Step-level pipeline timing: double-buffered compute/transfer overlap.
//!
//! The aggregate model in [`super::cycles`] overlaps *totals*; this model
//! walks the schedule step by step the way the accelerator's DMA +PE
//! pipeline would: while the PE array computes tile pass *t*, the DMA
//! prefetches the operands of pass *t+1*; a step stalls when its transfer
//! (including the §II-d read↔write turnaround) outlasts the previous
//! step's compute.  This resolves *where* the stalls land — the spilling
//! schemes stall on every psum round-trip, the hybrids only at window
//! boundaries — which the aggregate max() model cannot show.
//!
//! The walk is a [`CostSink`] over the fused single-pass replay
//! ([`super::replay`]): stall attribution rides the same step stream as
//! EMA/cycles/energy/timing, so per-tile TAS plans — and each device's
//! slice of a sharded plan ([`super::shard`]) — get stall breakdowns for
//! free.  [`simulate_pipeline`] keeps the standalone entry point.

use crate::arch::dram::DramDir;
use crate::arch::PeArray;
use crate::config::AcceleratorConfig;
use crate::dataflow::{Plan, Scheme};
use crate::gemm::{GemmShape, Tiling};
use crate::sim::replay::{replay, CostSink, StepCtx};

/// Per-step pipeline statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PipelineStats {
    pub steps: u64,
    /// Cycles the PE array was computing.
    pub compute_cycles: u64,
    /// Cycles the PE array sat idle waiting for transfers.
    pub stall_cycles: u64,
    /// Steps that stalled at all.
    pub stalled_steps: u64,
    /// Total latency (compute + stalls + pipeline fill).
    pub total_cycles: u64,
}

impl PipelineStats {
    pub fn stall_fraction(&self) -> f64 {
        if self.total_cycles == 0 {
            0.0
        } else {
            self.stall_cycles as f64 / self.total_cycles as f64
        }
    }

    /// Effective PE utilisation over the run.
    pub fn utilization(&self, shape: &GemmShape, cfg: &AcceleratorConfig) -> f64 {
        if self.total_cycles == 0 {
            return 0.0;
        }
        let peak = (cfg.pe_dim * cfg.pe_dim) as f64;
        shape.macs() as f64 / (self.total_cycles as f64 * peak)
    }
}

/// Pipeline backend for the fused replay: two-stage (DMA ‖ PE) overlap
/// with read↔write turnaround, resolved per step.
pub struct PipelineSink {
    pe: PeArray,
    bw: u64,
    turn: u64,
    last_dir: Option<DramDir>,
    /// Compute time of the previous step, which the current step's
    /// transfer overlaps against (primed with the pipeline prologue).
    prev_compute: u64,
    stats: PipelineStats,
}

impl PipelineSink {
    pub fn new(cfg: &AcceleratorConfig) -> PipelineSink {
        let pe = cfg.pe_array();
        PipelineSink {
            prev_compute: pe.fill_latency,
            pe,
            bw: cfg.dram_bandwidth,
            turn: cfg.dram_turnaround,
            last_dir: None,
            stats: PipelineStats::default(),
        }
    }

    pub fn finish(self) -> PipelineStats {
        let mut stats = self.stats;
        stats.total_cycles = self.pe.fill_latency + stats.compute_cycles + stats.stall_cycles;
        stats
    }
}

impl CostSink for PipelineSink {
    fn on_step(&mut self, ctx: &StepCtx) {
        let s = &ctx.step;
        let (mi, nr, kj) = (ctx.mi, ctx.nr, ctx.kj);

        // --- transfer phase for this step ---------------------------------
        let mut read_words = 0u64;
        let mut write_words = 0u64;
        let mut switches = 0u64;
        let last_dir = &mut self.last_dir;
        let mut dir = |d: DramDir, sw: &mut u64| {
            if last_dir.is_some() && *last_dir != Some(d) {
                *sw += 1;
            }
            *last_dir = Some(d);
        };
        if s.scalar_traffic {
            let macs = mi * nr * kj;
            read_words += 2 * macs;
            dir(DramDir::Read, &mut switches);
            write_words += macs;
            dir(DramDir::Write, &mut switches);
        } else {
            if s.load_input && !ctx.plan.input_residency.is_free() {
                read_words += mi * nr;
                dir(DramDir::Read, &mut switches);
            }
            if s.load_weight && !ctx.plan.weight_residency.is_free() {
                read_words += nr * kj;
                dir(DramDir::Read, &mut switches);
            }
            if s.psum_fetch {
                read_words += mi * kj;
                dir(DramDir::Read, &mut switches);
            }
            if s.psum_spill || (s.store_out && !ctx.plan.output_residency.is_free()) {
                write_words += mi * kj;
                dir(DramDir::Write, &mut switches);
            }
        }
        let xfer = (read_words + write_words).div_ceil(self.bw) + switches * self.turn;

        // --- overlap against the previous step's compute -------------------
        let stall = xfer.saturating_sub(self.prev_compute);
        if stall > 0 {
            self.stats.stall_cycles += stall;
            self.stats.stalled_steps += 1;
        }

        let compute = self.pe.tile_cycles(mi * nr * kj) - self.pe.fill_latency;
        self.stats.compute_cycles += compute;
        self.stats.steps += 1;
        self.prev_compute = compute.max(1);
    }
}

/// Walk the schedule through the two-stage (DMA ‖ PE) pipeline.
pub fn simulate_pipeline(
    scheme: Scheme,
    shape: &GemmShape,
    tiling: &Tiling,
    cfg: &AcceleratorConfig,
) -> PipelineStats {
    simulate_pipeline_plan(&Plan::from_scheme(scheme, shape, tiling), cfg)
}

/// Pipeline timing of any [`Plan`] (fixed scheme or per-tile TAS), via
/// the fused replay's sink interface.
pub fn simulate_pipeline_plan(plan: &Plan, cfg: &AcceleratorConfig) -> PipelineStats {
    let mut sink = PipelineSink::new(cfg);
    {
        let sinks: &mut [&mut dyn CostSink] = &mut [&mut sink];
        replay(plan, sinks);
    }
    sink.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AcceleratorConfig {
        AcceleratorConfig::default()
    }

    fn run(scheme: Scheme, shape: &GemmShape) -> PipelineStats {
        simulate_pipeline(scheme, shape, &Tiling::square(16), &cfg())
    }

    #[test]
    fn hybrids_stall_less_than_spilling_parents() {
        let shape = GemmShape::new(512, 512, 512);
        let is = run(Scheme::Is, &shape);
        let is_os = run(Scheme::IsOs, &shape);
        assert!(is_os.stall_cycles < is.stall_cycles,
                "{} vs {}", is_os.stall_cycles, is.stall_cycles);
        assert!(is_os.total_cycles < is.total_cycles);
        let ws = run(Scheme::Ws, &shape);
        let ws_os = run(Scheme::WsOs, &shape);
        assert!(ws_os.stall_cycles < ws.stall_cycles);
    }

    #[test]
    fn communication_efficiency_roughly_doubles() {
        // §I: "nearly twice the efficiency compared to the previous fixed
        // stationary method" — utilisation of TAS vs the spilling WS.
        let shape = GemmShape::new(384, 768, 768);
        let fixed = run(Scheme::Ws, &shape).utilization(&shape, &cfg());
        let tas = run(Scheme::Tas, &shape).utilization(&shape, &cfg());
        assert!(tas / fixed > 1.5, "tas {tas:.3} vs fixed {fixed:.3}");
    }

    #[test]
    fn naive_is_transfer_bound() {
        let shape = GemmShape::new(128, 128, 128);
        let s = run(Scheme::Naive, &shape);
        assert!(s.stall_fraction() > 0.5, "{}", s.stall_fraction());
        assert!(s.utilization(&shape, &cfg()) < 0.2);
    }

    #[test]
    fn compute_cycles_scheme_independent() {
        let shape = GemmShape::new(256, 192, 320);
        let base = run(Scheme::OsRow, &shape).compute_cycles;
        for scheme in [Scheme::Is, Scheme::Ws, Scheme::IsOs, Scheme::WsOs] {
            assert_eq!(run(scheme, &shape).compute_cycles, base, "{scheme:?}");
        }
    }

    #[test]
    fn totals_consistent() {
        let shape = GemmShape::new(96, 96, 96);
        for scheme in Scheme::FIXED {
            let s = run(scheme, &shape);
            assert_eq!(
                s.total_cycles,
                cfg().pe_array().fill_latency + s.compute_cycles + s.stall_cycles
            );
            assert!(s.stalled_steps <= s.steps);
        }
    }

    #[test]
    fn per_tile_plans_get_stall_attribution() {
        // The sink consumes any Plan through the fused replay — including
        // mixed per-tile covers the old schedule-walking loop never saw.
        let shape = GemmShape::new(2048, 64, 65);
        let tiling = Tiling::square(16).with_kp(64).with_mp(32);
        let plan = Plan::tas_per_tile(&shape, &tiling);
        let stats = simulate_pipeline_plan(&plan, &cfg());
        assert_eq!(stats.steps, plan.step_count());
        assert!(stats.compute_cycles > 0);
        assert_eq!(
            stats.total_cycles,
            cfg().pe_array().fill_latency + stats.compute_cycles + stats.stall_cycles
        );
    }

    #[test]
    fn residency_reduces_transfer_stalls() {
        // A resident input removes its DRAM reads from the transfer phase:
        // stalls can only go down.
        let shape = GemmShape::new(384, 768, 768);
        let tiling = Tiling::square(16);
        use crate::dataflow::Residency;
        let base = simulate_pipeline_plan(&Plan::tas_per_tile(&shape, &tiling), &cfg());
        let resident = simulate_pipeline_plan(
            &Plan::tas_with_residency(&shape, &tiling, Residency::Full, Residency::None),
            &cfg(),
        );
        assert!(resident.stall_cycles <= base.stall_cycles);
    }
}
