//! Step-level pipeline timing: double-buffered compute/transfer overlap.
//!
//! The aggregate model in [`super::cycles`] overlaps *totals*; this model
//! walks the schedule step by step the way the accelerator's DMA +PE
//! pipeline would: while the PE array computes tile pass *t*, the DMA
//! prefetches the operands of pass *t+1*; a step stalls when its transfer
//! (including the §II-d read↔write turnaround) outlasts the previous
//! step's compute.  This resolves *where* the stalls land — the spilling
//! schemes stall on every psum round-trip, the hybrids only at window
//! boundaries — which the aggregate max() model cannot show.

use crate::arch::dram::DramDir;
use crate::config::AcceleratorConfig;
use crate::dataflow::{for_each_step, Scheme};
use crate::gemm::{tile_extent, GemmShape, Tiling};

/// Per-step pipeline statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PipelineStats {
    pub steps: u64,
    /// Cycles the PE array was computing.
    pub compute_cycles: u64,
    /// Cycles the PE array sat idle waiting for transfers.
    pub stall_cycles: u64,
    /// Steps that stalled at all.
    pub stalled_steps: u64,
    /// Total latency (compute + stalls + pipeline fill).
    pub total_cycles: u64,
}

impl PipelineStats {
    pub fn stall_fraction(&self) -> f64 {
        if self.total_cycles == 0 {
            0.0
        } else {
            self.stall_cycles as f64 / self.total_cycles as f64
        }
    }

    /// Effective PE utilisation over the run.
    pub fn utilization(&self, shape: &GemmShape, cfg: &AcceleratorConfig) -> f64 {
        if self.total_cycles == 0 {
            return 0.0;
        }
        let peak = (cfg.pe_dim * cfg.pe_dim) as f64;
        shape.macs() as f64 / (self.total_cycles as f64 * peak)
    }
}

/// Walk the schedule through the two-stage (DMA ‖ PE) pipeline.
pub fn simulate_pipeline(
    scheme: Scheme,
    shape: &GemmShape,
    tiling: &Tiling,
    cfg: &AcceleratorConfig,
) -> PipelineStats {
    let pe = cfg.pe_array();
    let bw = cfg.dram_bandwidth;
    let turn = cfg.dram_turnaround;
    let mut stats = PipelineStats::default();
    let mut last_dir: Option<DramDir> = None;

    // transfer time of the *next* step overlaps this step's compute: keep
    // the previous compute time and charge max(0, xfer - prev_compute).
    let mut prev_compute = pe.fill_latency; // pipeline prologue

    for_each_step(scheme, shape, tiling, |s| {
        let mi = tile_extent(shape.m, tiling.tm, s.i);
        let nr = tile_extent(shape.n, tiling.tn, s.r);
        let kj = tile_extent(shape.k, tiling.tk, s.j);

        // --- transfer phase for this step ---------------------------------
        let mut read_words = 0u64;
        let mut write_words = 0u64;
        let mut switches = 0u64;
        let mut dir = |d: DramDir, sw: &mut u64| {
            if last_dir.is_some() && last_dir != Some(d) {
                *sw += 1;
            }
            last_dir = Some(d);
        };
        if s.scalar_traffic {
            let macs = mi * nr * kj;
            read_words += 2 * macs;
            dir(DramDir::Read, &mut switches);
            write_words += macs;
            dir(DramDir::Write, &mut switches);
        } else {
            if s.load_input {
                read_words += mi * nr;
                dir(DramDir::Read, &mut switches);
            }
            if s.load_weight {
                read_words += nr * kj;
                dir(DramDir::Read, &mut switches);
            }
            if s.psum_fetch {
                read_words += mi * kj;
                dir(DramDir::Read, &mut switches);
            }
            if s.psum_spill || s.store_out {
                write_words += mi * kj;
                dir(DramDir::Write, &mut switches);
            }
        }
        let xfer = (read_words + write_words).div_ceil(bw) + switches * turn;

        // --- overlap against the previous step's compute -------------------
        let stall = xfer.saturating_sub(prev_compute);
        if stall > 0 {
            stats.stall_cycles += stall;
            stats.stalled_steps += 1;
        }

        let compute = pe.tile_cycles(mi * nr * kj) - pe.fill_latency;
        stats.compute_cycles += compute;
        stats.steps += 1;
        prev_compute = compute.max(1);
    });

    stats.total_cycles = pe.fill_latency + stats.compute_cycles + stats.stall_cycles;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AcceleratorConfig {
        AcceleratorConfig::default()
    }

    fn run(scheme: Scheme, shape: &GemmShape) -> PipelineStats {
        simulate_pipeline(scheme, shape, &Tiling::square(16), &cfg())
    }

    #[test]
    fn hybrids_stall_less_than_spilling_parents() {
        let shape = GemmShape::new(512, 512, 512);
        let is = run(Scheme::Is, &shape);
        let is_os = run(Scheme::IsOs, &shape);
        assert!(is_os.stall_cycles < is.stall_cycles,
                "{} vs {}", is_os.stall_cycles, is.stall_cycles);
        assert!(is_os.total_cycles < is.total_cycles);
        let ws = run(Scheme::Ws, &shape);
        let ws_os = run(Scheme::WsOs, &shape);
        assert!(ws_os.stall_cycles < ws.stall_cycles);
    }

    #[test]
    fn communication_efficiency_roughly_doubles() {
        // §I: "nearly twice the efficiency compared to the previous fixed
        // stationary method" — utilisation of TAS vs the spilling WS.
        let shape = GemmShape::new(384, 768, 768);
        let fixed = run(Scheme::Ws, &shape).utilization(&shape, &cfg());
        let tas = run(Scheme::Tas, &shape).utilization(&shape, &cfg());
        assert!(tas / fixed > 1.5, "tas {tas:.3} vs fixed {fixed:.3}");
    }

    #[test]
    fn naive_is_transfer_bound() {
        let shape = GemmShape::new(128, 128, 128);
        let s = run(Scheme::Naive, &shape);
        assert!(s.stall_fraction() > 0.5, "{}", s.stall_fraction());
        assert!(s.utilization(&shape, &cfg()) < 0.2);
    }

    #[test]
    fn compute_cycles_scheme_independent() {
        let shape = GemmShape::new(256, 192, 320);
        let base = run(Scheme::OsRow, &shape).compute_cycles;
        for scheme in [Scheme::Is, Scheme::Ws, Scheme::IsOs, Scheme::WsOs] {
            assert_eq!(run(scheme, &shape).compute_cycles, base, "{scheme:?}");
        }
    }

    #[test]
    fn totals_consistent() {
        let shape = GemmShape::new(96, 96, 96);
        for scheme in Scheme::FIXED {
            let s = run(scheme, &shape);
            assert_eq!(
                s.total_cycles,
                cfg().pe_array().fill_latency + s.compute_cycles + s.stall_cycles
            );
            assert!(s.stalled_steps <= s.steps);
        }
    }
}
