//! Step-level pipeline timing: double-buffered compute/transfer overlap.
//!
//! The aggregate model in [`super::cycles`] overlaps *totals*; this model
//! walks the schedule step by step the way the accelerator's DMA +PE
//! pipeline would: while the PE array computes tile pass *t*, the DMA
//! prefetches the operands of pass *t+1*; a step stalls when its transfer
//! (including the §II-d read↔write turnaround) outlasts the previous
//! step's compute.  This resolves *where* the stalls land — the spilling
//! schemes stall on every psum round-trip, the hybrids only at window
//! boundaries — which the aggregate max() model cannot show.
//!
//! The walk is a [`CostSink`] over the fused single-pass replay
//! ([`super::replay`]): stall attribution rides the same step stream as
//! EMA/cycles/energy/timing, so per-tile TAS plans — and each device's
//! slice of a sharded plan ([`super::shard`]) — get stall breakdowns for
//! free.  [`simulate_pipeline`] keeps the standalone entry point.
//!
//! Besides the DMA prefetch, a replayed step can carry a **third
//! stream**: inter-chip link rounds ([`LinkStream`], fed by the round
//! lists of [`crate::arch::Interconnect`]) drain behind the same per-step
//! compute windows the DMA overlaps against, so all-gather operand
//! traffic and tree-reduce psum payloads hide behind compute instead of
//! serializing after it (see [`super::shard`] / [`super::decode`]).
//!
//! Fill-latency convention: one pipeline fill is charged **per replay**
//! (per plan segment).  Multi-segment trajectories (decode stage slices,
//! per-device shard slices) charge one fill per segment instance — the
//! [`PipelineStats::fills`] counter makes the convention auditable, and
//! `total_cycles == fills·fill_latency + compute + stalls` is asserted at
//! both aggregation sites (`sim::decode`, `sim::shard`).

use crate::arch::backend::BackendParams;
use crate::arch::dram::DramDir;
use crate::arch::PeArray;
use crate::config::AcceleratorConfig;
use crate::dataflow::{Plan, Scheme};
use crate::gemm::{GemmShape, Tiling};
use crate::sim::replay::{replay, CostSink, StepCtx};

/// Per-step pipeline statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PipelineStats {
    pub steps: u64,
    /// Cycles the PE array was computing.
    pub compute_cycles: u64,
    /// Cycles the PE array sat idle waiting for transfers.
    pub stall_cycles: u64,
    /// Steps that stalled at all.
    pub stalled_steps: u64,
    /// Pipeline fills charged (one per replayed plan segment — see the
    /// module docs for the convention).
    pub fills: u64,
    /// Total latency (compute + stalls + pipeline fill).
    pub total_cycles: u64,
}

impl PipelineStats {
    pub fn stall_fraction(&self) -> f64 {
        if self.total_cycles == 0 {
            0.0
        } else {
            self.stall_cycles as f64 / self.total_cycles as f64
        }
    }

    /// Effective PE utilisation over the run.
    pub fn utilization(&self, shape: &GemmShape, cfg: &AcceleratorConfig) -> f64 {
        if self.total_cycles == 0 {
            return 0.0;
        }
        let peak = (cfg.pe_dim * cfg.pe_dim) as f64;
        shape.macs() as f64 / (self.total_cycles as f64 * peak)
    }
}

/// Pipeline backend for the fused replay: two-stage (DMA ‖ PE) overlap
/// with read↔write turnaround, resolved per step.
pub struct PipelineSink {
    params: BackendParams,
    last_dir: Option<DramDir>,
    /// Compute time of the previous step, which the current step's
    /// transfer overlaps against (primed with the pipeline prologue).
    prev_compute: u64,
    stats: PipelineStats,
}

impl PipelineSink {
    pub fn new(cfg: &AcceleratorConfig) -> PipelineSink {
        PipelineSink::with_params(BackendParams::systolic(cfg))
    }

    /// A pipeline sink for any backend's parameter block — the systolic
    /// block reproduces [`PipelineSink::new`] exactly.
    pub fn with_params(params: BackendParams) -> PipelineSink {
        PipelineSink {
            prev_compute: params.fill_latency,
            params,
            last_dir: None,
            stats: PipelineStats::default(),
        }
    }

    pub fn finish(self) -> PipelineStats {
        let mut stats = self.stats;
        stats.fills = 1;
        stats.total_cycles = self.params.fill_latency + stats.compute_cycles + stats.stall_cycles;
        stats
    }
}

impl CostSink for PipelineSink {
    fn on_step(&mut self, ctx: &StepCtx) {
        let s = &ctx.step;
        let (mi, nr, kj) = (ctx.mi, ctx.nr, ctx.kj);
        let charge = self.params.charge;

        // --- transfer phase for this step ---------------------------------
        // Words are residency-gated × backend-charged; a zero-word stream
        // touches neither the bus nor the direction chain, exactly like
        // the closed-form walker and the DRAM model.
        let mut read_words = 0u64;
        let mut write_words = 0u64;
        let mut switches = 0u64;
        let last_dir = &mut self.last_dir;
        let mut dir = |d: DramDir, sw: &mut u64| {
            if last_dir.is_some() && *last_dir != Some(d) {
                *sw += 1;
            }
            *last_dir = Some(d);
        };
        if s.scalar_traffic {
            let macs = mi * nr * kj;
            let r = (charge[0] + charge[1]) * macs;
            if r > 0 {
                read_words += r;
                dir(DramDir::Read, &mut switches);
            }
            let w = charge[2] * macs;
            if w > 0 {
                write_words += w;
                dir(DramDir::Write, &mut switches);
            }
        } else {
            if s.load_input && !ctx.plan.input_residency.is_free() && charge[0] > 0 {
                read_words += charge[0] * mi * nr;
                dir(DramDir::Read, &mut switches);
            }
            if s.load_weight && !ctx.plan.weight_residency.is_free() && charge[1] > 0 {
                read_words += charge[1] * nr * kj;
                dir(DramDir::Read, &mut switches);
            }
            if s.psum_fetch && charge[2] > 0 {
                read_words += charge[2] * mi * kj;
                dir(DramDir::Read, &mut switches);
            }
            if (s.psum_spill || (s.store_out && !ctx.plan.output_residency.is_free()))
                && charge[2] > 0
            {
                write_words += charge[2] * mi * kj;
                dir(DramDir::Write, &mut switches);
            }
        }
        let xfer = (read_words + write_words).div_ceil(self.params.bandwidth)
            + switches * self.params.turnaround;

        // --- overlap against the previous step's compute -------------------
        let stall = xfer.saturating_sub(self.prev_compute);
        if stall > 0 {
            self.stats.stall_cycles += stall;
            self.stats.stalled_steps += 1;
        }

        let compute = self.params.tile_cycles(mi * nr * kj) - self.params.fill_latency;
        self.stats.compute_cycles += compute;
        self.stats.steps += 1;
        self.prev_compute = compute.max(1);
    }
}

/// Drain state of one inter-chip round sequence against compute windows.
///
/// The rounds come from the [`crate::arch::Interconnect`] round lists
/// (ring all-gather shares, tree-reduce payloads); [`LinkSchedule::drain`]
/// hides up to one compute window's worth of link cycles per call, in
/// round order.  Whatever is left at the end is *exposed* link time the
/// shard (or decode step) pays after compute — the overlapped latency is
/// `busy + exposed` instead of the serialized `busy + total`.
#[derive(Clone, Debug)]
pub struct LinkSchedule {
    rounds: Vec<u64>,
    next: usize,
    done_in_round: u64,
    total: u64,
    hidden: u64,
}

impl LinkSchedule {
    pub fn new(rounds: Vec<u64>) -> LinkSchedule {
        let total = rounds.iter().sum();
        LinkSchedule { rounds, next: 0, done_in_round: 0, total, hidden: 0 }
    }

    /// Hide up to `window` cycles of link streaming behind one compute
    /// window (round by round; a round never outlives its own cycles).
    pub fn drain(&mut self, mut window: u64) {
        while window > 0 && self.next < self.rounds.len() {
            let left = self.rounds[self.next] - self.done_in_round;
            let take = left.min(window);
            self.done_in_round += take;
            self.hidden += take;
            window -= take;
            if self.done_in_round == self.rounds[self.next] {
                self.next += 1;
                self.done_in_round = 0;
            }
        }
    }

    /// Serialized link time: every round end to end.
    pub fn total_cycles(&self) -> u64 {
        self.total
    }

    /// Link cycles hidden behind the compute windows drained so far.
    pub fn hidden_cycles(&self) -> u64 {
        self.hidden
    }

    /// Link cycles still exposed (paid after compute).
    pub fn exposed_cycles(&self) -> u64 {
        self.total - self.hidden
    }
}

/// Third pipeline stream: inter-chip link rounds riding the fused replay.
///
/// Each replayed step contributes its MAC-burst window (tile compute
/// without fill) to the [`LinkSchedule`] drain, so link transfers hide
/// behind exactly the compute the device performs while they stream —
/// the step-granular counterpart of the aggregate overlap in
/// [`super::shard::ShardLatency`].  The greedy drain makes the total
/// hidden time `min(link total, Σ step windows)` regardless of round
/// granularity (property-pinned below).
pub struct LinkStream {
    pe: PeArray,
    schedule: LinkSchedule,
}

impl LinkStream {
    pub fn new(cfg: &AcceleratorConfig, rounds: Vec<u64>) -> LinkStream {
        LinkStream { pe: cfg.pe_array(), schedule: LinkSchedule::new(rounds) }
    }

    pub fn finish(self) -> LinkSchedule {
        self.schedule
    }
}

impl CostSink for LinkStream {
    fn on_step(&mut self, ctx: &StepCtx) {
        let macs = ctx.mi * ctx.nr * ctx.kj;
        let window = self.pe.tile_cycles(macs) - self.pe.fill_latency;
        self.schedule.drain(window);
    }
}

/// Walk the schedule through the two-stage (DMA ‖ PE) pipeline.
pub fn simulate_pipeline(
    scheme: Scheme,
    shape: &GemmShape,
    tiling: &Tiling,
    cfg: &AcceleratorConfig,
) -> PipelineStats {
    simulate_pipeline_plan(&Plan::from_scheme(scheme, shape, tiling), cfg)
}

/// Pipeline timing of any [`Plan`] (fixed scheme or per-tile TAS), via
/// the fused replay's sink interface.
pub fn simulate_pipeline_plan(plan: &Plan, cfg: &AcceleratorConfig) -> PipelineStats {
    let mut sink = PipelineSink::new(cfg);
    {
        let sinks: &mut [&mut dyn CostSink] = &mut [&mut sink];
        replay(plan, sinks);
    }
    sink.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AcceleratorConfig {
        AcceleratorConfig::default()
    }

    fn run(scheme: Scheme, shape: &GemmShape) -> PipelineStats {
        simulate_pipeline(scheme, shape, &Tiling::square(16), &cfg())
    }

    #[test]
    fn hybrids_stall_less_than_spilling_parents() {
        let shape = GemmShape::new(512, 512, 512);
        let is = run(Scheme::Is, &shape);
        let is_os = run(Scheme::IsOs, &shape);
        assert!(is_os.stall_cycles < is.stall_cycles,
                "{} vs {}", is_os.stall_cycles, is.stall_cycles);
        assert!(is_os.total_cycles < is.total_cycles);
        let ws = run(Scheme::Ws, &shape);
        let ws_os = run(Scheme::WsOs, &shape);
        assert!(ws_os.stall_cycles < ws.stall_cycles);
    }

    #[test]
    fn communication_efficiency_roughly_doubles() {
        // §I: "nearly twice the efficiency compared to the previous fixed
        // stationary method" — utilisation of TAS vs the spilling WS.
        let shape = GemmShape::new(384, 768, 768);
        let fixed = run(Scheme::Ws, &shape).utilization(&shape, &cfg());
        let tas = run(Scheme::Tas, &shape).utilization(&shape, &cfg());
        assert!(tas / fixed > 1.5, "tas {tas:.3} vs fixed {fixed:.3}");
    }

    #[test]
    fn naive_is_transfer_bound() {
        let shape = GemmShape::new(128, 128, 128);
        let s = run(Scheme::Naive, &shape);
        assert!(s.stall_fraction() > 0.5, "{}", s.stall_fraction());
        assert!(s.utilization(&shape, &cfg()) < 0.2);
    }

    #[test]
    fn compute_cycles_scheme_independent() {
        let shape = GemmShape::new(256, 192, 320);
        let base = run(Scheme::OsRow, &shape).compute_cycles;
        for scheme in [Scheme::Is, Scheme::Ws, Scheme::IsOs, Scheme::WsOs] {
            assert_eq!(run(scheme, &shape).compute_cycles, base, "{scheme:?}");
        }
    }

    #[test]
    fn totals_consistent() {
        let shape = GemmShape::new(96, 96, 96);
        for scheme in Scheme::FIXED {
            let s = run(scheme, &shape);
            assert_eq!(s.fills, 1, "one fill per replayed segment");
            assert_eq!(
                s.total_cycles,
                s.fills * cfg().pe_array().fill_latency + s.compute_cycles + s.stall_cycles
            );
            assert!(s.stalled_steps <= s.steps);
        }
    }

    #[test]
    fn link_stream_hides_min_of_link_and_compute() {
        // The greedy drain's total is min(link, Σ MAC windows), no matter
        // how the link cycles are cut into rounds.
        use crate::sim::replay::replay;
        let shape = GemmShape::new(130, 70, 90);
        let tiling = Tiling::square(16);
        let plan = Plan::tas_per_tile(&shape, &tiling);
        let cfg = cfg();
        let pe = cfg.pe_array();
        let mut mac_windows = 0u64;
        plan.for_each_step(|s| {
            use crate::gemm::tile_extent;
            let mi = tile_extent(shape.m, tiling.tm, s.i);
            let nr = tile_extent(shape.n, tiling.tn, s.r);
            let kj = tile_extent(shape.k, tiling.tk, s.j);
            mac_windows += pe.tile_cycles(mi * nr * kj) - pe.fill_latency;
        });
        for rounds in [
            vec![],
            vec![1u64],
            vec![517, 517, 517],
            vec![mac_windows + 10_000],
            vec![1; 97],
            vec![mac_windows / 2, 3, mac_windows],
        ] {
            let total: u64 = rounds.iter().sum();
            let mut link = LinkStream::new(&cfg, rounds);
            {
                let sinks: &mut [&mut dyn CostSink] = &mut [&mut link];
                replay(&plan, sinks);
            }
            let sched = link.finish();
            assert_eq!(sched.total_cycles(), total);
            assert_eq!(sched.hidden_cycles(), total.min(mac_windows));
            assert_eq!(
                sched.exposed_cycles(),
                total - total.min(mac_windows)
            );
        }
    }

    #[test]
    fn link_schedule_drains_round_by_round() {
        let mut s = LinkSchedule::new(vec![10, 5]);
        assert_eq!(s.total_cycles(), 15);
        s.drain(4);
        assert_eq!(s.hidden_cycles(), 4);
        s.drain(8); // finishes round 0, eats 2 of round 1
        assert_eq!(s.hidden_cycles(), 12);
        s.drain(100);
        assert_eq!(s.hidden_cycles(), 15);
        assert_eq!(s.exposed_cycles(), 0);
        s.drain(7); // nothing left
        assert_eq!(s.hidden_cycles(), 15);
    }

    #[test]
    fn per_tile_plans_get_stall_attribution() {
        // The sink consumes any Plan through the fused replay — including
        // mixed per-tile covers the old schedule-walking loop never saw.
        let shape = GemmShape::new(2048, 64, 65);
        let tiling = Tiling::square(16).with_kp(64).with_mp(32);
        let plan = Plan::tas_per_tile(&shape, &tiling);
        let stats = simulate_pipeline_plan(&plan, &cfg());
        assert_eq!(stats.steps, plan.step_count());
        assert!(stats.compute_cycles > 0);
        assert_eq!(
            stats.total_cycles,
            cfg().pe_array().fill_latency + stats.compute_cycles + stats.stall_cycles
        );
    }

    #[test]
    fn residency_reduces_transfer_stalls() {
        // A resident input removes its DRAM reads from the transfer phase:
        // stalls can only go down.
        let shape = GemmShape::new(384, 768, 768);
        let tiling = Tiling::square(16);
        use crate::dataflow::Residency;
        let base = simulate_pipeline_plan(&Plan::tas_per_tile(&shape, &tiling), &cfg());
        let resident = simulate_pipeline_plan(
            &Plan::tas_with_residency(&shape, &tiling, Residency::Full, Residency::None),
            &cfg(),
        );
        assert!(resident.stall_cycles <= base.stall_cycles);
    }
}
