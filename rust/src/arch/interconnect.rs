//! Inter-chip interconnect model for multi-accelerator sharding.
//!
//! When a [`crate::dataflow::Plan`] is partitioned across devices
//! ([`crate::dataflow::shard`]), operand words whose home device differs
//! from the consuming device cross a chip-to-chip link instead of staying
//! on the local DRAM bus.  The link carries the same cost algebra as DRAM
//! — bandwidth (words/cycle), a per-message latency, and an energy per
//! word — but with serving-scale ratios: inter-chip SerDes moves a word
//! slower and at higher energy than local DRAM ("Data Movement Is All You
//! Need", Ivanov et al.; multi-core data arrangement, Amirshahi et al.).
//!
//! Like the rest of [`crate::arch`], these types carry *capacities and
//! costs*; which words actually cross a link is decided by the shard
//! partition and accounted in [`crate::dataflow::shard`] /
//! [`crate::sim::shard`].

/// Link parameters shared by every chip-to-chip connection.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct InterconnectConfig {
    /// Link bandwidth in words/cycle (per direction).
    pub link_bandwidth: u64,
    /// Per-message latency in cycles (hop setup / SerDes).
    pub link_latency: u64,
    /// Energy per word crossing one link (pJ).
    pub link_energy_pj: f64,
}

impl Default for InterconnectConfig {
    fn default() -> Self {
        // Half the default DRAM bandwidth (16 w/cyc), 500-cycle hop
        // latency, 2x the default DRAM word energy (200 pJ): inter-chip
        // traffic is strictly worse than local DRAM, never free.
        InterconnectConfig { link_bandwidth: 8, link_latency: 500, link_energy_pj: 400.0 }
    }
}

impl InterconnectConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.link_bandwidth > 0, "link_bandwidth must be positive");
        anyhow::ensure!(self.link_energy_pj >= 0.0, "link_energy_pj must be non-negative");
        Ok(())
    }
}

/// The interconnect: link config + transfer-primitive cost formulas.
#[derive(Clone, Copy, Debug, Default)]
pub struct Interconnect {
    pub cfg: InterconnectConfig,
}

impl Interconnect {
    pub fn new(cfg: InterconnectConfig) -> Interconnect {
        Interconnect { cfg }
    }

    /// Streaming time of `words` over one link, without hop latency.
    pub fn stream_cycles(&self, words: u64) -> u64 {
        words.div_ceil(self.cfg.link_bandwidth)
    }

    /// Point-to-point transfer: one hop latency + streaming.
    pub fn p2p_cycles(&self, words: u64) -> u64 {
        if words == 0 {
            0
        } else {
            self.cfg.link_latency + self.stream_cycles(words)
        }
    }

    /// Ring all-gather of `words_per_device` from each of `devices`
    /// participants: D-1 rounds, each a p2p of one shard.
    pub fn all_gather_cycles(&self, words_per_device: u64, devices: u64) -> u64 {
        if devices <= 1 {
            0
        } else {
            (devices - 1) * self.p2p_cycles(words_per_device)
        }
    }

    /// Per-round cycle costs of the ring all-gather: D-1 rounds, each a
    /// p2p of one per-device share.  Sums to
    /// [`Interconnect::all_gather_cycles`] exactly; the round list is what
    /// the pipeline's link stream ([`crate::sim::pipeline::LinkStream`])
    /// drains behind compute windows.
    pub fn all_gather_rounds(&self, words_per_device: u64, devices: u64) -> Vec<u64> {
        if devices <= 1 {
            Vec::new()
        } else {
            vec![self.p2p_cycles(words_per_device); (devices - 1) as usize]
        }
    }

    /// Per-round cycle costs of the collective tree reduce: ceil(log2 D)
    /// rounds, each a p2p of the payload.  Sums to
    /// [`Interconnect::tree_reduce_cycles`] exactly.
    pub fn tree_reduce_rounds(&self, words: u64, devices: u64) -> Vec<u64> {
        if devices <= 1 || words == 0 {
            Vec::new()
        } else {
            let rounds = 64 - u64::leading_zeros(devices - 1) as u64;
            vec![self.p2p_cycles(words); rounds as usize]
        }
    }

    /// Tree reduce of `total_words` crossing links down to one device:
    /// ceil(log2 D) latency rounds, all words streamed once — the
    /// *serialized* model (every transfer shares one link).
    pub fn reduce_cycles(&self, total_words: u64, devices: u64) -> u64 {
        if devices <= 1 || total_words == 0 {
            0
        } else {
            let rounds = 64 - u64::leading_zeros(devices - 1) as u64;
            rounds * self.cfg.link_latency + self.stream_cycles(total_words)
        }
    }

    /// Collective tree reduce of one `words` payload per device: ceil(log2
    /// D) rounds, each round's pairwise transfers running on *disjoint
    /// links* in parallel, so every round costs one p2p of the payload.
    /// At 4+ devices this beats [`Interconnect::reduce_cycles`] fed the
    /// summed `(D-1)·words` traffic, which streams every copy serially.
    pub fn tree_reduce_cycles(&self, words: u64, devices: u64) -> u64 {
        if devices <= 1 || words == 0 {
            0
        } else {
            let rounds = 64 - u64::leading_zeros(devices - 1) as u64;
            rounds * self.p2p_cycles(words)
        }
    }

    /// Energy of `words` crossing links (each word counted once per hop).
    pub fn transfer_energy_pj(&self, words: u64) -> f64 {
        self.cfg.link_energy_pj * words as f64
    }

    /// Time cost of a link word relative to a local DRAM word at
    /// `dram_bandwidth` words/cycle — the weight the device-aware per-tile
    /// chooser applies to a remote-prone operand stream.
    pub fn remote_word_weight(&self, dram_bandwidth: u64) -> f64 {
        dram_bandwidth as f64 / self.cfg.link_bandwidth as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        InterconnectConfig::default().validate().unwrap();
    }

    #[test]
    fn zero_words_cost_nothing() {
        let icx = Interconnect::default();
        assert_eq!(icx.p2p_cycles(0), 0);
        assert_eq!(icx.reduce_cycles(0, 4), 0);
        assert_eq!(icx.all_gather_cycles(100, 1), 0);
        assert_eq!(icx.transfer_energy_pj(0), 0.0);
    }

    #[test]
    fn p2p_charges_latency_plus_stream() {
        let icx = Interconnect::new(InterconnectConfig {
            link_bandwidth: 8,
            link_latency: 500,
            link_energy_pj: 400.0,
        });
        assert_eq!(icx.p2p_cycles(80), 500 + 10);
        assert_eq!(icx.stream_cycles(81), 11);
    }

    #[test]
    fn reduce_rounds_are_logarithmic() {
        let icx = Interconnect::default();
        // 4 devices -> 2 latency rounds; 8 -> 3.
        let r4 = icx.reduce_cycles(8, 4);
        let r8 = icx.reduce_cycles(8, 8);
        assert_eq!(r4, 2 * 500 + 1);
        assert_eq!(r8, 3 * 500 + 1);
    }

    #[test]
    fn tree_reduce_parallelises_rounds() {
        let icx = Interconnect::default();
        let w = 100_000u64;
        // serialized model streams (D-1)·w once; tree streams w per round
        for d in [4u64, 8, 16] {
            let serial = icx.reduce_cycles((d - 1) * w, d);
            let tree = icx.tree_reduce_cycles(w, d);
            assert!(tree < serial, "d={d}: tree {tree} >= serial {serial}");
        }
        // two devices: one round, identical to a single p2p
        assert_eq!(icx.tree_reduce_cycles(w, 2), icx.p2p_cycles(w));
        assert_eq!(icx.tree_reduce_cycles(0, 8), 0);
        assert_eq!(icx.tree_reduce_cycles(w, 1), 0);
    }

    #[test]
    fn all_gather_scales_with_participants() {
        let icx = Interconnect::default();
        let one = icx.p2p_cycles(64);
        assert_eq!(icx.all_gather_cycles(64, 4), 3 * one);
    }

    #[test]
    fn round_lists_sum_to_the_closed_forms() {
        let icx = Interconnect::default();
        for d in [1u64, 2, 3, 4, 8, 16] {
            for w in [1u64, 64, 1000, 123_457] {
                let ag = icx.all_gather_rounds(w, d);
                assert_eq!(ag.iter().sum::<u64>(), icx.all_gather_cycles(w, d));
                assert_eq!(ag.len() as u64, d.saturating_sub(1));
                let tr = icx.tree_reduce_rounds(w, d);
                assert_eq!(tr.iter().sum::<u64>(), icx.tree_reduce_cycles(w, d));
            }
        }
        assert!(icx.tree_reduce_rounds(0, 8).is_empty());
        assert!(icx.all_gather_rounds(64, 1).is_empty());
    }

    #[test]
    fn remote_word_weight_tracks_bandwidth_ratio() {
        let icx = Interconnect::default();
        // 16 w/cyc DRAM vs 8 w/cyc link -> a link word costs 2x.
        assert_eq!(icx.remote_word_weight(16), 2.0);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = InterconnectConfig::default();
        c.link_bandwidth = 0;
        assert!(c.validate().is_err());
    }
}
