//! Bank/row-buffer DRAM timing model.
//!
//! §II-d's argument is *temporal*: "external memory like DRAM cannot read
//! and write data simultaneously", and interleaved psum spills stall the
//! bus.  The flat [`super::Dram`] counts words and direction switches;
//! this model adds the microarchitectural detail a memory-controller
//! engineer would ask about — banks, open rows, activate/precharge and
//! read↔write turnaround timing — so the stall claim can be quantified
//! in cycles rather than just switch counts.
//!
//! The model is transaction-level: each tile transfer becomes a burst of
//! column accesses at a matrix-resident address; a row miss pays
//! tRP + tRCD, a direction switch pays tWTR/tRTW, column accesses pipeline
//! at the burst rate.

use super::dram::DramDir;

/// Timing parameters in controller cycles (DDR4-ish ratios by default).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DramTimingConfig {
    pub n_banks: u64,
    /// Words per DRAM row (row-buffer size).
    pub row_words: u64,
    /// Activate-to-column delay.
    pub t_rcd: u64,
    /// Precharge delay.
    pub t_rp: u64,
    /// Column access latency (pipelined; charged once per burst).
    pub t_cas: u64,
    /// Write-to-read turnaround.
    pub t_wtr: u64,
    /// Read-to-write turnaround.
    pub t_rtw: u64,
    /// Words transferred per cycle once streaming.
    pub words_per_cycle: u64,
}

impl Default for DramTimingConfig {
    fn default() -> Self {
        DramTimingConfig {
            n_banks: 8,
            row_words: 1024, // 2 KB rows at 16-bit words
            t_rcd: 14,
            t_rp: 14,
            t_cas: 14,
            t_wtr: 8,
            t_rtw: 10,
            words_per_cycle: 8,
        }
    }
}

/// Accumulated timing statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DramTimingStats {
    pub transactions: u64,
    pub words: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub dir_switches: u64,
    pub cycles: u64,
}

impl DramTimingStats {
    pub fn row_hit_rate(&self) -> f64 {
        let total = self.row_hits + self.row_misses;
        if total == 0 {
            0.0
        } else {
            self.row_hits as f64 / total as f64
        }
    }

    /// Effective bandwidth in words/cycle.
    pub fn effective_bandwidth(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.words as f64 / self.cycles as f64
        }
    }
}

/// The timing model: open-row state per bank + last transfer direction.
#[derive(Clone, Debug)]
pub struct DramTiming {
    pub cfg: DramTimingConfig,
    open_rows: Vec<Option<u64>>,
    last_dir: Option<DramDir>,
    stats: DramTimingStats,
}

impl DramTiming {
    pub fn new(cfg: DramTimingConfig) -> Self {
        assert!(cfg.n_banks > 0 && cfg.row_words > 0 && cfg.words_per_cycle > 0);
        DramTiming {
            open_rows: vec![None; cfg.n_banks as usize],
            last_dir: None,
            cfg,
            stats: DramTimingStats::default(),
        }
    }

    /// Process one transaction: `words` contiguous words at `addr`
    /// (word-granular address) moving in `dir`.
    pub fn access(&mut self, dir: DramDir, addr: u64, words: u64) {
        if words == 0 {
            return;
        }
        self.stats.transactions += 1;
        self.stats.words += words;

        // direction turnaround
        if let Some(last) = self.last_dir {
            if last != dir {
                self.stats.dir_switches += 1;
                self.stats.cycles += match dir {
                    DramDir::Read => self.cfg.t_wtr,  // was writing
                    DramDir::Write => self.cfg.t_rtw, // was reading
                };
            }
        }
        self.last_dir = Some(dir);

        // walk the row spans the burst touches
        let mut remaining = words;
        let mut cur = addr;
        while remaining > 0 {
            let row = cur / self.cfg.row_words;
            let bank = (row % self.cfg.n_banks) as usize;
            let row_end = (row + 1) * self.cfg.row_words;
            let chunk = remaining.min(row_end - cur);
            if self.open_rows[bank] == Some(row) {
                self.stats.row_hits += 1;
            } else {
                self.stats.row_misses += 1;
                let penalty = if self.open_rows[bank].is_some() {
                    self.cfg.t_rp + self.cfg.t_rcd
                } else {
                    self.cfg.t_rcd
                };
                self.stats.cycles += penalty;
                self.open_rows[bank] = Some(row);
            }
            // one CAS per row span, then streaming
            self.stats.cycles += self.cfg.t_cas
                + chunk.div_ceil(self.cfg.words_per_cycle);
            cur += chunk;
            remaining -= chunk;
        }
    }

    pub fn stats(&self) -> DramTimingStats {
        self.stats
    }
}

/// Word-granular base addresses for the three matrices of a GEMM,
/// row-major, padded to DRAM row boundaries so matrices never share rows.
#[derive(Clone, Copy, Debug)]
pub struct MatrixLayout {
    pub input_base: u64,
    pub weight_base: u64,
    pub output_base: u64,
    /// Leading dimension (words per matrix row) of each matrix.
    pub input_ld: u64,
    pub weight_ld: u64,
    pub output_ld: u64,
}

impl MatrixLayout {
    pub fn for_gemm(shape: &crate::gemm::GemmShape, cfg: &DramTimingConfig) -> Self {
        let align = |x: u64| x.div_ceil(cfg.row_words) * cfg.row_words;
        let input_base = 0;
        let weight_base = align(shape.m * shape.n);
        let output_base = weight_base + align(shape.n * shape.k);
        MatrixLayout {
            input_base,
            weight_base,
            output_base,
            input_ld: shape.n,
            weight_ld: shape.k,
            output_ld: shape.k,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> DramTiming {
        DramTiming::new(DramTimingConfig::default())
    }

    #[test]
    fn sequential_stream_hits_rows() {
        let mut m = model();
        // read 4 full rows sequentially: 4 misses (first touch), rest hits
        m.access(DramDir::Read, 0, 4 * 1024);
        let s = m.stats();
        assert_eq!(s.row_misses, 4);
        assert_eq!(s.row_hits, 0);
        assert_eq!(s.words, 4096);
        // re-read the last row: hit
        m.access(DramDir::Read, 3 * 1024, 1024);
        assert_eq!(m.stats().row_hits, 1);
    }

    #[test]
    fn direction_switches_cost_cycles() {
        let mut a = model();
        a.access(DramDir::Read, 0, 64);
        a.access(DramDir::Read, 64, 64);
        let read_only = a.stats().cycles;
        let mut b = model();
        b.access(DramDir::Read, 0, 64);
        b.access(DramDir::Write, 1 << 20, 64);
        assert_eq!(b.stats().dir_switches, 1);
        assert!(b.stats().cycles > read_only);
    }

    #[test]
    fn row_conflict_pays_precharge() {
        let cfg = DramTimingConfig { n_banks: 1, ..Default::default() };
        let mut m = DramTiming::new(cfg);
        m.access(DramDir::Read, 0, 16); // opens row 0
        let after_first = m.stats().cycles;
        m.access(DramDir::Read, 1024, 16); // row 1, same bank: precharge+activate
        let delta = m.stats().cycles - after_first;
        assert_eq!(delta, cfg.t_rp + cfg.t_rcd + cfg.t_cas + 2);
    }

    #[test]
    fn effective_bandwidth_below_peak() {
        let mut m = model();
        for i in 0..100 {
            m.access(DramDir::Read, i * 3000, 64); // scattered: many misses
        }
        let bw = m.stats().effective_bandwidth();
        assert!(bw > 0.0 && bw < m.cfg.words_per_cycle as f64);
    }

    #[test]
    fn layout_separates_matrices() {
        let shape = crate::gemm::GemmShape::new(100, 200, 300);
        let cfg = DramTimingConfig::default();
        let l = MatrixLayout::for_gemm(&shape, &cfg);
        assert!(l.weight_base >= shape.m * shape.n);
        assert_eq!(l.weight_base % cfg.row_words, 0);
        assert!(l.output_base >= l.weight_base + shape.n * shape.k);
    }

    #[test]
    fn zero_word_access_is_noop() {
        let mut m = model();
        m.access(DramDir::Write, 0, 0);
        assert_eq!(m.stats(), DramTimingStats::default());
    }
}
