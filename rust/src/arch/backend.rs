//! Pluggable accelerator backends behind one trait.
//!
//! The paper argues TAS for one hardware point — a square systolic array fed
//! by SRAM over a half-duplex DRAM — but the claim is about *data movement*,
//! which should hold (or degenerate, informatively) across accelerator
//! styles.  [`Backend`] abstracts the four things every planner and cost sink
//! actually consumes:
//!
//! * tile-pass compute cycles ([`BackendParams::tile_cycles`]),
//! * per-operand word charges for the streamed-traffic model
//!   ([`BackendParams::charge`] / [`PlanPricing`]),
//! * residency capacity classes ([`Backend::residency_words`]) and the
//!   one-time weight *program* cost for backends with non-volatile
//!   stationary storage ([`Backend::program_words`]),
//! * the external-memory timing hook ([`Backend::timing_config`]) and the
//!   interconnect handle ([`Backend::interconnect`]).
//!
//! [`SystolicBackend`] reproduces the original PE/SRAM/DRAM stack
//! word-for-word; [`CrossbarBackend`] is an X-Former-style in-memory
//! crossbar where weights are programmed once into NVM tiles and only
//! activations and outputs move at run time.  The stationary sign rule and
//! the residency knapsack see the difference *by pricing, not by special
//! case*: a zero weight charge makes every cover activation-stationary on
//! its own.

use crate::arch::dram_timing::DramTimingConfig;
use crate::arch::interconnect::Interconnect;
use crate::config::{AcceleratorConfig, EnergyConfig};
use crate::energy::EnergyModel;

/// Fixed-point scale for the planner's per-word stream prices.  Must match
/// the scale `dataflow::Plan` uses internally for its cover chooser (a unit
/// test in `dataflow::plan` pins the two together).
pub const PRICE_SCALE: u64 = 256;

/// Operand indices into a `charge` triple: `[input, weight, output]`.
pub const OP_INPUT: usize = 0;
/// See [`OP_INPUT`].
pub const OP_WEIGHT: usize = 1;
/// See [`OP_INPUT`].
pub const OP_OUTPUT: usize = 2;

/// Which hardware model a plan was priced for.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum BackendKind {
    /// The paper's square systolic array + SRAM + half-duplex DRAM.
    #[default]
    Systolic,
    /// X-Former-style in-memory NVM crossbar: weights programmed once,
    /// activations streamed, psums accumulated at the array periphery.
    Crossbar,
}

impl BackendKind {
    /// Every backend the build knows about, in id order.
    pub const ALL: [BackendKind; 2] = [BackendKind::Systolic, BackendKind::Crossbar];

    /// Stable short name, used by the CLI, TOML, and the plan-db spec key.
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Systolic => "systolic",
            BackendKind::Crossbar => "crossbar",
        }
    }

    /// Stable numeric id for canonical spec keys.
    pub fn id(&self) -> u64 {
        match self {
            BackendKind::Systolic => 0,
            BackendKind::Crossbar => 1,
        }
    }

    /// Parse a CLI/TOML/plan-db name.
    pub fn from_name(name: &str) -> anyhow::Result<BackendKind> {
        for kind in BackendKind::ALL {
            if kind.name() == name {
                return Ok(kind);
            }
        }
        anyhow::bail!(
            "unknown backend '{name}' (expected one of: systolic, crossbar)"
        )
    }

    /// The planner pricing this backend kind implies.
    pub fn pricing(&self) -> PlanPricing {
        match self {
            BackendKind::Systolic => PlanPricing::systolic(),
            BackendKind::Crossbar => PlanPricing::crossbar(),
        }
    }

    /// Inverse of [`BackendKind::id`].
    pub fn from_id(id: u64) -> anyhow::Result<BackendKind> {
        for kind in BackendKind::ALL {
            if kind.id() == id {
                return Ok(kind);
            }
        }
        anyhow::bail!("unknown backend id {id}")
    }
}

/// The copyable parameter block the cycle/pipeline walkers consume.
///
/// `charge[op]` is the number of external words actually moved per logical
/// word of operand `op`; the systolic backend charges `[1, 1, 1]`, the
/// crossbar `[1, 0, 1]` because programmed weights never stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BackendParams {
    /// Cycles to fill the compute fabric before a tile pass drains
    /// (systolic skew, or crossbar DAC setup + sample/hold).
    pub fill_latency: u64,
    /// Sustained MACs per cycle once filled.
    pub macs_per_cycle: u64,
    /// External-memory words per cycle (DRAM bus, or activation bus).
    pub bandwidth: u64,
    /// Cycles lost on a read<->write direction switch.
    pub turnaround: u64,
    /// Per-operand word multipliers `[input, weight, output]`.
    pub charge: [u64; 3],
}

impl BackendParams {
    /// The identity parameters for the paper's systolic stack: exactly what
    /// `PeArray` + the raw `AcceleratorConfig` fields used to provide.
    pub fn systolic(cfg: &AcceleratorConfig) -> BackendParams {
        let pe = cfg.pe_array();
        BackendParams {
            fill_latency: pe.fill_latency,
            macs_per_cycle: pe.macs_per_cycle(),
            bandwidth: cfg.dram_bandwidth,
            turnaround: cfg.dram_turnaround,
            charge: [1, 1, 1],
        }
    }

    /// Cycles for one tile pass of `macs` MACs (fill + drain).  Mirrors
    /// `PeArray::tile_cycles` so the systolic path is bit-identical.
    pub fn tile_cycles(&self, macs: u64) -> u64 {
        self.fill_latency + macs.div_ceil(self.macs_per_cycle)
    }
}

/// The planner-facing prices the stationary sign rule and the residency
/// knapsack consume: per-word stream prices in [`PRICE_SCALE`] units plus
/// the same charge triple the walkers use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanPricing {
    /// Price of re-reading one input word, in [`PRICE_SCALE`] units.
    pub wi: u64,
    /// Price of re-reading one weight word, in [`PRICE_SCALE`] units.
    pub ww: u64,
    /// Per-operand word multipliers `[input, weight, output]`.
    pub charge: [u64; 3],
}

impl PlanPricing {
    /// Unit prices: both streams cost one external word per word.
    pub fn systolic() -> PlanPricing {
        PlanPricing { wi: PRICE_SCALE, ww: PRICE_SCALE, charge: [1, 1, 1] }
    }

    /// Crossbar prices: weights are programmed, not streamed, so their
    /// marginal re-read price is zero.
    pub fn crossbar() -> PlanPricing {
        PlanPricing { wi: PRICE_SCALE, ww: 0, charge: [1, 0, 1] }
    }

    /// Whether the fixed-scheme fallback (which bounces partial sums
    /// through external memory) is a sensible candidate.  Backends that do
    /// not stream every operand never spill psums off-chip.
    pub fn allows_fixed(&self) -> bool {
        self.charge == [1, 1, 1]
    }
}

/// One hardware target for the shared Plan IR.
///
/// Everything the simulator and the planners need is exposed here; the
/// concrete systolic types (`PeArray`, `Dram`, `Sram`) survive untouched
/// behind [`SystolicBackend`].
pub trait Backend: Send + Sync {
    /// Which backend this is (stable name + id for spec keys).
    fn kind(&self) -> BackendKind;
    /// Walker parameters: tile-pass cycles, bus, and charge triple.
    fn params(&self) -> BackendParams;
    /// Planner prices for the cover chooser and residency knapsack.
    fn pricing(&self) -> PlanPricing;
    /// Tile geometry, buffer capacities, and word width.
    fn accel(&self) -> &AcceleratorConfig;
    /// Energy table for streamed traffic and compute.
    fn energy(&self) -> EnergyModel;
    /// One-time external words moved to place a `weight_words`-word tensor
    /// into stationary storage.  Zero for stream-from-DRAM backends.
    fn program_words(&self, weight_words: u64) -> u64;
    /// One-time energy (pJ) for the same placement.
    fn program_pj(&self, weight_words: u64) -> f64;
    /// Capacity class (words) for the residency knapsack.
    fn residency_words(&self) -> u64;
    /// Bank/row timing for the transaction-level replay oracle.
    fn timing_config(&self) -> DramTimingConfig;
    /// Inter-device link model for sharded plans.
    fn interconnect(&self) -> &Interconnect;
}

/// The paper's hardware point, word-for-word: square PE array, SRAM,
/// half-duplex DRAM.  This is the identity backend — every cost it reports
/// equals the pre-trait code path.
#[derive(Clone, Copy, Debug, Default)]
pub struct SystolicBackend {
    accel: AcceleratorConfig,
    energy: EnergyConfig,
    icx: Interconnect,
}

impl SystolicBackend {
    pub fn new(accel: AcceleratorConfig, energy: EnergyConfig) -> SystolicBackend {
        SystolicBackend { accel, energy, icx: Interconnect::default() }
    }

    pub fn with_interconnect(mut self, icx: Interconnect) -> SystolicBackend {
        self.icx = icx;
        self
    }
}

impl Backend for SystolicBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Systolic
    }
    fn params(&self) -> BackendParams {
        BackendParams::systolic(&self.accel)
    }
    fn pricing(&self) -> PlanPricing {
        PlanPricing::systolic()
    }
    fn accel(&self) -> &AcceleratorConfig {
        &self.accel
    }
    fn energy(&self) -> EnergyModel {
        EnergyModel::new(self.energy)
    }
    fn program_words(&self, _weight_words: u64) -> u64 {
        0
    }
    fn program_pj(&self, _weight_words: u64) -> f64 {
        0.0
    }
    fn residency_words(&self) -> u64 {
        self.accel.sram_words
    }
    fn timing_config(&self) -> DramTimingConfig {
        DramTimingConfig::default()
    }
    fn interconnect(&self) -> &Interconnect {
        &self.icx
    }
}

/// Geometry and costs of the in-memory crossbar target.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CrossbarConfig {
    /// Crossbar tile dimension (rows == columns); weights for one
    /// `xbar_dim x xbar_dim` sub-matrix are programmed into one tile.
    pub xbar_dim: u64,
    /// Column readouts resolved per cycle (ADC lanes); each readout
    /// completes `xbar_dim` MACs, so throughput is `xbar_dim * adc_lanes`
    /// MACs per cycle.
    pub adc_lanes: u64,
    /// DAC input setup + sample/hold cycles before a tile pass drains.
    pub dac_setup: u64,
    /// Activation/psum bus words per cycle.
    pub bus_words_per_cycle: u64,
    /// Bus direction-switch penalty in cycles.
    pub bus_turnaround: u64,
    /// Activation buffer capacity in words (the residency class — weights
    /// live in NVM, so only activations and outputs compete for it).
    pub buffer_words: u64,
    /// Tile rows of activations batched per pass.
    pub tile_m: u64,
    /// Partial-sum accumulator capacity at the array periphery, in words.
    pub psum_regs: u64,
    /// One-time NVM write energy per programmed weight word, in pJ.
    pub program_pj_per_word: f64,
    /// External words moved per programmed weight word (program stream).
    pub program_words_per_word: u64,
}

impl Default for CrossbarConfig {
    fn default() -> CrossbarConfig {
        CrossbarConfig {
            xbar_dim: 128,
            adc_lanes: 16,
            dac_setup: 32,
            bus_words_per_cycle: 16,
            bus_turnaround: 4,
            buffer_words: 128 * 1024,
            tile_m: 16,
            psum_regs: 16 * 1024,
            program_pj_per_word: 2000.0,
            program_words_per_word: 1,
        }
    }
}

impl CrossbarConfig {
    /// Express the crossbar geometry in the shared `AcceleratorConfig`
    /// vocabulary so the tiling/grid machinery applies unchanged: the
    /// contraction and output tile dims are the crossbar dimension, and
    /// the "SRAM" capacity class is the activation buffer.
    pub fn accel(&self) -> AcceleratorConfig {
        AcceleratorConfig {
            pe_dim: self.xbar_dim,
            tile_m: self.tile_m,
            tile_n: self.xbar_dim,
            tile_k: self.xbar_dim,
            psum_regs: self.psum_regs,
            sram_words: self.buffer_words,
            dram_bandwidth: self.bus_words_per_cycle,
            dram_turnaround: self.bus_turnaround,
            word_bytes: 2,
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if self.xbar_dim == 0 || self.adc_lanes == 0 {
            anyhow::bail!("crossbar dimensions must be positive");
        }
        if self.bus_words_per_cycle == 0 {
            anyhow::bail!("crossbar bus bandwidth must be positive");
        }
        self.accel().validate()
    }
}

/// The X-Former-style in-memory crossbar backend: weights resident in NVM
/// at a one-time program cost, activations streamed and psums accumulated
/// at the crossbar periphery.
#[derive(Clone, Copy, Debug)]
pub struct CrossbarBackend {
    xbar: CrossbarConfig,
    accel: AcceleratorConfig,
    energy: EnergyConfig,
    icx: Interconnect,
}

impl Default for CrossbarBackend {
    fn default() -> CrossbarBackend {
        CrossbarBackend::new(CrossbarConfig::default(), EnergyConfig::default())
    }
}

impl CrossbarBackend {
    pub fn new(xbar: CrossbarConfig, energy: EnergyConfig) -> CrossbarBackend {
        CrossbarBackend {
            xbar,
            accel: xbar.accel(),
            energy,
            icx: Interconnect::default(),
        }
    }

    pub fn with_interconnect(mut self, icx: Interconnect) -> CrossbarBackend {
        self.icx = icx;
        self
    }

    pub fn crossbar(&self) -> &CrossbarConfig {
        &self.xbar
    }
}

impl Backend for CrossbarBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Crossbar
    }
    fn params(&self) -> BackendParams {
        BackendParams {
            fill_latency: self.xbar.dac_setup,
            macs_per_cycle: self.xbar.xbar_dim * self.xbar.adc_lanes,
            bandwidth: self.xbar.bus_words_per_cycle,
            turnaround: self.xbar.bus_turnaround,
            charge: [1, 0, 1],
        }
    }
    fn pricing(&self) -> PlanPricing {
        PlanPricing::crossbar()
    }
    fn accel(&self) -> &AcceleratorConfig {
        &self.accel
    }
    fn energy(&self) -> EnergyModel {
        EnergyModel::new(self.energy)
    }
    fn program_words(&self, weight_words: u64) -> u64 {
        weight_words * self.xbar.program_words_per_word
    }
    fn program_pj(&self, weight_words: u64) -> f64 {
        self.program_words(weight_words) as f64 * self.xbar.program_pj_per_word
    }
    fn residency_words(&self) -> u64 {
        self.xbar.buffer_words
    }
    fn timing_config(&self) -> DramTimingConfig {
        DramTimingConfig::default()
    }
    fn interconnect(&self) -> &Interconnect {
        &self.icx
    }
}

/// A concrete, copy-free way to hold "whichever backend the config chose"
/// without boxing; delegates every trait method.
#[derive(Clone, Copy, Debug)]
pub enum AnyBackend {
    Systolic(SystolicBackend),
    Crossbar(CrossbarBackend),
}

impl AnyBackend {
    /// Build the named backend.  The systolic backend adopts the given
    /// accelerator geometry; the crossbar derives its own from `xbar`.
    pub fn build(
        kind: BackendKind,
        accel: AcceleratorConfig,
        energy: EnergyConfig,
        xbar: CrossbarConfig,
    ) -> AnyBackend {
        match kind {
            BackendKind::Systolic => {
                AnyBackend::Systolic(SystolicBackend::new(accel, energy))
            }
            BackendKind::Crossbar => {
                AnyBackend::Crossbar(CrossbarBackend::new(xbar, energy))
            }
        }
    }

    fn inner(&self) -> &dyn Backend {
        match self {
            AnyBackend::Systolic(b) => b,
            AnyBackend::Crossbar(b) => b,
        }
    }
}

impl Default for AnyBackend {
    fn default() -> AnyBackend {
        AnyBackend::Systolic(SystolicBackend::default())
    }
}

impl Backend for AnyBackend {
    fn kind(&self) -> BackendKind {
        self.inner().kind()
    }
    fn params(&self) -> BackendParams {
        self.inner().params()
    }
    fn pricing(&self) -> PlanPricing {
        self.inner().pricing()
    }
    fn accel(&self) -> &AcceleratorConfig {
        self.inner().accel()
    }
    fn energy(&self) -> EnergyModel {
        self.inner().energy()
    }
    fn program_words(&self, weight_words: u64) -> u64 {
        self.inner().program_words(weight_words)
    }
    fn program_pj(&self, weight_words: u64) -> f64 {
        self.inner().program_pj(weight_words)
    }
    fn residency_words(&self) -> u64 {
        self.inner().residency_words()
    }
    fn timing_config(&self) -> DramTimingConfig {
        self.inner().timing_config()
    }
    fn interconnect(&self) -> &Interconnect {
        self.inner().interconnect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn systolic_params_match_the_raw_config() {
        let cfg = AcceleratorConfig::default();
        let b = SystolicBackend::new(cfg, EnergyConfig::default());
        let p = b.params();
        let pe = cfg.pe_array();
        assert_eq!(p.fill_latency, pe.fill_latency);
        assert_eq!(p.macs_per_cycle, pe.macs_per_cycle());
        assert_eq!(p.bandwidth, cfg.dram_bandwidth);
        assert_eq!(p.turnaround, cfg.dram_turnaround);
        assert_eq!(p.charge, [1, 1, 1]);
        for macs in [0, 1, 255, 256, 100_000] {
            assert_eq!(p.tile_cycles(macs), pe.tile_cycles(macs));
        }
        assert_eq!(b.program_words(1 << 20), 0);
        assert_eq!(b.residency_words(), cfg.sram_words);
    }

    #[test]
    fn crossbar_charges_no_weight_stream_but_a_program_cost() {
        let b = CrossbarBackend::default();
        assert_eq!(b.params().charge, [1, 0, 1]);
        assert_eq!(b.pricing().ww, 0);
        assert!(!b.pricing().allows_fixed());
        assert_eq!(b.program_words(768 * 768), 768 * 768);
        assert!(b.program_pj(1) > 0.0);
        b.crossbar().validate().expect("default crossbar validates");
    }

    #[test]
    fn kind_names_round_trip() {
        for kind in BackendKind::ALL {
            assert_eq!(BackendKind::from_name(kind.name()).unwrap(), kind);
            assert_eq!(BackendKind::from_id(kind.id()).unwrap(), kind);
        }
        assert!(BackendKind::from_name("tpu").is_err());
        assert!(BackendKind::from_id(99).is_err());
    }

    #[test]
    fn any_backend_delegates() {
        let any = AnyBackend::build(
            BackendKind::Crossbar,
            AcceleratorConfig::default(),
            EnergyConfig::default(),
            CrossbarConfig::default(),
        );
        assert_eq!(any.kind(), BackendKind::Crossbar);
        assert_eq!(any.params().charge, [1, 0, 1]);
        assert_eq!(any.accel().tile_n, CrossbarConfig::default().xbar_dim);
        let sys = AnyBackend::default();
        assert_eq!(sys.kind(), BackendKind::Systolic);
        assert_eq!(sys.params().charge, [1, 1, 1]);
    }
}
