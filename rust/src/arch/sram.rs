//! Internal-memory models: the tile SRAM and the partial-sum register file.
//!
//! §III-B motivates the hybrid schemes with internal capacity: plain IS/WS
//! keep up to K (resp. M) partial sums alive, while the OS hybrids cap the
//! live set at the window k'·m (resp. m'·k).  The simulator uses these
//! types to *verify* that cap (peak tracking + hard capacity errors).

use anyhow::{bail, Result};

/// Internal SRAM for stationary tiles, tracked in words.
#[derive(Clone, Debug)]
pub struct Sram {
    pub capacity_words: u64,
    used_words: u64,
    peak_words: u64,
}

impl Sram {
    pub fn new(capacity_words: u64) -> Self {
        Sram { capacity_words, used_words: 0, peak_words: 0 }
    }

    pub fn alloc(&mut self, words: u64) -> Result<()> {
        if self.used_words + words > self.capacity_words {
            bail!(
                "SRAM overflow: {} + {} > {} words",
                self.used_words,
                words,
                self.capacity_words
            );
        }
        self.used_words += words;
        self.peak_words = self.peak_words.max(self.used_words);
        Ok(())
    }

    pub fn free(&mut self, words: u64) {
        assert!(words <= self.used_words, "SRAM double-free");
        self.used_words -= words;
    }

    pub fn used(&self) -> u64 {
        self.used_words
    }

    pub fn peak(&self) -> u64 {
        self.peak_words
    }
}

/// Partial-sum register file (one word per live partial sum).
#[derive(Clone, Debug)]
pub struct RegFile {
    pub capacity: u64,
    live: u64,
    peak: u64,
}

impl RegFile {
    pub fn new(capacity: u64) -> Self {
        RegFile { capacity, live: 0, peak: 0 }
    }

    /// Unbounded tracker (capacity checks off, peak still recorded) — used
    /// to *measure* how many psums a non-hybrid scheme would need.
    pub fn unbounded() -> Self {
        RegFile { capacity: u64::MAX, live: 0, peak: 0 }
    }

    pub fn acquire(&mut self, n: u64) -> Result<()> {
        if self.live + n > self.capacity {
            bail!(
                "psum regfile overflow: {} + {} > {}",
                self.live,
                n,
                self.capacity
            );
        }
        self.live += n;
        self.peak = self.peak.max(self.live);
        Ok(())
    }

    pub fn release(&mut self, n: u64) {
        assert!(n <= self.live, "psum regfile double-release");
        self.live -= n;
    }

    pub fn live(&self) -> u64 {
        self.live
    }

    pub fn peak(&self) -> u64 {
        self.peak
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sram_tracks_peak() {
        let mut s = Sram::new(100);
        s.alloc(60).unwrap();
        s.alloc(30).unwrap();
        s.free(50);
        s.alloc(10).unwrap();
        assert_eq!(s.used(), 50);
        assert_eq!(s.peak(), 90);
    }

    #[test]
    fn sram_overflow_errors() {
        let mut s = Sram::new(10);
        assert!(s.alloc(11).is_err());
        s.alloc(10).unwrap();
        assert!(s.alloc(1).is_err());
    }

    #[test]
    #[should_panic(expected = "double-free")]
    fn sram_double_free_panics() {
        let mut s = Sram::new(10);
        s.free(1);
    }

    #[test]
    fn regfile_caps_and_peaks() {
        let mut r = RegFile::new(4);
        r.acquire(3).unwrap();
        assert!(r.acquire(2).is_err());
        r.release(1);
        r.acquire(2).unwrap();
        assert_eq!(r.live(), 4);
        assert_eq!(r.peak(), 4);
    }

    #[test]
    fn unbounded_regfile_measures() {
        let mut r = RegFile::unbounded();
        r.acquire(1_000_000).unwrap();
        assert_eq!(r.peak(), 1_000_000);
    }
}
