//! External-memory (DRAM) model.
//!
//! Two properties matter to the paper (§II-d):
//!   1. every off-chip word movement costs energy 10–100× a MAC, and
//!   2. DRAM cannot read and write simultaneously — each read↔write
//!      direction switch stalls the bus (tWTR/tRTW turnaround).
//!
//! The model counts words moved per logical stream (input/weight/psum/
//! output) and direction switches; the cycle model charges
//! `words / bandwidth + switches * turnaround`.

/// Transfer direction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DramDir {
    Read,
    Write,
}

/// Which logical stream a transfer belongs to (for Table II accounting).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stream {
    Input,
    Weight,
    /// Partial sums spilled and re-fetched (non-hybrid schemes).
    Psum,
    Output,
}

/// Accumulated DRAM statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DramStats {
    pub input_read_words: u64,
    pub weight_read_words: u64,
    pub psum_read_words: u64,
    pub psum_write_words: u64,
    pub output_write_words: u64,
    /// Read↔write direction switches (each costs `turnaround` cycles).
    pub direction_switches: u64,
}

impl DramStats {
    /// Total words moved in either direction.
    pub fn total_words(&self) -> u64 {
        self.read_words() + self.write_words()
    }

    pub fn read_words(&self) -> u64 {
        self.input_read_words + self.weight_read_words + self.psum_read_words
    }

    pub fn write_words(&self) -> u64 {
        self.psum_write_words + self.output_write_words
    }

    /// Table II-style accounting: the paper counts each matrix's traffic
    /// once per access (reads for input/weight, writes for output+psum).
    pub fn table2_words(&self) -> (u64, u64, u64) {
        (
            self.input_read_words,
            self.weight_read_words,
            self.psum_write_words + self.output_write_words,
        )
    }
}

/// The DRAM device: bandwidth, turnaround penalty, running stats.
#[derive(Clone, Debug)]
pub struct Dram {
    /// Words transferred per cycle when streaming.
    pub bandwidth_words_per_cycle: u64,
    /// Cycles lost on each read↔write direction switch.
    pub turnaround_cycles: u64,
    stats: DramStats,
    last_dir: Option<DramDir>,
}

impl Dram {
    pub fn new(bandwidth_words_per_cycle: u64, turnaround_cycles: u64) -> Self {
        assert!(bandwidth_words_per_cycle > 0);
        Dram {
            bandwidth_words_per_cycle,
            turnaround_cycles,
            stats: DramStats::default(),
            last_dir: None,
        }
    }

    /// Record a transfer of `words` on `stream`.
    pub fn transfer(&mut self, stream: Stream, words: u64) {
        if words == 0 {
            return;
        }
        let dir = match stream {
            Stream::Input | Stream::Weight => DramDir::Read,
            Stream::Output => DramDir::Write,
            Stream::Psum => unreachable!("use psum_read/psum_write"),
        };
        self.record(dir, stream, words);
    }

    /// Psum spill to DRAM (write direction).
    pub fn psum_write(&mut self, words: u64) {
        if words > 0 {
            self.record(DramDir::Write, Stream::Psum, words);
        }
    }

    /// Psum re-fetch from DRAM (read direction).
    pub fn psum_read(&mut self, words: u64) {
        if words > 0 {
            self.record(DramDir::Read, Stream::Psum, words);
        }
    }

    fn record(&mut self, dir: DramDir, stream: Stream, words: u64) {
        if let Some(last) = self.last_dir {
            if last != dir {
                self.stats.direction_switches += 1;
            }
        }
        self.last_dir = Some(dir);
        match (stream, dir) {
            (Stream::Input, _) => self.stats.input_read_words += words,
            (Stream::Weight, _) => self.stats.weight_read_words += words,
            (Stream::Output, _) => self.stats.output_write_words += words,
            (Stream::Psum, DramDir::Read) => self.stats.psum_read_words += words,
            (Stream::Psum, DramDir::Write) => self.stats.psum_write_words += words,
        }
    }

    pub fn stats(&self) -> DramStats {
        self.stats
    }

    /// Cycles the bus is busy: streaming time + turnaround stalls.
    pub fn bus_cycles(&self) -> u64 {
        self.stats.total_words().div_ceil(self.bandwidth_words_per_cycle)
            + self.stats.direction_switches * self.turnaround_cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_streams_separately() {
        let mut d = Dram::new(16, 10);
        d.transfer(Stream::Input, 100);
        d.transfer(Stream::Weight, 200);
        d.transfer(Stream::Output, 50);
        let s = d.stats();
        assert_eq!(s.input_read_words, 100);
        assert_eq!(s.weight_read_words, 200);
        assert_eq!(s.output_write_words, 50);
        assert_eq!(s.total_words(), 350);
    }

    #[test]
    fn direction_switches_counted() {
        let mut d = Dram::new(16, 10);
        d.transfer(Stream::Input, 1); // read
        d.transfer(Stream::Weight, 1); // read: no switch
        d.psum_write(1); // switch 1
        d.psum_read(1); // switch 2
        d.transfer(Stream::Output, 1); // switch 3
        assert_eq!(d.stats().direction_switches, 3);
    }

    #[test]
    fn bus_cycles_charge_turnaround() {
        let mut d = Dram::new(10, 100);
        d.transfer(Stream::Input, 100); // 10 cycles
        d.transfer(Stream::Output, 100); // 10 cycles + 1 switch
        assert_eq!(d.bus_cycles(), 20 + 100);
    }

    #[test]
    fn zero_word_transfers_ignored() {
        let mut d = Dram::new(16, 10);
        d.transfer(Stream::Input, 0);
        d.psum_write(0);
        assert_eq!(d.stats(), DramStats::default());
        assert_eq!(d.stats().direction_switches, 0);
    }
}
