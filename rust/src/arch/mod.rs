//! Hardware model of the accelerator the paper assumes (§II–III): a square
//! PE array fed by an internal SRAM, a partial-sum register file, and an
//! external DRAM that cannot read and write simultaneously.
//!
//! These types carry *capacities and costs*; the dynamic behaviour (what is
//! resident when) lives in the schedule replay inside [`crate::sim`].

pub mod backend;
pub mod dram;
pub mod dram_timing;
pub mod interconnect;
pub mod pe;
pub mod sram;

pub use backend::{
    AnyBackend, Backend, BackendKind, BackendParams, CrossbarBackend, CrossbarConfig,
    PlanPricing, SystolicBackend,
};
pub use dram::{Dram, DramDir, DramStats};
pub use interconnect::{Interconnect, InterconnectConfig};
pub use pe::PeArray;
pub use sram::{RegFile, Sram};
