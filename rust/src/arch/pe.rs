//! Processing-element array model.
//!
//! The paper assumes a square array (8×8 or 16×16, §III-A) that consumes an
//! (m × n) input tile and an (n × k) weight tile per pass.  We model
//! throughput as one MAC per PE per cycle with a fixed pipeline fill
//! latency — enough fidelity for cycle *estimates*; EMA (the paper's
//! metric) does not depend on it.

/// Square systolic PE array.
#[derive(Clone, Copy, Debug)]
pub struct PeArray {
    pub rows: u64,
    pub cols: u64,
    /// Pipeline fill/drain latency per tile pass, in cycles.
    pub fill_latency: u64,
}

impl PeArray {
    pub fn square(dim: u64) -> Self {
        assert!(dim > 0);
        PeArray { rows: dim, cols: dim, fill_latency: 2 * dim }
    }

    pub fn new(rows: u64, cols: u64) -> Self {
        assert!(rows > 0 && cols > 0);
        PeArray { rows, cols, fill_latency: rows + cols }
    }

    /// MACs retired per cycle at full utilisation.
    pub fn macs_per_cycle(&self) -> u64 {
        self.rows * self.cols
    }

    /// Cycles to compute an (m·n·k)-MAC tile pass, including fill.
    pub fn tile_cycles(&self, macs: u64) -> u64 {
        self.fill_latency + macs.div_ceil(self.macs_per_cycle())
    }

    /// Natural square tile edge for this array (the paper maps m≈n≈k to
    /// the PE dimensions, §III-A).
    pub fn natural_tile(&self) -> u64 {
        self.rows.min(self.cols)
    }

    /// Utilisation of one tile pass: useful MACs / (cycles · peak).
    pub fn utilization(&self, macs: u64) -> f64 {
        let cycles = self.tile_cycles(macs);
        macs as f64 / (cycles * self.macs_per_cycle()) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn square_array() {
        let pe = PeArray::square(16);
        assert_eq!(pe.macs_per_cycle(), 256);
        assert_eq!(pe.natural_tile(), 16);
    }

    #[test]
    fn tile_cycles_include_fill() {
        let pe = PeArray::square(8);
        // 8x8x8 tile = 512 MACs on 64 PEs = 8 cycles + 16 fill.
        assert_eq!(pe.tile_cycles(512), 24);
    }

    #[test]
    fn utilization_improves_with_bigger_tiles() {
        let pe = PeArray::square(8);
        let small = pe.utilization(8 * 8 * 8);
        let big = pe.utilization(64 * 64 * 64);
        assert!(big > small);
        assert!(big <= 1.0);
    }
}
