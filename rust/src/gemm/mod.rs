//! GEMM shape/tiling algebra shared by the analytic model, the schedule
//! generators and the simulator.
//!
//! Paper notation (§II, Fig. 1a): `out[M,K] = in[M,N] · w[N,K]` — **N is the
//! contraction dimension** (input columns == weight rows), M the input rows
//! (tokens), K the weight columns (output features).  Tile sizes are
//! `(m, n, k)`; the hybrid schemes add the psum window sizes `k'` (IS-OS)
//! and `m'` (WS-OS) from Fig. 2.

use crate::util::ceil_div;

/// Problem shape of one linear-projection GEMM.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GemmShape {
    /// Input rows = tokens (sequence length × batch).
    pub m: u64,
    /// Contraction dim = input columns = weight rows.
    pub n: u64,
    /// Weight columns = output features.
    pub k: u64,
}

impl GemmShape {
    pub fn new(m: u64, n: u64, k: u64) -> Self {
        assert!(m > 0 && n > 0 && k > 0, "degenerate gemm {m}x{n}x{k}");
        GemmShape { m, n, k }
    }

    /// Multiply-accumulate count.
    pub fn macs(&self) -> u64 {
        self.m * self.n * self.k
    }

    /// 2·MNK floating-point ops.
    pub fn flops(&self) -> u64 {
        2 * self.macs()
    }

    pub fn input_words(&self) -> u64 {
        self.m * self.n
    }

    pub fn weight_words(&self) -> u64 {
        self.n * self.k
    }

    pub fn output_words(&self) -> u64 {
        self.m * self.k
    }
}

/// Tile configuration: PE-array tile `(m, n, k)` plus the psum windows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tiling {
    /// Tile rows (input rows per tile).
    pub tm: u64,
    /// Tile contraction depth.
    pub tn: u64,
    /// Tile columns (output features per tile).
    pub tk: u64,
    /// IS-OS psum window: output columns whose psums stay on chip
    /// (Fig. 2a's k'). `None` = unbounded (Table II's ideal: k' = K).
    pub kp: Option<u64>,
    /// WS-OS psum window: output rows kept on chip (Fig. 2b's m').
    pub mp: Option<u64>,
}

impl Tiling {
    /// Square PE-array tiling, the common accelerator case (§III-A):
    /// m = n = k = `t`, unbounded psum windows.
    pub fn square(t: u64) -> Self {
        assert!(t > 0);
        Tiling { tm: t, tn: t, tk: t, kp: None, mp: None }
    }

    pub fn new(tm: u64, tn: u64, tk: u64) -> Self {
        assert!(tm > 0 && tn > 0 && tk > 0);
        Tiling { tm, tn, tk, kp: None, mp: None }
    }

    /// Set the IS-OS psum window k' (must be a multiple of tk).
    pub fn with_kp(mut self, kp: u64) -> Self {
        assert!(kp >= self.tk && kp % self.tk == 0, "k'={kp} vs k={}", self.tk);
        self.kp = Some(kp);
        self
    }

    /// Set the WS-OS psum window m' (must be a multiple of tm).
    pub fn with_mp(mut self, mp: u64) -> Self {
        assert!(mp >= self.tm && mp % self.tm == 0, "m'={mp} vs m={}", self.tm);
        self.mp = Some(mp);
        self
    }

    /// Effective k' clamped to the problem (defaults to K).
    pub fn kp_eff(&self, shape: &GemmShape) -> u64 {
        self.kp.unwrap_or(shape.k).min(shape.k)
    }

    /// Effective m' clamped to the problem (defaults to M).
    pub fn mp_eff(&self, shape: &GemmShape) -> u64 {
        self.mp.unwrap_or(shape.m).min(shape.m)
    }

    /// IS-OS psum window width **in tiles** along K.  `kp = None` (or
    /// `kp >= K`) means the whole output row fits: one window.  This is
    /// the single definition both the analytic model and the schedule
    /// generator use — they must never disagree.
    pub fn window_tiles_k(&self, shape: &GemmShape) -> u64 {
        let gk = ceil_div(shape.k, self.tk);
        match self.kp {
            Some(kp) if kp < shape.k => (kp / self.tk).max(1),
            _ => gk,
        }
    }

    /// WS-OS psum window height **in tiles** along M.
    pub fn window_tiles_m(&self, shape: &GemmShape) -> u64 {
        let gm = ceil_div(shape.m, self.tm);
        match self.mp {
            Some(mp) if mp < shape.m => (mp / self.tm).max(1),
            _ => gm,
        }
    }

    /// Grid extents (tiles along M, N, K) — ceiling division.
    pub fn grid(&self, shape: &GemmShape) -> (u64, u64, u64) {
        (
            ceil_div(shape.m, self.tm),
            ceil_div(shape.n, self.tn),
            ceil_div(shape.k, self.tk),
        )
    }

    /// Words in one input tile / weight tile / output tile (full tiles).
    pub fn input_tile_words(&self) -> u64 {
        self.tm * self.tn
    }

    pub fn weight_tile_words(&self) -> u64 {
        self.tn * self.tk
    }

    pub fn output_tile_words(&self) -> u64 {
        self.tm * self.tk
    }

    /// True iff the shape divides evenly (no ragged edge tiles).
    pub fn divides(&self, shape: &GemmShape) -> bool {
        shape.m % self.tm == 0 && shape.n % self.tn == 0 && shape.k % self.tk == 0
    }
}

/// Actual (possibly ragged) extent of tile index `idx` along a dimension.
pub fn tile_extent(dim: u64, tile: u64, idx: u64) -> u64 {
    let start = idx * tile;
    debug_assert!(start < dim, "tile {idx} out of range");
    tile.min(dim - start)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::property;
    use crate::util::prng::Rng;

    #[test]
    fn shape_counts() {
        let s = GemmShape::new(4, 6, 8);
        assert_eq!(s.macs(), 192);
        assert_eq!(s.flops(), 384);
        assert_eq!(s.input_words(), 24);
        assert_eq!(s.weight_words(), 48);
        assert_eq!(s.output_words(), 32);
    }

    #[test]
    fn grid_ceiling() {
        let s = GemmShape::new(100, 64, 33);
        let t = Tiling::new(16, 16, 16);
        assert_eq!(t.grid(&s), (7, 4, 3));
        assert!(!t.divides(&s));
        assert!(Tiling::new(10, 16, 11).divides(&GemmShape::new(20, 32, 33)));
    }

    #[test]
    fn psum_windows_validated() {
        let t = Tiling::square(16).with_kp(64).with_mp(32);
        assert_eq!(t.kp, Some(64));
        assert_eq!(t.mp, Some(32));
        let s = GemmShape::new(24, 32, 40);
        assert_eq!(t.kp_eff(&s), 40); // clamped to K
        assert_eq!(t.mp_eff(&s), 24); // clamped to M
    }

    #[test]
    #[should_panic(expected = "k'=10")]
    fn kp_must_be_tile_multiple() {
        Tiling::square(16).with_kp(10);
    }

    #[test]
    fn tile_extent_ragged_edge() {
        assert_eq!(tile_extent(100, 16, 0), 16);
        assert_eq!(tile_extent(100, 16, 6), 4);
        assert_eq!(tile_extent(96, 16, 5), 16);
    }

    #[test]
    fn prop_grid_covers_shape() {
        property("grid covers", 300, |rng: &mut Rng| {
            let s = GemmShape::new(
                rng.gen_in(1, 500),
                rng.gen_in(1, 500),
                rng.gen_in(1, 500),
            );
            let t = Tiling::new(
                rng.gen_in(1, 64),
                rng.gen_in(1, 64),
                rng.gen_in(1, 64),
            );
            let (gm, gn, gk) = t.grid(&s);
            // Sum of tile extents reconstructs each dimension exactly.
            let m: u64 = (0..gm).map(|i| tile_extent(s.m, t.tm, i)).sum();
            let n: u64 = (0..gn).map(|i| tile_extent(s.n, t.tn, i)).sum();
            let k: u64 = (0..gk).map(|i| tile_extent(s.k, t.tk, i)).sum();
            assert_eq!((m, n, k), (s.m, s.n, s.k));
        });
    }
}
