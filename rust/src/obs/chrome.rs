//! Chrome trace-event JSON export.
//!
//! Serialises [`TraceEvent`]s in the Trace Event Format consumed by
//! `chrome://tracing` and <https://ui.perfetto.dev>: an object with a
//! `traceEvents` array of `B`/`E`/`i`/`C` events plus `thread_name`
//! metadata, one tid per recorded track (assigned in first-seen order),
//! microsecond timestamps.

use super::span::{Phase, TraceEvent};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// Build the `{"traceEvents": [...]}` document for a recorded event list.
pub fn chrome_trace_json(events: &[TraceEvent]) -> Json {
    let mut tids: BTreeMap<&str, u64> = BTreeMap::new();
    let mut out: Vec<Json> = Vec::new();
    // tids in first-seen order so track rows match record order.
    for e in events {
        let next = tids.len() as u64 + 1;
        let tid = *tids.entry(e.track.as_str()).or_insert(next);
        if tid == next {
            out.push(obj(vec![
                ("ph", Json::Str("M".into())),
                ("name", Json::Str("thread_name".into())),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(tid as f64)),
                (
                    "args",
                    obj(vec![("name", Json::Str(e.track.clone()))]),
                ),
            ]));
        }
    }
    for e in events {
        let tid = tids[e.track.as_str()];
        let mut fields = vec![
            ("ph", Json::Str(ph(e.phase).into())),
            ("name", Json::Str(e.name.clone())),
            ("cat", Json::Str("tas".into())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(tid as f64)),
            ("ts", Json::Num(e.ts_us as f64)),
        ];
        match e.phase {
            Phase::Instant => fields.push(("s", Json::Str("t".into()))),
            Phase::Counter => fields.push((
                "args",
                obj(vec![("value", Json::Num(e.value.unwrap_or(0.0)))]),
            )),
            _ => {}
        }
        out.push(obj(fields));
    }
    obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

/// Write the trace document to `path` (compact JSON, one line).
pub fn write_chrome_trace(
    path: &Path,
    events: &[TraceEvent],
) -> anyhow::Result<()> {
    let doc = chrome_trace_json(events);
    std::fs::write(path, doc.to_string_compact())
        .map_err(|e| anyhow::anyhow!("writing trace {}: {e}", path.display()))
}

fn ph(p: Phase) -> &'static str {
    match p {
        Phase::Begin => "B",
        Phase::End => "E",
        Phase::Instant => "i",
        Phase::Counter => "C",
    }
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        fields
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::Tracer;

    #[test]
    fn export_roundtrips_and_assigns_tracks() {
        let t = Tracer::new(true);
        t.span_at("req 1", "queued", 0, 50);
        t.span_at("device 0", "exec", 10, 90);
        t.counter("queues", "depth", 4.0);
        let doc = chrome_trace_json(&t.events());
        let text = doc.to_string_compact();
        let parsed = Json::parse(&text).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 3 thread_name metadata + 4 span events + 1 counter.
        assert_eq!(events.len(), 8);
        let metas: Vec<_> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("M"))
            .collect();
        assert_eq!(metas.len(), 3);
        // Distinct tracks get distinct tids.
        let tids: std::collections::BTreeSet<u64> = metas
            .iter()
            .map(|e| e.get("tid").unwrap().as_u64().unwrap())
            .collect();
        assert_eq!(tids.len(), 3);
    }

    #[test]
    fn write_creates_a_parseable_file() {
        let t = Tracer::new(true);
        t.span_at("link", "round 0", 0, 7);
        let dir = std::env::temp_dir().join("tas-obs-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        write_chrome_trace(&path, &t.events()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
        std::fs::remove_file(&path).ok();
    }
}
