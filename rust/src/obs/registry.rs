//! Counter/gauge registry.
//!
//! Replaces the one-struct-field-per-statistic pattern in
//! [`crate::coordinator::Metrics`]: monotonic counters and last-value
//! gauges keyed by `&'static str` names, so adding a statistic is one
//! `add`/`set_gauge` call site plus one snapshot read — no struct churn.
//! Gauges also retain their high-water mark (`peak`), which is what the
//! queue-depth telemetry actually wants.

use std::collections::BTreeMap;

/// Named monotonic counters + last-value/peak gauges.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, Gauge>,
}

#[derive(Clone, Copy, Debug, Default)]
struct Gauge {
    last: f64,
    peak: f64,
}

impl Registry {
    /// Add `delta` to the named counter (created at zero).
    pub fn add(&mut self, key: &'static str, delta: u64) {
        *self.counters.entry(key).or_insert(0) += delta;
    }

    /// Current counter value; absent counters read as zero.
    pub fn counter(&self, key: &'static str) -> u64 {
        self.counters.get(key).copied().unwrap_or(0)
    }

    /// Record a gauge sample (keeps the last value and the peak).
    pub fn set_gauge(&mut self, key: &'static str, value: f64) {
        let g = self.gauges.entry(key).or_default();
        g.last = value;
        g.peak = g.peak.max(value);
    }

    /// Last sampled gauge value, `None` if never set.
    pub fn gauge(&self, key: &'static str) -> Option<f64> {
        self.gauges.get(key).map(|g| g.last)
    }

    /// High-water mark of the gauge, `None` if never set.
    pub fn gauge_peak(&self, key: &'static str) -> Option<f64> {
        self.gauges.get(key).map(|g| g.peak)
    }

    /// All counters, for bulk export.
    pub fn counters(&self) -> impl Iterator<Item = (&'static str, u64)> + '_ {
        self.counters.iter().map(|(k, v)| (*k, *v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_from_zero() {
        let mut r = Registry::default();
        assert_eq!(r.counter("requests"), 0);
        r.add("requests", 3);
        r.add("requests", 4);
        assert_eq!(r.counter("requests"), 7);
        assert_eq!(r.counters().count(), 1);
    }

    #[test]
    fn gauges_keep_last_and_peak() {
        let mut r = Registry::default();
        assert_eq!(r.gauge("queue_depth"), None);
        r.set_gauge("queue_depth", 5.0);
        r.set_gauge("queue_depth", 2.0);
        assert_eq!(r.gauge("queue_depth"), Some(2.0));
        assert_eq!(r.gauge_peak("queue_depth"), Some(5.0));
    }
}
