//! Simulated device/link timelines as trace spans.
//!
//! `tas shard` computes per-device compute bursts, link round drains,
//! and stall attribution ([`crate::sim::shard::sharded_fused_cost`]) and
//! used to throw the shape of that schedule away, keeping only totals.
//! This module replays the closed-form latency decomposition into a
//! [`Tracer`] so serialized-vs-overlapped becomes a picture: one track
//! per device (busy burst + the link time its own compute could not
//! hide, with the DMA-stall share nested inside the burst) and one track
//! for the interconnect draining its collective rounds.
//!
//! Timestamps are **simulated cycles**, not wall-clock microseconds; the
//! Chrome viewer only needs a consistent unit.  By construction the
//! longest track of one GEMM's timeline spans exactly
//! [`ShardCost::overlapped_cycles`] — pinned by the trace property suite
//! (`rust/tests/trace_and_ledger.rs`).

use super::span::Tracer;
use crate::sim::ShardCost;

/// Append one sharded GEMM's simulated timeline to `tracer`, starting at
/// simulated cycle `t0`.  `rounds` is the interconnect's per-round cycle
/// list ([`crate::sim::shard_link_rounds`]; its sum is the GEMM's
/// serialized link time).  Returns the GEMM's end time,
/// `t0 + overlapped_cycles` — the start cursor for the next GEMM, so a
/// whole forward pass chains into one contiguous trace.
pub fn shard_gemm_timeline(
    tracer: &Tracer,
    label: &str,
    cost: &ShardCost,
    rounds: &[u64],
    t0: u64,
) -> u64 {
    let link = cost.link_cycles();
    for dc in &cost.per_device {
        let track = format!("device {}", dc.device);
        let busy = dc.cycles.total_cycles;
        tracer.begin_at(&track, &format!("{label} compute"), t0);
        // The step-granular (DMA ‖ PE) stall share, nested at the tail of
        // the burst: turnaround + bandwidth time the pipeline exposed.
        let stall = dc.pipeline.stall_cycles.min(busy);
        if stall > 0 {
            tracer.span_at(&track, &format!("{label} stall"), t0 + busy - stall, stall);
        }
        tracer.end_at(&track, &format!("{label} compute"), t0 + busy);
        // Link time this device's own PE-busy window could not hide —
        // the exposed term of the overlapped model
        // ([`crate::sim::ShardLatency::from_parts`]).
        let exposed = link - link.min(dc.cycles.compute_cycles);
        if exposed > 0 {
            tracer.span_at(&track, &format!("{label} link wait"), t0 + busy, exposed);
        }
    }
    let mut t = t0;
    for (i, &dur) in rounds.iter().enumerate() {
        tracer.span_at("link", &format!("{label} round {i}"), t, dur);
        t += dur;
    }
    t0 + cost.overlapped_cycles()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Interconnect, InterconnectConfig};
    use crate::config::AcceleratorConfig;
    use crate::dataflow::{shard_gemm, ShardAxis, ShardSpec};
    use crate::energy::EnergyModel;
    use crate::gemm::{GemmShape, Tiling};
    use crate::obs::span::Phase;
    use crate::sim::{shard_link_rounds, sharded_fused_cost};

    #[test]
    fn longest_track_spans_the_overlapped_latency() {
        let shape = GemmShape::new(256, 768, 768);
        let tiling = Tiling::square(16);
        let spec = ShardSpec { devices: 4, axis: ShardAxis::Rows, link_aware: false };
        let sp = shard_gemm(&shape, &tiling, spec, 0.0);
        let cfg = AcceleratorConfig::default();
        let icx = Interconnect::new(InterconnectConfig::default());
        let cost = sharded_fused_cost(&sp, &cfg, &EnergyModel::default(), &icx);
        let rounds = shard_link_rounds(&sp, &icx);

        let tracer = Tracer::new(true);
        let end = shard_gemm_timeline(&tracer, "qkv", &cost, &rounds, 0);
        assert_eq!(end, cost.overlapped_cycles());

        // Per track, sum top-level B..E durations; the longest track is
        // the overlapped critical path, exactly.
        let mut sums: std::collections::BTreeMap<String, u64> = Default::default();
        let mut depth: std::collections::BTreeMap<String, (u64, u64)> = Default::default();
        for e in tracer.events() {
            let (d, open_ts) = depth.entry(e.track.clone()).or_insert((0, 0));
            match e.phase {
                Phase::Begin => {
                    if *d == 0 {
                        *open_ts = e.ts_us;
                    }
                    *d += 1;
                }
                Phase::End => {
                    *d -= 1;
                    if *d == 0 {
                        *sums.entry(e.track.clone()).or_insert(0) += e.ts_us - *open_ts;
                    }
                }
                _ => {}
            }
        }
        let longest = sums.values().copied().max().unwrap();
        assert_eq!(longest, cost.overlapped_cycles());
        // and the link track, when present, drains exactly the
        // serialized link time
        if let Some(l) = sums.get("link") {
            assert_eq!(*l, cost.link_cycles());
        }
    }
}
