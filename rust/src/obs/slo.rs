//! SLO accounting: sliding-window latency percentiles, per-window
//! goodput, and multi-rate burn-rate counters.
//!
//! A [`SloTracker`] buckets every observed latency sample into fixed
//! windows of virtual (or wall) time.  Each window holds bounded
//! [`Summary`] reservoirs for TTFT / TPOT / end-to-end latency plus the
//! SLO pass counters, so a long-running fleet keeps O(windows) memory
//! and the per-window percentiles stay reproducible.
//!
//! **Goodput** of a window is the fraction of SLO-checked samples that
//! met their bound: each TTFT sample is one request checked against
//! `ttft_ms`, each TPOT sample one decode dispatch checked against
//! `tpot_ms` (prefill-only traffic reduces to plain request goodput).
//! **Burn rate** over a horizon is the SRE multi-window form:
//! `(1 − goodput) / (1 − objective)` — 1.0 burns the error budget
//! exactly at the sustainable pace, 10× eats it ten times too fast.
//! [`SloSnapshot::burn`] reports the last-window, last-8-window and
//! whole-run rates, so a paging rule can require both a fast and a slow
//! window to fire (the standard guard against one-sample pages).
//!
//! Per-replica trackers merge exactly: windows align on the shared
//! index, counters add, and the reservoirs fold through
//! [`Summary::merge`] (exact count/sum/min/max, deterministic
//! percentiles) — so a fleet's aggregate histogram equals one global
//! tracker fed the union of the streams.

use crate::report::json::{jarr, jf64, jnum, jobj, jopt};
use crate::util::json::Json;
use crate::util::stats::Summary;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Latency objectives: a request is good when TTFT ≤ `ttft_ms`, a decode
/// dispatch when TPOT ≤ `tpot_ms`; `objective` is the target good
/// fraction the burn rate is measured against (0.99 → 1% error budget).
#[derive(Clone, Copy, Debug)]
pub struct SloSpec {
    pub ttft_ms: f64,
    pub tpot_ms: f64,
    pub objective: f64,
}

impl Default for SloSpec {
    fn default() -> Self {
        SloSpec { ttft_ms: 50.0, tpot_ms: 20.0, objective: 0.99 }
    }
}

/// Most windows a tracker retains; beyond it the oldest windows drop
/// (counted, so a snapshot can say its horizon was clipped).
const MAX_WINDOWS: usize = 4096;

/// One window's accumulators.
#[derive(Clone, Debug, Default)]
pub struct WindowAcc {
    pub ttft: Summary,
    pub tpot: Summary,
    pub e2e: Summary,
    pub ttft_good: u64,
    pub tpot_good: u64,
}

impl WindowAcc {
    fn checked(&self) -> u64 {
        self.ttft.count() + self.tpot.count()
    }

    fn good(&self) -> u64 {
        self.ttft_good + self.tpot_good
    }

    fn merge(&mut self, other: &WindowAcc) {
        self.ttft.merge(&other.ttft);
        self.tpot.merge(&other.tpot);
        self.e2e.merge(&other.e2e);
        self.ttft_good += other.ttft_good;
        self.tpot_good += other.tpot_good;
    }
}

/// Sliding-window SLO accountant.  Thread-safe; a disabled tracker is a
/// no-op on every observe call (the coordinator threads one through
/// unconditionally, like the span tracer).
#[derive(Debug)]
pub struct SloTracker {
    enabled: bool,
    spec: SloSpec,
    window_us: u64,
    epoch: Instant,
    inner: Mutex<Windows>,
}

#[derive(Debug, Default)]
struct Windows {
    map: BTreeMap<u64, WindowAcc>,
    dropped: u64,
}

impl SloTracker {
    pub fn new(spec: SloSpec, window_ms: u64) -> Self {
        assert!(window_ms >= 1, "window must be >= 1 ms");
        assert!(
            (0.0..1.0).contains(&spec.objective),
            "objective {} outside [0, 1)",
            spec.objective
        );
        SloTracker {
            enabled: true,
            spec,
            window_us: window_ms * 1000,
            epoch: Instant::now(),
            inner: Mutex::new(Windows::default()),
        }
    }

    /// A tracker that ignores every observation (default coordinator
    /// wiring when no SLO flags are set).
    pub fn disabled() -> Self {
        SloTracker {
            enabled: false,
            spec: SloSpec::default(),
            window_us: 1000,
            epoch: Instant::now(),
            inner: Mutex::new(Windows::default()),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn spec(&self) -> SloSpec {
        self.spec
    }

    pub fn window_ms(&self) -> f64 {
        self.window_us as f64 / 1000.0
    }

    /// Microseconds since this tracker's construction (wall-clock mode).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn observe(&self, t_us: u64, f: impl FnOnce(&mut WindowAcc)) {
        if !self.enabled {
            return;
        }
        let idx = t_us / self.window_us;
        let mut g = self.inner.lock().unwrap();
        f(g.map.entry(idx).or_default());
        while g.map.len() > MAX_WINDOWS {
            g.map.pop_first();
            g.dropped += 1;
        }
    }

    /// Record one request's TTFT observed at `t_us` (virtual or
    /// tracker-relative microseconds — the caller owns the clock).
    pub fn observe_ttft_at(&self, t_us: u64, ms: f64) {
        let good = ms <= self.spec.ttft_ms;
        self.observe(t_us, |w| {
            w.ttft.push(ms);
            w.ttft_good += good as u64;
        });
    }

    /// Record one decode dispatch's TPOT observed at `t_us`.
    pub fn observe_tpot_at(&self, t_us: u64, ms: f64) {
        let good = ms <= self.spec.tpot_ms;
        self.observe(t_us, |w| {
            w.tpot.push(ms);
            w.tpot_good += good as u64;
        });
    }

    /// Record one request's end-to-end latency observed at `t_us`
    /// (distribution only; the goodput criteria are TTFT/TPOT).
    pub fn observe_e2e_at(&self, t_us: u64, ms: f64) {
        self.observe(t_us, |w| w.e2e.push(ms));
    }

    /// Wall-clock conveniences for the serving path.
    pub fn observe_ttft_now(&self, ms: f64) {
        self.observe_ttft_at(self.now_us(), ms);
    }

    pub fn observe_tpot_now(&self, ms: f64) {
        self.observe_tpot_at(self.now_us(), ms);
    }

    pub fn observe_e2e_now(&self, ms: f64) {
        self.observe_e2e_at(self.now_us(), ms);
    }

    /// Fold another tracker's windows into this one (fleet aggregation).
    /// Windows align by index, so both trackers must share a window size
    /// and a time origin.
    pub fn merge_from(&self, other: &SloTracker) {
        assert_eq!(
            self.window_us, other.window_us,
            "cannot merge trackers with different window sizes"
        );
        if !self.enabled || !other.enabled {
            return;
        }
        let theirs = other.inner.lock().unwrap();
        let mut ours = self.inner.lock().unwrap();
        for (idx, acc) in theirs.map.iter() {
            ours.map.entry(*idx).or_default().merge(acc);
        }
        ours.dropped += theirs.dropped;
        while ours.map.len() > MAX_WINDOWS {
            ours.map.pop_first();
            ours.dropped += 1;
        }
    }

    pub fn snapshot(&self) -> SloSnapshot {
        let g = self.inner.lock().unwrap();
        let windows: Vec<WindowSnapshot> = g
            .map
            .iter()
            .map(|(&index, acc)| WindowSnapshot {
                index,
                start_ms: index as f64 * self.window_ms(),
                checked: acc.checked(),
                good: acc.good(),
                ttft_p50_ms: acc.ttft.p50(),
                ttft_p99_ms: acc.ttft.p99(),
                tpot_p50_ms: acc.tpot.p50(),
                tpot_p99_ms: acc.tpot.p99(),
                e2e_p50_ms: acc.e2e.p50(),
                e2e_p99_ms: acc.e2e.p99(),
            })
            .collect();
        let budget = 1.0 - self.spec.objective;
        let rate_over = |wins: &[WindowSnapshot]| -> Option<f64> {
            let checked: u64 = wins.iter().map(|w| w.checked).sum();
            let good: u64 = wins.iter().map(|w| w.good).sum();
            if checked == 0 {
                None
            } else {
                Some((1.0 - good as f64 / checked as f64) / budget)
            }
        };
        let last_k = |k: usize| -> Option<f64> {
            let last = windows.last()?.index;
            let lo = last.saturating_sub(k as u64 - 1);
            let tail: Vec<WindowSnapshot> = windows
                .iter()
                .filter(|w| w.index >= lo)
                .cloned()
                .collect();
            rate_over(&tail)
        };
        let checked: u64 = windows.iter().map(|w| w.checked).sum();
        let good: u64 = windows.iter().map(|w| w.good).sum();
        SloSnapshot {
            spec: self.spec,
            window_ms: self.window_ms(),
            dropped_windows: g.dropped,
            checked,
            good,
            goodput: if checked == 0 {
                None
            } else {
                Some(good as f64 / checked as f64)
            },
            burn: BurnRates {
                last_window: last_k(1),
                last_8_windows: last_k(8),
                overall: rate_over(&windows),
            },
            windows,
        }
    }
}

/// One window, snapshotted.
#[derive(Clone, Debug)]
pub struct WindowSnapshot {
    pub index: u64,
    pub start_ms: f64,
    pub checked: u64,
    pub good: u64,
    pub ttft_p50_ms: Option<f64>,
    pub ttft_p99_ms: Option<f64>,
    pub tpot_p50_ms: Option<f64>,
    pub tpot_p99_ms: Option<f64>,
    pub e2e_p50_ms: Option<f64>,
    pub e2e_p99_ms: Option<f64>,
}

impl WindowSnapshot {
    pub fn goodput(&self) -> Option<f64> {
        if self.checked == 0 {
            None
        } else {
            Some(self.good as f64 / self.checked as f64)
        }
    }
}

/// Multi-rate burn: the same `(1 − goodput) / budget` ratio over three
/// horizons (fast page, slow page, whole run).
#[derive(Clone, Copy, Debug, Default)]
pub struct BurnRates {
    pub last_window: Option<f64>,
    pub last_8_windows: Option<f64>,
    pub overall: Option<f64>,
}

/// Point-in-time view of a tracker; everything the fleet report and the
/// Prometheus exposition need.
#[derive(Clone, Debug)]
pub struct SloSnapshot {
    pub spec: SloSpec,
    pub window_ms: f64,
    pub dropped_windows: u64,
    pub checked: u64,
    pub good: u64,
    pub goodput: Option<f64>,
    pub burn: BurnRates,
    pub windows: Vec<WindowSnapshot>,
}

impl SloSnapshot {
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("slo_ttft_ms", jf64(self.spec.ttft_ms)),
            ("slo_tpot_ms", jf64(self.spec.tpot_ms)),
            ("objective", jf64(self.spec.objective)),
            ("window_ms", jf64(self.window_ms)),
            ("dropped_windows", jnum(self.dropped_windows)),
            ("checked", jnum(self.checked)),
            ("good", jnum(self.good)),
            ("goodput", jopt(self.goodput)),
            (
                "burn",
                jobj(vec![
                    ("last_window", jopt(self.burn.last_window)),
                    ("last_8_windows", jopt(self.burn.last_8_windows)),
                    ("overall", jopt(self.burn.overall)),
                ]),
            ),
            (
                "windows",
                jarr(
                    self.windows
                        .iter()
                        .map(|w| {
                            jobj(vec![
                                ("index", jnum(w.index)),
                                ("start_ms", jf64(w.start_ms)),
                                ("checked", jnum(w.checked)),
                                ("good", jnum(w.good)),
                                ("goodput", jopt(w.goodput())),
                                ("ttft_p50_ms", jopt(w.ttft_p50_ms)),
                                ("ttft_p99_ms", jopt(w.ttft_p99_ms)),
                                ("tpot_p50_ms", jopt(w.tpot_p50_ms)),
                                ("tpot_p99_ms", jopt(w.tpot_p99_ms)),
                                ("e2e_p50_ms", jopt(w.e2e_p50_ms)),
                                ("e2e_p99_ms", jopt(w.e2e_p99_ms)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tracker() -> SloTracker {
        SloTracker::new(
            SloSpec { ttft_ms: 10.0, tpot_ms: 5.0, objective: 0.9 },
            100, // 100 ms windows
        )
    }

    #[test]
    fn goodput_counts_both_criteria() {
        let t = tracker();
        t.observe_ttft_at(10_000, 5.0); // good
        t.observe_ttft_at(20_000, 50.0); // bad
        t.observe_tpot_at(30_000, 4.0); // good
        t.observe_tpot_at(40_000, 6.0); // bad
        let s = t.snapshot();
        assert_eq!(s.checked, 4);
        assert_eq!(s.good, 2);
        assert_eq!(s.goodput, Some(0.5));
        // budget is 0.1, bad fraction 0.5 -> burn 5x
        assert!((s.burn.overall.unwrap() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn windows_split_on_the_window_boundary() {
        let t = tracker();
        t.observe_ttft_at(99_999, 1.0); // window 0
        t.observe_ttft_at(100_000, 1.0); // window 1
        t.observe_ttft_at(250_000, 100.0); // window 2, violates
        let s = t.snapshot();
        assert_eq!(s.windows.len(), 3);
        assert_eq!(s.windows[0].index, 0);
        assert_eq!(s.windows[2].index, 2);
        assert_eq!(s.windows[2].goodput(), Some(0.0));
        // last-window burn sees only the violating window
        assert!((s.burn.last_window.unwrap() - 10.0).abs() < 1e-9);
        // whole-run burn: 1/3 bad over budget 0.1
        assert!((s.burn.overall.unwrap() - (1.0 / 3.0) / 0.1).abs() < 1e-9);
    }

    #[test]
    fn per_window_percentiles_match_a_full_sample_oracle() {
        let t = tracker();
        let mut oracle: Vec<f64> = Vec::new();
        for i in 0..200u64 {
            let ms = (i * 7 % 91) as f64;
            t.observe_ttft_at(i * 400, ms); // all land in window 0
            oracle.push(ms);
        }
        oracle.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let nearest =
            |p: f64| oracle[((p / 100.0) * (oracle.len() - 1) as f64).round() as usize];
        let w = &t.snapshot().windows[0];
        assert_eq!(w.ttft_p50_ms, Some(nearest(50.0)));
        assert_eq!(w.ttft_p99_ms, Some(nearest(99.0)));
    }

    #[test]
    fn merged_trackers_equal_one_global_tracker() {
        let (a, b, global) = (tracker(), tracker(), tracker());
        for i in 0..100u64 {
            let (t_us, ms) = (i * 3000, (i % 17) as f64);
            if i % 2 == 0 {
                a.observe_ttft_at(t_us, ms);
            } else {
                b.observe_ttft_at(t_us, ms);
            }
            global.observe_ttft_at(t_us, ms);
        }
        a.merge_from(&b);
        let (m, g) = (a.snapshot(), global.snapshot());
        assert_eq!(m.checked, g.checked);
        assert_eq!(m.good, g.good);
        assert_eq!(m.goodput, g.goodput);
        assert_eq!(m.windows.len(), g.windows.len());
        for (wm, wg) in m.windows.iter().zip(&g.windows) {
            assert_eq!(wm.checked, wg.checked);
            // same multiset per window (both under the reservoir cap)
            assert_eq!(wm.ttft_p50_ms, wg.ttft_p50_ms);
            assert_eq!(wm.ttft_p99_ms, wg.ttft_p99_ms);
        }
    }

    #[test]
    fn disabled_tracker_observes_nothing() {
        let t = SloTracker::disabled();
        t.observe_ttft_at(0, 1.0);
        t.observe_tpot_now(1.0);
        let s = t.snapshot();
        assert_eq!(s.checked, 0);
        assert_eq!(s.goodput, None);
        assert_eq!(s.burn.overall, None);
        assert!(s.windows.is_empty());
    }

    #[test]
    fn snapshot_serialises_without_nan() {
        let t = tracker();
        t.observe_ttft_at(5, 1.0);
        let text = t.snapshot().to_json().to_string_compact();
        assert!(!text.contains("NaN"));
        crate::util::json::Json::parse(&text).expect("slo snapshot must parse");
        let empty = SloTracker::disabled().snapshot().to_json().to_string_compact();
        crate::util::json::Json::parse(&empty).unwrap();
    }
}
