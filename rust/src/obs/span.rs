//! Span/event tracer.
//!
//! Events live on named *tracks* (one Chrome/Perfetto thread row each): a
//! served request gets its own track, a simulated device or link gets one
//! per lane.  Within a track, `begin`/`end` pairs must nest like a call
//! stack — the recorder clamps timestamps monotonically per track so the
//! exported trace is always well-formed even when spans are reconstructed
//! after the fact from stored [`std::time::Instant`]s.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Event kind, mirroring the Chrome trace-event `ph` field.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Span open (`ph: "B"`).
    Begin,
    /// Span close (`ph: "E"`); closes the innermost open span on the track.
    End,
    /// Zero-duration marker (`ph: "i"`).
    Instant,
    /// Sampled counter value (`ph: "C"`).
    Counter,
}

/// One recorded event.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Track (rendered as a thread row; tids are assigned at export).
    pub track: String,
    /// Span/marker/counter name (kept on `End` for readability).
    pub name: String,
    pub phase: Phase,
    /// Microseconds since the tracer epoch (or simulated cycles).
    pub ts_us: u64,
    /// Counter value; `None` for span/marker events.
    pub value: Option<f64>,
}

#[derive(Default)]
struct TraceBuf {
    events: Vec<TraceEvent>,
    /// Last timestamp per track, for monotonic clamping.
    last_ts: BTreeMap<String, u64>,
    /// Open-span depth per track, so `end` without `begin` is dropped
    /// instead of corrupting the nesting.
    depth: BTreeMap<String, u64>,
}

/// Thread-safe span recorder anchored to a construction-time epoch.
///
/// Disabled tracers reject every record with a single branch, so call
/// sites can stay unconditionally instrumented (the `bench_planner`
/// overhead guard pins the disabled cost at ≤5%).
pub struct Tracer {
    enabled: bool,
    epoch: Instant,
    buf: Mutex<TraceBuf>,
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer")
            .field("enabled", &self.enabled)
            .finish_non_exhaustive()
    }
}

impl Tracer {
    pub fn new(enabled: bool) -> Self {
        Tracer {
            enabled,
            epoch: Instant::now(),
            buf: Mutex::new(TraceBuf::default()),
        }
    }

    /// A tracer that records nothing (the default for `Coordinator`).
    pub fn disabled() -> Self {
        Tracer::new(false)
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Microseconds elapsed since the tracer epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Convert a stored [`Instant`] (e.g. `Request::arrived`) to trace time.
    pub fn ts_of(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    pub fn begin(&self, track: &str, name: &str) {
        self.begin_at(track, name, self.now_us());
    }

    pub fn begin_at(&self, track: &str, name: &str, ts_us: u64) {
        if !self.enabled {
            return;
        }
        let mut b = self.buf.lock().unwrap();
        *b.depth.entry(track.to_string()).or_insert(0) += 1;
        push(&mut b, track, name, Phase::Begin, ts_us, None);
    }

    pub fn end(&self, track: &str, name: &str) {
        self.end_at(track, name, self.now_us());
    }

    pub fn end_at(&self, track: &str, name: &str, ts_us: u64) {
        if !self.enabled {
            return;
        }
        let mut b = self.buf.lock().unwrap();
        match b.depth.get_mut(track) {
            Some(d) if *d > 0 => *d -= 1,
            _ => return, // unmatched end: drop rather than corrupt nesting
        }
        push(&mut b, track, name, Phase::End, ts_us, None);
    }

    /// Record an already-elapsed span from explicit timestamps.
    pub fn span_at(&self, track: &str, name: &str, ts_us: u64, dur_us: u64) {
        self.begin_at(track, name, ts_us);
        self.end_at(track, name, ts_us.saturating_add(dur_us));
    }

    pub fn instant(&self, track: &str, name: &str) {
        self.instant_at(track, name, self.now_us());
    }

    pub fn instant_at(&self, track: &str, name: &str, ts_us: u64) {
        if !self.enabled {
            return;
        }
        let mut b = self.buf.lock().unwrap();
        push(&mut b, track, name, Phase::Instant, ts_us, None);
    }

    /// Record a counter sample (rendered as a Perfetto counter track).
    pub fn counter(&self, track: &str, name: &str, value: f64) {
        if !self.enabled {
            return;
        }
        let ts = self.now_us();
        let mut b = self.buf.lock().unwrap();
        push(&mut b, track, name, Phase::Counter, ts, Some(value));
    }

    /// Snapshot of everything recorded so far, in record order.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.buf.lock().unwrap().events.clone()
    }
}

fn push(
    b: &mut TraceBuf,
    track: &str,
    name: &str,
    phase: Phase,
    ts_us: u64,
    value: Option<f64>,
) {
    // Monotonic clamp per track: spans rebuilt from stored Instants can
    // race the live clock by a few µs; the trace must never run backwards.
    let last = b.last_ts.entry(track.to_string()).or_insert(0);
    let ts = ts_us.max(*last);
    *last = ts;
    b.events.push(TraceEvent {
        track: track.to_string(),
        name: name.to_string(),
        phase,
        ts_us: ts,
        value,
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::disabled();
        t.begin("a", "x");
        t.end("a", "x");
        t.instant("a", "m");
        t.counter("c", "depth", 3.0);
        assert!(t.events().is_empty());
    }

    #[test]
    fn spans_record_in_order_with_monotonic_ts() {
        let t = Tracer::new(true);
        t.span_at("req 1", "queued", 100, 40);
        t.span_at("req 1", "exec", 140, 60);
        let ev = t.events();
        assert_eq!(ev.len(), 4);
        assert!(ev.windows(2).all(|w| w[0].ts_us <= w[1].ts_us));
        assert_eq!(ev[0].phase, Phase::Begin);
        assert_eq!(ev[1].phase, Phase::End);
        assert_eq!(ev[1].ts_us, 140);
    }

    #[test]
    fn backwards_timestamps_are_clamped() {
        let t = Tracer::new(true);
        t.span_at("d0", "a", 500, 10);
        t.span_at("d0", "b", 100, 10); // starts before the last end
        let ev = t.events();
        assert!(ev.iter().all(|e| e.ts_us >= 510));
    }

    #[test]
    fn unmatched_end_is_dropped() {
        let t = Tracer::new(true);
        t.end_at("d0", "ghost", 10);
        t.begin_at("d0", "real", 20);
        t.end_at("d0", "real", 30);
        let ev = t.events();
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0].phase, Phase::Begin);
    }

    #[test]
    fn nesting_depth_is_per_track() {
        let t = Tracer::new(true);
        t.begin_at("a", "outer", 0);
        t.begin_at("a", "inner", 1);
        t.end_at("b", "ghost", 2); // other track: dropped
        t.end_at("a", "inner", 3);
        t.end_at("a", "outer", 4);
        let ev = t.events();
        assert_eq!(ev.len(), 4);
        assert!(ev.iter().all(|e| e.track == "a"));
    }
}
