//! Observability: request spans, counter/gauge registry, Chrome-trace export.
//!
//! The paper's claim is about *where words move*; this module makes the
//! repro's movement and latency inspectable instead of scalar-only:
//!
//! * [`span::Tracer`] — a lightweight span/event recorder (no external
//!   deps; the crate builds bare).  The coordinator threads one through the
//!   request lifecycle (`enqueue → batch → plan → dispatch → complete`),
//!   and the simulated shard path replays device/link timelines into one.
//!   A disabled tracer is a branch and a return — cheap enough to leave
//!   compiled into the planner hot path (`bench_planner` pins ≤5%).
//! * [`registry::Registry`] — named monotonic counters and last-value/peak
//!   gauges; [`crate::coordinator::Metrics`] stores its scalar accounting
//!   here instead of one struct field per statistic.
//! * [`chrome`] — serialises recorded events as Chrome trace-event JSON
//!   (`chrome://tracing`, <https://ui.perfetto.dev>), one track per span
//!   source, B/E pairs nested per track, microsecond timestamps.
//! * [`slo`] — sliding-window SLO accounting: windowed TTFT/TPOT/e2e
//!   percentiles over mergeable [`crate::util::stats::Summary`] digests,
//!   per-window goodput and multi-rate burn rates.  Per-replica trackers
//!   fold exactly into a fleet aggregate.
//! * [`timeline`] — replays the sharded-GEMM latency decomposition
//!   (compute bursts, exposed link waits, collective round drains) into a
//!   tracer, so `tas shard --trace-out` exports the simulated schedule.

pub mod chrome;
pub mod registry;
pub mod slo;
pub mod span;
pub mod timeline;

pub use chrome::{chrome_trace_json, write_chrome_trace};
pub use registry::Registry;
pub use slo::{BurnRates, SloSnapshot, SloSpec, SloTracker};
pub use span::{Phase, TraceEvent, Tracer};
pub use timeline::shard_gemm_timeline;
