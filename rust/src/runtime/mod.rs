//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO text + weights checkpoint + manifest) and executes them on the CPU
//! PJRT client.  This is the only place Python output crosses into the
//! request path — as compiled artifacts, never as an interpreter.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serialises `HloModuleProto` with
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod engine;
pub mod manifest;

pub use engine::{Engine, HostTensor};
pub use manifest::{ArgKind, ArgMeta, ArtifactMeta, DType, Manifest};

use std::path::{Path, PathBuf};

/// Default artifacts directory: `$TAS_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("TAS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// True if a built artifact set exists at `dir` (manifest present).
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("manifest.json").is_file()
}
