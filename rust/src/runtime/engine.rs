//! Artifact execution engine: compile-once, weights-resident PJRT wrapper.
//!
//! On [`Engine::preload`] the HLO text is parsed and compiled and the
//! artifact's weight slices are uploaded to device buffers **once**; per
//! request only the (tiny) input tensor crosses the host/device boundary
//! and `execute_b` runs with the resident weights — the hot path does no
//! recompilation, no weight re-upload and no Python.

use super::manifest::{ArgKind, DType, Manifest};
use crate::util::bytes;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// A host-side tensor crossing the engine boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(..) => DType::F32,
            HostTensor::I32(..) => DType::I32,
        }
    }

    pub fn element_count(&self) -> usize {
        match self {
            HostTensor::F32(v, _) => v.len(),
            HostTensor::I32(v, _) => v.len(),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v, _) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(v, _) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }
}

struct Loaded {
    exe: xla::PjRtLoadedExecutable,
    /// Pre-uploaded device buffers for weight args; `None` at input slots.
    weight_bufs: Vec<Option<xla::PjRtBuffer>>,
}

/// The runtime engine. NOT `Send` (PJRT handles are thread-affine here);
/// the coordinator owns one per device thread.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    weights: Vec<u8>,
    loaded: BTreeMap<String, Loaded>,
}

impl Engine {
    /// Open an artifact directory: parse manifest, map the checkpoint,
    /// create the PJRT CPU client.  Compilation happens per artifact in
    /// [`Engine::preload`] (or lazily on first execute).
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let weights_path = dir.join(&manifest.weights_bin);
        let weights = std::fs::read(&weights_path)
            .with_context(|| format!("reading {}", weights_path.display()))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?;
        Ok(Engine {
            client,
            dir: dir.to_path_buf(),
            manifest,
            weights,
            loaded: BTreeMap::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest.artifacts.iter().map(|a| a.name.clone()).collect()
    }

    /// Compile `name` and upload its weights; idempotent.
    pub fn preload(&mut self, name: &str) -> Result<()> {
        if self.loaded.contains_key(name) {
            return Ok(());
        }
        let art = self.manifest.artifact(name)?.clone();
        let hlo_path = self.dir.join(&art.hlo);
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?;

        let mut weight_bufs = Vec::with_capacity(art.args.len());
        for arg in &art.args {
            match arg.kind {
                ArgKind::Weight { offset, nbytes } => {
                    let lo = offset as usize;
                    let hi = lo + nbytes as usize;
                    anyhow::ensure!(
                        hi <= self.weights.len(),
                        "weight '{}' [{lo}..{hi}) outside checkpoint ({} bytes)",
                        arg.name,
                        self.weights.len()
                    );
                    // NOTE: not `buffer_from_host_raw_bytes` — xla 0.1.6
                    // passes `ElementType as i32` where the C API expects
                    // `PrimitiveType`, so F32 is misread as F16 and the
                    // buffer arrives half-sized.  The typed upload path
                    // passes the correct primitive type.
                    let slice = &self.weights[lo..hi];
                    let buf = match arg.dtype {
                        DType::F32 => self.client.buffer_from_host_buffer(
                            &bytes::f32_from_le(slice)?,
                            &arg.shape,
                            None,
                        ),
                        DType::I32 => {
                            let vals: Vec<i32> = slice
                                .chunks_exact(4)
                                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                                .collect();
                            self.client.buffer_from_host_buffer(&vals, &arg.shape, None)
                        }
                    }
                    .map_err(|e| {
                        anyhow::anyhow!("uploading weight '{}': {e}", arg.name)
                    })?;
                    weight_bufs.push(Some(buf));
                }
                ArgKind::Input => weight_bufs.push(None),
            }
        }
        self.loaded.insert(name.to_string(), Loaded { exe, weight_bufs });
        Ok(())
    }

    /// Compile + upload every artifact in the manifest.
    pub fn preload_all(&mut self) -> Result<()> {
        for name in self.artifact_names() {
            self.preload(&name)?;
        }
        Ok(())
    }

    /// Execute `name` with per-request `inputs` (in manifest arg order,
    /// weights skipped).  Returns the output tensors.
    pub fn execute(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.preload(name)?;
        let art = self.manifest.artifact(name)?.clone();
        let loaded = self.loaded.get(name).expect("preloaded");

        let expected: Vec<&super::manifest::ArgMeta> =
            art.input_args().into_iter().map(|(_, a)| a).collect();
        anyhow::ensure!(
            expected.len() == inputs.len(),
            "{name}: expected {} inputs, got {}",
            expected.len(),
            inputs.len()
        );
        for (meta, t) in expected.iter().zip(inputs) {
            anyhow::ensure!(
                meta.dtype == t.dtype() && meta.shape == t.shape(),
                "{name}: input '{}' expects {:?}{:?}, got {:?}{:?}",
                meta.name,
                meta.dtype,
                meta.shape,
                t.dtype(),
                t.shape()
            );
        }

        // Upload the per-request inputs, then assemble the arg list from
        // resident weight buffers + the fresh input buffers.
        let mut fresh: Vec<xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        for t in inputs {
            let buf = match t {
                HostTensor::F32(v, s) => self.client.buffer_from_host_buffer(v, s, None),
                HostTensor::I32(v, s) => self.client.buffer_from_host_buffer(v, s, None),
            }
            .map_err(|e| anyhow::anyhow!("uploading input for {name}: {e}"))?;
            fresh.push(buf);
        }
        let mut next_input = 0usize;
        let mut arg_bufs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(art.args.len());
        for slot in &loaded.weight_bufs {
            match slot {
                Some(buf) => arg_bufs.push(buf),
                None => {
                    arg_bufs.push(&fresh[next_input]);
                    next_input += 1;
                }
            }
        }

        let result = loaded
            .exe
            .execute_b(&arg_bufs)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e}"))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {name}: {e}"))?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let elems = literal
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling result of {name}: {e}"))?;
        anyhow::ensure!(
            elems.len() == art.outputs.len(),
            "{name}: manifest lists {} outputs, module returned {}",
            art.outputs.len(),
            elems.len()
        );
        let mut out = Vec::with_capacity(elems.len());
        for (lit, meta) in elems.into_iter().zip(&art.outputs) {
            let t = match meta.dtype {
                DType::F32 => HostTensor::F32(
                    lit.to_vec::<f32>()
                        .map_err(|e| anyhow::anyhow!("reading output: {e}"))?,
                    meta.shape.clone(),
                ),
                DType::I32 => HostTensor::I32(
                    lit.to_vec::<i32>()
                        .map_err(|e| anyhow::anyhow!("reading output: {e}"))?,
                    meta.shape.clone(),
                ),
            };
            anyhow::ensure!(
                t.element_count() == meta.element_count(),
                "{name}: output has {} elements, manifest says {}",
                t.element_count(),
                meta.element_count()
            );
            out.push(t);
        }
        Ok(out)
    }

    /// Run the stored golden input through the artifact and compare with
    /// the stored oracle output. Returns the max abs error.
    pub fn validate_golden(&mut self, name: &str) -> Result<f32> {
        let art = self.manifest.artifact(name)?.clone();
        let golden = art
            .golden
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("{name} has no golden vectors"))?
            .clone();
        let input_meta = art
            .input_args()
            .first()
            .map(|(_, a)| (*a).clone())
            .ok_or_else(|| anyhow::anyhow!("{name} has no input args"))?;

        let input = match input_meta.dtype {
            DType::I32 => HostTensor::I32(
                bytes::read_i32_file(&self.dir.join(&golden.input))?,
                input_meta.shape.clone(),
            ),
            DType::F32 => HostTensor::F32(
                bytes::read_f32_file(&self.dir.join(&golden.input))?,
                input_meta.shape.clone(),
            ),
        };
        anyhow::ensure!(
            input.element_count() == input_meta.element_count(),
            "{name}: golden input size mismatch"
        );
        let want = bytes::read_f32_file(&self.dir.join(&golden.output))?;
        let got = self.execute(name, &[input])?;
        let got = got[0].as_f32()?;
        anyhow::ensure!(
            got.len() == want.len(),
            "{name}: golden output length {} vs {}",
            want.len(),
            got.len()
        );
        let mut max_err = 0f32;
        for (g, w) in got.iter().zip(&want) {
            max_err = max_err.max((g - w).abs());
        }
        Ok(max_err)
    }

    pub fn loaded_count(&self) -> usize {
        self.loaded.len()
    }
}

// Engine unit tests that need real artifacts live in rust/tests/ (they
// skip when `make artifacts` has not run); pure-logic tests are here.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_accessors() {
        let t = HostTensor::F32(vec![1.0, 2.0], vec![2]);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.element_count(), 2);
        assert!(t.as_i32().is_err());
        let i = HostTensor::I32(vec![1, 2, 3], vec![3]);
        assert_eq!(i.as_i32().unwrap(), &[1, 2, 3]);
        assert_eq!(i.shape(), &[3]);
    }

    #[test]
    fn engine_load_fails_cleanly_without_artifacts() {
        let err = Engine::load(Path::new("/nonexistent/artifacts"))
            .err()
            .expect("must fail");
        assert!(err.to_string().contains("manifest.json"));
    }
}
