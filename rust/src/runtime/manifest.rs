//! Typed view of `artifacts/manifest.json` (written by `aot.py`).

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Tensor element type used in artifacts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => bail!("unsupported dtype '{s}'"),
        }
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

/// Where an argument's data comes from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArgKind {
    /// Slice of `weights.bin` at `offset`, `nbytes` long.
    Weight { offset: u64, nbytes: u64 },
    /// Provided per request.
    Input,
}

/// One executable argument.
#[derive(Clone, Debug)]
pub struct ArgMeta {
    pub name: String,
    pub kind: ArgKind,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl ArgMeta {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Output tensor description.
#[derive(Clone, Debug)]
pub struct TensorMeta {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorMeta {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Golden input/output vector paths (relative to the artifacts dir).
#[derive(Clone, Debug)]
pub struct GoldenMeta {
    pub input: PathBuf,
    pub output: PathBuf,
}

/// One AOT-compiled artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub hlo: PathBuf,
    /// "bert" or "linear".
    pub kind: String,
    pub batch: Option<u64>,
    pub seq: Option<u64>,
    pub args: Vec<ArgMeta>,
    pub outputs: Vec<TensorMeta>,
    /// TAS scheme the compile path chose per projection (bert artifacts).
    pub schemes: BTreeMap<String, String>,
    pub flops: u64,
    pub golden: Option<GoldenMeta>,
}

impl ArtifactMeta {
    /// Indices of the per-request (non-weight) args.
    pub fn input_args(&self) -> Vec<(usize, &ArgMeta)> {
        self.args
            .iter()
            .enumerate()
            .filter(|(_, a)| matches!(a.kind, ArgKind::Input))
            .collect()
    }

    /// Token count M of a bert artifact (batch × seq).
    pub fn tokens(&self) -> Option<u64> {
        Some(self.batch? * self.seq?)
    }
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub weights_bin: PathBuf,
    /// Model hyper-parameters (vocab/hidden/...).
    pub model: BTreeMap<String, u64>,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let json = Json::parse(&text).context("parsing manifest.json")?;
        Self::from_json(&json)
    }

    pub fn from_json(json: &Json) -> Result<Manifest> {
        let version = json.req("version")?.as_u64().context("version")?;
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let model = json
            .req("model")?
            .as_obj()
            .context("model")?
            .iter()
            .filter_map(|(k, v)| v.as_u64().map(|n| (k.clone(), n)))
            .collect();
        let mut artifacts = Vec::new();
        for a in json.req("artifacts")?.as_arr().context("artifacts")? {
            artifacts.push(parse_artifact(a)?);
        }
        anyhow::ensure!(!artifacts.is_empty(), "manifest lists no artifacts");
        Ok(Manifest {
            weights_bin: PathBuf::from(
                json.req("weights_bin")?.as_str().context("weights_bin")?,
            ),
            model,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "artifact '{name}' not in manifest (have: {})",
                    self.artifacts
                        .iter()
                        .map(|a| a.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    /// (batch, seq) buckets of all bert artifacts, ascending by tokens.
    pub fn bert_buckets(&self) -> Vec<(u64, u64, String)> {
        let mut v: Vec<(u64, u64, String)> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == "bert")
            .filter_map(|a| Some((a.batch?, a.seq?, a.name.clone())))
            .collect();
        v.sort_by_key(|(b, s, _)| (b * s, *s));
        v
    }
}

fn parse_artifact(a: &Json) -> Result<ArtifactMeta> {
    let name = a.req("name")?.as_str().context("name")?.to_string();
    let ctx = |what: &str| format!("artifact '{name}': {what}");
    let mut args = Vec::new();
    for arg in a.req("args")?.as_arr().with_context(|| ctx("args"))? {
        let aname = arg.req("name")?.as_str().context("arg name")?.to_string();
        let kind = match arg.req("kind")?.as_str().context("arg kind")? {
            "weight" => ArgKind::Weight {
                offset: arg.req("offset")?.as_u64().context("offset")?,
                nbytes: arg.req("nbytes")?.as_u64().context("nbytes")?,
            },
            "input" => ArgKind::Input,
            other => bail!("{}: unknown arg kind '{other}'", ctx(&aname)),
        };
        args.push(ArgMeta {
            name: aname,
            kind,
            dtype: DType::parse(arg.req("dtype")?.as_str().context("dtype")?)?,
            shape: parse_shape(arg.req("shape")?)?,
        });
    }
    let mut outputs = Vec::new();
    for o in a.req("outputs")?.as_arr().with_context(|| ctx("outputs"))? {
        outputs.push(TensorMeta {
            dtype: DType::parse(o.req("dtype")?.as_str().context("dtype")?)?,
            shape: parse_shape(o.req("shape")?)?,
        });
    }
    let schemes = a
        .get("schemes")
        .and_then(|s| s.as_obj())
        .map(|m| {
            m.iter()
                .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                .collect()
        })
        .unwrap_or_default();
    let golden = a.get("golden").and_then(|g| {
        Some(GoldenMeta {
            input: PathBuf::from(g.get("input")?.as_str()?),
            output: PathBuf::from(g.get("output")?.as_str()?),
        })
    });
    Ok(ArtifactMeta {
        hlo: PathBuf::from(a.req("hlo")?.as_str().context("hlo")?),
        kind: a.req("kind")?.as_str().context("kind")?.to_string(),
        batch: a.get("batch").and_then(|v| v.as_u64()),
        seq: a.get("seq").and_then(|v| v.as_u64()),
        args,
        outputs,
        schemes,
        flops: a.get("flops").and_then(|v| v.as_u64()).unwrap_or(0),
        golden,
        name,
    })
}

fn parse_shape(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .context("shape not an array")?
        .iter()
        .map(|d| d.as_u64().map(|x| x as usize).context("bad dim"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "weights_bin": "weights.bin",
      "model": {"vocab": 512, "hidden": 128},
      "artifacts": [
        {"name": "bert_b1_s32", "hlo": "bert_b1_s32.hlo.txt", "kind": "bert",
         "batch": 1, "seq": 32,
         "args": [
           {"name": "emb", "kind": "weight", "dtype": "f32",
            "shape": [512, 128], "offset": 0, "nbytes": 262144},
           {"name": "ids", "kind": "input", "dtype": "i32", "shape": [1, 32]}
         ],
         "outputs": [{"dtype": "f32", "shape": [1, 32, 512]}],
         "schemes": {"qkv": "is_os"},
         "flops": 1000,
         "golden": {"input": "golden/in.bin", "output": "golden/out.bin"}}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(&Json::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.artifact("bert_b1_s32").unwrap();
        assert_eq!(a.tokens(), Some(32));
        assert_eq!(a.args.len(), 2);
        assert_eq!(
            a.args[0].kind,
            ArgKind::Weight { offset: 0, nbytes: 262144 }
        );
        assert_eq!(a.args[0].element_count(), 512 * 128);
        assert_eq!(a.input_args().len(), 1);
        assert_eq!(a.schemes["qkv"], "is_os");
        assert_eq!(a.outputs[0].element_count(), 32 * 512);
        assert_eq!(m.bert_buckets(), vec![(1, 32, "bert_b1_s32".into())]);
    }

    #[test]
    fn missing_artifact_error_lists_known() {
        let m = Manifest::from_json(&Json::parse(SAMPLE).unwrap()).unwrap();
        let err = m.artifact("nope").unwrap_err().to_string();
        assert!(err.contains("bert_b1_s32"));
    }

    #[test]
    fn rejects_bad_version() {
        let j = Json::parse(&SAMPLE.replace("\"version\": 1", "\"version\": 9")).unwrap();
        assert!(Manifest::from_json(&j).is_err());
    }

    #[test]
    fn rejects_unknown_dtype() {
        let j = Json::parse(&SAMPLE.replace("\"i32\"", "\"f64\"")).unwrap();
        assert!(Manifest::from_json(&j).is_err());
    }
}
