//! Exact tile-step generators for every stationary scheme.
//!
//! Each scheme is a loop nest over tile indices `(i over M, r over N, j
//! over K)` in its characteristic order (Fig. 1/2 circled arrows), emitting
//! one [`Step`] per tile MAC pass with flags that say which DRAM traffic
//! the step incurs.  The simulator replays steps; the analytic model
//! (Table II) must agree word-for-word — that equivalence is the central
//! property test of the repo.
//!
//! Generators use a visitor (`FnMut(Step)`) instead of an Iterator: the
//! loop nests stay readable, the compiler inlines the callback, and the
//! hot path allocates nothing.

use super::Scheme;
use crate::gemm::{GemmShape, Tiling};
use crate::util::ceil_div;

/// One tile MAC pass: `out[i,j] += in[i,r] · w[r,j]` plus its DRAM flags.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Step {
    /// Tile row index (along M).
    pub i: u64,
    /// Contraction tile index (along N).
    pub r: u64,
    /// Tile column index (along K).
    pub j: u64,
    /// Input tile fetched from DRAM at this step.
    pub load_input: bool,
    /// Weight tile fetched from DRAM at this step.
    pub load_weight: bool,
    /// Partial-sum tile re-fetched from DRAM (spilling schemes, r > 0).
    pub psum_fetch: bool,
    /// Partial-sum tile written to DRAM after this step (not final).
    pub psum_spill: bool,
    /// Final output tile written after this step.
    pub store_out: bool,
    /// Naive mode: operand traffic is per-MAC (tile words × tile depth).
    pub scalar_traffic: bool,
}

impl Step {
    /// Blank step at tile triple `(i, r, j)`; generators (including the
    /// [`crate::dataflow::plan`] IR) set the DRAM flags they need.
    pub(crate) fn new(i: u64, r: u64, j: u64) -> Step {
        Step {
            i,
            r,
            j,
            load_input: false,
            load_weight: false,
            psum_fetch: false,
            psum_spill: false,
            store_out: false,
            scalar_traffic: false,
        }
    }
}

/// Total steps of any schedule: every (i, r, j) tile triple exactly once.
pub fn step_count(shape: &GemmShape, tiling: &Tiling) -> u64 {
    let (gm, gn, gk) = tiling.grid(shape);
    gm * gn * gk
}

/// Drive `visit` over every step of `scheme` in schedule order.
/// `Tas` is resolved by shape first (§III-A decision rule).
pub fn for_each_step<F: FnMut(Step)>(
    scheme: Scheme,
    shape: &GemmShape,
    tiling: &Tiling,
    mut visit: F,
) {
    let (gm, gn, gk) = tiling.grid(shape);
    match scheme.resolve(shape) {
        Scheme::Naive => naive(gm, gn, gk, &mut visit),
        Scheme::Is => is(gm, gn, gk, &mut visit),
        Scheme::Ws => ws(gm, gn, gk, &mut visit),
        Scheme::OsRow => os_row(gm, gn, gk, &mut visit),
        Scheme::OsCol => os_col(gm, gn, gk, &mut visit),
        Scheme::IsOs => is_os(gm, gn, gk, tiling.window_tiles_k(shape), &mut visit),
        Scheme::WsOs => ws_os(gm, gn, gk, tiling.window_tiles_m(shape), &mut visit),
        Scheme::Tas => unreachable!("resolve() eliminated Tas"),
    }
}

/// Naive (no reuse): order is irrelevant to its EMA; row-major for
/// determinism.  Every step fetches operands per-MAC and spills per-MAC.
fn naive<F: FnMut(Step)>(gm: u64, gn: u64, gk: u64, visit: &mut F) {
    for i in 0..gm {
        for j in 0..gk {
            for r in 0..gn {
                let mut s = Step::new(i, r, j);
                s.load_input = true;
                s.load_weight = true;
                s.psum_spill = r + 1 < gn;
                s.store_out = r + 1 == gn;
                s.scalar_traffic = true;
                visit(s);
            }
        }
    }
}

/// Input stationary (Fig. 1b): nest (i, r, j).  The input tile (i, r)
/// stays while the weight tile walks the row dimension K; psums for the
/// whole output row spill to DRAM every contraction step.
fn is<F: FnMut(Step)>(gm: u64, gn: u64, gk: u64, visit: &mut F) {
    for i in 0..gm {
        for r in 0..gn {
            for j in 0..gk {
                let mut s = Step::new(i, r, j);
                s.load_input = j == 0;
                s.load_weight = true;
                s.psum_fetch = r > 0;
                s.psum_spill = r + 1 < gn;
                s.store_out = r + 1 == gn;
                visit(s);
            }
        }
    }
}

/// Weight stationary (Fig. 1c): nest (j, r, i).  The weight tile (r, j)
/// stays while input tiles stream down M; psums spill per step.
fn ws<F: FnMut(Step)>(gm: u64, gn: u64, gk: u64, visit: &mut F) {
    for j in 0..gk {
        for r in 0..gn {
            for i in 0..gm {
                let mut s = Step::new(i, r, j);
                s.load_input = true;
                s.load_weight = i == 0;
                s.psum_fetch = r > 0;
                s.psum_spill = r + 1 < gn;
                s.store_out = r + 1 == gn;
                visit(s);
            }
        }
    }
}

/// Row-oriented output stationary (Fig. 1d): nest (i, j, r).  The psum
/// tile (i, j) lives on chip across the whole contraction; both operands
/// stream.
fn os_row<F: FnMut(Step)>(gm: u64, gn: u64, gk: u64, visit: &mut F) {
    for i in 0..gm {
        for j in 0..gk {
            for r in 0..gn {
                let mut s = Step::new(i, r, j);
                s.load_input = true;
                s.load_weight = true;
                s.store_out = r + 1 == gn;
                visit(s);
            }
        }
    }
}

/// Column-oriented output stationary (Fig. 1e): nest (j, i, r).
fn os_col<F: FnMut(Step)>(gm: u64, gn: u64, gk: u64, visit: &mut F) {
    for j in 0..gk {
        for i in 0..gm {
            for r in 0..gn {
                let mut s = Step::new(i, r, j);
                s.load_input = true;
                s.load_weight = true;
                s.store_out = r + 1 == gn;
                visit(s);
            }
        }
    }
}

/// IS-OS hybrid (Fig. 2a): nest (i, window over K, r, j-in-window).
/// The input tile (i, r) is temporally reused across the k'-wide window
/// (flag ① in the figure); the window's psums stay in registers across
/// the whole contraction (spatial OS reuse, flag ②); outputs store once
/// when r completes; the input column re-streams per window (flag ③).
fn is_os<F: FnMut(Step)>(gm: u64, gn: u64, gk: u64, wk: u64, visit: &mut F) {
    let windows = ceil_div(gk, wk);
    for i in 0..gm {
        for w in 0..windows {
            let j0 = w * wk;
            let j1 = (j0 + wk).min(gk);
            for r in 0..gn {
                for j in j0..j1 {
                    let mut s = Step::new(i, r, j);
                    s.load_input = j == j0;
                    s.load_weight = true;
                    s.store_out = r + 1 == gn;
                    visit(s);
                }
            }
        }
    }
}

/// WS-OS hybrid (Fig. 2b): nest (j, window over M, r, i-in-window).
/// The weight tile (r, j) is temporally reused across the m'-tall window;
/// the window's psums stay in registers across the contraction; the
/// weight column re-streams per window.
fn ws_os<F: FnMut(Step)>(gm: u64, gn: u64, gk: u64, wm: u64, visit: &mut F) {
    let windows = ceil_div(gm, wm);
    for j in 0..gk {
        for w in 0..windows {
            let i0 = w * wm;
            let i1 = (i0 + wm).min(gm);
            for r in 0..gn {
                for i in i0..i1 {
                    let mut s = Step::new(i, r, j);
                    s.load_input = true;
                    s.load_weight = i == i0;
                    s.store_out = r + 1 == gn;
                    visit(s);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::property;
    use crate::util::prng::Rng;
    use std::collections::HashSet;

    fn collect(scheme: Scheme, shape: &GemmShape, tiling: &Tiling) -> Vec<Step> {
        let mut v = Vec::new();
        for_each_step(scheme, shape, tiling, |s| v.push(s));
        v
    }

    #[test]
    fn every_scheme_covers_each_tile_triple_once() {
        property("schedule coverage", 120, |rng: &mut Rng| {
            let shape = GemmShape::new(
                rng.gen_in(1, 200),
                rng.gen_in(1, 200),
                rng.gen_in(1, 200),
            );
            let t = Tiling::new(
                rng.gen_in(1, 32),
                rng.gen_in(1, 32),
                rng.gen_in(1, 32),
            );
            let (gm, gn, gk) = t.grid(&shape);
            for scheme in Scheme::FIXED {
                let steps = collect(scheme, &shape, &t);
                assert_eq!(steps.len() as u64, gm * gn * gk, "{scheme:?}");
                let uniq: HashSet<(u64, u64, u64)> =
                    steps.iter().map(|s| (s.i, s.r, s.j)).collect();
                assert_eq!(uniq.len(), steps.len(), "{scheme:?} repeats a tile");
            }
        });
    }

    #[test]
    fn every_output_tile_stored_exactly_once() {
        property("store-once", 120, |rng: &mut Rng| {
            let shape = GemmShape::new(
                rng.gen_in(1, 150),
                rng.gen_in(1, 150),
                rng.gen_in(1, 150),
            );
            let t = Tiling::square(*rng.choose(&[4, 8, 16]));
            let (gm, _, gk) = t.grid(&shape);
            for scheme in Scheme::FIXED {
                let stores: Vec<(u64, u64)> = collect(scheme, &shape, &t)
                    .into_iter()
                    .filter(|s| s.store_out)
                    .map(|s| (s.i, s.j))
                    .collect();
                assert_eq!(stores.len() as u64, gm * gk, "{scheme:?}");
                let uniq: HashSet<_> = stores.iter().collect();
                assert_eq!(uniq.len() as u64, gm * gk, "{scheme:?}");
            }
        });
    }

    #[test]
    fn is_keeps_input_tile_stationary() {
        let shape = GemmShape::new(64, 64, 64);
        let t = Tiling::square(16);
        let steps = collect(Scheme::Is, &shape, &t);
        // input loads only at j == 0: one load per (i, r)
        let loads = steps.iter().filter(|s| s.load_input).count() as u64;
        assert_eq!(loads, 4 * 4);
        // between loads, (i, r) never changes
        for w in steps.windows(2) {
            if !w[1].load_input {
                assert_eq!((w[0].i, w[0].r), (w[1].i, w[1].r));
            }
        }
    }

    #[test]
    fn ws_keeps_weight_tile_stationary() {
        let shape = GemmShape::new(64, 64, 64);
        let t = Tiling::square(16);
        let steps = collect(Scheme::Ws, &shape, &t);
        let loads = steps.iter().filter(|s| s.load_weight).count() as u64;
        assert_eq!(loads, 4 * 4); // one per (j, r)
        for w in steps.windows(2) {
            if !w[1].load_weight {
                assert_eq!((w[0].r, w[0].j), (w[1].r, w[1].j));
            }
        }
    }

    #[test]
    fn os_schemes_never_touch_psum_dram() {
        let shape = GemmShape::new(48, 80, 64);
        let t = Tiling::square(16);
        for scheme in [Scheme::OsRow, Scheme::OsCol, Scheme::IsOs, Scheme::WsOs] {
            for s in collect(scheme, &shape, &t) {
                assert!(!s.psum_fetch && !s.psum_spill, "{scheme:?} spilled");
            }
        }
    }

    #[test]
    fn is_os_window_bounds_psum_live_set() {
        // k' = 32 (2 tiles): within one (i, window), j spans <= 2 columns
        // between output stores.
        let shape = GemmShape::new(32, 64, 128);
        let t = Tiling::square(16).with_kp(32);
        let steps = collect(Scheme::IsOs, &shape, &t);
        let mut live: HashSet<(u64, u64)> = HashSet::new();
        let mut peak = 0;
        for s in &steps {
            live.insert((s.i, s.j));
            peak = peak.max(live.len());
            if s.store_out {
                live.remove(&(s.i, s.j));
            }
        }
        assert!(peak <= 2, "psum window exceeded: {peak}");
        assert!(live.is_empty(), "psums left unstored");
    }

    #[test]
    fn ws_os_window_bounds_psum_live_set() {
        let shape = GemmShape::new(128, 64, 32);
        let t = Tiling::square(16).with_mp(32); // m' = 32 -> 2 tile rows
        let steps = collect(Scheme::WsOs, &shape, &t);
        let mut live: HashSet<(u64, u64)> = HashSet::new();
        let mut peak = 0;
        for s in &steps {
            live.insert((s.i, s.j));
            peak = peak.max(live.len());
            if s.store_out {
                live.remove(&(s.i, s.j));
            }
        }
        assert!(peak <= 2, "psum window exceeded: {peak}");
        assert!(live.is_empty());
    }

    #[test]
    fn plain_is_needs_full_output_row_of_psums() {
        // §III-B: plain IS keeps up to K/k psum tiles alive — the
        // motivation for the hybrid.  Measure it.
        let shape = GemmShape::new(32, 64, 256);
        let t = Tiling::square(16);
        let steps = collect(Scheme::Is, &shape, &t);
        let mut live: HashSet<(u64, u64)> = HashSet::new();
        let mut peak = 0;
        for s in &steps {
            live.insert((s.i, s.j));
            peak = peak.max(live.len());
            if s.store_out {
                live.remove(&(s.i, s.j));
            }
        }
        assert_eq!(peak, 16); // K/k = 256/16 tiles live at once
    }

    #[test]
    fn ragged_shapes_still_cover() {
        let shape = GemmShape::new(33, 17, 65);
        let t = Tiling::square(16);
        let (gm, gn, gk) = t.grid(&shape);
        assert_eq!((gm, gn, gk), (3, 2, 5));
        for scheme in Scheme::FIXED {
            assert_eq!(
                collect(scheme, &shape, &t).len() as u64,
                gm * gn * gk
            );
        }
    }
}
