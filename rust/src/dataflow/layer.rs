//! Layer-level planning: chain the GEMMs of one transformer block and let
//! TAS decide stationary **per tile, given what is already SRAM-resident**.
//!
//! The paper optimises each linear projection in isolation.  A transformer
//! block, though, is a *chain* — QKV → attention → output projection →
//! FFN up → FFN down — and the tensor flowing along the chain is exactly
//! the operand TAS keeps stationary on the input side.  In the spirit of
//! cross-operator data-movement optimisation ("Data Movement Is All You
//! Need", Ivanov et al.; multi-core data arrangement, Amirshahi et al.),
//! [`LayerPlan`] models SRAM residency of the intermediate activations:
//!
//! * stages that **share an input** (Q, K, V all read the block input)
//!   load it from DRAM once and reuse it from SRAM when it fits;
//! * stages that **consume the previous stage's output** (FFN up consumes
//!   the attention projection, FFN down consumes FFN up) skip both the
//!   producer's DRAM store and their own DRAM load when the intermediate
//!   fits — elementwise ops between them (LayerNorm, GeLU) operate on the
//!   resident tensor in place and move no DRAM words either way.
//!
//! Each stage then gets a per-tile TAS [`Plan`] built with those residency
//! flags ([`Plan::tas_with_residency`]), so a free input flips the
//! stationary choice toward re-reading it — the decision the per-GEMM sign
//! rule cannot see.  By construction every stage plan is no worse than the
//! per-GEMM TAS hybrid, and residency only removes words, so a layer plan
//! never loses to per-GEMM TAS (property-tested over the model zoo).
//!
//! Weights are never considered resident: one block touches every weight
//! word at most once per forward pass, so parking them in SRAM cannot pay.

use super::analytic;
use super::plan::Plan;
use super::Scheme;
use crate::gemm::{GemmShape, Tiling};

/// One GEMM stage of a transformer block, with its chaining relations.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StageSpec {
    /// Role, e.g. "q", "ffn1".
    pub name: &'static str,
    pub shape: GemmShape,
    /// Instances per forward pass (usually the layer count).
    pub count: u64,
    /// This stage's input is the previous stage's output tensor.
    pub consumes_previous: bool,
    /// This stage reads the same input tensor as the previous stage.
    pub shares_input_with_previous: bool,
    /// K/V-cache relation of this stage, if any: attention stages declare
    /// the cache tensor they append to or read, so the decode planner
    /// ([`super::decode`]) can keep cache blocks SRAM-resident across
    /// autoregressive steps.  `None` for every prefill linear projection.
    pub cache: Option<super::decode::CacheEdge>,
}

/// A planned stage: the per-tile plan plus its residency decisions.
#[derive(Clone, Debug)]
pub struct StagePlan {
    pub spec: StageSpec,
    pub plan: Plan,
    /// Device this stage runs on (0 for single-accelerator plans).
    pub device: usize,
    /// Input served from SRAM (chained or shared) — no DRAM reads.
    pub input_resident: bool,
    /// Output handed to the next stage in SRAM — no DRAM writes.
    pub output_resident: bool,
    /// DRAM words per stage instance under this plan.
    pub ema_words: u64,
    /// DRAM words per instance under per-GEMM TAS (the paper's baseline).
    pub per_gemm_tas_words: u64,
}

/// A planned transformer block (× count per stage = one forward pass).
#[derive(Clone, Debug)]
pub struct LayerPlan {
    pub tokens: u64,
    pub tiling: Tiling,
    /// SRAM words available for parking intermediate activations.
    pub sram_budget: u64,
    pub stages: Vec<StagePlan>,
}

impl LayerPlan {
    /// Plan a chain of stages.  `sram_words` is the total internal SRAM;
    /// a working margin for double-buffered operand tiles is reserved
    /// before any activation may claim residency.
    pub fn plan(stages: Vec<StageSpec>, tokens: u64, tiling: &Tiling, sram_words: u64) -> LayerPlan {
        let placement = vec![0; stages.len()];
        LayerPlan::plan_placed(stages, tokens, tiling, sram_words, placement)
    }

    /// Plan a chain of stages placed on devices (`placement[i]` = device
    /// of stage `i`, e.g. from [`super::shard::place_stages`]).  SRAM is
    /// per-device, so residency only chains stages that share a device;
    /// a chained or shared tensor crossing devices instead becomes an
    /// activation handoff over the interconnect, costed as link traffic
    /// by [`LayerPlan::handoff_words`] — never silently free.
    pub fn plan_placed(
        stages: Vec<StageSpec>,
        tokens: u64,
        tiling: &Tiling,
        sram_words: u64,
        placement: Vec<usize>,
    ) -> LayerPlan {
        assert_eq!(placement.len(), stages.len(), "one device per stage");
        // Reserve space for two double-buffered operand tile pairs.
        let margin = 4 * (tiling.tm * tiling.tn + tiling.tn * tiling.tk);
        let budget = sram_words.saturating_sub(margin);
        let fits = |words: u64| words > 0 && words <= budget;

        let mut planned: Vec<StagePlan> = Vec::with_capacity(stages.len());
        for (idx, spec) in stages.iter().enumerate() {
            let same_device = idx > 0 && placement[idx] == placement[idx - 1];
            let input_resident = if spec.shares_input_with_previous && idx > 0 {
                // The previous stage already streamed this tensor; keep it
                // if it fits.  (The first stage of the sharing group pays
                // the DRAM read.)  Another device's SRAM doesn't help.
                same_device && fits(spec.shape.input_words())
            } else if spec.consumes_previous && idx > 0 {
                // Only resident if the producer could keep its output.
                same_device && planned[idx - 1].output_resident
            } else {
                false
            };
            // The budget is cumulative over what the stage holds at once:
            // a resident output coexists with this stage's resident input
            // (if any) while the stage runs.
            let held_with_output = spec.shape.output_words()
                + if input_resident { spec.shape.input_words() } else { 0 };
            let output_resident = stages
                .get(idx + 1)
                .map(|next| {
                    next.consumes_previous
                        && next.count == spec.count
                        && placement[idx + 1] == placement[idx]
                        && fits(held_with_output)
                })
                .unwrap_or(false);
            let plan = Plan::tas_with_residency(
                &spec.shape,
                tiling,
                input_resident,
                output_resident,
            );
            let ema_words = plan.ema().total();
            let per_gemm_tas_words =
                analytic::ema(Scheme::Tas, &spec.shape, tiling).total();
            planned.push(StagePlan {
                spec: spec.clone(),
                plan,
                device: placement[idx],
                input_resident,
                output_resident,
                ema_words,
                per_gemm_tas_words,
            });
        }
        LayerPlan { tokens, tiling: *tiling, sram_budget: budget, stages: planned }
    }

    /// Total DRAM words of one forward pass under the layer plan.
    pub fn total_ema(&self) -> u64 {
        self.stages.iter().map(|s| s.spec.count * s.ema_words).sum()
    }

    /// Total DRAM words under per-GEMM TAS — the baseline the layer plan
    /// must never exceed.
    pub fn per_gemm_tas_total(&self) -> u64 {
        self.stages
            .iter()
            .map(|s| s.spec.count * s.per_gemm_tas_words)
            .sum()
    }

    /// Fractional saving of layer planning over per-GEMM TAS.
    pub fn reduction_vs_per_gemm(&self) -> f64 {
        let base = self.per_gemm_tas_total();
        if base == 0 {
            0.0
        } else {
            1.0 - self.total_ema() as f64 / base as f64
        }
    }

    /// Stages whose intermediate stayed in SRAM (either direction).
    pub fn resident_edges(&self) -> u64 {
        self.stages
            .iter()
            .map(|s| s.input_resident as u64 + s.output_resident as u64)
            .sum()
    }

    /// Devices the placement spans (1 for single-accelerator plans).
    pub fn devices(&self) -> u64 {
        self.stages.iter().map(|s| s.device).max().unwrap_or(0) as u64 + 1
    }

    /// Activation words crossing inter-chip links per forward pass: each
    /// chained (or input-sharing) edge whose endpoints sit on different
    /// devices hands the consumer's input tensor across a link.
    pub fn handoff_words(&self) -> u64 {
        self.stages
            .windows(2)
            .map(|w| {
                let (prev, s) = (&w[0], &w[1]);
                let crosses = s.device != prev.device
                    && (s.spec.consumes_previous || s.spec.shares_input_with_previous);
                if crosses {
                    s.spec.count * s.spec.shape.input_words()
                } else {
                    0
                }
            })
            .sum()
    }

    /// Per-device DRAM words of one forward pass (length is
    /// [`LayerPlan::devices`]); sums to [`LayerPlan::total_ema`].
    pub fn per_device_ema(&self) -> Vec<u64> {
        let mut out = vec![0u64; self.devices() as usize];
        for s in &self.stages {
            out[s.device] += s.spec.count * s.ema_words;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::GemmShape;

    fn bert_block(tokens: u64) -> Vec<StageSpec> {
        // BERT-Base dims, one layer (count = 1 keeps the numbers small).
        let h = 768;
        let f = 3072;
        let stage = |name, shape, consumes, shares| StageSpec {
            name,
            shape,
            count: 1,
            consumes_previous: consumes,
            shares_input_with_previous: shares,
            cache: None,
        };
        vec![
            stage("q", GemmShape::new(tokens, h, h), false, false),
            stage("k", GemmShape::new(tokens, h, h), false, true),
            stage("v", GemmShape::new(tokens, h, h), false, true),
            stage("attn_out", GemmShape::new(tokens, h, h), false, false),
            stage("ffn1", GemmShape::new(tokens, h, f), true, false),
            stage("ffn2", GemmShape::new(tokens, f, h), true, false),
        ]
    }

    fn plan(tokens: u64, sram: u64) -> LayerPlan {
        LayerPlan::plan(bert_block(tokens), tokens, &Tiling::square(16), sram)
    }

    #[test]
    fn short_sequences_chain_through_sram() {
        // 64×768 activations = 49k words — fits the default 256k SRAM.
        let p = plan(64, 256 * 1024);
        assert!(p.resident_edges() > 0);
        // k and v reuse the block input q already streamed
        assert!(p.stages[1].input_resident && p.stages[2].input_resident);
        assert!(!p.stages[0].input_resident);
        // attn_out -> ffn1 chains; ffn1 output (64×3072 = 196k) fits too
        assert!(p.stages[4].input_resident);
        assert!(p.total_ema() < p.per_gemm_tas_total());
    }

    #[test]
    fn long_sequences_stop_fitting_and_degrade_gracefully() {
        // 4096×3072 = 12.6M words: the ffn1 output cannot stay resident.
        let p = plan(4096, 256 * 1024);
        let ffn2 = p.stages.iter().find(|s| s.spec.name == "ffn2").unwrap();
        assert!(!ffn2.input_resident);
        // but the plan still never loses to per-GEMM TAS
        assert!(p.total_ema() <= p.per_gemm_tas_total());
    }

    #[test]
    fn zero_sram_reduces_to_per_gemm_tas_or_better() {
        let p = plan(384, 0);
        assert_eq!(p.resident_edges(), 0);
        assert!(p.total_ema() <= p.per_gemm_tas_total());
    }

    #[test]
    fn residency_only_ever_removes_words() {
        for tokens in [64, 384, 512, 4096] {
            let with = plan(tokens, 256 * 1024);
            let without = plan(tokens, 0);
            assert!(with.total_ema() <= without.total_ema(), "tokens {tokens}");
        }
    }

    #[test]
    fn residency_budget_is_cumulative_per_stage() {
        // seq 80, BERT-Base dims, 256 KiW SRAM (budget ≈ 260k words):
        // ffn1's input (80×768 ≈ 61k) and output (80×3072 ≈ 246k) each
        // fit alone but not together — output residency must be denied.
        let p = plan(80, 256 * 1024);
        let ffn1 = p.stages.iter().find(|s| s.spec.name == "ffn1").unwrap();
        assert!(ffn1.input_resident);
        assert!(!ffn1.output_resident);
        // at seq 64 the sum (49k + 197k) fits, so the chain holds
        let p64 = plan(64, 256 * 1024);
        let ffn1_64 = p64.stages.iter().find(|s| s.spec.name == "ffn1").unwrap();
        assert!(ffn1_64.input_resident && ffn1_64.output_resident);
    }

    #[test]
    fn cross_device_edges_break_residency_and_become_handoffs() {
        // Split the block at the ffn boundary: qkv+attn on device 0, FFN
        // on device 1.  attn_out -> ffn1 now crosses a link: ffn1 loses
        // input residency and the activation becomes handoff words.
        let stages = bert_block(64);
        let placement = vec![0, 0, 0, 0, 1, 1];
        let single = LayerPlan::plan(bert_block(64), 64, &Tiling::square(16), 256 * 1024);
        let split =
            LayerPlan::plan_placed(stages, 64, &Tiling::square(16), 256 * 1024, placement);
        assert_eq!(split.devices(), 2);
        let ffn1 = split.stages.iter().find(|s| s.spec.name == "ffn1").unwrap();
        assert!(!ffn1.input_resident, "residency must not cross devices");
        assert_eq!(split.handoff_words(), ffn1.spec.shape.input_words());
        assert_eq!(single.handoff_words(), 0);
        // within-device chaining still works (ffn1 -> ffn2 on device 1)
        let ffn2 = split.stages.iter().find(|s| s.spec.name == "ffn2").unwrap();
        assert!(ffn2.input_resident);
        // the split never gains DRAM words it did not pay for as handoff
        assert!(split.total_ema() >= single.total_ema());
    }

    #[test]
    fn per_device_ema_sums_to_total() {
        let stages = bert_block(128);
        let placement = vec![0, 0, 1, 1, 2, 2];
        let p = LayerPlan::plan_placed(stages, 128, &Tiling::square(16), 256 * 1024, placement);
        assert_eq!(p.devices(), 3);
        assert_eq!(p.per_device_ema().iter().sum::<u64>(), p.total_ema());
        assert_eq!(p.per_device_ema().len(), 3);
    }

    #[test]
    fn single_device_placement_is_the_plain_plan() {
        let a = LayerPlan::plan(bert_block(64), 64, &Tiling::square(16), 256 * 1024);
        let b = LayerPlan::plan_placed(
            bert_block(64),
            64,
            &Tiling::square(16),
            256 * 1024,
            vec![0; 6],
        );
        assert_eq!(a.total_ema(), b.total_ema());
        assert_eq!(a.resident_edges(), b.resident_edges());
        assert_eq!(b.handoff_words(), 0);
    }

    #[test]
    fn chain_breaks_when_producer_cannot_keep_output() {
        // consumes_previous only grants residency if the producer's
        // output_resident was set — mismatched counts must not chain.
        let mut stages = bert_block(128);
        stages[5].count = 2; // ffn2 runs twice per ffn1: cannot chain
        let p = LayerPlan::plan(stages, 128, &Tiling::square(16), 256 * 1024);
        let ffn1 = p.stages.iter().find(|s| s.spec.name == "ffn1").unwrap();
        let ffn2 = p.stages.iter().find(|s| s.spec.name == "ffn2").unwrap();
        assert!(!ffn1.output_resident);
        assert!(!ffn2.input_resident);
    }
}
