//! Layer-level planning: chain the GEMMs of one transformer block and let
//! TAS decide stationary **per tile, given what is already SRAM-resident**.
//!
//! The paper optimises each linear projection in isolation.  A transformer
//! block, though, is a *chain* — QKV → attention → output projection →
//! FFN up → FFN down — and the tensor flowing along the chain is exactly
//! the operand TAS keeps stationary on the input side.  In the spirit of
//! cross-operator data-movement optimisation ("Data Movement Is All You
//! Need", Ivanov et al.; multi-core data arrangement, Amirshahi et al.),
//! [`LayerPlan`] models SRAM residency of the intermediate activations:
//!
//! * stages that **share an input** (Q, K, V all read the block input)
//!   load it from DRAM once and reuse the resident rows from SRAM;
//! * stages that **consume the previous stage's output** (FFN up consumes
//!   the attention projection, FFN down consumes FFN up) skip the
//!   producer's DRAM store and their own DRAM load for every resident row
//!   — elementwise ops between them (LayerNorm, GeLU) operate on the
//!   resident tensor in place and move no DRAM words either way.
//!
//! Residency is **fractional** ([`super::residency`]): the
//! [`ResidencyAllocator`] hands SRAM pages (tile rows) to the chain's
//! candidate tensors by marginal EMA saved per word, and a partially
//! resident tensor splits its stages into hot/cold row slices — the hot
//! slice plans with the operand [`Residency::Full`], flipping the per-tile
//! cover toward re-reading the free stream (the decision the per-GEMM
//! sign rule cannot see).  The seed's whole-tensor behaviour survives as
//! [`ResidencyPolicy::AllOrNothing`]; the paged planner prices both and
//! keeps the better plan, so fractional planning never loses to
//! all-or-nothing, which in turn never loses to per-GEMM TAS
//! (property-tested over the model zoo).
//!
//! Block weights are never considered resident here: one *prefill* pass
//! touches every weight word at most once, so parking them cannot pay.
//! (Decode is different — see [`super::decode`], where weights are
//! re-read every step and compete for pages with the K/V cache.)

use super::analytic;
use super::plan::Plan;
use super::residency::{Candidate, Residency, ResidencyAllocator, ResidencyPolicy};
use super::Scheme;
use crate::arch::backend::PlanPricing;
use crate::gemm::{GemmShape, Tiling};

/// One GEMM stage of a transformer block, with its chaining relations.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StageSpec {
    /// Role, e.g. "q", "ffn1".
    pub name: &'static str,
    pub shape: GemmShape,
    /// Instances per forward pass (usually the layer count).
    pub count: u64,
    /// This stage's input is the previous stage's output tensor.
    pub consumes_previous: bool,
    /// This stage reads the same input tensor as the previous stage.
    pub shares_input_with_previous: bool,
    /// K/V-cache relation of this stage, if any: attention stages declare
    /// the cache tensor they append to or read, so the decode planner
    /// ([`super::decode`]) can keep cache blocks SRAM-resident across
    /// autoregressive steps.  `None` for every prefill linear projection.
    pub cache: Option<super::decode::CacheEdge>,
}

/// A planned stage: hot/cold row-slice plans plus residency decisions.
#[derive(Clone, Debug)]
pub struct StagePlan {
    pub spec: StageSpec,
    /// Per-tile plans covering the stage's GEMM, split along M where the
    /// input/output tensors are partially resident (one slice otherwise).
    pub slices: Vec<Plan>,
    /// Device this stage runs on (0 for single-accelerator plans).
    pub device: usize,
    /// Rows of the stage's input served from SRAM (chained or shared).
    pub input: Residency,
    /// Rows of the output handed to the next stage in SRAM.
    pub output: Residency,
    /// DRAM words per stage instance under this plan (summed slices).
    pub ema_words: u64,
    /// DRAM words per instance under per-GEMM TAS (the paper's baseline).
    pub per_gemm_tas_words: u64,
}

impl StagePlan {
    /// Decision summary across the stage's slices, e.g. `"is-os"` or
    /// `"ws-os + is-os"` for a hot/cold split.
    pub fn describe(&self) -> String {
        self.slices
            .iter()
            .map(|p| p.describe())
            .collect::<Vec<_>>()
            .join(" + ")
    }
}

/// A planned transformer block (× count per stage = one forward pass).
#[derive(Clone, Debug)]
pub struct LayerPlan {
    pub tokens: u64,
    pub tiling: Tiling,
    /// SRAM words available for parking intermediate activations.
    pub sram_budget: u64,
    /// Residency model that produced this plan.  A paged request that
    /// lost to the all-or-nothing walk reports `AllOrNothing` — the
    /// planner keeps whichever plan moves fewer words.
    pub policy: ResidencyPolicy,
    /// Largest SRAM claim of resident activations at any stage of the
    /// chain — never exceeds [`LayerPlan::sram_budget`].
    pub resident_peak_words: u64,
    pub stages: Vec<StagePlan>,
}

/// Build the hot/cold row-slice plans of one stage: the input tensor's
/// leading `hot_in` rows and the output tensor's leading `hot_out` rows
/// are SRAM-resident; segments between the cut points plan independently
/// with full/none residency per stream.
fn segment_plans(
    shape: &GemmShape,
    tiling: &Tiling,
    hot_in: u64,
    hot_out: u64,
    pricing: &PlanPricing,
) -> Vec<Plan> {
    let m = shape.m;
    let hi = hot_in.min(m);
    let ho = hot_out.min(m);
    let mut cuts = [hi, ho, m];
    cuts.sort_unstable();
    let mut plans = Vec::new();
    let mut start = 0u64;
    for &cut in &cuts {
        if cut <= start {
            continue;
        }
        let seg = GemmShape::new(cut - start, shape.n, shape.k);
        let in_res = if cut <= hi { Residency::Full } else { Residency::None };
        let out_res = if cut <= ho { Residency::Full } else { Residency::None };
        plans.push(Plan::tas_priced(&seg, tiling, in_res, Residency::None, out_res, pricing));
        start = cut;
    }
    plans
}

/// Words the backend actually moves for the hot/cold slicing — the
/// quantity the residency allocator maximises savings against, so a
/// backend that never streams an operand contributes nothing for parking
/// it (the knapsack prices operands via backend costs, not special-case
/// flags).
fn segments_cost(
    shape: &GemmShape,
    tiling: &Tiling,
    hot_in: u64,
    hot_out: u64,
    pricing: &PlanPricing,
) -> u64 {
    segment_plans(shape, tiling, hot_in, hot_out, pricing)
        .iter()
        .map(|p| p.ema_words_charged(pricing.charge))
        .sum()
}

/// One tensor the chain can park in SRAM.
enum EdgeKind {
    /// Stages re-reading the tensor the group leader streams (k, v after
    /// q): resident rows save their input reads; the rows are also in
    /// DRAM, so a sharer keeps them only when its sliced plan wins.
    Shared { sharers: Vec<usize> },
    /// `consumer` reads exactly what `producer` wrote: resident rows are
    /// never stored or re-loaded.  Producer and consumer slice together.
    Chained { producer: usize, consumer: usize },
}

struct ResidencyEdge {
    kind: EdgeKind,
    /// Rows of the tensor (tokens).
    rows: u64,
    /// SRAM words per resident row.
    row_words: u64,
    /// Stage interval the resident rows are held across.
    live: std::ops::Range<usize>,
    /// Instances per forward pass (stage counts along the edge agree).
    count: u64,
}

impl LayerPlan {
    /// Plan a chain of stages under the paged (fractional) policy.
    /// `sram_words` is the total internal SRAM; a working margin for
    /// double-buffered operand tiles is reserved before any activation
    /// may claim residency.
    pub fn plan(stages: Vec<StageSpec>, tokens: u64, tiling: &Tiling, sram_words: u64) -> LayerPlan {
        let placement = vec![0; stages.len()];
        LayerPlan::plan_placed(stages, tokens, tiling, sram_words, placement)
    }

    /// [`LayerPlan::plan`] with an explicit residency policy — the
    /// all-or-nothing variant is the seed behaviour, kept as the baseline
    /// the paged planner must never lose to (and benched against).
    pub fn plan_with_policy(
        stages: Vec<StageSpec>,
        tokens: u64,
        tiling: &Tiling,
        sram_words: u64,
        policy: ResidencyPolicy,
    ) -> LayerPlan {
        let placement = vec![0; stages.len()];
        LayerPlan::plan_placed_policy(stages, tokens, tiling, sram_words, placement, policy)
    }

    /// Plan a chain of stages placed on devices (`placement[i]` = device
    /// of stage `i`, e.g. from [`super::shard::place_stages`]).  SRAM is
    /// per-device, so residency only chains stages that share a device;
    /// a chained or shared tensor crossing devices instead becomes an
    /// activation handoff over the interconnect, costed as link traffic
    /// by [`LayerPlan::handoff_words`] — never silently free.
    pub fn plan_placed(
        stages: Vec<StageSpec>,
        tokens: u64,
        tiling: &Tiling,
        sram_words: u64,
        placement: Vec<usize>,
    ) -> LayerPlan {
        LayerPlan::plan_placed_policy(
            stages,
            tokens,
            tiling,
            sram_words,
            placement,
            ResidencyPolicy::Paged,
        )
    }

    pub fn plan_placed_policy(
        stages: Vec<StageSpec>,
        tokens: u64,
        tiling: &Tiling,
        sram_words: u64,
        placement: Vec<usize>,
        policy: ResidencyPolicy,
    ) -> LayerPlan {
        LayerPlan::plan_placed_policy_priced(
            stages,
            tokens,
            tiling,
            sram_words,
            placement,
            policy,
            &PlanPricing::systolic(),
        )
    }

    /// [`LayerPlan::plan`] priced by a backend: per-stage covers come from
    /// [`Plan::tas_priced`] and the residency knapsack values each edge by
    /// the words the backend actually streams
    /// ([`Plan::ema_words_charged`]).  Under a backend whose weights are
    /// pinned (crossbar), every cover degenerates to activation-stationary
    /// and weight-side residency saves nothing — by pricing, not by
    /// special case.  Systolic pricing reproduces [`LayerPlan::plan`]
    /// exactly.
    pub fn plan_priced(
        stages: Vec<StageSpec>,
        tokens: u64,
        tiling: &Tiling,
        sram_words: u64,
        pricing: &PlanPricing,
    ) -> LayerPlan {
        let placement = vec![0; stages.len()];
        LayerPlan::plan_placed_policy_priced(
            stages,
            tokens,
            tiling,
            sram_words,
            placement,
            ResidencyPolicy::Paged,
            pricing,
        )
    }

    pub fn plan_placed_policy_priced(
        stages: Vec<StageSpec>,
        tokens: u64,
        tiling: &Tiling,
        sram_words: u64,
        placement: Vec<usize>,
        policy: ResidencyPolicy,
        pricing: &PlanPricing,
    ) -> LayerPlan {
        assert_eq!(placement.len(), stages.len(), "one device per stage");
        // Reserve space for two double-buffered operand tile pairs.
        let margin = 4 * (tiling.tm * tiling.tn + tiling.tn * tiling.tk);
        let budget = sram_words.saturating_sub(margin);
        let pricing = *pricing;
        match policy {
            ResidencyPolicy::Off => {
                let mut p = LayerPlan::plan_all_or_nothing(
                    stages, tokens, tiling, 0, &placement, &pricing,
                );
                p.policy = ResidencyPolicy::Off;
                p
            }
            ResidencyPolicy::AllOrNothing => LayerPlan::plan_all_or_nothing(
                stages, tokens, tiling, budget, &placement, &pricing,
            ),
            ResidencyPolicy::Paged => {
                // Price both; fractional planning must never lose to the
                // whole-tensor walk, so keep whichever moves fewer words.
                // The two walks share no state, so the all-or-nothing
                // baseline prices on a scoped worker while this thread
                // runs the paged planner.
                let stages_aon = stages.clone();
                let placement_ref: &[usize] = &placement;
                let (aon, paged) = std::thread::scope(|scope| {
                    let aon = scope.spawn(move || {
                        LayerPlan::plan_all_or_nothing(
                            stages_aon, tokens, tiling, budget, placement_ref, &pricing,
                        )
                    });
                    let paged = LayerPlan::plan_paged(
                        stages, tokens, tiling, budget, placement_ref, &pricing,
                    );
                    (aon.join().expect("all-or-nothing planner panicked"), paged)
                });
                if paged.total_ema() <= aon.total_ema() {
                    paged
                } else {
                    aon
                }
            }
        }
    }

    /// The seed walk: whole tensors only, first-fit along the chain.
    fn plan_all_or_nothing(
        stages: Vec<StageSpec>,
        tokens: u64,
        tiling: &Tiling,
        budget: u64,
        placement: &[usize],
        pricing: &PlanPricing,
    ) -> LayerPlan {
        let fits = |words: u64| words > 0 && words <= budget;
        let mut planned: Vec<StagePlan> = Vec::with_capacity(stages.len());
        let mut peak = 0u64;
        for (idx, spec) in stages.iter().enumerate() {
            let same_device = idx > 0 && placement[idx] == placement[idx - 1];
            let input_resident = if spec.shares_input_with_previous && idx > 0 {
                // The previous stage already streamed this tensor; keep it
                // if it fits.  (The first stage of the sharing group pays
                // the DRAM read.)  Another device's SRAM doesn't help.
                same_device && fits(spec.shape.input_words())
            } else if spec.consumes_previous && idx > 0 {
                // Only resident if the producer could keep its output.
                same_device && planned[idx - 1].output.is_free()
            } else {
                false
            };
            // The budget is cumulative over what the stage holds at once:
            // a resident output coexists with this stage's resident input
            // (if any) while the stage runs.
            let held_with_output = spec.shape.output_words()
                + if input_resident { spec.shape.input_words() } else { 0 };
            let output_resident = stages
                .get(idx + 1)
                .map(|next| {
                    next.consumes_previous
                        && next.count == spec.count
                        && placement[idx + 1] == placement[idx]
                        && fits(held_with_output)
                })
                .unwrap_or(false);
            let held = (if output_resident { held_with_output } else { 0 })
                .max(if input_resident { spec.shape.input_words() } else { 0 });
            peak = peak.max(held);
            let input = if input_resident { Residency::Full } else { Residency::None };
            let output = if output_resident { Residency::Full } else { Residency::None };
            let plan =
                Plan::tas_priced(&spec.shape, tiling, input, Residency::None, output, pricing);
            let ema_words = plan.ema_words_charged(pricing.charge);
            let per_gemm_tas_words =
                analytic::ema(Scheme::Tas, &spec.shape, tiling).total();
            planned.push(StagePlan {
                spec: spec.clone(),
                slices: vec![plan],
                device: placement[idx],
                input,
                output,
                ema_words,
                per_gemm_tas_words,
            });
        }
        LayerPlan {
            tokens,
            tiling: *tiling,
            sram_budget: budget,
            policy: ResidencyPolicy::AllOrNothing,
            resident_peak_words: peak,
            stages: planned,
        }
    }

    /// Collect the chain's candidate tensors for the allocator.
    fn residency_edges(stages: &[StageSpec], placement: &[usize]) -> Vec<ResidencyEdge> {
        let n = stages.len();
        let mut edges = Vec::new();
        // Shared-input groups: a maximal run of `shares_input_with_previous`
        // stages re-reads the tensor their leader streams.
        let mut idx = 1;
        while idx < n {
            if stages[idx].shares_input_with_previous {
                let leader = idx - 1;
                let mut end = idx;
                while end + 1 < n && stages[end + 1].shares_input_with_previous {
                    end += 1;
                }
                let sharers: Vec<usize> = (idx..=end)
                    .filter(|&s| {
                        placement[s] == placement[leader]
                            && stages[s].shape.m == stages[leader].shape.m
                            && stages[s].shape.n == stages[leader].shape.n
                            && stages[s].count == stages[leader].count
                    })
                    .collect();
                if !sharers.is_empty() {
                    edges.push(ResidencyEdge {
                        kind: EdgeKind::Shared { sharers },
                        rows: stages[leader].shape.m,
                        row_words: stages[leader].shape.n,
                        live: leader..end + 1,
                        count: stages[leader].count,
                    });
                }
                idx = end + 1;
            } else {
                idx += 1;
            }
        }
        // Chained intermediates: producer output == consumer input.
        for idx in 1..n {
            let (p, s) = (&stages[idx - 1], &stages[idx]);
            if s.consumes_previous
                && s.count == p.count
                && placement[idx] == placement[idx - 1]
                && s.shape.m == p.shape.m
                && s.shape.n == p.shape.k
            {
                edges.push(ResidencyEdge {
                    kind: EdgeKind::Chained { producer: idx - 1, consumer: idx },
                    rows: s.shape.m,
                    row_words: s.shape.n,
                    live: idx - 1..idx + 1,
                    count: s.count,
                });
            }
        }
        edges
    }

    /// The fractional planner: allocate tile-row pages to the chain's
    /// tensors by marginal EMA saved per word, then build hot/cold slice
    /// plans from the allocation.
    fn plan_paged(
        stages: Vec<StageSpec>,
        tokens: u64,
        tiling: &Tiling,
        budget: u64,
        placement: &[usize],
        pricing: &PlanPricing,
    ) -> LayerPlan {
        use std::cell::RefCell;
        use std::collections::HashMap;
        let pricing = *pricing;
        let n = stages.len();
        let edges = LayerPlan::residency_edges(&stages, placement);
        let page_rows = tiling.tm.max(1);

        // Exact savings per candidate, priced through the slice planner
        // itself (other edges held cold — interactions are second-order
        // and the final plan is compared against all-or-nothing anyway).
        // The allocator probes the same (shape, hot_in, hot_out) triples
        // many times across rounds, so cover searches are memoised — the
        // layer-planner twin of decode's PlanMemo.
        let memo: RefCell<HashMap<(GemmShape, u64, u64), u64>> =
            RefCell::new(HashMap::new());
        // Warm the memo concurrently before the sequential greedy runs:
        // the allocator's first rounds probe every stage at its base cost
        // and every edge at its full-residency endpoints, and those cover
        // searches dominate planning time.  Scoring each distinct
        // (shape, hot_in, hot_out) triple on a scoped worker leaves the
        // greedy itself untouched — it reads the same memoised numbers it
        // would have computed inline, so the allocation is unchanged.
        {
            let mut seen: std::collections::HashSet<(GemmShape, u64, u64)> =
                std::collections::HashSet::new();
            let mut probes: Vec<(GemmShape, u64, u64)> = Vec::new();
            let mut probe = |shape: &GemmShape, hot_in: u64, hot_out: u64| {
                let key = (*shape, hot_in.min(shape.m), hot_out.min(shape.m));
                if seen.insert(key) {
                    probes.push(key);
                }
            };
            for spec in &stages {
                probe(&spec.shape, 0, 0);
            }
            for e in &edges {
                match &e.kind {
                    EdgeKind::Shared { sharers } => {
                        for &s in sharers {
                            probe(&stages[s].shape, e.rows, 0);
                        }
                    }
                    EdgeKind::Chained { producer, consumer } => {
                        probe(&stages[*producer].shape, 0, e.rows);
                        probe(&stages[*consumer].shape, e.rows, 0);
                    }
                }
            }
            let costs: Vec<u64> = std::thread::scope(|scope| {
                let handles: Vec<_> = probes
                    .iter()
                    .map(|&(shape, hi, ho)| {
                        scope.spawn(move || segments_cost(&shape, tiling, hi, ho, &pricing))
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("slice-cost worker panicked"))
                    .collect()
            });
            memo.borrow_mut().extend(probes.into_iter().zip(costs));
        }
        let seg_cost = |shape: &GemmShape, hot_in: u64, hot_out: u64| -> u64 {
            let key = (*shape, hot_in.min(shape.m), hot_out.min(shape.m));
            if let Some(&c) = memo.borrow().get(&key) {
                return c;
            }
            let c = segments_cost(shape, tiling, hot_in, hot_out, &pricing);
            memo.borrow_mut().insert(key, c);
            c
        };
        let seg_cost = &seg_cost;
        let stages_ref = &stages;
        let base_cost = move |idx: usize| seg_cost(&stages_ref[idx].shape, 0, 0);
        let candidates: Vec<Candidate> = edges
            .iter()
            .map(|e| {
                let rows = e.rows;
                let count = e.count;
                Candidate {
                    label: match &e.kind {
                        EdgeKind::Shared { sharers } => format!("shared@{}", sharers[0]),
                        EdgeKind::Chained { consumer, .. } => format!("chain@{consumer}"),
                    },
                    page_words: page_rows * e.row_words,
                    max_pages: e.rows.div_ceil(page_rows),
                    live: e.live.clone(),
                    saving: match &e.kind {
                        EdgeKind::Shared { sharers } => {
                            let sharers = sharers.clone();
                            Box::new(move |pages: u64| {
                                let hot = (pages * page_rows).min(rows);
                                sharers
                                    .iter()
                                    .map(|&s| {
                                        let base = base_cost(s);
                                        let sliced =
                                            seg_cost(&stages_ref[s].shape, hot, 0);
                                        count * base.saturating_sub(sliced.min(base))
                                    })
                                    .sum()
                            })
                        }
                        EdgeKind::Chained { producer, consumer } => {
                            let (p, c) = (*producer, *consumer);
                            Box::new(move |pages: u64| {
                                let hot = (pages * page_rows).min(rows);
                                let (base_p, base_c) = (base_cost(p), base_cost(c));
                                let sliced_p = seg_cost(&stages_ref[p].shape, 0, hot);
                                let sliced_c = seg_cost(&stages_ref[c].shape, hot, 0);
                                // Either endpoint regressing (possible at
                                // segment boundaries under psum windows)
                                // voids the edge: residency must only
                                // ever remove words, per stage.
                                if sliced_p > base_p || sliced_c > base_c {
                                    0
                                } else {
                                    count * ((base_p - sliced_p) + (base_c - sliced_c))
                                }
                            })
                        }
                    },
                }
            })
            .collect();

        let alloc = ResidencyAllocator::new(budget, n.max(1)).allocate(&candidates);
        drop(candidates);

        // Resolve the allocation into per-stage hot input/output rows.
        let mut hot_in = vec![0u64; n];
        let mut hot_out = vec![0u64; n];
        let mut shared_consumer = vec![false; n];
        for (e, &pages) in edges.iter().zip(&alloc.pages) {
            let hot = (pages * page_rows).min(e.rows);
            if hot == 0 {
                continue;
            }
            match &e.kind {
                EdgeKind::Shared { sharers } => {
                    for &s in sharers {
                        hot_in[s] = hot;
                        shared_consumer[s] = true;
                    }
                }
                EdgeKind::Chained { producer, consumer } => {
                    hot_out[*producer] = hot;
                    hot_in[*consumer] = hot;
                }
            }
        }

        // Build, then drop any edge touching a stage that regressed below
        // its own unsplit per-tile cost (possible at segment boundaries
        // under psum windows): residency must only ever remove words, per
        // stage — the invariant `tests/plan_equivalence.rs` pins.  Each
        // round removes at least one edge, so this terminates at the
        // plain per-tile plan in the worst case.
        loop {
            let mut regressed: Option<usize> = None;
            for (idx, spec) in stages_ref.iter().enumerate() {
                let mut hi = hot_in[idx];
                if shared_consumer[idx]
                    && hi > 0
                    && seg_cost(&spec.shape, 0, hot_out[idx])
                        < seg_cost(&spec.shape, hi, hot_out[idx])
                {
                    // A shared tensor also lives in DRAM (its leader
                    // streamed it from there), so a sharer may ignore the
                    // hot rows if streaming whole is cheaper.
                    hot_in[idx] = 0;
                    hi = 0;
                }
                let built = seg_cost(&spec.shape, hi, hot_out[idx]);
                if built > seg_cost(&spec.shape, 0, 0) {
                    regressed = Some(idx);
                    break;
                }
            }
            let Some(idx) = regressed else { break };
            // Void every edge touching the regressing stage (and the far
            // endpoint of each chained edge — rows a producer keeps are
            // rows its consumer must use, so the pair drops together).
            for e in &edges {
                match &e.kind {
                    EdgeKind::Shared { sharers } => {
                        if sharers.contains(&idx) {
                            hot_in[idx] = 0;
                        }
                    }
                    EdgeKind::Chained { producer, consumer } => {
                        if *producer == idx || *consumer == idx {
                            hot_out[*producer] = 0;
                            hot_in[*consumer] = 0;
                        }
                    }
                }
            }
        }

        let mut planned: Vec<StagePlan> = Vec::with_capacity(n);
        for (idx, spec) in stages.iter().enumerate() {
            let m = spec.shape.m;
            let (hi, ho) = (hot_in[idx], hot_out[idx]);
            let slices = segment_plans(&spec.shape, tiling, hi, ho, &pricing);
            let ema_words: u64 =
                slices.iter().map(|p| p.ema_words_charged(pricing.charge)).sum();
            let per_gemm_tas_words =
                analytic::ema(Scheme::Tas, &spec.shape, tiling).total();
            planned.push(StagePlan {
                spec: spec.clone(),
                slices,
                device: placement[idx],
                input: Residency::rows(hi, m),
                output: Residency::rows(ho, m),
                ema_words,
                per_gemm_tas_words,
            });
        }
        LayerPlan {
            tokens,
            tiling: *tiling,
            sram_budget: budget,
            policy: ResidencyPolicy::Paged,
            resident_peak_words: alloc.peak_words,
            stages: planned,
        }
    }

    /// Total DRAM words of one forward pass under the layer plan.
    pub fn total_ema(&self) -> u64 {
        self.stages.iter().map(|s| s.spec.count * s.ema_words).sum()
    }

    /// Total DRAM words under per-GEMM TAS — the baseline the layer plan
    /// must never exceed.
    pub fn per_gemm_tas_total(&self) -> u64 {
        self.stages
            .iter()
            .map(|s| s.spec.count * s.per_gemm_tas_words)
            .sum()
    }

    /// Fractional saving of layer planning over per-GEMM TAS.
    pub fn reduction_vs_per_gemm(&self) -> f64 {
        let base = self.per_gemm_tas_total();
        if base == 0 {
            0.0
        } else {
            1.0 - self.total_ema() as f64 / base as f64
        }
    }

    /// Stages whose intermediate stayed in SRAM (either direction, whole
    /// or partial).
    pub fn resident_edges(&self) -> u64 {
        self.stages
            .iter()
            .map(|s| !s.input.is_none() as u64 + !s.output.is_none() as u64)
            .sum()
    }

    /// Total SRAM-resident input rows across the chain's stages — the
    /// `R` column `tas sweep --json` reports.
    pub fn resident_rows(&self) -> u64 {
        self.stages
            .iter()
            .map(|s| s.input.hot_in(s.spec.shape.m))
            .sum()
    }

    /// Devices the placement spans (1 for single-accelerator plans).
    pub fn devices(&self) -> u64 {
        self.stages.iter().map(|s| s.device).max().unwrap_or(0) as u64 + 1
    }

    /// Activation words crossing inter-chip links per forward pass: each
    /// chained (or input-sharing) edge whose endpoints sit on different
    /// devices hands the consumer's input tensor across a link.
    pub fn handoff_words(&self) -> u64 {
        self.stages
            .windows(2)
            .map(|w| {
                let (prev, s) = (&w[0], &w[1]);
                let crosses = s.device != prev.device
                    && (s.spec.consumes_previous || s.spec.shares_input_with_previous);
                if crosses {
                    s.spec.count * s.spec.shape.input_words()
                } else {
                    0
                }
            })
            .sum()
    }

    /// Per-device DRAM words of one forward pass (length is
    /// [`LayerPlan::devices`]); sums to [`LayerPlan::total_ema`].
    pub fn per_device_ema(&self) -> Vec<u64> {
        let mut out = vec![0u64; self.devices() as usize];
        for s in &self.stages {
            out[s.device] += s.spec.count * s.ema_words;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::GemmShape;

    fn bert_block(tokens: u64) -> Vec<StageSpec> {
        // BERT-Base dims, one layer (count = 1 keeps the numbers small).
        let h = 768;
        let f = 3072;
        let stage = |name, shape, consumes, shares| StageSpec {
            name,
            shape,
            count: 1,
            consumes_previous: consumes,
            shares_input_with_previous: shares,
            cache: None,
        };
        vec![
            stage("q", GemmShape::new(tokens, h, h), false, false),
            stage("k", GemmShape::new(tokens, h, h), false, true),
            stage("v", GemmShape::new(tokens, h, h), false, true),
            stage("attn_out", GemmShape::new(tokens, h, h), false, false),
            stage("ffn1", GemmShape::new(tokens, h, f), true, false),
            stage("ffn2", GemmShape::new(tokens, f, h), true, false),
        ]
    }

    fn plan(tokens: u64, sram: u64) -> LayerPlan {
        LayerPlan::plan(bert_block(tokens), tokens, &Tiling::square(16), sram)
    }

    fn plan_aon(tokens: u64, sram: u64) -> LayerPlan {
        LayerPlan::plan_with_policy(
            bert_block(tokens),
            tokens,
            &Tiling::square(16),
            sram,
            ResidencyPolicy::AllOrNothing,
        )
    }

    #[test]
    fn short_sequences_chain_through_sram() {
        // 64×768 activations = 49k words — fits the default 256k SRAM.
        let p = plan(64, 256 * 1024);
        assert!(p.resident_edges() > 0);
        // k and v reuse the block input q already streamed
        assert!(p.stages[1].input.is_free() && p.stages[2].input.is_free());
        assert!(p.stages[0].input.is_none());
        // attn_out -> ffn1 chains; ffn1 output (64×3072 = 196k) fits too
        assert!(p.stages[4].input.is_free());
        assert!(p.total_ema() < p.per_gemm_tas_total());
    }

    #[test]
    fn long_sequences_gain_partial_residency() {
        // 4096×3072 = 12.6M words: no intermediate fits whole, so the seed
        // walk degraded to per-GEMM TAS.  The paged planner parks hot tile
        // rows instead and must now strictly win.
        let p = plan(4096, 256 * 1024);
        let aon = plan_aon(4096, 256 * 1024);
        assert_eq!(aon.resident_edges(), 0, "nothing fits whole at seq 4096");
        assert!(p.total_ema() <= aon.total_ema());
        assert!(
            p.total_ema() < p.per_gemm_tas_total(),
            "partial residency should beat per-GEMM TAS at long seq"
        );
        // some stage is partially resident
        assert!(p.stages.iter().any(|s| s.input.is_partial() || s.output.is_partial()));
    }

    #[test]
    fn zero_sram_reduces_to_per_gemm_tas_or_better() {
        let p = plan(384, 0);
        assert_eq!(p.resident_edges(), 0);
        assert!(p.total_ema() <= p.per_gemm_tas_total());
    }

    #[test]
    fn residency_only_ever_removes_words() {
        for tokens in [64, 384, 512, 4096] {
            let with = plan(tokens, 256 * 1024);
            let without = plan(tokens, 0);
            assert!(with.total_ema() <= without.total_ema(), "tokens {tokens}");
        }
    }

    #[test]
    fn paged_never_loses_to_all_or_nothing() {
        for tokens in [64, 80, 256, 338, 384, 512, 4096] {
            let paged = plan(tokens, 256 * 1024);
            let aon = plan_aon(tokens, 256 * 1024);
            assert!(
                paged.total_ema() <= aon.total_ema(),
                "tokens {tokens}: paged {} > aon {}",
                paged.total_ema(),
                aon.total_ema()
            );
            assert!(paged.resident_peak_words <= paged.sram_budget.max(1));
        }
    }

    #[test]
    fn slices_partition_each_stage() {
        let p = plan(384, 256 * 1024);
        for s in &p.stages {
            let rows: u64 = s.slices.iter().map(|pl| pl.shape.m).sum();
            assert_eq!(rows, s.spec.shape.m, "{}", s.spec.name);
            for pl in &s.slices {
                assert_eq!(pl.shape.n, s.spec.shape.n);
                assert_eq!(pl.shape.k, s.spec.shape.k);
            }
        }
    }

    #[test]
    fn mid_sequences_beat_per_gemm_via_partial_rows() {
        // seq 384 at 256 KiW: the 384×768 block input no longer fits whole
        // (294912 words > the ~260k budget), so the all-or-nothing walk
        // equals per-GEMM TAS; parking ~21 tile-row pages flips the k/v
        // covers and must win — the ISSUE's acceptance configuration.
        let p = plan(384, 256 * 1024);
        let aon = plan_aon(384, 256 * 1024);
        assert_eq!(aon.total_ema(), aon.per_gemm_tas_total());
        assert!(p.total_ema() < p.per_gemm_tas_total());
    }

    #[test]
    fn cross_device_edges_break_residency_and_become_handoffs() {
        // Split the block at the ffn boundary: qkv+attn on device 0, FFN
        // on device 1.  attn_out -> ffn1 now crosses a link: ffn1 loses
        // input residency and the activation becomes handoff words.
        let stages = bert_block(64);
        let placement = vec![0, 0, 0, 0, 1, 1];
        let single = LayerPlan::plan(bert_block(64), 64, &Tiling::square(16), 256 * 1024);
        let split =
            LayerPlan::plan_placed(stages, 64, &Tiling::square(16), 256 * 1024, placement);
        assert_eq!(split.devices(), 2);
        let ffn1 = split.stages.iter().find(|s| s.spec.name == "ffn1").unwrap();
        assert!(ffn1.input.is_none(), "residency must not cross devices");
        assert_eq!(split.handoff_words(), ffn1.spec.shape.input_words());
        assert_eq!(single.handoff_words(), 0);
        // within-device chaining still works (ffn1 -> ffn2 on device 1)
        let ffn2 = split.stages.iter().find(|s| s.spec.name == "ffn2").unwrap();
        assert!(!ffn2.input.is_none());
        // the split never gains DRAM words it did not pay for as handoff
        assert!(split.total_ema() >= single.total_ema());
    }

    #[test]
    fn per_device_ema_sums_to_total() {
        let stages = bert_block(128);
        let placement = vec![0, 0, 1, 1, 2, 2];
        let p = LayerPlan::plan_placed(stages, 128, &Tiling::square(16), 256 * 1024, placement);
        assert_eq!(p.devices(), 3);
        assert_eq!(p.per_device_ema().iter().sum::<u64>(), p.total_ema());
        assert_eq!(p.per_device_ema().len(), 3);
    }

    #[test]
    fn single_device_placement_is_the_plain_plan() {
        let a = LayerPlan::plan(bert_block(64), 64, &Tiling::square(16), 256 * 1024);
        let b = LayerPlan::plan_placed(
            bert_block(64),
            64,
            &Tiling::square(16),
            256 * 1024,
            vec![0; 6],
        );
        assert_eq!(a.total_ema(), b.total_ema());
        assert_eq!(a.resident_edges(), b.resident_edges());
        assert_eq!(b.handoff_words(), 0);
    }

    #[test]
    fn chain_breaks_when_producer_cannot_keep_output() {
        // consumes_previous only grants residency if the counts agree —
        // mismatched counts must not chain (whole or partial).
        let mut stages = bert_block(128);
        stages[5].count = 2; // ffn2 runs twice per ffn1: cannot chain
        let p = LayerPlan::plan(stages, 128, &Tiling::square(16), 256 * 1024);
        let ffn1 = p.stages.iter().find(|s| s.spec.name == "ffn1").unwrap();
        let ffn2 = p.stages.iter().find(|s| s.spec.name == "ffn2").unwrap();
        assert!(ffn2.input.is_none());
        assert!(ffn1.output.is_none());
    }

    #[test]
    fn segment_plans_cover_and_price_residency() {
        let shape = GemmShape::new(384, 768, 768);
        let t = Tiling::square(16);
        let pricing = PlanPricing::systolic();
        let segs = segment_plans(&shape, &t, 336, 64, &pricing);
        let rows: u64 = segs.iter().map(|p| p.shape.m).sum();
        assert_eq!(rows, 384);
        assert_eq!(segs.len(), 3); // [0,64) both, [64,336) input, [336,384) none
        assert!(segs[0].input_residency.is_free() && segs[0].output_residency.is_free());
        assert!(segs[1].input_residency.is_free() && !segs[1].output_residency.is_free());
        assert!(!segs[2].input_residency.is_free());
        // resident rows only remove words
        let sliced: u64 = segs.iter().map(|p| p.ema().total()).sum();
        assert!(sliced < segments_cost(&shape, &t, 0, 0, &pricing));
    }

    #[test]
    fn crossbar_pricing_voids_weight_residency_value() {
        // Under crossbar pricing the planner still plans (activation
        // residency keeps saving input/output traffic), and every chosen
        // cover is activation-stationary because streamed weights cost
        // nothing — the sign rule prices them out, no special case.
        let pricing = PlanPricing::crossbar();
        let t = Tiling::square(16);
        let plan = LayerPlan::plan_priced(bert_block(512), 512, &t, 1 << 20, &pricing);
        for stage in &plan.stages {
            for p in &stage.slices {
                let (is_tiles, ws_tiles, _) = p.tile_mix();
                assert_eq!(ws_tiles, 0, "crossbar cover chose a WS tile");
                assert!(is_tiles > 0);
            }
        }
        // Layer planning must still beat or match per-stage planning on
        // the words the backend actually moves.
        let base: u64 = plan
            .stages
            .iter()
            .map(|s| {
                s.spec.count
                    * Plan::tas_priced(
                        &s.spec.shape,
                        &t,
                        Residency::None,
                        Residency::None,
                        Residency::None,
                        &pricing,
                    )
                    .ema_words_charged(pricing.charge)
            })
            .sum();
        assert!(plan.total_ema() <= base);
    }

    #[test]
    fn systolic_priced_layer_plan_matches_unpriced() {
        let t = Tiling::square(16);
        for tokens in [96u64, 512, 2048] {
            let a = LayerPlan::plan(bert_block(tokens), tokens, &t, 1 << 20);
            let b = LayerPlan::plan_priced(
                bert_block(tokens),
                tokens,
                &t,
                1 << 20,
                &PlanPricing::systolic(),
            );
            assert_eq!(a.total_ema(), b.total_ema(), "tokens={tokens}");
            assert_eq!(a.resident_peak_words, b.resident_peak_words);
        }
    }
}
