//! Dataflow schedules for tiled matrix–matrix multiplication — the paper's
//! subject matter.
//!
//! * [`Scheme`] — every stationary scheme from Fig. 1 (fixed) and Fig. 2
//!   (proposed hybrids), plus the adaptive TAS selector.
//! * [`schedule`] — exact tile-step generators (loop nests + DRAM flags).
//! * [`analytic`] — closed-form EMA model (Table II, generalised to the
//!   k'/m' psum windows of Fig. 2).
//! * [`plan`] — the schedule IR: a [`Plan`] owns a resolved tile-step
//!   stream with **per-tile** stationary decisions and is what every cost
//!   backend replays (see [`crate::sim::replay`]).
//! * [`residency`] — fractional SRAM residency: the [`Residency`] type,
//!   hot/cold GEMM slicing, and the greedy [`ResidencyAllocator`] that
//!   treats SRAM as a budgeted, fractionally divisible resource shared by
//!   layer, decode and lane planning.
//! * [`layer`] — layer-level planning: [`LayerPlan`] chains the GEMMs of
//!   one transformer block and models SRAM residency of intermediates
//!   (fractionally, via the allocator).
//! * [`shard`] — multi-accelerator sharding: partition a [`Plan`] across
//!   devices by strip ranges, inter-chip traffic under the same cost
//!   algebra ([`crate::arch::interconnect`]).
//! * [`decode`] — KV-cache-aware decode planning: the autoregressive
//!   phase model ([`decode::Phase`]), cache edges on [`StageSpec`], and
//!   [`decode::DecodePlan`] trajectories with cache-resident per-tile TAS
//!   (head-sharded across devices via [`decode::ShardedDecodePlan`]).
//! * [`search`] — joint plan search: (cover family × shard axis ×
//!   chained residency × lane split) minimizing overlapped latency,
//!   memoized in a persistent top-k [`PlanDb`] keyed on canonical
//!   [`GemmSpec`]s so dim-congruent requests share one search.
//!
//! The generators and the closed forms are developed independently and
//! cross-checked by property tests: for every shape (ragged included) the
//! replayed word counts equal the formulas exactly.

pub mod analytic;
pub mod decode;
pub mod layer;
pub mod plan;
pub mod residency;
pub mod schedule;
pub mod search;
pub mod shard;

pub use analytic::{ema, EmaBreakdown};
pub use decode::{
    CacheEdge, CacheTensor, DecodeDims, DecodePlan, DecodeStagePlan, DecodeStepPlan,
    Phase, ShardedDecodePlan, SlicePlan, StepResidency,
};
pub use layer::{LayerPlan, StagePlan, StageSpec};
pub use plan::{Plan, PlanBody, Strip, StripKind};
pub use residency::{Allocation, Candidate, Residency, ResidencyAllocator, ResidencyPolicy};
pub use schedule::{for_each_step, step_count, Step};
pub use search::{
    canonical_bucket_key, search_lane_split, search_stages, CoverFamily, DbEntry, GemmSpec,
    LaneSplitOutcome, PlanDb, SearchChoice, SearchCtx, SearchOutcome, SearchStats,
    StageDecision, StagesOutcome,
};
pub use shard::{
    natural_axis, place_stages, shard_gemm, shard_gemm_priced, shard_heads, DeviceCompute,
    LinkTraffic, ShardAxis, ShardSpec, ShardedPlan,
};

/// A stationary scheme. `Tas` resolves to `IsOs` or `WsOs` per shape via
/// the paper's rule (§III-A): input-stationary iff `M < K`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// No data reuse: every MAC fetches operands and writes its psum.
    Naive,
    /// Input stationary (Fig. 1b): input tiles loaded once; psums spill.
    Is,
    /// Weight stationary (Fig. 1c): weight tiles loaded once; psums spill.
    Ws,
    /// Row-oriented output stationary (Fig. 1d).
    OsRow,
    /// Column-oriented output stationary (Fig. 1e).
    OsCol,
    /// Proposed hybrid: input stationary + k'-window psum reuse (Fig. 2a).
    IsOs,
    /// Proposed hybrid: weight stationary + m'-window psum reuse (Fig. 2b).
    WsOs,
    /// Tile-based Adaptive Stationary: pick IsOs/WsOs by `M < K`.
    Tas,
}

impl Scheme {
    /// All concrete (non-adaptive) schemes.
    pub const FIXED: [Scheme; 7] = [
        Scheme::Naive,
        Scheme::Is,
        Scheme::Ws,
        Scheme::OsRow,
        Scheme::OsCol,
        Scheme::IsOs,
        Scheme::WsOs,
    ];

    /// Resolve `Tas` for a given shape; other schemes return themselves.
    pub fn resolve(self, shape: &crate::gemm::GemmShape) -> Scheme {
        match self {
            Scheme::Tas => {
                // MN - NK = N(M-K): negative -> input matrix smaller -> IS.
                if shape.m < shape.k {
                    Scheme::IsOs
                } else {
                    Scheme::WsOs
                }
            }
            s => s,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Naive => "naive",
            Scheme::Is => "is",
            Scheme::Ws => "ws",
            Scheme::OsRow => "os-row",
            Scheme::OsCol => "os-col",
            Scheme::IsOs => "is-os",
            Scheme::WsOs => "ws-os",
            Scheme::Tas => "tas",
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<Scheme> {
        Ok(match name {
            "naive" => Scheme::Naive,
            "is" => Scheme::Is,
            "ws" => Scheme::Ws,
            "os-row" | "os_row" | "os" => Scheme::OsRow,
            "os-col" | "os_col" => Scheme::OsCol,
            "is-os" | "is_os" => Scheme::IsOs,
            "ws-os" | "ws_os" => Scheme::WsOs,
            "tas" => Scheme::Tas,
            _ => anyhow::bail!("unknown scheme '{name}'"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::GemmShape;

    #[test]
    fn tas_resolution_follows_rule() {
        let small_m = GemmShape::new(64, 256, 1024);
        let big_m = GemmShape::new(4096, 256, 1024);
        let equal = GemmShape::new(1024, 256, 1024);
        assert_eq!(Scheme::Tas.resolve(&small_m), Scheme::IsOs);
        assert_eq!(Scheme::Tas.resolve(&big_m), Scheme::WsOs);
        // paper: "zero or positive (M >= K) -> WS preferred"
        assert_eq!(Scheme::Tas.resolve(&equal), Scheme::WsOs);
        // non-adaptive schemes are fixed points
        assert_eq!(Scheme::Is.resolve(&small_m), Scheme::Is);
    }

    #[test]
    fn names_roundtrip() {
        for s in Scheme::FIXED.iter().chain([Scheme::Tas].iter()) {
            assert_eq!(Scheme::from_name(s.name()).unwrap(), *s);
        }
        assert!(Scheme::from_name("bogus").is_err());
    }
}
