//! First-class schedule IR: a [`Plan`] owns the resolved tile-step stream
//! of one GEMM — including **per-tile** stationary decisions — and is the
//! single object every cost backend replays (see [`crate::sim::replay`]).
//!
//! The seed code resolved `Scheme::Tas` once per GEMM shape; the paper's
//! claim, though, is that the stationary choice is a *tile*-granularity
//! decision.  The IR makes that honest:
//!
//! * a plan for a fixed scheme ([`Plan::from_scheme`]) wraps the exact
//!   loop-nest generator from [`super::schedule`], so every existing
//!   analytic/simulator equivalence keeps holding bit-for-bit;
//! * a per-tile TAS plan ([`Plan::tas_per_tile`]) covers the output tile
//!   grid with output-stationary **strips**, each strip choosing input- or
//!   weight-stationary independently.  A strip is the psum-window unit of
//!   Fig. 2: an IS strip is one tile row × ≤k'/k tile columns (the input
//!   tile stays, psums of the window live on chip); a WS strip is one tile
//!   column × ≤m'/m tile rows.  Pure IS-OS and pure WS-OS are the two
//!   degenerate covers, so the planner can never lose to either.
//!
//! The planner searches the guillotine families (a leading or trailing
//! block of columns or rows weight-stationary, the complement
//! input-stationary) in O(grid) with prefix sums, then falls back to the
//! best fixed scheme if one beats the strip cover (possible for spilling
//! schemes on extreme aspect ratios).
//! On ragged shapes a *mixed* cover can strictly beat both pure hybrids —
//! the per-tile decision is not just a per-GEMM argmin in disguise.
//!
//! Plans also carry per-stream SRAM [`Residency`] used by layer-level
//! planning ([`super::layer`]) and decode planning ([`super::decode`]):
//! an input already resident in SRAM costs no DRAM reads; an output
//! consumed on-chip by the next stage costs no DRAM writes; a resident
//! *weight* operand (a K/V-cache block or parked weight slice) costs no
//! DRAM reads either.  At the plan level a stream is either fully
//! resident or fully streamed — a *partial* [`Residency::Rows`] is
//! resolved by the planners into hot/cold **slice** plans (see
//! [`super::residency`]), so every cost backend keeps one charging rule.
//! Step flags keep their schedule semantics (`load_input` means "tile
//! enters the PE array"); residency is a plan-level property the cost
//! backends consult when charging DRAM.

use super::analytic::{self, EmaBreakdown};
use super::residency::Residency;
use super::schedule::{self, Step};
use super::Scheme;
use crate::arch::backend::PlanPricing;
use crate::gemm::{tile_extent, GemmShape, Tiling};
use crate::util::ceil_div;

/// Stationary orientation of one output strip.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StripKind {
    /// Input tile stays; one tile row, psums for the column window on chip.
    InputStationary,
    /// Weight tile stays; one tile column, psums for the row window on chip.
    WeightStationary,
}

/// A rectangular strip of output tiles `[i0, i1) × [j0, j1)` processed
/// output-stationary: every tile in the strip accumulates over the full
/// contraction and stores exactly once.  IS strips have `i1 == i0 + 1`,
/// WS strips have `j1 == j0 + 1`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Strip {
    pub kind: StripKind,
    pub i0: u64,
    pub i1: u64,
    pub j0: u64,
    pub j1: u64,
}

impl Strip {
    /// Output tiles covered.
    pub fn tiles(&self) -> u64 {
        (self.i1 - self.i0) * (self.j1 - self.j0)
    }

    /// (input, weight, output) words this strip moves over the full
    /// contraction (ragged edges resolved) — the single source of truth
    /// for per-strip EMA, shared by [`Plan::ema`] and the shard
    /// partitioner ([`super::shard`]).  O(1): a contiguous tile range's
    /// element count is a difference of clamped prefixes, so pricing a
    /// plan is O(strips) rather than O(strip widths).
    pub(crate) fn words(&self, shape: &GemmShape, tiling: &Tiling) -> (u64, u64, u64) {
        let n = shape.n;
        match self.kind {
            StripKind::InputStationary => {
                let mi = tile_extent(shape.m, tiling.tm, self.i0);
                let kw = extent_sum(shape.k, tiling.tk, self.j0, self.j1);
                (mi * n, n * kw, mi * kw)
            }
            StripKind::WeightStationary => {
                let kj = tile_extent(shape.k, tiling.tk, self.j0);
                let mw = extent_sum(shape.m, tiling.tm, self.i0, self.i1);
                (mw * n, n * kj, mw * kj)
            }
        }
    }
}

/// Σ `tile_extent(dim, tile, idx)` for `idx ∈ [lo, hi)`, in O(1): the
/// elements covered by tiles `[0, x)` are `min(x·tile, dim)`, so a range
/// sum is a difference of two clamped prefixes (exact on ragged edges).
pub(crate) fn extent_sum(dim: u64, tile: u64, lo: u64, hi: u64) -> u64 {
    debug_assert!(lo <= hi);
    (hi * tile).min(dim) - (lo * tile).min(dim)
}

/// How a plan's step stream is produced.
#[derive(Clone, Debug, PartialEq)]
pub enum PlanBody {
    /// A fixed-scheme loop nest over the whole grid (already resolved —
    /// never `Scheme::Tas`).
    Fixed(Scheme),
    /// An output-grid cover by stationary strips.
    Strips(Vec<Strip>),
}

/// The schedule IR: shape + tiling + resolved step stream + residency.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    pub shape: GemmShape,
    pub tiling: Tiling,
    pub body: PlanBody,
    /// SRAM residency of the input matrix: a free stream costs no DRAM
    /// reads.  Plan-level residency is never partial — planners slice a
    /// partially resident tensor into hot/cold plans first.
    pub input_residency: Residency,
    /// SRAM residency of the weight-side operand (a parked K/V-cache
    /// block or a weight slice retained across decode steps).
    pub weight_residency: Residency,
    /// SRAM residency of the output (consumed on-chip by the next
    /// stage): a free stream costs no DRAM writes.
    pub output_residency: Residency,
}

impl Plan {
    /// Wrap a fixed scheme's generator.  `Tas` resolves per-GEMM with the
    /// paper's §III-A sign rule — the seed behaviour, kept for all
    /// existing call sites; use [`Plan::tas_per_tile`] for the
    /// tile-granular planner.
    pub fn from_scheme(scheme: Scheme, shape: &GemmShape, tiling: &Tiling) -> Plan {
        Plan {
            shape: *shape,
            tiling: *tiling,
            body: PlanBody::Fixed(scheme.resolve(shape)),
            input_residency: Residency::None,
            weight_residency: Residency::None,
            output_residency: Residency::None,
        }
    }

    /// Tile-granular TAS for a standalone GEMM (nothing resident).
    pub fn tas_per_tile(shape: &GemmShape, tiling: &Tiling) -> Plan {
        Plan::tas_with_residency(shape, tiling, Residency::None, Residency::None)
    }

    /// Tile-granular TAS given SRAM residency of the input/output tensors
    /// (layer-level planning feeds these per chained stage slice).
    pub fn tas_with_residency(
        shape: &GemmShape,
        tiling: &Tiling,
        input: Residency,
        output: Residency,
    ) -> Plan {
        Plan::tas_cached(shape, tiling, input, Residency::None, output)
    }

    /// Tile-granular TAS with full residency control, including a
    /// SRAM-resident *weight* operand — the decode planner's entry point
    /// for cache-resident attention slices and parked weight slices
    /// ([`super::decode`]).  A free stream drops out of the chooser's
    /// objective, so the cover flips toward re-reading whatever residency
    /// made free.  Partial residency is a planner-level notion: resolve
    /// it into hot/cold slices ([`super::residency`]) before planning.
    pub fn tas_cached(
        shape: &GemmShape,
        tiling: &Tiling,
        input: Residency,
        weight: Residency,
        output: Residency,
    ) -> Plan {
        debug_assert!(
            !input.is_partial() && !weight.is_partial() && !output.is_partial(),
            "partial residency must be sliced before planning"
        );
        Plan::plan_cover(
            shape,
            tiling,
            input,
            weight,
            output,
            Plan::WEIGHT_SCALE,
            Plan::WEIGHT_SCALE,
            true,
        )
    }

    /// Chooser stream weights are integers in 1/256ths of a local DRAM
    /// word, so uniform (all-local) planning is an exact rescaling of the
    /// unweighted objective — same argmin, same ties, same plan.
    const WEIGHT_SCALE: u64 = 256;

    /// Tile-granular TAS restricted to strip covers (no fixed-scheme
    /// fallback): every output tile belongs to an explicit stationary
    /// strip, so the plan can be partitioned across devices by strip
    /// ranges ([`super::shard`]).
    pub fn tas_strips(shape: &GemmShape, tiling: &Tiling) -> Plan {
        Plan::plan_cover(
            shape,
            tiling,
            Residency::None,
            Residency::None,
            Residency::None,
            Plan::WEIGHT_SCALE,
            Plan::WEIGHT_SCALE,
            false,
        )
    }

    /// Device-aware per-tile TAS: each operand stream is weighted by its
    /// expected cost per word (`1.0` = a local DRAM word), so a stationary
    /// choice that keeps re-reading a remote operand pays the link premium
    /// inside the chooser's objective.  Uniform weights reproduce the
    /// [`Plan::tas_strips`] cover exactly.
    pub fn tas_link_weighted(
        shape: &GemmShape,
        tiling: &Tiling,
        input_weight: f64,
        weight_weight: f64,
    ) -> Plan {
        let wi = ((Plan::WEIGHT_SCALE as f64 * input_weight).round() as u64).max(1);
        let ww = ((Plan::WEIGHT_SCALE as f64 * weight_weight).round() as u64).max(1);
        Plan::plan_cover(
            shape,
            tiling,
            Residency::None,
            Residency::None,
            Residency::None,
            wi,
            ww,
            false,
        )
    }

    /// [`Plan::tas_link_weighted`] over a backend's base prices: each link
    /// premium multiplies what the backend pays per word of that stream,
    /// with **no lower clamp** — a stream the backend never issues (a
    /// crossbar's programmed weights) stays free under any premium, so
    /// sharding can never re-introduce weight traffic the hardware does
    /// not have.  Restricted to strip covers, like the link-weighted
    /// chooser.  Systolic pricing with weights ≥ 1 reproduces
    /// [`Plan::tas_link_weighted`] exactly.
    pub fn tas_link_priced(
        shape: &GemmShape,
        tiling: &Tiling,
        input_weight: f64,
        weight_weight: f64,
        pricing: &PlanPricing,
    ) -> Plan {
        let wi = (pricing.wi as f64 * input_weight).round() as u64;
        let ww = (pricing.ww as f64 * weight_weight).round() as u64;
        Plan::plan_cover(
            shape,
            tiling,
            Residency::None,
            Residency::None,
            Residency::None,
            wi,
            ww,
            false,
        )
    }

    /// [`Plan::tas_strips`] under a backend's pricing (no fixed-scheme
    /// fallback, so the cover always partitions into strip ranges).
    pub fn tas_strips_priced(shape: &GemmShape, tiling: &Tiling, pricing: &PlanPricing) -> Plan {
        Plan::plan_cover(
            shape,
            tiling,
            Residency::None,
            Residency::None,
            Residency::None,
            pricing.wi,
            pricing.ww,
            false,
        )
    }

    /// Tile-granular TAS priced by a backend: the chooser's stream weights
    /// come straight from [`PlanPricing`] with **no lower clamp**, so a
    /// backend that never streams an operand (a crossbar's programmed
    /// weights, `ww == 0`) flips every cover toward re-reading that
    /// operand — activation-stationary scheduling by pricing, not by
    /// special case.  The fixed-scheme fallback (which spills psums
    /// through external memory) is only considered when the backend
    /// streams all three operands ([`PlanPricing::allows_fixed`]).
    ///
    /// Systolic pricing reproduces [`Plan::tas_cached`] exactly.
    pub fn tas_priced(
        shape: &GemmShape,
        tiling: &Tiling,
        input: Residency,
        weight: Residency,
        output: Residency,
        pricing: &PlanPricing,
    ) -> Plan {
        debug_assert!(
            !input.is_partial() && !weight.is_partial() && !output.is_partial(),
            "partial residency must be sliced before planning"
        );
        Plan::plan_cover(
            shape,
            tiling,
            input,
            weight,
            output,
            pricing.wi,
            pricing.ww,
            pricing.allows_fixed(),
        )
    }

    /// The strip-cover search behind every per-tile constructor.  `wi` /
    /// `ww` weight the input / weight streams (in [`Plan::WEIGHT_SCALE`]
    /// units); `allow_fixed` enables the fixed-scheme fallback.
    fn plan_cover(
        shape: &GemmShape,
        tiling: &Tiling,
        input_residency: Residency,
        weight_residency: Residency,
        output_residency: Residency,
        wi: u64,
        ww: u64,
        allow_fixed: bool,
    ) -> Plan {
        let input_resident = input_residency.is_free();
        let weight_resident = weight_residency.is_free();
        let output_resident = output_residency.is_free();
        let (gm, _gn, gk) = tiling.grid(shape);
        let wk = tiling.window_tiles_k(shape);
        let wm = tiling.window_tiles_m(shape);
        let n = shape.n;

        // Exact per-row / per-column operand word counts (ragged-aware):
        // a tile row i costs mi·N input words over a full contraction; a
        // tile column j costs N·kj weight words.
        let mut in_pre = vec![0u64; gm as usize + 1];
        for i in 0..gm {
            in_pre[i as usize + 1] =
                in_pre[i as usize] + tile_extent(shape.m, tiling.tm, i) * n;
        }
        let mut w_pre = vec![0u64; gk as usize + 1];
        for j in 0..gk {
            w_pre[j as usize + 1] =
                w_pre[j as usize] + n * tile_extent(shape.k, tiling.tk, j);
        }
        let in_total = in_pre[gm as usize]; // M·N
        let w_total = w_pre[gk as usize]; // N·K
        let nwin_m = ceil_div(gm, wm);
        let nwin_k = ceil_div(gk, wk);
        let in_cost = |w: u64| if input_resident { 0 } else { wi * w };
        let w_cost = |w: u64| if weight_resident { 0 } else { ww * w };

        // Guillotine families: one contiguous block of columns (or rows)
        // goes weight-stationary, the complement input-stationary.  Both
        // leading- and trailing-block variants are searched — a ragged
        // last column under WS next to aligned IS windows (or vice versa)
        // is exactly where a mixed cover strictly beats both pure hybrids.
        // Endpoints reproduce pure IS-OS / WS-OS covers.
        let mut best_cost = u64::MAX;
        let mut best_split = SplitChoice { col_split: true, ws_block_first: true, at: 0 };
        let mut consider = |cost: u64, split: SplitChoice| {
            if cost < best_cost {
                best_cost = cost;
                best_split = split;
            }
        };
        for c in 0..=gk {
            let w_lo = w_pre[c as usize];
            let w_hi = w_total - w_lo;
            // WS cols [0, c), IS cols [c, gk):
            consider(
                w_cost(nwin_m * w_lo)                        // WS stationary weights
                    + in_cost(c * in_total)                  // WS streamed inputs
                    + in_cost(ceil_div(gk - c, wk) * in_total) // IS stationary inputs
                    + w_cost(gm * w_hi),                     // IS streamed weights
                SplitChoice { col_split: true, ws_block_first: true, at: c },
            );
            // IS cols [0, c), WS cols [c, gk):
            consider(
                in_cost(ceil_div(c, wk) * in_total)
                    + w_cost(gm * w_lo)
                    + w_cost(nwin_m * w_hi)
                    + in_cost((gk - c) * in_total),
                SplitChoice { col_split: true, ws_block_first: false, at: c },
            );
        }
        for r in 0..=gm {
            let in_lo = in_pre[r as usize];
            let in_hi = in_total - in_lo;
            // IS rows [0, r), WS rows [r, gm):
            consider(
                in_cost(nwin_k * in_lo)
                    + w_cost(r * w_total)
                    + w_cost(ceil_div(gm - r, wm) * w_total)
                    + in_cost(gk * in_hi),
                SplitChoice { col_split: false, ws_block_first: false, at: r },
            );
            // WS rows [0, r), IS rows [r, gm):
            consider(
                w_cost(ceil_div(r, wm) * w_total)
                    + in_cost(gk * in_lo)
                    + in_cost(nwin_k * in_hi)
                    + w_cost((gm - r) * w_total),
                SplitChoice { col_split: false, ws_block_first: true, at: r },
            );
        }

        // Fixed-scheme fallback: without residency, a spilling scheme can
        // still beat the OS strip covers on extreme aspect ratios (e.g. a
        // single contraction tile makes plain IS's spill column free).
        if allow_fixed && !input_resident && !weight_resident && !output_resident {
            let strip_total = best_cost + Plan::WEIGHT_SCALE * shape.output_words();
            let mut best_fixed: Option<(u64, Scheme)> = None;
            for s in Scheme::FIXED {
                let e = analytic::ema(s, shape, tiling);
                let total = wi * e.input + ww * e.weight + Plan::WEIGHT_SCALE * e.output;
                if best_fixed.map(|(t, _)| total < t).unwrap_or(true) {
                    best_fixed = Some((total, s));
                }
            }
            if let Some((total, s)) = best_fixed {
                if total < strip_total {
                    return Plan {
                        shape: *shape,
                        tiling: *tiling,
                        body: PlanBody::Fixed(s),
                        input_residency,
                        weight_residency,
                        output_residency,
                    };
                }
            }
        }

        let strips = build_strips(best_split, gm, gk, wm, wk);
        debug_assert_eq!(
            strips.iter().map(Strip::tiles).sum::<u64>(),
            gm * gk,
            "strip cover must tile the output grid exactly"
        );
        Plan {
            shape: *shape,
            tiling: *tiling,
            body: PlanBody::Strips(strips),
            input_residency,
            weight_residency,
            output_residency,
        }
    }

    /// Drive `visit` over every step of the plan in schedule order.
    pub fn for_each_step<F: FnMut(Step)>(&self, mut visit: F) {
        match &self.body {
            PlanBody::Fixed(s) => {
                schedule::for_each_step(*s, &self.shape, &self.tiling, visit)
            }
            PlanBody::Strips(strips) => {
                for strip in strips {
                    self.for_each_strip_step(strip, &mut visit);
                }
            }
        }
    }

    /// Steps of one strip in schedule order — the per-strip half of
    /// [`Plan::for_each_step`], also used by the shard partitioner
    /// ([`super::shard`]) to route whole strips to devices.
    pub(crate) fn for_each_strip_step<F: FnMut(Step)>(&self, strip: &Strip, visit: &mut F) {
        let (_, gn, _) = self.tiling.grid(&self.shape);
        match strip.kind {
            StripKind::InputStationary => {
                let i = strip.i0;
                for r in 0..gn {
                    for j in strip.j0..strip.j1 {
                        let mut s = Step::new(i, r, j);
                        s.load_input = j == strip.j0;
                        s.load_weight = true;
                        s.store_out = r + 1 == gn;
                        visit(s);
                    }
                }
            }
            StripKind::WeightStationary => {
                let j = strip.j0;
                for r in 0..gn {
                    for i in strip.i0..strip.i1 {
                        let mut s = Step::new(i, r, j);
                        s.load_input = true;
                        s.load_weight = i == strip.i0;
                        s.store_out = r + 1 == gn;
                        visit(s);
                    }
                }
            }
        }
    }

    /// Total steps: every (i, r, j) tile triple exactly once.
    pub fn step_count(&self) -> u64 {
        schedule::step_count(&self.shape, &self.tiling)
    }

    /// Closed-form EMA of the plan in words (DRAM traffic only: resident
    /// operands cost nothing).  For fixed bodies this is Table II; for
    /// strip bodies it is the per-strip cost model, which the replay
    /// property tests pin to the step stream word-for-word.
    pub fn ema(&self) -> EmaBreakdown {
        match &self.body {
            PlanBody::Fixed(s) => {
                debug_assert!(
                    !self.input_residency.is_free()
                        && !self.weight_residency.is_free()
                        && !self.output_residency.is_free(),
                    "residency is only planned onto strip bodies"
                );
                analytic::ema(*s, &self.shape, &self.tiling)
            }
            PlanBody::Strips(strips) => {
                let mut input = 0u64;
                let mut weight = 0u64;
                let mut output = 0u64;
                for strip in strips {
                    let (iw, ww, ow) = strip.words(&self.shape, &self.tiling);
                    input += iw;
                    weight += ww;
                    // Σ per-strip output == M·K: the cover tiles the grid
                    // exactly (debug-asserted at construction).
                    output += ow;
                }
                EmaBreakdown {
                    input: if self.input_residency.is_free() { 0 } else { input },
                    weight: if self.weight_residency.is_free() { 0 } else { weight },
                    output: if self.output_residency.is_free() { 0 } else { output },
                }
            }
        }
    }

    /// External words this plan actually moves on a backend with the
    /// given charge triple: the residency-gated [`Plan::ema`] breakdown
    /// with each stream multiplied by its per-operand charge.  This is
    /// the quantity the residency knapsack should value — on a crossbar
    /// (`charge[1] == 0`) parking a weight slice saves nothing, so the
    /// allocator spends its buffer on activations automatically.
    pub fn ema_words_charged(&self, charge: [u64; 3]) -> u64 {
        let e = self.ema();
        charge[0] * e.input + charge[1] * e.weight + charge[2] * e.output
    }

    /// Output tiles under each orientation: `(input_stationary,
    /// weight_stationary, other)`.  Fixed OS/naive bodies count as other.
    pub fn tile_mix(&self) -> (u64, u64, u64) {
        let (gm, _, gk) = self.tiling.grid(&self.shape);
        let total = gm * gk;
        match &self.body {
            PlanBody::Fixed(Scheme::Is) | PlanBody::Fixed(Scheme::IsOs) => (total, 0, 0),
            PlanBody::Fixed(Scheme::Ws) | PlanBody::Fixed(Scheme::WsOs) => (0, total, 0),
            PlanBody::Fixed(_) => (0, 0, total),
            PlanBody::Strips(strips) => {
                let is: u64 = strips
                    .iter()
                    .filter(|s| s.kind == StripKind::InputStationary)
                    .map(Strip::tiles)
                    .sum();
                let ws: u64 = strips
                    .iter()
                    .filter(|s| s.kind == StripKind::WeightStationary)
                    .map(Strip::tiles)
                    .sum();
                (is, ws, total - is - ws)
            }
        }
    }

    /// Human-readable decision summary for reports: `"is-os"`, `"ws-os"`,
    /// a fixed-scheme name, or `"mixed(41% is)"`.
    pub fn describe(&self) -> String {
        match &self.body {
            PlanBody::Fixed(s) => s.name().to_string(),
            PlanBody::Strips(_) => {
                let (is, ws, _) = self.tile_mix();
                if ws == 0 {
                    "is-os".to_string()
                } else if is == 0 {
                    "ws-os".to_string()
                } else {
                    format!("mixed({}% is)", 100 * is / (is + ws))
                }
            }
        }
    }
}

/// One guillotine partition of the output grid: a contiguous block of
/// columns (or rows) starting at index 0 or ending at the grid edge goes
/// weight-stationary, the complement input-stationary.
#[derive(Clone, Copy, Debug)]
struct SplitChoice {
    /// Split along columns (else along rows).
    col_split: bool,
    /// The WS block is the leading one.
    ws_block_first: bool,
    /// Split index in tiles.
    at: u64,
}

fn build_strips(split: SplitChoice, gm: u64, gk: u64, wm: u64, wk: u64) -> Vec<Strip> {
    let mut strips = Vec::new();
    // (ws_cols, is_cols) or (ws_rows, is_rows) as half-open ranges.
    let (ws_range, is_range) = {
        let extent = if split.col_split { gk } else { gm };
        if split.ws_block_first {
            ((0, split.at), (split.at, extent))
        } else {
            ((split.at, extent), (0, split.at))
        }
    };
    let mut push_ws_col = |j: u64| {
        let mut i0 = 0;
        while i0 < gm {
            let i1 = (i0 + wm).min(gm);
            strips.push(Strip { kind: StripKind::WeightStationary, i0, i1, j0: j, j1: j + 1 });
            i0 = i1;
        }
    };
    if split.col_split {
        for j in ws_range.0..ws_range.1 {
            push_ws_col(j);
        }
        for i in 0..gm {
            let mut j0 = is_range.0;
            while j0 < is_range.1 {
                let j1 = (j0 + wk).min(is_range.1);
                strips.push(Strip { kind: StripKind::InputStationary, i0: i, i1: i + 1, j0, j1 });
                j0 = j1;
            }
        }
    } else {
        for i in is_range.0..is_range.1 {
            let mut j0 = 0;
            while j0 < gk {
                let j1 = (j0 + wk).min(gk);
                strips.push(Strip { kind: StripKind::InputStationary, i0: i, i1: i + 1, j0, j1 });
                j0 = j1;
            }
        }
        for j in 0..gk {
            let mut i0 = ws_range.0;
            while i0 < ws_range.1 {
                let i1 = (i0 + wm).min(ws_range.1);
                strips.push(Strip { kind: StripKind::WeightStationary, i0, i1, j0: j, j1: j + 1 });
                i0 = i1;
            }
        }
    }
    strips
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::property;
    use crate::util::prng::Rng;
    use std::collections::HashSet;

    fn replayed_ema(plan: &Plan) -> EmaBreakdown {
        // Independent word count straight off the step stream.
        let mut e = EmaBreakdown::default();
        let (shape, t) = (plan.shape, plan.tiling);
        plan.for_each_step(|s| {
            let mi = tile_extent(shape.m, t.tm, s.i);
            let nr = tile_extent(shape.n, t.tn, s.r);
            let kj = tile_extent(shape.k, t.tk, s.j);
            if s.load_input && !plan.input_residency.is_free() {
                e.input += mi * nr;
            }
            if s.load_weight && !plan.weight_residency.is_free() {
                e.weight += nr * kj;
            }
            if s.psum_spill {
                e.output += mi * kj;
            }
            if s.store_out && !plan.output_residency.is_free() {
                e.output += mi * kj;
            }
        });
        e
    }

    fn rand_tiling(rng: &mut Rng) -> Tiling {
        let t = *rng.choose(&[4u64, 8, 16]);
        let mut tiling = Tiling::square(t);
        if rng.gen_range(2) == 0 {
            tiling = tiling.with_kp(rng.gen_in(1, 6) * t);
        }
        if rng.gen_range(2) == 0 {
            tiling = tiling.with_mp(rng.gen_in(1, 6) * t);
        }
        tiling
    }

    #[test]
    fn extent_sum_matches_looped_tile_extents() {
        property("extent_sum == Σ tile_extent", 120, |rng: &mut Rng| {
            let dim = rng.gen_in(1, 500);
            let tile = rng.gen_in(1, 40);
            let grid = crate::util::ceil_div(dim, tile);
            let lo = rng.gen_range(grid + 1);
            let hi = lo + rng.gen_range(grid + 1 - lo);
            let looped: u64 = (lo..hi).map(|i| tile_extent(dim, tile, i)).sum();
            assert_eq!(extent_sum(dim, tile, lo, hi), looped, "{dim}/{tile} [{lo},{hi})");
        });
    }

    #[test]
    fn per_tile_plan_covers_each_tile_triple_once() {
        property("plan coverage", 80, |rng: &mut Rng| {
            let shape = GemmShape::new(
                rng.gen_in(1, 150),
                rng.gen_in(1, 150),
                rng.gen_in(1, 150),
            );
            let tiling = rand_tiling(rng);
            let plan = Plan::tas_per_tile(&shape, &tiling);
            let mut seen: HashSet<(u64, u64, u64)> = HashSet::new();
            let mut n = 0u64;
            plan.for_each_step(|s| {
                n += 1;
                assert!(seen.insert((s.i, s.r, s.j)), "repeated tile");
            });
            assert_eq!(n, plan.step_count());
        });
    }

    #[test]
    fn per_tile_plan_stores_each_output_tile_once() {
        property("plan store-once", 80, |rng: &mut Rng| {
            let shape = GemmShape::new(
                rng.gen_in(1, 120),
                rng.gen_in(1, 120),
                rng.gen_in(1, 120),
            );
            let tiling = rand_tiling(rng);
            let plan = Plan::tas_per_tile(&shape, &tiling);
            let (gm, _, gk) = tiling.grid(&shape);
            let mut stores: HashSet<(u64, u64)> = HashSet::new();
            plan.for_each_step(|s| {
                if s.store_out {
                    assert!(stores.insert((s.i, s.j)), "double store");
                }
            });
            assert_eq!(stores.len() as u64, gm * gk);
        });
    }

    #[test]
    fn closed_form_ema_matches_step_stream() {
        property("plan ema == replay", 100, |rng: &mut Rng| {
            let shape = GemmShape::new(
                rng.gen_in(1, 120),
                rng.gen_in(1, 120),
                rng.gen_in(1, 120),
            );
            let tiling = rand_tiling(rng);
            let plan = Plan::tas_per_tile(&shape, &tiling);
            let closed = plan.ema();
            let replay = replayed_ema(&plan);
            // Fixed fallbacks may spill psums (extra output words counted
            // identically by both sides via analytic::ema).
            match &plan.body {
                PlanBody::Strips(_) => assert_eq!(closed, replay, "{shape:?}"),
                PlanBody::Fixed(s) => {
                    assert_eq!(closed, analytic::ema(*s, &shape, &tiling))
                }
            }
        });
    }

    #[test]
    fn per_tile_never_worse_than_any_fixed_scheme() {
        property("per-tile <= best fixed", 150, |rng: &mut Rng| {
            let shape = GemmShape::new(
                rng.gen_in(1, 2000),
                rng.gen_in(1, 2000),
                rng.gen_in(1, 2000),
            );
            let tiling = rand_tiling(rng);
            let plan = Plan::tas_per_tile(&shape, &tiling);
            let mine = plan.ema().total();
            for s in Scheme::FIXED.iter().chain([Scheme::Tas].iter()) {
                let fixed = analytic::ema(*s, &shape, &tiling).total();
                assert!(
                    mine <= fixed,
                    "{shape:?} {tiling:?}: plan {mine} > {s:?} {fixed}"
                );
            }
        });
    }

    #[test]
    fn mixed_cover_beats_pure_hybrids_on_ragged_windows() {
        // K = 65 with a 4-tile psum window: pure IS-OS needs 2 windows
        // just for the 1-wide ragged column, re-reading the whole input.
        // Handing that column to WS strips leaves one aligned window for
        // the rest — a strict win over both pure hybrids, i.e. the
        // per-tile decision is not a per-GEMM argmin in disguise.
        let tiling = Tiling::square(16).with_kp(64).with_mp(32);
        let shape = GemmShape::new(2048, 64, 65);
        let plan = Plan::tas_per_tile(&shape, &tiling);
        let mine = plan.ema().total();
        let is_os = analytic::ema(Scheme::IsOs, &shape, &tiling).total();
        let ws_os = analytic::ema(Scheme::WsOs, &shape, &tiling).total();
        assert!(
            mine < is_os.min(ws_os),
            "mixed {mine} vs is-os {is_os} / ws-os {ws_os}"
        );
        let (is, ws, other) = plan.tile_mix();
        assert_eq!(other, 0);
        assert!(is > 0 && ws > 0, "expected a mixed cover: is {is} ws {ws}");
    }

    #[test]
    fn residency_zeroes_the_resident_streams() {
        let shape = GemmShape::new(384, 768, 768);
        let tiling = Tiling::square(16);
        let base = Plan::tas_per_tile(&shape, &tiling).ema();
        let in_res =
            Plan::tas_with_residency(&shape, &tiling, Residency::Full, Residency::None).ema();
        let out_res =
            Plan::tas_with_residency(&shape, &tiling, Residency::None, Residency::Full).ema();
        assert_eq!(in_res.input, 0);
        assert_eq!(out_res.output, 0);
        assert!(in_res.total() < base.total());
        assert!(out_res.total() < base.total());
        // weight traffic is never resident
        assert!(in_res.weight > 0 && out_res.weight > 0);
    }

    #[test]
    fn weight_residency_zeroes_the_weight_stream() {
        property("weight residency", 80, |rng: &mut Rng| {
            let shape = GemmShape::new(
                rng.gen_in(1, 150),
                rng.gen_in(1, 150),
                rng.gen_in(1, 150),
            );
            let tiling = rand_tiling(rng);
            let plan =
                Plan::tas_cached(&shape, &tiling, Residency::None, Residency::Full, Residency::None);
            let e = plan.ema();
            assert_eq!(e.weight, 0);
            // closed form still matches the replayed step stream
            assert_eq!(e, replayed_ema(&plan), "{shape:?}");
            // with weights free, the chooser reads the input once per
            // psum window (an all-IS cover; one window when k' covers K)
            let nwin_k = crate::util::ceil_div(
                tiling.grid(&shape).2,
                tiling.window_tiles_k(&shape),
            );
            assert_eq!(e.input, nwin_k * shape.input_words());
            assert_eq!(e.output, shape.output_words());
        });
    }

    #[test]
    fn resident_input_reduces_cost_to_single_weight_read() {
        // With the input free, the only remaining traffic is weights; the
        // planner must find a cover that reads each weight word once.
        let shape = GemmShape::new(4096, 768, 768);
        let tiling = Tiling::square(16);
        let plan = Plan::tas_with_residency(&shape, &tiling, Residency::Full, Residency::None);
        let e = plan.ema();
        assert_eq!(e.input, 0);
        assert_eq!(e.weight, shape.weight_words());
    }

    #[test]
    fn strips_only_planner_matches_per_tile_when_no_fallback() {
        let shape = GemmShape::new(384, 768, 768);
        let tiling = Tiling::square(16);
        let per_tile = Plan::tas_per_tile(&shape, &tiling);
        let strips = Plan::tas_strips(&shape, &tiling);
        assert_eq!(per_tile, strips);
        // uniform link weights are an exact rescaling: same cover again
        let weighted = Plan::tas_link_weighted(&shape, &tiling, 1.0, 1.0);
        assert_eq!(strips, weighted);
    }

    #[test]
    fn link_weighting_shifts_cover_toward_rereading_the_cheap_stream() {
        // M < K: the unweighted chooser keeps inputs stationary and
        // re-reads weights.  Pricing weight words 4x (remote weights on
        // another chip) flips the cover to weight-stationary.
        let shape = GemmShape::new(64, 768, 768);
        let tiling = Tiling::square(16);
        let (gm, _, gk) = tiling.grid(&shape);
        let base = Plan::tas_per_tile(&shape, &tiling);
        let (is, _, _) = base.tile_mix();
        assert_eq!(is, gm * gk, "baseline should be all input-stationary");
        let weighted = Plan::tas_link_weighted(&shape, &tiling, 1.0, 4.0);
        let (_, ws, _) = weighted.tile_mix();
        assert_eq!(ws, gm * gk, "weighted cover should go weight-stationary");
        // the weighted objective never increases under the weighted plan
        let cost = |p: &Plan, wi: u64, ww: u64| {
            let e = p.ema();
            wi * e.input + ww * e.weight + e.output
        };
        assert!(cost(&weighted, 1, 4) <= cost(&base, 1, 4));
    }

    #[test]
    fn price_scale_matches_backend_pricing_units() {
        // PlanPricing's wi/ww are expressed in the chooser's fixed-point
        // units; the two constants must stay equal or backend pricing
        // would silently rescale against the output stream's weight.
        assert_eq!(Plan::WEIGHT_SCALE, crate::arch::backend::PRICE_SCALE);
    }

    #[test]
    fn systolic_pricing_reproduces_tas_cached_exactly() {
        let pricing = PlanPricing::systolic();
        let combos = [
            (Residency::None, Residency::None, Residency::None),
            (Residency::Full, Residency::None, Residency::None),
            (Residency::None, Residency::Full, Residency::None),
            (Residency::None, Residency::None, Residency::Full),
        ];
        property("tas_priced(systolic) == tas_cached", 80, |rng: &mut Rng| {
            let shape = GemmShape::new(
                rng.gen_in(1, 250),
                rng.gen_in(1, 250),
                rng.gen_in(1, 250),
            );
            let tiling = Tiling::square(*rng.choose(&[8u64, 16]));
            let (i, w, o) = *rng.choose(&combos);
            assert_eq!(
                Plan::tas_priced(&shape, &tiling, i, w, o, &pricing),
                Plan::tas_cached(&shape, &tiling, i, w, o),
                "{shape:?}"
            );
        });
    }

    #[test]
    fn crossbar_pricing_degenerates_to_activation_stationary() {
        // ww == 0: weights are free to re-read, so the chooser must keep
        // the *input* (activation) stationary everywhere, reach the
        // minimum possible input traffic, and never pick the spilling
        // fixed fallback.  No crossbar-specific branch exists in the
        // planner — this is the sign rule under a zero weight price.
        let pricing = PlanPricing::crossbar();
        property("crossbar pricing => all-IS", 80, |rng: &mut Rng| {
            let shape = GemmShape::new(
                rng.gen_in(1, 250),
                rng.gen_in(1, 250),
                rng.gen_in(1, 250),
            );
            let tiling = Tiling::square(*rng.choose(&[8u64, 16]));
            let plan = Plan::tas_priced(
                &shape,
                &tiling,
                Residency::None,
                Residency::None,
                Residency::None,
                &pricing,
            );
            let (gm, _, gk) = tiling.grid(&shape);
            let (is, ws, other) = plan.tile_mix();
            assert_eq!((is, ws, other), (gm * gk, 0, 0), "{shape:?}");
            // charged words ignore the weight stream entirely
            let e = plan.ema();
            assert_eq!(
                plan.ema_words_charged(pricing.charge),
                e.input + e.output,
                "{shape:?}"
            );
            // input traffic is the windowed-minimum: one read per input
            // word per contraction window pass
            let wk = tiling.window_tiles_k(&shape);
            let nwin_k = tiling.grid(&shape).2.div_ceil(wk);
            assert_eq!(e.input, nwin_k * shape.input_words(), "{shape:?}");
        });
    }

    #[test]
    fn fixed_bodies_reproduce_schedule_generators() {
        let shape = GemmShape::new(96, 80, 112);
        let tiling = Tiling::square(16);
        for scheme in Scheme::FIXED.iter().chain([Scheme::Tas].iter()) {
            let plan = Plan::from_scheme(*scheme, &shape, &tiling);
            let mut plan_steps = Vec::new();
            plan.for_each_step(|s| plan_steps.push(s));
            let mut gen_steps = Vec::new();
            schedule::for_each_step(*scheme, &shape, &tiling, |s| gen_steps.push(s));
            assert_eq!(plan_steps, gen_steps, "{scheme:?}");
        }
    }

    #[test]
    fn psum_live_set_respects_windows() {
        property("plan psum windows", 60, |rng: &mut Rng| {
            let t = 8u64;
            let tiling = Tiling::square(t)
                .with_kp(rng.gen_in(1, 4) * t)
                .with_mp(rng.gen_in(1, 4) * t);
            let shape = GemmShape::new(
                rng.gen_in(1, 200),
                rng.gen_in(1, 200),
                rng.gen_in(1, 200),
            );
            let plan = Plan::tas_per_tile(&shape, &tiling);
            if let PlanBody::Strips(_) = plan.body {
                let wk = tiling.window_tiles_k(&shape);
                let wm = tiling.window_tiles_m(&shape);
                let cap = wk.max(wm);
                let mut live: HashSet<(u64, u64)> = HashSet::new();
                let mut peak = 0;
                plan.for_each_step(|s| {
                    live.insert((s.i, s.j));
                    peak = peak.max(live.len() as u64);
                    if s.store_out {
                        live.remove(&(s.i, s.j));
                    }
                });
                assert!(peak <= cap, "peak {peak} > window cap {cap}");
                assert!(live.is_empty());
            }
        });
    }
}
