//! Closed-form EMA model — Table II of the paper, generalised to the
//! psum windows k'/m' of Fig. 2.
//!
//! All counts are in **words** and exact (the tile-count multipliers are
//! ceilings times whole-matrix word counts, so they hold for ragged shapes
//! too — the schedule replay in [`crate::sim`] is property-tested to match
//! these formulas bit-exactly).

use super::Scheme;
use crate::gemm::{GemmShape, Tiling};
use crate::util::ceil_div;

/// Per-matrix external memory access, in words.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EmaBreakdown {
    /// Input-matrix reads.
    pub input: u64,
    /// Weight-matrix reads.
    pub weight: u64,
    /// Output/psum writes (Table II counts the write direction).
    pub output: u64,
}

impl EmaBreakdown {
    pub fn total(&self) -> u64 {
        self.input + self.weight + self.output
    }
}

/// Table II (+ Fig. 2 windows): EMA of `scheme` on `shape` under `tiling`.
pub fn ema(scheme: Scheme, shape: &GemmShape, tiling: &Tiling) -> EmaBreakdown {
    let GemmShape { m, n, k } = *shape;
    let (mn, nk, mk) = (m * n, n * k, m * k);
    let tiles_m = ceil_div(m, tiling.tm);
    let tiles_n = ceil_div(n, tiling.tn);
    let tiles_k = ceil_div(k, tiling.tk);
    // Window counts in *tiles* — the same definition the schedule uses.
    let windows_kp = ceil_div(tiles_k, tiling.window_tiles_k(shape));
    let windows_mp = ceil_div(tiles_m, tiling.window_tiles_m(shape));

    match scheme.resolve(shape) {
        // Every MAC fetches both operands and writes its psum: 3·MNK.
        Scheme::Naive => EmaBreakdown { input: k * mn, weight: m * nk, output: n * mk },
        // IS: input once; weights re-read per input row-block; psums spill
        // once per contraction tile.
        Scheme::Is => EmaBreakdown {
            input: mn,
            weight: tiles_m * nk,
            output: tiles_n * mk,
        },
        // WS: weights once; input re-read per weight column-block.
        Scheme::Ws => EmaBreakdown {
            input: tiles_k * mn,
            weight: nk,
            output: tiles_n * mk,
        },
        // OS: psums stay on chip; both operands re-read.
        Scheme::OsRow | Scheme::OsCol => EmaBreakdown {
            input: tiles_k * mn,
            weight: tiles_m * nk,
            output: mk,
        },
        // IS-OS (Fig. 2a): input re-read once per k'-column window
        // (Table II's row is the k' = K ideal -> input = MN).
        Scheme::IsOs => EmaBreakdown {
            input: windows_kp * mn,
            weight: tiles_m * nk,
            output: mk,
        },
        // WS-OS (Fig. 2b): weights re-read once per m'-row window
        // (Table II's row is the m' = M ideal -> weight = NK).
        Scheme::WsOs => EmaBreakdown {
            input: tiles_k * mn,
            weight: windows_mp * nk,
            output: mk,
        },
        Scheme::Tas => unreachable!("resolve() eliminated Tas"),
    }
}

/// The decision quantity of §III-A: `MN − NK = N(M−K)` in words.
/// Negative ⇒ IS preferred; zero/positive ⇒ WS preferred.
pub fn is_ws_difference(shape: &GemmShape) -> i128 {
    (shape.m as i128 - shape.k as i128) * shape.n as i128
}

/// EMA of the *stationary matrix only* — the quantity Table III tabulates
/// (`IS` column = input matrix under IS = MN; `WS` column = NK).
pub fn stationary_matrix_words(scheme: Scheme, shape: &GemmShape) -> u64 {
    match scheme {
        Scheme::Is | Scheme::IsOs => shape.input_words(),
        Scheme::Ws | Scheme::WsOs => shape.weight_words(),
        _ => panic!("stationary_matrix_words: {scheme:?} has no single stationary matrix"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::property;
    use crate::util::prng::Rng;

    fn shape() -> GemmShape {
        GemmShape::new(384, 1024, 1024) // wav2vec2-large Q projection, mean len
    }

    #[test]
    fn naive_is_three_mnk() {
        let s = shape();
        let e = ema(Scheme::Naive, &s, &Tiling::square(16));
        assert_eq!(e.total(), 3 * s.macs());
    }

    #[test]
    fn table2_formulas_divisible() {
        // M=64, N=32, K=128 with 16-tiles: tiles = (4, 2, 8).
        let s = GemmShape::new(64, 32, 128);
        let t = Tiling::square(16);
        let (mn, nk, mk) = (s.m * s.n, s.n * s.k, s.m * s.k);
        assert_eq!(ema(Scheme::Is, &s, &t), EmaBreakdown { input: mn, weight: 4 * nk, output: 2 * mk });
        assert_eq!(ema(Scheme::Ws, &s, &t), EmaBreakdown { input: 8 * mn, weight: nk, output: 2 * mk });
        assert_eq!(ema(Scheme::OsRow, &s, &t), EmaBreakdown { input: 8 * mn, weight: 4 * nk, output: mk });
        assert_eq!(ema(Scheme::IsOs, &s, &t), EmaBreakdown { input: mn, weight: 4 * nk, output: mk });
        assert_eq!(ema(Scheme::WsOs, &s, &t), EmaBreakdown { input: 8 * mn, weight: nk, output: mk });
    }

    #[test]
    fn psum_windows_scale_reloads() {
        let s = GemmShape::new(64, 32, 128);
        let t = Tiling::square(16).with_kp(32); // 4 windows over K=128
        assert_eq!(ema(Scheme::IsOs, &s, &t).input, 4 * s.m * s.n);
        let t2 = Tiling::square(16).with_mp(16); // 4 windows over M=64
        assert_eq!(ema(Scheme::WsOs, &s, &t2).weight, 4 * s.n * s.k);
    }

    #[test]
    fn tas_is_min_of_hybrids_on_divisible_shapes() {
        // §III-A: with square tiles (m = n = k) and tile-divisible shapes
        // the sign of N(M−K) picks the EMA argmin *exactly*.
        property("tas = min(is-os, ws-os)", 500, |rng: &mut Rng| {
            let t_edge = *rng.choose(&[8u64, 16, 32]);
            let s = GemmShape::new(
                rng.gen_in(1, 128) * t_edge,
                rng.gen_in(1, 128) * t_edge,
                rng.gen_in(1, 128) * t_edge,
            );
            let t = Tiling::square(t_edge);
            let tas = ema(Scheme::Tas, &s, &t).total();
            let is_os = ema(Scheme::IsOs, &s, &t).total();
            let ws_os = ema(Scheme::WsOs, &s, &t).total();
            assert_eq!(
                tas,
                is_os.min(ws_os),
                "shape {s:?}: tas {tas}, is-os {is_os}, ws-os {ws_os}"
            );
        });
    }

    #[test]
    fn tas_near_optimal_on_ragged_shapes() {
        // On non-divisible shapes the ceilings make the cheap sign rule
        // off-by-a-whisker in rare cases; bound the regret at 10%.
        property("tas <= 1.1 min (ragged)", 500, |rng: &mut Rng| {
            let s = GemmShape::new(
                rng.gen_in(1, 4096),
                rng.gen_in(1, 4096),
                rng.gen_in(1, 4096),
            );
            let t = Tiling::square(*rng.choose(&[8, 16, 32]));
            let tas = ema(Scheme::Tas, &s, &t).total();
            let best = ema(Scheme::IsOs, &s, &t)
                .total()
                .min(ema(Scheme::WsOs, &s, &t).total());
            assert!(
                tas as f64 <= best as f64 * 1.1,
                "shape {s:?}: tas {tas} vs best {best}"
            );
        });
    }

    #[test]
    fn decision_rule_sign() {
        assert!(is_ws_difference(&GemmShape::new(115, 1024, 1024)) < 0);
        assert!(is_ws_difference(&GemmShape::new(1565, 1024, 1024)) > 0);
        assert_eq!(is_ws_difference(&GemmShape::new(1024, 77, 1024)), 0);
    }

    #[test]
    fn hybrids_never_worse_than_parents() {
        property("is-os <= is, ws-os <= ws", 300, |rng: &mut Rng| {
            let s = GemmShape::new(
                rng.gen_in(1, 2048),
                rng.gen_in(1, 2048),
                rng.gen_in(1, 2048),
            );
            let t = Tiling::square(16);
            assert!(ema(Scheme::IsOs, &s, &t).total() <= ema(Scheme::Is, &s, &t).total());
            assert!(ema(Scheme::WsOs, &s, &t).total() <= ema(Scheme::Ws, &s, &t).total());
            // and everything beats naive
            for sch in Scheme::FIXED {
                assert!(ema(sch, &s, &t).total() <= ema(Scheme::Naive, &s, &t).total());
            }
        });
    }

    #[test]
    fn stationary_matrix_table3_semantics() {
        // Wav2Vec2-Large Q proj: N = K = 1024 (Table III).
        let s = GemmShape::new(115, 1024, 1024);
        assert_eq!(stationary_matrix_words(Scheme::Is, &s), 115 * 1024);
        assert_eq!(stationary_matrix_words(Scheme::Ws, &s), 1024 * 1024);
    }
}
