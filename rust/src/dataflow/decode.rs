//! KV-cache-aware decode planning: the autoregressive phase model.
//!
//! Prefill processes all prompt tokens at once, so per-GEMM TAS and the
//! layer planner ([`super::layer`]) have fat `M` to work with.  Decode is
//! the opposite regime: every step is a *skinny* GEMM (`M = 1..batch`)
//! against a K/V cache that grows by one token per step, so weight and
//! cache traffic dominate and the prefill residency model does not apply
//! (T-REX, ISSCC 2025; "Data Movement Is All You Need", Ivanov et al.).
//!
//! This module introduces:
//!
//! * a [`Phase`] model — `Prefill` vs `Decode { step, batch }`;
//! * a **cache edge** on [`StageSpec`] ([`CacheEdge`]): attention stages
//!   declare the K/V tensor they append to or read, so the planner knows
//!   which weight-side operands persist and grow across steps;
//! * [`DecodePlan`] — a whole trajectory (prefill at seq `S`, then `T`
//!   decode steps at batch `B`).  The planner keeps the **newest** cache
//!   rows SRAM-resident under the cumulative budget (coldest rows are
//!   evicted first; the cache is write-through, so eviction is free) and
//!   runs the per-tile TAS chooser with cache-resident operands priced at
//!   zero EMA ([`Plan::tas_cached`]).  A partially resident cache splits
//!   the attention GEMM into a hot slice (resident rows, weight stream
//!   free) and a cold slice (DRAM rows) — the stationary decision flips
//!   per tile, not per GEMM, and the split is only kept when it beats the
//!   unsplit plan, so a decode plan never loses to per-GEMM TAS;
//! * [`ShardedDecodePlan`] — decode across devices with the cache
//!   **sharded by heads** ([`super::shard::shard_heads`]): each device
//!   owns its heads' K/V blocks (aggregate SRAM scales with the device
//!   count), QKV/FFN weights are column/row split Megatron-style, and the
//!   per-layer partial sums cross the interconnect as tree reduces.
//!
//! Residency model for one decode step: attention touches every cache
//! row, so streaming the cold rows necessarily brings them on-chip —
//! *retaining* the newest `R` of them for the next step costs nothing.
//! Hot rows are therefore free from step 1 on (step 0 inherits nothing:
//! prefill wrote the cache through to DRAM), and the resident set never
//! exceeds `R · row_words`, which is carved out of the SRAM budget after
//! the step's activation residency claim.

use super::analytic;
use super::layer::{LayerPlan, StageSpec};
use super::plan::Plan;
use super::shard::{even_bounds, shard_heads};
use super::Scheme;
use crate::arch::Interconnect;
use crate::gemm::{GemmShape, Tiling};
use crate::models::ModelSpec;
use crate::util::ceil_div;
use std::collections::HashMap;

/// Memo of cover searches keyed by (shape, residency flags): within one
/// trajectory the tiling is fixed and the cache-length-independent stages
/// (projections, FFN, LM head) repeat identical searches every step.
type PlanMemo = HashMap<(GemmShape, bool, bool, bool), Plan>;

fn memo_plan(
    memo: &mut PlanMemo,
    shape: &GemmShape,
    tiling: &Tiling,
    input_resident: bool,
    weight_resident: bool,
    output_resident: bool,
) -> Plan {
    memo.entry((*shape, input_resident, weight_resident, output_resident))
        .or_insert_with(|| {
            Plan::tas_cached(shape, tiling, input_resident, weight_resident, output_resident)
        })
        .clone()
}

/// Execution phase of a transformer workload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Prompt ingestion: all tokens at once (`M = batch × seq`).
    Prefill { seq: u64 },
    /// One autoregressive step: `M = batch`, attention over the cache.
    Decode { step: u64, batch: u64 },
}

/// Which persistent cache tensor an attention stage touches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheTensor {
    Key,
    Value,
}

/// How a stage relates to a K/V cache tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheEdge {
    /// The stage's output appends one row per sequence (k/v projections).
    Append(CacheTensor),
    /// The stage's weight-side operand *is* the cache (attention matmuls:
    /// `q·Kᵀ` reads the K cache along its output axis, `p·V` reads the V
    /// cache along its contraction axis).
    Read(CacheTensor),
}

/// Raw decode dimensions — the coordinator builds these straight from
/// manifest dims, the CLI from a [`ModelSpec`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecodeDims {
    pub hidden: u64,
    pub ffn: u64,
    pub layers: u64,
    pub heads: u64,
    /// 0 = no LM head.
    pub vocab: u64,
}

impl DecodeDims {
    pub fn of(model: &ModelSpec) -> DecodeDims {
        DecodeDims {
            hidden: model.hidden,
            ffn: model.ffn,
            layers: model.layers,
            heads: model.heads,
            vocab: model.vocab.unwrap_or(0),
        }
    }

    pub fn head_dim(&self) -> u64 {
        self.hidden / self.heads
    }

    fn validate(&self) {
        assert!(self.layers > 0 && self.heads > 0 && self.hidden > 0);
        assert_eq!(
            self.hidden % self.heads,
            0,
            "hidden {} must divide into {} heads",
            self.hidden,
            self.heads
        );
    }
}

/// Stage inventory of ONE decode step: `batch` in-flight sequences whose
/// per-sequence K/V caches hold `cache_len` positions (including the
/// token being generated).  Linear projections are batched across
/// sequences (shared weights, `M = batch`); attention matmuls are
/// per-sequence-per-head (`M = 1`, distinct caches), which is exactly
/// where cache-resident per-tile TAS acts.
pub fn decode_step_stages(dims: &DecodeDims, batch: u64, cache_len: u64) -> Vec<StageSpec> {
    decode_step_stages_sliced(dims, batch, cache_len, dims.heads, dims.ffn, dims.vocab)
}

/// Head/ffn/vocab-sliced variant for head-sharded decode: weight columns
/// shrink to the slice, the input width stays the full hidden dim.
pub(crate) fn decode_step_stages_sliced(
    dims: &DecodeDims,
    batch: u64,
    cache_len: u64,
    heads_slice: u64,
    ffn_slice: u64,
    vocab_slice: u64,
) -> Vec<StageSpec> {
    dims.validate();
    assert!(batch > 0 && cache_len > 0 && heads_slice > 0 && ffn_slice > 0);
    let h = dims.hidden;
    let d = dims.head_dim();
    let hs = heads_slice * d;
    let l = dims.layers;
    let attn = l * heads_slice * batch;
    let stage = |name, shape, count, consumes, shares, cache| StageSpec {
        name,
        shape,
        count,
        consumes_previous: consumes,
        shares_input_with_previous: shares,
        cache,
    };
    let k_app = Some(CacheEdge::Append(CacheTensor::Key));
    let v_app = Some(CacheEdge::Append(CacheTensor::Value));
    let k_read = Some(CacheEdge::Read(CacheTensor::Key));
    let v_read = Some(CacheEdge::Read(CacheTensor::Value));
    let proj = GemmShape::new(batch, h, hs);
    let mut v = vec![
        stage("k", proj, l, false, false, k_app),
        stage("v", proj, l, false, true, v_app),
        stage("q", proj, l, false, true, None),
        stage("qk_t", GemmShape::new(1, d, cache_len), attn, true, false, k_read),
        stage("attn_v", GemmShape::new(1, cache_len, d), attn, true, false, v_read),
        stage("attn_out", GemmShape::new(batch, hs, h), l, true, false, None),
        stage("ffn1", GemmShape::new(batch, h, ffn_slice), l, true, false, None),
        stage("ffn2", GemmShape::new(batch, ffn_slice, h), l, true, false, None),
    ];
    if vocab_slice > 0 {
        let head = GemmShape::new(batch, h, vocab_slice);
        v.push(stage("lm_head", head, 1, false, false, None));
    }
    v
}

/// Prefill stage chain with sliced weight columns — reduces to
/// [`ModelSpec::block_stages`] for full slices (asserted in tests).
pub(crate) fn prefill_stages_sliced(
    dims: &DecodeDims,
    tokens: u64,
    heads_slice: u64,
    ffn_slice: u64,
    vocab_slice: u64,
) -> Vec<StageSpec> {
    dims.validate();
    assert!(tokens > 0 && heads_slice > 0 && ffn_slice > 0);
    let h = dims.hidden;
    let hs = heads_slice * dims.head_dim();
    let l = dims.layers;
    let stage = |name, shape, count, consumes, shares| StageSpec {
        name,
        shape,
        count,
        consumes_previous: consumes,
        shares_input_with_previous: shares,
        cache: None,
    };
    let mut v = vec![
        stage("q", GemmShape::new(tokens, h, hs), l, false, false),
        stage("k", GemmShape::new(tokens, h, hs), l, false, true),
        stage("v", GemmShape::new(tokens, h, hs), l, false, true),
        stage("attn_out", GemmShape::new(tokens, hs, h), l, false, false),
        stage("ffn1", GemmShape::new(tokens, h, ffn_slice), l, true, false),
        stage("ffn2", GemmShape::new(tokens, ffn_slice, h), l, true, false),
    ];
    if vocab_slice > 0 {
        v.push(stage("lm_head", GemmShape::new(tokens, h, vocab_slice), 1, false, false));
    }
    v
}

/// One planned decode stage: residency decisions plus the slice plans.
#[derive(Clone, Debug)]
pub struct DecodeStagePlan {
    pub spec: StageSpec,
    /// GEMM slice plans — one normally; a hot/cold pair when a partially
    /// resident cache splits the stage along its cache axis.
    pub slices: Vec<Plan>,
    /// Input served from SRAM (chained activation) — no DRAM reads.
    pub input_resident: bool,
    /// Output handed on-chip to the next stage — no DRAM writes.
    pub output_resident: bool,
    /// Cache words served from SRAM per instance (hot-slice weights).
    pub cache_hot_words: u64,
    /// DRAM words per instance under this plan (summed over slices).
    pub ema_words: u64,
    /// DRAM words per instance under per-GEMM TAS on the unsplit shape.
    pub per_gemm_tas_words: u64,
}

/// One planned decode step: every stage of the block at one cache length.
#[derive(Clone, Debug)]
pub struct DecodeStepPlan {
    pub phase: Phase,
    /// Positions attended this step (cache length including new token).
    pub cache_len: u64,
    /// Cache rows resident in SRAM while this step runs (newest rows).
    pub hot_rows: u64,
    /// Peak SRAM words the step's resident activations claim.
    pub act_resident_words: u64,
    pub stages: Vec<DecodeStagePlan>,
}

impl DecodeStepPlan {
    /// DRAM words of one decode step under this plan.
    pub fn total_ema(&self) -> u64 {
        self.stages.iter().map(|s| s.spec.count * s.ema_words).sum()
    }

    /// DRAM words of the same step under per-GEMM TAS (the baseline the
    /// decode plan must never exceed).
    pub fn per_gemm_tas_total(&self) -> u64 {
        self.stages
            .iter()
            .map(|s| s.spec.count * s.per_gemm_tas_words)
            .sum()
    }

    /// Cache words served from SRAM this step (all instances).
    pub fn cache_hot_total(&self) -> u64 {
        self.stages
            .iter()
            .map(|s| s.spec.count * s.cache_hot_words)
            .sum()
    }

    pub fn reduction_vs_per_gemm(&self) -> f64 {
        let base = self.per_gemm_tas_total();
        if base == 0 {
            0.0
        } else {
            1.0 - self.total_ema() as f64 / base as f64
        }
    }
}

/// Plan one decode step over an explicit stage list.  `hot_rows` cache
/// rows (strictly fewer than `cache_len` — the new token's row is never
/// pre-resident) are SRAM-resident; `budget` bounds activation residency.
pub fn plan_decode_step(
    stages: &[StageSpec],
    layers: u64,
    cache_len: u64,
    hot_rows: u64,
    tiling: &Tiling,
    budget: u64,
    phase: Phase,
) -> DecodeStepPlan {
    let mut memo = PlanMemo::new();
    plan_decode_step_memo(stages, layers, cache_len, hot_rows, tiling, budget, phase, &mut memo)
}

/// The memoised core: `memo` carries cover searches across the steps of
/// one trajectory, so the shapes that do not depend on the cache length
/// are planned once instead of once per step.
#[allow(clippy::too_many_arguments)]
fn plan_decode_step_memo(
    stages: &[StageSpec],
    layers: u64,
    cache_len: u64,
    hot_rows: u64,
    tiling: &Tiling,
    budget: u64,
    phase: Phase,
    memo: &mut PlanMemo,
) -> DecodeStepPlan {
    assert!(hot_rows < cache_len, "the newest row is appended this step");
    let fits = |w: u64| w > 0 && w <= budget;
    // Aggregate tensor sizes per layer: attention stages run
    // heads × batch instances whose activations coexist within a layer.
    let per_layer = |s: &StageSpec| (s.count / layers.max(1)).max(1);

    let mut planned: Vec<DecodeStagePlan> = Vec::with_capacity(stages.len());
    let mut act_peak = 0u64;
    for (idx, spec) in stages.iter().enumerate() {
        let group_in = per_layer(spec) * spec.shape.input_words();
        let group_out = per_layer(spec) * spec.shape.output_words();
        let input_resident = if spec.shares_input_with_previous && idx > 0 {
            fits(spec.shape.input_words())
        } else if spec.consumes_previous && idx > 0 {
            planned[idx - 1].output_resident
        } else {
            false
        };
        // The consumer may fan out (q -> per-head qk_t) or fan in
        // (per-head attn_v -> attn_out); either way the chained tensor is
        // the same per-layer aggregate, so counts must divide.
        let output_resident = stages
            .get(idx + 1)
            .map(|next| {
                next.consumes_previous
                    && (next.count % spec.count.max(1) == 0
                        || spec.count % next.count.max(1) == 0)
                    && fits(group_out + if input_resident { group_in } else { 0 })
            })
            .unwrap_or(false);
        let held = (if output_resident { group_out } else { 0 })
            + (if input_resident { group_in } else { 0 });
        act_peak = act_peak.max(held);

        let unsplit =
            memo_plan(memo, &spec.shape, tiling, input_resident, false, output_resident);
        let mut slices = vec![unsplit];
        let mut cache_hot_words = 0u64;
        if let Some(CacheEdge::Read(tensor)) = spec.cache {
            if hot_rows > 0 {
                let GemmShape { m, n, k } = spec.shape;
                let (hot, cold) = match tensor {
                    // K cache runs along the output axis: split K.
                    CacheTensor::Key => {
                        debug_assert_eq!(k, cache_len);
                        (
                            memo_plan(
                                memo,
                                &GemmShape::new(m, n, hot_rows),
                                tiling,
                                input_resident,
                                true,
                                output_resident,
                            ),
                            memo_plan(
                                memo,
                                &GemmShape::new(m, n, k - hot_rows),
                                tiling,
                                input_resident,
                                false,
                                output_resident,
                            ),
                        )
                    }
                    // V cache runs along the contraction: split N; the hot
                    // slice's partial context accumulates on chip.
                    CacheTensor::Value => {
                        debug_assert_eq!(n, cache_len);
                        (
                            memo_plan(
                                memo,
                                &GemmShape::new(m, hot_rows, k),
                                tiling,
                                input_resident,
                                true,
                                true,
                            ),
                            memo_plan(
                                memo,
                                &GemmShape::new(m, n - hot_rows, k),
                                tiling,
                                input_resident,
                                false,
                                output_resident,
                            ),
                        )
                    }
                };
                let split_total = hot.ema().total() + cold.ema().total();
                // Keep the split only when it wins: never worse than the
                // unsplit per-tile plan, hence never worse than per-GEMM
                // TAS either.
                if split_total < slices[0].ema().total() {
                    cache_hot_words = hot.shape.weight_words();
                    slices = vec![hot, cold];
                }
            }
        }
        let ema_words: u64 = slices.iter().map(|p| p.ema().total()).sum();
        let per_gemm_tas_words = analytic::ema(Scheme::Tas, &spec.shape, tiling).total();
        planned.push(DecodeStagePlan {
            spec: spec.clone(),
            slices,
            input_resident,
            output_resident,
            cache_hot_words,
            ema_words,
            per_gemm_tas_words,
        });
    }
    DecodeStepPlan {
        phase,
        cache_len,
        hot_rows,
        act_resident_words: act_peak,
        stages: planned,
    }
}

/// A planned decode trajectory: prefill at seq `S`, then `T` decode steps
/// at batch `B`, with a static cache-residency allocation.
#[derive(Clone, Debug)]
pub struct DecodePlan {
    pub dims: DecodeDims,
    pub batch: u64,
    pub prefill_seq: u64,
    pub steps: u64,
    pub tiling: Tiling,
    /// Head/ffn/vocab slice this plan covers (full dims unless sharded).
    pub heads_slice: u64,
    pub ffn_slice: u64,
    pub vocab_slice: u64,
    /// Planning budget: SRAM minus the double-buffered operand margin.
    pub budget: u64,
    /// SRAM words one resident cache row occupies (one position, both
    /// tensors, every layer, every sequence of the batch).
    pub row_words: u64,
    /// Cache rows the planner keeps resident (newest-first; coldest are
    /// evicted — free, the cache is write-through).
    pub resident_rows: u64,
    /// Peak activation residency reserved ahead of the cache.
    pub act_peak_words: u64,
    pub prefill: LayerPlan,
    pub step_plans: Vec<DecodeStepPlan>,
}

impl DecodePlan {
    /// Plan a trajectory for a zoo model with cache residency on.
    pub fn plan(
        model: &ModelSpec,
        prefill_seq: u64,
        steps: u64,
        batch: u64,
        tiling: &Tiling,
        sram_words: u64,
    ) -> DecodePlan {
        DecodePlan::plan_policy(
            &DecodeDims::of(model),
            prefill_seq,
            steps,
            batch,
            tiling,
            sram_words,
            true,
        )
    }

    /// Plan with explicit cache-residency policy (`false` disables the
    /// hot-row pricing entirely — the conservation baseline the property
    /// tests pin against).
    pub fn plan_policy(
        dims: &DecodeDims,
        prefill_seq: u64,
        steps: u64,
        batch: u64,
        tiling: &Tiling,
        sram_words: u64,
        cache_residency: bool,
    ) -> DecodePlan {
        DecodePlan::plan_sliced(
            dims,
            dims.heads,
            dims.ffn,
            dims.vocab,
            prefill_seq,
            steps,
            batch,
            tiling,
            sram_words,
            cache_residency,
        )
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn plan_sliced(
        dims: &DecodeDims,
        heads_slice: u64,
        ffn_slice: u64,
        vocab_slice: u64,
        prefill_seq: u64,
        steps: u64,
        batch: u64,
        tiling: &Tiling,
        sram_words: u64,
        cache_residency: bool,
    ) -> DecodePlan {
        dims.validate();
        assert!(prefill_seq > 0 && steps > 0 && batch > 0);
        let margin = 4 * (tiling.tm * tiling.tn + tiling.tn * tiling.tk);
        let budget = sram_words.saturating_sub(margin);

        // Pass 1: plan every step cold (hot = 0) to size the activation
        // claim.  Per-step activation claims are NOT monotone in cache
        // length — a per-layer group can stop fitting at the longest
        // step — so the peak is taken over the whole trajectory, not a
        // single probe.  One memo carries the cover searches of the
        // cache-length-independent stages across both passes.
        let mut memo = PlanMemo::new();
        let step_stages = |cache_len: u64| {
            decode_step_stages_sliced(dims, batch, cache_len, heads_slice, ffn_slice, vocab_slice)
        };
        let mut act_peak = 0u64;
        let mut cold_steps = Vec::with_capacity(steps as usize);
        for t in 0..steps {
            let cache_len = prefill_seq + t + 1;
            let sp = plan_decode_step_memo(
                &step_stages(cache_len),
                dims.layers,
                cache_len,
                0,
                tiling,
                budget,
                Phase::Decode { step: t, batch },
                &mut memo,
            );
            act_peak = act_peak.max(sp.act_resident_words);
            cold_steps.push(sp);
        }
        let row_words = 2 * dims.layers * batch * heads_slice * dims.head_dim();
        let cache_budget = budget.saturating_sub(act_peak);
        // Cap at the most rows any step can actually retain (the last
        // step inherits prefill_seq + steps - 1 rows), so the residency
        // claim reports SRAM the trajectory really occupies.
        let resident_rows = if cache_residency && row_words > 0 {
            (cache_budget / row_words).min(prefill_seq + steps - 1)
        } else {
            0
        };

        let prefill_tokens = batch * prefill_seq;
        let prefill = LayerPlan::plan(
            prefill_stages_sliced(dims, prefill_tokens, heads_slice, ffn_slice, vocab_slice),
            prefill_tokens,
            tiling,
            sram_words,
        );

        // Pass 2: re-plan with hot rows; a step that retains nothing
        // reuses its cold plan (the residency walk never depends on
        // hot_rows, so the two passes agree on the activation flags).
        let mut step_plans = Vec::with_capacity(steps as usize);
        for (t, cold) in cold_steps.into_iter().enumerate() {
            let t = t as u64;
            let cache_len = prefill_seq + t + 1;
            // Step 0 inherits nothing (prefill wrote through to DRAM);
            // later steps retain the newest rows streamed last step.
            let hot = if t == 0 { 0 } else { (prefill_seq + t).min(resident_rows) };
            if hot == 0 {
                step_plans.push(cold);
                continue;
            }
            step_plans.push(plan_decode_step_memo(
                &step_stages(cache_len),
                dims.layers,
                cache_len,
                hot,
                tiling,
                budget,
                Phase::Decode { step: t, batch },
                &mut memo,
            ));
        }
        DecodePlan {
            dims: *dims,
            batch,
            prefill_seq,
            steps,
            tiling: *tiling,
            heads_slice,
            ffn_slice,
            vocab_slice,
            budget,
            row_words,
            resident_rows,
            act_peak_words: act_peak,
            prefill,
            step_plans,
        }
    }

    /// One steady-state decode step at `cache_len` (the coordinator's
    /// decode-bucket unit): hot rows as a retained trajectory would have.
    pub fn plan_step(
        dims: &DecodeDims,
        batch: u64,
        cache_len: u64,
        tiling: &Tiling,
        sram_words: u64,
    ) -> DecodeStepPlan {
        dims.validate();
        assert!(batch > 0 && cache_len > 0);
        let margin = 4 * (tiling.tm * tiling.tn + tiling.tn * tiling.tk);
        let budget = sram_words.saturating_sub(margin);
        let stages = decode_step_stages(dims, batch, cache_len);
        // One memo serves both passes: the probe's cover searches for the
        // cache-length-independent stages are reused by the final plan.
        let mut memo = PlanMemo::new();
        let probe = plan_decode_step_memo(
            &stages,
            dims.layers,
            cache_len,
            0,
            tiling,
            budget,
            Phase::Decode { step: 0, batch },
            &mut memo,
        );
        let row_words = 2 * dims.layers * batch * dims.hidden;
        let cache_budget = budget.saturating_sub(probe.act_resident_words);
        let hot = if row_words > 0 {
            (cache_budget / row_words).min(cache_len - 1)
        } else {
            0
        };
        if hot == 0 {
            return probe;
        }
        plan_decode_step_memo(
            &stages,
            dims.layers,
            cache_len,
            hot,
            tiling,
            budget,
            Phase::Decode { step: 0, batch },
            &mut memo,
        )
    }

    /// DRAM words of the decode phase (all `T` steps).
    pub fn decode_ema(&self) -> u64 {
        self.step_plans.iter().map(|s| s.total_ema()).sum()
    }

    /// Decode-phase DRAM words under per-GEMM TAS at the same shapes.
    pub fn per_gemm_tas_decode_total(&self) -> u64 {
        self.step_plans.iter().map(|s| s.per_gemm_tas_total()).sum()
    }

    /// Whole-trajectory DRAM words (prefill + decode).
    pub fn total_ema(&self) -> u64 {
        self.prefill.total_ema() + self.decode_ema()
    }

    /// Decode DRAM words per generated token.
    pub fn per_token_ema(&self) -> f64 {
        self.decode_ema() as f64 / (self.steps * self.batch) as f64
    }

    /// Per-token baseline under per-GEMM TAS.
    pub fn per_token_per_gemm_tas(&self) -> f64 {
        self.per_gemm_tas_decode_total() as f64 / (self.steps * self.batch) as f64
    }

    /// Fractional decode saving over per-GEMM TAS.
    pub fn reduction_vs_per_gemm(&self) -> f64 {
        let base = self.per_gemm_tas_decode_total();
        if base == 0 {
            0.0
        } else {
            1.0 - self.decode_ema() as f64 / base as f64
        }
    }

    /// Upper bound on cache words resident at any point of the trajectory.
    pub fn max_cache_resident_words(&self) -> u64 {
        self.resident_rows * self.row_words
    }

    /// Peak SRAM the plan ever claims (activations + resident cache) —
    /// never exceeds [`DecodePlan::budget`] by construction
    /// (property-tested in `rust/tests/decode_invariants.rs`).
    pub fn peak_sram_claim(&self) -> u64 {
        self.act_peak_words + self.max_cache_resident_words()
    }
}

/// Decode across devices with the cache sharded by heads: device `d` owns
/// head range `head_ranges[d]` (its K/V blocks live in — and fill — its
/// own SRAM), QKV/FFN weight columns are split to match, and each layer's
/// attention/FFN partial sums are all-reduced across the links.
#[derive(Clone, Debug)]
pub struct ShardedDecodePlan {
    pub dims: DecodeDims,
    pub batch: u64,
    pub steps: u64,
    pub devices: u64,
    /// `(head_lo, head_hi)` per device.
    pub head_ranges: Vec<(u64, u64)>,
    pub per_device: Vec<DecodePlan>,
    /// Partial-sum words crossing links per decode step (tree reduces of
    /// the attention-output and FFN contractions, every layer).
    pub reduce_words_per_step: u64,
    /// Broadcast/all-gather words per decode step (reduced activations
    /// back to every device, plus the LM-head logit gather).
    pub gather_words_per_step: u64,
}

impl ShardedDecodePlan {
    pub fn plan(
        dims: &DecodeDims,
        prefill_seq: u64,
        steps: u64,
        batch: u64,
        tiling: &Tiling,
        sram_words_per_device: u64,
        devices: u64,
    ) -> anyhow::Result<ShardedDecodePlan> {
        dims.validate();
        let devices = devices.max(1);
        anyhow::ensure!(
            devices <= dims.heads,
            "cannot shard {} heads across {devices} devices",
            dims.heads
        );
        let head_ranges = shard_heads(dims.heads, devices);
        let ffn_bounds = even_bounds(dims.ffn, devices);
        let vocab_bounds = even_bounds(dims.vocab, devices);
        let mut per_device = Vec::with_capacity(devices as usize);
        for dev in 0..devices as usize {
            let heads_slice = head_ranges[dev].1 - head_ranges[dev].0;
            let ffn_slice = ffn_bounds[dev + 1] - ffn_bounds[dev];
            let vocab_slice = vocab_bounds[dev + 1] - vocab_bounds[dev];
            per_device.push(DecodePlan::plan_sliced(
                dims,
                heads_slice,
                ffn_slice,
                vocab_slice,
                prefill_seq,
                steps,
                batch,
                tiling,
                sram_words_per_device,
                true,
            ));
        }
        let bh = batch * dims.hidden;
        let (reduce, mut gather) = if devices > 1 {
            // Two all-reduces per layer (attention output + FFN down),
            // modelled as tree-reduce + tree-broadcast of B×H partials.
            let per_layer = 2 * (devices - 1) * bh;
            (dims.layers * per_layer, dims.layers * per_layer)
        } else {
            (0, 0)
        };
        if dims.vocab > 0 && devices > 1 {
            gather += (devices - 1) * batch * dims.vocab;
        }
        Ok(ShardedDecodePlan {
            dims: *dims,
            batch,
            steps,
            devices,
            head_ranges,
            per_device,
            reduce_words_per_step: reduce,
            gather_words_per_step: gather,
        })
    }

    /// Summed decode DRAM words across devices.
    pub fn decode_ema(&self) -> u64 {
        self.per_device.iter().map(|p| p.decode_ema()).sum()
    }

    /// Busiest device's decode DRAM words — the critical path.
    pub fn max_device_decode_ema(&self) -> u64 {
        self.per_device
            .iter()
            .map(|p| p.decode_ema())
            .max()
            .unwrap_or(0)
    }

    pub fn per_gemm_tas_decode_total(&self) -> u64 {
        self.per_device
            .iter()
            .map(|p| p.per_gemm_tas_decode_total())
            .sum()
    }

    /// Inter-chip words over the whole trajectory.
    pub fn link_words_total(&self) -> u64 {
        self.steps * (self.reduce_words_per_step + self.gather_words_per_step)
    }

    /// Cache words resident across ALL devices — head sharding scales the
    /// aggregate residency with the device count.
    pub fn total_resident_cache_words(&self) -> u64 {
        self.per_device
            .iter()
            .map(|p| p.max_cache_resident_words())
            .sum()
    }

    /// Serialized link time of one decode step under `icx`: per layer two
    /// all-reduces (tree reduce + broadcast), plus the logit all-gather.
    pub fn link_cycles_per_step(&self, icx: &Interconnect) -> u64 {
        if self.devices <= 1 {
            return 0;
        }
        let bh = self.batch * self.dims.hidden;
        let allreduce = 2 * icx.tree_reduce_cycles(bh, self.devices);
        let mut cycles = 2 * self.dims.layers * allreduce;
        if self.dims.vocab > 0 {
            cycles += icx.all_gather_cycles(
                ceil_div(self.batch * self.dims.vocab, self.devices),
                self.devices,
            );
        }
        cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    fn dims() -> DecodeDims {
        DecodeDims::of(&zoo::bert_base())
    }

    #[test]
    fn decode_stages_cover_the_block_and_scale_with_heads() {
        let d = dims();
        let stages = decode_step_stages(&d, 8, 96);
        let qk = stages.iter().find(|s| s.name == "qk_t").unwrap();
        assert_eq!(qk.count, 12 * 12 * 8);
        assert_eq!(qk.shape, GemmShape::new(1, 64, 96));
        assert_eq!(qk.cache, Some(CacheEdge::Read(CacheTensor::Key)));
        let av = stages.iter().find(|s| s.name == "attn_v").unwrap();
        assert_eq!(av.shape, GemmShape::new(1, 96, 64));
        let k = stages.iter().find(|s| s.name == "k").unwrap();
        assert_eq!(k.cache, Some(CacheEdge::Append(CacheTensor::Key)));
        // linear stages batch across sequences
        let ffn1 = stages.iter().find(|s| s.name == "ffn1").unwrap();
        assert_eq!(ffn1.shape, GemmShape::new(8, 768, 3072));
    }

    #[test]
    fn prefill_stages_reduce_to_block_stages() {
        for m in zoo::all_models() {
            let d = DecodeDims::of(&m);
            let mine = prefill_stages_sliced(&d, 384, d.heads, d.ffn, d.vocab);
            assert_eq!(mine, m.block_stages(384), "{}", m.name);
        }
    }

    #[test]
    fn step_plan_never_worse_than_per_gemm_tas() {
        let d = dims();
        let t = Tiling::square(16);
        let phase = Phase::Decode { step: 1, batch: 8 };
        for hot in [0u64, 1, 13, 64] {
            let stages = decode_step_stages(&d, 8, 96);
            let p = plan_decode_step(&stages, d.layers, 96, hot, &t, 256 * 1024, phase);
            for s in &p.stages {
                assert!(
                    s.ema_words <= s.per_gemm_tas_words,
                    "{} hot={hot}: {} > {}",
                    s.spec.name,
                    s.ema_words,
                    s.per_gemm_tas_words
                );
            }
            assert!(p.total_ema() <= p.per_gemm_tas_total());
        }
    }

    #[test]
    fn hot_rows_price_the_cache_at_zero_and_win() {
        let d = dims();
        let t = Tiling::square(16);
        let stages = decode_step_stages(&d, 1, 96);
        let phase = Phase::Decode { step: 1, batch: 1 };
        let cold = plan_decode_step(&stages, d.layers, 96, 0, &t, 256 * 1024, phase);
        let hot = plan_decode_step(&stages, d.layers, 96, 64, &t, 256 * 1024, phase);
        assert!(hot.total_ema() < cold.total_ema());
        assert!(hot.cache_hot_total() > 0);
        assert_eq!(cold.cache_hot_total(), 0);
        // the attention stages actually split
        let qk = hot.stages.iter().find(|s| s.spec.name == "qk_t").unwrap();
        assert_eq!(qk.slices.len(), 2);
        assert!(qk.slices[0].weight_resident);
        assert!(!qk.slices[1].weight_resident);
    }

    #[test]
    fn trajectory_retains_rows_from_step_one() {
        let p = DecodePlan::plan(&zoo::bert_base(), 64, 8, 1, &Tiling::square(16), 256 * 1024);
        assert_eq!(p.step_plans[0].hot_rows, 0, "nothing retained from prefill");
        if p.resident_rows > 0 {
            assert!(p.step_plans[1].hot_rows > 0);
        }
        for (t, sp) in p.step_plans.iter().enumerate() {
            assert_eq!(sp.cache_len, 64 + t as u64 + 1);
            assert!(sp.hot_rows < sp.cache_len);
            assert!(sp.hot_rows <= p.resident_rows);
        }
        // the budget is respected
        assert!(p.peak_sram_claim() <= p.budget);
    }

    #[test]
    fn resident_rows_never_exceed_what_the_trajectory_holds() {
        // Plenty of SRAM, short trajectory: the claim must report rows
        // the cache can actually contain, not raw budget capacity.
        let p = DecodePlan::plan(
            &zoo::bert_base(),
            64,
            4,
            1,
            &Tiling::square(16),
            4 * 1024 * 1024,
        );
        assert_eq!(p.resident_rows, 64 + 4 - 1);
        assert!(p.peak_sram_claim() <= p.budget);
    }

    #[test]
    fn residency_disabled_prices_every_row_cold() {
        let d = dims();
        let t = Tiling::square(16);
        let on = DecodePlan::plan_policy(&d, 64, 4, 1, &t, 256 * 1024, true);
        let off = DecodePlan::plan_policy(&d, 64, 4, 1, &t, 256 * 1024, false);
        assert_eq!(off.resident_rows, 0);
        assert!(off.step_plans.iter().all(|s| s.hot_rows == 0));
        assert!(on.decode_ema() <= off.decode_ema());
        // identical per-GEMM baseline either way
        assert_eq!(on.per_gemm_tas_decode_total(), off.per_gemm_tas_decode_total());
    }

    #[test]
    fn steady_state_step_plan_uses_retained_rows() {
        let d = dims();
        let sp = DecodePlan::plan_step(&d, 1, 96, &Tiling::square(16), 256 * 1024);
        assert!(sp.hot_rows > 0);
        assert!(sp.total_ema() <= sp.per_gemm_tas_total());
    }

    #[test]
    fn head_sharding_splits_work_and_scales_cache_residency() {
        let d = dims();
        let t = Tiling::square(16);
        let single = DecodePlan::plan_policy(&d, 64, 4, 8, &t, 256 * 1024, true);
        let sharded =
            ShardedDecodePlan::plan(&d, 64, 4, 8, &t, 256 * 1024, 4).unwrap();
        assert_eq!(sharded.per_device.len(), 4);
        // every device owns a non-empty contiguous head range
        let total_heads: u64 =
            sharded.head_ranges.iter().map(|(lo, hi)| hi - lo).sum();
        assert_eq!(total_heads, d.heads);
        // MACs partition exactly across devices
        let macs = |p: &DecodePlan| -> u64 {
            p.step_plans
                .iter()
                .flat_map(|s| s.stages.iter())
                .map(|s| s.spec.count * s.spec.shape.macs())
                .sum()
        };
        let total: u64 = sharded.per_device.iter().map(macs).sum();
        assert_eq!(total, macs(&single));
        // aggregate SRAM scales: 4 devices park at least as many cache
        // words as one (in practice several times more)
        assert!(
            sharded.total_resident_cache_words()
                >= single.max_cache_resident_words()
        );
        // the links carry the per-layer all-reduces
        assert!(sharded.reduce_words_per_step > 0);
        assert!(sharded.link_words_total() > 0);
        assert!(sharded.link_cycles_per_step(&Interconnect::default()) > 0);
    }

    #[test]
    fn sharding_rejects_more_devices_than_heads() {
        let d = dims();
        assert!(ShardedDecodePlan::plan(&d, 64, 2, 1, &Tiling::square(16), 256 * 1024, 64)
            .is_err());
    }

    #[test]
    fn one_device_shard_matches_the_unsharded_plan() {
        let d = dims();
        let t = Tiling::square(16);
        let single = DecodePlan::plan_policy(&d, 64, 4, 2, &t, 256 * 1024, true);
        let sharded = ShardedDecodePlan::plan(&d, 64, 4, 2, &t, 256 * 1024, 1).unwrap();
        assert_eq!(sharded.decode_ema(), single.decode_ema());
        assert_eq!(sharded.link_words_total(), 0);
        assert_eq!(sharded.link_cycles_per_step(&Interconnect::default()), 0);
    }
}
