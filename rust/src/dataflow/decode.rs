//! KV-cache-aware decode planning: the autoregressive phase model.
//!
//! Prefill processes all prompt tokens at once, so per-GEMM TAS and the
//! layer planner ([`super::layer`]) have fat `M` to work with.  Decode is
//! the opposite regime: every step is a *skinny* GEMM (`M = 1..batch`)
//! against a K/V cache that grows per step, so weight and cache traffic
//! dominate and the prefill residency model does not apply (T-REX, ISSCC
//! 2025; "Data Movement Is All You Need", Ivanov et al.).
//!
//! This module introduces:
//!
//! * a [`Phase`] model — `Prefill` vs `Decode { step, batch }`.  A
//!   speculative draft-and-verify step is the same model with
//!   `M = batch × (draft + 1)` — see [`DecodePlan::plan_draft`];
//! * a **cache edge** on [`StageSpec`] ([`CacheEdge`]): attention stages
//!   declare the K/V tensor they append to or read, so the planner knows
//!   which weight-side operands persist and grow across steps;
//! * [`DecodePlan`] — a whole trajectory (prefill at seq `S`, then `T`
//!   decode steps at batch `B`).  Under the paged policy
//!   ([`ResidencyPolicy::Paged`]) the SRAM left after the step's
//!   activation claim is handed to the [`ResidencyAllocator`]
//!   ([`super::residency`]): the candidates are every layer's K/V cache
//!   rows *and every layer's weight slices* (decode re-reads weights each
//!   step, so parked weight columns save exactly as many words per SRAM
//!   word as parked cache rows — the FlexGen-style trade the uniform
//!   split could not express).  A partially resident cache or weight
//!   splits its GEMM into a hot slice (resident operand, weight stream
//!   free — [`Plan::tas_cached`]) and a cold slice; the split is kept
//!   only when it wins, so a decode plan never loses to per-GEMM TAS.
//!   The seed's uniform per-layer cache split survives as
//!   [`ResidencyPolicy::AllOrNothing`] and the paged planner keeps
//!   whichever prices lower, so paged never loses to uniform either;
//! * [`ShardedDecodePlan`] — decode across devices with the cache
//!   **sharded by heads** ([`super::shard::shard_heads`]): each device
//!   owns its heads' K/V blocks (aggregate SRAM scales with the device
//!   count), QKV/FFN weights are column/row split Megatron-style, and the
//!   per-layer partial sums cross the interconnect as tree reduces.
//!
//! Residency model for one decode step: attention touches every cache
//! row, so streaming the cold rows necessarily brings them on-chip —
//! *retaining* the newest rows for the next step costs nothing, and the
//! same holds for weight slices (every step streams every weight).  Hot
//! operands are therefore free from step 1 on (step 0 inherits nothing:
//! prefill wrote the cache through to DRAM), and the resident claim never
//! exceeds the allocation, which is carved out of the SRAM budget after
//! the step's activation residency claim.

use super::analytic;
use super::layer::{LayerPlan, StageSpec};
use super::plan::Plan;
use super::residency::{
    split_cols, split_contraction, Candidate, Residency, ResidencyAllocator, ResidencyPolicy,
};
use super::shard::{even_bounds, shard_heads};
use super::Scheme;
use crate::arch::backend::PlanPricing;
use crate::arch::Interconnect;
use crate::gemm::{GemmShape, Tiling};
use crate::models::ModelSpec;
use crate::util::ceil_div;
use std::collections::{BTreeMap, HashMap};

/// Memo of cover searches keyed by (shape, residency triple): within one
/// trajectory the tiling is fixed and the cache-length-independent stages
/// (projections, FFN, LM head) repeat identical searches every step.
/// Carries the backend pricing every cover is searched and costed under,
/// so one trajectory never mixes backends.
struct PlanMemo {
    pricing: PlanPricing,
    plans: HashMap<(GemmShape, Residency, Residency, Residency), Plan>,
}

impl PlanMemo {
    fn new() -> PlanMemo {
        PlanMemo::priced(PlanPricing::systolic())
    }

    fn priced(pricing: PlanPricing) -> PlanMemo {
        PlanMemo { pricing, plans: HashMap::new() }
    }

    /// Words the backend streams for `plan` — the quantity every
    /// split-vs-unsplit comparison below minimises.  Systolic pricing
    /// charges every operand, reproducing `plan.ema().total()`.
    fn cost(&self, plan: &Plan) -> u64 {
        plan.ema_words_charged(self.pricing.charge)
    }
}

fn memo_plan(
    memo: &mut PlanMemo,
    shape: &GemmShape,
    tiling: &Tiling,
    input: Residency,
    weight: Residency,
    output: Residency,
) -> Plan {
    let pricing = memo.pricing;
    memo.plans
        .entry((*shape, input, weight, output))
        .or_insert_with(|| Plan::tas_priced(shape, tiling, input, weight, output, &pricing))
        .clone()
}

/// Execution phase of a transformer workload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Prompt ingestion: all tokens at once (`M = batch × seq`).
    Prefill { seq: u64 },
    /// One autoregressive step: `M = batch × step_tokens`, attention over
    /// the cache.  Plain decode has one token per sequence per step;
    /// speculative draft-and-verify has `draft + 1`.
    Decode { step: u64, batch: u64 },
}

/// Which persistent cache tensor an attention stage touches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheTensor {
    Key,
    Value,
}

/// How a stage relates to a K/V cache tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheEdge {
    /// The stage's output appends one row per sequence (k/v projections).
    Append(CacheTensor),
    /// The stage's weight-side operand *is* the cache (attention matmuls:
    /// `q·Kᵀ` reads the K cache along its output axis, `p·V` reads the V
    /// cache along its contraction axis).
    Read(CacheTensor),
}

/// Raw decode dimensions — the coordinator builds these straight from
/// manifest dims, the CLI from a [`ModelSpec`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecodeDims {
    pub hidden: u64,
    pub ffn: u64,
    pub layers: u64,
    pub heads: u64,
    /// 0 = no LM head.
    pub vocab: u64,
}

impl DecodeDims {
    pub fn of(model: &ModelSpec) -> DecodeDims {
        DecodeDims {
            hidden: model.hidden,
            ffn: model.ffn,
            layers: model.layers,
            heads: model.heads,
            vocab: model.vocab.unwrap_or(0),
        }
    }

    pub fn head_dim(&self) -> u64 {
        self.hidden / self.heads
    }

    fn validate(&self) {
        assert!(self.layers > 0 && self.heads > 0 && self.hidden > 0);
        assert_eq!(
            self.hidden % self.heads,
            0,
            "hidden {} must divide into {} heads",
            self.hidden,
            self.heads
        );
    }
}

/// Stage inventory of ONE decode step: `batch` in-flight sequences whose
/// per-sequence K/V caches hold `cache_len` positions (including the
/// token being generated).  Linear projections are batched across
/// sequences (shared weights, `M = batch`); attention matmuls are
/// per-sequence-per-head (`M = 1`, distinct caches), which is exactly
/// where cache-resident per-tile TAS acts.
pub fn decode_step_stages(dims: &DecodeDims, batch: u64, cache_len: u64) -> Vec<StageSpec> {
    decode_step_stages_spec(dims, batch, cache_len, 1, dims.heads, dims.ffn, dims.vocab)
}

/// The general builder: `step_tokens` tokens per sequence are processed
/// this step (`1` for plain decode, `draft + 1` for a speculative
/// draft-and-verify step — the `M = batch × (draft + 1)` GEMM of the
/// ROADMAP item, expressed through the existing [`Phase`] model).
pub(crate) fn decode_step_stages_spec(
    dims: &DecodeDims,
    batch: u64,
    cache_len: u64,
    step_tokens: u64,
    heads_slice: u64,
    ffn_slice: u64,
    vocab_slice: u64,
) -> Vec<StageSpec> {
    dims.validate();
    assert!(batch > 0 && cache_len > 0 && heads_slice > 0 && ffn_slice > 0);
    assert!(step_tokens > 0 && step_tokens <= cache_len);
    let h = dims.hidden;
    let d = dims.head_dim();
    let hs = heads_slice * d;
    let l = dims.layers;
    let m = batch * step_tokens;
    let attn = l * heads_slice * batch;
    let stage = |name, shape, count, consumes, shares, cache| StageSpec {
        name,
        shape,
        count,
        consumes_previous: consumes,
        shares_input_with_previous: shares,
        cache,
    };
    let k_app = Some(CacheEdge::Append(CacheTensor::Key));
    let v_app = Some(CacheEdge::Append(CacheTensor::Value));
    let k_read = Some(CacheEdge::Read(CacheTensor::Key));
    let v_read = Some(CacheEdge::Read(CacheTensor::Value));
    let proj = GemmShape::new(m, h, hs);
    let mut v = vec![
        stage("k", proj, l, false, false, k_app),
        stage("v", proj, l, false, true, v_app),
        stage("q", proj, l, false, true, None),
        stage("qk_t", GemmShape::new(step_tokens, d, cache_len), attn, true, false, k_read),
        stage("attn_v", GemmShape::new(step_tokens, cache_len, d), attn, true, false, v_read),
        stage("attn_out", GemmShape::new(m, hs, h), l, true, false, None),
        stage("ffn1", GemmShape::new(m, h, ffn_slice), l, true, false, None),
        stage("ffn2", GemmShape::new(m, ffn_slice, h), l, true, false, None),
    ];
    if vocab_slice > 0 {
        let head = GemmShape::new(m, h, vocab_slice);
        v.push(stage("lm_head", head, 1, false, false, None));
    }
    v
}

/// Prefill stage chain with sliced weight columns — reduces to
/// [`ModelSpec::block_stages`] for full slices (asserted in tests).
pub(crate) fn prefill_stages_sliced(
    dims: &DecodeDims,
    tokens: u64,
    heads_slice: u64,
    ffn_slice: u64,
    vocab_slice: u64,
) -> Vec<StageSpec> {
    dims.validate();
    assert!(tokens > 0 && heads_slice > 0 && ffn_slice > 0);
    let h = dims.hidden;
    let hs = heads_slice * dims.head_dim();
    let l = dims.layers;
    let stage = |name, shape, count, consumes, shares| StageSpec {
        name,
        shape,
        count,
        consumes_previous: consumes,
        shares_input_with_previous: shares,
        cache: None,
    };
    let mut v = vec![
        stage("q", GemmShape::new(tokens, h, hs), l, false, false),
        stage("k", GemmShape::new(tokens, h, hs), l, false, true),
        stage("v", GemmShape::new(tokens, h, hs), l, false, true),
        stage("attn_out", GemmShape::new(tokens, hs, h), l, false, false),
        stage("ffn1", GemmShape::new(tokens, h, ffn_slice), l, true, false),
        stage("ffn2", GemmShape::new(tokens, ffn_slice, h), l, true, false),
    ];
    if vocab_slice > 0 {
        v.push(stage("lm_head", GemmShape::new(tokens, h, vocab_slice), 1, false, false));
    }
    v
}

/// Residency allocation feeding one decode step: per-layer resident cache
/// rows and per-stage, per-layer parked weight columns.  Produced by the
/// allocator (paged), a uniform split (all-or-nothing) or empty (off /
/// step 0).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StepResidency {
    /// Resident cache rows per layer (newest rows retained).
    pub cache_rows: Vec<u64>,
    /// Parked weight columns per stage name, per layer (`lm_head` has a
    /// single entry — it is not a per-layer stage).
    pub weight_cols: BTreeMap<&'static str, Vec<u64>>,
}

impl StepResidency {
    pub fn none() -> StepResidency {
        StepResidency::default()
    }

    /// The seed's uniform split: every layer retains the same `rows`.
    pub fn uniform(rows: u64, layers: u64) -> StepResidency {
        StepResidency {
            cache_rows: vec![rows; layers as usize],
            weight_cols: BTreeMap::new(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.cache_rows.iter().all(|&r| r == 0)
            && self.weight_cols.values().all(|v| v.iter().all(|&c| c == 0))
    }

    /// Largest per-layer resident row count.
    pub fn max_rows(&self) -> u64 {
        self.cache_rows.iter().copied().max().unwrap_or(0)
    }
}

/// One GEMM slice of a planned stage with the instances it covers.
#[derive(Clone, Debug)]
pub struct SlicePlan {
    /// Stage instances this slice plan runs for (layer groups with equal
    /// allocations share one plan).
    pub count: u64,
    pub plan: Plan,
}

/// One planned decode stage: residency decisions plus the slice plans.
#[derive(Clone, Debug)]
pub struct DecodeStagePlan {
    pub spec: StageSpec,
    /// Slice plans.  One per instance group normally; a hot/cold pair per
    /// group when a partially resident cache or weight splits the stage.
    pub slices: Vec<SlicePlan>,
    /// Input served from SRAM (chained activation) — no DRAM reads.
    pub input: Residency,
    /// Output handed on-chip to the next stage — no DRAM writes.
    pub output: Residency,
    /// Cache words served from SRAM across all instances this step.
    pub cache_hot_words: u64,
    /// Weight words parked in SRAM for this stage (summed over layers —
    /// the stage's share of the step's weight-residency claim).
    pub weight_hot_words: u64,
    /// DRAM words of this stage across all instances under this plan.
    pub ema_words: u64,
    /// DRAM words across all instances under per-GEMM TAS.
    pub per_gemm_tas_words: u64,
}

/// One planned decode step: every stage of the block at one cache length.
#[derive(Clone, Debug)]
pub struct DecodeStepPlan {
    pub phase: Phase,
    /// Positions attended this step (cache length including new tokens).
    pub cache_len: u64,
    /// Largest per-layer resident cache row count while this step runs.
    pub hot_rows: u64,
    /// Peak SRAM words the step's resident activations claim.
    pub act_resident_words: u64,
    pub stages: Vec<DecodeStagePlan>,
}

impl DecodeStepPlan {
    /// DRAM words of one decode step under this plan.
    pub fn total_ema(&self) -> u64 {
        self.stages.iter().map(|s| s.ema_words).sum()
    }

    /// DRAM words of the same step under per-GEMM TAS (the baseline the
    /// decode plan must never exceed).
    pub fn per_gemm_tas_total(&self) -> u64 {
        self.stages.iter().map(|s| s.per_gemm_tas_words).sum()
    }

    /// Cache words served from SRAM this step (all instances).
    pub fn cache_hot_total(&self) -> u64 {
        self.stages.iter().map(|s| s.cache_hot_words).sum()
    }

    /// Weight words parked in SRAM while this step runs.
    pub fn weight_hot_total(&self) -> u64 {
        self.stages.iter().map(|s| s.weight_hot_words).sum()
    }

    pub fn reduction_vs_per_gemm(&self) -> f64 {
        let base = self.per_gemm_tas_total();
        if base == 0 {
            0.0
        } else {
            1.0 - self.total_ema() as f64 / base as f64
        }
    }
}

/// Plan one decode step over an explicit stage list with a uniform
/// cache-row residency (`hot_rows` rows in every layer, strictly fewer
/// than `cache_len` — the new token's row is never pre-resident) and no
/// parked weights; `budget` bounds activation residency.  The paged
/// planners call the [`StepResidency`]-shaped core instead.
pub fn plan_decode_step(
    stages: &[StageSpec],
    layers: u64,
    cache_len: u64,
    hot_rows: u64,
    tiling: &Tiling,
    budget: u64,
    phase: Phase,
) -> DecodeStepPlan {
    assert!(hot_rows < cache_len, "the newest row is appended this step");
    let mut memo = PlanMemo::new();
    plan_decode_step_res(
        stages,
        layers,
        cache_len,
        1,
        &StepResidency::uniform(hot_rows, layers),
        tiling,
        budget,
        phase,
        &mut memo,
    )
}

/// The memoised core: `memo` carries cover searches across the steps of
/// one trajectory, so the shapes that do not depend on the cache length
/// are planned once instead of once per step.
#[allow(clippy::too_many_arguments)]
fn plan_decode_step_res(
    stages: &[StageSpec],
    layers: u64,
    cache_len: u64,
    step_tokens: u64,
    res: &StepResidency,
    tiling: &Tiling,
    budget: u64,
    phase: Phase,
    memo: &mut PlanMemo,
) -> DecodeStepPlan {
    assert!(step_tokens >= 1 && step_tokens <= cache_len);
    let fits = |w: u64| w > 0 && w <= budget;
    // Aggregate tensor sizes per layer: attention stages run
    // heads × batch instances whose activations coexist within a layer.
    let per_layer = |s: &StageSpec| (s.count / layers.max(1)).max(1);
    // Cache rows available for retention this step: the step's own new
    // rows were never streamed before, so they cannot be pre-resident.
    let retained_cap = cache_len - step_tokens;

    let mut planned: Vec<DecodeStagePlan> = Vec::with_capacity(stages.len());
    let mut act_peak = 0u64;
    for (idx, spec) in stages.iter().enumerate() {
        let group_in = per_layer(spec) * spec.shape.input_words();
        let group_out = per_layer(spec) * spec.shape.output_words();
        let input_resident = if spec.shares_input_with_previous && idx > 0 {
            fits(spec.shape.input_words())
        } else if spec.consumes_previous && idx > 0 {
            planned[idx - 1].output.is_free()
        } else {
            false
        };
        // The consumer may fan out (q -> per-head qk_t) or fan in
        // (per-head attn_v -> attn_out); either way the chained tensor is
        // the same per-layer aggregate, so counts must divide.
        let output_resident = stages
            .get(idx + 1)
            .map(|next| {
                next.consumes_previous
                    && (next.count % spec.count.max(1) == 0
                        || spec.count % next.count.max(1) == 0)
                    && fits(group_out + if input_resident { group_in } else { 0 })
            })
            .unwrap_or(false);
        let held = (if output_resident { group_out } else { 0 })
            + (if input_resident { group_in } else { 0 });
        act_peak = act_peak.max(held);
        let in_res = if input_resident { Residency::Full } else { Residency::None };
        let out_res = if output_resident { Residency::Full } else { Residency::None };

        // Layers collapse into groups with equal residency allocations;
        // a stage whose count is not a per-layer multiple (the LM head)
        // forms a single group.
        let l_s = if layers > 0 && spec.count % layers.max(1) == 0 && spec.count > 0 {
            layers
        } else {
            1
        };
        let inst_per_layer = spec.count / l_s;
        let is_cache_read = matches!(spec.cache, Some(CacheEdge::Read(_)));
        let layer_value = |l: usize| -> u64 {
            if is_cache_read {
                res.cache_rows.get(l).copied().unwrap_or(0).min(retained_cap)
            } else {
                res.weight_cols
                    .get(spec.name)
                    .and_then(|v| v.get(l.min(v.len().saturating_sub(1))))
                    .copied()
                    .unwrap_or(0)
                    .min(spec.shape.k)
            }
        };
        let mut groups: BTreeMap<u64, u64> = BTreeMap::new();
        for l in 0..l_s as usize {
            *groups.entry(layer_value(l)).or_insert(0) += 1;
        }

        let unsplit = memo_plan(memo, &spec.shape, tiling, in_res, Residency::None, out_res);
        let unsplit_cost = memo.cost(&unsplit);
        let mut slices: Vec<SlicePlan> = Vec::new();
        let mut cache_hot_words = 0u64;
        let mut weight_hot_words = 0u64;
        let mut ema_words = 0u64;
        for (&value, &n_layers) in &groups {
            let inst = n_layers * inst_per_layer;
            if value == 0 {
                ema_words += inst * unsplit_cost;
                slices.push(SlicePlan { count: inst, plan: unsplit.clone() });
                continue;
            }
            // Split the GEMM along the resident operand's axis: the K
            // cache runs along the output axis (split K), the V cache
            // along the contraction (split N, hot context accumulating on
            // chip), parked weight columns along K.
            let (hot_shape, cold_shape, hot_out_res) = match spec.cache {
                Some(CacheEdge::Read(CacheTensor::Key)) => {
                    debug_assert_eq!(spec.shape.k, cache_len);
                    let (h, c) = split_cols(&spec.shape, value);
                    (h, c, out_res)
                }
                Some(CacheEdge::Read(CacheTensor::Value)) => {
                    debug_assert_eq!(spec.shape.n, cache_len);
                    let (h, c) = split_contraction(&spec.shape, value);
                    (h, c, Residency::Full)
                }
                _ => {
                    let (h, c) = split_cols(&spec.shape, value);
                    (h, c, out_res)
                }
            };
            let hot = hot_shape.map(|hs| {
                memo_plan(memo, &hs, tiling, in_res, Residency::Full, hot_out_res)
            });
            let cold = cold_shape.map(|cs| {
                memo_plan(memo, &cs, tiling, in_res, Residency::None, out_res)
            });
            let split_cost = hot.as_ref().map(|p| memo.cost(p)).unwrap_or(0)
                + cold.as_ref().map(|p| memo.cost(p)).unwrap_or(0);
            // Keep the split only when it wins: never worse than the
            // unsplit per-tile plan, hence never worse than per-GEMM TAS.
            if split_cost < unsplit_cost {
                let hot_words = hot
                    .as_ref()
                    .map(|p| p.shape.weight_words())
                    .unwrap_or(0);
                if is_cache_read {
                    cache_hot_words += inst * hot_words;
                } else {
                    // Weights are shared across the layer's instances:
                    // the SRAM claim scales with layers, not instances.
                    weight_hot_words += n_layers * hot_words;
                }
                ema_words += inst * split_cost;
                if let Some(p) = hot {
                    slices.push(SlicePlan { count: inst, plan: p });
                }
                if let Some(p) = cold {
                    slices.push(SlicePlan { count: inst, plan: p });
                }
            } else {
                ema_words += inst * unsplit_cost;
                slices.push(SlicePlan { count: inst, plan: unsplit.clone() });
            }
        }
        let per_gemm_tas_words =
            spec.count * analytic::ema(Scheme::Tas, &spec.shape, tiling).total();
        planned.push(DecodeStagePlan {
            spec: spec.clone(),
            slices,
            input: in_res,
            output: out_res,
            cache_hot_words,
            weight_hot_words,
            ema_words,
            per_gemm_tas_words,
        });
    }
    DecodeStepPlan {
        phase,
        cache_len,
        hot_rows: res.max_rows().min(retained_cap),
        act_resident_words: act_peak,
        stages: planned,
    }
}

/// A planned decode trajectory: prefill at seq `S`, then `T` decode steps
/// at batch `B`, with a static residency allocation.
#[derive(Clone, Debug)]
pub struct DecodePlan {
    pub dims: DecodeDims,
    pub batch: u64,
    pub prefill_seq: u64,
    pub steps: u64,
    /// Speculative draft tokens verified per step (0 = plain decode);
    /// each step processes `batch × (draft + 1)` tokens and the cache
    /// grows by `draft + 1` rows per sequence.
    pub draft: u64,
    pub tiling: Tiling,
    /// Head/ffn/vocab slice this plan covers (full dims unless sharded).
    pub heads_slice: u64,
    pub ffn_slice: u64,
    pub vocab_slice: u64,
    /// Planning budget: SRAM minus the double-buffered operand margin.
    pub budget: u64,
    /// SRAM words one resident cache row occupies across **all** layers
    /// (one position, both tensors, every sequence of the batch) — the
    /// uniform split's page size.
    pub row_words: u64,
    /// SRAM words one cache row of ONE layer occupies — the paged
    /// allocator's page size.
    pub layer_row_words: u64,
    /// Resident cache rows per layer (newest-first; coldest are evicted —
    /// free, the cache is write-through).  Uniform under the
    /// all-or-nothing policy.
    pub cache_rows: Vec<u64>,
    /// Largest per-layer resident row count.
    pub resident_rows: u64,
    /// Weight words parked across decode steps (paged policy only).
    pub weight_hot_words: u64,
    /// Peak activation residency reserved ahead of the cache.
    pub act_peak_words: u64,
    /// Residency model that produced this plan (a paged request that lost
    /// to the uniform split reports `AllOrNothing`).
    pub policy: ResidencyPolicy,
    pub prefill: LayerPlan,
    pub step_plans: Vec<DecodeStepPlan>,
}

impl DecodePlan {
    /// Plan a trajectory for a zoo model with paged residency.
    pub fn plan(
        model: &ModelSpec,
        prefill_seq: u64,
        steps: u64,
        batch: u64,
        tiling: &Tiling,
        sram_words: u64,
    ) -> DecodePlan {
        DecodePlan::plan_with_policy(
            &DecodeDims::of(model),
            prefill_seq,
            steps,
            batch,
            tiling,
            sram_words,
            ResidencyPolicy::Paged,
        )
    }

    /// Plan a speculative decode trajectory: each step drafts and
    /// verifies `draft + 1` tokens per sequence (`M = batch × (draft+1)`,
    /// all drafts assumed accepted — the optimistic shape sweep of the
    /// ROADMAP item).
    pub fn plan_draft(
        model: &ModelSpec,
        prefill_seq: u64,
        steps: u64,
        batch: u64,
        draft: u64,
        tiling: &Tiling,
        sram_words: u64,
    ) -> DecodePlan {
        let dims = DecodeDims::of(model);
        DecodePlan::plan_sliced(
            &dims,
            dims.heads,
            dims.ffn,
            dims.vocab,
            prefill_seq,
            steps,
            batch,
            draft,
            tiling,
            sram_words,
            ResidencyPolicy::Paged,
            &PlanPricing::systolic(),
        )
    }

    /// [`DecodePlan::plan`] under a backend's pricing: every cover search
    /// and every split-vs-unsplit comparison in the trajectory values
    /// operands by what the backend streams, so a weight-pinning backend
    /// stops parking cache rows and weight slices (their re-reads are
    /// free) without any special case.  Systolic pricing reproduces
    /// [`DecodePlan::plan`] exactly.
    pub fn plan_priced(
        model: &ModelSpec,
        prefill_seq: u64,
        steps: u64,
        batch: u64,
        tiling: &Tiling,
        sram_words: u64,
        pricing: &PlanPricing,
    ) -> DecodePlan {
        let dims = DecodeDims::of(model);
        DecodePlan::plan_sliced(
            &dims,
            dims.heads,
            dims.ffn,
            dims.vocab,
            prefill_seq,
            steps,
            batch,
            0,
            tiling,
            sram_words,
            ResidencyPolicy::Paged,
            pricing,
        )
    }

    /// Plan with an explicit residency policy (`Off` disables cache and
    /// weight residency entirely — the conservation baseline the property
    /// tests pin against; `AllOrNothing` is the seed's uniform split).
    pub fn plan_with_policy(
        dims: &DecodeDims,
        prefill_seq: u64,
        steps: u64,
        batch: u64,
        tiling: &Tiling,
        sram_words: u64,
        policy: ResidencyPolicy,
    ) -> DecodePlan {
        DecodePlan::plan_sliced(
            dims,
            dims.heads,
            dims.ffn,
            dims.vocab,
            prefill_seq,
            steps,
            batch,
            0,
            tiling,
            sram_words,
            policy,
            &PlanPricing::systolic(),
        )
    }

    /// The paged allocation for one trajectory (or one steady-state step
    /// when `hot_steps == 1`): every layer's cache rows and weight slices
    /// compete for the post-activation budget by marginal EMA saved per
    /// word.  Cache candidates precede weight candidates, so at the equal
    /// steady-state rate the cache wins ties (its rows also serve the
    /// *next* trajectory's longer contexts).
    #[allow(clippy::too_many_arguments)]
    fn paged_allocation(
        stages: &[StageSpec],
        layers: u64,
        layer_row_words: u64,
        max_rows: u64,
        cache_budget: u64,
        tiling: &Tiling,
        hot_steps: u64,
    ) -> StepResidency {
        if cache_budget == 0 || hot_steps == 0 {
            return StepResidency::uniform(0, layers);
        }
        let mut candidates: Vec<Candidate> = Vec::new();
        // K/V cache rows, one candidate per layer.
        for l in 0..layers {
            let lrw = layer_row_words;
            candidates.push(Candidate {
                label: format!("cache:L{l}"),
                page_words: lrw,
                max_pages: max_rows,
                live: 0..1,
                saving: Box::new(move |p| p * lrw * hot_steps),
            });
        }
        // Weight slices of every linear stage, one candidate per layer
        // (tile-column pages): a parked weight word saves one DRAM word
        // per step it is hot, same rate as a cache word.
        let mut weight_stages: Vec<(usize, u64)> = Vec::new(); // (stage idx, layers)
        for (idx, spec) in stages.iter().enumerate() {
            if matches!(spec.cache, Some(CacheEdge::Read(_))) {
                continue; // the cache IS this stage's weight operand
            }
            let l_s = if spec.count % layers.max(1) == 0 { layers } else { 1 };
            weight_stages.push((idx, l_s));
            let n = spec.shape.n;
            let k = spec.shape.k;
            let tk = tiling.tk;
            for l in 0..l_s {
                candidates.push(Candidate {
                    label: format!("w:{}:L{l}", spec.name),
                    page_words: n * tk,
                    max_pages: ceil_div(k, tk),
                    live: 0..1,
                    saving: Box::new(move |p| (p * tk).min(k) * n * hot_steps),
                });
            }
        }
        let alloc = ResidencyAllocator::new(cache_budget, 1).allocate(&candidates);
        let mut res = StepResidency {
            cache_rows: alloc.pages[..layers as usize].to_vec(),
            weight_cols: BTreeMap::new(),
        };
        let mut cursor = layers as usize;
        for (idx, l_s) in weight_stages {
            let spec = &stages[idx];
            let cols: Vec<u64> = alloc.pages[cursor..cursor + l_s as usize]
                .iter()
                .map(|p| (p * tiling.tk).min(spec.shape.k))
                .collect();
            cursor += l_s as usize;
            if cols.iter().any(|&c| c > 0) {
                res.weight_cols.insert(spec.name, cols);
            }
        }
        res
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn plan_sliced(
        dims: &DecodeDims,
        heads_slice: u64,
        ffn_slice: u64,
        vocab_slice: u64,
        prefill_seq: u64,
        steps: u64,
        batch: u64,
        draft: u64,
        tiling: &Tiling,
        sram_words: u64,
        policy: ResidencyPolicy,
        pricing: &PlanPricing,
    ) -> DecodePlan {
        dims.validate();
        assert!(prefill_seq > 0 && steps > 0 && batch > 0);
        let step_tokens = draft + 1;
        let margin = 4 * (tiling.tm * tiling.tn + tiling.tn * tiling.tk);
        let budget = sram_words.saturating_sub(margin);
        let layers = dims.layers;

        // Pass 1: plan every step cold to size the activation claim.
        // Per-step activation claims are NOT monotone in cache length — a
        // per-layer group can stop fitting at the longest step — so the
        // peak is taken over the whole trajectory, not a single probe.
        // One memo carries the cover searches of the cache-length-
        // independent stages across both passes.
        let mut memo = PlanMemo::priced(*pricing);
        let step_stages = |cache_len: u64| {
            decode_step_stages_spec(
                dims,
                batch,
                cache_len,
                step_tokens,
                heads_slice,
                ffn_slice,
                vocab_slice,
            )
        };
        let cache_len_at = |t: u64| prefill_seq + (t + 1) * step_tokens;
        let none = StepResidency::none();
        let mut act_peak = 0u64;
        let mut cold_steps = Vec::with_capacity(steps as usize);
        for t in 0..steps {
            let cache_len = cache_len_at(t);
            let sp = plan_decode_step_res(
                &step_stages(cache_len),
                layers,
                cache_len,
                step_tokens,
                &none,
                tiling,
                budget,
                Phase::Decode { step: t, batch },
                &mut memo,
            );
            act_peak = act_peak.max(sp.act_resident_words);
            cold_steps.push(sp);
        }
        let layer_row_words = 2 * batch * heads_slice * dims.head_dim();
        let row_words = layers * layer_row_words;
        let cache_budget = budget.saturating_sub(act_peak);
        // Cap at the most rows any step can actually retain (the last
        // step inherits prefill + (T-1)·step_tokens rows), so the
        // residency claim reports SRAM the trajectory really occupies.
        let max_rows = prefill_seq + (steps - 1) * step_tokens;

        let prefill_tokens = batch * prefill_seq;
        let prefill = LayerPlan::plan_priced(
            prefill_stages_sliced(dims, prefill_tokens, heads_slice, ffn_slice, vocab_slice),
            prefill_tokens,
            tiling,
            sram_words,
            pricing,
        );

        // Pass 2 under one allocation: a step that retains nothing reuses
        // its cold plan (the residency walk never depends on the hot
        // allocation, so the passes agree on the activation flags).
        let replan = |alloc: &StepResidency,
                      cold: &[DecodeStepPlan],
                      memo: &mut PlanMemo|
         -> Vec<DecodeStepPlan> {
            let mut out = Vec::with_capacity(cold.len());
            for (t, cold_sp) in cold.iter().enumerate() {
                let t = t as u64;
                // Step 0 inherits nothing (prefill wrote through to
                // DRAM); later steps retain what streamed last step.
                if t == 0 || alloc.is_empty() {
                    out.push(cold_sp.clone());
                    continue;
                }
                let cache_len = cache_len_at(t);
                let avail = prefill_seq + t * step_tokens;
                let step_alloc = StepResidency {
                    cache_rows: alloc.cache_rows.iter().map(|r| (*r).min(avail)).collect(),
                    weight_cols: alloc.weight_cols.clone(),
                };
                out.push(plan_decode_step_res(
                    &step_stages(cache_len),
                    layers,
                    cache_len,
                    step_tokens,
                    &step_alloc,
                    tiling,
                    budget,
                    Phase::Decode { step: t, batch },
                    memo,
                ));
            }
            out
        };

        let uniform_rows = if row_words > 0 {
            (cache_budget / row_words).min(max_rows)
        } else {
            0
        };
        let (alloc, step_plans, policy_used) = match policy {
            ResidencyPolicy::Off => (StepResidency::none(), cold_steps, ResidencyPolicy::Off),
            ResidencyPolicy::AllOrNothing => {
                let alloc = StepResidency::uniform(uniform_rows, layers);
                let plans = replan(&alloc, &cold_steps, &mut memo);
                (alloc, plans, ResidencyPolicy::AllOrNothing)
            }
            ResidencyPolicy::Paged => {
                let stages0 = step_stages(cache_len_at(0));
                let paged_alloc = DecodePlan::paged_allocation(
                    &stages0,
                    layers,
                    layer_row_words,
                    max_rows,
                    cache_budget,
                    tiling,
                    steps.saturating_sub(1),
                );
                let paged_plans = replan(&paged_alloc, &cold_steps, &mut memo);
                let uniform_alloc = StepResidency::uniform(uniform_rows, layers);
                let uniform_plans = replan(&uniform_alloc, &cold_steps, &mut memo);
                let paged_total: u64 = paged_plans.iter().map(|s| s.total_ema()).sum();
                let uniform_total: u64 = uniform_plans.iter().map(|s| s.total_ema()).sum();
                // Paged must never lose to the uniform split.
                if paged_total <= uniform_total {
                    (paged_alloc, paged_plans, ResidencyPolicy::Paged)
                } else {
                    (uniform_alloc, uniform_plans, ResidencyPolicy::AllOrNothing)
                }
            }
        };

        let weight_hot_words = step_plans
            .iter()
            .map(|s| s.weight_hot_total())
            .max()
            .unwrap_or(0);
        DecodePlan {
            dims: *dims,
            batch,
            prefill_seq,
            steps,
            draft,
            tiling: *tiling,
            heads_slice,
            ffn_slice,
            vocab_slice,
            budget,
            row_words,
            layer_row_words,
            resident_rows: alloc.max_rows().min(max_rows),
            cache_rows: alloc.cache_rows.clone(),
            weight_hot_words,
            act_peak_words: act_peak,
            policy: policy_used,
            prefill,
            step_plans,
        }
    }

    /// One steady-state decode step at `cache_len` (the coordinator's
    /// decode-bucket unit): residency as a retained trajectory would have
    /// — cache rows and weight slices allocated by the same paged policy.
    pub fn plan_step(
        dims: &DecodeDims,
        batch: u64,
        cache_len: u64,
        tiling: &Tiling,
        sram_words: u64,
    ) -> DecodeStepPlan {
        dims.validate();
        assert!(batch > 0 && cache_len > 0);
        let margin = 4 * (tiling.tm * tiling.tn + tiling.tn * tiling.tk);
        let budget = sram_words.saturating_sub(margin);
        let stages = decode_step_stages(dims, batch, cache_len);
        // One memo serves both passes: the probe's cover searches for the
        // cache-length-independent stages are reused by the final plan.
        let mut memo = PlanMemo::new();
        let phase = Phase::Decode { step: 0, batch };
        let none = StepResidency::none();
        let probe = plan_decode_step_res(
            &stages,
            dims.layers,
            cache_len,
            1,
            &none,
            tiling,
            budget,
            phase,
            &mut memo,
        );
        if cache_len <= 1 {
            return probe;
        }
        // One resident cache position of ONE layer: K and V vectors of
        // the full hidden width, for every sequence of the batch.
        let layer_row_words = 2 * batch * dims.hidden;
        let cache_budget = budget.saturating_sub(probe.act_resident_words);
        let alloc = DecodePlan::paged_allocation(
            &stages,
            dims.layers,
            layer_row_words,
            cache_len - 1,
            cache_budget,
            tiling,
            1,
        );
        if alloc.is_empty() {
            return probe;
        }
        let paged = plan_decode_step_res(
            &stages,
            dims.layers,
            cache_len,
            1,
            &alloc,
            tiling,
            budget,
            phase,
            &mut memo,
        );
        // The steady state must also never lose to the uniform split.
        let row_words = 2 * dims.layers * batch * dims.hidden;
        let uniform_rows = if row_words > 0 {
            (cache_budget / row_words).min(cache_len - 1)
        } else {
            0
        };
        let uniform = if uniform_rows > 0 {
            plan_decode_step_res(
                &stages,
                dims.layers,
                cache_len,
                1,
                &StepResidency::uniform(uniform_rows, dims.layers),
                tiling,
                budget,
                phase,
                &mut memo,
            )
        } else {
            probe
        };
        if paged.total_ema() <= uniform.total_ema() {
            paged
        } else {
            uniform
        }
    }

    /// DRAM words of the decode phase (all `T` steps).
    pub fn decode_ema(&self) -> u64 {
        self.step_plans.iter().map(|s| s.total_ema()).sum()
    }

    /// Decode-phase DRAM words under per-GEMM TAS at the same shapes.
    pub fn per_gemm_tas_decode_total(&self) -> u64 {
        self.step_plans.iter().map(|s| s.per_gemm_tas_total()).sum()
    }

    /// Whole-trajectory DRAM words (prefill + decode).
    pub fn total_ema(&self) -> u64 {
        self.prefill.total_ema() + self.decode_ema()
    }

    /// Tokens generated (and, for speculative decode, verified) over the
    /// trajectory.
    pub fn generated_tokens(&self) -> u64 {
        self.steps * self.batch * (self.draft + 1)
    }

    /// Decode DRAM words per generated token.
    pub fn per_token_ema(&self) -> f64 {
        self.decode_ema() as f64 / self.generated_tokens() as f64
    }

    /// Per-token baseline under per-GEMM TAS.
    pub fn per_token_per_gemm_tas(&self) -> f64 {
        self.per_gemm_tas_decode_total() as f64 / self.generated_tokens() as f64
    }

    /// Fractional decode saving over per-GEMM TAS.
    pub fn reduction_vs_per_gemm(&self) -> f64 {
        let base = self.per_gemm_tas_decode_total();
        if base == 0 {
            0.0
        } else {
            1.0 - self.decode_ema() as f64 / base as f64
        }
    }

    /// Upper bound on cache words resident at any point of the trajectory
    /// (summed over the per-layer allocations).
    pub fn max_cache_resident_words(&self) -> u64 {
        self.cache_rows.iter().map(|r| r * self.layer_row_words).sum()
    }

    /// Peak SRAM the plan ever claims (activations + resident cache +
    /// parked weights) — never exceeds [`DecodePlan::budget`] by
    /// construction (property-tested in `rust/tests/decode_invariants.rs`
    /// and `rust/tests/residency_invariants.rs`).
    pub fn peak_sram_claim(&self) -> u64 {
        self.act_peak_words + self.max_cache_resident_words() + self.weight_hot_words
    }
}

/// Decode across devices with the cache sharded by heads: device `d` owns
/// head range `head_ranges[d]` (its K/V blocks live in — and fill — its
/// own SRAM), QKV/FFN weight columns are split to match, and each layer's
/// attention/FFN partial sums are all-reduced across the links.
#[derive(Clone, Debug)]
pub struct ShardedDecodePlan {
    pub dims: DecodeDims,
    pub batch: u64,
    pub steps: u64,
    pub devices: u64,
    /// `(head_lo, head_hi)` per device.
    pub head_ranges: Vec<(u64, u64)>,
    pub per_device: Vec<DecodePlan>,
    /// Partial-sum words crossing links per decode step (tree reduces of
    /// the attention-output and FFN contractions, every layer).
    pub reduce_words_per_step: u64,
    /// Broadcast/all-gather words per decode step (reduced activations
    /// back to every device, plus the LM-head logit gather).
    pub gather_words_per_step: u64,
}

impl ShardedDecodePlan {
    pub fn plan(
        dims: &DecodeDims,
        prefill_seq: u64,
        steps: u64,
        batch: u64,
        tiling: &Tiling,
        sram_words_per_device: u64,
        devices: u64,
    ) -> anyhow::Result<ShardedDecodePlan> {
        dims.validate();
        let devices = devices.max(1);
        anyhow::ensure!(
            devices <= dims.heads,
            "cannot shard {} heads across {devices} devices",
            dims.heads
        );
        let head_ranges = shard_heads(dims.heads, devices);
        let ffn_bounds = even_bounds(dims.ffn, devices);
        let vocab_bounds = even_bounds(dims.vocab, devices);
        let mut per_device = Vec::with_capacity(devices as usize);
        for dev in 0..devices as usize {
            let heads_slice = head_ranges[dev].1 - head_ranges[dev].0;
            let ffn_slice = ffn_bounds[dev + 1] - ffn_bounds[dev];
            let vocab_slice = vocab_bounds[dev + 1] - vocab_bounds[dev];
            per_device.push(DecodePlan::plan_sliced(
                dims,
                heads_slice,
                ffn_slice,
                vocab_slice,
                prefill_seq,
                steps,
                batch,
                0,
                tiling,
                sram_words_per_device,
                ResidencyPolicy::Paged,
                &PlanPricing::systolic(),
            ));
        }
        let bh = batch * dims.hidden;
        let (reduce, mut gather) = if devices > 1 {
            // Two all-reduces per layer (attention output + FFN down),
            // modelled as tree-reduce + tree-broadcast of B×H partials.
            let per_layer = 2 * (devices - 1) * bh;
            (dims.layers * per_layer, dims.layers * per_layer)
        } else {
            (0, 0)
        };
        if dims.vocab > 0 && devices > 1 {
            gather += (devices - 1) * batch * dims.vocab;
        }
        Ok(ShardedDecodePlan {
            dims: *dims,
            batch,
            steps,
            devices,
            head_ranges,
            per_device,
            reduce_words_per_step: reduce,
            gather_words_per_step: gather,
        })
    }

    /// Summed decode DRAM words across devices.
    pub fn decode_ema(&self) -> u64 {
        self.per_device.iter().map(|p| p.decode_ema()).sum()
    }

    /// Busiest device's decode DRAM words — the critical path.
    pub fn max_device_decode_ema(&self) -> u64 {
        self.per_device
            .iter()
            .map(|p| p.decode_ema())
            .max()
            .unwrap_or(0)
    }

    pub fn per_gemm_tas_decode_total(&self) -> u64 {
        self.per_device
            .iter()
            .map(|p| p.per_gemm_tas_decode_total())
            .sum()
    }

    /// Inter-chip words over the whole trajectory.
    pub fn link_words_total(&self) -> u64 {
        self.steps * (self.reduce_words_per_step + self.gather_words_per_step)
    }

    /// Cache words resident across ALL devices — head sharding scales the
    /// aggregate residency with the device count.
    pub fn total_resident_cache_words(&self) -> u64 {
        self.per_device
            .iter()
            .map(|p| p.max_cache_resident_words())
            .sum()
    }

    /// Serialized link time of one decode step under `icx`: per layer two
    /// all-reduces (tree reduce + broadcast), plus the logit all-gather.
    pub fn link_cycles_per_step(&self, icx: &Interconnect) -> u64 {
        if self.devices <= 1 {
            return 0;
        }
        let bh = self.batch * self.dims.hidden;
        let allreduce = 2 * icx.tree_reduce_cycles(bh, self.devices);
        let mut cycles = 2 * self.dims.layers * allreduce;
        if self.dims.vocab > 0 {
            cycles += icx.all_gather_cycles(
                ceil_div(self.batch * self.dims.vocab, self.devices),
                self.devices,
            );
        }
        cycles
    }

    /// The same link time as a per-round list (two all-reduces per layer,
    /// each a tree reduce plus a tree broadcast of the B×H partials, then
    /// the logit all-gather) — what the trajectory replay drains behind
    /// each step's compute window ([`crate::sim::decode`]).  Sums to
    /// [`ShardedDecodePlan::link_cycles_per_step`] exactly.
    pub fn link_rounds_per_step(&self, icx: &Interconnect) -> Vec<u64> {
        let mut rounds = Vec::new();
        if self.devices <= 1 {
            return rounds;
        }
        let bh = self.batch * self.dims.hidden;
        for _layer in 0..self.dims.layers {
            // attention-output + FFN-down all-reduces: reduce, broadcast
            for _op in 0..4 {
                rounds.extend(icx.tree_reduce_rounds(bh, self.devices));
            }
        }
        if self.dims.vocab > 0 {
            rounds.extend(icx.all_gather_rounds(
                ceil_div(self.batch * self.dims.vocab, self.devices),
                self.devices,
            ));
        }
        rounds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    fn dims() -> DecodeDims {
        DecodeDims::of(&zoo::bert_base())
    }

    #[test]
    fn decode_stages_cover_the_block_and_scale_with_heads() {
        let d = dims();
        let stages = decode_step_stages(&d, 8, 96);
        let qk = stages.iter().find(|s| s.name == "qk_t").unwrap();
        assert_eq!(qk.count, 12 * 12 * 8);
        assert_eq!(qk.shape, GemmShape::new(1, 64, 96));
        assert_eq!(qk.cache, Some(CacheEdge::Read(CacheTensor::Key)));
        let av = stages.iter().find(|s| s.name == "attn_v").unwrap();
        assert_eq!(av.shape, GemmShape::new(1, 96, 64));
        let k = stages.iter().find(|s| s.name == "k").unwrap();
        assert_eq!(k.cache, Some(CacheEdge::Append(CacheTensor::Key)));
        // linear stages batch across sequences
        let ffn1 = stages.iter().find(|s| s.name == "ffn1").unwrap();
        assert_eq!(ffn1.shape, GemmShape::new(8, 768, 3072));
    }

    #[test]
    fn draft_steps_widen_every_stage() {
        let d = dims();
        let stages = decode_step_stages_spec(&d, 4, 96, 3, d.heads, d.ffn, d.vocab);
        let ffn1 = stages.iter().find(|s| s.name == "ffn1").unwrap();
        assert_eq!(ffn1.shape.m, 12, "M = batch × step_tokens");
        let qk = stages.iter().find(|s| s.name == "qk_t").unwrap();
        assert_eq!(qk.shape, GemmShape::new(3, 64, 96));
    }

    #[test]
    fn prefill_stages_reduce_to_block_stages() {
        for m in zoo::all_models() {
            let d = DecodeDims::of(&m);
            let mine = prefill_stages_sliced(&d, 384, d.heads, d.ffn, d.vocab);
            assert_eq!(mine, m.block_stages(384), "{}", m.name);
        }
    }

    #[test]
    fn step_plan_never_worse_than_per_gemm_tas() {
        let d = dims();
        let t = Tiling::square(16);
        let phase = Phase::Decode { step: 1, batch: 8 };
        for hot in [0u64, 1, 13, 64] {
            let stages = decode_step_stages(&d, 8, 96);
            let p = plan_decode_step(&stages, d.layers, 96, hot, &t, 256 * 1024, phase);
            for s in &p.stages {
                assert!(
                    s.ema_words <= s.per_gemm_tas_words,
                    "{} hot={hot}: {} > {}",
                    s.spec.name,
                    s.ema_words,
                    s.per_gemm_tas_words
                );
            }
            assert!(p.total_ema() <= p.per_gemm_tas_total());
        }
    }

    #[test]
    fn hot_rows_price_the_cache_at_zero_and_win() {
        let d = dims();
        let t = Tiling::square(16);
        let stages = decode_step_stages(&d, 1, 96);
        let phase = Phase::Decode { step: 1, batch: 1 };
        let cold = plan_decode_step(&stages, d.layers, 96, 0, &t, 256 * 1024, phase);
        let hot = plan_decode_step(&stages, d.layers, 96, 64, &t, 256 * 1024, phase);
        assert!(hot.total_ema() < cold.total_ema());
        assert!(hot.cache_hot_total() > 0);
        assert_eq!(cold.cache_hot_total(), 0);
        // the attention stages actually split
        let qk = hot.stages.iter().find(|s| s.spec.name == "qk_t").unwrap();
        assert_eq!(qk.slices.len(), 2);
        assert!(qk.slices[0].plan.weight_residency.is_free());
        assert!(!qk.slices[1].plan.weight_residency.is_free());
        // slice instances cover the stage exactly
        let inst: u64 = qk.slices.iter().map(|s| s.count).sum();
        assert_eq!(inst, 2 * qk.spec.count, "hot+cold pair per instance");
    }

    #[test]
    fn parked_weights_split_projections_and_win() {
        let d = dims();
        let t = Tiling::square(16);
        let stages = decode_step_stages(&d, 1, 96);
        let phase = Phase::Decode { step: 1, batch: 1 };
        let mut memo = PlanMemo::new();
        let mut res = StepResidency::none();
        res.cache_rows = vec![0; d.layers as usize];
        res.weight_cols
            .insert("ffn1", vec![256; d.layers as usize]);
        let with = plan_decode_step_res(
            &stages, d.layers, 96, 1, &res, &t, 256 * 1024, phase, &mut memo,
        );
        let without = plan_decode_step_res(
            &stages,
            d.layers,
            96,
            1,
            &StepResidency::none(),
            &t,
            256 * 1024,
            phase,
            &mut memo,
        );
        assert!(with.total_ema() < without.total_ema());
        let ffn1 = with.stages.iter().find(|s| s.spec.name == "ffn1").unwrap();
        assert!(ffn1.weight_hot_words > 0);
        assert_eq!(ffn1.weight_hot_words, d.layers * 256 * 768);
        assert_eq!(ffn1.slices.len(), 2, "hot/cold column split");
    }

    #[test]
    fn trajectory_retains_rows_from_step_one() {
        let p = DecodePlan::plan(&zoo::bert_base(), 64, 8, 1, &Tiling::square(16), 256 * 1024);
        assert_eq!(p.step_plans[0].hot_rows, 0, "nothing retained from prefill");
        if p.resident_rows > 0 {
            assert!(p.step_plans[1].hot_rows > 0);
        }
        for (t, sp) in p.step_plans.iter().enumerate() {
            assert_eq!(sp.cache_len, 64 + t as u64 + 1);
            assert!(sp.hot_rows < sp.cache_len);
            assert!(sp.hot_rows <= p.resident_rows);
        }
        // the budget is respected
        assert!(p.peak_sram_claim() <= p.budget);
    }

    #[test]
    fn resident_rows_never_exceed_what_the_trajectory_holds() {
        // Plenty of SRAM, short trajectory: the claim must report rows
        // the cache can actually contain, not raw budget capacity.
        let p = DecodePlan::plan(
            &zoo::bert_base(),
            64,
            4,
            1,
            &Tiling::square(16),
            4 * 1024 * 1024,
        );
        assert_eq!(p.resident_rows, 64 + 4 - 1);
        assert!(p.cache_rows.iter().all(|&r| r <= 64 + 4 - 1));
        assert!(p.peak_sram_claim() <= p.budget);
    }

    #[test]
    fn residency_disabled_prices_every_row_cold() {
        let d = dims();
        let t = Tiling::square(16);
        let on = DecodePlan::plan_with_policy(
            &d,
            64,
            4,
            1,
            &t,
            256 * 1024,
            ResidencyPolicy::Paged,
        );
        let off =
            DecodePlan::plan_with_policy(&d, 64, 4, 1, &t, 256 * 1024, ResidencyPolicy::Off);
        assert_eq!(off.resident_rows, 0);
        assert_eq!(off.weight_hot_words, 0);
        assert!(off.step_plans.iter().all(|s| s.hot_rows == 0));
        assert!(on.decode_ema() <= off.decode_ema());
        // identical per-GEMM baseline either way
        assert_eq!(on.per_gemm_tas_decode_total(), off.per_gemm_tas_decode_total());
    }

    #[test]
    fn paged_never_loses_to_the_uniform_split() {
        let d = dims();
        let t = Tiling::square(16);
        for batch in [1u64, 8] {
            let paged = DecodePlan::plan_with_policy(
                &d,
                64,
                6,
                batch,
                &t,
                256 * 1024,
                ResidencyPolicy::Paged,
            );
            let uniform = DecodePlan::plan_with_policy(
                &d,
                64,
                6,
                batch,
                &t,
                256 * 1024,
                ResidencyPolicy::AllOrNothing,
            );
            assert!(
                paged.decode_ema() <= uniform.decode_ema(),
                "batch {batch}: paged {} > uniform {}",
                paged.decode_ema(),
                uniform.decode_ema()
            );
            assert!(paged.peak_sram_claim() <= paged.budget);
        }
    }

    #[test]
    fn steady_state_step_plan_uses_retained_rows() {
        let d = dims();
        let sp = DecodePlan::plan_step(&d, 1, 96, &Tiling::square(16), 256 * 1024);
        assert!(sp.hot_rows > 0 || sp.weight_hot_total() > 0);
        assert!(sp.total_ema() <= sp.per_gemm_tas_total());
    }

    #[test]
    fn draft_trajectories_grow_the_cache_by_draft_plus_one() {
        let p = DecodePlan::plan_draft(
            &zoo::bert_base(),
            32,
            4,
            2,
            3,
            &Tiling::square(16),
            256 * 1024,
        );
        assert_eq!(p.draft, 3);
        for (t, sp) in p.step_plans.iter().enumerate() {
            assert_eq!(sp.cache_len, 32 + (t as u64 + 1) * 4);
        }
        assert_eq!(p.generated_tokens(), 4 * 2 * 4);
        assert!(p.decode_ema() <= p.per_gemm_tas_decode_total());
        assert!(p.peak_sram_claim() <= p.budget);
    }

    #[test]
    fn head_sharding_splits_work_and_scales_cache_residency() {
        let d = dims();
        let t = Tiling::square(16);
        let single = DecodePlan::plan_with_policy(
            &d,
            64,
            4,
            8,
            &t,
            256 * 1024,
            ResidencyPolicy::Paged,
        );
        let sharded =
            ShardedDecodePlan::plan(&d, 64, 4, 8, &t, 256 * 1024, 4).unwrap();
        assert_eq!(sharded.per_device.len(), 4);
        // every device owns a non-empty contiguous head range
        let total_heads: u64 =
            sharded.head_ranges.iter().map(|(lo, hi)| hi - lo).sum();
        assert_eq!(total_heads, d.heads);
        // MACs partition exactly across devices
        let macs = |p: &DecodePlan| -> u64 {
            p.step_plans
                .iter()
                .flat_map(|s| s.stages.iter())
                .map(|s| s.spec.count * s.spec.shape.macs())
                .sum()
        };
        let total: u64 = sharded.per_device.iter().map(macs).sum();
        assert_eq!(total, macs(&single));
        // the links carry the per-layer all-reduces
        assert!(sharded.reduce_words_per_step > 0);
        assert!(sharded.link_words_total() > 0);
        assert!(sharded.link_cycles_per_step(&Interconnect::default()) > 0);
    }

    #[test]
    fn link_rounds_sum_to_the_per_step_cycles() {
        let d = dims();
        let t = Tiling::square(16);
        let icx = Interconnect::default();
        for devices in [1u64, 2, 4, 8] {
            let sp = ShardedDecodePlan::plan(&d, 64, 3, 4, &t, 256 * 1024, devices).unwrap();
            let rounds = sp.link_rounds_per_step(&icx);
            assert_eq!(
                rounds.iter().sum::<u64>(),
                sp.link_cycles_per_step(&icx),
                "devices={devices}"
            );
            if devices == 1 {
                assert!(rounds.is_empty());
            }
        }
    }

    #[test]
    fn sharding_rejects_more_devices_than_heads() {
        let d = dims();
        assert!(ShardedDecodePlan::plan(&d, 64, 2, 1, &Tiling::square(16), 256 * 1024, 64)
            .is_err());
    }

    #[test]
    fn one_device_shard_matches_the_unsharded_plan() {
        let d = dims();
        let t = Tiling::square(16);
        let single = DecodePlan::plan_with_policy(
            &d,
            64,
            4,
            2,
            &t,
            256 * 1024,
            ResidencyPolicy::Paged,
        );
        let sharded = ShardedDecodePlan::plan(&d, 64, 4, 2, &t, 256 * 1024, 1).unwrap();
        assert_eq!(sharded.decode_ema(), single.decode_ema());
        assert_eq!(sharded.link_words_total(), 0);
        assert_eq!(sharded.link_cycles_per_step(&Interconnect::default()), 0);
    }
}
