//! Fractional SRAM residency: the [`Residency`] type, hot/cold GEMM
//! slicing, and the greedy page allocator shared by layer-level planning
//! ([`super::layer`]), decode planning ([`super::decode`]) and the
//! coordinator's lane splitting ([`crate::coordinator::decisions`]).
//!
//! The seed planners treated SRAM residency as an all-or-nothing boolean
//! per tensor: an intermediate either fit the budget whole or moved every
//! word through DRAM, and the decode cache was split uniformly across
//! layers.  This module makes SRAM a *budgeted, fractionally divisible*
//! resource, the way FlexGen-style offloading policies and FLAT's on-chip
//! fusion budgets treat it:
//!
//! * [`Residency`] describes how much of a tensor is SRAM-resident —
//!   nothing, everything, or a leading *row range* along the tensor's
//!   residency axis.  It replaces the `weight_resident: bool` flags the
//!   [`super::plan::Plan`] IR used to carry.
//! * A partially resident operand is priced by **hot/cold slicing**
//!   ([`split_rows`] / [`split_cols`] / [`split_contraction`]): the GEMM
//!   splits along the axis the resident rows run along, the hot slice
//!   plans with the operand [`Residency::Full`] (the per-tile TAS chooser
//!   then flips its cover toward re-reading the free stream), the cold
//!   slice streams from DRAM.  This generalises the decode planner's
//!   attention split to *every* GEMM; a split is only kept when it wins,
//!   so fractional plans never lose to the all-or-nothing planner.
//! * [`ResidencyAllocator`] takes the SRAM budget plus every candidate
//!   tensor and allocates pages greedily by **marginal EMA saved per
//!   word**.  Savings curves are supplied by the planners (exact slice
//!   pricing for layer intermediates, closed-form rates for cache rows
//!   and decode weights); candidates carry a *live interval* over the
//!   plan's timeline so tensors that coexist share the budget and
//!   tensors that don't can reuse it.

use crate::gemm::GemmShape;
use std::ops::Range;

/// SRAM residency of one tensor (or one operand stream of a plan).
///
/// At the [`super::plan::Plan`] level only [`Residency::None`] and
/// [`Residency::Full`] appear: the planners resolve a partial
/// [`Residency::Rows`] into hot/cold slice plans before constructing the
/// step streams, so every cost backend keeps a single charging rule
/// (free stream or charged stream, per slice).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Residency {
    /// Streamed from DRAM: every operand word is charged.
    #[default]
    None,
    /// The whole tensor is SRAM-resident: the stream charges nothing.
    Full,
    /// The leading `hot` of `of` rows along the tensor's residency axis
    /// are SRAM-resident (a planner-level fraction, resolved by slicing).
    Rows { hot: u64, of: u64 },
}

impl Residency {
    /// Normalising constructor: 0 hot rows is [`Residency::None`], all
    /// rows is [`Residency::Full`].
    pub fn rows(hot: u64, of: u64) -> Residency {
        if hot == 0 || of == 0 {
            Residency::None
        } else if hot >= of {
            Residency::Full
        } else {
            Residency::Rows { hot, of }
        }
    }

    /// The stream charges no DRAM words (plan-level semantics).
    pub fn is_free(&self) -> bool {
        matches!(self, Residency::Full)
    }

    pub fn is_none(&self) -> bool {
        matches!(self, Residency::None)
    }

    pub fn is_partial(&self) -> bool {
        matches!(self, Residency::Rows { .. })
    }

    /// Hot rows given the tensor's total row count.
    pub fn hot_in(&self, total: u64) -> u64 {
        match self {
            Residency::None => 0,
            Residency::Full => total,
            Residency::Rows { hot, .. } => (*hot).min(total),
        }
    }

    /// Human-readable summary: `-`, `full`, or `hot/total`.
    pub fn describe(&self) -> String {
        match self {
            Residency::None => "-".to_string(),
            Residency::Full => "full".to_string(),
            Residency::Rows { hot, of } => format!("{hot}/{of}"),
        }
    }
}

/// Which residency model a planner runs under.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResidencyPolicy {
    /// No SRAM residency at all: every tensor streams through DRAM.
    Off,
    /// The seed behaviour: whole tensors only (layer chains), uniform
    /// per-layer decode cache split.
    AllOrNothing,
    /// Fractional paged allocation via [`ResidencyAllocator`].  Never
    /// loses to [`ResidencyPolicy::AllOrNothing`]: the planners price
    /// both and keep the better plan.
    Paged,
}

impl ResidencyPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            ResidencyPolicy::Off => "off",
            ResidencyPolicy::AllOrNothing => "all-or-nothing",
            ResidencyPolicy::Paged => "paged",
        }
    }
}

/// Split `shape` along M at `hot` rows: `(hot_shape, cold_shape)`.
/// `hot` is clamped to `[0, m]`; a degenerate side returns `None`.
pub fn split_rows(shape: &GemmShape, hot: u64) -> (Option<GemmShape>, Option<GemmShape>) {
    let hot = hot.min(shape.m);
    let hot_s = (hot > 0).then(|| GemmShape::new(hot, shape.n, shape.k));
    let cold_s = (hot < shape.m).then(|| GemmShape::new(shape.m - hot, shape.n, shape.k));
    (hot_s, cold_s)
}

/// Split `shape` along K (weight columns / output features) at `hot`.
pub fn split_cols(shape: &GemmShape, hot: u64) -> (Option<GemmShape>, Option<GemmShape>) {
    let hot = hot.min(shape.k);
    let hot_s = (hot > 0).then(|| GemmShape::new(shape.m, shape.n, hot));
    let cold_s = (hot < shape.k).then(|| GemmShape::new(shape.m, shape.n, shape.k - hot));
    (hot_s, cold_s)
}

/// Split `shape` along N (the contraction) at `hot`.
pub fn split_contraction(shape: &GemmShape, hot: u64) -> (Option<GemmShape>, Option<GemmShape>) {
    let hot = hot.min(shape.n);
    let hot_s = (hot > 0).then(|| GemmShape::new(shape.m, hot, shape.k));
    let cold_s = (hot < shape.n).then(|| GemmShape::new(shape.m, shape.n - hot, shape.k));
    (hot_s, cold_s)
}

/// One tensor competing for SRAM pages.
pub struct Candidate<'a> {
    /// Debug/report label (e.g. `"shared:k+v"`, `"cache:L3"`).
    pub label: String,
    /// SRAM words one page occupies while the tensor is live.
    pub page_words: u64,
    /// Most pages this tensor can use.
    pub max_pages: u64,
    /// Timeline slots the resident pages occupy (stages for layer plans,
    /// a single steady-state slot for decode).  Tensors whose live
    /// intervals are disjoint reuse the same SRAM words.
    pub live: Range<usize>,
    /// Total EMA words saved when `p` pages of this tensor are resident.
    /// Supplied by the planner; need not be linear (the allocator probes
    /// geometric jumps, so flat-then-steep curves — an input flipping the
    /// stationary cover once a slice goes free — are still found).
    pub saving: Box<dyn Fn(u64) -> u64 + 'a>,
}

/// Result of one allocation: pages per candidate plus the peak SRAM
/// claim over the timeline.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Allocation {
    /// Pages granted per candidate (same order as the candidate list).
    pub pages: Vec<u64>,
    /// Largest per-slot word claim — never exceeds the budget.
    pub peak_words: u64,
}

/// Greedy fractional SRAM allocator: highest marginal-EMA-saved-per-word
/// first, in bulk jumps.
pub struct ResidencyAllocator {
    budget: u64,
    slots: usize,
}

impl ResidencyAllocator {
    /// `budget` words are available in each of `slots` timeline slots.
    pub fn new(budget: u64, slots: usize) -> ResidencyAllocator {
        ResidencyAllocator { budget, slots: slots.max(1) }
    }

    /// Allocate pages to `candidates` greedily.  Each round the allocator
    /// probes every candidate at geometrically spaced jumps (1, 2, 4, …
    /// pages up to its headroom) and takes the jump with the best
    /// saved-words-per-SRAM-word rate; it stops when no jump saves
    /// anything.  Deterministic: ties keep the earliest candidate and the
    /// largest jump at that rate.
    pub fn allocate(&self, candidates: &[Candidate]) -> Allocation {
        let mut pages = vec![0u64; candidates.len()];
        let mut used = vec![0u64; self.slots];
        loop {
            // (rate, gain, candidate, jump)
            let mut best: Option<(f64, u64, usize, u64)> = None;
            for (i, c) in candidates.iter().enumerate() {
                if c.page_words == 0 || c.live.start >= self.slots {
                    continue;
                }
                let live = c.live.start..c.live.end.min(self.slots);
                let headroom = live
                    .clone()
                    .map(|s| self.budget.saturating_sub(used[s]))
                    .min()
                    .unwrap_or(0)
                    / c.page_words;
                let max_jump = headroom.min(c.max_pages.saturating_sub(pages[i]));
                if max_jump == 0 {
                    continue;
                }
                let base = (c.saving)(pages[i]);
                let mut jump = 1u64;
                loop {
                    let j = jump.min(max_jump);
                    let gain = (c.saving)(pages[i] + j).saturating_sub(base);
                    if gain > 0 {
                        let rate = gain as f64 / (j * c.page_words) as f64;
                        let better = match best {
                            None => true,
                            // strictly better rate wins; at equal rate the
                            // earliest candidate keeps its claim and a
                            // larger jump is preferred within it
                            Some((r, g, bi, _)) => {
                                rate > r || (bi == i && rate >= r && gain > g)
                            }
                        };
                        if better {
                            best = Some((rate, gain, i, j));
                        }
                    }
                    if j == max_jump {
                        break;
                    }
                    jump *= 2;
                }
            }
            let Some((_, _, i, jump)) = best else { break };
            pages[i] += jump;
            let c = &candidates[i];
            for s in c.live.start..c.live.end.min(self.slots) {
                used[s] += jump * c.page_words;
            }
        }
        Allocation {
            pages,
            peak_words: used.iter().copied().max().unwrap_or(0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::property;
    use crate::util::prng::Rng;

    #[test]
    fn residency_normalises() {
        assert_eq!(Residency::rows(0, 10), Residency::None);
        assert_eq!(Residency::rows(10, 10), Residency::Full);
        assert_eq!(Residency::rows(12, 10), Residency::Full);
        assert_eq!(Residency::rows(3, 10), Residency::Rows { hot: 3, of: 10 });
        assert!(Residency::Full.is_free());
        assert!(!Residency::rows(3, 10).is_free());
        assert_eq!(Residency::rows(3, 10).hot_in(10), 3);
        assert_eq!(Residency::Full.hot_in(7), 7);
        assert_eq!(Residency::None.hot_in(7), 0);
        assert_eq!(Residency::rows(3, 10).describe(), "3/10");
    }

    #[test]
    fn splits_partition_the_shape() {
        let s = GemmShape::new(100, 64, 80);
        let (h, c) = split_rows(&s, 48);
        assert_eq!(h.unwrap().m + c.unwrap().m, 100);
        let (h, c) = split_cols(&s, 16);
        assert_eq!(h.unwrap().k + c.unwrap().k, 80);
        let (h, c) = split_contraction(&s, 64);
        assert_eq!(h.unwrap(), GemmShape::new(100, 64, 80));
        assert!(c.is_none());
        let (h, c) = split_rows(&s, 0);
        assert!(h.is_none());
        assert_eq!(c.unwrap(), s);
    }

    fn linear(rate: u64) -> Box<dyn Fn(u64) -> u64> {
        Box::new(move |p| p * rate)
    }

    #[test]
    fn allocator_respects_the_budget_per_slot() {
        property("allocator budget", 60, |rng: &mut Rng| {
            let budget = rng.gen_in(1, 10_000);
            let slots = rng.gen_in(1, 5) as usize;
            let n = rng.gen_in(1, 6) as usize;
            let cands: Vec<Candidate> = (0..n)
                .map(|i| {
                    let lo = rng.gen_range(slots as u64) as usize;
                    let hi = lo + 1 + rng.gen_range((slots - lo) as u64) as usize;
                    Candidate {
                        label: format!("c{i}"),
                        page_words: rng.gen_in(1, 200),
                        max_pages: rng.gen_in(1, 50),
                        live: lo..hi,
                        saving: linear(rng.gen_in(1, 300)),
                    }
                })
                .collect();
            let alloc = ResidencyAllocator::new(budget, slots).allocate(&cands);
            assert!(alloc.peak_words <= budget);
            // recompute per-slot usage independently
            let mut used = vec![0u64; slots];
            for (c, p) in cands.iter().zip(&alloc.pages) {
                assert!(*p <= c.max_pages);
                for s in c.live.start..c.live.end.min(slots) {
                    used[s] += p * c.page_words;
                }
            }
            assert!(used.iter().all(|u| *u <= budget));
            assert_eq!(used.iter().copied().max().unwrap_or(0), alloc.peak_words);
        });
    }

    #[test]
    fn allocator_prefers_the_better_rate() {
        // Two candidates on one slot: the second saves 10 words per SRAM
        // word, the first only 1 — the second must be served first.
        let cands = vec![
            Candidate {
                label: "cheap".into(),
                page_words: 10,
                max_pages: 100,
                live: 0..1,
                saving: linear(10),
            },
            Candidate {
                label: "dense".into(),
                page_words: 10,
                max_pages: 100,
                live: 0..1,
                saving: linear(100),
            },
        ];
        let alloc = ResidencyAllocator::new(200, 1).allocate(&cands);
        assert_eq!(alloc.pages[1], 20, "dense candidate fills the budget");
        assert_eq!(alloc.pages[0], 0);
    }

    #[test]
    fn allocator_finds_flat_then_steep_curves() {
        // Saving is 0 for the first page and jumps at the second — the
        // greedy's geometric probes must see past the flat start.
        let cands = vec![Candidate {
            label: "steep".into(),
            page_words: 1,
            max_pages: 8,
            live: 0..1,
            saving: Box::new(|p| if p >= 2 { 1000 + p } else { 0 }),
        }];
        let alloc = ResidencyAllocator::new(100, 1).allocate(&cands);
        assert!(alloc.pages[0] >= 2, "got {:?}", alloc.pages);
    }

    #[test]
    fn disjoint_live_ranges_reuse_the_budget() {
        let cands = vec![
            Candidate {
                label: "a".into(),
                page_words: 10,
                max_pages: 10,
                live: 0..1,
                saving: linear(5),
            },
            Candidate {
                label: "b".into(),
                page_words: 10,
                max_pages: 10,
                live: 1..2,
                saving: linear(5),
            },
        ];
        let alloc = ResidencyAllocator::new(100, 2).allocate(&cands);
        assert_eq!(alloc.pages, vec![10, 10], "both fill their own slot");
        assert_eq!(alloc.peak_words, 100);
    }

    #[test]
    fn zero_saving_allocates_nothing() {
        let cands = vec![Candidate {
            label: "dead".into(),
            page_words: 1,
            max_pages: 10,
            live: 0..1,
            saving: linear(0),
        }];
        let alloc = ResidencyAllocator::new(100, 1).allocate(&cands);
        assert_eq!(alloc.pages, vec![0]);
        assert_eq!(alloc.peak_words, 0);
    }
}
