//! Joint plan search with a memoized top-k plan database.
//!
//! The planner stack up to PR 8 makes its choices greedily and
//! independently: `ShardAxis::Auto` picks an axis from the tile mix
//! alone, the prefill/decode lane split scans an eighths grid, and
//! residency is a one-pass marginal allocator.  This module searches the
//! joint space — (tile cover family × shard axis × chained-residency
//! allocation × prefill/decode lane split) — minimizing *overlapped*
//! latency ([`crate::sim::sharded_closed_latency`]), and memoizes
//! results in a top-k database keyed on canonical GEMM specs
//! ([`GemmSpec`]: dims reduced to tile-grid shape + SRAM-budget class +
//! device count), so dim-congruent requests share one search.
//!
//! Search cost is bounded three ways:
//!
//! * every candidate is priced through the `sim::strip` closed forms
//!   (no tile replay),
//! * candidates are beam-pruned with a true lower bound —
//!   `max(per-device compute floor, link rounds)` against the shared
//!   incumbent ([`crate::sim::shard::overlapped_lower_bound`]) — and
//! * the greedy stack's choice seeds the incumbent, so the search can
//!   never return something worse than greedy.
//!
//! Candidates are priced on `std::thread::scope` workers (the crate
//! builds bare — no rayon).  The database persists across coordinator
//! restarts as a versioned line format (`# tas-plandb v2`, see
//! [`PlanDb::to_text`]) and is loaded at boot before
//! `DispatchPlanner::warm_up`, so a warmed fleet replica replans
//! congruent requests without searching at all.

use std::collections::BTreeMap;
use std::io;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use super::layer::StageSpec;
use super::plan::Plan;
use super::shard::{natural_axis, shard_gemm_priced, ShardAxis, ShardSpec, ShardedPlan};
use crate::arch::backend::{BackendKind, PlanPricing};
use crate::arch::Interconnect;
use crate::config::AcceleratorConfig;
use crate::gemm::{GemmShape, Tiling};
use crate::sim::shard::overlapped_lower_bound;
use crate::sim::{shard_link_rounds, sharded_closed_latency};

/// Entries kept per canonical spec: the winner plus runners-up, so a
/// congruent shape can reprice a handful of known-good choices instead
/// of re-running the search.
pub const DB_TOP_K: usize = 4;

/// Default spec-key capacity of a [`PlanDb`] (LRU-evicted beyond this).
pub const PLAN_DB_CAP: usize = 256;

/// First line of the persisted database file.  v2 added the backend name
/// to every spec line; v1 files are rejected (a warmed database priced
/// for one hardware model must never serve another's plans).
pub const PLAN_DB_MAGIC: &str = "# tas-plandb v2";

/// Weight ratio that forces `tas_link_weighted` into a single-scheme
/// cover.  Large enough to dominate any real word-count imbalance, small
/// enough that `WEIGHT_SCALE`-scaled u64 cost terms cannot overflow even
/// on gpt3-sized shapes.
const PURE_WEIGHT: f64 = 1.0e4;

/// Tile-cover families the search chooses between.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoverFamily {
    /// Per-tile adaptive stationary (the paper's sign rule).
    Tas,
    /// Adaptive cover with the remote-prone operand stream priced at the
    /// link premium (`shard_gemm`'s `link_aware` chooser).
    LinkAware,
    /// Uniform input-stationary cover.
    PureIs,
    /// Uniform weight-stationary cover.
    PureWs,
}

impl CoverFamily {
    pub fn name(self) -> &'static str {
        match self {
            CoverFamily::Tas => "tas",
            CoverFamily::LinkAware => "link-aware",
            CoverFamily::PureIs => "pure-is",
            CoverFamily::PureWs => "pure-ws",
        }
    }

    pub fn from_name(name: &str) -> Option<CoverFamily> {
        Some(match name {
            "tas" => CoverFamily::Tas,
            "link-aware" => CoverFamily::LinkAware,
            "pure-is" => CoverFamily::PureIs,
            "pure-ws" => CoverFamily::PureWs,
            _ => return None,
        })
    }
}

/// One point in the per-GEMM search space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SearchChoice {
    pub family: CoverFamily,
    pub axis: ShardAxis,
}

impl SearchChoice {
    /// Stable tie-break rank so result ordering (and therefore the
    /// persisted database) is deterministic across thread schedules.
    pub fn rank(self) -> u64 {
        let f = match self.family {
            CoverFamily::Tas => 0,
            CoverFamily::LinkAware => 1,
            CoverFamily::PureIs => 2,
            CoverFamily::PureWs => 3,
        };
        let a = match self.axis {
            ShardAxis::Rows => 0,
            ShardAxis::Cols => 1,
            ShardAxis::Contraction => 2,
            ShardAxis::Auto => 3,
        };
        f * 4 + a
    }

    pub fn describe(self) -> String {
        format!("{}/{}", self.family.name(), self.axis.name())
    }
}

/// Power-of-two class of an SRAM budget: budgets in the same class share
/// database entries (the residency knapsack re-solves per exact budget;
/// only the cover/axis choice is memoized).
pub fn sram_class(sram_words: u64) -> u32 {
    if sram_words == 0 {
        0
    } else {
        64 - (sram_words - 1).leading_zeros()
    }
}

/// Canonical GEMM spec: the database key.  Dims are reduced to the
/// tile-grid shape under the tiling, so bert-base seq 384 and any
/// dim-congruent request (same grid, same tiling, same SRAM class, same
/// device count) share one entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct GemmSpec {
    pub gm: u64,
    pub gn: u64,
    pub gk: u64,
    pub tm: u64,
    pub tn: u64,
    pub tk: u64,
    /// Psum-window sizes, 0 when unset.
    pub kp: u64,
    pub mp: u64,
    pub sram_class: u32,
    pub devices: u64,
    /// Hardware model the memoized choices were priced for: a plan priced
    /// on one backend never answers a lookup for another.
    pub backend: BackendKind,
}

impl GemmSpec {
    /// Canonical key under the systolic backend (the historical default).
    pub fn canonical(shape: GemmShape, tiling: Tiling, sram_words: u64, devices: u64) -> GemmSpec {
        GemmSpec::canonical_on(shape, tiling, sram_words, devices, BackendKind::Systolic)
    }

    /// Canonical key for an explicit backend.
    pub fn canonical_on(
        shape: GemmShape,
        tiling: Tiling,
        sram_words: u64,
        devices: u64,
        backend: BackendKind,
    ) -> GemmSpec {
        let (gm, gn, gk) = tiling.grid(&shape);
        GemmSpec {
            gm,
            gn,
            gk,
            tm: tiling.tm,
            tn: tiling.tn,
            tk: tiling.tk,
            kp: tiling.kp.unwrap_or(0),
            mp: tiling.mp.unwrap_or(0),
            sram_class: sram_class(sram_words),
            devices,
            backend,
        }
    }
}

/// One memoized result: a choice, the exact shape it was priced on, and
/// both sides of the comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DbEntry {
    pub choice: SearchChoice,
    pub shape: GemmShape,
    pub overlapped_cycles: u64,
    pub greedy_cycles: u64,
}

/// Counters surfaced through the coordinator metrics snapshot.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SearchStats {
    /// Full joint searches run (database misses that priced candidates).
    pub searches: u64,
    /// Lookups served from the database (exact or congruent-repriced).
    pub db_hits: u64,
    /// Lookups that found no usable entry.
    pub db_misses: u64,
    /// Spec keys evicted by the LRU cap.
    pub evictions: u64,
    /// Entries currently stored (across all spec keys).
    pub entries: u64,
    /// Candidates discarded by the beam bound without full pricing.
    pub pruned: u64,
}

/// Memoized top-k plan database, LRU-bounded on spec keys.
#[derive(Clone, Debug)]
pub struct PlanDb {
    map: BTreeMap<GemmSpec, (u64, Vec<DbEntry>)>,
    cap: usize,
    tick: u64,
    searches: u64,
    db_hits: u64,
    db_misses: u64,
    evictions: u64,
    pruned: u64,
}

impl Default for PlanDb {
    fn default() -> Self {
        PlanDb::new(PLAN_DB_CAP)
    }
}

impl PlanDb {
    pub fn new(cap: usize) -> PlanDb {
        PlanDb {
            map: BTreeMap::new(),
            cap: cap.max(1),
            tick: 0,
            searches: 0,
            db_hits: 0,
            db_misses: 0,
            evictions: 0,
            pruned: 0,
        }
    }

    /// Spec keys currently stored.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn stats(&self) -> SearchStats {
        SearchStats {
            searches: self.searches,
            db_hits: self.db_hits,
            db_misses: self.db_misses,
            evictions: self.evictions,
            entries: self.map.values().map(|(_, v)| v.len() as u64).sum(),
            pruned: self.pruned,
        }
    }

    /// Stored entries for one spec, best first (empty when absent).
    pub fn entries(&self, spec: GemmSpec) -> &[DbEntry] {
        self.map.get(&spec).map(|(_, v)| v.as_slice()).unwrap_or(&[])
    }

    /// Insert one entry under its spec: dedupe on (choice, shape), keep
    /// the list sorted by cycles (rank tie-break), truncate to
    /// [`DB_TOP_K`], and LRU-evict the stalest spec past the cap.
    pub fn insert(&mut self, spec: GemmSpec, entry: DbEntry) {
        if !self.map.contains_key(&spec) && self.map.len() >= self.cap {
            let stale = self.map.iter().min_by_key(|(_, v)| v.0).map(|(k, _)| *k);
            if let Some(k) = stale {
                self.map.remove(&k);
                self.evictions += 1;
            }
        }
        self.tick += 1;
        let slot = self.map.entry(spec).or_insert((self.tick, Vec::new()));
        slot.0 = self.tick;
        let list = &mut slot.1;
        if let Some(existing) = list
            .iter_mut()
            .find(|e| e.choice == entry.choice && e.shape == entry.shape)
        {
            if entry.overlapped_cycles < existing.overlapped_cycles {
                *existing = entry;
            }
        } else {
            list.push(entry);
        }
        list.sort_by_key(|e| (e.overlapped_cycles, e.choice.rank()));
        list.truncate(DB_TOP_K);
    }

    fn hit_exact(&mut self, spec: GemmSpec, shape: GemmShape) -> Option<DbEntry> {
        self.tick += 1;
        let tick = self.tick;
        let slot = self.map.get_mut(&spec)?;
        let found = slot.1.iter().find(|e| e.shape == shape).copied();
        if found.is_some() {
            slot.0 = tick;
            self.db_hits += 1;
        }
        found
    }

    /// Congruent lookup: the spec matches but no entry was priced on
    /// this exact shape.  Returns the stored choices (deduped, best
    /// first) for repricing; counts the terminal hit/miss.
    fn hit_congruent(&mut self, spec: GemmSpec) -> Option<Vec<SearchChoice>> {
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(&spec) {
            Some(slot) if !slot.1.is_empty() => {
                slot.0 = tick;
                self.db_hits += 1;
                let mut out: Vec<SearchChoice> = Vec::new();
                for e in &slot.1 {
                    if !out.contains(&e.choice) {
                        out.push(e.choice);
                    }
                }
                Some(out)
            }
            _ => {
                self.db_misses += 1;
                None
            }
        }
    }

    /// Serialize to the versioned line format.  Specs stream in
    /// `BTreeMap` order and entries best-first, so save → load → save is
    /// byte-identical.
    pub fn to_text(&self) -> String {
        let mut out = String::with_capacity(64 + self.map.len() * 128);
        out.push_str(PLAN_DB_MAGIC);
        out.push('\n');
        for (spec, (_, entries)) in &self.map {
            out.push_str(&format!(
                "spec {} {} {} {} {} {} {} {} {} {} {}\n",
                spec.gm,
                spec.gn,
                spec.gk,
                spec.tm,
                spec.tn,
                spec.tk,
                spec.kp,
                spec.mp,
                spec.sram_class,
                spec.devices,
                spec.backend.name(),
            ));
            for e in entries {
                out.push_str(&format!(
                    "entry {} {} {} {} {} {} {}\n",
                    e.choice.family.name(),
                    e.choice.axis.name(),
                    e.shape.m,
                    e.shape.n,
                    e.shape.k,
                    e.overlapped_cycles,
                    e.greedy_cycles,
                ));
            }
        }
        out
    }

    pub fn from_text(text: &str, cap: usize) -> io::Result<PlanDb> {
        let bad = |msg: String| io::Error::new(io::ErrorKind::InvalidData, msg);
        let mut lines = text.lines();
        let head = lines.next().unwrap_or("").trim();
        if head != PLAN_DB_MAGIC {
            return Err(bad(format!(
                "bad plan-db header {head:?} (want {PLAN_DB_MAGIC:?})"
            )));
        }
        let mut db = PlanDb::new(cap);
        let mut cur: Option<GemmSpec> = None;
        for (ln, raw) in lines.enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            let n = |s: &str| {
                s.parse::<u64>()
                    .map_err(|e| bad(format!("plan-db line {}: {e}", ln + 2)))
            };
            match f[0] {
                "spec" => {
                    if f.len() != 12 {
                        return Err(bad(format!(
                            "plan-db line {}: spec wants 11 fields, got {}",
                            ln + 2,
                            f.len() - 1
                        )));
                    }
                    let backend = BackendKind::from_name(f[11]).map_err(|e| {
                        bad(format!("plan-db line {}: {e}", ln + 2))
                    })?;
                    cur = Some(GemmSpec {
                        gm: n(f[1])?,
                        gn: n(f[2])?,
                        gk: n(f[3])?,
                        tm: n(f[4])?,
                        tn: n(f[5])?,
                        tk: n(f[6])?,
                        kp: n(f[7])?,
                        mp: n(f[8])?,
                        sram_class: n(f[9])? as u32,
                        devices: n(f[10])?,
                        backend,
                    });
                }
                "entry" => {
                    let spec = cur.ok_or_else(|| {
                        bad(format!("plan-db line {}: entry before spec", ln + 2))
                    })?;
                    if f.len() != 8 {
                        return Err(bad(format!(
                            "plan-db line {}: entry wants 7 fields, got {}",
                            ln + 2,
                            f.len() - 1
                        )));
                    }
                    let family = CoverFamily::from_name(f[1]).ok_or_else(|| {
                        bad(format!("plan-db line {}: unknown family '{}'", ln + 2, f[1]))
                    })?;
                    let axis = ShardAxis::from_name(f[2]).map_err(|e| {
                        bad(format!("plan-db line {}: {e}", ln + 2))
                    })?;
                    db.insert(
                        spec,
                        DbEntry {
                            choice: SearchChoice { family, axis },
                            shape: GemmShape::new(n(f[3])?, n(f[4])?, n(f[5])?),
                            overlapped_cycles: n(f[6])?,
                            greedy_cycles: n(f[7])?,
                        },
                    );
                }
                other => {
                    return Err(bad(format!(
                        "plan-db line {}: unknown record '{other}'",
                        ln + 2
                    )));
                }
            }
        }
        Ok(db)
    }

    pub fn save(&self, path: &Path) -> io::Result<()> {
        std::fs::write(path, self.to_text())
    }

    pub fn load(path: &Path, cap: usize) -> io::Result<PlanDb> {
        PlanDb::from_text(&std::fs::read_to_string(path)?, cap)
    }
}

/// Everything a per-GEMM search needs besides the shape.
///
/// `backend` selects the hardware model: covers are searched under its
/// pricing ([`BackendKind::pricing`]), spec keys carry it (so one
/// database can hold both targets without cross-talk), and `cfg` must be
/// that backend's derived [`AcceleratorConfig`].
#[derive(Clone, Copy, Debug)]
pub struct SearchCtx<'a> {
    pub tiling: Tiling,
    pub sram_words: u64,
    pub devices: u64,
    pub cfg: &'a AcceleratorConfig,
    pub icx: &'a Interconnect,
    pub backend: BackendKind,
}

/// Result of one per-GEMM lookup/search.
#[derive(Clone, Copy, Debug)]
pub struct SearchOutcome {
    pub choice: SearchChoice,
    /// Overlapped latency of the winning candidate, cycles.
    pub overlapped_cycles: u64,
    /// Overlapped latency of the greedy stack's choice (TAS cover on the
    /// tile-mix natural axis), cycles.
    pub greedy_cycles: u64,
    /// True when a full candidate search ran (database miss).
    pub searched: bool,
}

/// The candidate grid for one GEMM at `devices` shards.
pub fn candidate_choices(devices: u64) -> Vec<SearchChoice> {
    if devices <= 1 {
        return vec![
            SearchChoice { family: CoverFamily::Tas, axis: ShardAxis::Rows },
            SearchChoice { family: CoverFamily::PureIs, axis: ShardAxis::Rows },
            SearchChoice { family: CoverFamily::PureWs, axis: ShardAxis::Rows },
        ];
    }
    let mut out = Vec::new();
    for axis in [ShardAxis::Rows, ShardAxis::Cols, ShardAxis::Contraction] {
        out.push(SearchChoice { family: CoverFamily::Tas, axis });
    }
    // The link-aware chooser only reweights the remote-prone operand on
    // the p2p axes; contraction operands are range-local already.
    for axis in [ShardAxis::Rows, ShardAxis::Cols] {
        out.push(SearchChoice { family: CoverFamily::LinkAware, axis });
    }
    for axis in [ShardAxis::Rows, ShardAxis::Cols, ShardAxis::Contraction] {
        out.push(SearchChoice { family: CoverFamily::PureIs, axis });
        out.push(SearchChoice { family: CoverFamily::PureWs, axis });
    }
    out
}

/// Materialize one candidate as a sharded plan under a backend's pricing.
/// A pure-stationary family pushes the *other* stream's backend price up
/// by [`PURE_WEIGHT`]; a stream the backend never issues stays free, so
/// e.g. `PureWs` on a crossbar degenerates to the activation-stationary
/// cover instead of forcing traffic the hardware does not have.
pub fn candidate_plan(
    shape: GemmShape,
    tiling: Tiling,
    choice: SearchChoice,
    devices: u64,
    remote_word_weight: f64,
    pricing: &PlanPricing,
) -> ShardedPlan {
    match choice.family {
        CoverFamily::Tas => shard_gemm_priced(
            &shape,
            &tiling,
            ShardSpec::new(devices, choice.axis),
            remote_word_weight,
            pricing,
        ),
        CoverFamily::LinkAware => {
            let mut spec = ShardSpec::new(devices, choice.axis);
            spec.link_aware = true;
            shard_gemm_priced(&shape, &tiling, spec, remote_word_weight, pricing)
        }
        CoverFamily::PureIs => ShardedPlan::new(
            Plan::tas_link_priced(&shape, &tiling, PURE_WEIGHT, 1.0, pricing),
            devices,
            choice.axis,
        ),
        CoverFamily::PureWs => ShardedPlan::new(
            Plan::tas_link_priced(&shape, &tiling, 1.0, PURE_WEIGHT, pricing),
            devices,
            choice.axis,
        ),
    }
}

impl SearchCtx<'_> {
    fn remote_word_weight(&self) -> f64 {
        self.icx.remote_word_weight(self.cfg.dram_bandwidth)
    }

    /// Canonical database key for a shape under this context.
    pub fn spec(&self, shape: GemmShape) -> GemmSpec {
        GemmSpec::canonical_on(shape, self.tiling, self.sram_words, self.devices, self.backend)
    }

    /// The greedy stack's choice: TAS cover, `ShardAxis::Auto`'s
    /// tile-mix natural axis.
    pub fn greedy_choice(&self, shape: GemmShape) -> SearchChoice {
        let axis = if self.devices <= 1 {
            ShardAxis::Rows
        } else {
            natural_axis(&Plan::tas_strips_priced(
                &shape,
                &self.tiling,
                &self.backend.pricing(),
            ))
        };
        SearchChoice { family: CoverFamily::Tas, axis }
    }

    /// Overlapped latency of one candidate, closed-form.
    pub fn price(&self, shape: GemmShape, choice: SearchChoice) -> u64 {
        let sp = candidate_plan(
            shape,
            self.tiling,
            choice,
            self.devices,
            self.remote_word_weight(),
            &self.backend.pricing(),
        );
        sharded_closed_latency(&sp, self.cfg, self.icx).overlapped_cycles
    }

    /// Resolve one GEMM through the database, searching on a miss.
    pub fn search(&self, shape: GemmShape, db: &mut PlanDb) -> SearchOutcome {
        let spec = self.spec(shape);
        if let Some(e) = db.hit_exact(spec, shape) {
            return SearchOutcome {
                choice: e.choice,
                overlapped_cycles: e.overlapped_cycles,
                greedy_cycles: e.greedy_cycles,
                searched: false,
            };
        }
        let greedy_choice = self.greedy_choice(shape);
        if let Some(choices) = db.hit_congruent(spec) {
            // Congruent hit: reprice the memoized top-k on this shape
            // plus the greedy floor — a handful of closed-form pricings
            // instead of a full search.
            let greedy_cycles = self.price(shape, greedy_choice);
            let mut best = (greedy_choice, greedy_cycles);
            for c in choices {
                let cy = self.price(shape, c);
                if cy < best.1 || (cy == best.1 && c.rank() < best.0.rank()) {
                    best = (c, cy);
                }
            }
            db.insert(
                spec,
                DbEntry {
                    choice: best.0,
                    shape,
                    overlapped_cycles: best.1,
                    greedy_cycles,
                },
            );
            return SearchOutcome {
                choice: best.0,
                overlapped_cycles: best.1,
                greedy_cycles,
                searched: false,
            };
        }

        // Full search.  Seed the incumbent with the greedy choice and
        // both pure covers on the same axis, then fan the rest of the
        // grid across scoped workers with the beam bound.
        db.searches += 1;
        let floor = overlapped_lower_bound(shape, self.devices, self.cfg);
        let greedy_cycles = self.price(shape, greedy_choice);
        let mut results: Vec<(SearchChoice, u64)> = vec![(greedy_choice, greedy_cycles)];
        for family in [CoverFamily::PureIs, CoverFamily::PureWs] {
            let c = SearchChoice { family, axis: greedy_choice.axis };
            results.push((c, self.price(shape, c)));
        }
        let rest: Vec<SearchChoice> = candidate_choices(self.devices)
            .into_iter()
            .filter(|c| !results.iter().any(|(s, _)| s == c))
            .collect();
        let incumbent =
            AtomicU64::new(results.iter().map(|r| r.1).min().unwrap_or(u64::MAX));
        let pruned = AtomicU64::new(0);
        let mut priced: Vec<Option<(SearchChoice, u64)>> = vec![None; rest.len()];
        if !rest.is_empty() {
            let workers = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(rest.len());
            let chunk = rest.len().div_ceil(workers);
            let ctx = *self;
            std::thread::scope(|s| {
                for (cands, out) in rest.chunks(chunk).zip(priced.chunks_mut(chunk)) {
                    let incumbent = &incumbent;
                    let pruned = &pruned;
                    s.spawn(move || {
                        for (c, slot) in cands.iter().zip(out.iter_mut()) {
                            let sp = candidate_plan(
                                shape,
                                ctx.tiling,
                                *c,
                                ctx.devices,
                                ctx.remote_word_weight(),
                                &ctx.backend.pricing(),
                            );
                            let link: u64 =
                                shard_link_rounds(&sp, ctx.icx).iter().sum();
                            if floor.max(link) >= incumbent.load(Ordering::Relaxed) {
                                pruned.fetch_add(1, Ordering::Relaxed);
                                continue;
                            }
                            let cy =
                                sharded_closed_latency(&sp, ctx.cfg, ctx.icx).overlapped_cycles;
                            incumbent.fetch_min(cy, Ordering::Relaxed);
                            *slot = Some((*c, cy));
                        }
                    });
                }
            });
        }
        results.extend(priced.into_iter().flatten());
        results.sort_by_key(|r| (r.1, r.0.rank()));
        db.pruned += pruned.into_inner();
        for (choice, cy) in results.iter().take(DB_TOP_K) {
            db.insert(
                spec,
                DbEntry {
                    choice: *choice,
                    shape,
                    overlapped_cycles: *cy,
                    greedy_cycles,
                },
            );
        }
        SearchOutcome {
            choice: results[0].0,
            overlapped_cycles: results[0].1,
            greedy_cycles,
            searched: true,
        }
    }
}

/// Per-stage decision in a [`StagesOutcome`].
#[derive(Clone, Debug)]
pub struct StageDecision {
    pub name: &'static str,
    pub shape: GemmShape,
    pub count: u64,
    pub choice: SearchChoice,
    /// Searched overlapped cycles per stage instance.
    pub overlapped_cycles: u64,
    /// Greedy overlapped cycles per stage instance.
    pub greedy_cycles: u64,
    /// True when the joint residency pick parks this stage's input
    /// (previous stage's output) in SRAM.
    pub chained: bool,
}

/// Joint search over a stage chain: per-GEMM (cover × axis) through the
/// database, plus an exact knapsack over chained-residency edges.
#[derive(Clone, Debug)]
pub struct StagesOutcome {
    pub decisions: Vec<StageDecision>,
    pub searched_cycles: u64,
    pub greedy_cycles: u64,
}

/// Search every stage of a chain through the database, then jointly
/// allocate chained-residency edges (exact small knapsack vs the greedy
/// stack's savings-per-word ratio walk).  DRAM-stream savings are a
/// closed-form proxy (`words / dram_bandwidth` per chained edge), used
/// identically on both sides of the comparison.
pub fn search_stages(stages: &[StageSpec], ctx: SearchCtx<'_>, db: &mut PlanDb) -> StagesOutcome {
    let mut decisions = Vec::with_capacity(stages.len());
    let mut searched = 0u64;
    let mut greedy = 0u64;
    for spec in stages {
        let o = ctx.search(spec.shape, db);
        searched += o.overlapped_cycles.saturating_mul(spec.count);
        greedy += o.greedy_cycles.saturating_mul(spec.count);
        decisions.push(StageDecision {
            name: spec.name,
            shape: spec.shape,
            count: spec.count,
            choice: o.choice,
            overlapped_cycles: o.overlapped_cycles,
            greedy_cycles: o.greedy_cycles,
            chained: false,
        });
    }
    // Residency edges: chaining stage i's input parks the previous
    // stage's output in SRAM and strips the input stream from DRAM.
    let edges: Vec<(usize, u64, u64)> = stages
        .iter()
        .enumerate()
        .filter(|(i, s)| *i > 0 && s.consumes_previous)
        .map(|(i, s)| {
            let words = s.shape.input_words().div_ceil(ctx.devices.max(1));
            let saved = s
                .count
                .saturating_mul(words.div_ceil(ctx.cfg.dram_bandwidth.max(1)));
            (i, words, saved)
        })
        .filter(|&(_, w, s)| w > 0 && s > 0 && w <= ctx.sram_words)
        .collect();
    let best_set = best_edge_subset(&edges, ctx.sram_words);
    let greedy_set = greedy_edge_subset(&edges, ctx.sram_words);
    let saved_best: u64 = best_set.iter().map(|&e| edges[e].2).sum();
    let saved_greedy: u64 = greedy_set.iter().map(|&e| edges[e].2).sum();
    for &e in &best_set {
        decisions[edges[e].0].chained = true;
    }
    StagesOutcome {
        decisions,
        searched_cycles: searched.saturating_sub(saved_best),
        greedy_cycles: greedy.saturating_sub(saved_greedy),
    }
}

/// Exact best subset of `(stage, words, saved)` edges under the SRAM
/// budget.  A transformer block has at most a handful of chained edges,
/// so enumeration is exact and cheap; past 16 edges fall back to the
/// ratio greedy.
fn best_edge_subset(edges: &[(usize, u64, u64)], budget: u64) -> Vec<usize> {
    if edges.is_empty() {
        return Vec::new();
    }
    if edges.len() > 16 {
        return greedy_edge_subset(edges, budget);
    }
    let mut best_saved = 0u64;
    let mut best: Vec<usize> = Vec::new();
    for mask in 0u32..(1u32 << edges.len()) {
        let mut words = 0u64;
        let mut saved = 0u64;
        let mut ok = true;
        for (j, e) in edges.iter().enumerate() {
            if mask & (1 << j) != 0 {
                words += e.1;
                saved += e.2;
                if words > budget {
                    ok = false;
                    break;
                }
            }
        }
        if ok && saved > best_saved {
            best_saved = saved;
            best = (0..edges.len()).filter(|j| mask & (1 << j) != 0).collect();
        }
    }
    best
}

/// The greedy stack's shape: take edges by savings-per-word ratio while
/// they fit.
fn greedy_edge_subset(edges: &[(usize, u64, u64)], budget: u64) -> Vec<usize> {
    let mut order: Vec<usize> = (0..edges.len()).collect();
    order.sort_by(|&a, &b| {
        let ra = edges[a].2 as f64 / edges[a].1 as f64;
        let rb = edges[b].2 as f64 / edges[b].1 as f64;
        rb.partial_cmp(&ra).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    let mut words = 0u64;
    let mut out = Vec::new();
    for &j in &order {
        if words + edges[j].1 <= budget {
            words += edges[j].1;
            out.push(j);
        }
    }
    out.sort_unstable();
    out
}

/// Joint prefill/decode lane split: both lane chains searched through
/// the database at every eighths split of the SRAM budget.
#[derive(Clone, Debug)]
pub struct LaneSplitOutcome {
    /// Winning prefill share of the SRAM budget, in eighths (1..=7).
    pub prefill_eighths: u64,
    pub prefill: StagesOutcome,
    pub decode: StagesOutcome,
    /// Searched total (prefill pass + decode step) at the winning split.
    pub searched_cycles: u64,
    /// Greedy floor: the even split with both lanes planned greedily.
    pub greedy_cycles: u64,
    /// Searched total at every grid point (`grid_cycles[f - 1]` is the
    /// total at prefill share `f/8`), so callers can see the whole
    /// cycle landscape — the dispatch planner restricts its full-plan
    /// EMA refinement to the cycle-optimal splits.
    pub grid_cycles: [u64; 7],
}

/// Scan prefill SRAM shares f/8 for f in 1..=7, searching both lane
/// chains at each split; the greedy floor is the even split priced with
/// the greedy stack's choices.  Database memoization makes the scan
/// cheap: splits in the same SRAM class share every per-GEMM entry.
pub fn search_lane_split(
    prefill: &[StageSpec],
    decode: &[StageSpec],
    ctx: SearchCtx<'_>,
    db: &mut PlanDb,
) -> LaneSplitOutcome {
    let mut best: Option<LaneSplitOutcome> = None;
    let mut greedy_even = 0u64;
    let mut grid = [0u64; 7];
    for f in 1..=7u64 {
        let pctx = SearchCtx { sram_words: ctx.sram_words * f / 8, ..ctx };
        let dctx = SearchCtx { sram_words: ctx.sram_words * (8 - f) / 8, ..ctx };
        let p = search_stages(prefill, pctx, db);
        let d = search_stages(decode, dctx, db);
        if f == 4 {
            greedy_even = p.greedy_cycles.saturating_add(d.greedy_cycles);
        }
        let total = p.searched_cycles.saturating_add(d.searched_cycles);
        grid[(f - 1) as usize] = total;
        let better = match &best {
            None => true,
            Some(b) => total < b.searched_cycles,
        };
        if better {
            best = Some(LaneSplitOutcome {
                prefill_eighths: f,
                prefill: p,
                decode: d,
                searched_cycles: total,
                greedy_cycles: 0,
                grid_cycles: [0; 7],
            });
        }
    }
    let mut out = best.expect("eighths scan is non-empty");
    out.greedy_cycles = greedy_even;
    out.grid_cycles = grid;
    out
}

/// Canonical bucket key for fleet cache-affinity routing.  Two buckets
/// whose token counts land on the same tile-grid row count (under the
/// same tiling and SRAM class) generate the same `GemmSpec`s, so they
/// belong on the replica whose database is already warm.
pub fn canonical_bucket_key(tokens: u64, tiling: Tiling, sram_words: u64) -> u64 {
    fnv64(&[
        tokens.div_ceil(tiling.tm.max(1)),
        sram_class(sram_words) as u64,
        tiling.tm,
        tiling.tn,
        tiling.tk,
        tiling.kp.unwrap_or(0),
        tiling.mp.unwrap_or(0),
    ])
}

fn fnv64(xs: &[u64]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &x in xs {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx<'a>(
        cfg: &'a AcceleratorConfig,
        icx: &'a Interconnect,
        devices: u64,
    ) -> SearchCtx<'a> {
        SearchCtx {
            tiling: Tiling::square(16),
            sram_words: 256 * 1024,
            devices,
            cfg,
            icx,
            backend: BackendKind::Systolic,
        }
    }

    #[test]
    fn backends_never_share_spec_keys_or_entries() {
        let cfg = AcceleratorConfig::default();
        let icx = Interconnect::default();
        let sys = ctx(&cfg, &icx, 2);
        let xbar = SearchCtx { backend: BackendKind::Crossbar, ..sys };
        let shape = GemmShape::new(512, 768, 768);
        assert_ne!(sys.spec(shape), xbar.spec(shape));

        // A database warmed on one backend misses for the other.
        let mut db = PlanDb::default();
        let first = sys.search(shape, &mut db);
        assert!(first.searched);
        let other = xbar.search(shape, &mut db);
        assert!(other.searched, "crossbar lookup must not reuse systolic plans");
        assert_eq!(db.stats().searches, 2);

        // The round-tripped text carries both backend tags.
        let text = db.to_text();
        assert!(text.contains(" systolic\n"));
        assert!(text.contains(" crossbar\n"));
        let reloaded = PlanDb::from_text(&text, PLAN_DB_CAP).unwrap();
        assert_eq!(reloaded.to_text(), text);
    }

    #[test]
    fn search_never_loses_to_greedy_on_a_square_shard() {
        let cfg = AcceleratorConfig::default();
        let icx = Interconnect::default();
        for d in [1, 2, 4, 8] {
            let c = ctx(&cfg, &icx, d);
            let mut db = PlanDb::default();
            let o = c.search(GemmShape::new(64, 768, 768), &mut db);
            assert!(
                o.overlapped_cycles <= o.greedy_cycles,
                "d={d}: searched {} > greedy {}",
                o.overlapped_cycles,
                o.greedy_cycles
            );
        }
    }

    #[test]
    fn search_flips_the_square_shard_to_contraction_at_scale() {
        // Mirrors the pinned overlap-aware result: on 64x768x768 the
        // natural (tile-mix) axis loses to the contraction split from
        // d=4 — the joint search must find the flip and strictly win.
        let cfg = AcceleratorConfig::default();
        let icx = Interconnect::default();
        for d in [4u64, 8] {
            let c = ctx(&cfg, &icx, d);
            let mut db = PlanDb::default();
            let o = c.search(GemmShape::new(64, 768, 768), &mut db);
            assert!(o.searched);
            assert_eq!(o.choice.axis, ShardAxis::Contraction, "d={d}");
            assert!(
                o.overlapped_cycles < o.greedy_cycles,
                "d={d}: expected a strict win, got {} vs greedy {}",
                o.overlapped_cycles,
                o.greedy_cycles
            );
        }
    }

    #[test]
    fn exact_hit_is_free_and_congruent_hit_skips_the_search() {
        let cfg = AcceleratorConfig::default();
        let icx = Interconnect::default();
        let c = ctx(&cfg, &icx, 4);
        let mut db = PlanDb::default();
        let first = c.search(GemmShape::new(512, 768, 768), &mut db);
        assert!(first.searched);
        assert_eq!(db.stats().searches, 1);

        // Same shape again: exact hit, identical answer, no new search.
        let again = c.search(GemmShape::new(512, 768, 768), &mut db);
        assert!(!again.searched);
        assert_eq!(again.choice, first.choice);
        assert_eq!(again.overlapped_cycles, first.overlapped_cycles);

        // 500 rows lands on the same 32-row tile grid: congruent hit —
        // repriced, not searched.
        assert_eq!(
            c.spec(GemmShape::new(500, 768, 768)),
            c.spec(GemmShape::new(512, 768, 768))
        );
        let congruent = c.search(GemmShape::new(500, 768, 768), &mut db);
        assert!(!congruent.searched);
        assert!(congruent.overlapped_cycles <= congruent.greedy_cycles);
        let s = db.stats();
        assert_eq!(s.searches, 1);
        assert_eq!(s.db_hits, 2);
    }

    #[test]
    fn database_round_trips_byte_identically() {
        let cfg = AcceleratorConfig::default();
        let icx = Interconnect::default();
        let c = ctx(&cfg, &icx, 4);
        let mut db = PlanDb::default();
        c.search(GemmShape::new(64, 768, 768), &mut db);
        c.search(GemmShape::new(384, 768, 3072), &mut db);
        let text = db.to_text();
        let reloaded = PlanDb::from_text(&text, PLAN_DB_CAP).unwrap();
        assert_eq!(reloaded.to_text(), text);
        assert!(PlanDb::from_text("# tas-plandb v9\n", 8).is_err());
    }

    #[test]
    fn top_k_stays_sorted_and_bounded() {
        let spec = GemmSpec::canonical(
            GemmShape::new(64, 64, 64),
            Tiling::square(16),
            1024,
            1,
        );
        let mut db = PlanDb::new(8);
        let axes = [ShardAxis::Rows, ShardAxis::Cols, ShardAxis::Contraction];
        for (i, family) in [
            CoverFamily::Tas,
            CoverFamily::PureWs,
            CoverFamily::PureIs,
            CoverFamily::LinkAware,
            CoverFamily::Tas,
            CoverFamily::PureIs,
        ]
        .into_iter()
        .enumerate()
        {
            db.insert(
                spec,
                DbEntry {
                    choice: SearchChoice { family, axis: axes[i % 3] },
                    shape: GemmShape::new(64, 64, 64),
                    overlapped_cycles: [900, 100, 400, 250, 700, 520][i],
                    greedy_cycles: 900,
                },
            );
        }
        let entries = db.entries(spec);
        assert_eq!(entries.len(), DB_TOP_K);
        assert!(entries.windows(2).all(|w| w[0].overlapped_cycles
            <= w[1].overlapped_cycles));
        assert_eq!(entries[0].overlapped_cycles, 100);
    }

    #[test]
    fn lru_evicts_the_stalest_spec_at_the_cap() {
        let mut db = PlanDb::new(2);
        let t = Tiling::square(16);
        let mk = |m: u64| GemmSpec::canonical(GemmShape::new(m, 64, 64), t, 1024, 1);
        let entry = |m: u64| DbEntry {
            choice: SearchChoice { family: CoverFamily::Tas, axis: ShardAxis::Rows },
            shape: GemmShape::new(m, 64, 64),
            overlapped_cycles: 10,
            greedy_cycles: 10,
        };
        db.insert(mk(16), entry(16));
        db.insert(mk(32), entry(32));
        db.insert(mk(48), entry(48));
        assert_eq!(db.len(), 2);
        assert_eq!(db.stats().evictions, 1);
        assert!(db.entries(mk(16)).is_empty());
    }

    #[test]
    fn stage_and_lane_searches_never_lose() {
        let cfg = AcceleratorConfig::default();
        let icx = Interconnect::default();
        let c = ctx(&cfg, &icx, 2);
        let stages =
            crate::coordinator::bucket_stages(256, 768, 3072, 0, 2);
        let mut db = PlanDb::default();
        let o = search_stages(&stages, c, &mut db);
        assert!(o.searched_cycles <= o.greedy_cycles);
        assert_eq!(o.decisions.len(), stages.len());

        let decode = crate::coordinator::bucket_stages(64, 768, 3072, 0, 2);
        let lane = search_lane_split(&stages, &decode, c, &mut db);
        assert!(lane.searched_cycles <= lane.greedy_cycles);
        assert!((1..=7).contains(&lane.prefill_eighths));
    }

    #[test]
    fn congruent_buckets_share_the_canonical_routing_key() {
        let t = Tiling::square(16);
        let a = canonical_bucket_key(512, t, 256 * 1024);
        let b = canonical_bucket_key(500, t, 256 * 1024);
        let other = canonical_bucket_key(1024, t, 256 * 1024);
        assert_eq!(a, b);
        assert_ne!(a, other);
    }
}
