//! Multi-accelerator sharding: partition a [`Plan`] across devices by
//! strip ranges, with inter-chip traffic under the same cost algebra as
//! DRAM.
//!
//! A [`Plan`]'s strip cover is already a set of independent
//! output-stationary work units, so sharding routes **whole strips** to
//! devices instead of re-planning per-device sub-GEMMs:
//!
//! * every schedule step runs on exactly one device, so the per-device
//!   *compute EMA* (words a device's PE array consumes, wherever they were
//!   homed) sums to the unsharded plan's EMA **exactly** — conservation is
//!   a construction invariant, not an approximation;
//! * operand words whose home device differs from the consuming device
//!   additionally cross a chip-to-chip link ([`LinkTraffic`]), costed by
//!   [`crate::arch::Interconnect`]; link traffic is additive on top of the
//!   conserved EMA, so a sharded plan can never undercut its unsharded
//!   cost;
//! * one device degenerates to the unsharded plan byte-for-byte.
//!
//! The partition axis follows the paper's notation (`out[M,K] =
//! in[M,N]·w[N,K]`, N the contraction dim): [`ShardAxis::Rows`] splits
//! output rows (M), [`ShardAxis::Cols`] splits output columns (K), and
//! [`ShardAxis::Contraction`] splits N — each device computes partial sums
//! of the whole output and a psum-reduce crosses the links.  The natural
//! axis depends on the stationary decision: IS strips are single output
//! rows (they partition cleanly by M), WS strips are single output columns
//! (cleanly by K), which is what [`ShardAxis::Auto`] picks from the tile
//! mix — the per-tile stationary choice dictates the partition axis.

use super::analytic::EmaBreakdown;
use super::layer::StageSpec;
use super::plan::{Plan, PlanBody, Strip, StripKind};
use super::residency::Residency;
use crate::arch::backend::PlanPricing;
use crate::gemm::{tile_extent, GemmShape, Tiling};

/// Partition axis of a sharded GEMM.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardAxis {
    /// Split output tile rows (M): inputs and outputs are row-local,
    /// weights are homed by K tile-column.
    Rows,
    /// Split output tile columns (K): weights and outputs are
    /// column-local, inputs are homed by M tile-row.
    Cols,
    /// Split the contraction (N): operands are range-local, every device
    /// holds full-output partial sums, reduced across links at the end.
    Contraction,
    /// Pick [`ShardAxis::Rows`] or [`ShardAxis::Cols`] from the plan's
    /// tile mix (IS-dominated covers shard by rows, WS by columns).
    Auto,
}

impl ShardAxis {
    pub fn from_name(name: &str) -> anyhow::Result<ShardAxis> {
        Ok(match name {
            "rows" | "m" => ShardAxis::Rows,
            "cols" | "k" => ShardAxis::Cols,
            "contraction" | "n" => ShardAxis::Contraction,
            "auto" => ShardAxis::Auto,
            _ => anyhow::bail!("unknown shard axis '{name}' (rows|cols|contraction|auto)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ShardAxis::Rows => "rows",
            ShardAxis::Cols => "cols",
            ShardAxis::Contraction => "contraction",
            ShardAxis::Auto => "auto",
        }
    }
}

/// How to shard one GEMM.
#[derive(Clone, Copy, Debug)]
pub struct ShardSpec {
    pub devices: u64,
    pub axis: ShardAxis,
    /// Let the per-tile chooser price the remote-prone operand stream at
    /// its link premium ([`Plan::tas_link_weighted`]): trades extra local
    /// DRAM words for fewer inter-chip words.  No effect on
    /// [`ShardAxis::Contraction`], whose operands are range-local by
    /// construction (only the psum reduce crosses links).
    pub link_aware: bool,
}

impl ShardSpec {
    pub fn new(devices: u64, axis: ShardAxis) -> ShardSpec {
        ShardSpec { devices, axis, link_aware: false }
    }
}

/// Inter-chip word counts of one sharded plan.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LinkTraffic {
    /// Operand/output words served from (or written back to) a remote
    /// home device, point-to-point.
    pub operand_words: u64,
    /// Partial-sum words crossing links in the contraction-split reduce.
    pub reduce_words: u64,
    /// Words received per device.
    pub per_device_in: Vec<u64>,
    /// Words sent per device.
    pub per_device_out: Vec<u64>,
}

impl LinkTraffic {
    pub fn total(&self) -> u64 {
        self.operand_words + self.reduce_words
    }
}

/// Per-device closed-form compute summary: the schedule-side inputs of the
/// aggregate cycle model ([`crate::sim::cycles::cycles_from_parts`]) —
/// obtained from strip ranges without replaying the step stream, so
/// zoo-scale latency checks stay cheap ([`crate::sim::shard`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DeviceCompute {
    /// Schedule steps this device executes.
    pub steps: u64,
    /// MACs this device executes.
    pub macs: u64,
    /// Output tiles this device stores (each stored exactly once).
    pub stores: u64,
}

fn p2p(lt: &mut LinkTraffic, from: usize, to: usize, words: u64) {
    lt.operand_words += words;
    lt.per_device_out[from] += words;
    lt.per_device_in[to] += words;
}

/// Even tile split: `bounds[d] = d·extent/devices`, length `devices + 1`.
pub fn even_bounds(extent: u64, devices: u64) -> Vec<u64> {
    let d = devices.max(1);
    (0..=d).map(|i| i * extent / d).collect()
}

/// Device owning tile index `t` under `bounds` (skipping empty ranges).
pub fn owner_of(bounds: &[u64], t: u64) -> usize {
    let d = bounds.len() - 1;
    for dev in 0..d {
        if t < bounds[dev + 1] {
            return dev;
        }
    }
    d - 1
}

/// A [`Plan`] partitioned across `devices` by strip ranges.
#[derive(Clone, Debug)]
pub struct ShardedPlan {
    pub plan: Plan,
    pub devices: u64,
    /// Resolved partition axis (never [`ShardAxis::Auto`]).
    pub axis: ShardAxis,
    /// Tile-index boundaries along the partition axis (len devices + 1).
    bounds: Vec<u64>,
}

impl ShardedPlan {
    /// Partition `plan`.  Multi-device shards require a strip-cover body
    /// (strips are the atomic routing unit); one device accepts any plan.
    pub fn new(plan: Plan, devices: u64, axis: ShardAxis) -> ShardedPlan {
        let devices = devices.max(1);
        let axis = resolve_axis(axis, &plan);
        assert!(
            devices == 1 || matches!(plan.body, PlanBody::Strips(_)),
            "multi-device shards require a strip-cover plan"
        );
        let (gm, gn, gk) = plan.tiling.grid(&plan.shape);
        let extent = match axis {
            ShardAxis::Rows => gm,
            ShardAxis::Cols => gk,
            ShardAxis::Contraction => gn,
            ShardAxis::Auto => unreachable!("axis resolved above"),
        };
        let bounds = even_bounds(extent, devices);
        ShardedPlan { plan, devices, axis, bounds }
    }

    fn strip_owner(&self, strip: &Strip) -> usize {
        match self.axis {
            ShardAxis::Rows => owner_of(&self.bounds, strip.i0),
            ShardAxis::Cols => owner_of(&self.bounds, strip.j0),
            // Contraction routes by step (r), not by strip.
            ShardAxis::Contraction => 0,
            ShardAxis::Auto => unreachable!("axis resolved at construction"),
        }
    }

    /// Element extent of device `dev`'s contraction range.
    fn contraction_elems(&self, dev: usize) -> u64 {
        let n = self.plan.shape.n;
        let tn = self.plan.tiling.tn;
        let lo = (self.bounds[dev] * tn).min(n);
        let hi = (self.bounds[dev + 1] * tn).min(n);
        hi - lo
    }

    /// Drive `visit` over every `(device, strip, round range)` triple of
    /// the partition — the strip-granular routing the closed per-device
    /// walker folds ([`crate::sim::strip`]).  Rows/Cols devices own whole
    /// strips (`[0, gn)`); Contraction devices own the round range
    /// `[bounds[d], bounds[d+1])` of **every** strip, in the same order
    /// [`ShardedPlan::for_each_step_device`] dispatches the steps.  Fixed
    /// bodies (reachable only unsharded) yield nothing — callers fall
    /// back to the step replay.
    pub fn for_each_strip_range<F: FnMut(usize, &Strip, u64, u64)>(&self, mut visit: F) {
        let strips = match &self.plan.body {
            PlanBody::Fixed(_) => return,
            PlanBody::Strips(s) => s,
        };
        let (_, gn, _) = self.plan.tiling.grid(&self.plan.shape);
        match self.axis {
            ShardAxis::Rows | ShardAxis::Cols => {
                for strip in strips {
                    visit(self.strip_owner(strip), strip, 0, gn);
                }
            }
            ShardAxis::Contraction => {
                for strip in strips {
                    for dev in 0..self.devices as usize {
                        let (lo, hi) = (self.bounds[dev], self.bounds[dev + 1]);
                        if lo < hi {
                            visit(dev, strip, lo, hi);
                        }
                    }
                }
            }
            ShardAxis::Auto => unreachable!("axis resolved at construction"),
        }
    }

    /// Drive `visit` over every step with the device that executes it.
    /// Each step of the underlying plan is visited exactly once.
    pub fn for_each_step_device<F: FnMut(usize, super::Step)>(&self, mut visit: F) {
        match &self.plan.body {
            PlanBody::Fixed(_) => self.plan.for_each_step(|s| visit(0, s)),
            PlanBody::Strips(strips) => match self.axis {
                ShardAxis::Rows | ShardAxis::Cols => {
                    for strip in strips {
                        let dev = self.strip_owner(strip);
                        self.plan.for_each_strip_step(strip, &mut |s| visit(dev, s));
                    }
                }
                ShardAxis::Contraction => {
                    for strip in strips {
                        self.plan.for_each_strip_step(strip, &mut |s: super::Step| {
                            visit(owner_of(&self.bounds, s.r), s)
                        });
                    }
                }
                ShardAxis::Auto => unreachable!("axis resolved at construction"),
            },
        }
    }

    /// Closed-form per-device compute EMA: the DRAM words each device's
    /// replayed steps charge (see [`crate::sim::ema::charge_step`]'s
    /// accounting).  Sums to `self.plan.ema()` exactly — each step is
    /// owned by exactly one device.
    pub fn device_emas(&self) -> Vec<EmaBreakdown> {
        let d = self.devices as usize;
        let mut out = vec![EmaBreakdown::default(); d];
        let shape = self.plan.shape;
        let t = self.plan.tiling;
        let strips = match &self.plan.body {
            PlanBody::Fixed(_) => {
                out[0] = self.plan.ema();
                return out;
            }
            PlanBody::Strips(s) => s,
        };
        let (_, gn, _) = t.grid(&shape);
        match self.axis {
            ShardAxis::Rows | ShardAxis::Cols => {
                for strip in strips {
                    let dev = self.strip_owner(strip);
                    let (iw, ww, ow) = strip.words(&shape, &t);
                    let e = &mut out[dev];
                    if !self.plan.input_residency.is_free() {
                        e.input += iw;
                    }
                    e.weight += ww;
                    if !self.plan.output_residency.is_free() {
                        e.output += ow;
                    }
                }
            }
            ShardAxis::Contraction => {
                // Operand reads split by each device's N-range: both
                // streams are linear in the contraction extent, and every
                // per-strip word count is a multiple of N, so the split is
                // exact.  Only the final-range owner stores the output.
                let n = shape.n;
                let last = owner_of(&self.bounds, gn - 1);
                let elems: Vec<u64> =
                    (0..d).map(|dev| self.contraction_elems(dev)).collect();
                for strip in strips {
                    let (iw, ww, ow) = strip.words(&shape, &t);
                    for (dev, e) in out.iter_mut().enumerate() {
                        if elems[dev] == 0 {
                            continue;
                        }
                        if !self.plan.input_residency.is_free() {
                            e.input += (iw / n) * elems[dev];
                        }
                        e.weight += (ww / n) * elems[dev];
                    }
                    if !self.plan.output_residency.is_free() {
                        out[last].output += ow;
                    }
                }
            }
            ShardAxis::Auto => unreachable!("axis resolved at construction"),
        }
        out
    }

    /// Closed-form per-device (steps, MACs, output stores): sums to the
    /// whole plan's step/MAC counts exactly — each step and each store is
    /// owned by exactly one device.  For a strip body, per-strip MACs are
    /// `output words × N` (every output element accumulates over the full
    /// contraction), split by each device's N-range on the contraction
    /// axis; the rare fixed-scheme body only occurs unsharded (1 device).
    pub fn device_compute(&self) -> Vec<DeviceCompute> {
        let d = self.devices as usize;
        let mut out = vec![DeviceCompute::default(); d];
        let shape = self.plan.shape;
        let t = self.plan.tiling;
        let (gm, gn, gk) = t.grid(&shape);
        let strips = match &self.plan.body {
            PlanBody::Fixed(_) => {
                out[0] = DeviceCompute {
                    steps: self.plan.step_count(),
                    macs: shape.macs(),
                    stores: gm * gk,
                };
                return out;
            }
            PlanBody::Strips(s) => s,
        };
        let n = shape.n;
        match self.axis {
            ShardAxis::Rows | ShardAxis::Cols => {
                for strip in strips {
                    let dev = self.strip_owner(strip);
                    let (_, _, ow) = strip.words(&shape, &t);
                    let e = &mut out[dev];
                    e.steps += strip.tiles() * gn;
                    e.macs += ow * n;
                    e.stores += strip.tiles();
                }
            }
            ShardAxis::Contraction => {
                let last = owner_of(&self.bounds, gn - 1);
                for strip in strips {
                    let (_, _, ow) = strip.words(&shape, &t);
                    for (dev, e) in out.iter_mut().enumerate() {
                        let range_tiles = self.bounds[dev + 1] - self.bounds[dev];
                        if range_tiles == 0 {
                            continue;
                        }
                        e.steps += strip.tiles() * range_tiles;
                        e.macs += ow * self.contraction_elems(dev);
                    }
                    out[last].stores += strip.tiles();
                }
            }
            ShardAxis::Auto => unreachable!("axis resolved at construction"),
        }
        out
    }

    /// Closed-form inter-chip traffic of the partition.
    pub fn link_traffic(&self) -> LinkTraffic {
        let d = self.devices as usize;
        let mut lt = LinkTraffic {
            per_device_in: vec![0; d],
            per_device_out: vec![0; d],
            ..Default::default()
        };
        let shape = self.plan.shape;
        let t = self.plan.tiling;
        let n = shape.n;
        let strips = match &self.plan.body {
            PlanBody::Fixed(_) => return lt,
            PlanBody::Strips(s) => s,
        };
        if d == 1 {
            return lt;
        }
        let (gm, gn, gk) = t.grid(&shape);
        match self.axis {
            ShardAxis::Rows => {
                // Inputs/outputs are homed with their row owner (the shard
                // bounds); weights are homed by K tile-column.
                let col_bounds = even_bounds(gk, self.devices);
                for strip in strips {
                    let dev = self.strip_owner(strip);
                    match strip.kind {
                        StripKind::InputStationary => {
                            // the strip's input row is its owner's: local
                            for j in strip.j0..strip.j1 {
                                let home = owner_of(&col_bounds, j);
                                if home != dev {
                                    p2p(&mut lt, home, dev, n * tile_extent(shape.k, t.tk, j));
                                }
                            }
                        }
                        StripKind::WeightStationary => {
                            let kj = tile_extent(shape.k, t.tk, strip.j0);
                            let home_w = owner_of(&col_bounds, strip.j0);
                            if home_w != dev {
                                p2p(&mut lt, home_w, dev, n * kj);
                            }
                            for i in strip.i0..strip.i1 {
                                let home = owner_of(&self.bounds, i);
                                if home != dev {
                                    let mi = tile_extent(shape.m, t.tm, i);
                                    if !self.plan.input_residency.is_free() {
                                        p2p(&mut lt, home, dev, mi * n);
                                    }
                                    if !self.plan.output_residency.is_free() {
                                        p2p(&mut lt, dev, home, mi * kj);
                                    }
                                }
                            }
                        }
                    }
                }
            }
            ShardAxis::Cols => {
                // Weights/outputs are homed with their column owner;
                // inputs are homed by M tile-row.
                let row_bounds = even_bounds(gm, self.devices);
                for strip in strips {
                    let dev = self.strip_owner(strip);
                    match strip.kind {
                        StripKind::InputStationary => {
                            let i = strip.i0;
                            let mi = tile_extent(shape.m, t.tm, i);
                            let home_in = owner_of(&row_bounds, i);
                            if home_in != dev && !self.plan.input_residency.is_free() {
                                p2p(&mut lt, home_in, dev, mi * n);
                            }
                            for j in strip.j0..strip.j1 {
                                let home = owner_of(&self.bounds, j);
                                if home != dev {
                                    let kj = tile_extent(shape.k, t.tk, j);
                                    p2p(&mut lt, home, dev, n * kj);
                                    if !self.plan.output_residency.is_free() {
                                        p2p(&mut lt, dev, home, mi * kj);
                                    }
                                }
                            }
                        }
                        StripKind::WeightStationary => {
                            // the strip's weight column is its owner's: local
                            for i in strip.i0..strip.i1 {
                                let home = owner_of(&row_bounds, i);
                                if home != dev && !self.plan.input_residency.is_free() {
                                    p2p(&mut lt, home, dev, tile_extent(shape.m, t.tm, i) * n);
                                }
                            }
                        }
                    }
                }
            }
            ShardAxis::Contraction => {
                // Operands are range-local; every non-final device ships
                // its full-output partials to the final-range owner.
                let last = owner_of(&self.bounds, gn - 1);
                let ow = shape.output_words();
                for dev in 0..d {
                    if dev != last && self.contraction_elems(dev) > 0 {
                        lt.reduce_words += ow;
                        lt.per_device_out[dev] += ow;
                        lt.per_device_in[last] += ow;
                    }
                }
            }
            ShardAxis::Auto => unreachable!("axis resolved at construction"),
        }
        lt
    }
}

/// The tile-mix default behind [`ShardAxis::Auto`]: IS-dominated covers
/// shard by output rows, WS-dominated by output columns — the stationary
/// decision dictates the partition axis.  The overlap-aware resolver
/// ([`crate::sim::shard::shard_gemm_overlap_aware`]) starts from this
/// axis and only moves on a strict overlapped-latency win.
pub fn natural_axis(plan: &Plan) -> ShardAxis {
    let (is, ws, _) = plan.tile_mix();
    if ws > is {
        ShardAxis::Cols
    } else {
        ShardAxis::Rows
    }
}

fn resolve_axis(axis: ShardAxis, plan: &Plan) -> ShardAxis {
    match axis {
        ShardAxis::Auto => natural_axis(plan),
        a => a,
    }
}

/// Shard one GEMM: plan per-tile TAS, then partition the strip cover.
///
/// `remote_word_weight` is the link premium per word relative to a local
/// DRAM word (see [`crate::arch::Interconnect::remote_word_weight`]); it
/// only matters when `spec.link_aware` is set.  One device returns the
/// unsharded [`Plan::tas_per_tile`] verbatim.
pub fn shard_gemm(
    shape: &GemmShape,
    tiling: &Tiling,
    spec: ShardSpec,
    remote_word_weight: f64,
) -> ShardedPlan {
    shard_gemm_priced(shape, tiling, spec, remote_word_weight, &PlanPricing::systolic())
}

/// [`shard_gemm`] under a backend's pricing: the link premium multiplies
/// the backend's per-word stream prices ([`Plan::tas_link_priced`]), so a
/// backend that never streams an operand keeps it free across any device
/// count — sharding cannot re-introduce traffic the hardware does not
/// issue.  Systolic pricing reproduces [`shard_gemm`] exactly.
pub fn shard_gemm_priced(
    shape: &GemmShape,
    tiling: &Tiling,
    spec: ShardSpec,
    remote_word_weight: f64,
    pricing: &PlanPricing,
) -> ShardedPlan {
    let devices = spec.devices.max(1);
    let base =
        Plan::tas_priced(shape, tiling, Residency::None, Residency::None, Residency::None, pricing);
    if devices == 1 {
        return ShardedPlan::new(base, 1, spec.axis);
    }
    // Strips are the routing unit: the rare fixed-scheme fallback has no
    // strips, so rebuild as the best pure strip cover.
    let base = match base.body {
        PlanBody::Strips(_) => base,
        PlanBody::Fixed(_) => Plan::tas_strips_priced(shape, tiling, pricing),
    };
    let axis = resolve_axis(spec.axis, &base);
    let lambda = remote_word_weight.max(0.0);
    let plan = if spec.link_aware && lambda > 0.0 {
        // The axis decides which stationary operand is device-resident:
        // row ownership co-locates input/output rows, so weight-stationary
        // strips — which re-read input rows homed on other devices — pay
        // the link premium on every re-read (symmetrically for columns).
        // Pricing that stream keeps the cover axis-aligned; an evenly
        // spread home makes (D-1)/D of its words cross a link.
        let frac = (devices - 1) as f64 / devices as f64;
        match axis {
            ShardAxis::Rows => {
                Plan::tas_link_priced(shape, tiling, 1.0 + lambda * frac, 1.0, pricing)
            }
            ShardAxis::Cols => {
                Plan::tas_link_priced(shape, tiling, 1.0, 1.0 + lambda * frac, pricing)
            }
            _ => base,
        }
    } else {
        base
    };
    ShardedPlan::new(plan, devices, axis)
}

/// Head-parallel partition for decode attention: contiguous head ranges
/// `(lo, hi)` per device.  A head's K/V cache lives wholly on its owner
/// (no cache words ever cross a link), so aggregate cache residency
/// scales with the device count — see [`super::decode`].
pub fn shard_heads(heads: u64, devices: u64) -> Vec<(u64, u64)> {
    even_bounds(heads, devices.max(1))
        .windows(2)
        .map(|w| (w[0], w[1]))
        .collect()
}

/// Place chained block stages on devices: contiguous groups balanced by
/// MAC count (for two devices: QKV+attention on the first, FFN on the
/// second).  Returns one device index per stage, non-decreasing.
pub fn place_stages(stages: &[StageSpec], devices: u64) -> Vec<usize> {
    let d = devices.max(1) as usize;
    let total: u128 = stages
        .iter()
        .map(|s| (s.count * s.shape.macs()) as u128)
        .sum();
    if total == 0 || d == 1 {
        return vec![0; stages.len()];
    }
    let mut placement = Vec::with_capacity(stages.len());
    let mut cum: u128 = 0;
    for s in stages {
        let macs = (s.count * s.shape.macs()) as u128;
        // a stage lives where the midpoint of its MAC interval falls
        let dev = ((cum + macs / 2) * d as u128 / total) as usize;
        placement.push(dev.min(d - 1));
        cum += macs;
    }
    placement
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    fn sum_emas(emas: &[EmaBreakdown]) -> EmaBreakdown {
        let mut total = EmaBreakdown::default();
        for e in emas {
            total.input += e.input;
            total.weight += e.weight;
            total.output += e.output;
        }
        total
    }

    #[test]
    fn even_bounds_cover_and_are_monotone() {
        for extent in [1u64, 3, 7, 16, 100] {
            for d in [1u64, 2, 4, 8, 13] {
                let b = even_bounds(extent, d);
                assert_eq!(b.len() as u64, d + 1);
                assert_eq!(b[0], 0);
                assert_eq!(*b.last().unwrap(), extent);
                assert!(b.windows(2).all(|w| w[0] <= w[1]));
                for t in 0..extent {
                    let o = owner_of(&b, t);
                    assert!(b[o] <= t && t < b[o + 1]);
                }
            }
        }
    }

    #[test]
    fn one_device_shard_is_the_unsharded_plan() {
        let shape = GemmShape::new(384, 768, 768);
        let tiling = Tiling::square(16);
        let sp = shard_gemm(&shape, &tiling, ShardSpec::new(1, ShardAxis::Auto), 0.0);
        assert_eq!(sp.plan, Plan::tas_per_tile(&shape, &tiling));
        let emas = sp.device_emas();
        assert_eq!(emas.len(), 1);
        assert_eq!(emas[0], sp.plan.ema());
        assert_eq!(sp.link_traffic().total(), 0);
    }

    #[test]
    fn sharded_steps_cover_each_tile_triple_once() {
        let shape = GemmShape::new(130, 70, 90);
        let tiling = Tiling::square(16);
        for axis in [ShardAxis::Rows, ShardAxis::Cols, ShardAxis::Contraction] {
            let sp = shard_gemm(&shape, &tiling, ShardSpec::new(3, axis), 0.0);
            let mut seen: HashSet<(u64, u64, u64)> = HashSet::new();
            let mut steps = 0u64;
            sp.for_each_step_device(|dev, s| {
                assert!((dev as u64) < sp.devices);
                assert!(seen.insert((s.i, s.r, s.j)), "step visited twice");
                steps += 1;
            });
            assert_eq!(steps, sp.plan.step_count(), "{axis:?}");
        }
    }

    #[test]
    fn device_emas_sum_to_the_plan_ema() {
        let tiling = Tiling::square(16);
        for shape in [
            GemmShape::new(64, 768, 768),
            GemmShape::new(4096, 768, 768),
            GemmShape::new(130, 70, 90),
        ] {
            for axis in [ShardAxis::Rows, ShardAxis::Cols, ShardAxis::Contraction, ShardAxis::Auto]
            {
                for d in [1u64, 2, 4, 8] {
                    let sp = shard_gemm(&shape, &tiling, ShardSpec::new(d, axis), 0.0);
                    let total = sum_emas(&sp.device_emas());
                    assert_eq!(total, sp.plan.ema(), "{shape:?} {axis:?} d={d}");
                }
            }
        }
    }

    #[test]
    fn auto_axis_follows_the_stationary_decision() {
        let tiling = Tiling::square(16);
        // M < K: all-IS cover -> rows; M >= K: all-WS cover -> cols.
        let is_shape = GemmShape::new(64, 768, 768);
        let ws_shape = GemmShape::new(4096, 768, 768);
        let sp_is = shard_gemm(&is_shape, &tiling, ShardSpec::new(4, ShardAxis::Auto), 0.0);
        let sp_ws = shard_gemm(&ws_shape, &tiling, ShardSpec::new(4, ShardAxis::Auto), 0.0);
        assert_eq!(sp_is.axis, ShardAxis::Rows);
        assert_eq!(sp_ws.axis, ShardAxis::Cols);
        // ...and the natural axis balances the shard: every device works.
        for sp in [&sp_is, &sp_ws] {
            let emas = sp.device_emas();
            assert!(emas.iter().all(|e| e.total() > 0), "{:?}", sp.axis);
        }
    }

    #[test]
    fn rows_shard_links_only_remote_weight_columns() {
        // All-IS cover, rows axis: every device owns its input rows and
        // output rows; only weight columns homed elsewhere cross links.
        // Each of the gm row strips reads all of W, of which (D-1)/D is
        // homed remotely: gm·W·(D-1)/D link words in total (gm = D here).
        let shape = GemmShape::new(64, 768, 768);
        let tiling = Tiling::square(16);
        let d = 4u64;
        let sp = shard_gemm(&shape, &tiling, ShardSpec::new(d, ShardAxis::Rows), 0.0);
        let lt = sp.link_traffic();
        assert_eq!(lt.reduce_words, 0);
        assert_eq!(lt.operand_words, (d - 1) * shape.weight_words(), "{lt:?}");
        assert_eq!(lt.per_device_in.iter().sum::<u64>(), lt.total());
        assert_eq!(lt.per_device_out.iter().sum::<u64>(), lt.total());
    }

    #[test]
    fn contraction_shard_pays_one_reduce_per_extra_device() {
        let shape = GemmShape::new(128, 256, 128);
        let tiling = Tiling::square(16);
        for d in [2u64, 4, 8] {
            let sp = shard_gemm(&shape, &tiling, ShardSpec::new(d, ShardAxis::Contraction), 0.0);
            let lt = sp.link_traffic();
            assert_eq!(lt.operand_words, 0, "operands are range-local");
            assert_eq!(lt.reduce_words, (d - 1) * shape.output_words());
        }
    }

    #[test]
    fn device_compute_partitions_steps_macs_and_stores() {
        let tiling = Tiling::square(16);
        for shape in [
            GemmShape::new(130, 70, 90),
            GemmShape::new(64, 768, 768),
            GemmShape::new(4096, 768, 768),
        ] {
            let (gm, _, gk) = tiling.grid(&shape);
            for axis in [ShardAxis::Rows, ShardAxis::Cols, ShardAxis::Contraction, ShardAxis::Auto]
            {
                for d in [1u64, 2, 3, 4, 8] {
                    let sp = shard_gemm(&shape, &tiling, ShardSpec::new(d, axis), 0.0);
                    let dc = sp.device_compute();
                    assert_eq!(dc.len() as u64, sp.devices);
                    let steps: u64 = dc.iter().map(|c| c.steps).sum();
                    let macs: u64 = dc.iter().map(|c| c.macs).sum();
                    let stores: u64 = dc.iter().map(|c| c.stores).sum();
                    assert_eq!(steps, sp.plan.step_count(), "{shape:?} {axis:?} d={d}");
                    assert_eq!(macs, shape.macs(), "{shape:?} {axis:?} d={d}");
                    assert_eq!(stores, gm * gk, "{shape:?} {axis:?} d={d}");
                    // replayed per-device step counts agree
                    let mut replayed = vec![0u64; sp.devices as usize];
                    sp.for_each_step_device(|dev, _| replayed[dev] += 1);
                    for (c, r) in dc.iter().zip(&replayed) {
                        assert_eq!(c.steps, *r);
                    }
                }
            }
        }
    }

    #[test]
    fn more_devices_than_tiles_leaves_spares_idle() {
        let shape = GemmShape::new(32, 64, 64); // 2 tile rows
        let tiling = Tiling::square(16);
        let sp = shard_gemm(&shape, &tiling, ShardSpec::new(8, ShardAxis::Rows), 0.0);
        let emas = sp.device_emas();
        assert_eq!(emas.len(), 8);
        assert_eq!(sum_emas(&emas), sp.plan.ema());
        assert!(emas.iter().filter(|e| e.total() > 0).count() <= 2);
    }

    #[test]
    fn link_aware_plan_cuts_link_words_and_rebalances() {
        // M >= K forced onto the rows axis: the default cover goes
        // weight-stationary, whose full-height strips all land on the
        // first row owner and re-read remote input rows per column.
        // Pricing the input stream flips the cover to row-aligned IS
        // strips: fewer inter-chip words AND a balanced partition.
        let shape = GemmShape::new(4096, 768, 768);
        let tiling = Tiling::square(16);
        let d = 4u64;
        let plain = shard_gemm(&shape, &tiling, ShardSpec::new(d, ShardAxis::Rows), 2.0);
        let mut spec = ShardSpec::new(d, ShardAxis::Rows);
        spec.link_aware = true;
        let aware = shard_gemm(&shape, &tiling, spec, 2.0);
        let (pl, al) = (plain.link_traffic().total(), aware.link_traffic().total());
        assert!(al < pl, "aware {al} >= plain {pl}");
        let max_ema = |sp: &ShardedPlan| {
            sp.device_emas().iter().map(|e| e.total()).max().unwrap()
        };
        assert!(max_ema(&aware) < max_ema(&plain), "partition should rebalance");
        // conservation still holds for the aware plan
        assert_eq!(sum_emas(&aware.device_emas()), aware.plan.ema());
    }

    #[test]
    fn fixed_fallback_rebuilds_as_strips_for_multi_device() {
        // A shape whose per-tile plan falls back to a fixed scheme (single
        // contraction tile favours spilling IS on extreme ratios) must
        // still shard: the planner rebuilds a strip cover.
        let tiling = Tiling::square(16).with_kp(16).with_mp(16);
        let shape = GemmShape::new(4096, 16, 4096);
        let sp = shard_gemm(&shape, &tiling, ShardSpec::new(4, ShardAxis::Auto), 0.0);
        assert!(matches!(sp.plan.body, PlanBody::Strips(_)));
        assert_eq!(sum_emas(&sp.device_emas()), sp.plan.ema());
    }

    #[test]
    fn shard_heads_covers_every_head_once() {
        for heads in [1u64, 12, 16, 96] {
            for d in [1u64, 2, 3, 4, 8] {
                if d > heads {
                    continue;
                }
                let ranges = shard_heads(heads, d);
                assert_eq!(ranges.len() as u64, d);
                assert_eq!(ranges[0].0, 0);
                assert_eq!(ranges.last().unwrap().1, heads);
                for w in ranges.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "ranges contiguous");
                }
                let total: u64 = ranges.iter().map(|(lo, hi)| hi - lo).sum();
                assert_eq!(total, heads);
            }
        }
    }

    #[test]
    fn place_stages_balances_and_stays_contiguous() {
        use crate::models::zoo;
        let m = zoo::bert_base();
        let stages = m.block_stages(512);
        for d in [1u64, 2, 4, 8] {
            let p = place_stages(&stages, d);
            assert_eq!(p.len(), stages.len());
            assert!(p.windows(2).all(|w| w[0] <= w[1]), "placement contiguous");
            assert!(p.iter().all(|&x| (x as u64) < d));
            if d >= 2 {
                // FFN must not share a device with the QKV projections
                assert!(p[p.len() - 1] > p[0]);
            }
        }
    }
}
