//! Typed configuration for the accelerator, energy model and simulator,
//! loadable from TOML files (see `configs/`) or built from presets.
//!
//! Defaults model the paper's assumed hardware: a 16×16 PE array, 16-bit
//! words, an internal SRAM of a few hundred KiB, and Ayaka-calibrated
//! energy ratios (external transfer 10–100× internal compute, §IV).

use crate::arch::backend::{AnyBackend, BackendKind, CrossbarConfig};
use crate::arch::{Dram, InterconnectConfig, PeArray, RegFile, Sram};
use crate::gemm::Tiling;
use crate::util::toml::TomlDoc;
use anyhow::{Context, Result};
use std::path::Path;

/// Accelerator hardware parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AcceleratorConfig {
    /// PE array edge (square, §III-A).
    pub pe_dim: u64,
    /// Tile sizes; usually `pe_dim` each.
    pub tile_m: u64,
    pub tile_n: u64,
    pub tile_k: u64,
    /// Partial-sum register capacity in words (bounds k'·m / m'·k).
    pub psum_regs: u64,
    /// Internal SRAM capacity in words.
    pub sram_words: u64,
    /// DRAM bandwidth in words/cycle.
    pub dram_bandwidth: u64,
    /// DRAM read↔write turnaround penalty in cycles.
    pub dram_turnaround: u64,
    /// Word width in bytes (paper uses 16-bit fixed point).
    pub word_bytes: u64,
}

impl Default for AcceleratorConfig {
    fn default() -> Self {
        AcceleratorConfig {
            pe_dim: 16,
            tile_m: 16,
            tile_n: 16,
            tile_k: 16,
            // 16 KiW psum regs: a 16-wide row of 64 psum tiles (k' = 1024).
            psum_regs: 16 * 1024,
            // 256 KiW (~512 KB at 16-bit) internal SRAM.
            sram_words: 256 * 1024,
            dram_bandwidth: 16,
            dram_turnaround: 12,
            word_bytes: 2,
        }
    }
}

impl AcceleratorConfig {
    /// The 8×8 variant the paper also cites.
    pub fn small() -> Self {
        AcceleratorConfig {
            pe_dim: 8,
            tile_m: 8,
            tile_n: 8,
            tile_k: 8,
            psum_regs: 4 * 1024,
            sram_words: 64 * 1024,
            ..Default::default()
        }
    }

    pub fn pe_array(&self) -> PeArray {
        PeArray::square(self.pe_dim)
    }

    pub fn dram(&self) -> Dram {
        Dram::new(self.dram_bandwidth, self.dram_turnaround)
    }

    pub fn sram(&self) -> Sram {
        Sram::new(self.sram_words)
    }

    pub fn regfile(&self) -> RegFile {
        RegFile::new(self.psum_regs)
    }

    /// Tiling with psum windows sized to the register capacity:
    /// k' = floor(P / m)·k-aligned, m' likewise (Fig. 2's k', m').
    pub fn tiling(&self) -> Tiling {
        let t = Tiling::new(self.tile_m, self.tile_n, self.tile_k);
        let kp = (self.psum_regs / self.tile_m / self.tile_k).max(1) * self.tile_k;
        let mp = (self.psum_regs / self.tile_k / self.tile_m).max(1) * self.tile_m;
        t.with_kp(kp).with_mp(mp)
    }

    pub fn from_toml(doc: &TomlDoc) -> Self {
        let d = AcceleratorConfig::default();
        AcceleratorConfig {
            pe_dim: doc.get_u64("accelerator.pe_dim", d.pe_dim),
            tile_m: doc.get_u64("accelerator.tile_m", d.tile_m),
            tile_n: doc.get_u64("accelerator.tile_n", d.tile_n),
            tile_k: doc.get_u64("accelerator.tile_k", d.tile_k),
            psum_regs: doc.get_u64("accelerator.psum_regs", d.psum_regs),
            sram_words: doc.get_u64("accelerator.sram_words", d.sram_words),
            dram_bandwidth: doc.get_u64("accelerator.dram_bandwidth", d.dram_bandwidth),
            dram_turnaround: doc.get_u64("accelerator.dram_turnaround", d.dram_turnaround),
            word_bytes: doc.get_u64("accelerator.word_bytes", d.word_bytes),
        }
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.pe_dim > 0, "pe_dim must be positive");
        anyhow::ensure!(
            self.tile_m > 0 && self.tile_n > 0 && self.tile_k > 0,
            "tile sizes must be positive"
        );
        anyhow::ensure!(
            self.psum_regs >= self.tile_m * self.tile_k,
            "psum regs must hold at least one output tile ({} < {})",
            self.psum_regs,
            self.tile_m * self.tile_k
        );
        anyhow::ensure!(
            self.sram_words >= self.tile_m * self.tile_n + self.tile_n * self.tile_k,
            "SRAM must hold one input + one weight tile"
        );
        anyhow::ensure!(self.dram_bandwidth > 0, "dram_bandwidth must be positive");
        Ok(())
    }
}

/// Energy cost table (per word / per MAC), Ayaka-calibrated ratios.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EnergyConfig {
    /// Energy per DRAM word access (pJ).
    pub dram_pj: f64,
    /// Energy per SRAM word access (pJ).
    pub sram_pj: f64,
    /// Energy per psum register access (pJ).
    pub reg_pj: f64,
    /// Energy per MAC (pJ).
    pub mac_pj: f64,
}

impl Default for EnergyConfig {
    fn default() -> Self {
        // Eyeriss/Ayaka-style ratios: DRAM ≈ 200×, SRAM ≈ 6×, reg ≈ 1× MAC.
        EnergyConfig { dram_pj: 200.0, sram_pj: 6.0, reg_pj: 1.0, mac_pj: 1.0 }
    }
}

impl EnergyConfig {
    pub fn from_toml(doc: &TomlDoc) -> Self {
        let d = EnergyConfig::default();
        EnergyConfig {
            dram_pj: doc.get_f64("energy.dram_pj", d.dram_pj),
            sram_pj: doc.get_f64("energy.sram_pj", d.sram_pj),
            reg_pj: doc.get_f64("energy.reg_pj", d.reg_pj),
            mac_pj: doc.get_f64("energy.mac_pj", d.mac_pj),
        }
    }
}

/// TOML loading for the inter-chip link model (see
/// [`crate::arch::interconnect`]), kept beside the other config parsers
/// so every `[section]` is parsed the same way.
impl InterconnectConfig {
    pub fn from_toml(doc: &TomlDoc) -> Self {
        let d = InterconnectConfig::default();
        InterconnectConfig {
            link_bandwidth: doc.get_u64("interconnect.link_bandwidth", d.link_bandwidth),
            link_latency: doc.get_u64("interconnect.link_latency", d.link_latency),
            link_energy_pj: doc.get_f64("interconnect.link_energy_pj", d.link_energy_pj),
        }
    }
}

/// TOML loading for the crossbar backend geometry, `[backend.crossbar]`
/// (see [`crate::arch::backend::CrossbarConfig`]).
impl CrossbarConfig {
    pub fn from_toml(doc: &TomlDoc) -> Self {
        let d = CrossbarConfig::default();
        CrossbarConfig {
            xbar_dim: doc.get_u64("backend.crossbar.xbar_dim", d.xbar_dim),
            adc_lanes: doc.get_u64("backend.crossbar.adc_lanes", d.adc_lanes),
            dac_setup: doc.get_u64("backend.crossbar.dac_setup", d.dac_setup),
            bus_words_per_cycle: doc
                .get_u64("backend.crossbar.bus_words_per_cycle", d.bus_words_per_cycle),
            bus_turnaround: doc
                .get_u64("backend.crossbar.bus_turnaround", d.bus_turnaround),
            buffer_words: doc.get_u64("backend.crossbar.buffer_words", d.buffer_words),
            tile_m: doc.get_u64("backend.crossbar.tile_m", d.tile_m),
            psum_regs: doc.get_u64("backend.crossbar.psum_regs", d.psum_regs),
            program_pj_per_word: doc
                .get_f64("backend.crossbar.program_pj_per_word", d.program_pj_per_word),
            program_words_per_word: doc.get_u64(
                "backend.crossbar.program_words_per_word",
                d.program_words_per_word,
            ),
        }
    }
}

/// Top-level config bundle.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    pub accelerator: AcceleratorConfig,
    pub energy: EnergyConfig,
    pub interconnect: InterconnectConfig,
    /// Hardware model selected by `[backend] kind = "..."`.
    pub backend: BackendKind,
    /// Crossbar geometry, `[backend.crossbar]`; ignored unless `backend`
    /// is [`BackendKind::Crossbar`].
    pub crossbar: CrossbarConfig,
}

impl Config {
    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let doc = TomlDoc::parse(&text)?;
        let cfg = Config {
            accelerator: AcceleratorConfig::from_toml(&doc),
            energy: EnergyConfig::from_toml(&doc),
            interconnect: InterconnectConfig::from_toml(&doc),
            backend: BackendKind::from_name(doc.get_str("backend.kind", "systolic"))?,
            crossbar: CrossbarConfig::from_toml(&doc),
        };
        cfg.accelerator.validate()?;
        cfg.interconnect.validate()?;
        if cfg.backend == BackendKind::Crossbar {
            cfg.crossbar.validate()?;
        }
        Ok(cfg)
    }

    /// Build the selected hardware backend: the systolic target adopts
    /// `[accelerator]`, the crossbar derives its geometry from
    /// `[backend.crossbar]`; both share `[energy]`.
    pub fn make_backend(&self) -> AnyBackend {
        AnyBackend::build(self.backend, self.accelerator, self.energy, self.crossbar)
    }

    /// The accelerator geometry the selected backend plans on (the
    /// crossbar re-expresses its own dims in the shared vocabulary).
    pub fn planning_accel(&self) -> AcceleratorConfig {
        match self.backend {
            BackendKind::Systolic => self.accelerator,
            BackendKind::Crossbar => self.crossbar.accel(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        AcceleratorConfig::default().validate().unwrap();
        AcceleratorConfig::small().validate().unwrap();
    }

    #[test]
    fn tiling_windows_fit_regfile() {
        let c = AcceleratorConfig::default();
        let t = c.tiling();
        // k'·m and m'·k must fit in the register file.
        assert!(t.kp.unwrap() * c.tile_m <= c.psum_regs);
        assert!(t.mp.unwrap() * c.tile_k <= c.psum_regs);
    }

    #[test]
    fn toml_overrides() {
        let doc = TomlDoc::parse(
            "[accelerator]\npe_dim = 8\ntile_m = 8\ntile_n = 8\ntile_k = 8\n\
             [energy]\ndram_pj = 160.0",
        )
        .unwrap();
        let a = AcceleratorConfig::from_toml(&doc);
        assert_eq!(a.pe_dim, 8);
        assert_eq!(a.tile_m, 8);
        // untouched fields keep defaults
        assert_eq!(a.sram_words, AcceleratorConfig::default().sram_words);
        let e = EnergyConfig::from_toml(&doc);
        assert_eq!(e.dram_pj, 160.0);
        assert_eq!(e.mac_pj, 1.0);
    }

    #[test]
    fn interconnect_toml_overrides() {
        let doc = TomlDoc::parse(
            "[interconnect]\nlink_bandwidth = 4\nlink_energy_pj = 800.0",
        )
        .unwrap();
        let i = InterconnectConfig::from_toml(&doc);
        assert_eq!(i.link_bandwidth, 4);
        assert_eq!(i.link_energy_pj, 800.0);
        // untouched fields keep defaults
        assert_eq!(i.link_latency, InterconnectConfig::default().link_latency);
    }

    #[test]
    fn backend_toml_selects_and_overrides() {
        let doc = TomlDoc::parse(
            "[backend]\nkind = \"crossbar\"\n\
             [backend.crossbar]\nxbar_dim = 64\nprogram_pj_per_word = 1500.0",
        )
        .unwrap();
        assert_eq!(doc.get_str("backend.kind", "systolic"), "crossbar");
        let x = CrossbarConfig::from_toml(&doc);
        assert_eq!(x.xbar_dim, 64);
        assert_eq!(x.program_pj_per_word, 1500.0);
        // untouched fields keep defaults
        assert_eq!(x.adc_lanes, CrossbarConfig::default().adc_lanes);
        // an absent section means the systolic default
        let empty = TomlDoc::parse("").unwrap();
        assert_eq!(
            BackendKind::from_name(empty.get_str("backend.kind", "systolic")).unwrap(),
            BackendKind::Systolic
        );
    }

    #[test]
    fn make_backend_follows_the_selected_kind() {
        use crate::arch::backend::Backend;
        let mut cfg = Config::default();
        assert_eq!(cfg.make_backend().kind(), BackendKind::Systolic);
        assert_eq!(cfg.planning_accel(), cfg.accelerator);
        cfg.backend = BackendKind::Crossbar;
        let b = cfg.make_backend();
        assert_eq!(b.kind(), BackendKind::Crossbar);
        assert_eq!(cfg.planning_accel(), cfg.crossbar.accel());
        // crossbar planning geometry is the crossbar's, not [accelerator]
        assert_eq!(cfg.planning_accel().tile_k, cfg.crossbar.xbar_dim);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = AcceleratorConfig::default();
        c.psum_regs = 1;
        assert!(c.validate().is_err());
        let mut c2 = AcceleratorConfig::default();
        c2.sram_words = 1;
        assert!(c2.validate().is_err());
    }
}

#[cfg(test)]
mod file_tests {
    use super::*;

    #[test]
    fn ships_loadable_config_files() {
        // the configs/ directory must stay in sync with the parser
        for name in [
            "configs/default.toml",
            "configs/small8x8.toml",
            "configs/crossbar.toml",
        ] {
            let path = Path::new(name);
            if !path.exists() {
                // tests may run from another cwd; resolve via manifest dir
                let alt = Path::new(env!("CARGO_MANIFEST_DIR")).join(name);
                let cfg = Config::load(&alt).unwrap();
                cfg.accelerator.validate().unwrap();
                continue;
            }
            let cfg = Config::load(path).unwrap();
            cfg.accelerator.validate().unwrap();
        }
    }

    #[test]
    fn default_toml_matches_builtin_defaults() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/default.toml");
        let cfg = Config::load(&path).unwrap();
        assert_eq!(cfg.accelerator, AcceleratorConfig::default());
        assert_eq!(cfg.energy, EnergyConfig::default());
        assert_eq!(cfg.interconnect, InterconnectConfig::default());
        assert_eq!(cfg.backend, BackendKind::Systolic);
        assert_eq!(cfg.crossbar, CrossbarConfig::default());
    }

    #[test]
    fn crossbar_toml_selects_the_crossbar_backend() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/crossbar.toml");
        let cfg = Config::load(&path).unwrap();
        assert_eq!(cfg.backend, BackendKind::Crossbar);
        cfg.crossbar.validate().unwrap();
    }
}
