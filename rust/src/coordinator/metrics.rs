//! Serving + accelerator-side metrics.
//!
//! Besides the usual latency/throughput, the coordinator accounts the
//! *dataflow* quantities the paper cares about for every batch it
//! dispatches: EMA words under TAS vs the fixed baselines, computed from
//! the analytic model on the served bucket's GEMMs.
//!
//! Scalar accounting lives in an [`obs::Registry`] (named counters +
//! last-value/peak gauges) instead of one struct field per statistic;
//! latency distributions (end-to-end, TTFT, TPOT, batch exec) are bounded
//! [`Summary`] reservoirs.  Percentiles and ratios are `Option`-valued:
//! an empty coordinator reports JSON `null`, never a bare `NaN` token.

use crate::dataflow::Scheme;
use crate::energy::workload_read_ema;
use crate::gemm::Tiling;
use crate::models::GemmWorkload;
use crate::obs::Registry;
use crate::report::json::{jarr, jf64, jnum, jobj, jopt};
use crate::util::json::Json;
use crate::util::stats::Summary;
use std::sync::Mutex;
use std::time::Duration;

// Registry keys. One name per statistic; the snapshot reads them back out
// into its stable public fields.
const REQUESTS: &str = "requests";
const BATCHES: &str = "batches";
const TOKENS: &str = "tokens";
const PADDED_TOKENS: &str = "padded_tokens";
const EMA_NAIVE: &str = "ema_naive_words";
const EMA_AYAKA: &str = "ema_ayaka_words";
const EMA_TAS: &str = "ema_tas_words";
const EMA_PLAN: &str = "ema_plan_words";
const EMA_PLAN_BASE: &str = "ema_plan_baseline_words";
const LINK_WORDS: &str = "link_words";
const FLOPS: &str = "flops";
const DECODE_BATCHES: &str = "decode_batches";
const DECODE_TOKENS: &str = "decode_tokens";
const EMA_DECODE: &str = "ema_decode_words";
const EMA_DECODE_BASE: &str = "ema_decode_baseline_words";
const DECODE_CACHE_HOT: &str = "decode_cache_hot_words";
const QUEUE_DEPTH: &str = "queue_depth";
const DECODE_QUEUE_DEPTH: &str = "decode_queue_depth";
const BATCH_OCCUPANCY: &str = "batch_occupancy";

/// Aggregated over one coordinator lifetime. Thread-safe.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    reg: Registry,
    latency: Summary,
    ttft: Summary,
    tpot: Summary,
    batch_exec: Summary,
    device_ema_words: Vec<u64>,
    planner_cache: crate::coordinator::decisions::PlannerCacheStats,
    plan_db: crate::dataflow::SearchStats,
}

/// Point-in-time snapshot for reporting.
///
/// Latency fields are `None` until at least one sample lands, so JSON
/// emission ([`MetricsSnapshot::to_json`]) produces `null` instead of the
/// invalid `NaN` token a raw empty percentile used to leak.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub tokens: u64,
    pub padded_tokens: u64,
    pub latency_p50_ms: Option<f64>,
    pub latency_p99_ms: Option<f64>,
    pub latency_mean_ms: Option<f64>,
    /// Exact sample count and millisecond sum of the latency stream —
    /// what a Prometheus summary's `_count`/`_sum` series need (the
    /// reservoir only bounds the percentile samples, not these).
    pub latency_count: u64,
    pub latency_sum_ms: f64,
    pub batch_exec_mean_ms: Option<f64>,
    /// Time-to-first-token distribution (prefill completion latency).
    pub ttft_p50_ms: Option<f64>,
    pub ttft_p99_ms: Option<f64>,
    pub ttft_count: u64,
    pub ttft_sum_ms: f64,
    /// Time-per-output-token distribution (decode-step dispatch latency
    /// per generated token; accounting-only until decode artifacts exist).
    pub tpot_p50_ms: Option<f64>,
    pub tpot_p99_ms: Option<f64>,
    pub tpot_count: u64,
    pub tpot_sum_ms: f64,
    /// Prefill queue depth at the last batcher poll (and its high-water
    /// mark over the coordinator lifetime).
    pub queue_depth: Option<f64>,
    pub queue_depth_peak: Option<f64>,
    pub decode_queue_depth: Option<f64>,
    pub decode_queue_depth_peak: Option<f64>,
    /// Requests per dispatched batch over the bucket's capacity (last /
    /// peak), i.e. how full the padding buckets run.
    pub batch_occupancy: Option<f64>,
    pub ema_naive_words: u64,
    pub ema_ayaka_words: u64,
    pub ema_tas_words: u64,
    /// Layer-level plan (per-tile TAS + SRAM residency) — total EMA, not
    /// just the read direction, hence comparable to `ema_plan_baseline`.
    pub ema_plan_words: u64,
    /// Per-GEMM TAS total EMA for the same batches (the plan's baseline).
    pub ema_plan_baseline_words: u64,
    /// Inter-chip activation handoffs of the served (placed) layer plans.
    pub link_words: u64,
    /// Plan EMA per device (len = widest placement seen; sums to
    /// `ema_plan_words`).
    pub per_device_ema_words: Vec<u64>,
    pub flops: u64,
    /// Decode-lane accounting: dispatched decode steps, generated tokens,
    /// and their EMA under the cache-resident decode plan vs per-GEMM TAS.
    pub decode_batches: u64,
    pub decode_tokens: u64,
    pub ema_decode_words: u64,
    pub ema_decode_baseline_words: u64,
    /// Cache words served from SRAM instead of DRAM across decode steps.
    pub decode_cache_hot_words: u64,
    /// Cumulative hit/miss/evict counters of the dispatch planner's
    /// bounded plan-memo caches (latest counters recorded by the device
    /// loop — already cumulative on the planner side).
    pub planner_cache: crate::coordinator::decisions::PlannerCacheStats,
    /// Cumulative joint-search counters of the planner's memoized plan
    /// database (searches run, hits/misses, evictions, entries, beam
    /// candidates pruned) — shows search amortization per replica.
    pub plan_db: crate::dataflow::SearchStats,
}

fn ratio_saved(spent: u64, baseline: u64) -> Option<f64> {
    if baseline == 0 {
        None
    } else {
        Some(1.0 - spent as f64 / baseline as f64)
    }
}

impl MetricsSnapshot {
    /// (A−C)/A — the Table IV headline, live. `None` before any batch.
    pub fn ema_reduction_vs_naive(&self) -> Option<f64> {
        ratio_saved(self.ema_tas_words, self.ema_naive_words)
    }

    pub fn ema_reduction_vs_ayaka(&self) -> Option<f64> {
        ratio_saved(self.ema_tas_words, self.ema_ayaka_words)
    }

    /// Saving of layer-level planning over per-GEMM TAS on the batches
    /// actually served (total EMA words, both sides).
    pub fn ema_reduction_vs_per_gemm(&self) -> Option<f64> {
        ratio_saved(self.ema_plan_words, self.ema_plan_baseline_words)
    }

    /// Saving of the decode plan over per-GEMM TAS on dispatched steps.
    pub fn decode_reduction_vs_per_gemm(&self) -> Option<f64> {
        ratio_saved(self.ema_decode_words, self.ema_decode_baseline_words)
    }

    /// Decode DRAM words per generated token.
    pub fn decode_per_token_ema(&self) -> Option<f64> {
        if self.decode_tokens == 0 {
            None
        } else {
            Some(self.ema_decode_words as f64 / self.decode_tokens as f64)
        }
    }

    pub fn padding_fraction(&self) -> Option<f64> {
        let total = self.tokens + self.padded_tokens;
        if total == 0 {
            None
        } else {
            Some(self.padded_tokens as f64 / total as f64)
        }
    }

    /// The full snapshot as a JSON object — the one emission path the CLI
    /// `--json` report and the regression tests share. Every possibly-empty
    /// statistic goes through [`jopt`], so the document is always valid
    /// JSON (property: parses on a fresh coordinator).
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("requests", jnum(self.requests)),
            ("batches", jnum(self.batches)),
            ("tokens", jnum(self.tokens)),
            ("padded_tokens", jnum(self.padded_tokens)),
            ("padding_fraction", jopt(self.padding_fraction())),
            ("latency_p50_ms", jopt(self.latency_p50_ms)),
            ("latency_p99_ms", jopt(self.latency_p99_ms)),
            ("latency_mean_ms", jopt(self.latency_mean_ms)),
            ("latency_count", jnum(self.latency_count)),
            ("latency_sum_ms", jf64(self.latency_sum_ms)),
            ("batch_exec_mean_ms", jopt(self.batch_exec_mean_ms)),
            ("ttft_p50_ms", jopt(self.ttft_p50_ms)),
            ("ttft_p99_ms", jopt(self.ttft_p99_ms)),
            ("ttft_count", jnum(self.ttft_count)),
            ("ttft_sum_ms", jf64(self.ttft_sum_ms)),
            ("tpot_p50_ms", jopt(self.tpot_p50_ms)),
            ("tpot_p99_ms", jopt(self.tpot_p99_ms)),
            ("tpot_count", jnum(self.tpot_count)),
            ("tpot_sum_ms", jf64(self.tpot_sum_ms)),
            ("queue_depth", jopt(self.queue_depth)),
            ("queue_depth_peak", jopt(self.queue_depth_peak)),
            ("decode_queue_depth", jopt(self.decode_queue_depth)),
            (
                "decode_queue_depth_peak",
                jopt(self.decode_queue_depth_peak),
            ),
            ("batch_occupancy", jopt(self.batch_occupancy)),
            ("ema_naive_words", jnum(self.ema_naive_words)),
            ("ema_ayaka_words", jnum(self.ema_ayaka_words)),
            ("ema_tas_words", jnum(self.ema_tas_words)),
            ("ema_plan_words", jnum(self.ema_plan_words)),
            (
                "ema_plan_baseline_words",
                jnum(self.ema_plan_baseline_words),
            ),
            (
                "ema_reduction_vs_naive",
                jopt(self.ema_reduction_vs_naive()),
            ),
            (
                "ema_reduction_vs_ayaka",
                jopt(self.ema_reduction_vs_ayaka()),
            ),
            (
                "ema_reduction_vs_per_gemm",
                jopt(self.ema_reduction_vs_per_gemm()),
            ),
            ("link_words", jnum(self.link_words)),
            (
                "per_device_ema_words",
                jarr(self
                    .per_device_ema_words
                    .iter()
                    .map(|&w| jnum(w))
                    .collect()),
            ),
            ("flops", jnum(self.flops)),
            ("decode_batches", jnum(self.decode_batches)),
            ("decode_tokens", jnum(self.decode_tokens)),
            ("ema_decode_words", jnum(self.ema_decode_words)),
            (
                "ema_decode_baseline_words",
                jnum(self.ema_decode_baseline_words),
            ),
            (
                "decode_reduction_vs_per_gemm",
                jopt(self.decode_reduction_vs_per_gemm()),
            ),
            ("decode_per_token_ema", jopt(self.decode_per_token_ema())),
            (
                "decode_cache_hot_words",
                jnum(self.decode_cache_hot_words),
            ),
            (
                "planner_cache",
                jobj(vec![
                    ("hits", jnum(self.planner_cache.hits)),
                    ("misses", jnum(self.planner_cache.misses)),
                    ("evictions", jnum(self.planner_cache.evictions)),
                    ("entries", jnum(self.planner_cache.entries)),
                ]),
            ),
            (
                "plan_db",
                jobj(vec![
                    ("searches", jnum(self.plan_db.searches)),
                    ("hits", jnum(self.plan_db.db_hits)),
                    ("misses", jnum(self.plan_db.db_misses)),
                    ("evictions", jnum(self.plan_db.evictions)),
                    ("entries", jnum(self.plan_db.entries)),
                    ("pruned", jnum(self.plan_db.pruned)),
                ]),
            ),
        ])
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Record one dispatched batch with its accelerator-side accounting.
    /// `layer_plan` is the bucket's layer-level plan (per-tile TAS + SRAM
    /// residency); its total EMA and per-GEMM TAS baseline are accumulated
    /// alongside the paper's read-EMA columns.
    #[allow(clippy::too_many_arguments)]
    pub fn record_batch(
        &self,
        n_requests: usize,
        real_tokens: u64,
        padded_tokens: u64,
        exec: Duration,
        gemms: &[GemmWorkload],
        tiling: &Tiling,
        layer_plan: &crate::dataflow::LayerPlan,
        flops: u64,
    ) {
        let naive = workload_read_ema(Scheme::Naive, gemms, tiling);
        let ayaka = crate::energy::ayaka::ayaka_workload_read_ema(gemms);
        let tas = workload_read_ema(Scheme::Tas, gemms, tiling);
        let plan_words = layer_plan.total_ema();
        let plan_baseline = layer_plan.per_gemm_tas_total();
        let link_words = layer_plan.handoff_words();
        let per_device = layer_plan.per_device_ema();
        let mut g = self.inner.lock().unwrap();
        g.reg.add(BATCHES, 1);
        g.reg.add(REQUESTS, n_requests as u64);
        g.reg.add(TOKENS, real_tokens);
        g.reg.add(PADDED_TOKENS, padded_tokens);
        g.batch_exec.push(exec.as_secs_f64() * 1e3);
        g.reg.add(EMA_NAIVE, naive);
        g.reg.add(EMA_AYAKA, ayaka);
        g.reg.add(EMA_TAS, tas);
        g.reg.add(EMA_PLAN, plan_words);
        g.reg.add(EMA_PLAN_BASE, plan_baseline);
        g.reg.add(LINK_WORDS, link_words);
        if g.device_ema_words.len() < per_device.len() {
            g.device_ema_words.resize(per_device.len(), 0);
        }
        for (acc, w) in g.device_ema_words.iter_mut().zip(&per_device) {
            *acc += w;
        }
        g.reg.add(FLOPS, flops);
    }

    /// Record one dispatched decode step: `slots` sequences each advanced
    /// by one token under `step_plan`'s accounting. `exec` is the step's
    /// dispatch latency; each non-empty step contributes it as one TPOT
    /// sample (every slot advances exactly one token per step, so the
    /// step latency *is* the per-token latency of its sequences).
    pub fn record_decode_batch(
        &self,
        slots: usize,
        step_plan: &crate::dataflow::DecodeStepPlan,
        exec: Duration,
    ) {
        let mut g = self.inner.lock().unwrap();
        g.reg.add(DECODE_BATCHES, 1);
        g.reg.add(DECODE_TOKENS, slots as u64);
        g.reg.add(EMA_DECODE, step_plan.total_ema());
        g.reg.add(EMA_DECODE_BASE, step_plan.per_gemm_tas_total());
        g.reg.add(DECODE_CACHE_HOT, step_plan.cache_hot_total());
        if slots > 0 {
            g.tpot.push(exec.as_secs_f64() * 1e3);
        }
    }

    /// Record one completed request's end-to-end latency.
    pub fn record_latency(&self, latency: Duration) {
        self.inner.lock().unwrap().latency.push(latency.as_secs_f64() * 1e3);
    }

    /// Record one prefill request's time-to-first-token (arrival → reply).
    pub fn record_ttft(&self, ttft: Duration) {
        self.inner.lock().unwrap().ttft.push(ttft.as_secs_f64() * 1e3);
    }

    /// Sample the batcher's queue depths (prefill pending, decode pending).
    pub fn record_queue_depth(&self, prefill: usize, decode: usize) {
        let mut g = self.inner.lock().unwrap();
        g.reg.set_gauge(QUEUE_DEPTH, prefill as f64);
        g.reg.set_gauge(DECODE_QUEUE_DEPTH, decode as f64);
    }

    /// Sample a dispatched batch's occupancy: requests over bucket slots.
    pub fn record_batch_occupancy(&self, filled: usize, capacity: usize) {
        if capacity == 0 {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.reg
            .set_gauge(BATCH_OCCUPANCY, filled as f64 / capacity as f64);
    }

    /// Record the dispatch planner's cache counters.  The planner's
    /// counters are cumulative, so the latest snapshot replaces the
    /// stored one rather than accumulating.
    pub fn record_planner_cache(
        &self,
        stats: crate::coordinator::decisions::PlannerCacheStats,
    ) {
        self.inner.lock().unwrap().planner_cache = stats;
    }

    /// Record the planner's joint-search database counters.  Cumulative
    /// on the planner side, so the latest snapshot replaces the stored
    /// one.
    pub fn record_search_stats(&self, stats: crate::dataflow::SearchStats) {
        self.inner.lock().unwrap().plan_db = stats;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let mean_of = |s: &Summary| {
            if s.count() == 0 {
                None
            } else {
                Some(s.mean())
            }
        };
        MetricsSnapshot {
            requests: g.reg.counter(REQUESTS),
            batches: g.reg.counter(BATCHES),
            tokens: g.reg.counter(TOKENS),
            padded_tokens: g.reg.counter(PADDED_TOKENS),
            latency_p50_ms: g.latency.p50(),
            latency_p99_ms: g.latency.p99(),
            latency_mean_ms: mean_of(&g.latency),
            latency_count: g.latency.count(),
            latency_sum_ms: g.latency.sum(),
            batch_exec_mean_ms: mean_of(&g.batch_exec),
            ttft_p50_ms: g.ttft.p50(),
            ttft_p99_ms: g.ttft.p99(),
            ttft_count: g.ttft.count(),
            ttft_sum_ms: g.ttft.sum(),
            tpot_p50_ms: g.tpot.p50(),
            tpot_p99_ms: g.tpot.p99(),
            tpot_count: g.tpot.count(),
            tpot_sum_ms: g.tpot.sum(),
            queue_depth: g.reg.gauge(QUEUE_DEPTH),
            queue_depth_peak: g.reg.gauge_peak(QUEUE_DEPTH),
            decode_queue_depth: g.reg.gauge(DECODE_QUEUE_DEPTH),
            decode_queue_depth_peak: g.reg.gauge_peak(DECODE_QUEUE_DEPTH),
            batch_occupancy: g.reg.gauge(BATCH_OCCUPANCY),
            ema_naive_words: g.reg.counter(EMA_NAIVE),
            ema_ayaka_words: g.reg.counter(EMA_AYAKA),
            ema_tas_words: g.reg.counter(EMA_TAS),
            ema_plan_words: g.reg.counter(EMA_PLAN),
            ema_plan_baseline_words: g.reg.counter(EMA_PLAN_BASE),
            link_words: g.reg.counter(LINK_WORDS),
            per_device_ema_words: g.device_ema_words.clone(),
            flops: g.reg.counter(FLOPS),
            decode_batches: g.reg.counter(DECODE_BATCHES),
            decode_tokens: g.reg.counter(DECODE_TOKENS),
            ema_decode_words: g.reg.counter(EMA_DECODE),
            ema_decode_baseline_words: g.reg.counter(EMA_DECODE_BASE),
            decode_cache_hot_words: g.reg.counter(DECODE_CACHE_HOT),
            planner_cache: g.planner_cache,
            plan_db: g.plan_db,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::decisions::layer_plan_for_bucket;
    use crate::dataflow::LayerPlan;
    use crate::gemm::GemmShape;

    fn gemms() -> Vec<GemmWorkload> {
        vec![GemmWorkload {
            name: "qkv",
            shape: GemmShape::new(64, 128, 128),
            count: 2,
        }]
    }

    fn plan() -> LayerPlan {
        layer_plan_for_bucket(64, 128, 256, 512, 1, &Tiling::square(16), 256 * 1024)
    }

    #[test]
    fn batch_accounting_accumulates() {
        let m = Metrics::new();
        m.record_batch(
            2,
            100,
            28,
            Duration::from_millis(3),
            &gemms(),
            &Tiling::square(16),
            &plan(),
            1000,
        );
        m.record_batch(
            1,
            60,
            4,
            Duration::from_millis(5),
            &gemms(),
            &Tiling::square(16),
            &plan(),
            500,
        );
        m.record_latency(Duration::from_millis(4));
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.batches, 2);
        assert_eq!(s.tokens, 160);
        assert_eq!(s.flops, 1500);
        assert!(s.ema_reduction_vs_naive().unwrap() > 0.9);
        assert!(s.ema_reduction_vs_ayaka().unwrap() > 0.5);
        assert_eq!(s.ema_plan_words, 2 * plan().total_ema());
        assert!(s.ema_plan_words <= s.ema_plan_baseline_words);
        assert!((0.0..=1.0).contains(&s.ema_reduction_vs_per_gemm().unwrap()));
        assert!((s.padding_fraction().unwrap() - 32.0 / 192.0).abs() < 1e-9);
        assert!(s.latency_p50_ms.unwrap() > 0.0);
    }

    #[test]
    fn empty_snapshot_is_sane() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.ema_reduction_vs_naive(), None);
        assert_eq!(s.ema_reduction_vs_per_gemm(), None);
        assert_eq!(s.padding_fraction(), None);
        assert_eq!(s.link_words, 0);
        assert!(s.per_device_ema_words.is_empty());
        assert_eq!(s.decode_reduction_vs_per_gemm(), None);
        assert_eq!(s.decode_per_token_ema(), None);
        assert_eq!(s.latency_p50_ms, None);
        assert_eq!(s.ttft_p99_ms, None);
        assert_eq!(s.queue_depth, None);
    }

    #[test]
    fn fresh_snapshot_serialises_to_valid_json_without_nan() {
        // Regression for the NaN leak: an empty coordinator's --json
        // report used to contain bare `NaN` tokens (invalid JSON).
        let s = Metrics::new().snapshot();
        let text = s.to_json().to_string_compact();
        assert!(!text.contains("NaN"), "NaN leaked into {text}");
        let doc = Json::parse(&text).expect("fresh snapshot must parse");
        assert_eq!(doc.get("latency_p50_ms"), Some(&Json::Null));
        assert_eq!(doc.get("ttft_p50_ms"), Some(&Json::Null));
        assert_eq!(doc.get("padding_fraction"), Some(&Json::Null));
        assert_eq!(doc.get("requests").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn populated_snapshot_serialises_the_new_telemetry() {
        let m = Metrics::new();
        m.record_ttft(Duration::from_millis(7));
        m.record_queue_depth(5, 2);
        m.record_queue_depth(1, 0);
        m.record_batch_occupancy(3, 8);
        let s = m.snapshot();
        assert_eq!(s.ttft_p50_ms.map(|v| v.round()), Some(7.0));
        assert_eq!(s.ttft_count, 1);
        assert!((s.ttft_sum_ms - 7.0).abs() < 1e-6);
        assert_eq!(s.latency_count, 0);
        assert_eq!(s.latency_sum_ms, 0.0);
        assert_eq!(s.queue_depth, Some(1.0));
        assert_eq!(s.queue_depth_peak, Some(5.0));
        assert_eq!(s.decode_queue_depth_peak, Some(2.0));
        assert_eq!(s.batch_occupancy, Some(0.375));
        let doc = Json::parse(&s.to_json().to_string_compact()).unwrap();
        assert_eq!(
            doc.get("queue_depth_peak").unwrap().as_f64(),
            Some(5.0)
        );
    }

    #[test]
    fn decode_batches_accumulate_their_own_lane() {
        use crate::coordinator::decisions::decode_plan_for_bucket;
        let m = Metrics::new();
        let step = decode_plan_for_bucket(
            4,
            96,
            128,
            512,
            0,
            4,
            2,
            &Tiling::square(16),
            256 * 1024,
        );
        m.record_decode_batch(4, &step, Duration::from_millis(2));
        m.record_decode_batch(4, &step, Duration::from_millis(2));
        let s = m.snapshot();
        assert_eq!(s.decode_batches, 2);
        assert_eq!(s.decode_tokens, 8);
        assert_eq!(s.ema_decode_words, 2 * step.total_ema());
        assert!(s.ema_decode_words <= s.ema_decode_baseline_words);
        assert!(
            (0.0..=1.0).contains(&s.decode_reduction_vs_per_gemm().unwrap())
        );
        assert!(s.decode_per_token_ema().unwrap() > 0.0);
        assert!(s.tpot_p50_ms.unwrap() > 0.0);
        // the prefill lane is untouched
        assert_eq!(s.batches, 0);
        assert_eq!(s.ema_plan_words, 0);
        assert_eq!(s.ttft_p50_ms, None);
    }

    #[test]
    fn planner_cache_counters_surface_in_the_snapshot() {
        use crate::coordinator::decisions::DispatchPlanner;
        let m = Metrics::new();
        assert_eq!(m.snapshot().planner_cache.misses, 0);
        let mut planner =
            DispatchPlanner::new(128, 512, 0, 2, 2, Tiling::square(16), 64 * 1024, 1);
        planner.plan_dispatch(Some(64), None);
        planner.plan_dispatch(Some(64), None);
        m.record_planner_cache(planner.cache_stats());
        let s = m.snapshot();
        assert_eq!(s.planner_cache.misses, 1);
        assert_eq!(s.planner_cache.hits, 1);
        assert_eq!(s.planner_cache.entries, 1);
        // counters are cumulative on the planner: re-recording replaces
        planner.plan_dispatch(Some(128), None);
        m.record_planner_cache(planner.cache_stats());
        assert_eq!(m.snapshot().planner_cache.misses, 2);
    }

    #[test]
    fn plan_db_counters_surface_in_the_snapshot() {
        use crate::coordinator::decisions::DispatchPlanner;
        let m = Metrics::new();
        assert_eq!(m.snapshot().plan_db.searches, 0);
        let mut planner =
            DispatchPlanner::new(128, 512, 0, 2, 2, Tiling::square(16), 64 * 1024, 1);
        planner.plan_dispatch(Some(64), None);
        m.record_search_stats(planner.search_stats());
        let after_first = m.snapshot().plan_db;
        assert!(after_first.searches > 0);
        assert!(after_first.entries > 0);
        // The same bucket again resolves from exact-shape hits.
        planner.plan_dispatch(Some(64), None);
        m.record_search_stats(planner.search_stats());
        let after_second = m.snapshot().plan_db;
        assert_eq!(after_second.searches, after_first.searches);
        assert!(after_second.db_hits > after_first.db_hits);
        let json = m.snapshot().to_json();
        assert!(json.contains("\"plan_db\""));
        assert!(json.contains("\"searches\""));
    }

    #[test]
    fn sharded_batches_report_per_device_and_link_words() {
        use crate::coordinator::decisions::sharded_layer_plan_for_bucket;
        let m = Metrics::new();
        let plan = sharded_layer_plan_for_bucket(
            256,
            128,
            512,
            0,
            2,
            &Tiling::square(16),
            256 * 1024,
            2,
        );
        m.record_batch(
            1,
            200,
            56,
            Duration::from_millis(2),
            &gemms(),
            &Tiling::square(16),
            &plan,
            100,
        );
        let s = m.snapshot();
        assert_eq!(s.per_device_ema_words.len(), plan.devices() as usize);
        assert_eq!(
            s.per_device_ema_words.iter().sum::<u64>(),
            s.ema_plan_words
        );
        assert_eq!(s.link_words, plan.handoff_words());
    }
}
