//! Serving + accelerator-side metrics.
//!
//! Besides the usual latency/throughput, the coordinator accounts the
//! *dataflow* quantities the paper cares about for every batch it
//! dispatches: EMA words under TAS vs the fixed baselines, computed from
//! the analytic model on the served bucket's GEMMs.

use crate::dataflow::Scheme;
use crate::energy::workload_read_ema;
use crate::gemm::Tiling;
use crate::models::GemmWorkload;
use crate::util::stats::Summary;
use std::sync::Mutex;
use std::time::Duration;

/// Aggregated over one coordinator lifetime. Thread-safe.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    batches: u64,
    tokens: u64,
    padded_tokens: u64,
    latency: Summary,
    batch_exec: Summary,
    ema_naive_words: u64,
    ema_ayaka_words: u64,
    ema_tas_words: u64,
    ema_plan_words: u64,
    ema_plan_baseline_words: u64,
    link_words: u64,
    device_ema_words: Vec<u64>,
    flops: u64,
    decode_batches: u64,
    decode_tokens: u64,
    ema_decode_words: u64,
    ema_decode_baseline_words: u64,
    decode_cache_hot_words: u64,
    planner_cache: crate::coordinator::decisions::PlannerCacheStats,
}

/// Point-in-time snapshot for reporting.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub tokens: u64,
    pub padded_tokens: u64,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
    pub latency_mean_ms: f64,
    pub batch_exec_mean_ms: f64,
    pub ema_naive_words: u64,
    pub ema_ayaka_words: u64,
    pub ema_tas_words: u64,
    /// Layer-level plan (per-tile TAS + SRAM residency) — total EMA, not
    /// just the read direction, hence comparable to `ema_plan_baseline`.
    pub ema_plan_words: u64,
    /// Per-GEMM TAS total EMA for the same batches (the plan's baseline).
    pub ema_plan_baseline_words: u64,
    /// Inter-chip activation handoffs of the served (placed) layer plans.
    pub link_words: u64,
    /// Plan EMA per device (len = widest placement seen; sums to
    /// `ema_plan_words`).
    pub per_device_ema_words: Vec<u64>,
    pub flops: u64,
    /// Decode-lane accounting: dispatched decode steps, generated tokens,
    /// and their EMA under the cache-resident decode plan vs per-GEMM TAS.
    pub decode_batches: u64,
    pub decode_tokens: u64,
    pub ema_decode_words: u64,
    pub ema_decode_baseline_words: u64,
    /// Cache words served from SRAM instead of DRAM across decode steps.
    pub decode_cache_hot_words: u64,
    /// Cumulative hit/miss/evict counters of the dispatch planner's
    /// bounded plan-memo caches (latest counters recorded by the device
    /// loop — already cumulative on the planner side).
    pub planner_cache: crate::coordinator::decisions::PlannerCacheStats,
}

impl MetricsSnapshot {
    /// (A−C)/A — the Table IV headline, live.
    pub fn ema_reduction_vs_naive(&self) -> f64 {
        if self.ema_naive_words == 0 {
            0.0
        } else {
            1.0 - self.ema_tas_words as f64 / self.ema_naive_words as f64
        }
    }

    pub fn ema_reduction_vs_ayaka(&self) -> f64 {
        if self.ema_ayaka_words == 0 {
            0.0
        } else {
            1.0 - self.ema_tas_words as f64 / self.ema_ayaka_words as f64
        }
    }

    /// Saving of layer-level planning over per-GEMM TAS on the batches
    /// actually served (total EMA words, both sides).
    pub fn ema_reduction_vs_per_gemm(&self) -> f64 {
        if self.ema_plan_baseline_words == 0 {
            0.0
        } else {
            1.0 - self.ema_plan_words as f64 / self.ema_plan_baseline_words as f64
        }
    }

    /// Saving of the decode plan over per-GEMM TAS on dispatched steps.
    pub fn decode_reduction_vs_per_gemm(&self) -> f64 {
        if self.ema_decode_baseline_words == 0 {
            0.0
        } else {
            1.0 - self.ema_decode_words as f64 / self.ema_decode_baseline_words as f64
        }
    }

    /// Decode DRAM words per generated token.
    pub fn decode_per_token_ema(&self) -> f64 {
        if self.decode_tokens == 0 {
            0.0
        } else {
            self.ema_decode_words as f64 / self.decode_tokens as f64
        }
    }

    pub fn padding_fraction(&self) -> f64 {
        let total = self.tokens + self.padded_tokens;
        if total == 0 {
            0.0
        } else {
            self.padded_tokens as f64 / total as f64
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Record one dispatched batch with its accelerator-side accounting.
    /// `layer_plan` is the bucket's layer-level plan (per-tile TAS + SRAM
    /// residency); its total EMA and per-GEMM TAS baseline are accumulated
    /// alongside the paper's read-EMA columns.
    #[allow(clippy::too_many_arguments)]
    pub fn record_batch(
        &self,
        n_requests: usize,
        real_tokens: u64,
        padded_tokens: u64,
        exec: Duration,
        gemms: &[GemmWorkload],
        tiling: &Tiling,
        layer_plan: &crate::dataflow::LayerPlan,
        flops: u64,
    ) {
        let naive = workload_read_ema(Scheme::Naive, gemms, tiling);
        let ayaka = crate::energy::ayaka::ayaka_workload_read_ema(gemms);
        let tas = workload_read_ema(Scheme::Tas, gemms, tiling);
        let plan_words = layer_plan.total_ema();
        let plan_baseline = layer_plan.per_gemm_tas_total();
        let link_words = layer_plan.handoff_words();
        let per_device = layer_plan.per_device_ema();
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.requests += n_requests as u64;
        g.tokens += real_tokens;
        g.padded_tokens += padded_tokens;
        g.batch_exec.push(exec.as_secs_f64() * 1e3);
        g.ema_naive_words += naive;
        g.ema_ayaka_words += ayaka;
        g.ema_tas_words += tas;
        g.ema_plan_words += plan_words;
        g.ema_plan_baseline_words += plan_baseline;
        g.link_words += link_words;
        if g.device_ema_words.len() < per_device.len() {
            g.device_ema_words.resize(per_device.len(), 0);
        }
        for (acc, w) in g.device_ema_words.iter_mut().zip(&per_device) {
            *acc += w;
        }
        g.flops += flops;
    }

    /// Record one dispatched decode step: `slots` sequences each advanced
    /// by one token under `step_plan`'s accounting.
    pub fn record_decode_batch(
        &self,
        slots: usize,
        step_plan: &crate::dataflow::DecodeStepPlan,
    ) {
        let mut g = self.inner.lock().unwrap();
        g.decode_batches += 1;
        g.decode_tokens += slots as u64;
        g.ema_decode_words += step_plan.total_ema();
        g.ema_decode_baseline_words += step_plan.per_gemm_tas_total();
        g.decode_cache_hot_words += step_plan.cache_hot_total();
    }

    /// Record one completed request's end-to-end latency.
    pub fn record_latency(&self, latency: Duration) {
        self.inner.lock().unwrap().latency.push(latency.as_secs_f64() * 1e3);
    }

    /// Record the dispatch planner's cache counters.  The planner's
    /// counters are cumulative, so the latest snapshot replaces the
    /// stored one rather than accumulating.
    pub fn record_planner_cache(
        &self,
        stats: crate::coordinator::decisions::PlannerCacheStats,
    ) {
        self.inner.lock().unwrap().planner_cache = stats;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        MetricsSnapshot {
            requests: g.requests,
            batches: g.batches,
            tokens: g.tokens,
            padded_tokens: g.padded_tokens,
            latency_p50_ms: g.latency.p50(),
            latency_p99_ms: g.latency.p99(),
            latency_mean_ms: g.latency.mean(),
            batch_exec_mean_ms: g.batch_exec.mean(),
            ema_naive_words: g.ema_naive_words,
            ema_ayaka_words: g.ema_ayaka_words,
            ema_tas_words: g.ema_tas_words,
            ema_plan_words: g.ema_plan_words,
            ema_plan_baseline_words: g.ema_plan_baseline_words,
            link_words: g.link_words,
            per_device_ema_words: g.device_ema_words.clone(),
            flops: g.flops,
            decode_batches: g.decode_batches,
            decode_tokens: g.decode_tokens,
            ema_decode_words: g.ema_decode_words,
            ema_decode_baseline_words: g.ema_decode_baseline_words,
            decode_cache_hot_words: g.decode_cache_hot_words,
            planner_cache: g.planner_cache,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::decisions::layer_plan_for_bucket;
    use crate::dataflow::LayerPlan;
    use crate::gemm::GemmShape;

    fn gemms() -> Vec<GemmWorkload> {
        vec![GemmWorkload {
            name: "qkv",
            shape: GemmShape::new(64, 128, 128),
            count: 2,
        }]
    }

    fn plan() -> LayerPlan {
        layer_plan_for_bucket(64, 128, 256, 512, 1, &Tiling::square(16), 256 * 1024)
    }

    #[test]
    fn batch_accounting_accumulates() {
        let m = Metrics::new();
        m.record_batch(
            2,
            100,
            28,
            Duration::from_millis(3),
            &gemms(),
            &Tiling::square(16),
            &plan(),
            1000,
        );
        m.record_batch(
            1,
            60,
            4,
            Duration::from_millis(5),
            &gemms(),
            &Tiling::square(16),
            &plan(),
            500,
        );
        m.record_latency(Duration::from_millis(4));
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.batches, 2);
        assert_eq!(s.tokens, 160);
        assert_eq!(s.flops, 1500);
        assert!(s.ema_reduction_vs_naive() > 0.9);
        assert!(s.ema_reduction_vs_ayaka() > 0.5);
        assert_eq!(s.ema_plan_words, 2 * plan().total_ema());
        assert!(s.ema_plan_words <= s.ema_plan_baseline_words);
        assert!((0.0..=1.0).contains(&s.ema_reduction_vs_per_gemm()));
        assert!((s.padding_fraction() - 32.0 / 192.0).abs() < 1e-9);
        assert!(s.latency_p50_ms > 0.0);
    }

    #[test]
    fn empty_snapshot_is_sane() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.ema_reduction_vs_naive(), 0.0);
        assert_eq!(s.ema_reduction_vs_per_gemm(), 0.0);
        assert_eq!(s.padding_fraction(), 0.0);
        assert_eq!(s.link_words, 0);
        assert!(s.per_device_ema_words.is_empty());
        assert_eq!(s.decode_reduction_vs_per_gemm(), 0.0);
        assert_eq!(s.decode_per_token_ema(), 0.0);
    }

    #[test]
    fn decode_batches_accumulate_their_own_lane() {
        use crate::coordinator::decisions::decode_plan_for_bucket;
        let m = Metrics::new();
        let step = decode_plan_for_bucket(
            4,
            96,
            128,
            512,
            0,
            4,
            2,
            &Tiling::square(16),
            256 * 1024,
        );
        m.record_decode_batch(4, &step);
        m.record_decode_batch(4, &step);
        let s = m.snapshot();
        assert_eq!(s.decode_batches, 2);
        assert_eq!(s.decode_tokens, 8);
        assert_eq!(s.ema_decode_words, 2 * step.total_ema());
        assert!(s.ema_decode_words <= s.ema_decode_baseline_words);
        assert!((0.0..=1.0).contains(&s.decode_reduction_vs_per_gemm()));
        assert!(s.decode_per_token_ema() > 0.0);
        // the prefill lane is untouched
        assert_eq!(s.batches, 0);
        assert_eq!(s.ema_plan_words, 0);
    }

    #[test]
    fn planner_cache_counters_surface_in_the_snapshot() {
        use crate::coordinator::decisions::DispatchPlanner;
        let m = Metrics::new();
        assert_eq!(m.snapshot().planner_cache.misses, 0);
        let mut planner =
            DispatchPlanner::new(128, 512, 0, 2, 2, Tiling::square(16), 64 * 1024, 1);
        planner.plan_dispatch(Some(64), None);
        planner.plan_dispatch(Some(64), None);
        m.record_planner_cache(planner.cache_stats());
        let s = m.snapshot();
        assert_eq!(s.planner_cache.misses, 1);
        assert_eq!(s.planner_cache.hits, 1);
        assert_eq!(s.planner_cache.entries, 1);
        // counters are cumulative on the planner: re-recording replaces
        planner.plan_dispatch(Some(128), None);
        m.record_planner_cache(planner.cache_stats());
        assert_eq!(m.snapshot().planner_cache.misses, 2);
    }

    #[test]
    fn sharded_batches_report_per_device_and_link_words() {
        use crate::coordinator::decisions::sharded_layer_plan_for_bucket;
        let m = Metrics::new();
        let plan = sharded_layer_plan_for_bucket(
            256,
            128,
            512,
            0,
            2,
            &Tiling::square(16),
            256 * 1024,
            2,
        );
        m.record_batch(
            1,
            200,
            56,
            Duration::from_millis(2),
            &gemms(),
            &Tiling::square(16),
            &plan,
            100,
        );
        let s = m.snapshot();
        assert_eq!(s.per_device_ema_words.len(), plan.devices() as usize);
        assert_eq!(
            s.per_device_ema_words.iter().sum::<u64>(),
            s.ema_plan_words
        );
        assert_eq!(s.link_words, plan.handoff_words());
    }
}
