//! The TAS decision engine — the paper's §III-A rule applied per request
//! bucket, at the coordinator level.
//!
//! For a bucket of `M = batch × seq` tokens and a projection with output
//! width `K`, choose input-stationary iff `M < K` (`N(M−K) < 0`).  The
//! compile path (`python/compile/model.py::scheme_plan`) made the same
//! decision when lowering each artifact; [`verify_against_manifest`]
//! asserts the two implementations agree — a cross-language contract
//! test run at coordinator startup.
//!
//! On top of the per-projection rule, [`layer_plan_for_bucket`] builds the
//! layer-level plan ([`crate::dataflow::LayerPlan`]) for a bucket: the
//! block's GEMM chain with SRAM residency and per-tile stationary
//! decisions.  The coordinator accounts every dispatched batch against
//! both (the per-GEMM rule is the compile-path contract; the layer plan is
//! what the accelerator-side accounting reports as achievable EMA).

use crate::arch::backend::BackendKind;
use crate::arch::Interconnect;
use crate::config::AcceleratorConfig;
use crate::dataflow::decode::decode_step_stages;
use crate::dataflow::search::{
    search_lane_split, search_stages, LaneSplitOutcome, PlanDb, SearchCtx, SearchStats,
    StagesOutcome,
};
use crate::dataflow::{DecodeDims, DecodePlan, DecodeStepPlan, LayerPlan, Scheme, StageSpec};
use crate::gemm::{GemmShape, Tiling};
use crate::runtime::Manifest;
use anyhow::Result;
use std::collections::BTreeMap;

/// Scheme choice per linear projection of the served model.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SchemePlan {
    pub tokens: u64,
    /// projection name -> resolved scheme.
    pub choices: BTreeMap<&'static str, Scheme>,
}

/// Apply the TAS rule to every projection of a model with the given dims.
pub fn scheme_plan(tokens: u64, hidden: u64, ffn: u64, vocab: u64) -> SchemePlan {
    let pick = |k: u64| {
        if tokens < k {
            Scheme::IsOs
        } else {
            Scheme::WsOs
        }
    };
    let mut choices = BTreeMap::new();
    choices.insert("qkv", pick(hidden));
    choices.insert("attn_out", pick(hidden));
    choices.insert("ffn1", pick(ffn));
    choices.insert("ffn2", pick(hidden));
    choices.insert("lm_head", pick(vocab));
    SchemePlan { tokens, choices }
}

/// The chained stage list of one served block, from raw manifest dims —
/// the coordinator-side twin of [`crate::models::ModelSpec::block_stages`].
pub fn bucket_stages(
    tokens: u64,
    hidden: u64,
    ffn: u64,
    vocab: u64,
    n_layers: u64,
) -> Vec<StageSpec> {
    let stage = |name, shape, count, consumes, shares| StageSpec {
        name,
        shape,
        count,
        consumes_previous: consumes,
        shares_input_with_previous: shares,
        cache: None,
    };
    let mut v = vec![
        stage("q", GemmShape::new(tokens, hidden, hidden), n_layers, false, false),
        stage("k", GemmShape::new(tokens, hidden, hidden), n_layers, false, true),
        stage("v", GemmShape::new(tokens, hidden, hidden), n_layers, false, true),
        stage("attn_out", GemmShape::new(tokens, hidden, hidden), n_layers, false, false),
        stage("ffn1", GemmShape::new(tokens, hidden, ffn), n_layers, true, false),
        stage("ffn2", GemmShape::new(tokens, ffn, hidden), n_layers, true, false),
    ];
    if vocab > 0 {
        v.push(stage("lm_head", GemmShape::new(tokens, hidden, vocab), 1, false, false));
    }
    v
}

/// Layer-level plan for one (batch × seq) bucket: per-tile TAS with SRAM
/// residency across the block's chained GEMMs.
pub fn layer_plan_for_bucket(
    tokens: u64,
    hidden: u64,
    ffn: u64,
    vocab: u64,
    n_layers: u64,
    tiling: &Tiling,
    sram_words: u64,
) -> LayerPlan {
    LayerPlan::plan(
        bucket_stages(tokens, hidden, ffn, vocab, n_layers),
        tokens,
        tiling,
        sram_words,
    )
}

/// Floor on per-device work when widening a bucket across accelerators:
/// below this many tokens a device's GEMM slices are too small for the
/// strip planner to amortise anything and link latency dominates.
pub const MIN_TOKENS_PER_DEVICE: u64 = 64;

/// Device-aware bucket decision: how many of the `max_devices` chips a
/// bucket of `tokens` tokens should span.  Powers of two, each device
/// keeping at least [`MIN_TOKENS_PER_DEVICE`] tokens of work.
pub fn devices_for_bucket(tokens: u64, max_devices: u64) -> u64 {
    let max = max_devices.max(1);
    let mut d = 1u64;
    while d * 2 <= max && tokens / (d * 2) >= MIN_TOKENS_PER_DEVICE {
        d *= 2;
    }
    d
}

/// Layer-level plan for a bucket placed across `devices` accelerators:
/// stages are balanced by MAC count ([`crate::dataflow::place_stages`])
/// and residency only chains stages sharing a device — the cross-device
/// activations surface as [`LayerPlan::handoff_words`] link traffic.
#[allow(clippy::too_many_arguments)]
pub fn sharded_layer_plan_for_bucket(
    tokens: u64,
    hidden: u64,
    ffn: u64,
    vocab: u64,
    n_layers: u64,
    tiling: &Tiling,
    sram_words: u64,
    devices: u64,
) -> LayerPlan {
    let stages = bucket_stages(tokens, hidden, ffn, vocab, n_layers);
    let placement = crate::dataflow::place_stages(&stages, devices);
    LayerPlan::plan_placed(stages, tokens, tiling, sram_words, placement)
}

/// Decode dims from raw manifest model entries.  `heads` defaults to one
/// head per 64 hidden lanes when the manifest predates the field, walked
/// down to the nearest divisor of `hidden` (1 always qualifies) so the
/// repaired dims can never trip the `hidden % heads == 0` invariant.
pub fn decode_dims(hidden: u64, ffn: u64, vocab: u64, n_layers: u64, heads: u64) -> DecodeDims {
    let heads = if heads > 0 && hidden % heads == 0 {
        heads
    } else {
        let mut h = (hidden / 64).max(1);
        while hidden % h != 0 {
            h -= 1;
        }
        h
    };
    DecodeDims { hidden, ffn, layers: n_layers.max(1), heads, vocab }
}

/// Decode-bucket plan: one steady-state autoregressive step for `batch`
/// in-flight sequences at `cache_len` cache positions, with cache rows
/// SRAM-resident under the budget ([`DecodePlan::plan_step`]).
#[allow(clippy::too_many_arguments)]
pub fn decode_plan_for_bucket(
    batch: u64,
    cache_len: u64,
    hidden: u64,
    ffn: u64,
    vocab: u64,
    n_layers: u64,
    heads: u64,
    tiling: &Tiling,
    sram_words: u64,
) -> DecodeStepPlan {
    DecodePlan::plan_step(
        &decode_dims(hidden, ffn, vocab, n_layers, heads),
        batch,
        cache_len,
        tiling,
        sram_words,
    )
}

/// One continuous-batching bucket plan: a prefill chunk and a decode step
/// priced together.  When both phases share the dispatch, the SRAM is
/// split between the prefill residency chain and the decode cache by
/// **marginal EMA**: both lanes are residency-aware planners, so the
/// split is searched over a fraction grid (always including the even
/// split, so the searched split never loses to the legacy 50/50) and the
/// cheapest total wins — neither planner may claim words the other holds.
#[derive(Clone, Debug)]
pub struct MixedBucketPlan {
    pub prefill: Option<LayerPlan>,
    pub decode: Option<DecodeStepPlan>,
    /// SRAM words granted to the prefill lane (the decode lane gets the
    /// complement; meaningful only for mixed dispatches).
    pub prefill_sram_words: u64,
}

impl MixedBucketPlan {
    /// DRAM words of the whole mixed dispatch.
    pub fn total_ema(&self) -> u64 {
        self.prefill.as_ref().map(|p| p.total_ema()).unwrap_or(0)
            + self.decode.as_ref().map(|d| d.total_ema()).unwrap_or(0)
    }

    /// The per-GEMM TAS baseline for the same dispatch.
    pub fn per_gemm_tas_total(&self) -> u64 {
        self.prefill
            .as_ref()
            .map(|p| p.per_gemm_tas_total())
            .unwrap_or(0)
            + self
                .decode
                .as_ref()
                .map(|d| d.per_gemm_tas_total())
                .unwrap_or(0)
    }

    pub fn reduction_vs_per_gemm(&self) -> f64 {
        let base = self.per_gemm_tas_total();
        if base == 0 {
            0.0
        } else {
            1.0 - self.total_ema() as f64 / base as f64
        }
    }
}

/// Plan a mixed prefill+decode bucket.  `prefill_tokens` is the padded
/// token count of the prefill half (None = decode-only dispatch);
/// `decode` is `(batch, cache_len)` of the decode half (None =
/// prefill-only — the classic bucket plan); `devices` is the accelerator
/// count the prefill lane spans ([`devices_for_bucket`]; 1 keeps the
/// single-chip plan, and the decode lane is single-device either way).
///
/// When both halves are present the SRAM split between the lanes is
/// chosen by marginal EMA over an eighth-fraction grid — the discrete
/// form of the residency allocator's greedy, applied at lane
/// granularity.  The even split is always a grid point, so the searched
/// split never loses to the old fixed 50/50.
#[allow(clippy::too_many_arguments)]
pub fn mixed_bucket_plan(
    prefill_tokens: Option<u64>,
    decode: Option<(u64, u64)>,
    hidden: u64,
    ffn: u64,
    vocab: u64,
    n_layers: u64,
    heads: u64,
    tiling: &Tiling,
    sram_words: u64,
    devices: u64,
) -> MixedBucketPlan {
    mixed_bucket_plan_grid(
        &[1, 2, 3, 4, 5, 6, 7],
        prefill_tokens,
        decode,
        hidden,
        ffn,
        vocab,
        n_layers,
        heads,
        tiling,
        sram_words,
        devices,
    )
}

/// [`mixed_bucket_plan`] over an explicit eighths grid.  The dispatch
/// planner passes the cycle-optimal subset of the grid from the joint
/// lane-split search ([`crate::dataflow::search::search_lane_split`]);
/// standalone callers pass the full `1..=7` grid.  The pick walks the
/// grid in the given order with a strict `<`, so the lowest listed
/// eighths wins EMA ties — list grid points in ascending order to keep
/// the scan's deterministic answer.
#[allow(clippy::too_many_arguments)]
pub fn mixed_bucket_plan_grid(
    eighths_grid: &[u64],
    prefill_tokens: Option<u64>,
    decode: Option<(u64, u64)>,
    hidden: u64,
    ffn: u64,
    vocab: u64,
    n_layers: u64,
    heads: u64,
    tiling: &Tiling,
    sram_words: u64,
    devices: u64,
) -> MixedBucketPlan {
    let plan_prefill = |tokens: u64, sram: u64| {
        sharded_layer_plan_for_bucket(
            tokens, hidden, ffn, vocab, n_layers, tiling, sram, devices,
        )
    };
    let plan_decode = |batch: u64, cache_len: u64, sram: u64| {
        decode_plan_for_bucket(
            batch, cache_len, hidden, ffn, vocab, n_layers, heads, tiling, sram,
        )
    };
    match (prefill_tokens, decode) {
        (Some(tokens), Some((batch, cache_len))) => {
            // Each grid point plans a full prefill chain plus a decode
            // step and the points are independent, so score all seven
            // lane splits concurrently.  The pick below walks the joined
            // results in grid order with a strict `<`, which keeps the
            // lowest eighths on ties — exactly the sequential loop's
            // deterministic answer.
            let candidates = std::thread::scope(|scope| {
                let handles: Vec<_> = eighths_grid
                    .iter()
                    .map(|&eighths| {
                        let (plan_prefill, plan_decode) = (&plan_prefill, &plan_decode);
                        scope.spawn(move || {
                            let prefill_sram = sram_words * eighths / 8;
                            let p = plan_prefill(tokens, prefill_sram);
                            let d = plan_decode(batch, cache_len, sram_words - prefill_sram);
                            MixedBucketPlan {
                                prefill: Some(p),
                                decode: Some(d),
                                prefill_sram_words: prefill_sram,
                            }
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("lane-split worker panicked"))
                    .collect::<Vec<_>>()
            });
            let mut best: Option<MixedBucketPlan> = None;
            for cand in candidates {
                let better = best
                    .as_ref()
                    .map(|b| cand.total_ema() < b.total_ema())
                    .unwrap_or(true);
                if better {
                    best = Some(cand);
                }
            }
            best.expect("grid is non-empty")
        }
        (prefill_tokens, decode) => MixedBucketPlan {
            prefill: prefill_tokens.map(|tokens| plan_prefill(tokens, sram_words)),
            decode: decode.map(|(batch, cache_len)| plan_decode(batch, cache_len, sram_words)),
            prefill_sram_words: if prefill_tokens.is_some() { sram_words } else { 0 },
        },
    }
}

/// Default entry cap per planner memo cache ([`PlanCache`]).  A serving
/// run sees a handful of padded buckets per lane, so 64 joint keys is
/// generous; the cap exists to bound the resident plan memory when a
/// workload's cache-length buckets churn (every decode step can shift
/// the `(slots, cache bucket)` key).
pub const PLAN_CACHE_CAP: usize = 64;

/// Hit/miss/evict counters of the planner's bounded memo caches, summed
/// across the three lanes and surfaced in the coordinator metrics
/// ([`crate::coordinator::metrics::MetricsSnapshot`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlannerCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Entries currently resident across the three caches.
    pub entries: u64,
}

/// A bounded memo: ordered map storage plus an LRU clock.  Eviction runs
/// *before* insertion because [`PlanCache::get_or_insert_with`] hands out
/// a borrow of the inserted value — the planner's `plan_dispatch` returns
/// plans by reference, so a post-insert sweep could invalidate the entry
/// it just promised.
struct PlanCache<K: Ord + Clone, V> {
    map: BTreeMap<K, (u64, V)>,
    cap: usize,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl<K: Ord + Clone, V> PlanCache<K, V> {
    fn new(cap: usize) -> PlanCache<K, V> {
        PlanCache {
            map: BTreeMap::new(),
            cap: cap.max(1),
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    fn contains(&self, key: &K) -> bool {
        self.map.contains_key(key)
    }

    fn get_or_insert_with(&mut self, key: K, build: impl FnOnce() -> V) -> &V {
        self.tick += 1;
        if self.map.contains_key(&key) {
            self.hits += 1;
        } else {
            self.misses += 1;
            if self.map.len() >= self.cap {
                let stalest = self
                    .map
                    .iter()
                    .min_by_key(|(_, (stamp, _))| *stamp)
                    .map(|(k, _)| k.clone())
                    .expect("cap >= 1, so a full cache has an entry");
                self.map.remove(&stalest);
                self.evictions += 1;
            }
        }
        let entry = self.map.entry(key).or_insert_with(|| (0, build()));
        entry.0 = self.tick;
        &entry.1
    }
}

/// The device loop's plan memo: layer / decode-step / mixed plans keyed
/// by the **joint** dispatch.
///
/// The seed device loop keyed its two caches on one lane's bucket alone
/// (`(tokens, mixed)` / `(slots, cache bucket, mixed)`) and hard-coded
/// the even SRAM split for mixed dispatches, so the lane split
/// [`mixed_bucket_plan`] searches never reached the served metrics — a
/// planner/executor divergence.  Here a mixed dispatch resolves through
/// the searched joint plan, memoised on `(prefill bucket, decode slots,
/// decode cache bucket)`; the granted split is a deterministic function
/// of that key, so the cache can never hand one joint dispatch another
/// dispatch's split.  Single-lane dispatches keep the whole SRAM.
///
/// The split itself comes from the joint lane-split search
/// ([`crate::dataflow::search::search_lane_split`], database-memoized
/// under backend-tagged specs): the full residency-aware plans are
/// built only at the cycle-optimal eighths, and the EMA scan breaks
/// the ties the coarse cycle model leaves.
///
/// The caches are bounded ([`PLAN_CACHE_CAP`] entries each, LRU
/// eviction) and counted ([`DispatchPlanner::cache_stats`]); known
/// dispatch keys can be planned ahead of serving with
/// [`DispatchPlanner::warm_up`], which fans the misses out across
/// scoped worker threads.
pub struct DispatchPlanner {
    hidden: u64,
    ffn: u64,
    vocab: u64,
    n_layers: u64,
    heads: u64,
    tiling: Tiling,
    sram_words: u64,
    max_devices: u64,
    prefill_cache: PlanCache<u64, LayerPlan>,
    decode_cache: PlanCache<(u64, u64), DecodeStepPlan>,
    mixed_cache: PlanCache<(u64, u64, u64), MixedBucketPlan>,
    /// Hardware model the joint search prices overlapped latency on.
    cfg: AcceleratorConfig,
    icx: Interconnect,
    /// Backend the searches price covers under; spec keys carry it, so
    /// one persisted database never serves another hardware model's
    /// plans ([`crate::dataflow::search::GemmSpec::canonical_on`]).
    backend: BackendKind,
    /// Memoized joint-search database ([`crate::dataflow::search`]):
    /// misses run the (cover × axis × residency) search, hits replan for
    /// free.  Persisted across restarts by the server boot path.
    plan_db: PlanDb,
}

/// One dispatch's resolved plans, borrowed from the planner's memo.
#[derive(Clone, Copy)]
pub enum PlannedDispatch<'a> {
    /// Joint mixed plan carrying the searched SRAM lane split.
    Mixed(&'a MixedBucketPlan),
    /// Prefill-only dispatch: the bucket's layer plan, whole SRAM.
    Prefill(&'a LayerPlan),
    /// Decode-only dispatch: the step plan, whole SRAM.
    Decode(&'a DecodeStepPlan),
    /// Nothing to run.
    Empty,
}

impl<'a> PlannedDispatch<'a> {
    pub fn prefill(&self) -> Option<&'a LayerPlan> {
        match *self {
            PlannedDispatch::Mixed(m) => m.prefill.as_ref(),
            PlannedDispatch::Prefill(p) => Some(p),
            _ => None,
        }
    }

    pub fn decode(&self) -> Option<&'a DecodeStepPlan> {
        match *self {
            PlannedDispatch::Mixed(m) => m.decode.as_ref(),
            PlannedDispatch::Decode(d) => Some(d),
            _ => None,
        }
    }

    /// The mixed joint plan, when this dispatch carried both lanes.
    pub fn mixed(&self) -> Option<&'a MixedBucketPlan> {
        match *self {
            PlannedDispatch::Mixed(m) => Some(m),
            _ => None,
        }
    }
}

impl DispatchPlanner {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        hidden: u64,
        ffn: u64,
        vocab: u64,
        n_layers: u64,
        heads: u64,
        tiling: Tiling,
        sram_words: u64,
        max_devices: u64,
    ) -> DispatchPlanner {
        DispatchPlanner {
            hidden,
            ffn,
            vocab,
            n_layers,
            heads,
            tiling,
            sram_words,
            max_devices,
            prefill_cache: PlanCache::new(PLAN_CACHE_CAP),
            decode_cache: PlanCache::new(PLAN_CACHE_CAP),
            mixed_cache: PlanCache::new(PLAN_CACHE_CAP),
            cfg: AcceleratorConfig::default(),
            icx: Interconnect::default(),
            backend: BackendKind::Systolic,
            plan_db: PlanDb::default(),
        }
    }

    /// Retarget the planner's searches at another hardware model: covers
    /// are priced under the backend's operand costs
    /// ([`BackendKind::pricing`]), cycle pricing runs on its derived
    /// accelerator config, and every database key is tagged with it.
    pub fn with_backend(mut self, backend: BackendKind) -> DispatchPlanner {
        self.backend = backend;
        self.cfg = match backend {
            BackendKind::Systolic => AcceleratorConfig::default(),
            BackendKind::Crossbar => {
                crate::arch::backend::CrossbarConfig::default().accel()
            }
        };
        self
    }

    /// Install a (typically persisted) joint-search database.  Called by
    /// the server boot path before [`DispatchPlanner::warm_up`], so a
    /// reloaded database serves the manifest's buckets with zero new
    /// searches.
    pub fn with_plan_db(mut self, db: PlanDb) -> DispatchPlanner {
        self.plan_db = db;
        self
    }

    /// The joint-search database (for persistence and inspection).
    pub fn plan_db(&self) -> &PlanDb {
        &self.plan_db
    }

    /// Cumulative joint-search counters (searches, database hits/misses,
    /// evictions, entries, beam-pruned candidates).
    pub fn search_stats(&self) -> SearchStats {
        self.plan_db.stats()
    }

    /// Resolve a prefill bucket's stage chain through the joint search
    /// ([`crate::dataflow::search::search_stages`]).  A cold database
    /// prices the candidate grid once per canonical GEMM spec; a warm
    /// one answers from exact-shape hits without pricing anything, so
    /// per-dispatch replanning is effectively free.
    pub fn search_bucket(&mut self, prefill_tokens: u64) -> StagesOutcome {
        let stages = bucket_stages(
            prefill_tokens,
            self.hidden,
            self.ffn,
            self.vocab,
            self.n_layers,
        );
        let ctx = SearchCtx {
            tiling: self.tiling,
            sram_words: self.sram_words,
            devices: devices_for_bucket(prefill_tokens, self.max_devices),
            cfg: &self.cfg,
            icx: &self.icx,
            backend: self.backend,
        };
        search_stages(&stages, ctx, &mut self.plan_db)
    }

    /// Joint lane-split search for a mixed dispatch, through the
    /// database ([`crate::dataflow::search::search_lane_split`]): both
    /// lane chains priced at every eighths split of the SRAM budget.
    pub fn search_mixed_split(
        &mut self,
        prefill_tokens: u64,
        slots: u64,
        cache_bucket: u64,
    ) -> LaneSplitOutcome {
        let prefill = bucket_stages(
            prefill_tokens,
            self.hidden,
            self.ffn,
            self.vocab,
            self.n_layers,
        );
        let dims =
            decode_dims(self.hidden, self.ffn, self.vocab, self.n_layers, self.heads);
        let decode = decode_step_stages(&dims, slots, cache_bucket);
        let ctx = SearchCtx {
            tiling: self.tiling,
            sram_words: self.sram_words,
            devices: devices_for_bucket(prefill_tokens, self.max_devices),
            cfg: &self.cfg,
            icx: &self.icx,
            backend: self.backend,
        };
        search_lane_split(&prefill, &decode, ctx, &mut self.plan_db)
    }

    /// The cycle-optimal eighths grid for a mixed dispatch: the subset
    /// of prefill SRAM shares whose searched lane total ties the
    /// minimum, ascending.  The served split is then chosen by the
    /// full-plan EMA scan *restricted to this set* — the searched split
    /// drives serving, and the residency-aware planners only break the
    /// ties the coarse cycle model cannot see (the per-GEMM search is
    /// SRAM-independent, so splits often tie; the knapsack's chained
    /// edges are what separates them).
    fn mixed_eighths_grid(
        &mut self,
        prefill_tokens: u64,
        slots: u64,
        cache_bucket: u64,
    ) -> Vec<u64> {
        let lane = self.search_mixed_split(prefill_tokens, slots, cache_bucket);
        let min = lane
            .grid_cycles
            .iter()
            .copied()
            .min()
            .expect("eighths grid is non-empty");
        (1..=7u64)
            .filter(|f| lane.grid_cycles[(f - 1) as usize] == min)
            .collect()
    }

    /// Build the joint plan a mixed dispatch serves: lane split searched
    /// through the database, full residency-aware plans at the searched
    /// split(s).
    fn searched_mixed_plan(
        &mut self,
        prefill_tokens: u64,
        slots: u64,
        cache_bucket: u64,
    ) -> MixedBucketPlan {
        let grid = self.mixed_eighths_grid(prefill_tokens, slots, cache_bucket);
        mixed_bucket_plan_grid(
            &grid,
            Some(prefill_tokens),
            Some((slots, cache_bucket)),
            self.hidden,
            self.ffn,
            self.vocab,
            self.n_layers,
            self.heads,
            &self.tiling,
            self.sram_words,
            devices_for_bucket(prefill_tokens, self.max_devices),
        )
    }

    /// Override the per-cache entry cap (tests use tiny caps to exercise
    /// eviction; [`PLAN_CACHE_CAP`] otherwise).
    pub fn with_cache_cap(mut self, cap: usize) -> DispatchPlanner {
        self.prefill_cache = PlanCache::new(cap);
        self.decode_cache = PlanCache::new(cap);
        self.mixed_cache = PlanCache::new(cap);
        self
    }

    /// Cumulative hit/miss/evict counters summed over the three caches.
    pub fn cache_stats(&self) -> PlannerCacheStats {
        let caches = [
            (
                self.prefill_cache.hits,
                self.prefill_cache.misses,
                self.prefill_cache.evictions,
                self.prefill_cache.map.len(),
            ),
            (
                self.decode_cache.hits,
                self.decode_cache.misses,
                self.decode_cache.evictions,
                self.decode_cache.map.len(),
            ),
            (
                self.mixed_cache.hits,
                self.mixed_cache.misses,
                self.mixed_cache.evictions,
                self.mixed_cache.map.len(),
            ),
        ];
        let mut s = PlannerCacheStats::default();
        for (h, m, e, n) in caches {
            s.hits += h;
            s.misses += m;
            s.evictions += e;
            s.entries += n as u64;
        }
        s
    }

    /// Plan a batch of dispatch keys ahead of serving.  Keys not yet
    /// cached are planned concurrently in scoped worker threads (each
    /// plan is independent), then inserted in key order — so a warmed
    /// planner answers its first dispatches from cache, and the plans are
    /// byte-identical to what the lazy path would have built.
    pub fn warm_up(&mut self, dispatches: &[(Option<u64>, Option<(u64, u64)>)]) {
        let (hidden, ffn, vocab, n_layers, heads) =
            (self.hidden, self.ffn, self.vocab, self.n_layers, self.heads);
        let (tiling, sram_words, max_devices) =
            (self.tiling, self.sram_words, self.max_devices);
        enum Warmed {
            Prefill(u64, LayerPlan),
            Decode((u64, u64), DecodeStepPlan),
            Mixed((u64, u64, u64), MixedBucketPlan),
        }
        let mut todo: Vec<(Option<u64>, Option<(u64, u64)>)> = Vec::new();
        for &key in dispatches {
            let missing = match key {
                (Some(tokens), Some((slots, cache))) => {
                    !self.mixed_cache.contains(&(tokens, slots, cache))
                }
                (Some(tokens), None) => !self.prefill_cache.contains(&tokens),
                (None, Some(decode)) => !self.decode_cache.contains(&decode),
                (None, None) => false,
            };
            if missing && !todo.contains(&key) {
                todo.push(key);
            }
        }
        // Resolve the mixed keys' lane-split searches up front: they
        // share the database (mutably), so they run sequentially here —
        // cheap, since splits in the same SRAM class share every
        // per-GEMM entry — and the workers below get plain grids.
        let mixed_keys: Vec<(u64, u64, u64)> = todo
            .iter()
            .filter_map(|&key| match key {
                (Some(tokens), Some((slots, cache))) => Some((tokens, slots, cache)),
                _ => None,
            })
            .collect();
        let mut mixed_grids: Vec<((u64, u64, u64), Vec<u64>)> = Vec::new();
        for (tokens, slots, cache) in mixed_keys {
            let grid = self.mixed_eighths_grid(tokens, slots, cache);
            mixed_grids.push(((tokens, slots, cache), grid));
        }
        let mixed_grids = &mixed_grids;
        let warmed = std::thread::scope(|scope| {
            let handles: Vec<_> = todo
                .iter()
                .map(|&key| {
                    scope.spawn(move || match key {
                        (Some(tokens), Some((slots, cache))) => {
                            let grid = mixed_grids
                                .iter()
                                .find(|(k, _)| *k == (tokens, slots, cache))
                                .map(|(_, g)| g.as_slice())
                                .expect("mixed keys resolved their grids above");
                            Warmed::Mixed(
                                (tokens, slots, cache),
                                mixed_bucket_plan_grid(
                                    grid,
                                    Some(tokens),
                                    Some((slots, cache)),
                                    hidden,
                                    ffn,
                                    vocab,
                                    n_layers,
                                    heads,
                                    &tiling,
                                    sram_words,
                                    devices_for_bucket(tokens, max_devices),
                                ),
                            )
                        }
                        (Some(tokens), None) => Warmed::Prefill(
                            tokens,
                            sharded_layer_plan_for_bucket(
                                tokens,
                                hidden,
                                ffn,
                                vocab,
                                n_layers,
                                &tiling,
                                sram_words,
                                devices_for_bucket(tokens, max_devices),
                            ),
                        ),
                        (None, Some((slots, cache))) => Warmed::Decode(
                            (slots, cache),
                            decode_plan_for_bucket(
                                slots, cache, hidden, ffn, vocab, n_layers, heads, &tiling,
                                sram_words,
                            ),
                        ),
                        (None, None) => unreachable!("empty dispatches are filtered"),
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("warm-up worker panicked"))
                .collect::<Vec<_>>()
        });
        for plan in warmed {
            match plan {
                Warmed::Prefill(key, p) => {
                    self.prefill_cache.get_or_insert_with(key, move || p);
                }
                Warmed::Decode(key, d) => {
                    self.decode_cache.get_or_insert_with(key, move || d);
                }
                Warmed::Mixed(key, m) => {
                    self.mixed_cache.get_or_insert_with(key, move || m);
                }
            }
        }
        // Warm the joint-search database too: every prefill bucket
        // resolves its stage chain once (the search parallelizes its own
        // candidate pricing), so a planner booted from a persisted
        // database answers with zero new searches.
        let mut seen: Vec<u64> = Vec::new();
        for &(prefill, _) in dispatches {
            if let Some(tokens) = prefill {
                if !seen.contains(&tokens) {
                    seen.push(tokens);
                    self.search_bucket(tokens);
                }
            }
        }
    }

    /// Resolve (and memoise) the plans for one dispatch.  `prefill_tokens`
    /// is the padded prefill bucket (batch × seq); `decode` is
    /// `(slots, cache-length bucket)`.
    pub fn plan_dispatch(
        &mut self,
        prefill_tokens: Option<u64>,
        decode: Option<(u64, u64)>,
    ) -> PlannedDispatch<'_> {
        // Keep the joint-search database in the loop on every prefill
        // dispatch: a warm database resolves the bucket from exact-shape
        // hits (no candidate pricing), a cold one searches once and
        // amortizes it across every congruent dispatch that follows.
        if let Some(tokens) = prefill_tokens {
            self.search_bucket(tokens);
        }
        let (hidden, ffn, vocab, n_layers, heads) =
            (self.hidden, self.ffn, self.vocab, self.n_layers, self.heads);
        let (tiling, sram_words, max_devices) =
            (self.tiling, self.sram_words, self.max_devices);
        match (prefill_tokens, decode) {
            (Some(tokens), Some((slots, cache_bucket))) => {
                // Mixed dispatches serve the *searched* lane split: the
                // joint lane-split search resolves the cycle-optimal
                // eighths through the database, the full plans are built
                // only at those splits.  The search runs before the memo
                // lookup (it needs the database mutably), but only for
                // keys the memo has not already resolved.
                let key = (tokens, slots, cache_bucket);
                let prebuilt = if self.mixed_cache.contains(&key) {
                    None
                } else {
                    Some(self.searched_mixed_plan(tokens, slots, cache_bucket))
                };
                let plan = self.mixed_cache.get_or_insert_with(key, move || {
                    prebuilt.expect("missing mixed keys are prebuilt above")
                });
                PlannedDispatch::Mixed(plan)
            }
            (Some(tokens), None) => {
                let devices = devices_for_bucket(tokens, max_devices);
                let plan = self.prefill_cache.get_or_insert_with(tokens, || {
                    sharded_layer_plan_for_bucket(
                        tokens, hidden, ffn, vocab, n_layers, &tiling, sram_words, devices,
                    )
                });
                PlannedDispatch::Prefill(plan)
            }
            (None, Some((slots, cache_bucket))) => {
                let plan = self
                    .decode_cache
                    .get_or_insert_with((slots, cache_bucket), || {
                        decode_plan_for_bucket(
                            slots,
                            cache_bucket,
                            hidden,
                            ffn,
                            vocab,
                            n_layers,
                            heads,
                            &tiling,
                            sram_words,
                        )
                    });
                PlannedDispatch::Decode(plan)
            }
            (None, None) => PlannedDispatch::Empty,
        }
    }
}

fn scheme_to_manifest_name(s: Scheme) -> &'static str {
    match s {
        Scheme::IsOs => "is_os",
        Scheme::WsOs => "ws_os",
        _ => unreachable!("TAS only resolves to the hybrids"),
    }
}

/// Assert that the rust rule reproduces the schemes the python compile
/// path recorded for every bert artifact in the manifest.
pub fn verify_against_manifest(manifest: &Manifest) -> Result<()> {
    let hidden = *manifest.model.get("hidden").unwrap_or(&0);
    let ffn = *manifest.model.get("ffn").unwrap_or(&0);
    let vocab = *manifest.model.get("vocab").unwrap_or(&0);
    anyhow::ensure!(
        hidden > 0 && ffn > 0 && vocab > 0,
        "manifest model dims missing"
    );
    for art in manifest.artifacts.iter().filter(|a| a.kind == "bert") {
        let tokens = art
            .tokens()
            .ok_or_else(|| anyhow::anyhow!("{}: no batch/seq", art.name))?;
        let plan = scheme_plan(tokens, hidden, ffn, vocab);
        for (proj, want) in &art.schemes {
            let got = plan
                .choices
                .get(proj.as_str())
                .ok_or_else(|| anyhow::anyhow!("{}: unknown projection '{proj}'", art.name))?;
            let got_name = scheme_to_manifest_name(*got);
            anyhow::ensure!(
                got_name == want,
                "{}: projection '{proj}': compile path chose {want}, \
                 coordinator rule chose {got_name} (M={tokens})",
                art.name
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_flips_per_projection() {
        // M=256 vs hidden=128 (WS), ffn=256 (WS: M >= K), vocab=512 (IS)
        let p = scheme_plan(256, 128, 256, 512);
        assert_eq!(p.choices["qkv"], Scheme::WsOs);
        assert_eq!(p.choices["ffn1"], Scheme::WsOs);
        assert_eq!(p.choices["lm_head"], Scheme::IsOs);
    }

    #[test]
    fn small_batches_prefer_input_stationary() {
        let p = scheme_plan(32, 256, 1024, 1024);
        assert!(p.choices.values().all(|s| *s == Scheme::IsOs));
    }

    #[test]
    fn bucket_layer_plan_never_loses_to_per_gemm_rule() {
        for tokens in [32u64, 256, 2048] {
            let plan = layer_plan_for_bucket(
                tokens,
                128,
                512,
                1024,
                4,
                &Tiling::square(16),
                256 * 1024,
            );
            assert!(plan.total_ema() <= plan.per_gemm_tas_total(), "M={tokens}");
        }
    }

    /// Cross-implementation contract (like `verify_against_manifest` for
    /// the per-GEMM rule): the coordinator's stage list from raw manifest
    /// dims must equal the model zoo's chained stage list, or the served
    /// `ema_plan_words` silently diverges from `tas plan`.
    #[test]
    fn bucket_stages_match_model_block_stages() {
        for m in crate::models::zoo::all_models() {
            for tokens in [64u64, 384] {
                let from_dims = bucket_stages(
                    tokens,
                    m.hidden,
                    m.ffn,
                    m.vocab.unwrap_or(0),
                    m.layers,
                );
                assert_eq!(from_dims, m.block_stages(tokens), "{}", m.name);
            }
        }
    }

    #[test]
    fn devices_scale_with_bucket_tokens() {
        assert_eq!(devices_for_bucket(32, 8), 1);
        assert_eq!(devices_for_bucket(128, 8), 2);
        assert_eq!(devices_for_bucket(512, 8), 8);
        assert_eq!(devices_for_bucket(512, 4), 4);
        assert_eq!(devices_for_bucket(4096, 1), 1);
        // never zero devices, even on degenerate input
        assert_eq!(devices_for_bucket(1, 0), 1);
    }

    #[test]
    fn sharded_bucket_plan_conserves_and_reports_handoffs() {
        let tiling = Tiling::square(16);
        let single = layer_plan_for_bucket(512, 128, 512, 0, 4, &tiling, 256 * 1024);
        let sharded =
            sharded_layer_plan_for_bucket(512, 128, 512, 0, 4, &tiling, 256 * 1024, 2);
        assert_eq!(sharded.devices(), 2);
        assert_eq!(
            sharded.per_device_ema().iter().sum::<u64>(),
            sharded.total_ema()
        );
        // a 1-device "shard" is the plain bucket plan
        let one = sharded_layer_plan_for_bucket(512, 128, 512, 0, 4, &tiling, 256 * 1024, 1);
        assert_eq!(one.total_ema(), single.total_ema());
        assert_eq!(one.handoff_words(), 0);
    }

    #[test]
    fn decode_bucket_plan_beats_per_gemm_rule() {
        let t = Tiling::square(16);
        for (batch, cache_len) in [(1u64, 65u64), (8, 96), (32, 512)] {
            let p = decode_plan_for_bucket(
                batch, cache_len, 128, 512, 0, 4, 2, &t, 256 * 1024,
            );
            assert!(
                p.total_ema() <= p.per_gemm_tas_total(),
                "batch {batch} cache {cache_len}"
            );
            assert_eq!(p.cache_len, cache_len);
        }
    }

    #[test]
    fn decode_dims_repairs_missing_heads() {
        // heads absent from an old manifest: derive from hidden
        let d = decode_dims(768, 3072, 0, 12, 0);
        assert_eq!(d.heads, 12);
        assert_eq!(d.head_dim(), 64);
        // heads that do not divide hidden are replaced, not trusted
        let d2 = decode_dims(768, 3072, 0, 12, 7);
        assert_eq!(d2.hidden % d2.heads, 0);
        // ... and the fallback itself is walked down to a divisor even
        // when hidden/64 does not divide hidden (1000/64 = 15 ∤ 1000)
        let d3 = decode_dims(1000, 4000, 0, 4, 0);
        assert_eq!(d3.hidden % d3.heads, 0);
        assert!(d3.heads >= 1);
    }

    #[test]
    fn mixed_bucket_plan_prices_both_phases() {
        let t = Tiling::square(16);
        let mixed = mixed_bucket_plan(
            Some(256),
            Some((4, 96)),
            128,
            512,
            0,
            4,
            2,
            &t,
            256 * 1024,
            1,
        );
        let prefill_only =
            mixed_bucket_plan(Some(256), None, 128, 512, 0, 4, 2, &t, 256 * 1024, 1);
        let decode_only =
            mixed_bucket_plan(None, Some((4, 96)), 128, 512, 0, 4, 2, &t, 256 * 1024, 1);
        assert!(mixed.prefill.is_some() && mixed.decode.is_some());
        assert!(mixed.total_ema() > 0);
        // each half never loses to the per-GEMM rule, so neither does the mix
        assert!(mixed.total_ema() <= mixed.per_gemm_tas_total());
        assert!(prefill_only.decode.is_none());
        assert!(decode_only.prefill.is_none());
        // halving the SRAM for the mix can only cost words, never gain
        assert!(
            mixed.total_ema()
                >= prefill_only.total_ema() + decode_only.total_ema()
        );
    }

    /// ISSUE-5 headline regression: on bert-base dims the searched lane
    /// split differs from even (the replica scan picks a 7/8 prefill
    /// share at every probed config) and strictly beats the even-split
    /// total the old device loop hard-coded.
    #[test]
    fn mixed_searched_split_beats_the_even_split() {
        let t = Tiling::square(16);
        let (hidden, ffn, vocab, layers, heads) = (768u64, 3072, 0, 12, 12);
        let sram = 256 * 1024u64;
        let mixed = mixed_bucket_plan(
            Some(384),
            Some((4, 64)),
            hidden,
            ffn,
            vocab,
            layers,
            heads,
            &t,
            sram,
            1,
        );
        assert_ne!(
            mixed.prefill_sram_words,
            sram / 2,
            "searched split must differ from even on this config"
        );
        // the old device-loop behaviour: even split, lanes planned apart
        let even_p = sharded_layer_plan_for_bucket(
            384, hidden, ffn, vocab, layers, &t, sram / 2, 1,
        );
        let even_d = decode_plan_for_bucket(
            4, 64, hidden, ffn, vocab, layers, heads, &t, sram - sram / 2,
        );
        let even_total = even_p.total_ema() + even_d.total_ema();
        assert!(
            mixed.total_ema() < even_total,
            "searched {} must strictly beat even {}",
            mixed.total_ema(),
            even_total
        );
    }

    /// The served metrics must see the searched plan: the device loop's
    /// planner resolves a mixed dispatch to `mixed_bucket_plan`'s joint
    /// plan, and recording those plans yields served EMA equal to the
    /// searched total — not the even-split total.
    #[test]
    fn dispatch_planner_serves_the_searched_lane_split() {
        use crate::coordinator::metrics::Metrics;
        use crate::models::GemmWorkload;
        use std::time::Duration;
        let t = Tiling::square(16);
        let (hidden, ffn, vocab, layers, heads) = (768u64, 3072, 0, 12, 12);
        let sram = 256 * 1024u64;
        let mut planner =
            DispatchPlanner::new(hidden, ffn, vocab, layers, heads, t, sram, 1);
        let metrics = Metrics::new();
        {
            let planned = planner.plan_dispatch(Some(384), Some((4, 64)));
            let step_plan = planned.decode().expect("mixed dispatch has a decode plan");
            let layer_plan = planned.prefill().expect("mixed dispatch has a layer plan");
            metrics.record_decode_batch(4, step_plan, Duration::from_millis(1));
            let gemms = vec![GemmWorkload {
                name: "qkv",
                shape: crate::gemm::GemmShape::new(384, hidden, hidden),
                count: 1,
            }];
            metrics.record_batch(
                1,
                384,
                0,
                Duration::from_millis(1),
                &gemms,
                &t,
                layer_plan,
                0,
            );
        }
        let snap = metrics.snapshot();
        let searched = mixed_bucket_plan(
            Some(384),
            Some((4, 64)),
            hidden,
            ffn,
            vocab,
            layers,
            heads,
            &t,
            sram,
            1,
        );
        assert_eq!(snap.ema_decode_words, searched.decode.as_ref().unwrap().total_ema());
        assert_eq!(snap.ema_plan_words, searched.prefill.as_ref().unwrap().total_ema());
        assert_eq!(
            snap.ema_plan_words + snap.ema_decode_words,
            searched.total_ema(),
            "served EMA must equal the searched plan's chosen total"
        );
        let even_p = sharded_layer_plan_for_bucket(
            384, hidden, ffn, vocab, layers, &t, sram / 2, 1,
        );
        let even_d = decode_plan_for_bucket(
            4, 64, hidden, ffn, vocab, layers, heads, &t, sram - sram / 2,
        );
        assert!(
            snap.ema_plan_words + snap.ema_decode_words
                < even_p.total_ema() + even_d.total_ema(),
            "served EMA must not be the even-split total"
        );
    }

    /// Satellite: served mixed dispatches use the searched lane split.
    /// On bert-base dims at 256 prefill tokens the ffn1 chained edge
    /// (256 × 768 = 196,608 words) fits the prefill lane's SRAM share
    /// only at 6/8 and 7/8 of the 256 KiW budget, so the lane-split
    /// search's cycle-optimal grid is exactly {6, 7} — the planner must
    /// serve one of those splits, never a cycle-suboptimal one.
    #[test]
    fn mixed_dispatch_serves_a_cycle_optimal_split_from_the_lane_search() {
        let t = Tiling::square(16);
        let sram = 256 * 1024u64;
        let mut planner = DispatchPlanner::new(768, 3072, 0, 12, 12, t, sram, 1);
        let lane = planner.search_mixed_split(256, 4, 64);
        let min = *lane.grid_cycles.iter().min().unwrap();
        assert!(
            lane.grid_cycles[..5].iter().all(|&c| c > min),
            "splits below 6/8 must be cycle-suboptimal here: {:?}",
            lane.grid_cycles
        );
        assert_eq!(lane.grid_cycles[5], min);
        assert_eq!(lane.grid_cycles[6], min);
        let served = {
            let p = planner.plan_dispatch(Some(256), Some((4, 64)));
            p.mixed().unwrap().prefill_sram_words
        };
        assert!(
            served == sram * 6 / 8 || served == sram * 7 / 8,
            "served split {served} is not one of the searched splits"
        );
        // The lane searches are memoized under canonical specs: a
        // dim-congruent prefill bucket (252 tokens, same tile-grid rows)
        // re-serves with zero new full searches.
        let before = planner.search_stats().searches;
        planner.plan_dispatch(Some(252), Some((4, 64)));
        assert_eq!(planner.search_stats().searches, before);
    }

    /// Backend-tagged memoization: two planners targeting different
    /// hardware models write disjoint spec keys into their databases.
    #[test]
    fn planner_tags_its_search_database_with_the_backend() {
        let t = Tiling::square(16);
        let sram = 64 * 1024u64;
        let mut sys = DispatchPlanner::new(128, 512, 0, 2, 2, t, sram, 1);
        let mut xbar = DispatchPlanner::new(128, 512, 0, 2, 2, t, sram, 1)
            .with_backend(BackendKind::Crossbar);
        sys.search_bucket(128);
        xbar.search_bucket(128);
        let sys_text = sys.plan_db().to_text();
        let xbar_text = xbar.plan_db().to_text();
        assert!(sys_text.contains(" systolic\n"));
        assert!(!sys_text.contains(" crossbar\n"));
        assert!(xbar_text.contains(" crossbar\n"));
        assert!(!xbar_text.contains(" systolic\n"));
    }

    #[test]
    fn dispatch_planner_keys_caches_on_the_joint_dispatch() {
        let t = Tiling::square(16);
        let sram = 256 * 1024u64;
        let mut planner = DispatchPlanner::new(768, 3072, 0, 12, 12, t, sram, 1);
        // same prefill bucket, two different decode halves: distinct
        // joint plans (the seed's (tokens, mixed) key conflated them)
        let small = {
            let p = planner.plan_dispatch(Some(256), Some((1, 64)));
            let m = p.mixed().unwrap();
            (m.prefill_sram_words, m.total_ema())
        };
        let big = {
            let p = planner.plan_dispatch(Some(256), Some((32, 256)));
            let m = p.mixed().unwrap();
            (m.prefill_sram_words, m.total_ema())
        };
        assert_ne!(small.1, big.1, "different decode halves, different plans");
        // memoised: the same joint dispatch returns the identical plan
        let again = {
            let p = planner.plan_dispatch(Some(256), Some((1, 64)));
            let m = p.mixed().unwrap();
            (m.prefill_sram_words, m.total_ema())
        };
        assert_eq!(small, again);
        // single-lane dispatches keep the whole SRAM (no halving)
        let solo = planner
            .plan_dispatch(Some(256), None)
            .prefill()
            .unwrap()
            .total_ema();
        let full =
            sharded_layer_plan_for_bucket(256, 768, 3072, 0, 12, &t, sram, 1).total_ema();
        assert_eq!(solo, full);
        assert!(planner.plan_dispatch(None, None).prefill().is_none());
        assert!(planner.plan_dispatch(None, Some((4, 64))).decode().is_some());
    }

    #[test]
    fn plan_cache_evicts_least_recently_used_and_counts() {
        let t = Tiling::square(16);
        let sram = 64 * 1024u64;
        let mut planner =
            DispatchPlanner::new(128, 512, 0, 2, 2, t, sram, 1).with_cache_cap(2);
        let ema = |planner: &mut DispatchPlanner, tokens| {
            planner
                .plan_dispatch(Some(tokens), None)
                .prefill()
                .unwrap()
                .total_ema()
        };
        let (a, b) = (ema(&mut planner, 64), ema(&mut planner, 128));
        assert_eq!(planner.cache_stats().misses, 2);
        assert_eq!(planner.cache_stats().entries, 2);
        // touch A so B becomes the LRU entry, then overflow the cap
        assert_eq!(ema(&mut planner, 64), a);
        assert_eq!(planner.cache_stats().hits, 1);
        ema(&mut planner, 256);
        let s = planner.cache_stats();
        assert_eq!(s.evictions, 1, "cap 2 + third key evicts one entry");
        assert_eq!(s.entries, 2, "cache stays at its cap");
        // A survived (recently used): hit.  B was evicted: miss, but the
        // rebuilt plan is identical — eviction never changes answers.
        assert_eq!(ema(&mut planner, 64), a);
        assert_eq!(planner.cache_stats().hits, 2);
        let miss_before = planner.cache_stats().misses;
        assert_eq!(ema(&mut planner, 128), b);
        assert_eq!(planner.cache_stats().misses, miss_before + 1);
    }

    #[test]
    fn warm_up_precomputes_the_dispatch_plans() {
        let t = Tiling::square(16);
        let sram = 64 * 1024u64;
        let mut warmed = DispatchPlanner::new(128, 512, 0, 2, 2, t, sram, 1);
        let mut lazy = DispatchPlanner::new(128, 512, 0, 2, 2, t, sram, 1);
        let dispatches = [
            (Some(128), None),
            (Some(128), Some((4u64, 64u64))),
            (None, Some((4, 64))),
            (None, None),          // filtered out
            (Some(128), None),     // duplicate, planned once
        ];
        warmed.warm_up(&dispatches);
        let s = warmed.cache_stats();
        assert_eq!(s.entries, 3, "one entry per distinct non-empty key");
        assert_eq!(s.misses, 3);
        assert_eq!(s.hits, 0);
        // the warmed planner serves from cache and matches the lazy path
        for key in [(Some(128), None), (None, Some((4, 64)))] {
            let w = warmed.plan_dispatch(key.0, key.1);
            let l = lazy.plan_dispatch(key.0, key.1);
            assert_eq!(
                w.prefill().map(|p| p.total_ema()),
                l.prefill().map(|p| p.total_ema())
            );
            assert_eq!(
                w.decode().map(|d| d.total_ema()),
                l.decode().map(|d| d.total_ema())
            );
        }
        assert_eq!(warmed.cache_stats().hits, 2, "warmed keys are cache hits");
        assert_eq!(warmed.cache_stats().misses, 3, "no new planning after warm-up");
    }

    #[test]
    fn bucket_stages_skip_head_without_vocab() {
        let with = bucket_stages(64, 128, 256, 512, 2);
        let without = bucket_stages(64, 128, 256, 0, 2);
        assert_eq!(with.len(), 7);
        assert_eq!(without.len(), 6);
        assert!(with.iter().any(|s| s.name == "lm_head"));
    }

    #[test]
    fn verify_catches_mismatch() {
        use crate::util::json::Json;
        // Manifest whose recorded scheme contradicts the rule (M=64 <
        // hidden=128 should be is_os, manifest says ws_os).
        let j = Json::parse(
            r#"{"version":1,"weights_bin":"w.bin",
                "model":{"hidden":128,"ffn":256,"vocab":512},
                "artifacts":[{"name":"bert_b2_s32","hlo":"x.hlo.txt",
                  "kind":"bert","batch":2,"seq":32,
                  "args":[],"outputs":[],
                  "schemes":{"qkv":"ws_os"},"flops":1}]}"#,
        )
        .unwrap();
        let m = Manifest::from_json(&j).unwrap();
        let err = verify_against_manifest(&m).unwrap_err().to_string();
        assert!(err.contains("qkv"), "{err}");
    }

    #[test]
    fn verify_accepts_consistent_manifest() {
        use crate::util::json::Json;
        let j = Json::parse(
            r#"{"version":1,"weights_bin":"w.bin",
                "model":{"hidden":128,"ffn":256,"vocab":512},
                "artifacts":[{"name":"bert_b2_s32","hlo":"x.hlo.txt",
                  "kind":"bert","batch":2,"seq":32,
                  "args":[],"outputs":[],
                  "schemes":{"qkv":"is_os","ffn1":"is_os","lm_head":"is_os"},
                  "flops":1}]}"#,
        )
        .unwrap();
        let m = Manifest::from_json(&j).unwrap();
        verify_against_manifest(&m).unwrap();
    }
}
