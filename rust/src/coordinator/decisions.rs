//! The TAS decision engine — the paper's §III-A rule applied per request
//! bucket, at the coordinator level.
//!
//! For a bucket of `M = batch × seq` tokens and a projection with output
//! width `K`, choose input-stationary iff `M < K` (`N(M−K) < 0`).  The
//! compile path (`python/compile/model.py::scheme_plan`) made the same
//! decision when lowering each artifact; [`verify_against_manifest`]
//! asserts the two implementations agree — a cross-language contract
//! test run at coordinator startup.

use crate::dataflow::Scheme;
use crate::runtime::Manifest;
use anyhow::Result;
use std::collections::BTreeMap;

/// Scheme choice per linear projection of the served model.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SchemePlan {
    pub tokens: u64,
    /// projection name -> resolved scheme.
    pub choices: BTreeMap<&'static str, Scheme>,
}

/// Apply the TAS rule to every projection of a model with the given dims.
pub fn scheme_plan(tokens: u64, hidden: u64, ffn: u64, vocab: u64) -> SchemePlan {
    let pick = |k: u64| {
        if tokens < k {
            Scheme::IsOs
        } else {
            Scheme::WsOs
        }
    };
    let mut choices = BTreeMap::new();
    choices.insert("qkv", pick(hidden));
    choices.insert("attn_out", pick(hidden));
    choices.insert("ffn1", pick(ffn));
    choices.insert("ffn2", pick(hidden));
    choices.insert("lm_head", pick(vocab));
    SchemePlan { tokens, choices }
}

fn scheme_to_manifest_name(s: Scheme) -> &'static str {
    match s {
        Scheme::IsOs => "is_os",
        Scheme::WsOs => "ws_os",
        _ => unreachable!("TAS only resolves to the hybrids"),
    }
}

/// Assert that the rust rule reproduces the schemes the python compile
/// path recorded for every bert artifact in the manifest.
pub fn verify_against_manifest(manifest: &Manifest) -> Result<()> {
    let hidden = *manifest.model.get("hidden").unwrap_or(&0);
    let ffn = *manifest.model.get("ffn").unwrap_or(&0);
    let vocab = *manifest.model.get("vocab").unwrap_or(&0);
    anyhow::ensure!(
        hidden > 0 && ffn > 0 && vocab > 0,
        "manifest model dims missing"
    );
    for art in manifest.artifacts.iter().filter(|a| a.kind == "bert") {
        let tokens = art
            .tokens()
            .ok_or_else(|| anyhow::anyhow!("{}: no batch/seq", art.name))?;
        let plan = scheme_plan(tokens, hidden, ffn, vocab);
        for (proj, want) in &art.schemes {
            let got = plan
                .choices
                .get(proj.as_str())
                .ok_or_else(|| anyhow::anyhow!("{}: unknown projection '{proj}'", art.name))?;
            let got_name = scheme_to_manifest_name(*got);
            anyhow::ensure!(
                got_name == want,
                "{}: projection '{proj}': compile path chose {want}, \
                 coordinator rule chose {got_name} (M={tokens})",
                art.name
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_flips_per_projection() {
        // M=256 vs hidden=128 (WS), ffn=256 (WS: M >= K), vocab=512 (IS)
        let p = scheme_plan(256, 128, 256, 512);
        assert_eq!(p.choices["qkv"], Scheme::WsOs);
        assert_eq!(p.choices["ffn1"], Scheme::WsOs);
        assert_eq!(p.choices["lm_head"], Scheme::IsOs);
    }

    #[test]
    fn small_batches_prefer_input_stationary() {
        let p = scheme_plan(32, 256, 1024, 1024);
        assert!(p.choices.values().all(|s| *s == Scheme::IsOs));
    }

    #[test]
    fn verify_catches_mismatch() {
        use crate::util::json::Json;
        // Manifest whose recorded scheme contradicts the rule (M=64 <
        // hidden=128 should be is_os, manifest says ws_os).
        let j = Json::parse(
            r#"{"version":1,"weights_bin":"w.bin",
                "model":{"hidden":128,"ffn":256,"vocab":512},
                "artifacts":[{"name":"bert_b2_s32","hlo":"x.hlo.txt",
                  "kind":"bert","batch":2,"seq":32,
                  "args":[],"outputs":[],
                  "schemes":{"qkv":"ws_os"},"flops":1}]}"#,
        )
        .unwrap();
        let m = Manifest::from_json(&j).unwrap();
        let err = verify_against_manifest(&m).unwrap_err().to_string();
        assert!(err.contains("qkv"), "{err}");
    }

    #[test]
    fn verify_accepts_consistent_manifest() {
        use crate::util::json::Json;
        let j = Json::parse(
            r#"{"version":1,"weights_bin":"w.bin",
                "model":{"hidden":128,"ffn":256,"vocab":512},
                "artifacts":[{"name":"bert_b2_s32","hlo":"x.hlo.txt",
                  "kind":"bert","batch":2,"seq":32,
                  "args":[],"outputs":[],
                  "schemes":{"qkv":"is_os","ffn1":"is_os","lm_head":"is_os"},
                  "flops":1}]}"#,
        )
        .unwrap();
        let m = Manifest::from_json(&j).unwrap();
        verify_against_manifest(&m).unwrap();
    }
}
