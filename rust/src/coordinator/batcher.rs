//! Length-aware request batcher.
//!
//! The AOT step compiles one executable per (batch, seq) bucket; the
//! batcher routes each request to the bucket with the smallest `seq ≥
//! len` (minimising padding — padding wastes exactly the EMA the paper
//! fights), accumulates per-seq queues, and flushes a batch when the
//! largest compiled batch size for that seq fills up or the oldest
//! request exceeds the linger deadline.
//!
//! Besides the prefill lane, the batcher carries a **decode lane**: each
//! in-flight autoregressive sequence is a [`DecodeSlot`] awaiting its
//! next single-token step.  Decode slots are always ready (every step is
//! on a request's latency path) and ride the same dispatch as a prefill
//! batch — continuous batching, planned as one mixed bucket by
//! [`super::decisions::mixed_bucket_plan`].

use super::request::Request;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// One compiled (batch, seq) bucket and its artifact name.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bucket {
    pub batch: u64,
    pub seq: u64,
    pub artifact: String,
}

/// A flushed batch: requests padded/stacked to a concrete bucket.
#[derive(Clone, Debug)]
pub struct Batch {
    pub bucket: Bucket,
    pub requests: Vec<Request>,
    pub formed: Instant,
}

impl Batch {
    /// Flattened `[batch, seq]` token-id tensor, zero-padded.
    pub fn padded_ids(&self) -> Vec<i32> {
        let (b, s) = (self.bucket.batch as usize, self.bucket.seq as usize);
        let mut ids = vec![0i32; b * s];
        for (row, req) in self.requests.iter().enumerate() {
            ids[row * s..row * s + req.len()].copy_from_slice(&req.tokens);
        }
        ids
    }

    /// Padding overhead: padded tokens / bucket capacity.
    pub fn padding_fraction(&self) -> f64 {
        let cap = (self.bucket.batch * self.bucket.seq) as f64;
        let used: usize = self.requests.iter().map(|r| r.len()).sum();
        1.0 - used as f64 / cap
    }
}

/// One in-flight autoregressive sequence awaiting its next decode step.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DecodeSlot {
    pub id: u64,
    /// Cache positions the next step attends (prompt + generated so far).
    pub cache_len: u64,
}

/// A mixed continuous-batching dispatch: at most one prefill batch plus
/// the decode slots that ride along with it.
#[derive(Clone, Debug)]
pub struct MixedBatch {
    pub prefill: Option<Batch>,
    pub decode: Vec<DecodeSlot>,
}

impl MixedBatch {
    /// Largest cache length among the decode slots (the decode bucket's
    /// planning length — shorter caches pad up to it).
    pub fn max_cache_len(&self) -> u64 {
        self.decode.iter().map(|s| s.cache_len).max().unwrap_or(0)
    }
}

/// The batcher: per-seq pending queues over a fixed bucket set.
#[derive(Debug)]
pub struct Batcher {
    /// seq -> batch sizes available (ascending), artifact per (b, s).
    by_seq: BTreeMap<u64, Vec<(u64, String)>>,
    pending: BTreeMap<u64, Vec<Request>>,
    /// In-flight sequences awaiting their next decode step (FIFO).
    decode_pending: Vec<DecodeSlot>,
    /// Flush a non-full batch once its oldest request waited this long.
    pub linger: Duration,
}

impl Batcher {
    /// Build from manifest buckets `(batch, seq, artifact)`.
    pub fn new(buckets: &[(u64, u64, String)], linger: Duration) -> anyhow::Result<Self> {
        anyhow::ensure!(!buckets.is_empty(), "no buckets");
        let mut by_seq: BTreeMap<u64, Vec<(u64, String)>> = BTreeMap::new();
        for (b, s, name) in buckets {
            by_seq.entry(*s).or_default().push((*b, name.clone()));
        }
        for v in by_seq.values_mut() {
            v.sort_by_key(|(b, _)| *b);
        }
        Ok(Batcher {
            by_seq,
            pending: BTreeMap::new(),
            decode_pending: Vec::new(),
            linger,
        })
    }

    /// Largest request length any bucket can serve.
    pub fn max_len(&self) -> u64 {
        *self.by_seq.keys().last().unwrap()
    }

    /// The seq bucket a request of `len` tokens routes to.
    pub fn route(&self, len: usize) -> anyhow::Result<u64> {
        self.by_seq
            .range(len as u64..)
            .next()
            .map(|(s, _)| *s)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "request of {len} tokens exceeds the largest bucket ({}); \
                     chunk it upstream",
                    self.max_len()
                )
            })
    }

    /// Enqueue a request; returns its seq bucket.
    pub fn push(&mut self, req: Request) -> anyhow::Result<u64> {
        let seq = self.route(req.len())?;
        self.pending.entry(seq).or_default().push(req);
        Ok(seq)
    }

    pub fn pending_count(&self) -> usize {
        self.pending.values().map(|v| v.len()).sum()
    }

    /// Enqueue an in-flight sequence for its next decode step.
    pub fn push_decode(&mut self, slot: DecodeSlot) {
        self.decode_pending.push(slot);
    }

    pub fn decode_pending_count(&self) -> usize {
        self.decode_pending.len()
    }

    /// Pop one mixed dispatch: a ready prefill batch (if any) plus up to
    /// `max_decode` decode slots.  Decode slots never linger — each one
    /// is a token on a request's latency path — so the pop is non-empty
    /// whenever either lane has ready work.
    pub fn pop_mixed_ready(&mut self, now: Instant, max_decode: usize) -> Option<MixedBatch> {
        let prefill = self.pop_ready(now);
        let take = self.decode_pending.len().min(max_decode);
        if prefill.is_none() && take == 0 {
            return None;
        }
        let decode: Vec<DecodeSlot> = self.decode_pending.drain(..take).collect();
        Some(MixedBatch { prefill, decode })
    }

    /// Pop at most one ready batch.  A seq queue is ready when it can
    /// fill its largest batch bucket, or its oldest request has lingered
    /// past the deadline (then the smallest sufficient bucket is used).
    pub fn pop_ready(&mut self, now: Instant) -> Option<Batch> {
        let linger = self.linger;
        let mut choice: Option<(u64, usize)> = None; // (seq, take)
        for (&seq, queue) in &self.pending {
            if queue.is_empty() {
                continue;
            }
            let sizes = &self.by_seq[&seq];
            let max_b = sizes.last().unwrap().0 as usize;
            if queue.len() >= max_b {
                choice = Some((seq, max_b));
                break;
            }
            let oldest = queue.first().unwrap().arrived;
            if now.duration_since(oldest) >= linger {
                choice = Some((seq, queue.len()));
                break;
            }
        }
        let (seq, take) = choice?;
        let queue = self.pending.get_mut(&seq).unwrap();
        let take = take.min(queue.len());
        let reqs: Vec<Request> = queue.drain(..take).collect();
        // smallest compiled batch size that fits `take` requests
        let (batch, artifact) = self.by_seq[&seq]
            .iter()
            .find(|(b, _)| *b as usize >= take)
            .cloned()
            .unwrap_or_else(|| self.by_seq[&seq].last().cloned().unwrap());
        Some(Batch {
            bucket: Bucket { batch, seq, artifact },
            requests: reqs,
            formed: now,
        })
    }

    /// Hand back every pending decode slot (shutdown / draining) — the
    /// decode lane counterpart of [`Batcher::drain`], so in-flight
    /// sequences are never silently dropped.
    pub fn drain_decode(&mut self) -> Vec<DecodeSlot> {
        std::mem::take(&mut self.decode_pending)
    }

    /// Flush everything regardless of deadlines (shutdown / draining).
    pub fn drain(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        let far_future = Instant::now() + Duration::from_secs(3600);
        // force deadline expiry by zeroing linger temporarily
        let saved = self.linger;
        self.linger = Duration::ZERO;
        while let Some(b) = self.pop_ready(far_future) {
            out.push(b);
        }
        self.linger = saved;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buckets() -> Vec<(u64, u64, String)> {
        vec![
            (1, 32, "b1_s32".into()),
            (1, 64, "b1_s64".into()),
            (4, 64, "b4_s64".into()),
            (8, 64, "b8_s64".into()),
            (1, 128, "b1_s128".into()),
        ]
    }

    fn batcher() -> Batcher {
        Batcher::new(&buckets(), Duration::from_millis(5)).unwrap()
    }

    fn req(id: u64, len: usize) -> Request {
        Request::new(id, vec![1; len])
    }

    #[test]
    fn routes_to_smallest_sufficient_seq() {
        let b = batcher();
        assert_eq!(b.route(10).unwrap(), 32);
        assert_eq!(b.route(32).unwrap(), 32);
        assert_eq!(b.route(33).unwrap(), 64);
        assert_eq!(b.route(128).unwrap(), 128);
        assert!(b.route(129).is_err());
    }

    #[test]
    fn fills_largest_batch_when_demand_high() {
        let mut b = batcher();
        for i in 0..9 {
            b.push(req(i, 50)).unwrap();
        }
        let batch = b.pop_ready(Instant::now()).unwrap();
        assert_eq!(batch.bucket.batch, 8);
        assert_eq!(batch.bucket.artifact, "b8_s64");
        assert_eq!(batch.requests.len(), 8);
        assert_eq!(b.pending_count(), 1);
    }

    #[test]
    fn linger_flushes_partial_batch_into_smallest_fit() {
        let mut b = batcher();
        b.push(req(1, 50)).unwrap();
        b.push(req(2, 40)).unwrap();
        // before the deadline: nothing
        assert!(b.pop_ready(Instant::now()).is_none());
        // after the deadline: both flushed into the 4-batch (smallest >= 2)
        let later = Instant::now() + Duration::from_millis(10);
        let batch = b.pop_ready(later).unwrap();
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(batch.bucket.batch, 4);
    }

    #[test]
    fn padded_ids_layout() {
        let bucket = Bucket { batch: 2, seq: 4, artifact: "x".into() };
        let batch = Batch {
            bucket,
            requests: vec![Request::new(1, vec![7, 8, 9]), Request::new(2, vec![5])],
            formed: Instant::now(),
        };
        assert_eq!(batch.padded_ids(), vec![7, 8, 9, 0, 5, 0, 0, 0]);
        assert!((batch.padding_fraction() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn decode_slots_ride_along_with_prefill_batches() {
        let mut b = batcher();
        for i in 0..8 {
            b.push(req(i, 50)).unwrap();
        }
        for i in 0..3u64 {
            b.push_decode(DecodeSlot { id: 100 + i, cache_len: 64 + i });
        }
        assert_eq!(b.decode_pending_count(), 3);
        let mixed = b.pop_mixed_ready(Instant::now(), 8).unwrap();
        let prefill = mixed.prefill.as_ref().unwrap();
        assert_eq!(prefill.requests.len(), 8);
        assert_eq!(mixed.decode.len(), 3);
        assert_eq!(mixed.max_cache_len(), 66);
        assert_eq!(b.decode_pending_count(), 0);
    }

    #[test]
    fn decode_slots_never_linger() {
        // No prefill demand at all: a lone decode slot still pops.
        let mut b = batcher();
        b.push_decode(DecodeSlot { id: 1, cache_len: 32 });
        let mixed = b.pop_mixed_ready(Instant::now(), 4).unwrap();
        assert!(mixed.prefill.is_none());
        assert_eq!(mixed.decode.len(), 1);
        // both lanes empty: nothing to pop
        assert!(b.pop_mixed_ready(Instant::now(), 4).is_none());
    }

    #[test]
    fn decode_pop_respects_the_batch_cap() {
        let mut b = batcher();
        for i in 0..10u64 {
            b.push_decode(DecodeSlot { id: i, cache_len: 16 });
        }
        let mixed = b.pop_mixed_ready(Instant::now(), 4).unwrap();
        assert_eq!(mixed.decode.len(), 4);
        assert_eq!(b.decode_pending_count(), 6);
        // FIFO order preserved
        assert_eq!(mixed.decode[0].id, 0);
        assert_eq!(mixed.decode[3].id, 3);
    }

    #[test]
    fn drain_empties_all_queues() {
        let mut b = batcher();
        for i in 0..3 {
            b.push(req(i, 20)).unwrap();
        }
        b.push(req(9, 100)).unwrap();
        b.push_decode(DecodeSlot { id: 50, cache_len: 40 });
        let batches = b.drain();
        assert_eq!(b.pending_count(), 0);
        let total: usize = batches.iter().map(|x| x.requests.len()).sum();
        assert_eq!(total, 4);
        // the decode lane drains through its own exit
        let slots = b.drain_decode();
        assert_eq!(slots.len(), 1);
        assert_eq!(b.decode_pending_count(), 0);
    }
}
