//! Long-sequence chunking — the paper's Table III footnote made real:
//! *"For sequences exceeding the maximum length, they are usually
//! segmented into chunks for inference."*
//!
//! A request longer than the largest compiled bucket is split into
//! overlapping chunks; each chunk is served independently (the EMA
//! analysis is per-chunk GEMM — more rows in the input matrix, same
//! computation flow) and the logits are stitched back, preferring the
//! deeper-context half of each overlap.

use super::request::Response;
use super::server::Coordinator;
use anyhow::Result;

/// Chunking policy.
#[derive(Clone, Copy, Debug)]
pub struct ChunkPolicy {
    /// Chunk length in tokens (≤ the coordinator's max bucket).
    pub chunk_len: usize,
    /// Tokens of context overlap between consecutive chunks.
    pub overlap: usize,
}

impl ChunkPolicy {
    pub fn new(chunk_len: usize, overlap: usize) -> Result<Self> {
        anyhow::ensure!(chunk_len > 0, "chunk_len must be positive");
        anyhow::ensure!(overlap < chunk_len, "overlap {overlap} >= chunk_len {chunk_len}");
        Ok(ChunkPolicy { chunk_len, overlap })
    }

    /// Split `tokens` into chunk ranges `(start, end)` with overlap.
    pub fn split(&self, len: usize) -> Vec<(usize, usize)> {
        assert!(len > 0);
        if len <= self.chunk_len {
            return vec![(0, len)];
        }
        let stride = self.chunk_len - self.overlap;
        let mut out = Vec::new();
        let mut start = 0;
        loop {
            let end = (start + self.chunk_len).min(len);
            out.push((start, end));
            if end == len {
                return out;
            }
            start += stride;
        }
    }

    /// For chunk `idx` of `n` spanning `(start, end)`, the sub-range of
    /// positions whose logits this chunk *owns* after stitching: overlap
    /// halves go to the chunk with deeper left context.
    pub fn owned_range(&self, idx: usize, n: usize, start: usize, end: usize) -> (usize, usize) {
        let half = self.overlap / 2;
        let lo = if idx == 0 { start } else { start + self.overlap - half };
        let hi = if idx + 1 == n { end } else { end - half };
        (lo, hi)
    }
}

/// Serve one over-length request by chunking; returns stitched logits
/// (`len × vocab`) plus the per-chunk artifacts used.
pub fn serve_chunked(
    coordinator: &Coordinator,
    tokens: &[i32],
    policy: ChunkPolicy,
) -> Result<(Vec<f32>, Vec<String>)> {
    anyhow::ensure!(!tokens.is_empty(), "empty request");
    anyhow::ensure!(
        policy.chunk_len as u64 <= coordinator.max_len(),
        "chunk_len {} exceeds max bucket {}",
        policy.chunk_len,
        coordinator.max_len()
    );
    let ranges = policy.split(tokens.len());
    let requests: Vec<Vec<i32>> = ranges
        .iter()
        .map(|&(s, e)| tokens[s..e].to_vec())
        .collect();
    let responses: Vec<Response> = coordinator.run_closed_loop(requests)?;
    let vocab = responses[0].vocab;
    let mut logits = vec![0f32; tokens.len() * vocab];
    let mut artifacts = Vec::with_capacity(responses.len());
    let n = ranges.len();
    for (idx, (resp, &(start, end))) in responses.iter().zip(&ranges).enumerate() {
        anyhow::ensure!(resp.vocab == vocab, "vocab drift across chunks");
        let (lo, hi) = policy.owned_range(idx, n, start, end);
        for pos in lo..hi {
            let src = (pos - start) * vocab;
            let dst = pos * vocab;
            logits[dst..dst + vocab].copy_from_slice(&resp.logits[src..src + vocab]);
        }
        artifacts.push(resp.artifact.clone());
    }
    Ok((logits, artifacts))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_request_is_one_chunk() {
        let p = ChunkPolicy::new(64, 16).unwrap();
        assert_eq!(p.split(40), vec![(0, 40)]);
        assert_eq!(p.split(64), vec![(0, 64)]);
    }

    #[test]
    fn chunks_cover_with_overlap() {
        let p = ChunkPolicy::new(64, 16).unwrap();
        let ranges = p.split(200);
        assert_eq!(ranges.first().unwrap().0, 0);
        assert_eq!(ranges.last().unwrap().1, 200);
        for w in ranges.windows(2) {
            assert_eq!(w[0].1 - w[1].0, 16, "overlap preserved");
        }
        // stride = 48: starts at 0, 48, 96, 144 (end 200 <= 144+64)
        assert_eq!(ranges, vec![(0, 64), (48, 112), (96, 160), (144, 200)]);
    }

    #[test]
    fn owned_ranges_partition_the_sequence() {
        let p = ChunkPolicy::new(64, 16).unwrap();
        for len in [65usize, 100, 200, 513, 1000] {
            let ranges = p.split(len);
            let n = ranges.len();
            let mut covered = vec![0u8; len];
            for (idx, &(s, e)) in ranges.iter().enumerate() {
                let (lo, hi) = p.owned_range(idx, n, s, e);
                assert!(s <= lo && hi <= e);
                for c in &mut covered[lo..hi] {
                    *c += 1;
                }
            }
            assert!(
                covered.iter().all(|&c| c == 1),
                "len {len}: positions covered {:?} times",
                covered.iter().filter(|&&c| c != 1).count()
            );
        }
    }

    #[test]
    fn rejects_bad_policies() {
        assert!(ChunkPolicy::new(0, 0).is_err());
        assert!(ChunkPolicy::new(16, 16).is_err());
        assert!(ChunkPolicy::new(16, 32).is_err());
    }

    #[test]
    fn zero_overlap_tiles_exactly() {
        let p = ChunkPolicy::new(50, 0).unwrap();
        let ranges = p.split(120);
        assert_eq!(ranges, vec![(0, 50), (50, 100), (100, 120)]);
        for (idx, &(s, e)) in ranges.iter().enumerate() {
            assert_eq!(p.owned_range(idx, 3, s, e), (s, e));
        }
    }
}
