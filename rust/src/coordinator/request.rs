//! Request/response types crossing the coordinator boundary.

use std::time::{Duration, Instant};

/// Monotonic request identifier.
pub type RequestId = u64;

/// An inference request: a token-id sequence (already tokenised).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    pub arrived: Instant,
}

impl Request {
    pub fn new(id: RequestId, tokens: Vec<i32>) -> Self {
        assert!(!tokens.is_empty(), "empty request");
        Request { id, tokens, arrived: Instant::now() }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// A completed inference.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    /// Logits for the request's own (unpadded) tokens: `[len, vocab]`.
    pub logits: Vec<f32>,
    pub vocab: usize,
    /// Queue + batch + execute time.
    pub latency: Duration,
    /// Which artifact served it, e.g. "bert_b4_s64".
    pub artifact: String,
    /// Tokens of padding added to fit the bucket.
    pub padded_tokens: usize,
}

impl Response {
    /// Argmax token id per position — a smoke-usable prediction.
    pub fn argmax_ids(&self) -> Vec<i32> {
        self.logits
            .chunks_exact(self.vocab)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_per_position() {
        let r = Response {
            id: 1,
            logits: vec![0.1, 0.9, 0.0, /* pos0 -> 1 */ 5.0, -1.0, 2.0 /* pos1 -> 0 */],
            vocab: 3,
            latency: Duration::from_millis(1),
            artifact: "a".into(),
            padded_tokens: 0,
        };
        assert_eq!(r.argmax_ids(), vec![1, 0]);
    }

    #[test]
    #[should_panic(expected = "empty request")]
    fn empty_request_rejected() {
        Request::new(1, vec![]);
    }
}
