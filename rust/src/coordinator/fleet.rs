//! Multi-replica fleet harness: open-loop traffic over N coordinator
//! replicas, with SLO accounting and per-replica Chrome traces.
//!
//! Each replica is the real serving stack — a [`Batcher`], a
//! [`DispatchPlanner`] over its own device group, [`Metrics`], an
//! [`SloTracker`] and a [`Tracer`] — but time is **virtual**: the fleet
//! runs as a discrete-event simulation in microseconds, so a fixed
//! arrival trace yields bit-identical goodput/burn numbers on every run
//! (a wall-clock harness cannot promise that, and the acceptance tests
//! demand it).  Virtual instants are materialised as `epoch + t`, which
//! lets the unmodified batcher apply its linger deadline to simulated
//! arrivals.
//!
//! A dispatch's service time comes from the plan the paper's stack
//! produced for it: `overhead + plan_EMA_words / words_per_us` — the
//! EMA-bound serving regime the paper argues for, so every planner win
//! (PR 1–6) surfaces directly as TTFT/goodput here.
//!
//! The router is pluggable ([`RoutePolicy`]): round-robin,
//! join-shortest-queue on in-flight requests, or cache-affinity keyed on
//! the request's seq bucket — the plan-memo key — so one replica's
//! planner cache serves each bucket's whole stream.

use super::batcher::{Batcher, DecodeSlot};
use super::decisions::DispatchPlanner;
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::Request;
use super::server::{bucket_gemms, DECODE_DISPATCH_CAP, DECODE_LEN_BUCKET};
use crate::dataflow::search::canonical_bucket_key;
use crate::gemm::Tiling;
use crate::models::ArrivalEvent;
use crate::obs::slo::{SloSnapshot, SloSpec, SloTracker};
use crate::obs::span::{TraceEvent, Tracer};
use crate::report::json::{jarr, jf64, jnum, jobj, jopt, jstr};
use crate::util::json::Json;
use crate::util::stats::Summary;
use anyhow::Result;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// How arriving requests pick a replica.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through replicas in arrival order.
    RoundRobin,
    /// Fewest in-flight requests wins (ties to the lowest index).
    JoinShortestQueue,
    /// Hash the request's seq bucket — the planner's plan-memo key — so
    /// each bucket's stream stays on one replica's warm caches.
    CacheAffinity,
}

impl RoutePolicy {
    pub fn parse(s: &str) -> Result<RoutePolicy> {
        match s {
            "rr" | "round-robin" => Ok(RoutePolicy::RoundRobin),
            "jsq" | "join-shortest-queue" => Ok(RoutePolicy::JoinShortestQueue),
            "affinity" | "cache-affinity" => Ok(RoutePolicy::CacheAffinity),
            other => anyhow::bail!("unknown router '{other}' (rr|jsq|affinity)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "rr",
            RoutePolicy::JoinShortestQueue => "jsq",
            RoutePolicy::CacheAffinity => "affinity",
        }
    }
}

/// Model dims every replica serves (the synthetic tiny-BERT by default —
/// the same dims the artifact-free coordinator boots with).
#[derive(Clone, Copy, Debug)]
pub struct FleetModel {
    pub hidden: u64,
    pub ffn: u64,
    pub vocab: u64,
    pub n_layers: u64,
    pub heads: u64,
}

impl Default for FleetModel {
    fn default() -> Self {
        FleetModel { hidden: 128, ffn: 512, vocab: 1000, n_layers: 2, heads: 2 }
    }
}

/// Fleet configuration.  Defaults mirror the synthetic coordinator:
/// tiny-BERT dims, the `(4,64)/(4,128)/(8,256)` bucket ladder, 2 ms
/// linger.
#[derive(Clone, Debug)]
pub struct FleetOptions {
    pub replicas: usize,
    pub route: RoutePolicy,
    pub slo: SloSpec,
    /// SLO accounting window (milliseconds of virtual time).
    pub window_ms: u64,
    pub linger: Duration,
    /// Accelerators in each replica's device group (prefill sharding).
    pub devices_per_replica: u64,
    pub tiling: Tiling,
    pub sram_words: u64,
    /// Compiled (batch, seq, artifact) buckets each replica serves.
    pub buckets: Vec<(u64, u64, String)>,
    pub model: FleetModel,
    /// Service-rate model: DRAM words a device group moves per virtual
    /// microsecond (the EMA-bound regime's only throughput knob).
    pub words_per_us: f64,
    /// Fixed per-dispatch overhead (queueing glue, launch) in µs.
    pub dispatch_overhead_us: u64,
    /// Autoregressive steps per request after prefill (0 = encoder-only).
    pub decode_steps: u64,
    /// Pre-plan every prefill bucket before serving (true mirrors the
    /// server; false leaves cold caches so router affinity is visible).
    pub warm_plans: bool,
    /// Record per-replica Chrome traces.
    pub tracing: bool,
}

impl Default for FleetOptions {
    fn default() -> Self {
        let buckets = [(4u64, 64u64), (4, 128), (8, 256)]
            .iter()
            .map(|&(b, s)| (b, s, format!("synthetic_b{b}_s{s}")))
            .collect();
        FleetOptions {
            replicas: 2,
            route: RoutePolicy::RoundRobin,
            slo: SloSpec::default(),
            window_ms: 100,
            linger: Duration::from_millis(2),
            devices_per_replica: 1,
            tiling: Tiling::square(16),
            sram_words: 256 * 1024,
            buckets,
            model: FleetModel::default(),
            words_per_us: 1000.0,
            dispatch_overhead_us: 50,
            decode_steps: 0,
            warm_plans: false,
            tracing: false,
        }
    }
}

/// One replica's serving stack plus its DES bookkeeping.
struct Replica {
    batcher: Batcher,
    planner: DispatchPlanner,
    metrics: Metrics,
    slo: SloTracker,
    tracer: Tracer,
    /// Virtual µs when the device group frees (0 = idle).
    busy_until: u64,
    /// Requests routed here and not yet fully served (JSQ's signal).
    inflight: u64,
    routed: u64,
    dispatches: u64,
    busy_us: u64,
    /// Fleet-level latency digests (merged across replicas for the
    /// report; these are what the merge-exactness acceptance checks).
    ttft: Summary,
    e2e: Summary,
    tpot: Summary,
}

/// Per-request DES state.
struct ReqState {
    arrived_us: u64,
    replica: usize,
    steps_left: u64,
}

/// Scheduled event. `Complete` carries everything the dispatch decided
/// at pop time; its effects land at the service-completion instant.
enum Ev {
    Arrival(usize),
    Poll(usize),
    Complete(Completion),
}

struct Completion {
    replica: usize,
    /// Prefill requests served: (id, unpadded length).
    prefill: Vec<(u64, usize)>,
    /// Seq bucket of the prefill batch (initial decode cache length).
    prefill_seq: u64,
    decode: Vec<DecodeSlot>,
    service_us: u64,
}

/// One replica's slice of the fleet report.
#[derive(Clone, Debug)]
pub struct ReplicaReport {
    pub routed: u64,
    pub completed: u64,
    pub dispatches: u64,
    pub busy_us: u64,
    pub metrics: MetricsSnapshot,
    pub ttft: Summary,
    pub e2e: Summary,
    pub tpot: Summary,
}

/// The fleet run's result: merged digests, the aggregated SLO snapshot,
/// per-replica detail, and (when tracing) per-replica Chrome events.
#[derive(Debug)]
pub struct FleetReport {
    pub replicas: usize,
    pub route: RoutePolicy,
    pub offered: u64,
    pub rejected: u64,
    pub completed: u64,
    /// Virtual time of the last completion (ms).
    pub makespan_ms: f64,
    pub offered_rate_per_s: Option<f64>,
    pub achieved_rate_per_s: Option<f64>,
    /// Exact fold of the per-replica digests ([`Summary::merge`]).
    pub ttft: Summary,
    pub e2e: Summary,
    pub tpot: Summary,
    pub slo: SloSnapshot,
    pub per_replica: Vec<ReplicaReport>,
    /// Per-replica trace events (empty unless `tracing`).
    pub traces: Vec<Vec<TraceEvent>>,
}

impl FleetReport {
    pub fn to_json(&self) -> Json {
        let dig = |s: &Summary| {
            jobj(vec![
                ("count", jnum(s.count())),
                ("sum_ms", jf64(s.sum())),
                ("min_ms", jopt(s.min())),
                ("max_ms", jopt(s.max())),
                ("p50_ms", jopt(s.p50())),
                ("p99_ms", jopt(s.p99())),
            ])
        };
        jobj(vec![
            ("replicas", jnum(self.replicas as u64)),
            ("router", jstr(self.route.name())),
            ("offered", jnum(self.offered)),
            ("rejected", jnum(self.rejected)),
            ("completed", jnum(self.completed)),
            ("makespan_ms", jf64(self.makespan_ms)),
            ("offered_rate_per_s", jopt(self.offered_rate_per_s)),
            ("achieved_rate_per_s", jopt(self.achieved_rate_per_s)),
            ("ttft", dig(&self.ttft)),
            ("e2e", dig(&self.e2e)),
            ("tpot", dig(&self.tpot)),
            ("slo", self.slo.to_json()),
            (
                "per_replica",
                jarr(
                    self.per_replica
                        .iter()
                        .enumerate()
                        .map(|(i, r)| {
                            jobj(vec![
                                ("replica", jnum(i as u64)),
                                ("routed", jnum(r.routed)),
                                ("completed", jnum(r.completed)),
                                ("dispatches", jnum(r.dispatches)),
                                ("busy_us", jnum(r.busy_us)),
                                (
                                    "utilization",
                                    if self.makespan_ms > 0.0 {
                                        jf64(
                                            r.busy_us as f64
                                                / (self.makespan_ms * 1000.0),
                                        )
                                    } else {
                                        Json::Null
                                    },
                                ),
                                ("ttft_p99_ms", jopt(r.ttft.p99())),
                                (
                                    "planner_cache_hits",
                                    jnum(r.metrics.planner_cache.hits),
                                ),
                                (
                                    "planner_cache_misses",
                                    jnum(r.metrics.planner_cache.misses),
                                ),
                                ("searches", jnum(r.metrics.plan_db.searches)),
                                ("plan_db_hits", jnum(r.metrics.plan_db.db_hits)),
                                ("ema_plan_words", jnum(r.metrics.ema_plan_words)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Run the fleet DES over a fixed arrival trace.  Deterministic: the
/// same options + arrivals yield the identical report on every run.
pub fn run_fleet(opts: &FleetOptions, arrivals: &[ArrivalEvent]) -> Result<FleetReport> {
    anyhow::ensure!(opts.replicas >= 1, "need at least one replica");
    anyhow::ensure!(opts.words_per_us > 0.0, "words_per_us must be positive");
    anyhow::ensure!(!opts.buckets.is_empty(), "need at least one bucket");
    let m = opts.model;
    let epoch = Instant::now();
    let virt = |t_us: u64| epoch + Duration::from_micros(t_us);
    let linger_us = opts.linger.as_micros() as u64;

    let mut replicas: Vec<Replica> = (0..opts.replicas)
        .map(|_| -> Result<Replica> {
            let mut planner = DispatchPlanner::new(
                m.hidden,
                m.ffn,
                m.vocab,
                m.n_layers,
                m.heads,
                opts.tiling,
                opts.sram_words,
                opts.devices_per_replica,
            );
            if opts.warm_plans {
                let keys: Vec<_> = opts
                    .buckets
                    .iter()
                    .map(|(b, s, _)| (Some(b * s), None))
                    .collect();
                planner.warm_up(&keys);
            }
            Ok(Replica {
                batcher: Batcher::new(&opts.buckets, opts.linger)?,
                planner,
                metrics: Metrics::new(),
                slo: SloTracker::new(opts.slo, opts.window_ms),
                tracer: Tracer::new(opts.tracing),
                busy_until: 0,
                inflight: 0,
                routed: 0,
                dispatches: 0,
                busy_us: 0,
                ttft: Summary::default(),
                e2e: Summary::default(),
                tpot: Summary::default(),
            })
        })
        .collect::<Result<_>>()?;

    let mut events: BTreeMap<(u64, u64), Ev> = BTreeMap::new();
    let mut eseq = 0u64;
    let mut push_ev = |events: &mut BTreeMap<(u64, u64), Ev>, t: u64, ev: Ev| {
        events.insert((t, eseq), ev);
        eseq += 1;
    };
    for (i, a) in arrivals.iter().enumerate() {
        push_ev(&mut events, a.t_us, Ev::Arrival(i));
    }

    let mut reqs: BTreeMap<u64, ReqState> = BTreeMap::new();
    let mut rr_next = 0usize;
    let mut rejected = 0u64;
    let mut completed = 0u64;
    let mut last_t = 0u64;

    // Attempt one dispatch on replica `ri` at virtual time `t`; returns
    // the scheduled completion (pushed by the caller — borrow rules).
    let try_dispatch = |r: &mut Replica, ri: usize, t: u64| -> Option<(u64, Completion)> {
        if r.busy_until > t {
            return None;
        }
        let mixed = r.batcher.pop_mixed_ready(virt(t), DECODE_DISPATCH_CAP)?;
        r.metrics
            .record_queue_depth(r.batcher.pending_count(), r.batcher.decode_pending_count());
        let prefill_tokens = mixed
            .prefill
            .as_ref()
            .map(|b| b.bucket.batch * b.bucket.seq);
        let decode_key = if mixed.decode.is_empty() {
            None
        } else {
            let bucket_len =
                mixed.max_cache_len().div_ceil(DECODE_LEN_BUCKET) * DECODE_LEN_BUCKET;
            Some((mixed.decode.len() as u64, bucket_len))
        };
        let service_us;
        {
            let planned = r.planner.plan_dispatch(prefill_tokens, decode_key);
            let total_words = planned.prefill().map(|p| p.total_ema()).unwrap_or(0)
                + planned.decode().map(|d| d.total_ema()).unwrap_or(0);
            service_us = opts.dispatch_overhead_us
                + (total_words as f64 / opts.words_per_us).ceil() as u64;
            let exec = Duration::from_micros(service_us);
            if let Some(batch) = mixed.prefill.as_ref() {
                let tokens = batch.bucket.batch * batch.bucket.seq;
                let gemms = bucket_gemms(tokens, m.hidden, m.ffn, m.vocab, m.n_layers);
                let flops: u64 = gemms.iter().map(|g| g.total_macs()).sum();
                let real: u64 = batch.requests.iter().map(|q| q.len() as u64).sum();
                let layer_plan = planned
                    .prefill()
                    .expect("a dispatched prefill batch always has a layer plan");
                r.metrics.record_batch(
                    batch.requests.len(),
                    real,
                    tokens - real,
                    exec,
                    &gemms,
                    &opts.tiling,
                    layer_plan,
                    flops,
                );
                r.metrics
                    .record_batch_occupancy(batch.requests.len(), batch.bucket.batch as usize);
            }
            if let Some(step_plan) = planned.decode() {
                r.metrics
                    .record_decode_batch(mixed.decode.len(), step_plan, exec);
            }
        }
        r.metrics.record_planner_cache(r.planner.cache_stats());
        r.metrics.record_search_stats(r.planner.search_stats());
        let done = t + service_us;
        r.busy_until = done;
        r.busy_us += service_us;
        r.dispatches += 1;
        if r.tracer.enabled() {
            let label = match (&mixed.prefill, mixed.decode.len()) {
                (Some(b), 0) => format!("prefill b{}_s{}", b.bucket.batch, b.bucket.seq),
                (Some(b), d) => {
                    format!("mixed b{}_s{}+d{d}", b.bucket.batch, b.bucket.seq)
                }
                (None, d) => format!("decode d{d}"),
            };
            r.tracer.span_at("device", &label, t, service_us);
        }
        let (prefill, prefill_seq) = match mixed.prefill {
            Some(b) => (
                b.requests.iter().map(|q| (q.id, q.len())).collect(),
                b.bucket.seq,
            ),
            None => (Vec::new(), 0),
        };
        Some((
            done,
            Completion {
                replica: ri,
                prefill,
                prefill_seq,
                decode: mixed.decode,
                service_us,
            },
        ))
    };

    while let Some(((t, _), ev)) = events.pop_first() {
        last_t = last_t.max(t);
        match ev {
            Ev::Arrival(i) => {
                let a = arrivals[i];
                let len = a.tokens.max(1) as usize;
                if len as u64 > replicas[0].batcher.max_len() {
                    rejected += 1;
                    continue;
                }
                let ri = match opts.route {
                    RoutePolicy::RoundRobin => {
                        let ri = rr_next % opts.replicas;
                        rr_next += 1;
                        ri
                    }
                    RoutePolicy::JoinShortestQueue => replicas
                        .iter()
                        .enumerate()
                        .min_by_key(|(i, r)| (r.inflight, *i))
                        .map(|(i, _)| i)
                        .expect("replicas is non-empty"),
                    RoutePolicy::CacheAffinity => {
                        // Route on the canonical spec key, not the raw
                        // seq-bucket position: dim-congruent buckets
                        // (same tile-grid token count and SRAM class)
                        // generate identical plan-database specs, so
                        // they belong on the replica whose database is
                        // already warm.
                        let seq = replicas[0].batcher.route(len)?;
                        let batch = opts
                            .buckets
                            .iter()
                            .find(|(_, s, _)| *s == seq)
                            .map(|(b, _, _)| *b)
                            .unwrap_or(1);
                        let key =
                            canonical_bucket_key(batch * seq, opts.tiling, opts.sram_words);
                        (key % opts.replicas as u64) as usize
                    }
                };
                let id = i as u64;
                let mut req = Request::new(id, vec![1; len]);
                req.arrived = virt(t);
                let r = &mut replicas[ri];
                r.batcher.push(req)?;
                r.routed += 1;
                r.inflight += 1;
                if r.tracer.enabled() {
                    r.tracer.instant_at("queue", &format!("arrive req {id}"), t);
                }
                reqs.insert(
                    id,
                    ReqState { arrived_us: t, replica: ri, steps_left: opts.decode_steps },
                );
                // This request's linger deadline: the latest instant a
                // pop must include it (no-op if dispatched earlier).
                push_ev(&mut events, t + linger_us, Ev::Poll(ri));
                if let Some((done, c)) = try_dispatch(&mut replicas[ri], ri, t) {
                    push_ev(&mut events, done, Ev::Complete(c));
                }
            }
            Ev::Poll(ri) => {
                if let Some((done, c)) = try_dispatch(&mut replicas[ri], ri, t) {
                    push_ev(&mut events, done, Ev::Complete(c));
                }
            }
            Ev::Complete(c) => {
                let ri = c.replica;
                let service_ms = c.service_us as f64 / 1000.0;
                {
                    let r = &mut replicas[ri];
                    for &(id, _len) in &c.prefill {
                        let st = reqs.get_mut(&id).expect("completed request is tracked");
                        let ttft_ms = (t - st.arrived_us) as f64 / 1000.0;
                        r.metrics
                            .record_ttft(Duration::from_micros(t - st.arrived_us));
                        r.slo.observe_ttft_at(t, ttft_ms);
                        r.ttft.push(ttft_ms);
                        if st.steps_left == 0 {
                            finish(r, &mut reqs, id, t, &mut completed);
                        } else {
                            r.batcher
                                .push_decode(DecodeSlot { id, cache_len: c.prefill_seq });
                        }
                    }
                    if !c.decode.is_empty() {
                        // One TPOT sample per decode dispatch (every slot
                        // advanced one token in `service_us`), mirroring
                        // the server's accounting.
                        r.slo.observe_tpot_at(t, service_ms);
                        r.tpot.push(service_ms);
                        for slot in &c.decode {
                            let st =
                                reqs.get_mut(&slot.id).expect("decoding request is tracked");
                            st.steps_left -= 1;
                            if st.steps_left == 0 {
                                finish(r, &mut reqs, slot.id, t, &mut completed);
                            } else {
                                r.batcher.push_decode(DecodeSlot {
                                    id: slot.id,
                                    cache_len: slot.cache_len + 1,
                                });
                            }
                        }
                    }
                }
                if let Some((done, c)) = try_dispatch(&mut replicas[ri], ri, t) {
                    push_ev(&mut events, done, Ev::Complete(c));
                }
            }
        }
    }

    // Fold the per-replica digests and SLO windows into fleet totals.
    let slo = SloTracker::new(opts.slo, opts.window_ms);
    let (mut ttft, mut e2e, mut tpot) =
        (Summary::default(), Summary::default(), Summary::default());
    let mut per_replica = Vec::with_capacity(opts.replicas);
    let mut traces = Vec::new();
    for r in &replicas {
        slo.merge_from(&r.slo);
        ttft.merge(&r.ttft);
        e2e.merge(&r.e2e);
        tpot.merge(&r.tpot);
        per_replica.push(ReplicaReport {
            routed: r.routed,
            completed: r.routed
                - r.inflight.min(r.routed), // still-queued work never completed
            dispatches: r.dispatches,
            busy_us: r.busy_us,
            metrics: r.metrics.snapshot(),
            ttft: r.ttft.clone(),
            e2e: r.e2e.clone(),
            tpot: r.tpot.clone(),
        });
        traces.push(if opts.tracing { r.tracer.events() } else { Vec::new() });
    }
    let offered = arrivals.len() as u64;
    let horizon_s = arrivals.last().map(|a| a.t_us as f64 / 1e6).unwrap_or(0.0);
    let makespan_ms = last_t as f64 / 1000.0;
    Ok(FleetReport {
        replicas: opts.replicas,
        route: opts.route,
        offered,
        rejected,
        completed,
        makespan_ms,
        offered_rate_per_s: if horizon_s > 0.0 {
            Some(offered as f64 / horizon_s)
        } else {
            None
        },
        achieved_rate_per_s: if makespan_ms > 0.0 {
            Some(completed as f64 / (makespan_ms / 1000.0))
        } else {
            None
        },
        ttft,
        e2e,
        tpot,
        slo: slo.snapshot(),
        per_replica,
        traces,
    })
}

/// Finalise one request: e2e accounting, in-flight bookkeeping.
fn finish(
    r: &mut Replica,
    reqs: &mut BTreeMap<u64, ReqState>,
    id: u64,
    t: u64,
    completed: &mut u64,
) {
    let st = reqs.remove(&id).expect("finishing request is tracked");
    let e2e_us = t - st.arrived_us;
    let e2e_ms = e2e_us as f64 / 1000.0;
    r.metrics.record_latency(Duration::from_micros(e2e_us));
    r.slo.observe_e2e_at(t, e2e_ms);
    r.e2e.push(e2e_ms);
    r.inflight -= 1;
    *completed += 1;
    if r.tracer.enabled() {
        r.tracer.instant_at("queue", &format!("complete req {id}"), t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{generate_arrivals, ArrivalProcess, LengthDist};
    use crate::util::prng::Rng;

    fn arrivals(n: usize, rate: f64, seed: u64) -> Vec<ArrivalEvent> {
        let mut rng = Rng::new(seed);
        generate_arrivals(
            &ArrivalProcess::poisson(rate),
            &LengthDist::lognormal(80, 0.5, 4, 256),
            &mut rng,
            n,
        )
    }

    #[test]
    fn fleet_serves_every_request_and_is_deterministic() {
        let opts = FleetOptions::default();
        let a = arrivals(128, 400.0, 7);
        let r1 = run_fleet(&opts, &a).unwrap();
        let r2 = run_fleet(&opts, &a).unwrap();
        assert_eq!(r1.completed + r1.rejected, r1.offered);
        assert_eq!(r1.completed, r2.completed);
        assert_eq!(r1.slo.goodput, r2.slo.goodput);
        assert_eq!(r1.slo.checked, r2.slo.checked);
        assert_eq!(r1.ttft.p99(), r2.ttft.p99());
        assert_eq!(r1.makespan_ms, r2.makespan_ms);
        // every replica saw work under round-robin
        assert!(r1.per_replica.iter().all(|p| p.routed > 0));
    }

    #[test]
    fn merged_digests_equal_the_per_replica_union_exactly() {
        let opts = FleetOptions { replicas: 3, ..FleetOptions::default() };
        let r = run_fleet(&opts, &arrivals(200, 500.0, 11)).unwrap();
        let count: u64 = r.per_replica.iter().map(|p| p.ttft.count()).sum();
        let sum: f64 = r.per_replica.iter().map(|p| p.ttft.sum()).sum();
        assert_eq!(r.ttft.count(), count);
        assert!((r.ttft.sum() - sum).abs() < 1e-6);
        let min = r
            .per_replica
            .iter()
            .filter_map(|p| p.ttft.min())
            .fold(f64::INFINITY, f64::min);
        assert_eq!(r.ttft.min(), Some(min));
        // SLO windows merge exactly too: checked == sum of replicas
        let checked: u64 = r
            .per_replica
            .iter()
            .map(|p| p.metrics.ttft_count + p.metrics.tpot_count)
            .sum();
        assert_eq!(r.slo.checked, checked);
    }

    #[test]
    fn decode_lane_runs_when_steps_are_requested() {
        let opts = FleetOptions { decode_steps: 4, ..FleetOptions::default() };
        let r = run_fleet(&opts, &arrivals(64, 300.0, 3)).unwrap();
        assert_eq!(r.completed + r.rejected, r.offered);
        assert!(r.tpot.count() > 0, "decode dispatches must sample TPOT");
        let decode_tokens: u64 =
            r.per_replica.iter().map(|p| p.metrics.decode_tokens).sum();
        assert_eq!(decode_tokens, r.completed * 4);
        // e2e strictly dominates TTFT once decoding follows prefill
        assert!(r.e2e.p50() >= r.ttft.p50());
    }

    #[test]
    fn goodput_is_monotone_non_increasing_in_rate() {
        let opts = FleetOptions::default();
        let mut last = f64::INFINITY;
        for rate in [50.0, 200.0, 800.0, 3200.0] {
            let r = run_fleet(&opts, &arrivals(256, rate, 13)).unwrap();
            let g = r.slo.goodput.expect("completed requests were checked");
            assert!(
                g <= last + 1e-9,
                "goodput must not improve as rate climbs: {g} after {last} at {rate}/s"
            );
            last = g;
        }
    }

    #[test]
    fn jsq_beats_round_robin_p99_ttft_under_bursty_arrivals() {
        let mut rng = Rng::new(23);
        let a = generate_arrivals(
            &ArrivalProcess::bursty(3000.0, 0.04, 0.08),
            &LengthDist::lognormal(80, 0.5, 4, 256),
            &mut rng,
            512,
        );
        let rr = run_fleet(
            &FleetOptions { route: RoutePolicy::RoundRobin, ..FleetOptions::default() },
            &a,
        )
        .unwrap();
        let jsq = run_fleet(
            &FleetOptions {
                route: RoutePolicy::JoinShortestQueue,
                ..FleetOptions::default()
            },
            &a,
        )
        .unwrap();
        let (rr99, jsq99) = (rr.ttft.p99().unwrap(), jsq.ttft.p99().unwrap());
        assert!(
            jsq99 < rr99,
            "JSQ p99 TTFT {jsq99} must beat round-robin {rr99} under bursts"
        );
    }

    #[test]
    fn cache_affinity_takes_fewer_planner_misses_than_round_robin() {
        let misses = |route| {
            let opts = FleetOptions { replicas: 3, route, ..FleetOptions::default() };
            run_fleet(&opts, &arrivals(256, 600.0, 31))
                .unwrap()
                .per_replica
                .iter()
                .map(|p| p.metrics.planner_cache.misses)
                .sum::<u64>()
        };
        let (rr, aff) = (misses(RoutePolicy::RoundRobin), misses(RoutePolicy::CacheAffinity));
        assert!(
            aff < rr,
            "affinity misses {aff} must undercut round-robin {rr} on cold caches"
        );
    }

    #[test]
    fn cache_affinity_routes_congruent_buckets_to_one_warm_database() {
        // (4,125) and (4,128) pad to 500 and 512 tokens — different
        // shapes, same 32-row tile grid, so every GEMM spec they plan is
        // congruent.  The canonical-key router lands both on the same
        // replica, whose plan database reprices its stored choices
        // instead of searching again; round-robin alternates them across
        // cold replicas, which each pay a full search.
        let buckets: Vec<(u64, u64, String)> = [(4u64, 125u64), (4, 128)]
            .iter()
            .map(|&(b, s)| (b, s, format!("synthetic_b{b}_s{s}")))
            .collect();
        let a: Vec<ArrivalEvent> = (0..64)
            .map(|i| ArrivalEvent {
                t_us: i * 500,
                tokens: if i % 2 == 0 { 120 } else { 127 },
            })
            .collect();
        let searches = |route| {
            let opts =
                FleetOptions { route, buckets: buckets.clone(), ..FleetOptions::default() };
            run_fleet(&opts, &a)
                .unwrap()
                .per_replica
                .iter()
                .map(|p| p.metrics.plan_db.searches)
                .sum::<u64>()
        };
        let (rr, aff) =
            (searches(RoutePolicy::RoundRobin), searches(RoutePolicy::CacheAffinity));
        assert!(
            aff < rr,
            "affinity searches {aff} must undercut round-robin {rr} on a \
             congruent-heavy trace"
        );
    }

    #[test]
    fn oversized_requests_are_rejected_not_served() {
        let opts = FleetOptions::default();
        let a = vec![
            ArrivalEvent { t_us: 0, tokens: 40 },
            ArrivalEvent { t_us: 10, tokens: 100_000 },
            ArrivalEvent { t_us: 20, tokens: 60 },
        ];
        let r = run_fleet(&opts, &a).unwrap();
        assert_eq!(r.rejected, 1);
        assert_eq!(r.completed, 2);
    }

    #[test]
    fn report_serialises_to_valid_json() {
        let opts = FleetOptions { tracing: true, ..FleetOptions::default() };
        let r = run_fleet(&opts, &arrivals(32, 200.0, 5)).unwrap();
        let text = r.to_json().to_string_compact();
        assert!(!text.contains("NaN"));
        let doc = Json::parse(&text).expect("fleet report must parse");
        assert_eq!(doc.get("completed").unwrap().as_u64(), Some(r.completed));
        assert!(r.traces.iter().any(|t| !t.is_empty()), "tracing was on");
        // empty run parses too
        let empty = run_fleet(&FleetOptions::default(), &[]).unwrap();
        Json::parse(&empty.to_json().to_string_compact()).unwrap();
    }
}
